//! Cross-engine equivalence: the discrete-event simulator, the live
//! threaded engine and a minimal serialized reference driver all drive
//! the *same* [`RelayCoordinator`] event API — so for a seeded trace the
//! per-request [`CacheOutcome`] sequences must be identical across
//! engines.  A divergence means an engine made (or skipped) a decision
//! the coordinator did not make — exactly the policy drift this
//! refactor exists to prevent.

use relaygr::cluster::{drive_reference, run_reference, run_sim, SimConfig};
use relaygr::relay::baseline::Mode;
use relaygr::relay::coordinator::{
    QueuedReload, RankAction, RelayCoordinator, SignalAction, Stage,
};
use relaygr::relay::pipeline::CacheOutcome;
use relaygr::relay::tier::{DramPolicy, EvictPolicy, TierConfig};
use relaygr::relay::trigger::AdmissionMode;
use relaygr::workload::{generate, ScenarioKind, WorkloadConfig};

fn workload(dram: bool) -> WorkloadConfig {
    WorkloadConfig {
        qps: 40.0,
        duration_us: 6_000_000,
        num_users: 5_000,
        fixed_long_len: Some(4096),
        max_prefix: 4096,
        refresh_prob: if dram { 0.6 } else { 0.0 },
        seed: 1234,
        ..Default::default()
    }
}

fn sim_outcomes(cfg: &SimConfig, wl: &WorkloadConfig) -> Vec<(u64, CacheOutcome)> {
    let mut cfg = cfg.clone();
    cfg.log_outcomes = true;
    let m = run_sim(cfg, wl).expect("simulation runs");
    let mut log = m.outcome_log();
    log.sort_by_key(|&(id, _)| id);
    log
}

/// Strict equivalence (no DRAM tier, no refresh bursts): the simulator
/// and the serialized reference must classify every request identically.
#[test]
fn sim_and_serial_driver_agree_exactly() {
    let wl = workload(false);
    let mut cfg = SimConfig::standard(Mode::RelayGr { dram: DramPolicy::Disabled });
    // The two drivers evaluate lease expiry at slightly different clock
    // points (arrival vs pipeline time); a T_life longer than the trace
    // removes that boundary so any remaining divergence is a genuine
    // policy difference, not a timing artifact.
    cfg.pipeline.t_life_us = 2 * wl.duration_us;
    let sim_log = sim_outcomes(&cfg, &wl);
    let serial = run_reference(&cfg, &wl).expect("serialized reference runs").outcomes;
    assert_eq!(sim_log.len(), serial.len(), "both engines serve the whole trace");
    for (a, b) in sim_log.iter().zip(&serial) {
        assert_eq!(a, b, "request {} classified differently across engines", a.0);
    }
    // Sanity: the trace actually exercised the relay path.
    assert!(sim_log.iter().any(|&(_, o)| o == CacheOutcome::HbmHit), "no relay traffic");
    assert!(sim_log.iter().any(|&(_, o)| o == CacheOutcome::FullInference), "no normal traffic");
}

/// The bounded streaming comparator reproduces the full-log equivalence
/// check without materializing the simulator's outcome log: the
/// serialized reference's outcomes become a dense expectation table and
/// the simulator checks each completion against it in O(1) memory per
/// request.  This is the memory-bounded path scale replays rely on.
#[test]
fn streaming_outcome_check_matches_serial_reference() {
    let wl = workload(false);
    let mut cfg = SimConfig::standard(Mode::RelayGr { dram: DramPolicy::Disabled });
    cfg.pipeline.t_life_us = 2 * wl.duration_us;
    let serial = run_reference(&cfg, &wl).expect("serialized reference runs").outcomes;
    let table = std::sync::Arc::new(relaygr::metrics::outcome_table(serial.iter().copied()));

    let mut check_cfg = cfg.clone();
    check_cfg.outcome_check = Some(table.clone());
    let m = run_sim(check_cfg, &wl).expect("simulation runs");
    let check = m.outcome_check().expect("check mode was requested");
    assert!(
        check.matches(),
        "streaming compare diverged: seen {} of {}, first mismatches {:?}",
        check.seen,
        serial.len(),
        check.mismatches
    );
    assert!(m.outcome_log().is_empty(), "check mode must not accumulate a log");

    // A poisoned table must be detected (and reported boundedly).
    let mut bad = table.as_ref().clone();
    let flip = bad.iter().position(|&c| c != 0).expect("table is non-empty");
    bad[flip] = if bad[flip] == 1 { 2 } else { 1 };
    let mut bad_cfg = cfg.clone();
    bad_cfg.outcome_check = Some(std::sync::Arc::new(bad));
    let m = run_sim(bad_cfg, &wl).expect("simulation runs");
    let check = m.outcome_check().expect("check mode was requested");
    assert!(!check.matches(), "poisoned expectation table must be flagged");
    assert_eq!(check.mismatches.len(), 1, "exactly one entry was poisoned");
    assert_eq!(check.mismatches[0].request, flip as u64);
}

/// `--admission static` (the default) must stay decision-for-decision
/// identical to the pre-adaptive trigger on *every* scenario: the
/// simulator and the serialized reference classify each request the
/// same way under the strict shape (no DRAM tier, no refresh bursts,
/// T_life beyond the trace).
#[test]
fn static_admission_identical_across_engines_on_all_scenarios() {
    for name in ScenarioKind::NAMES {
        let mut wl = workload(false);
        wl.scenario = ScenarioKind::parse(name).expect("built-in scenario");
        let mut cfg = SimConfig::standard(Mode::RelayGr { dram: DramPolicy::Disabled });
        cfg.pipeline.t_life_us = 2 * wl.duration_us;
        assert_eq!(cfg.admission.mode, AdmissionMode::Static, "static is the default");
        let sim_log = sim_outcomes(&cfg, &wl);
        let serial = run_reference(&cfg, &wl).expect("serialized reference runs").outcomes;
        assert_eq!(sim_log.len(), serial.len(), "{name}: trace length");
        for (a, b) in sim_log.iter().zip(&serial) {
            assert_eq!(a, b, "{name}: request {} classified differently across engines", a.0);
        }
        assert!(
            sim_log.iter().any(|&(_, o)| o == CacheOutcome::HbmHit),
            "{name}: no relay traffic"
        );
    }
}

/// Tentpole: the closed-loop controller's signals are all
/// decision-synchronous (observed footprints, metadata estimates,
/// arrival clocks — never completion timing), so adaptive admission
/// must *also* be decision-identical across engines — here under the
/// misprovisioned shape where the static bound collapses (`L_max = 0`)
/// and the adaptive bound does all the work.
#[test]
fn adaptive_admission_identical_across_engines_and_beats_collapsed_bound() {
    let mut wl = workload(false);
    wl.long_frac = 0.2;
    wl.fixed_long_len = Some(3072);
    wl.max_prefix = 3072;
    wl.scenario = ScenarioKind::parse("burst").unwrap();
    let run = |mode: AdmissionMode| {
        let mut cfg = SimConfig::standard(Mode::RelayGr { dram: DramPolicy::Disabled });
        cfg.pipeline.t_life_us = 2 * wl.duration_us;
        // Provisioned worst-case ψ (32K tokens ≈ 512 MB) exceeds the 1%
        // r1 slice (≈ 344 MB): the static Eq. 2 bound admits nothing.
        cfg.r1 = 0.01;
        cfg.kv_p99_prefix = 32_768;
        cfg.admission.mode = mode;
        let sim_log = sim_outcomes(&cfg, &wl);
        let serial = run_reference(&cfg, &wl).expect("serialized reference runs");
        assert_eq!(
            sim_log, serial.outcomes,
            "{mode:?}: engines diverged on per-request outcomes"
        );
        (sim_log, serial)
    };
    let (_, stat) = run(AdmissionMode::Static);
    let (_, adpt) = run(AdmissionMode::Adaptive);
    assert_eq!(stat.trigger.admitted, 0, "collapsed static bound admits nothing");
    assert!(stat.trigger.footprint_limited > 0);
    assert!(adpt.trigger.admitted > 0, "adaptive admits against observed footprints");
    assert!(
        adpt.trigger.footprint_limited < stat.trigger.footprint_limited,
        "adaptive fp-limited {} !< static {}",
        adpt.trigger.footprint_limited,
        stat.trigger.footprint_limited
    );
    // More relay service, less full inference — and no lost productions
    // (the occupancy-aware bound never outruns the ψ window).
    let full = |r: &relaygr::cluster::ReferenceRun| r.outcome_counts[0];
    assert!(full(&adpt) < full(&stat));
    assert_eq!((adpt.hbm.lost, adpt.hbm.rejected), (0, 0), "{:?}", adpt.hbm);
    assert!(adpt.trigger.l_max_effective > 0);
}

/// Tentpole: the coordinator's batch former groups rank *executions*
/// after each request is classified, so microbatching must never move a
/// [`CacheOutcome`] — on every scenario, in both replayable engines,
/// even though the simulator offers passes at rank-exec-ready simulated
/// times and the serialized reference at arrival times (they form
/// *different* batches).  `--batch-window 0` is the unbatched identity
/// configuration: it takes the `Solo` path, touches no batch state, and
/// the whole pre-batching test suite above pins it decision-for-decision
/// against the serialized reference.
#[test]
fn microbatching_never_changes_decisions_across_engines() {
    for name in ScenarioKind::NAMES {
        let mut wl = workload(false);
        // Enough per-instance pressure that multi-member batches really
        // form inside a 100 ms window (the mean-rank check below keeps
        // this test honest about that).
        wl.qps = 250.0;
        wl.scenario = ScenarioKind::parse(name).expect("built-in scenario");
        let run = |window: u64, max: usize, seg_frac: f64| {
            let mut cfg = SimConfig::standard(Mode::RelayGr { dram: DramPolicy::Disabled });
            cfg.pipeline.t_life_us = 2 * wl.duration_us;
            cfg.batch_window_us = window;
            cfg.batch_max = max;
            cfg.segment_frac = seg_frac;
            cfg.log_outcomes = true;
            let m = run_sim(cfg.clone(), &wl).expect("simulation runs");
            let mut sim_log = m.outcome_log();
            sim_log.sort_by_key(|&(id, _)| id);
            let serial = run_reference(&cfg, &wl).expect("serialized reference runs");
            assert_eq!(
                sim_log, serial.outcomes,
                "{name}, window {window}: engines diverged on per-request outcomes"
            );
            (sim_log, m, serial)
        };
        let (w0, w0_m, _) = run(0, 32, 0.0);
        let (batched, batched_m, _) = run(100_000, 8, 0.0);
        assert_eq!(w0, batched, "{name}: batching changed CacheOutcome decisions");
        // Batches actually formed: every ≥2-member pass records the
        // longer shared duration, so the mean strictly rises.
        assert!(
            batched_m.rank_exec.mean() > w0_m.rank_exec.mean(),
            "{name}: no batches formed (mean rank {} !> {})",
            batched_m.rank_exec.mean(),
            w0_m.rank_exec.mean()
        );
        // batch_max 1 fills every batch immediately: grouped bookkeeping,
        // solo pricing, identical decisions.
        let (filled, filled_m, _) = run(100_000, 1, 0.0);
        assert_eq!(w0, filled, "{name}: batch_max=1 former changed decisions");
        assert_eq!(
            filled_m.rank_exec.mean().to_bits(),
            w0_m.rank_exec.mean().to_bits(),
            "{name}: batch_max=1 must price exactly like the unbatched path"
        );
        // Segment reuse composes: co-batched members plan before any of
        // them completes, so duplicate candidate segments dedup through
        // the single-flight store — still without moving a ψ decision.
        let (seg, _, seg_serial) = run(100_000, 8, 0.25);
        assert_eq!(w0, seg, "{name}: batching + segment reuse changed decisions");
        assert!(
            seg_serial.segments.hit_ratio() > 0.0,
            "{name}: segment cache unused ({:?})",
            seg_serial.segments
        );
    }
}

/// With the DRAM tier and refresh bursts, cache-path timing may differ
/// across engines for overlapping same-user requests (started vs joined
/// a reload; HBM-resident vs respilled-to-DRAM) — all of those are
/// cache-served.  The serve *class* (cache-served vs full inference vs
/// fallback) must still match per request.
#[test]
fn sim_and_serial_driver_agree_on_service_class() {
    fn class(o: CacheOutcome) -> &'static str {
        match o {
            CacheOutcome::FullInference => "full",
            CacheOutcome::HbmHit | CacheOutcome::DramHit | CacheOutcome::JoinedReload => {
                "cached"
            }
            CacheOutcome::Fallback => "fallback",
            CacheOutcome::Shed => "shed",
        }
    }
    let wl = workload(true);
    let cfg = SimConfig::standard(Mode::RelayGr { dram: DramPolicy::Capacity(500 << 30) });
    let sim_log = sim_outcomes(&cfg, &wl);
    let serial = run_reference(&cfg, &wl).expect("serialized reference runs").outcomes;
    assert_eq!(sim_log.len(), serial.len());
    for (&(id, a), &(_, b)) in sim_log.iter().zip(&serial) {
        assert_eq!(
            class(a),
            class(b),
            "request {id}: sim {a:?} vs serial {b:?} — different service class"
        );
    }
    assert!(sim_log.iter().any(|&(_, o)| matches!(o, CacheOutcome::DramHit | CacheOutcome::JoinedReload)),
        "refresh traffic must exercise the DRAM tier");
}

/// Non-default eviction policies flow through the same coordinator: for
/// every policy the simulator and the serialized reference must agree on
/// the per-request service class, and the DRAM tier must actually bind
/// (small capacity ⇒ evictions occur, so the policy's victim choices are
/// on the decision path of both engines).
#[test]
fn engines_agree_under_nondefault_eviction_policies() {
    fn class(o: CacheOutcome) -> &'static str {
        match o {
            CacheOutcome::FullInference => "full",
            CacheOutcome::HbmHit | CacheOutcome::DramHit | CacheOutcome::JoinedReload => {
                "cached"
            }
            CacheOutcome::Fallback => "fallback",
            CacheOutcome::Shed => "shed",
        }
    }
    let wl = workload(true);
    for policy in [EvictPolicy::Lfu, EvictPolicy::CostAware, EvictPolicy::Lifecycle] {
        // 2 GB over ~32 MB ψ entries: the tier holds ~64 users, far
        // fewer than the trace touches — eviction decisions matter.
        let mut cfg = SimConfig::standard(Mode::RelayGr { dram: DramPolicy::Capacity(2 << 30) });
        cfg.dram_policy = policy;
        let sim_log = sim_outcomes(&cfg, &wl);
        let serial = run_reference(&cfg, &wl).expect("serialized reference runs").outcomes;
        assert_eq!(sim_log.len(), serial.len(), "{policy:?}: trace length");
        for (&(id, a), &(_, b)) in sim_log.iter().zip(&serial) {
            assert_eq!(
                class(a),
                class(b),
                "policy {policy:?}, request {id}: sim {a:?} vs serial {b:?}"
            );
        }
        assert!(
            sim_log
                .iter()
                .any(|&(_, o)| matches!(o, CacheOutcome::DramHit | CacheOutcome::JoinedReload)),
            "{policy:?}: DRAM tier unused"
        );
    }
}

/// Satellite: the coordinator's reload-abort path, driven event by event
/// — a queued promotion whose DRAM entry is evicted mid-flight must
/// abort via `begin_queued_reload`, its joined waiters must fall back,
/// and the freed slot must pass on.  Exact per-request outcomes are
/// asserted (the host completes instantly, so there is no timing slack).
#[test]
fn coordinator_reload_abort_falls_back_joined_waiters() {
    let mut cfg = SimConfig::standard(Mode::RelayGr { dram: DramPolicy::Capacity(1 << 40) });
    cfg.max_reload_concurrency = 1; // force the second reload to queue
    let mut coord: RelayCoordinator<()> =
        RelayCoordinator::new(cfg.coordinator_config(), |_| cfg.estimator()).unwrap();
    let kv = |p: usize| cfg.spec.kv_bytes_for(p);

    // Seed DRAM for a set of users via full relay cycles, keeping the two
    // that landed on the same special instance (affinity-hashed).
    let mut seeded: Vec<(u64, usize)> = Vec::new();
    for user in 0..32u64 {
        let t = user * 50_000; // spaced so admission rate limits never bind
        let (req, wants) = coord.on_arrival(t, user, user, 4096, &[]);
        assert!(wants);
        if let SignalAction::Produce { instance, user, .. } = coord.on_trigger_check(t, req) {
            coord.on_psi_ready(t, instance, user, Some(()));
        }
        coord.on_stage_done(t, req, Stage::Preproc).unwrap();
        let _ = coord.on_rank_start(t, req);
        let _ = coord.rank_compute(t, req);
        let done = coord.on_rank_done(t, req, kv(4096));
        if let Some(bytes) = done.spill {
            if coord.complete_spill(t, done.instance, done.user, bytes, ()) {
                seeded.push((user, done.instance));
            }
        }
    }
    let (inst, (a, b)) = seeded
        .iter()
        .find_map(|&(a, ia)| {
            seeded.iter().find(|&&(b, ib)| b != a && ib == ia).map(|&(b, _)| (ia, (a, b)))
        })
        .expect("two seeded users share a special instance");

    // Two racing rank requests (pre-infer delayed, §3.4 out-of-order):
    // A starts the only reload slot, B queues behind it.
    let now = 2_000_000;
    let (ra, _) = coord.on_arrival(now, 100, a, 4096, &[]);
    let (rb, _) = coord.on_arrival(now, 101, b, 4096, &[]);
    assert_eq!(coord.on_stage_done(now, ra, Stage::Preproc), Some(inst));
    assert_eq!(coord.on_stage_done(now, rb, Stage::Preproc), Some(inst));
    let RankAction::StartReload { bytes } = coord.on_rank_start(now, ra) else {
        panic!("A must start the reload");
    };
    assert_eq!(coord.on_rank_start(now, rb), RankAction::WaitReload, "B queues behind A");

    // B's DRAM entry is evicted mid-flight (stale prefix).
    assert!(coord.invalidate_user(inst, b));

    // A's H2D completes: A wakes, and the freed slot grants B its turn —
    // whose payload is gone, so the reload aborts and B falls back.
    let res = coord.on_reload_done(now + 1_000, inst, a, Some(()), bytes);
    assert!(res.installed);
    assert_eq!(res.woken, vec![ra]);
    assert_eq!(res.next, Some(b));
    match coord.begin_queued_reload(now + 1_000, inst, b) {
        QueuedReload::Aborted { woken, next } => {
            assert_eq!(woken, vec![rb], "joined waiter must be released");
            assert_eq!(next, None);
        }
        other => panic!("expected abort for evicted payload, got {other:?}"),
    }
    assert!(coord.wait_resolved(ra) && coord.wait_resolved(rb));

    let _ = coord.rank_compute(now + 2_000, ra);
    let _ = coord.rank_compute(now + 2_000, rb);
    let da = coord.on_rank_done(now + 2_000, ra, kv(4096));
    let db = coord.on_rank_done(now + 2_000, rb, kv(4096));
    assert_eq!(da.outcome, CacheOutcome::DramHit, "A's promotion succeeded");
    assert_eq!(db.outcome, CacheOutcome::Fallback, "B must fall back, never fetch remotely");
    assert!(!db.cached);
    assert!((db.wait_us - 1_000.0).abs() < 1e-9, "B waited from rank start to the abort");
}

/// The same abort path when the H2D itself fails (`payload = None`):
/// waiters fall back instead of wedging.
#[test]
fn coordinator_failed_reload_payload_falls_back() {
    let cfg = SimConfig::standard(Mode::RelayGr { dram: DramPolicy::Capacity(1 << 40) });
    let mut coord: RelayCoordinator<()> =
        RelayCoordinator::new(cfg.coordinator_config(), |_| cfg.estimator()).unwrap();
    let kv = cfg.spec.kv_bytes_for(4096);

    // Seed one user's DRAM entry.
    let (r1, wants) = coord.on_arrival(0, 1, 7, 4096, &[]);
    assert!(wants);
    if let SignalAction::Produce { instance, user, .. } = coord.on_trigger_check(0, r1) {
        coord.on_psi_ready(0, instance, user, Some(()));
    }
    coord.on_stage_done(0, r1, Stage::Preproc).unwrap();
    let _ = coord.on_rank_start(0, r1);
    let _ = coord.rank_compute(0, r1);
    let done = coord.on_rank_done(0, r1, kv);
    let inst = done.instance;
    assert!(coord.complete_spill(0, inst, 7, done.spill.expect("fresh ψ spills"), ()));

    // A refresh rank request starts the reload; the transfer fails.
    let (r2, _) = coord.on_arrival(400_000, 2, 7, 4096, &[]);
    coord.on_stage_done(400_000, r2, Stage::Preproc).unwrap();
    let RankAction::StartReload { bytes } = coord.on_rank_start(400_000, r2) else {
        panic!("expected reload");
    };
    let res = coord.on_reload_done(400_500, inst, 7, None, bytes);
    assert!(!res.installed);
    assert_eq!(res.woken, vec![r2]);
    let rc = coord.rank_compute(400_500, r2);
    assert!(!rc.cached && rc.payload.is_none());
    let d = coord.on_rank_done(400_500, r2, kv);
    assert_eq!(d.outcome, CacheOutcome::Fallback);
}

/// Tentpole: candidate-segment reuse on the `burst` scenario (hot,
/// heavily overlapping candidate sets, Zipf s ≥ 1.0).  With the segment
/// cache on, the simulator and the serialized reference must (a) still
/// classify every request identically — the segment plane never touches
/// the ψ path — (b) both report a segment hit ratio > 0, and (c) both
/// show strictly lower mean rank-compute time than the reuse-off
/// baseline.
#[test]
fn segment_reuse_cuts_rank_compute_with_identical_outcomes() {
    let wl = WorkloadConfig {
        qps: 50.0,
        duration_us: 6_000_000,
        num_users: 5_000,
        fixed_long_len: Some(4096),
        max_prefix: 4096,
        refresh_prob: 0.0,
        cand_zipf_s: 1.1,
        scenario: ScenarioKind::parse("burst").unwrap(),
        seed: 1234,
        ..Default::default()
    };
    let run = |frac: f64| {
        let mut cfg = SimConfig::standard(Mode::RelayGr { dram: DramPolicy::Disabled });
        cfg.pipeline.t_life_us = 2 * wl.duration_us;
        cfg.segment_frac = frac;
        cfg.log_outcomes = true;
        let m = run_sim(cfg.clone(), &wl).expect("simulation runs");
        let mut sim_log = m.outcome_log();
        sim_log.sort_by_key(|&(id, _)| id);
        let serial = run_reference(&cfg, &wl).expect("serialized reference runs");
        assert_eq!(
            sim_log, serial.outcomes,
            "segment-cache {frac}: engines diverged on per-request outcomes"
        );
        (sim_log, m, serial)
    };
    let (off_log, off_m, off_serial) = run(0.0);
    let (on_log, on_m, on_serial) = run(0.25);
    // With ψ-window headroom (this workload's ψ footprint is far below
    // even the carved-down 75% slice), the segment plane makes no ψ
    // decision: identical classifications.  Under genuine window
    // pressure the partition is explicit contention and ψ outcomes may
    // legitimately shift — that regime is not what this test pins.
    assert_eq!(off_log, on_log, "segment reuse must not perturb CacheOutcome decisions");
    assert_eq!(off_m.segments.lookups, 0, "reuse off ⇒ no segment traffic");
    // Both engines see reuse on the hot candidate sets...
    assert!(on_m.segments.hit_ratio() > 0.0, "sim hit ratio: {:?}", on_m.segments);
    assert!(on_serial.segments.hit_ratio() > 0.0, "serial hit ratio: {:?}", on_serial.segments);
    assert!(on_m.segments.bytes_saved > 0 && on_serial.segments.bytes_saved > 0);
    // ...and both engines' mean rank-compute time strictly drops.
    assert!(
        on_m.rank_exec.mean() < off_m.rank_exec.mean(),
        "sim mean rank {:.1} !< {:.1}",
        on_m.rank_exec.mean(),
        off_m.rank_exec.mean()
    );
    assert!(
        on_serial.mean_rank_us < off_serial.mean_rank_us,
        "serial mean rank {:.1} !< {:.1}",
        on_serial.mean_rank_us,
        off_serial.mean_rank_us
    );
}

/// Segment reuse composed with non-default ψ tier policies and refresh
/// bursts: the DRAM tier binds (evictions occur) while the segment cache
/// dedups candidates — per-request service classes must still agree
/// across engines, and both engines must report segment hits.
#[test]
fn segments_agree_under_nondefault_tier_policies() {
    fn class(o: CacheOutcome) -> &'static str {
        match o {
            CacheOutcome::FullInference => "full",
            CacheOutcome::HbmHit | CacheOutcome::DramHit | CacheOutcome::JoinedReload => {
                "cached"
            }
            CacheOutcome::Fallback => "fallback",
            CacheOutcome::Shed => "shed",
        }
    }
    let wl = workload(true);
    for policy in [EvictPolicy::Lfu, EvictPolicy::CostAware] {
        let mut cfg = SimConfig::standard(Mode::RelayGr { dram: DramPolicy::Capacity(2 << 30) });
        cfg.dram_policy = policy;
        cfg.segment_frac = 0.25;
        cfg.log_outcomes = true;
        let sim_m = run_sim(cfg.clone(), &wl).expect("simulation runs");
        let mut sim_log = sim_m.outcome_log();
        sim_log.sort_by_key(|&(id, _)| id);
        let serial = run_reference(&cfg, &wl).expect("serialized reference runs");
        assert_eq!(sim_log.len(), serial.outcomes.len(), "{policy:?}: trace length");
        for (&(id, a), &(_, b)) in sim_log.iter().zip(&serial.outcomes) {
            assert_eq!(
                class(a),
                class(b),
                "policy {policy:?}, request {id}: sim {a:?} vs serial {b:?}"
            );
        }
        assert!(
            sim_m.segments.hit_ratio() > 0.0 && serial.segments.hit_ratio() > 0.0,
            "{policy:?}: segment cache unused (sim {:?}, serial {:?})",
            sim_m.segments,
            serial.segments
        );
    }
}

/// Tentpole (parallel evaluation plane): the figure grid's rows must be
/// byte-identical at any `--jobs` count — every (scenario, mode) cell
/// builds its own seeded simulator, and the executor merges results in
/// declaration order, so parallelism may only change wall-clock time.
#[test]
fn figure_grid_rows_byte_identical_across_jobs() {
    use relaygr::util::cli::Args;
    let mk = |jobs: &str| {
        Args::parse(
            ["test", "figure", "--quick", "--qps", "40", "--jobs", jobs]
                .iter()
                .map(|s| s.to_string()),
        )
        .unwrap()
    };
    let serial = relaygr::figures::scenarios::grid_rows(&mk("1")).expect("serial grid runs");
    let parallel = relaygr::figures::scenarios::grid_rows(&mk("4")).expect("parallel grid runs");
    assert_eq!(serial.len(), 8, "4 scenarios × 2 modes");
    assert_eq!(serial, parallel, "figure rows must not depend on the job count");
}

/// Satellite (PR 8): the flight recorder is observe-only — a traced run
/// must be decision-for-decision bit-identical to an untraced one, on
/// every scenario, in both replayable engines, and the engines must
/// still agree with each other while tracing.  Any divergence means a
/// span emission leaked into the decision plane.
#[test]
fn tracing_is_decision_invisible_across_engines() {
    for name in ScenarioKind::NAMES {
        let mut wl = workload(false);
        wl.scenario = ScenarioKind::parse(name).expect("built-in scenario");
        let mut cfg = SimConfig::standard(Mode::RelayGr { dram: DramPolicy::Disabled });
        cfg.pipeline.t_life_us = 2 * wl.duration_us;
        let mut traced_cfg = cfg.clone();
        traced_cfg.trace_spans = 1 << 14;

        let plain = sim_outcomes(&cfg, &wl);
        let traced = sim_outcomes(&traced_cfg, &wl);
        assert_eq!(plain, traced, "{name}: tracing changed simulator decisions");

        let serial_plain = run_reference(&cfg, &wl).expect("serialized reference runs");
        let serial_traced = run_reference(&traced_cfg, &wl).expect("serialized reference runs");
        assert_eq!(
            serial_plain.outcomes, serial_traced.outcomes,
            "{name}: tracing changed reference decisions"
        );
        assert_eq!(plain, serial_traced.outcomes, "{name}: engines diverged while tracing");

        // Tracing actually happened — and only when asked for.
        let fl = serial_traced.flight.as_ref().expect("traced run detaches its recorder");
        assert!(fl.emitted() > 0, "{name}: recorder armed but silent");
        assert!(!serial_traced.stages.is_empty(), "{name}: no stage folds");
        assert!(serial_plain.flight.is_none() && serial_plain.stages.is_empty());
    }
}

/// Satellite (PR 8): `relaygr explain` round-trip — a traced simulator
/// run writes its RGSP sidecar; reading it back and reconstructing each
/// request's timeline must (a) reproduce the exact [`CacheOutcome`] the
/// run's own outcome log recorded, and (b) telescope: the per-stage
/// durations sum exactly to the request's recorded e2e interval.
#[test]
fn explain_reconstructs_recorded_outcomes_from_sidecar() {
    let wl = workload(true);
    let mut cfg = SimConfig::standard(Mode::RelayGr { dram: DramPolicy::Capacity(500 << 30) });
    cfg.log_outcomes = true;
    cfg.trace_spans = 1 << 16; // retain everything: the round trip must cover every request
    let m = run_sim(cfg, &wl).expect("simulation runs");
    let fl = m.flight.as_deref().expect("traced run detaches its recorder");
    assert_eq!(fl.dropped(), 0, "retention bound must cover this trace");

    let path = std::env::temp_dir()
        .join("relaygr_cross_engine_explain.rgsp")
        .to_str()
        .unwrap()
        .to_string();
    let (n, bytes) = fl.write_rgsp(&path).expect("sidecar writes");
    assert!(n > 0 && bytes > 0);
    let file = relaygr::relay::flight::read_rgsp(&path).expect("sidecar parses");
    assert_eq!(file.spans.len() as u64, n, "round trip preserves the span count");
    assert_eq!((file.emitted, file.dropped), (fl.emitted(), fl.dropped()));

    let log = m.outcome_log();
    assert!(!log.is_empty());
    assert!(
        log.iter().any(|&(_, o)| matches!(o, CacheOutcome::DramHit | CacheOutcome::JoinedReload)),
        "refresh traffic must exercise the reload spans"
    );
    for &(rid, outcome) in &log {
        let tl = relaygr::relay::flight::timeline(&file.spans, rid)
            .unwrap_or_else(|| panic!("request {rid} completed but has no spans"));
        assert_eq!(
            tl.outcome,
            Some(relaygr::metrics::outcome_index(outcome)),
            "request {rid}: explain reconstructed a different outcome than the run reported"
        );
        let total: u64 = tl.stages.iter().map(|&(_, d)| d).sum();
        assert_eq!(
            total,
            tl.e2e_us(),
            "request {rid}: stage durations must telescope to the e2e interval"
        );
    }
    let _ = std::fs::remove_file(&path);
}

/// The real thing, when artifacts exist: a 1-instance, 1-slot live engine
/// (stage sleeps scaled to ~0, generous wait budget) serves a seeded
/// all-long trace; its per-request outcomes must equal the serialized
/// reference under the *live* coordinator configuration.
#[test]
fn live_engine_matches_serial_reference() {
    use relaygr::runtime::Manifest;
    use relaygr::serve::{LiveCluster, LiveConfig};
    use relaygr::util::rng::Rng;

    let dir = std::env::var("RELAYGR_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    if !std::path::Path::new(&dir).join("manifest.json").exists() {
        eprintln!("skipping: no artifacts");
        return;
    }
    let manifest = Manifest::load(&dir).unwrap();
    let spec = manifest
        .variants()
        .into_iter()
        .min_by_key(|s| s.prefix_len * s.dim * s.layers)
        .unwrap();
    let mut cfg = LiveConfig::new(&dir, spec, Mode::RelayGr { dram: DramPolicy::Disabled });
    // Non-default policy on the tier stack: a cost-aware tier too small
    // to accept any ψ, so every spill is rejected deterministically in
    // both engines (wall-clock reload races would otherwise make exact
    // per-request equality timing-dependent) while the hierarchy + policy
    // code path stays on the live decision flow.
    cfg.tiers = Some(vec![TierConfig::new(1, EvictPolicy::CostAware)]);
    cfg.n_instances = 1;
    cfg.m_slots = 1; // FIFO worker: production always precedes ranking
    cfg.hbm_bytes = 4 << 30; // ample footprint: admission never binds
    cfg.stage_scale = 0.02;
    cfg.wait_budget_us = 5_000_000;
    let wl = WorkloadConfig {
        qps: 10.0,
        duration_us: 2_500_000,
        num_users: 12,
        long_threshold: cfg.long_threshold,
        min_prefix: spec.prefix_len, // every request long → special path
        max_prefix: spec.prefix_len,
        fixed_long_len: Some(spec.prefix_len),
        refresh_prob: 0.0,
        seed: 77,
        ..Default::default()
    };
    let trace = generate(&wl);
    assert!(!trace.is_empty());

    let cluster = LiveCluster::start(cfg.clone()).unwrap();
    let mut rng = Rng::new(9);
    let mut live: Vec<(u64, CacheOutcome)> = Vec::new();
    for req in &trace {
        let lc = cluster.drive_request(*req, &mut rng).unwrap();
        live.push((req.rid(), lc.outcome));
    }
    cluster.shutdown();
    live.sort_by_key(|&(id, _)| id);

    let threshold = cfg.long_threshold;
    let coord: RelayCoordinator<()> = RelayCoordinator::new(cfg.coordinator_config(), |_| {
        Box::new(move |m: &relaygr::relay::trigger::BehaviorMeta| {
            if m.prefix_len > threshold {
                1e9
            } else {
                0.0
            }
        })
    })
    .unwrap();
    let serial =
        drive_reference(coord, trace.iter().copied(), &wl, |_| spec.kv_bytes(), |_, _| 0.0)
            .expect("serialized reference runs")
            .outcomes;
    assert_eq!(live, serial, "live engine diverged from the shared coordinator's decisions");
    assert!(live.iter().all(|&(_, o)| o == CacheOutcome::HbmHit),
        "all-long serialized trace must relay every request: {live:?}");

    // The same trace through a live wall-clock batch former (window
    // leaders time out on the condvar; the serial driver and single slot
    // keep batches at size one): every decision must stay in place —
    // batching may change pricing and timing, never outcomes.
    let mut bcfg = cfg.clone();
    bcfg.batch_window_us = 20_000;
    bcfg.batch_max = 4;
    let cluster = LiveCluster::start(bcfg).unwrap();
    let mut rng = Rng::new(9);
    let mut batched: Vec<(u64, CacheOutcome)> = Vec::new();
    for req in &trace {
        let lc = cluster.drive_request(*req, &mut rng).unwrap();
        batched.push((req.rid(), lc.outcome));
    }
    cluster.shutdown();
    batched.sort_by_key(|&(id, _)| id);
    assert_eq!(batched, serial, "live batch former changed decisions");

    // PR 8: the same trace with the flight recorder armed in the live
    // coordinator — the observe-only contract must hold under wall
    // clocks too: tracing may never move a decision.
    let mut tcfg = cfg.clone();
    tcfg.trace_spans = 1 << 14;
    let cluster = LiveCluster::start(tcfg).unwrap();
    let mut rng = Rng::new(9);
    let mut traced: Vec<(u64, CacheOutcome)> = Vec::new();
    for req in &trace {
        let lc = cluster.drive_request(*req, &mut rng).unwrap();
        traced.push((req.rid(), lc.outcome));
    }
    cluster.shutdown();
    traced.sort_by_key(|&(id, _)| id);
    assert_eq!(traced, serial, "live tracing changed decisions");
}

/// Tentpole (PR 9): at `--cells 1` the cell layer is a structural
/// passthrough, so the cell-aware serialized driver must stay
/// decision-for-decision identical — on every scenario — to the legacy
/// single-coordinator driver, an independent implementation that never
/// heard of cells.  The simulator (which now always routes through the
/// cell layer) must agree with both.
#[test]
fn single_cell_layer_identical_to_legacy_driver_on_all_scenarios() {
    use relaygr::workload::stream;
    for name in ScenarioKind::NAMES {
        let mut wl = workload(false);
        wl.scenario = ScenarioKind::parse(name).expect("built-in scenario");
        let mut cfg = SimConfig::standard(Mode::RelayGr { dram: DramPolicy::Disabled });
        cfg.pipeline.t_life_us = 2 * wl.duration_us;
        assert_eq!(cfg.cells, 1, "single cell is the default");

        // The legacy driver, seeded exactly as `run_reference` seeds it.
        let mut legacy_cfg = cfg.clone();
        let profile = wl.scenario.admission_profile();
        legacy_cfg.admission.seed_operating_point(profile.headroom_init, profile.rate_mult_init);
        let coord: RelayCoordinator<()> =
            RelayCoordinator::new(legacy_cfg.coordinator_config(), |_| legacy_cfg.estimator())
                .unwrap();
        let spec = legacy_cfg.spec;
        let hw = legacy_cfg.hw.clone();
        let legacy = drive_reference(
            coord,
            stream(&wl),
            &wl,
            |p| spec.kv_bytes_for(p),
            move |members, skipped| hw.rank_batched_us(&spec, members, skipped),
        )
        .expect("legacy serialized driver runs");

        let cellaware = run_reference(&cfg, &wl).expect("cell-aware serialized driver runs");
        assert_eq!(
            legacy.outcomes, cellaware.outcomes,
            "{name}: cells=1 diverged from the pre-cell serialized driver"
        );
        assert_eq!(legacy.outcome_counts, cellaware.outcome_counts, "{name}");
        assert_eq!(
            legacy.mean_rank_us.to_bits(),
            cellaware.mean_rank_us.to_bits(),
            "{name}: cells=1 must price rank passes bit-identically"
        );
        let sim_log = sim_outcomes(&cfg, &wl);
        assert_eq!(sim_log, cellaware.outcomes, "{name}: simulator diverged at cells=1");
    }
}

/// Tentpole (PR 9): at `--cells 4` the two-level router, the scripted
/// churn (instance failure + reload storm, cell drain, elastic
/// scale-up/down) and both picker policies are all decisions — so the
/// simulator and the cell-aware serialized reference must classify every
/// request identically for every (picker, churn scenario) combination,
/// and repeating a run must reproduce it exactly.
#[test]
fn multi_cell_engines_agree_across_pickers_and_churn_scenarios() {
    use relaygr::relay::cell::{CellPickerKind, CellScenario};
    let wl = workload(false);
    for picker in [CellPickerKind::Affinity, CellPickerKind::Spread] {
        for scenario in CellScenario::NAMES {
            let mut cfg = SimConfig::standard(Mode::RelayGr { dram: DramPolicy::Disabled });
            cfg.pipeline.t_life_us = 2 * wl.duration_us;
            cfg.router.servers = 8; // divisible by 4 cells
            cfg.cells = 4;
            cfg.cell_picker = picker;
            cfg.cell_scenario = CellScenario::parse(scenario).expect("built-in cell scenario");
            let sim_log = sim_outcomes(&cfg, &wl);
            let serial = run_reference(&cfg, &wl).expect("serialized reference runs");
            assert_eq!(
                sim_log, serial.outcomes,
                "picker {picker:?}, churn {scenario}: engines diverged on per-request outcomes"
            );
            assert_eq!(sim_log.len(), generate(&wl).len(), "every request completes");
            assert_eq!(serial.cells.len(), 4);
            let picks: u64 = serial.cells.iter().map(|c| c.picks).sum();
            assert_eq!(picks as usize, sim_log.len(), "every request picked exactly one cell");
            if scenario == "failure" {
                let fails: u64 = serial.cells.iter().map(|c| c.failures).sum();
                assert!(fails > 0, "{picker:?}: failure script injected no failures");
            }
            // Determinism: the same configuration replays itself.
            let again = run_reference(&cfg, &wl).expect("serialized reference runs");
            assert_eq!(serial.outcomes, again.outcomes, "{picker:?}/{scenario}: not deterministic");
            assert_eq!(serial.cells, again.cells, "{picker:?}/{scenario}: cell reports drifted");
        }
    }
}

/// Satellite (PR 9): the spread picker actually spreads (a user's
/// repeats scatter off their ψ home, which the cross-cell miss counters
/// must surface), while affinity keeps repeats home — so affinity must
/// record strictly fewer cross-cell routes than spread on the same
/// trace, and strictly more HBM hits on a locality-heavy population.
#[test]
fn affinity_picker_beats_spread_on_locality_and_cross_traffic() {
    use relaygr::relay::cell::CellPickerKind;
    let mut wl = workload(false);
    wl.num_users = 200; // small population: repeats against warm caches
    wl.qps = 150.0; // ~4-5 arrivals per user: placement decides the hit rate
    let run = |picker: CellPickerKind| {
        let mut cfg = SimConfig::standard(Mode::RelayGr { dram: DramPolicy::Disabled });
        cfg.pipeline.t_life_us = 2 * wl.duration_us;
        cfg.router.servers = 8;
        cfg.cells = 4;
        cfg.cell_picker = picker;
        cfg.log_outcomes = true;
        let m = run_sim(cfg.clone(), &wl).expect("simulation runs");
        let mut log = m.outcome_log();
        log.sort_by_key(|&(id, _)| id);
        let serial = run_reference(&cfg, &wl).expect("serialized reference runs");
        assert_eq!(log, serial.outcomes, "{picker:?}: engines diverged");
        m
    };
    let aff = run(CellPickerKind::Affinity);
    let spr = run(CellPickerKind::Spread);
    let cross = |m: &relaygr::metrics::RunMetrics| -> u64 {
        m.cells.iter().map(|c| c.cross_routes).sum()
    };
    let miss = |m: &relaygr::metrics::RunMetrics| -> u64 {
        m.cells.iter().map(|c| c.cross_psi_miss).sum()
    };
    assert!(
        cross(&aff) < cross(&spr),
        "affinity cross routes {} !< spread {}",
        cross(&aff),
        cross(&spr)
    );
    assert!(miss(&spr) > 0, "spread must pay cross-cell psi misses");
    assert!(
        aff.outcome_counts[1] > spr.outcome_counts[1],
        "affinity HBM hits {} !> spread {}",
        aff.outcome_counts[1],
        spr.outcome_counts[1]
    );
}
