//! Cross-engine equivalence: the discrete-event simulator, the live
//! threaded engine and a minimal serialized reference driver all drive
//! the *same* [`RelayCoordinator`] event API — so for a seeded trace the
//! per-request [`CacheOutcome`] sequences must be identical across
//! engines.  A divergence means an engine made (or skipped) a decision
//! the coordinator did not make — exactly the policy drift this
//! refactor exists to prevent.

use relaygr::cluster::{run_sim, SimConfig};
use relaygr::relay::baseline::Mode;
use relaygr::relay::coordinator::{RankAction, RelayCoordinator, SignalAction, Stage};
use relaygr::relay::expander::DramPolicy;
use relaygr::relay::pipeline::CacheOutcome;
use relaygr::workload::{generate, GenRequest, WorkloadConfig};

/// Serialized reference driver: each request runs start-to-finish with an
/// instantly-completing host (production, reloads and spills take zero
/// time), using the request's arrival time as the clock.  All decisions
/// still flow through the shared coordinator.
fn drive_serial(
    mut coord: RelayCoordinator<()>,
    trace: &[GenRequest],
    kv_bytes: impl Fn(usize) -> usize,
) -> Vec<(u64, CacheOutcome)> {
    let mut out = Vec::new();
    for req in trace {
        let now = req.arrival_us;
        if coord.on_arrival(now, req.id, req.user, req.prefix_len) {
            match coord.on_trigger_check(now, req.id) {
                SignalAction::Produce { instance, user, .. } => {
                    coord.on_psi_ready(now, instance, user, Some(()));
                }
                SignalAction::Reload { instance, user, bytes } => {
                    let res = coord.on_reload_done(now, instance, user, Some(()), bytes);
                    assert!(res.installed, "instant reload must install");
                }
                SignalAction::None => {}
            }
        }
        coord.on_stage_done(now, req.id, Stage::Retrieval);
        let inst = coord
            .on_stage_done(now, req.id, Stage::Preproc)
            .expect("preproc resolves the ranking instance");
        match coord.on_rank_start(now, req.id) {
            RankAction::Proceed { .. } => {}
            RankAction::StartReload { bytes } => {
                coord.on_reload_done(now, inst, req.user, Some(()), bytes);
            }
            RankAction::Wait | RankAction::WaitReload => {
                panic!("serialized driver has no in-flight work to wait on (req {})", req.id)
            }
        }
        let _ = coord.rank_compute(now, req.id);
        let done = coord.on_rank_done(now, req.id, kv_bytes(req.prefix_len));
        if let Some(bytes) = done.spill {
            coord.complete_spill(done.instance, done.user, bytes, ());
        }
        out.push((req.id, done.outcome));
    }
    out.sort_by_key(|&(id, _)| id);
    out
}

fn workload(dram: bool) -> WorkloadConfig {
    WorkloadConfig {
        qps: 40.0,
        duration_us: 6_000_000,
        num_users: 5_000,
        fixed_long_len: Some(4096),
        max_prefix: 4096,
        refresh_prob: if dram { 0.6 } else { 0.0 },
        seed: 1234,
        ..Default::default()
    }
}

fn sim_outcomes(cfg: &SimConfig, wl: &WorkloadConfig) -> Vec<(u64, CacheOutcome)> {
    let mut cfg = cfg.clone();
    cfg.log_outcomes = true;
    let m = run_sim(cfg, wl).expect("simulation runs");
    let mut log = m.outcome_log;
    log.sort_by_key(|&(id, _)| id);
    log
}

/// Strict equivalence (no DRAM tier, no refresh bursts): the simulator
/// and the serialized reference must classify every request identically.
#[test]
fn sim_and_serial_driver_agree_exactly() {
    let wl = workload(false);
    let mut cfg = SimConfig::standard(Mode::RelayGr { dram: DramPolicy::Disabled });
    // The two drivers evaluate lease expiry at slightly different clock
    // points (arrival vs pipeline time); a T_life longer than the trace
    // removes that boundary so any remaining divergence is a genuine
    // policy difference, not a timing artifact.
    cfg.pipeline.t_life_us = 2 * wl.duration_us;
    let sim_log = sim_outcomes(&cfg, &wl);
    let coord: RelayCoordinator<()> =
        RelayCoordinator::new(cfg.coordinator_config(), |_| cfg.estimator()).unwrap();
    let spec = cfg.spec;
    let serial = drive_serial(coord, &generate(&wl), |p| spec.kv_bytes_for(p));
    assert_eq!(sim_log.len(), serial.len(), "both engines serve the whole trace");
    for (a, b) in sim_log.iter().zip(&serial) {
        assert_eq!(a, b, "request {} classified differently across engines", a.0);
    }
    // Sanity: the trace actually exercised the relay path.
    assert!(sim_log.iter().any(|&(_, o)| o == CacheOutcome::HbmHit), "no relay traffic");
    assert!(sim_log.iter().any(|&(_, o)| o == CacheOutcome::FullInference), "no normal traffic");
}

/// With the DRAM tier and refresh bursts, cache-path timing may differ
/// across engines for overlapping same-user requests (started vs joined
/// a reload; HBM-resident vs respilled-to-DRAM) — all of those are
/// cache-served.  The serve *class* (cache-served vs full inference vs
/// fallback) must still match per request.
#[test]
fn sim_and_serial_driver_agree_on_service_class() {
    fn class(o: CacheOutcome) -> &'static str {
        match o {
            CacheOutcome::FullInference => "full",
            CacheOutcome::HbmHit | CacheOutcome::DramHit | CacheOutcome::JoinedReload => {
                "cached"
            }
            CacheOutcome::Fallback => "fallback",
        }
    }
    let wl = workload(true);
    let cfg = SimConfig::standard(Mode::RelayGr { dram: DramPolicy::Capacity(500 << 30) });
    let sim_log = sim_outcomes(&cfg, &wl);
    let coord: RelayCoordinator<()> =
        RelayCoordinator::new(cfg.coordinator_config(), |_| cfg.estimator()).unwrap();
    let spec = cfg.spec;
    let serial = drive_serial(coord, &generate(&wl), |p| spec.kv_bytes_for(p));
    assert_eq!(sim_log.len(), serial.len());
    for (&(id, a), &(_, b)) in sim_log.iter().zip(&serial) {
        assert_eq!(
            class(a),
            class(b),
            "request {id}: sim {a:?} vs serial {b:?} — different service class"
        );
    }
    assert!(sim_log.iter().any(|&(_, o)| matches!(o, CacheOutcome::DramHit | CacheOutcome::JoinedReload)),
        "refresh traffic must exercise the DRAM tier");
}

/// The real thing, when artifacts exist: a 1-instance, 1-slot live engine
/// (stage sleeps scaled to ~0, generous wait budget) serves a seeded
/// all-long trace; its per-request outcomes must equal the serialized
/// reference under the *live* coordinator configuration.
#[test]
fn live_engine_matches_serial_reference() {
    use relaygr::runtime::Manifest;
    use relaygr::serve::{LiveCluster, LiveConfig};
    use relaygr::util::rng::Rng;

    let dir = std::env::var("RELAYGR_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    if !std::path::Path::new(&dir).join("manifest.json").exists() {
        eprintln!("skipping: no artifacts");
        return;
    }
    let manifest = Manifest::load(&dir).unwrap();
    let spec = manifest
        .variants()
        .into_iter()
        .min_by_key(|s| s.prefix_len * s.dim * s.layers)
        .unwrap();
    let mut cfg = LiveConfig::new(&dir, spec, Mode::RelayGr { dram: DramPolicy::Disabled });
    cfg.n_instances = 1;
    cfg.m_slots = 1; // FIFO worker: production always precedes ranking
    cfg.hbm_bytes = 4 << 30; // ample footprint: admission never binds
    cfg.stage_scale = 0.02;
    cfg.wait_budget_us = 5_000_000;
    let wl = WorkloadConfig {
        qps: 10.0,
        duration_us: 2_500_000,
        num_users: 12,
        long_threshold: cfg.long_threshold,
        min_prefix: spec.prefix_len, // every request long → special path
        max_prefix: spec.prefix_len,
        fixed_long_len: Some(spec.prefix_len),
        refresh_prob: 0.0,
        seed: 77,
        ..Default::default()
    };
    let trace = generate(&wl);
    assert!(!trace.is_empty());

    let cluster = LiveCluster::start(cfg.clone()).unwrap();
    let mut rng = Rng::new(9);
    let mut live: Vec<(u64, CacheOutcome)> = Vec::new();
    for req in &trace {
        let lc = cluster.drive_request(*req, &mut rng).unwrap();
        live.push((req.id, lc.outcome));
    }
    cluster.shutdown();
    live.sort_by_key(|&(id, _)| id);

    let threshold = cfg.long_threshold;
    let coord: RelayCoordinator<()> = RelayCoordinator::new(cfg.coordinator_config(), |_| {
        Box::new(move |m: &relaygr::relay::trigger::BehaviorMeta| {
            if m.prefix_len > threshold {
                1e9
            } else {
                0.0
            }
        })
    })
    .unwrap();
    let serial = drive_serial(coord, &trace, |_| spec.kv_bytes());
    assert_eq!(live, serial, "live engine diverged from the shared coordinator's decisions");
    assert!(live.iter().all(|&(_, o)| o == CacheOutcome::HbmHit),
        "all-long serialized trace must relay every request: {live:?}");
}
