//! Fault-plane determinism: injection draws are pure functions of
//! decision-plane state (plan seed, fault kind, stable request/user id,
//! attempt number) — never clocks, never event ordinals, never executor
//! scheduling.  So a `--faults` spec + seed must reproduce byte-identical
//! per-request outcomes AND a byte-identical [`FaultReport`] across
//! repeat runs, across the sim/reference engines (under the strict
//! timing-insensitive shape), and at any `--jobs` count.  And `--faults
//! none` must be decision-bit-identical to a run that never heard of the
//! fault plane — the PR 9 pin.

use relaygr::cluster::{run_reference, run_sim, SimConfig};
use relaygr::relay::baseline::Mode;
use relaygr::relay::fault::{FaultConfig, FaultReport};
use relaygr::relay::pipeline::CacheOutcome;
use relaygr::relay::tier::DramPolicy;
use relaygr::util::parallel;
use relaygr::workload::{ScenarioKind, WorkloadConfig};

const SPEC: &str = "psi-fail:0.1,trigger-drop:0.05,shed:0.4,retry:2,backoff:200us";

fn workload(scenario: &str) -> WorkloadConfig {
    WorkloadConfig {
        qps: 60.0,
        duration_us: 5_000_000,
        num_users: 800,
        fixed_long_len: Some(4096),
        max_prefix: 4096,
        refresh_prob: 0.0,
        scenario: ScenarioKind::parse(scenario).expect("built-in scenario"),
        seed: 1234,
        ..Default::default()
    }
}

/// Strict engine-identity shape: no DRAM tier, lifecycle beyond the
/// trace — any divergence is a leaked draw, not clock skew.
fn config(spec: &str, cells: usize, wl: &WorkloadConfig) -> SimConfig {
    let mut cfg = SimConfig::standard(Mode::RelayGr { dram: DramPolicy::Disabled });
    cfg.pipeline.t_life_us = 2 * wl.duration_us;
    cfg.router.servers = 8; // divisible by 1 and 2 cells
    cfg.cells = cells;
    cfg.faults = FaultConfig::parse(spec).expect("valid fault spec");
    cfg.log_outcomes = true;
    cfg
}

fn sim_run(cfg: &SimConfig, wl: &WorkloadConfig) -> (Vec<(u64, CacheOutcome)>, FaultReport) {
    let m = run_sim(cfg.clone(), wl).expect("simulation runs");
    let mut log = m.outcome_log();
    log.sort_by_key(|&(id, _)| id);
    (log, m.faults)
}

/// Same spec + seed ⇒ byte-identical outcomes and fault report, run to
/// run and engine to engine.
#[test]
fn same_spec_same_seed_byte_identical_across_runs_and_engines() {
    let wl = workload("steady");
    let cfg = config(SPEC, 1, &wl);
    let (log_a, rep_a) = sim_run(&cfg, &wl);
    let (log_b, rep_b) = sim_run(&cfg, &wl);
    assert_eq!(log_a, log_b, "sim is not run-to-run deterministic under faults");
    assert_eq!(rep_a, rep_b, "fault report is not run-to-run deterministic");

    let serial = run_reference(&cfg, &wl).expect("serialized reference runs");
    assert_eq!(log_a, serial.outcomes, "engines diverged on per-request outcomes");
    assert_eq!(rep_a, serial.faults, "engines diverged on the fault report");
    let again = run_reference(&cfg, &wl).expect("serialized reference runs");
    assert_eq!(serial.outcomes, again.outcomes);
    assert_eq!(serial.faults, again.faults);

    // The plan actually fired — and the retry policy actually recovered.
    let (inj, ret, rec, _, _) = rep_a.totals();
    assert!(inj > 0, "spec injected nothing: {rep_a:?}");
    assert!(ret > 0 && rec > 0, "retries never recovered: {rep_a:?}");

    // A different run seed draws a different fault pattern on the SAME
    // trace (the folded plan seed is live, not vestigial).
    let mut other = cfg.clone();
    other.seed ^= 0xDEAD_BEEF;
    let (_, rep_c) = sim_run(&other, &wl);
    assert_ne!(rep_a, rep_c, "run seed does not reach the fault draws");
}

/// The figure-grid executor may only change wall-clock time: a faulted
/// grid evaluated at `--jobs 1` and `--jobs 4` must produce identical
/// (outcomes, report) pairs for every cell, including the multi-cell
/// scheduled-crash row.
#[test]
fn jobs_count_never_changes_faulted_results() {
    let grid: Vec<(&str, &str, usize)> = vec![
        (SPEC, "steady", 1),
        (SPEC, "burst", 1),
        ("psi-fail:0.1,trigger-drop:0.05", "steady", 1), // retry off
        ("psi-fail:0.1,crash@50%", "steady", 2),
    ];
    let eval = |jobs: usize| -> Vec<(Vec<(u64, CacheOutcome)>, FaultReport)> {
        parallel::map_indexed(jobs, grid.len(), |i| {
            let (spec, scenario, cells) = grid[i];
            let wl = workload(scenario);
            let cfg = config(spec, cells, &wl);
            sim_run(&cfg, &wl)
        })
    };
    let serial = eval(1);
    let threaded = eval(4);
    for (i, (a, b)) in serial.iter().zip(&threaded).enumerate() {
        assert_eq!(a.0, b.0, "grid cell {i}: outcomes depend on the job count");
        assert_eq!(a.1, b.1, "grid cell {i}: fault report depends on the job count");
    }
    // The crash row scheduled its event in both engines identically.
    let crash_row = &serial[3];
    use relaygr::relay::fault::FaultKind;
    // `crash@50%` with no target cell hits every cell once.
    assert_eq!(crash_row.1.injected[FaultKind::Crash.index()], 2, "crash never fired");
    let wl = workload("steady");
    let cfg = config("psi-fail:0.1,crash@50%", 2, &wl);
    let reference = run_reference(&cfg, &wl).expect("serialized reference runs");
    assert_eq!(crash_row.0, reference.outcomes, "crash run diverged across engines");
    assert_eq!(crash_row.1, reference.faults, "crash report diverged across engines");
}

/// `--faults none` is the PR 9 pin: the disabled plane folds no retry
/// budget, draws nothing, sheds nothing, and every decision matches a
/// run whose fault config differs only in its (never-consulted) seed.
#[test]
fn faults_none_is_decision_identical_to_fault_free_runs() {
    for scenario in ["steady", "burst"] {
        let wl = workload(scenario);
        let off = config("none", 1, &wl);
        assert!(!off.faults.enabled());
        assert_eq!(off.faults.retry_budget_us(), 0);
        let (log_off, rep_off) = sim_run(&off, &wl);
        assert!(!rep_off.any(), "{scenario}: disabled plane injected something");
        assert!(
            log_off.iter().all(|&(_, o)| o != CacheOutcome::Shed),
            "{scenario}: disabled plane shed a request"
        );
        // A different plan seed must be invisible when nothing can draw.
        let mut reseeded = off.clone();
        reseeded.faults.seed = 0x5EED;
        let (log_re, rep_re) = sim_run(&reseeded, &wl);
        assert_eq!(log_off, log_re, "{scenario}: dormant fault seed moved a decision");
        assert!(!rep_re.any());
        // And both engines agree, as they always did pre-fault-plane.
        let serial = run_reference(&off, &wl).expect("serialized reference runs");
        assert_eq!(log_off, serial.outcomes, "{scenario}: engines diverged with faults off");
        assert!(!serial.faults.any());
    }
}
