//! Integration: the live threaded serving engine over real artifacts —
//! relay-race correctness under concurrency, fallback safety, DRAM reuse.

use relaygr::relay::baseline::Mode;
use relaygr::relay::tier::DramPolicy;
use relaygr::runtime::Manifest;
use relaygr::serve::{LiveCluster, LiveConfig};
use relaygr::util::rng::Rng;
use relaygr::workload::WorkloadConfig;

fn artifacts_dir() -> Option<String> {
    let dir = std::env::var("RELAYGR_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    std::path::Path::new(&dir).join("manifest.json").exists().then_some(dir)
}

fn smallest_variant(dir: &str) -> relaygr::model::ModelSpec {
    let manifest = Manifest::load(dir).unwrap();
    manifest
        .variants()
        .into_iter()
        .min_by_key(|s| s.prefix_len * s.dim * s.layers)
        .unwrap()
}

fn fast_config(dir: &str, mode: Mode) -> LiveConfig {
    let mut cfg = LiveConfig::new(dir, smallest_variant(dir), mode);
    // Compress the pipeline stages so the test runs in seconds.
    cfg.stage_scale = 0.1;
    cfg
}

fn workload(cfg: &LiveConfig, qps: f64, secs: f64) -> WorkloadConfig {
    WorkloadConfig {
        qps,
        duration_us: (secs * 1e6) as u64,
        num_users: 50,
        long_frac: 0.6,
        long_threshold: cfg.long_threshold,
        min_prefix: 64,
        max_prefix: cfg.spec.prefix_len,
        fixed_long_len: Some(cfg.spec.prefix_len),
        refresh_prob: 0.6,
        refresh_gap_us: (50_000, 200_000),
        seed: 5,
        ..Default::default()
    }
}

#[test]
fn relay_trace_completes_with_cache_hits() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    let cfg = fast_config(&dir, Mode::RelayGr { dram: DramPolicy::Capacity(1 << 30) });
    let wl = workload(&cfg, 25.0, 4.0);
    let cluster = LiveCluster::start(cfg).unwrap();
    let m = cluster.run_trace(&wl).unwrap();
    assert!(m.completed > 40, "{}", m.brief());
    let hits = m.outcome_counts[1] + m.outcome_counts[2] + m.outcome_counts[3];
    assert!(hits > 0, "expected cache hits: {}", m.brief());
    // Every request produced scores (drive_request enforces non-empty).
    assert!(m.rank_exec.count() == m.completed);
    cluster.shutdown();
}

#[test]
fn baseline_trace_never_touches_caches() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    let cfg = fast_config(&dir, Mode::Baseline);
    let wl = workload(&cfg, 15.0, 3.0);
    let cluster = LiveCluster::start(cfg).unwrap();
    let m = cluster.run_trace(&wl).unwrap();
    assert!(m.completed > 20, "{}", m.brief());
    assert_eq!(m.outcome_counts[1] + m.outcome_counts[2] + m.outcome_counts[3], 0);
    assert_eq!(m.admitted, 0);
    cluster.shutdown();
}

#[test]
fn relay_rank_stage_beats_baseline() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    let run = |mode| {
        let cfg = fast_config(&dir, mode);
        let wl = workload(&cfg, 20.0, 4.0);
        let cluster = LiveCluster::start(cfg).unwrap();
        // Warm-up so compile costs don't pollute the comparison.
        let mut rng = Rng::new(3);
        for req in relaygr::workload::generate(&WorkloadConfig {
            qps: 10.0,
            duration_us: 300_000,
            ..wl.clone()
        })
        .into_iter()
        .take(3)
        {
            let _ = cluster.drive_request(req, &mut rng);
        }
        let m = cluster.run_trace(&wl).unwrap();
        cluster.shutdown();
        m
    };
    let base = run(Mode::Baseline);
    let relay = run(Mode::RelayGr { dram: DramPolicy::Capacity(1 << 30) });
    // The relay's ranking critical path must be clearly faster at p50
    // (full inference leaves the critical path for cache hits).
    assert!(
        relay.rank_exec.p50() < base.rank_exec.p50(),
        "relay rank p50 {:.1}µs !< baseline {:.1}µs",
        relay.rank_exec.p50(),
        base.rank_exec.p50()
    );
}

#[test]
fn concurrent_same_user_requests_are_safe() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    // Hammer one user from many threads: single-flight + pseudo-pre-infer
    // must keep everything consistent (no panics, valid scores).
    let cfg = fast_config(&dir, Mode::RelayGr { dram: DramPolicy::Capacity(1 << 30) });
    let threshold = cfg.long_threshold;
    let prefix_len = cfg.spec.prefix_len;
    let cluster = LiveCluster::start(cfg).unwrap();
    std::thread::scope(|s| {
        for i in 0..8u64 {
            let cluster = &cluster;
            s.spawn(move || {
                let mut rng = Rng::new(i);
                let req = relaygr::workload::GenRequest {
                    id: i as u32,
                    arrival_us: 0,
                    user: 777,
                    prefix_len: prefix_len as u32,
                    is_refresh: i > 0,
                };
                let lc = cluster.drive_request(req, &mut rng).unwrap();
                assert!(lc.rank_us > 0.0);
                let _ = threshold;
            });
        }
    });
    cluster.shutdown();
}
