//! Cross-module property and failure-injection tests: the relay state
//! machines composed the way the simulator composes them, under random
//! interleavings, churn and adversarial timing.

use relaygr::cluster::{run_sim, SimConfig};
use relaygr::relay::baseline::Mode;
use relaygr::relay::hbm::EntryState;
use relaygr::relay::hierarchy::{CacheHierarchy, PseudoAction};
use relaygr::relay::router::{Router, RouterConfig};
use relaygr::relay::tier::{DramPolicy, EvictPolicy, TierConfig};
use relaygr::relay::trigger::{BehaviorMeta, Decision, Trigger, TriggerConfig};
use relaygr::util::prop;
use relaygr::util::rng::Rng;
use relaygr::workload::{generate, user_prefix_len, GenRequest, ScenarioKind, WorkloadConfig};

const MB: usize = 1 << 20;

/// The full admission→produce→route→consume→spill→reload cycle — with
/// mid-flight invalidations forcing the reload-abort path — under random
/// interleavings never double-reloads, never overcommits HBM, never
/// exceeds the promotion cap, and never leaves an aborted user's
/// single-flight guard behind.
#[test]
fn prop_full_relay_cycle_consistent() {
    prop::check("relay-full-cycle", 60, |rng: &mut Rng| {
        let mut cfg = TriggerConfig::paper_example();
        cfg.kv_p99_bytes = 32 * MB;
        cfg.q_m = 1e9;
        let mut trigger = Trigger::new(cfg, Box::new(|_: &BehaviorMeta| 1e9));
        let policy = *rng.choice(&[EvictPolicy::Lru, EvictPolicy::Lfu, EvictPolicy::CostAware]);
        let mut cache: CacheHierarchy<u32> =
            CacheHierarchy::new(512 * MB, &[TierConfig::new(1 << 30, policy)], 2);
        let mut router = Router::new(RouterConfig::default()).unwrap();
        let mut now = 0u64;
        let mut producing: Vec<u64> = Vec::new();
        let mut reloading: Vec<u64> = Vec::new();
        for step in 0..400 {
            now += rng.range(0, 30_000) as u64;
            let user = rng.range_u64(12);
            match rng.range(0, 6) {
                // Admission + signal-side pseudo pre-infer.
                0 => {
                    let meta = BehaviorMeta { user, prefix_len: 4096, dim: 256 };
                    if trigger.decide(now, &meta, 32 * MB) == Decision::Admit {
                        let r1 = router.route_special(user);
                        let r2 = router.route_special(user);
                        router.on_complete(r1.instance);
                        router.on_complete(r2.instance);
                        if r1.instance != r2.instance {
                            return Err(format!("step {step}: affinity broken"));
                        }
                        match cache.pseudo_pre_infer(user, now) {
                            PseudoAction::Miss => {
                                if cache
                                    .hbm_mut()
                                    .begin_produce(user, 32 * MB, now, 300_000)
                                    .is_ok()
                                {
                                    producing.push(user);
                                } else {
                                    trigger.release();
                                }
                            }
                            PseudoAction::StartReload { .. } => reloading.push(user),
                            _ => trigger.release(),
                        }
                    }
                }
                // Pre-inference completes.
                1 => {
                    if let Some(i) = (!producing.is_empty()).then(|| rng.range(0, producing.len()))
                    {
                        let u = producing.remove(i);
                        if !cache.hbm_mut().complete_produce(u, 1) {
                            trigger.release(); // lost work
                        }
                    }
                }
                // Reload resolves: complete when the backing copy is
                // still there, abort when it was invalidated mid-flight
                // (the engine's `begin_queued_reload` abort path).
                2 => {
                    if let Some(i) = (!reloading.is_empty()).then(|| rng.range(0, reloading.len()))
                    {
                        let u = reloading.remove(i);
                        let next = if cache.payload_below(u).is_some() {
                            cache.complete_reload(u, 1, 32 * MB, now, 300_000).next
                        } else {
                            cache.abort_reload(u)
                        };
                        if cache.inflight_for(u) {
                            return Err(format!("step {step}: {u} kept its guard"));
                        }
                        if let Some(next) = next {
                            reloading.push(next);
                        }
                    }
                }
                // Ranking consumes + spills.
                3 => {
                    if cache.hbm().state_of(user) == Some(EntryState::Ready) {
                        cache.hbm_mut().consume(user).ok_or("ready entry must consume")?;
                        trigger.release();
                        if cache.spill(user, 32 * MB, 1) {
                            cache.hbm_mut().evict(user);
                        }
                    }
                }
                // Behaviours refreshed upstream: lower-tier entry dropped
                // even while a reload for it may be in flight.
                4 => {
                    cache.invalidate(user);
                }
                // Rank-side pseudo check (may start a reload).
                _ => match cache.pseudo_pre_infer(user, now) {
                    PseudoAction::StartReload { .. } => reloading.push(user),
                    _ => {}
                },
            }
            if cache.hbm().used_bytes() > cache.hbm().capacity_bytes() {
                return Err("HBM overcommitted".into());
            }
            if cache.active_reloads() > 2 {
                return Err("reload concurrency cap violated".into());
            }
            let mut sorted = reloading.clone();
            sorted.sort_unstable();
            sorted.dedup();
            if sorted.len() != reloading.len() {
                return Err("duplicate in-flight reload for one user".into());
            }
        }
        // Drain every pending reload: the guards and slots must all clear.
        while let Some(u) = reloading.pop() {
            let next = if cache.payload_below(u).is_some() {
                cache.complete_reload(u, 1, 32 * MB, now, 300_000).next
            } else {
                cache.abort_reload(u)
            };
            if let Some(n) = next {
                reloading.push(n);
            }
        }
        if cache.active_reloads() != 0 {
            return Err("drain left promotion slots held".into());
        }
        Ok(())
    });
}

/// Simulator results are a pure function of (config, workload seed):
/// different seeds differ, same seeds agree bit-for-bit, and outcome
/// totals always equal completed requests.
#[test]
fn prop_sim_determinism_and_accounting() {
    prop::check("sim-determinism", 10, |rng: &mut Rng| {
        let seed = rng.next_u64() % 1000;
        let wl = WorkloadConfig {
            qps: 60.0 + (seed % 5) as f64 * 20.0,
            duration_us: 4_000_000,
            num_users: 10_000,
            fixed_long_len: Some(3072),
            max_prefix: 3072,
            seed,
            ..Default::default()
        };
        let mode = Mode::RelayGr { dram: DramPolicy::Capacity(64 << 30) };
        let a = run_sim(SimConfig::standard(mode), &wl).map_err(|e| e.to_string())?;
        let b = run_sim(SimConfig::standard(mode), &wl).map_err(|e| e.to_string())?;
        if a.completed != b.completed || a.outcome_counts != b.outcome_counts {
            return Err("nondeterministic run".into());
        }
        if a.p99_e2e() != b.p99_e2e() {
            return Err("nondeterministic latency".into());
        }
        let total: u64 = a.outcome_counts.iter().sum();
        if total != a.completed {
            return Err(format!("outcome leak: {} vs {}", total, a.completed));
        }
        Ok(())
    });
}

/// Affinity churn injection: removing special instances mid-run must only
/// remap the victims' keys and never route to a dead instance.
#[test]
fn prop_router_churn_safety() {
    prop::check("router-churn", 40, |rng: &mut Rng| {
        let mut router = Router::new(RouterConfig::default()).unwrap();
        let users: Vec<u64> = (0..300).map(|_| rng.next_u64() % 5000).collect();
        for round in 0..4 {
            let specials = router.special_instances().to_vec();
            if specials.len() > 1 && rng.bernoulli(0.5) {
                let victim = *rng.choice(&specials);
                router.remove_special(victim);
                for &u in &users {
                    let r = router.route_special(u);
                    router.on_complete(r.instance);
                    if r.instance == victim {
                        return Err(format!("round {round}: routed to removed {victim}"));
                    }
                }
            }
            // Re-adding restores it as a valid target.
            if rng.bernoulli(0.3) {
                if let Some(&inst) = specials.first() {
                    router.add_special(inst);
                }
            }
            for &u in &users {
                let a = router.route_special(u).instance;
                let b = router.route_special(u).instance;
                router.on_complete(a);
                router.on_complete(b);
                if a != b {
                    return Err("affinity violated after churn".into());
                }
            }
        }
        Ok(())
    });
}

/// Failure injection: a workload far beyond Q_max must be shed by the
/// trigger without ever losing a live cache, and the system must still
/// serve every request (fallback, never drop).
#[test]
fn overload_sheds_but_serves_everything() {
    let wl = WorkloadConfig {
        qps: 2500.0,
        duration_us: 5_000_000,
        num_users: 50_000,
        fixed_long_len: Some(4096),
        max_prefix: 4096,
        seed: 3,
        ..Default::default()
    };
    let trace_len = relaygr::workload::generate(&wl).len();
    let m = run_sim(
        SimConfig::standard(Mode::RelayGr { dram: DramPolicy::Disabled }),
        &wl,
    )
    .unwrap();
    assert_eq!(m.completed as usize, trace_len, "no request may be dropped");
    assert!(m.trigger.rate_limited + m.trigger.footprint_limited > 0);
    assert_eq!(m.hbm.lost, 0);
    assert_eq!(m.hbm.rejected, 0);
}

// ---------------------------------------------------------------------------
// Scenario-generator properties
// ---------------------------------------------------------------------------

/// The pre-scenario workload generator, copied verbatim: the `steady`
/// scenario must reproduce it bit-for-bit (same RNG stream, same ids).
fn legacy_generate(cfg: &WorkloadConfig) -> Vec<GenRequest> {
    let mut rng = Rng::new(cfg.seed);
    let mut out = Vec::new();
    let mut t = 0.0_f64;
    let rate_per_us = cfg.qps / 1e6;
    let mut id = 0u32;
    while (t as u64) < cfg.duration_us {
        t += rng.exponential(rate_per_us);
        let arrival = t as u64;
        if arrival >= cfg.duration_us {
            break;
        }
        let user = rng.zipf(cfg.num_users, cfg.zipf_s) - 1;
        let prefix_len = user_prefix_len(cfg, user);
        out.push(GenRequest {
            id,
            arrival_us: arrival,
            user: user as u32,
            prefix_len: prefix_len as u32,
            is_refresh: false,
        });
        id += 1;
        if prefix_len > cfg.long_threshold && rng.bernoulli(cfg.refresh_prob) {
            let burst = 1 + rng.range(0, cfg.refresh_burst_max);
            let mut rt = arrival;
            for _ in 0..burst {
                rt += rng.range(cfg.refresh_gap_us.0 as usize, cfg.refresh_gap_us.1 as usize)
                    as u64;
                if rt >= cfg.duration_us {
                    break;
                }
                out.push(GenRequest {
                    id,
                    arrival_us: rt,
                    user: user as u32,
                    prefix_len: prefix_len as u32,
                    is_refresh: true,
                });
                id += 1;
            }
        }
    }
    out.sort_by_key(|r| (r.arrival_us, r.id));
    out
}

#[test]
fn steady_matches_legacy_generator_bit_for_bit() {
    for seed in [1u64, 42, 99, 12345] {
        let cfg = WorkloadConfig {
            qps: 400.0,
            duration_us: 10_000_000,
            num_users: 30_000,
            refresh_prob: 0.4,
            scenario: ScenarioKind::Steady,
            seed,
            ..Default::default()
        };
        assert_eq!(generate(&cfg), legacy_generate(&cfg), "seed {seed} trace diverged");
    }
}

/// Rate conservation: every scenario's base (non-refresh) request count
/// matches its declared expected rate within Poisson noise.
#[test]
fn prop_scenario_rate_conservation() {
    prop::check("scenario-rate", 10, |rng: &mut Rng| {
        let qps = rng.uniform(100.0, 400.0);
        let seed = rng.next_u64();
        for name in ScenarioKind::NAMES {
            let kind = ScenarioKind::parse(name).unwrap();
            let cfg = WorkloadConfig {
                qps,
                duration_us: 20_000_000,
                num_users: 20_000,
                refresh_prob: 0.0,
                scenario: kind,
                seed,
                ..Default::default()
            };
            let base = generate(&cfg).iter().filter(|r| !r.is_refresh).count() as f64;
            let expect = kind.expected_base_requests(&cfg);
            let tolerance = 6.0 * expect.sqrt() + 0.01 * expect;
            if (base - expect).abs() > tolerance {
                return Err(format!(
                    "{name}: {base} requests vs expected {expect:.0} (qps {qps:.0})"
                ));
            }
        }
        Ok(())
    });
}

/// Every scenario is a pure function of its seed, and different seeds
/// give different traces.
#[test]
fn prop_scenario_determinism_per_seed() {
    prop::check("scenario-determinism", 8, |rng: &mut Rng| {
        let seed = rng.next_u64();
        for name in ScenarioKind::NAMES {
            let kind = ScenarioKind::parse(name).unwrap();
            let cfg = WorkloadConfig {
                qps: 200.0,
                duration_us: 8_000_000,
                num_users: 10_000,
                scenario: kind,
                seed,
                ..Default::default()
            };
            if generate(&cfg) != generate(&cfg) {
                return Err(format!("{name}: same seed produced different traces"));
            }
            let other = WorkloadConfig { seed: seed ^ 0xdead_beef, ..cfg.clone() };
            if generate(&cfg) == generate(&other) {
                return Err(format!("{name}: different seeds produced identical traces"));
            }
        }
        Ok(())
    });
}

/// Scenario traces are valid inputs to the simulator: every request is
/// served (never dropped) and outcome accounting stays exact, across
/// all four scenarios.
#[test]
fn scenarios_run_end_to_end_in_simulator() {
    for name in ScenarioKind::NAMES {
        let kind = ScenarioKind::parse(name).unwrap();
        let wl = WorkloadConfig {
            qps: 80.0,
            duration_us: 4_000_000,
            num_users: 5_000,
            fixed_long_len: Some(3072),
            max_prefix: 3072,
            scenario: kind,
            seed: 5,
            ..Default::default()
        };
        let n = generate(&wl).len() as u64;
        let m = run_sim(
            SimConfig::standard(Mode::RelayGr { dram: DramPolicy::Capacity(64 << 30) }),
            &wl,
        )
        .unwrap();
        assert_eq!(m.completed, n, "{name}: dropped requests");
        assert_eq!(m.outcome_counts.iter().sum::<u64>(), m.completed, "{name}: outcome leak");
        assert_eq!(m.scenario, name, "{name}: scenario label missing from metrics");
    }
}

/// DRAM capacity ablation: smaller tiers must evict more and never hit
/// more than bigger tiers under the same workload.
#[test]
fn dram_capacity_monotonicity() {
    let run = |gb: usize| {
        let wl = WorkloadConfig {
            qps: 120.0,
            duration_us: 8_000_000,
            num_users: 30_000,
            fixed_long_len: Some(3072),
            max_prefix: 3072,
            refresh_prob: 0.8,
            seed: 11,
            ..Default::default()
        };
        run_sim(
            SimConfig::standard(Mode::RelayGr { dram: DramPolicy::Capacity(gb << 30) }),
            &wl,
        )
        .unwrap()
    };
    let small = run(1);
    let big = run(512);
    assert!(
        big.dram_hit_rate() >= small.dram_hit_rate(),
        "bigger DRAM must not hit less: {:.3} vs {:.3}",
        big.dram_hit_rate(),
        small.dram_hit_rate()
    );
    assert!(small.hierarchy.dram_evictions >= big.hierarchy.dram_evictions);
}
