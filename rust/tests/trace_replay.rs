//! Trace record→replay round trip, pinned across engines: for every
//! built-in scenario, recording the arrival stream and replaying it from
//! the file must yield byte-identical per-request outcomes to the live
//! generator — under both the discrete-event simulator and the
//! serialized reference driver.  A replayed trace carries its full
//! workload config in the header, so candidate sets, admission seeding
//! and long/short classification reproduce without any side channel.

use relaygr::cluster::{run_reference, run_sim, SimConfig};
use relaygr::relay::baseline::Mode;
use relaygr::relay::pipeline::CacheOutcome;
use relaygr::relay::tier::DramPolicy;
use relaygr::workload::{trace, ScenarioKind, WorkloadConfig};

fn tmp(name: &str) -> String {
    let dir = std::env::temp_dir().join("relaygr_trace_replay_test");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(name).to_str().unwrap().to_string()
}

fn workload(kind: ScenarioKind) -> WorkloadConfig {
    WorkloadConfig {
        qps: 40.0,
        duration_us: 5_000_000,
        num_users: 5_000,
        fixed_long_len: Some(4096),
        max_prefix: 4096,
        refresh_prob: 0.3,
        scenario: kind,
        seed: 99,
        ..Default::default()
    }
}

fn sim_outcomes(cfg: &SimConfig, wl: &WorkloadConfig) -> Vec<(u64, CacheOutcome)> {
    let mut cfg = cfg.clone();
    cfg.log_outcomes = true;
    let mut log = run_sim(cfg, wl).expect("simulation runs").outcome_log();
    log.sort_by_key(|&(id, _)| id);
    log
}

/// The property the trace format exists for: replay == live, per
/// request, on every scenario, under both engines — and the replayed
/// run still matches across engines (the trace changes the arrival
/// *source*, never a decision).
#[test]
fn replay_outcomes_bit_identical_on_every_scenario_and_engine() {
    for name in ScenarioKind::NAMES {
        let wl = workload(ScenarioKind::parse(name).expect("built-in scenario"));
        let path = tmp(&format!("{name}.trace"));
        let (records, _) = trace::record(&path, &wl).expect("trace records");
        assert!(records > 0, "{name}: empty trace");
        let replay = trace::open_replay(&path).expect("trace header parses");

        let mut cfg = SimConfig::standard(Mode::RelayGr { dram: DramPolicy::Disabled });
        cfg.pipeline.t_life_us = 2 * wl.duration_us;

        let live_sim = sim_outcomes(&cfg, &wl);
        let replay_sim = sim_outcomes(&cfg, &replay);
        assert_eq!(live_sim.len() as u64, records, "{name}: sim served the whole trace");
        assert_eq!(live_sim, replay_sim, "{name}: sim diverged between live and replay");

        let live_ref = run_reference(&cfg, &wl).expect("reference runs").outcomes;
        let replay_ref = run_reference(&cfg, &replay).expect("reference replays").outcomes;
        assert_eq!(live_ref, replay_ref, "{name}: reference diverged between live and replay");
        assert_eq!(replay_sim, replay_ref, "{name}: engines diverged on the replayed trace");
    }
}

/// Replay composes with the DRAM tier and refresh bursts (the stateful
/// cache paths): same trace, same decisions, live or from disk.
#[test]
fn replay_bit_identical_with_dram_tier() {
    let mut wl = workload(ScenarioKind::Steady);
    wl.refresh_prob = 0.6;
    let path = tmp("dram.trace");
    trace::record(&path, &wl).expect("trace records");
    let replay = trace::open_replay(&path).expect("trace header parses");
    let cfg = SimConfig::standard(Mode::RelayGr { dram: DramPolicy::Capacity(500 << 30) });
    assert_eq!(sim_outcomes(&cfg, &wl), sim_outcomes(&cfg, &replay));
}
