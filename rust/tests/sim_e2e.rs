//! Integration: paper-shape assertions on the discrete-event simulator —
//! the relative results every figure depends on must hold end to end.

use relaygr::cluster::{run_sim, SimConfig};
use relaygr::metrics::slo;
use relaygr::relay::baseline::Mode;
use relaygr::relay::tier::DramPolicy;
use relaygr::workload::WorkloadConfig;

fn wl(len: usize, qps: f64) -> WorkloadConfig {
    WorkloadConfig {
        qps,
        duration_us: 8_000_000,
        num_users: 30_000,
        fixed_long_len: Some(len),
        max_prefix: len.max(2048),
        refresh_prob: 0.5,
        seed: 99,
        ..Default::default()
    }
}

#[test]
fn headline_relaygr_extends_max_length() {
    // Fig. 11a shape: RelayGR's max supported length ≥ baseline's, and
    // strictly greater at the paper's ~1.5× point.
    let lens = [2048usize, 3072, 4096];
    let max_len = |mode| {
        slo::max_supported_len(
            |len| run_sim(SimConfig::standard(mode), &wl(len, 70.0)).unwrap(),
            &lens,
            0.999,
        )
        .value
    };
    let base = max_len(Mode::Baseline);
    let relay = max_len(Mode::RelayGr { dram: DramPolicy::Disabled });
    assert!(relay >= base * 1.4, "relay {relay} vs baseline {base}");
}

#[test]
fn headline_relaygr_improves_slo_throughput() {
    // Fig. 11d shape: at a long length the baseline collapses while
    // RelayGR (and more so with DRAM) sustains real throughput.
    let len = 3072;
    let cap = |mode| {
        slo::max_qps(
            |q| run_sim(SimConfig::standard(mode), &wl(len, q)).unwrap(),
            5.0,
            2000.0,
            0.999,
            0.1,
        )
        .value
    };
    let base = cap(Mode::Baseline);
    let relay = cap(Mode::RelayGr { dram: DramPolicy::Disabled });
    let dram = cap(Mode::RelayGr { dram: DramPolicy::Capacity(500 << 30) });
    assert!(relay > 3.0 * base.max(5.0), "relay {relay} vs base {base}");
    assert!(dram >= relay * 0.95, "dram {dram} must not regress relay {relay}");
}

#[test]
fn no_remote_fetch_invariant_i1() {
    // Invariant I1: a RelayGR run never blocks ranking on a remote fetch;
    // misses fall back to full inference (outcome Fallback/Full only).
    let m = run_sim(
        SimConfig::standard(Mode::RelayGr { dram: DramPolicy::Disabled }),
        &wl(4096, 200.0),
    )
    .unwrap();
    let total: u64 = m.outcome_counts.iter().sum();
    assert_eq!(total, m.completed);
    // All five outcomes are local-or-fallback by construction; remote
    // fetch simply does not exist in the relay path.  Sanity: some longs
    // actually used the cache.
    assert!(m.outcome_counts[1] > 0);
}

#[test]
fn survivability_invariant_i2_under_overload() {
    // Invariant I2: under heavy offered load the trigger sheds traffic
    // (rate/footprint limited) and HBM never loses live caches.
    let m = run_sim(
        SimConfig::standard(Mode::RelayGr { dram: DramPolicy::Disabled }),
        &wl(4096, 1200.0),
    )
    .unwrap();
    assert_eq!(m.hbm.lost, 0, "admission control must bound the live set");
    assert_eq!(m.hbm.rejected, 0, "begin_produce must never hit capacity");
    assert!(m.trigger.admitted > 0);
}

#[test]
fn dram_hit_rate_scales_with_refresh_reuse() {
    let mode = Mode::RelayGr { dram: DramPolicy::Capacity(500 << 30) };
    let mut low_wl = wl(3072, 100.0);
    low_wl.refresh_prob = 0.05;
    let mut high_wl = wl(3072, 100.0);
    high_wl.refresh_prob = 0.9;
    let low = run_sim(SimConfig::standard(mode), &low_wl).unwrap();
    let high = run_sim(SimConfig::standard(mode), &high_wl).unwrap();
    assert!(
        high.dram_hit_rate() > low.dram_hit_rate() + 0.1,
        "hit rates: high {:.2} vs low {:.2}",
        high.dram_hit_rate(),
        low.dram_hit_rate()
    );
}

#[test]
fn deeper_models_amplify_relaygr_gain() {
    // Fig. 14d shape: the relay advantage grows with depth.
    // Lower the special-service threshold so the 2K class is
    // relay-eligible and the near-threshold short tail stays cheap
    // (the Fig. 14d setup).
    let gain_at = |layers: usize| {
        let mk = |mode| {
            let mut cfg = SimConfig::standard(mode);
            cfg.spec.layers = layers;
            cfg.long_threshold = 1024;
            cfg
        };
        let mut w = wl(2048, 0.0);
        w.long_threshold = 1024;
        let cap = |cfg: SimConfig| {
            slo::max_qps(
                |q| {
                    let mut w = w.clone();
                    w.qps = q;
                    run_sim(cfg.clone(), &w).unwrap()
                },
                5.0,
                1500.0,
                0.999,
                0.1,
            )
            .value
        };
        let base = cap(mk(Mode::Baseline));
        let relay = cap(mk(Mode::RelayGr { dram: DramPolicy::Capacity(500 << 30) }));
        relay / base.max(5.0)
    };
    let shallow = gain_at(4);
    let deep = gain_at(16);
    assert!(deep > shallow, "gain should grow with depth: {deep:.2} vs {shallow:.2}");
}
