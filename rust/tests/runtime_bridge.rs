//! Integration: the python-AOT → rust-PJRT bridge over the real artifact
//! grid — ε-equivalence (cached vs full), ψ residency, spill/reload
//! numerics, and manifest consistency.
//!
//! Requires `make artifacts` (the Makefile runs it before `cargo test`).

use relaygr::model::ModelType;
use relaygr::runtime::{synth_embedding, Engine, FnKind};

fn artifacts_dir() -> Option<String> {
    let dir = std::env::var("RELAYGR_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    std::path::Path::new(&dir).join("manifest.json").exists().then_some(dir)
}

macro_rules! require_artifacts {
    () => {
        match artifacts_dir() {
            Some(d) => d,
            None => {
                eprintln!("skipping: no artifacts (run `make artifacts`)");
                return;
            }
        }
    };
}

#[test]
fn epsilon_bound_holds_for_every_variant() {
    let dir = require_artifacts!();
    let engine = Engine::load(&dir).unwrap();
    let mut checked = 0;
    for spec in engine.manifest.variants() {
        if engine.manifest.find(FnKind::Full, &spec).is_none() {
            continue;
        }
        let prefix_m = engine.model(FnKind::Prefix, &spec).unwrap();
        let rank_m = engine.model(FnKind::Rank, &spec).unwrap();
        let full_m = engine.model(FnKind::Full, &spec).unwrap();
        let prefix = synth_embedding(11, spec.prefix_len, spec.dim, 0.5);
        let incr = synth_embedding(12, spec.incr_len, spec.dim, 0.5);
        let items = synth_embedding(13, spec.num_items, spec.dim, 0.5);

        let full = full_m.execute_host(&[&prefix, &incr, &items]).unwrap();
        let kv = prefix_m.execute_to_device(&[&prefix]).unwrap();
        let cached = rank_m.execute_with_kv(&kv, &[&incr, &items]).unwrap();

        assert_eq!(full.len(), spec.num_items);
        let eps = full
            .iter()
            .zip(&cached)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(eps <= 1e-3, "{}: ε = {eps}", spec.name());
        // Guard against the zeroed-constants failure mode.
        let mag = full.iter().map(|v| v.abs()).fold(0.0f32, f32::max);
        assert!(mag > 1e-3, "{}: all-zero scores (elided constants?)", spec.name());
        checked += 1;
    }
    assert!(checked >= 5, "expected a real grid, checked {checked}");
}

#[test]
fn kv_buffer_survives_spill_and_reload_exactly() {
    let dir = require_artifacts!();
    let engine = Engine::load(&dir).unwrap();
    let spec = engine.manifest.default_variant().unwrap();
    let prefix_m = engine.model(FnKind::Prefix, &spec).unwrap();
    let rank_m = engine.model(FnKind::Rank, &spec).unwrap();
    let prefix = synth_embedding(21, spec.prefix_len, spec.dim, 0.5);
    let incr = synth_embedding(22, spec.incr_len, spec.dim, 0.5);
    let items = synth_embedding(23, spec.num_items, spec.dim, 0.5);

    let kv = prefix_m.execute_to_device(&[&prefix]).unwrap();
    let direct = rank_m.execute_with_kv(&kv, &[&incr, &items]).unwrap();
    // D2H spill → H2D reload (the hierarchy's DRAM round trip).
    let host = kv.to_host().unwrap();
    assert_eq!(host.len(), kv.elements);
    let kv2 = rank_m.kv_from_host(&host).unwrap();
    let reloaded = rank_m.execute_with_kv(&kv2, &[&incr, &items]).unwrap();
    assert_eq!(direct, reloaded, "spill/reload must preserve ψ bit-for-bit");
}

#[test]
fn candidate_independence_one_psi_many_item_sets() {
    let dir = require_artifacts!();
    let engine = Engine::load(&dir).unwrap();
    let spec = engine.manifest.default_variant().unwrap();
    let prefix_m = engine.model(FnKind::Prefix, &spec).unwrap();
    let rank_m = engine.model(FnKind::Rank, &spec).unwrap();
    let full_m = engine.model(FnKind::Full, &spec).unwrap();
    let prefix = synth_embedding(31, spec.prefix_len, spec.dim, 0.5);
    let incr = synth_embedding(32, spec.incr_len, spec.dim, 0.5);
    let kv = prefix_m.execute_to_device(&[&prefix]).unwrap();
    // ψ produced once must serve arbitrarily many candidate sets.
    for seed in [100u64, 200, 300] {
        let items = synth_embedding(seed, spec.num_items, spec.dim, 0.5);
        let cached = rank_m.execute_with_kv(&kv, &[&incr, &items]).unwrap();
        let full = full_m.execute_host(&[&prefix, &incr, &items]).unwrap();
        let eps = full
            .iter()
            .zip(&cached)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(eps <= 1e-3, "item set {seed}: ε = {eps}");
    }
}

#[test]
fn manifest_variants_cover_all_model_types() {
    let dir = require_artifacts!();
    let engine = Engine::load(&dir).unwrap();
    let variants = engine.manifest.variants();
    let types: std::collections::HashSet<ModelType> =
        variants.iter().map(|s| s.model_type).collect();
    assert!(types.contains(&ModelType::Hstu));
    assert!(types.contains(&ModelType::HstuRev));
    assert!(types.contains(&ModelType::LongerRankMixer));
    // ψ footprint arithmetic must agree with the python manifest.
    for a in &engine.manifest.artifacts {
        if a.fn_kind == FnKind::Prefix {
            let out_elems: usize = a.outputs[0].shape.iter().product();
            assert_eq!(out_elems * 4, a.spec.kv_bytes(), "{}", a.name);
        }
    }
}

#[test]
fn executable_pool_compiles_once() {
    let dir = require_artifacts!();
    let engine = Engine::load(&dir).unwrap();
    let spec = engine.manifest.default_variant().unwrap();
    let a = engine.model(FnKind::Rank, &spec).unwrap();
    let before = engine.pooled();
    let b = engine.model(FnKind::Rank, &spec).unwrap();
    assert_eq!(engine.pooled(), before, "second lookup must hit the pool");
    assert!(std::sync::Arc::ptr_eq(&a, &b));
}

#[test]
fn wrong_arity_and_shape_are_rejected() {
    let dir = require_artifacts!();
    let engine = Engine::load(&dir).unwrap();
    let spec = engine.manifest.default_variant().unwrap();
    let full_m = engine.model(FnKind::Full, &spec).unwrap();
    let too_few = synth_embedding(1, spec.prefix_len, spec.dim, 0.5);
    assert!(full_m.execute_host(&[&too_few]).is_err());
    let wrong_len = vec![0.0f32; 7];
    let incr = synth_embedding(2, spec.incr_len, spec.dim, 0.5);
    let items = synth_embedding(3, spec.num_items, spec.dim, 0.5);
    assert!(full_m.execute_host(&[&wrong_len, &incr, &items]).is_err());
}
