#![allow(dead_code)]

//! Minimal benchmark harness (the offline vendor set has no `criterion`):
//! warm-up + timed iterations with mean / p50 / p99 reporting and JSON
//! persistence under `results/bench/`.
//!
//! Shared by both bench binaries via `#[path]` include.

use std::time::Instant;

pub struct BenchResult {
    pub name: String,
    pub iters: u32,
    pub mean_us: f64,
    pub p50_us: f64,
    pub p99_us: f64,
    pub min_us: f64,
}

/// Time `f` for `iters` iterations after `warmup` unmeasured runs.
pub fn bench<F: FnMut()>(name: &str, warmup: u32, iters: u32, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters as usize);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64() * 1e6);
    }
    samples.sort_by(|a, b| a.total_cmp(b));
    let n = samples.len();
    let mean = samples.iter().sum::<f64>() / n as f64;
    let r = BenchResult {
        name: name.to_string(),
        iters,
        mean_us: mean,
        p50_us: samples[n / 2],
        p99_us: samples[(n as f64 * 0.99) as usize % n],
        min_us: samples[0],
    };
    println!(
        "{:<44} {:>8} iters  mean {:>12.2} µs  p50 {:>12.2} µs  p99 {:>12.2} µs",
        r.name, r.iters, r.mean_us, r.p50_us, r.p99_us
    );
    r
}

/// Persist a suite of results as JSON: the archive copy under
/// `results/bench/` plus a `BENCH_<suite>.json` snapshot in the working
/// directory, so the perf trajectory is recorded run over run by tooling
/// that only looks for `BENCH_*` files.
pub fn write_results(file: &str, results: &[BenchResult]) {
    use relaygr::util::json::Json;
    let rows: Vec<Json> = results
        .iter()
        .map(|r| {
            let mut j = Json::obj();
            j.set("name", r.name.as_str().into())
                .set("iters", (r.iters as usize).into())
                .set("mean_us", r.mean_us.into())
                .set("p50_us", r.p50_us.into())
                .set("p99_us", r.p99_us.into())
                .set("min_us", r.min_us.into());
            j
        })
        .collect();
    let _ = std::fs::create_dir_all("results/bench");
    let mut j = Json::obj();
    j.set("suite", file.into()).set("results", Json::Arr(rows));
    let text = j.to_string_pretty();
    for path in [format!("results/bench/{file}.json"), format!("BENCH_{file}.json")] {
        if std::fs::write(&path, &text).is_ok() {
            println!("wrote {path}");
        }
    }
}
