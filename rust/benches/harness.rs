#![allow(dead_code)]

//! Minimal benchmark harness (the offline vendor set has no `criterion`):
//! warm-up + timed iterations with mean / p50 / p99 reporting and JSON
//! persistence under `results/bench/`.
//!
//! Shared by the bench binaries via `#[path]` include.
//!
//! ## Allocation accounting
//!
//! A bench binary opts into allocation counting by installing the
//! counting global allocator:
//!
//! ```ignore
//! #[global_allocator]
//! static ALLOC: harness::CountingAlloc = harness::CountingAlloc;
//! ```
//!
//! Every [`bench`] then measures the allocation count across the timed
//! loop and reports `allocs_per_op` (printed and persisted in the BENCH
//! JSON), so zero-allocation hot paths are asserted, not assumed.
//! Without the opt-in the field is absent — the harness detects the
//! allocator by whether the counter ever moved.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::time::Instant;

/// Counting wrapper around the system allocator.  Counts allocation
/// *operations* (alloc / realloc / alloc_zeroed); frees are not charged —
/// the hot-path budget is "no allocator traffic", and a free implies an
/// earlier charged alloc.
pub struct CountingAlloc;

static ALLOC_OPS: AtomicU64 = AtomicU64::new(0);
static COUNTER_LIVE: AtomicBool = AtomicBool::new(false);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        COUNTER_LIVE.store(true, Ordering::Relaxed);
        ALLOC_OPS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        COUNTER_LIVE.store(true, Ordering::Relaxed);
        ALLOC_OPS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        COUNTER_LIVE.store(true, Ordering::Relaxed);
        ALLOC_OPS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }
}

/// Cumulative allocation operations, or `None` when the binary did not
/// install [`CountingAlloc`].
pub fn alloc_ops() -> Option<u64> {
    if COUNTER_LIVE.load(Ordering::Relaxed) {
        Some(ALLOC_OPS.load(Ordering::Relaxed))
    } else {
        None
    }
}

pub struct BenchResult {
    pub name: String,
    pub iters: u32,
    pub mean_us: f64,
    pub p50_us: f64,
    pub p99_us: f64,
    pub min_us: f64,
    /// Allocator operations per iteration across the timed loop —
    /// present only under [`CountingAlloc`].
    pub allocs_per_op: Option<f64>,
    /// Suite-specific extra metrics persisted alongside the timings
    /// (e.g. `events_per_sec` for the sim loop).
    pub extra: Vec<(String, f64)>,
}

/// Time `f` for `iters` iterations after `warmup` unmeasured runs.
pub fn bench<F: FnMut()>(name: &str, warmup: u32, iters: u32, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    // Pre-size the sample buffer before the allocation snapshot so the
    // harness itself stays out of the measurement.
    let mut samples = Vec::with_capacity(iters as usize);
    let allocs_before = alloc_ops();
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64() * 1e6);
    }
    let allocs_per_op = match (allocs_before, alloc_ops()) {
        (Some(a), Some(b)) => Some((b - a) as f64 / iters.max(1) as f64),
        _ => None,
    };
    samples.sort_by(|a, b| a.total_cmp(b));
    let n = samples.len();
    let mean = samples.iter().sum::<f64>() / n as f64;
    let r = BenchResult {
        name: name.to_string(),
        iters,
        mean_us: mean,
        p50_us: samples[n / 2],
        p99_us: samples[(n as f64 * 0.99) as usize % n],
        min_us: samples[0],
        allocs_per_op,
        extra: Vec::new(),
    };
    let allocs = match r.allocs_per_op {
        Some(a) => format!("  allocs/op {a:>8.2}"),
        None => String::new(),
    };
    println!(
        "{:<44} {:>8} iters  mean {:>12.2} µs  p50 {:>12.2} µs  p99 {:>12.2} µs{allocs}",
        r.name, r.iters, r.mean_us, r.p50_us, r.p99_us
    );
    r
}

/// Persist a suite of results as JSON: the archive copy under
/// `results/bench/` plus a `BENCH_<suite>.json` snapshot in the working
/// directory, so the perf trajectory is recorded run over run by tooling
/// that only looks for `BENCH_*` files.
pub fn write_results(file: &str, results: &[BenchResult]) {
    use relaygr::util::json::Json;
    let rows: Vec<Json> = results
        .iter()
        .map(|r| {
            let mut j = Json::obj();
            j.set("name", r.name.as_str().into())
                .set("iters", (r.iters as usize).into())
                .set("mean_us", r.mean_us.into())
                .set("p50_us", r.p50_us.into())
                .set("p99_us", r.p99_us.into())
                .set("min_us", r.min_us.into());
            if let Some(a) = r.allocs_per_op {
                j.set("allocs_per_op", a.into());
            }
            for (k, v) in &r.extra {
                j.set(k, (*v).into());
            }
            j
        })
        .collect();
    let _ = std::fs::create_dir_all("results/bench");
    let mut j = Json::obj();
    j.set("suite", file.into()).set("results", Json::Arr(rows));
    let text = j.to_string_pretty();
    for path in [format!("results/bench/{file}.json"), format!("BENCH_{file}.json")] {
        if std::fs::write(&path, &text).is_ok() {
            println!("wrote {path}");
        }
    }
}
