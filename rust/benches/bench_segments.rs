//! Candidate-segment cache hot-path microbenchmarks: the per-candidate
//! acquire/release cycle (the coordinator runs it once per candidate per
//! rank pass, so its budget is sub-microsecond), churn under capacity
//! pressure, Zipf-mixed traffic, and the full coordinator decision flow
//! with segment planning enabled.  Emits `BENCH_segments.json` so the
//! segment hot path joins the recorded perf trajectory.

#[path = "harness.rs"]
mod harness;

use harness::{bench, write_results};
use relaygr::relay::segment::{SegmentAction, SegmentKey, SegmentStore};
use relaygr::relay::tier::DramPolicy;
use relaygr::util::rng::Rng;

fn main() {
    let mut results = Vec::new();
    let mut rng = Rng::new(11);
    const SEG: usize = 16 << 10;

    // --- steady-state reuse: everything resident --------------------------
    let mut hot: SegmentStore<u32> = SegmentStore::new(1 << 30, &[], 1 << 40, SEG);
    for item in 0..512u64 {
        let k = SegmentKey::new(item, 0).packed();
        let SegmentAction::Produce { ticket } = hot.acquire(k, 0) else {
            panic!("fresh store must produce");
        };
        hot.complete(k, ticket, 0);
        hot.release(k);
    }
    let mut i = 0u64;
    results.push(bench("segment/acquire_release_hit", 100, 50_000, || {
        i += 1;
        let k = SegmentKey::new(i % 512, 0).packed();
        hot.acquire(k, i);
        hot.release(k);
    }));

    // --- churn: small partition, rotating keys, constant eviction ---------
    let mut churn: SegmentStore<u32> = SegmentStore::new(256 * SEG, &[], 1 << 40, SEG);
    let mut u = 0u64;
    results.push(bench("segment/produce_churn_evicting", 100, 50_000, || {
        u += 1;
        let k = SegmentKey::new(u, 0).packed();
        if let SegmentAction::Produce { ticket } = churn.acquire(k, u) {
            churn.complete(k, ticket, 0);
        }
        churn.release(k);
    }));

    // --- zipf mix: hot reuse + cold production (the serving shape) --------
    let mut mix: SegmentStore<u32> = SegmentStore::new(1 << 28, &[], 1 << 40, SEG);
    let items: Vec<u64> = (0..4096).map(|_| rng.zipf(100_000, 1.1) - 1).collect();
    let mut t = 0u64;
    let mut j = 0usize;
    results.push(bench("segment/zipf_mix_acquire", 100, 50_000, || {
        t += 1;
        j = (j + 1) & 4095;
        let k = SegmentKey::new(items[j], 0).packed();
        if let SegmentAction::Produce { ticket } = mix.acquire(k, t) {
            mix.complete(k, ticket, 0);
        }
        mix.release(k);
    }));

    // --- coordinator decision flow with segment planning enabled ----------
    {
        use relaygr::relay::coordinator::{RankAction, RelayCoordinator, SignalAction, Stage};
        let mut sim_cfg = relaygr::cluster::SimConfig::standard(
            relaygr::relay::baseline::Mode::RelayGr { dram: DramPolicy::Capacity(64 << 30) },
        );
        sim_cfg.segment_frac = 0.25;
        let mut coord: RelayCoordinator<()> =
            RelayCoordinator::new(sim_cfg.coordinator_config(), |_| sim_cfg.estimator())
                .expect("coordinator builds");
        // 64 candidates per request, Zipf-skewed like the workload engine.
        let cands: Vec<Vec<u64>> = (0..256)
            .map(|_| (0..64).map(|_| rng.zipf(100_000, 1.1) - 1).collect())
            .collect();
        let kv = 32usize << 20;
        let mut id = 0u64;
        let mut now = 0u64;
        results.push(bench("coordinator/decision_flow_with_segments", 50, 20_000, || {
            id += 1;
            now += 700;
            let user = id % 1024;
            let (req, wants_trigger) =
                coord.on_arrival(now, id, user, 4096, &cands[(id & 255) as usize]);
            if wants_trigger {
                match coord.on_trigger_check(now, req) {
                    SignalAction::Produce { instance, user, .. } => {
                        coord.on_psi_ready(now, instance, user, Some(()));
                    }
                    SignalAction::Reload { instance, user, bytes } => {
                        coord.on_reload_done(now, instance, user, Some(()), bytes);
                    }
                    SignalAction::None => {}
                }
            }
            let inst = coord
                .on_stage_done(now, req, Stage::Preproc)
                .expect("rank instance routed");
            if let RankAction::StartReload { bytes } = coord.on_rank_start(now, req) {
                coord.on_reload_done(now, inst, user, Some(()), bytes);
            }
            let _ = coord.rank_compute(now, req);
            let done = coord.on_rank_done(now, req, kv);
            if let Some(bytes) = done.spill {
                coord.complete_spill(now, done.instance, done.user, bytes, ());
            }
        }));
    }

    write_results("segments", &results);
}
