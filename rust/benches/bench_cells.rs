//! Multi-cell routing microbenchmarks (PR 9): the two-level pick
//! (rendezvous home + affinity/spread policy + load bookkeeping) and the
//! drained-home failover path, measured through the full per-request
//! cell cycle — arrival pick → in-cell route → completion accounting.
//! The cell layer sits on the same microsecond control-plane budget as
//! routing and admission, so the pick and failover cycles are asserted
//! allocation-free in steady state on every run.

#[path = "harness.rs"]
mod harness;

use harness::{bench, write_results};

#[global_allocator]
static ALLOC: harness::CountingAlloc = harness::CountingAlloc;

use relaygr::relay::baseline::Mode;
use relaygr::relay::cell::{CellPickerKind, CellSet};
use relaygr::relay::coordinator::{RelayCoordinator, Stage};
use relaygr::relay::tier::DramPolicy;

/// A 4-cell set over the standard cluster shape (5 instances × 2
/// servers per cell), scripted-churn-free so the bench drives churn
/// explicitly where it wants it.
fn cell_set(picker: CellPickerKind, spill: f64) -> CellSet<()> {
    let mut cfg =
        relaygr::cluster::SimConfig::standard(Mode::RelayGr { dram: DramPolicy::Disabled });
    cfg.cells = 4;
    cfg.router.servers = 8;
    cfg.cell_picker = picker;
    cfg.cell_spill = spill;
    let coords = (0..cfg.cells)
        .map(|_| RelayCoordinator::new(cfg.cell_coordinator_config(), |_| cfg.estimator()))
        .collect::<Result<Vec<_>, _>>()
        .expect("coordinators build");
    CellSet::new(cfg.cell_config(), coords, 0).expect("cell set builds")
}

/// One full short-request cycle: level-1 pick, in-cell route, rank
/// classification, completion (slab slot recycled, cross flag cleared).
/// Short prefixes keep the ψ plane out of the loop — this measures the
/// routing control plane, not cache lifecycle.
fn cycle(set: &mut CellSet<()>, now: u64, rid: u64, user: u64) -> usize {
    let (req, _) = set.on_arrival(now, rid, user, 256, &[]);
    set.coord_mut(req.cell).on_stage_done(now, req.id, Stage::Preproc).expect("routed");
    let _ = set.coord_mut(req.cell).on_rank_start(now, req.id);
    let _ = set.coord_mut(req.cell).rank_compute(now, req.id);
    let done = set.on_rank_done(now, req, 32 << 20);
    std::hint::black_box(done.outcome);
    req.cell
}

fn main() {
    let mut results = Vec::new();

    // Affinity pick: rendezvous over 4 cells + decayed-load spill test.
    {
        let mut set = cell_set(CellPickerKind::Affinity, 2.0);
        let mut id = 0u64;
        let mut now = 0u64;
        results.push(bench("cells/route4_affinity_cycle", 100, 20_000, || {
            id += 1;
            now += 700;
            cycle(&mut set, now, id, id % 1024);
        }));
        std::hint::black_box(set.cross_totals());
    }

    // Spread pick: rendezvous on the request id — the no-locality
    // control whose cost must match affinity's to first order.
    {
        let mut set = cell_set(CellPickerKind::Spread, 2.0);
        let mut id = 0u64;
        let mut now = 0u64;
        results.push(bench("cells/route4_spread_cycle", 100, 20_000, || {
            id += 1;
            now += 700;
            cycle(&mut set, now, id, id % 1024);
        }));
        std::hint::black_box(set.cross_totals());
    }

    // Failover: every arrival's home cell is drained, so the pick must
    // re-rendezvous over the eligible mask and the cross-route counters
    // take the hit — the path a drain or failure puts every subsequent
    // request on.
    {
        // Find users homed on cell 1 (pure locality: picks == homes).
        let mut probe = cell_set(CellPickerKind::Affinity, f64::INFINITY);
        let mut homed: Vec<u64> = Vec::new();
        for u in 0..8192u64 {
            if homed.len() == 1024 {
                break;
            }
            if cycle(&mut probe, (u + 1) * 700, u + 1, u) == 1 {
                homed.push(u);
            }
        }
        assert!(homed.len() == 1024, "rendezvous sharded too unevenly: {}", homed.len());
        let mut set = cell_set(CellPickerKind::Affinity, f64::INFINITY);
        set.drain_cell(1);
        let mut id = 0u64;
        let mut now = 0u64;
        results.push(bench("cells/route4_failover_drained_home", 100, 20_000, || {
            id += 1;
            now += 700;
            let cell = cycle(&mut set, now, id, homed[(id % 1024) as usize]);
            assert_ne!(cell, 1, "drained cell must take no traffic");
        }));
        let (cross, _) = set.cross_totals();
        assert!(cross > 0, "failover path never cross-routed");
    }

    // The zero-allocation contract, extended to the cell layer: pick,
    // failover and completion accounting must show no allocator traffic
    // once slabs and flag vectors reach their high-water capacity.
    for r in &results {
        assert_eq!(
            r.allocs_per_op,
            Some(0.0),
            "steady-state allocation regression on '{}': {:?} allocs/op",
            r.name,
            r.allocs_per_op
        );
    }

    write_results("cells", &results);
}
