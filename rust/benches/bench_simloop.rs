//! End-to-end simulator-loop benchmarks: the first real perf-trajectory
//! datapoints for the evaluation plane itself.
//!
//! * `simloop/steady_2s_300qps` — one full discrete-event simulation
//!   (timer wheel + streaming arrivals + slab-backed coordinator),
//!   reporting wall-clock and `events_per_sec` (total wheel events over
//!   mean wall time);
//! * `simloop/figure_grid_jobs{1,N}` — the `figure scenarios` grid (4
//!   scenarios × 2 modes, quick shape) through the deterministic
//!   parallel executor at 1 vs N jobs, with the byte-identical-rows
//!   check run inline and `speedup_vs_jobs1` recorded on the parallel
//!   row;
//! * `simloop/scale_replay_2000qps` — the trace-scale arm: record a
//!   binary trace, then replay it through the simulator from disk,
//!   reporting `events_per_sec` / `requests_per_sec`.  The request count
//!   is capped by `RELAYGR_BENCH_SCALE` (CI sets a small cap; locally it
//!   defaults to 200k requests over a 1M-user population).
//!
//! Emits `BENCH_simloop.json` (and `results/bench/simloop.json`); runs
//! in CI next to the other suites.  `--jobs N` overrides the parallel
//! arm's job count (default 4).  `events_per_sec` on the steady and
//! scale arms is the committed perf-trajectory metric (see
//! `bench/trajectory/`).

#[path = "harness.rs"]
mod harness;

use harness::{bench, write_results};
use relaygr::cluster::SimConfig;
use relaygr::relay::baseline::Mode;
use relaygr::relay::tier::DramPolicy;
use relaygr::util::cli::Args;
use relaygr::workload::WorkloadConfig;

fn grid_args(jobs: usize) -> Args {
    Args::parse(
        [
            "bench".to_string(),
            "figure".to_string(),
            "--quick".to_string(),
            "--qps".to_string(),
            "60".to_string(),
            "--jobs".to_string(),
            jobs.to_string(),
        ]
        .into_iter(),
    )
    .expect("static args parse")
}

fn main() {
    let argv = Args::from_env().unwrap_or_default();
    let jobs = argv.get_usize("jobs", 4).unwrap_or(4);
    let mut results = Vec::new();

    // --- one full simulation: events/sec -----------------------------------
    let cfg = SimConfig::standard(Mode::RelayGr { dram: DramPolicy::Capacity(500 << 30) });
    let wl = WorkloadConfig {
        qps: 300.0,
        duration_us: 2_000_000,
        num_users: 10_000,
        ..Default::default()
    };
    let mut events = 0u64;
    let mut completed = 0u64;
    let mut r = bench("simloop/steady_2s_300qps", 1, 10, || {
        let m = relaygr::cluster::run_sim(cfg.clone(), &wl).expect("sim runs");
        events = m.sim_events;
        completed = m.completed;
        std::hint::black_box(&m);
    });
    r.extra.push(("events".into(), events as f64));
    r.extra.push(("events_per_sec".into(), events as f64 / (r.mean_us / 1e6)));
    r.extra.push(("completed_requests".into(), completed as f64));
    println!(
        "{:<44} {:>20.0} events/s ({} events, {} requests)",
        "simloop/steady_2s_300qps", events as f64 / (r.mean_us / 1e6), events, completed
    );
    results.push(r);

    // --- trace-scale replay: events/sec at population scale ------------------
    // Record once, replay from disk — the same path the CI scale-smoke
    // job and any 100M-request run use.  RELAYGR_BENCH_SCALE caps the
    // request count so CI stays fast while local runs measure at scale.
    let scale_requests: u64 = std::env::var("RELAYGR_BENCH_SCALE")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(200_000);
    let scale_qps = 2_000.0;
    let scale_wl = WorkloadConfig {
        qps: scale_qps,
        duration_us: (scale_requests as f64 / scale_qps * 1e6) as u64,
        num_users: 1_000_000,
        ..Default::default()
    };
    let trace_path = std::env::temp_dir().join("relaygr_bench_scale.trace");
    let trace_path = trace_path.to_str().expect("utf-8 temp path");
    let (recorded, _) =
        relaygr::workload::trace::record(trace_path, &scale_wl).expect("scale trace records");
    let replay_wl = relaygr::workload::trace::open_replay(trace_path).expect("trace opens");
    let scale_cfg = SimConfig::standard(Mode::RelayGr { dram: DramPolicy::Capacity(500 << 30) });
    let mut events = 0u64;
    let mut completed = 0u64;
    let mut rs = bench("simloop/scale_replay_2000qps", 0, 3, || {
        let m = relaygr::cluster::run_sim(scale_cfg.clone(), &replay_wl).expect("replay runs");
        events = m.sim_events;
        completed = m.completed;
        std::hint::black_box(&m);
    });
    let events_per_sec = events as f64 / (rs.mean_us / 1e6);
    rs.extra.push(("trace_requests".into(), recorded as f64));
    rs.extra.push(("events".into(), events as f64));
    rs.extra.push(("events_per_sec".into(), events_per_sec));
    rs.extra.push(("requests_per_sec".into(), completed as f64 / (rs.mean_us / 1e6)));
    println!(
        "{:<44} {:>20.0} events/s ({} events, {} of {} requests)",
        "simloop/scale_replay_2000qps", events_per_sec, events, completed, recorded
    );
    let _ = std::fs::remove_file(trace_path);
    results.push(rs);

    // --- figure grid: serial vs parallel wall-clock -------------------------
    let mut serial_rows = Vec::new();
    let r1 = bench("simloop/figure_grid_jobs1", 0, 3, || {
        serial_rows = relaygr::figures::scenarios::grid_rows(&grid_args(1)).expect("grid runs");
    });
    let mut parallel_rows = Vec::new();
    let mut rn = bench(&format!("simloop/figure_grid_jobs{jobs}"), 0, 3, || {
        parallel_rows =
            relaygr::figures::scenarios::grid_rows(&grid_args(jobs)).expect("grid runs");
    });
    assert_eq!(
        serial_rows, parallel_rows,
        "figure grid rows must be byte-identical at any job count"
    );
    let speedup = r1.mean_us / rn.mean_us;
    rn.extra.push(("speedup_vs_jobs1".into(), speedup));
    rn.extra.push(("jobs".into(), jobs as f64));
    println!("figure grid speedup at --jobs {jobs}: {speedup:.2}×");
    results.push(r1);
    results.push(rn);

    write_results("simloop", &results);
}
