//! End-to-end simulator-loop benchmarks: the first real perf-trajectory
//! datapoints for the evaluation plane itself.
//!
//! * `simloop/steady_2s_300qps` — one full discrete-event simulation
//!   (timer wheel + streaming arrivals + slab-backed coordinator),
//!   reporting wall-clock and `events_per_sec` (total wheel events over
//!   mean wall time);
//! * `simloop/figure_grid_jobs{1,N}` — the `figure scenarios` grid (4
//!   scenarios × 2 modes, quick shape) through the deterministic
//!   parallel executor at 1 vs N jobs, with the byte-identical-rows
//!   check run inline and `speedup_vs_jobs1` recorded on the parallel
//!   row.
//!
//! Emits `BENCH_simloop.json` (and `results/bench/simloop.json`); runs
//! in CI next to the other suites.  `--jobs N` overrides the parallel
//! arm's job count (default 4).

#[path = "harness.rs"]
mod harness;

use harness::{bench, write_results};
use relaygr::cluster::SimConfig;
use relaygr::relay::baseline::Mode;
use relaygr::relay::tier::DramPolicy;
use relaygr::util::cli::Args;
use relaygr::workload::WorkloadConfig;

fn grid_args(jobs: usize) -> Args {
    Args::parse(
        [
            "bench".to_string(),
            "figure".to_string(),
            "--quick".to_string(),
            "--qps".to_string(),
            "60".to_string(),
            "--jobs".to_string(),
            jobs.to_string(),
        ]
        .into_iter(),
    )
    .expect("static args parse")
}

fn main() {
    let argv = Args::from_env().unwrap_or_default();
    let jobs = argv.get_usize("jobs", 4).unwrap_or(4);
    let mut results = Vec::new();

    // --- one full simulation: events/sec -----------------------------------
    let cfg = SimConfig::standard(Mode::RelayGr { dram: DramPolicy::Capacity(500 << 30) });
    let wl = WorkloadConfig {
        qps: 300.0,
        duration_us: 2_000_000,
        num_users: 10_000,
        ..Default::default()
    };
    let mut events = 0u64;
    let mut completed = 0u64;
    let mut r = bench("simloop/steady_2s_300qps", 1, 10, || {
        let m = relaygr::cluster::run_sim(cfg.clone(), &wl).expect("sim runs");
        events = m.sim_events;
        completed = m.completed;
        std::hint::black_box(&m);
    });
    r.extra.push(("events".into(), events as f64));
    r.extra.push(("events_per_sec".into(), events as f64 / (r.mean_us / 1e6)));
    r.extra.push(("completed_requests".into(), completed as f64));
    println!(
        "{:<44} {:>20.0} events/s ({} events, {} requests)",
        "simloop/steady_2s_300qps", events as f64 / (r.mean_us / 1e6), events, completed
    );
    results.push(r);

    // --- figure grid: serial vs parallel wall-clock -------------------------
    let mut serial_rows = Vec::new();
    let r1 = bench("simloop/figure_grid_jobs1", 0, 3, || {
        serial_rows = relaygr::figures::scenarios::grid_rows(&grid_args(1)).expect("grid runs");
    });
    let mut parallel_rows = Vec::new();
    let mut rn = bench(&format!("simloop/figure_grid_jobs{jobs}"), 0, 3, || {
        parallel_rows =
            relaygr::figures::scenarios::grid_rows(&grid_args(jobs)).expect("grid runs");
    });
    assert_eq!(
        serial_rows, parallel_rows,
        "figure grid rows must be byte-identical at any job count"
    );
    let speedup = r1.mean_us / rn.mean_us;
    rn.extra.push(("speedup_vs_jobs1".into(), speedup));
    rn.extra.push(("jobs".into(), jobs as f64));
    println!("figure grid speedup at --jobs {jobs}: {speedup:.2}×");
    results.push(r1);
    results.push(rn);

    write_results("simloop", &results);
}
