//! Admission-control hot-path microbenchmarks: the static Eqs. 1–3
//! decide path, the closed-loop adaptive decide path (windowed
//! estimators + footprint window) under hot-user reuse and under
//! distinct-user churn, and the full coordinator decision flow with
//! adaptive admission enabled.  The trigger runs once per long request
//! on the side path, so its budget is a few microseconds; emits
//! `BENCH_admission.json` so the admission hot path joins the recorded
//! perf trajectory.

#[path = "harness.rs"]
mod harness;

use harness::{bench, write_results};
use relaygr::relay::trigger::{
    AdmissionConfig, BehaviorMeta, Decision, Trigger, TriggerConfig,
};

fn meta(user: u64) -> BehaviorMeta {
    BehaviorMeta { user, prefix_len: 4096, dim: 256 }
}

fn main() {
    let mut results = Vec::new();
    const KV: usize = 32 << 20;

    // --- static decide: the pre-adaptive Eqs. 1-3 flow --------------------
    let mut stat = Trigger::new(
        TriggerConfig::paper_example(),
        Box::new(|m: &BehaviorMeta| m.prefix_len as f64 * 20.0),
    );
    let mut now = 0u64;
    let mut i = 0u64;
    results.push(bench("admission/static_decide_release", 100, 50_000, || {
        now += 500;
        i += 1;
        if stat.decide(now, &meta(i & 1023), KV) == Decision::Admit {
            stat.release();
        }
    }));

    // --- adaptive decide, hot users: footprint window mostly re-admits ----
    let mut cfg = TriggerConfig::paper_example();
    cfg.admission = AdmissionConfig::adaptive();
    let mut hot = Trigger::new(cfg, Box::new(|m: &BehaviorMeta| m.prefix_len as f64 * 20.0));
    let mut now = 0u64;
    let mut i = 0u64;
    results.push(bench("admission/adaptive_decide_hot_users", 100, 50_000, || {
        now += 500;
        i += 1;
        if hot.decide(now, &meta(i & 63), KV) == Decision::Admit {
            hot.release();
        }
    }));

    // --- adaptive decide, distinct users: window churn + pruning ----------
    let mut cfg = TriggerConfig::paper_example();
    cfg.admission = AdmissionConfig::adaptive();
    cfg.t_life_us = 200_000; // short horizon: constant prune pressure
    let mut churn = Trigger::new(cfg, Box::new(|m: &BehaviorMeta| m.prefix_len as f64 * 20.0));
    let mut now = 0u64;
    let mut u = 0u64;
    results.push(bench("admission/adaptive_decide_cold_churn", 100, 50_000, || {
        now += 500;
        u += 1;
        if churn.decide(now, &meta(u), KV) == Decision::Admit {
            churn.release();
        }
    }));

    // --- coordinator decision flow with adaptive admission ----------------
    {
        use relaygr::relay::coordinator::{RankAction, RelayCoordinator, SignalAction, Stage};
        use relaygr::relay::tier::DramPolicy;
        let mut sim_cfg = relaygr::cluster::SimConfig::standard(
            relaygr::relay::baseline::Mode::RelayGr { dram: DramPolicy::Capacity(64 << 30) },
        );
        sim_cfg.admission = AdmissionConfig::adaptive();
        let mut coord: RelayCoordinator<()> =
            RelayCoordinator::new(sim_cfg.coordinator_config(), |_| sim_cfg.estimator())
                .expect("coordinator builds");
        let kv = 32usize << 20;
        let mut id = 0u64;
        let mut now = 0u64;
        results.push(bench("coordinator/decision_flow_adaptive", 50, 20_000, || {
            id += 1;
            now += 700;
            let user = id % 1024;
            let (req, wants_trigger) = coord.on_arrival(now, id, user, 4096, &[]);
            if wants_trigger {
                match coord.on_trigger_check(now, req) {
                    SignalAction::Produce { instance, user, .. } => {
                        coord.on_psi_ready(now, instance, user, Some(()));
                    }
                    SignalAction::Reload { instance, user, bytes } => {
                        coord.on_reload_done(now, instance, user, Some(()), bytes);
                    }
                    SignalAction::None => {}
                }
            }
            let inst = coord.on_stage_done(now, req, Stage::Preproc).expect("rank routed");
            if let RankAction::StartReload { bytes } = coord.on_rank_start(now, req) {
                coord.on_reload_done(now, inst, user, Some(()), bytes);
            }
            let _ = coord.rank_compute(now, req);
            let done = coord.on_rank_done(now, req, kv);
            if let Some(bytes) = done.spill {
                coord.complete_spill(now, done.instance, done.user, bytes, ());
            }
        }));
    }

    write_results("admission", &results);
}
