//! Fault-plane microbenchmarks (PR 10): the seeded draw/resolve cycle,
//! the bounded-retry ladder, the shed-or-degrade draw, and the full
//! per-request routing cycle with the plane off vs on.  Fault resolution
//! sits on the same microsecond control-plane budget as routing and
//! admission — every path here is asserted allocation-free in steady
//! state, and the off-vs-on cycle pair is the standing measurement of
//! what an enabled plan costs a request that faults never touch.

#[path = "harness.rs"]
mod harness;

use harness::{bench, write_results};

#[global_allocator]
static ALLOC: harness::CountingAlloc = harness::CountingAlloc;

use relaygr::relay::baseline::Mode;
use relaygr::relay::cell::CellSet;
use relaygr::relay::coordinator::{RelayCoordinator, Stage};
use relaygr::relay::fault::{FaultConfig, FaultKind, FaultPlan};
use relaygr::relay::tier::DramPolicy;

fn plan(spec: &str, seed: u64) -> FaultPlan {
    let mut cfg = FaultConfig::parse(spec).expect("valid fault spec");
    cfg.seed = seed;
    FaultPlan::new(cfg)
}

/// A single-cell set over the standard cluster shape with the given
/// fault spec compiled in (duration 0 — no scheduled crash events; this
/// measures the steady request path, not churn).
fn cell_set(spec: &str) -> CellSet<()> {
    let mut cfg =
        relaygr::cluster::SimConfig::standard(Mode::RelayGr { dram: DramPolicy::Disabled });
    cfg.faults = FaultConfig::parse(spec).expect("valid fault spec");
    let coords = (0..cfg.cells)
        .map(|_| RelayCoordinator::new(cfg.cell_coordinator_config(), |_| cfg.estimator()))
        .collect::<Result<Vec<_>, _>>()
        .expect("coordinators build");
    CellSet::new(cfg.cell_config(), coords, 0).expect("cell set builds")
}

/// One full short-request cycle (the bench_cells shape): arrival pick,
/// in-cell route, rank classification, completion.  Short prefixes keep
/// the ψ lifecycle out of the loop so the off-vs-on pair isolates the
/// fault plane's per-request overhead.
fn cycle(set: &mut CellSet<()>, now: u64, rid: u64, user: u64) {
    let (req, _) = set.on_arrival(now, rid, user, 256, &[]);
    set.coord_mut(req.cell).on_stage_done(now, req.id, Stage::Preproc).expect("routed");
    let _ = set.coord_mut(req.cell).on_rank_start(now, req.id);
    let _ = set.coord_mut(req.cell).rank_compute(now, req.id);
    let done = set.on_rank_done(now, req, 32 << 20);
    std::hint::black_box(done.outcome);
}

fn main() {
    let mut results = Vec::new();

    // Zero-rate passthrough: the branch every request pays when a kind
    // is not configured — must be a load and a compare, nothing more.
    {
        let mut p = plan("none", 42);
        let mut id = 0u64;
        results.push(bench("faults/resolve_off_passthrough_x1024", 100, 10_000, || {
            for _ in 0..1024 {
                id += 1;
                std::hint::black_box(p.resolve(FaultKind::PsiFail, id));
            }
        }));
        assert!(!p.report().any(), "zero-rate plan must never inject");
    }

    // Live draw at a realistic rate with retries: ~90% clean draws, ~10%
    // inject + bounded-retry ladder — the steady mix of a faulted run.
    {
        let mut p = plan("trigger-drop:0.1,retry:2,backoff:200us", 42);
        let mut id = 0u64;
        results.push(bench("faults/resolve_draw_retry_x1024", 100, 10_000, || {
            for _ in 0..1024 {
                id += 1;
                std::hint::black_box(p.resolve(FaultKind::TriggerDrop, id));
            }
        }));
        let r = p.report();
        assert!(r.any() && r.retried[FaultKind::TriggerDrop.index()] > 0);
    }

    // Worst case: rate 1.0 injects every op and burns the full 8-attempt
    // ladder (a [0,1) draw never beats rate 1.0, so nothing recovers).
    {
        let mut p = plan("trigger-drop:1.0,retry:8,backoff:200us", 42);
        let mut id = 0u64;
        results.push(bench("faults/resolve_full_ladder_x1024", 100, 5_000, || {
            for _ in 0..1024 {
                id += 1;
                std::hint::black_box(p.resolve(FaultKind::TriggerDrop, id));
            }
        }));
        let r = p.report();
        let idx = FaultKind::TriggerDrop.index();
        assert_eq!(r.recovered[idx], 0, "rate 1.0 must never recover");
        assert_eq!(r.retried[idx], 8 * r.injected[idx]);
    }

    // The degradation-ladder draw: shed-vs-degrade on every op.
    {
        let mut p = plan("psi-fail:1.0,shed:0.3", 42);
        let mut id = 0u64;
        results.push(bench("faults/shed_or_degrade_x1024", 100, 10_000, || {
            for _ in 0..1024 {
                id += 1;
                std::hint::black_box(p.shed_or_degrade(FaultKind::PsiFail, id));
            }
        }));
        let (_, _, _, deg, shed) = p.report().totals();
        assert!(deg > 0 && shed > 0, "shed:0.3 must split the ladder");
    }

    // The full per-request decision flow, plane off: the PR 9 baseline
    // this suite's on-cycle is compared against run over run.
    {
        let mut set = cell_set("none");
        let mut id = 0u64;
        let mut now = 0u64;
        results.push(bench("faults/cycle_plane_off", 100, 20_000, || {
            id += 1;
            now += 700;
            cycle(&mut set, now, id, id % 1024);
        }));
    }

    // The same flow with an enabled plan: every fault decision point is
    // consulted (and the retry budget is folded into admission), so the
    // delta vs cycle_plane_off is the plane's clean-path overhead.
    {
        let mut set = cell_set("psi-fail:0.05,trigger-drop:0.05,retry:2,backoff:200us,shed:0.3");
        let mut id = 0u64;
        let mut now = 0u64;
        results.push(bench("faults/cycle_plane_on", 100, 20_000, || {
            id += 1;
            now += 700;
            cycle(&mut set, now, id, id % 1024);
        }));
    }

    // The zero-allocation contract, extended to the fault plane: draws,
    // the retry ladder, the shed draw, and both cycle shapes must show
    // no allocator traffic once slabs reach their high-water capacity.
    for r in &results {
        assert_eq!(
            r.allocs_per_op,
            Some(0.0),
            "steady-state allocation regression on '{}': {:?} allocs/op",
            r.name,
            r.allocs_per_op
        );
    }

    write_results("faults", &results);
}
