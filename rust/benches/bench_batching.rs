//! Batch-former microbenchmarks (PR 7): the coordinator's offer →
//! fill/deadline → close cycle at representative windows, isolated from
//! ranking compute.  Every cycle shape must honour the zero-allocation
//! steady-state contract (pooled member and drain buffers), which this
//! binary asserts on every run — the batch former sits on the same
//! microsecond control-plane budget as routing and admission.

#[path = "harness.rs"]
mod harness;

use harness::{bench, write_results};

#[global_allocator]
static ALLOC: harness::CountingAlloc = harness::CountingAlloc;

use relaygr::relay::baseline::Mode;
use relaygr::relay::coordinator::{BatchDecision, RelayCoordinator, ReqId, Stage};
use relaygr::relay::tier::DramPolicy;

/// A coordinator with `n` perpetually rank-ready passes for one user
/// (affinity routes them to a single instance).  The former never
/// consumes request state, so the same handles cycle through
/// offer/close forever — the benchmarks measure the batch control plane
/// alone, with the member requests held steady.
fn ready_coord(window_us: u64, max: usize, n: u64) -> (RelayCoordinator<()>, Vec<ReqId>, usize) {
    let mut cfg = relaygr::cluster::SimConfig::standard(Mode::RelayGr { dram: DramPolicy::Disabled });
    cfg.batch_window_us = window_us;
    cfg.batch_max = max;
    let mut coord: RelayCoordinator<()> =
        RelayCoordinator::new(cfg.coordinator_config(), |_| cfg.estimator())
            .expect("coordinator builds");
    let mut inst = 0usize;
    let reqs: Vec<ReqId> = (0..n)
        .map(|i| {
            let (req, _) = coord.on_arrival(i * 10, i, 42, 4096, &[]);
            inst = coord.on_stage_done(i * 10, req, Stage::Preproc).expect("routed");
            let _ = coord.on_rank_start(i * 10, req);
            req
        })
        .collect();
    (coord, reqs, inst)
}

fn main() {
    let mut results = Vec::new();

    // Window 0: the unbatched identity path.  Every offer returns
    // `Solo` before touching any batch state — the cost of leaving the
    // feature compiled in but switched off.
    {
        let (mut coord, reqs, _) = ready_coord(0, 32, 8);
        let mut now = 0u64;
        results.push(bench("batch_former/offer8_window0_solo", 100, 20_000, || {
            now += 50;
            for &req in &reqs {
                assert!(matches!(coord.offer_rank(now, req), BatchDecision::Solo));
            }
        }));
    }

    // Filled flush: offers run the batch to `batch_max` and the filler
    // closes it immediately — the fast path the simulator and live
    // engine take under load.
    for window_us in [100u64, 1_000] {
        let (mut coord, reqs, inst) = ready_coord(window_us, 8, 8);
        let mut out: Vec<ReqId> = Vec::with_capacity(8);
        let mut now = 0u64;
        let mut r = bench(
            &format!("batch_former/fill8_flush_window{window_us}us"),
            100,
            20_000,
            || {
                now += window_us;
                let mut gen = 0u64;
                for &req in &reqs {
                    if let BatchDecision::Filled { gen: g } = coord.offer_rank(now, req) {
                        gen = g;
                    }
                }
                assert!(
                    coord.close_batch(now, inst, gen, &mut out),
                    "eighth offer filled the batch"
                );
                std::hint::black_box(out.len());
            },
        );
        let passes = 8e6 / r.mean_us.max(1e-9);
        r.extra.push(("passes_per_sec".to_string(), passes));
        results.push(r);
    }

    // Deadline flush: a short batch closed by its window timer (the
    // simulator's `BatchFlush` event, the reference driver's pending
    // deadline drain), then a second, stale close against the same
    // generation — the race every timer flush must lose cleanly after a
    // `Filled` drain.
    {
        let (mut coord, reqs, inst) = ready_coord(1_000, 8, 3);
        let mut out: Vec<ReqId> = Vec::with_capacity(8);
        let mut now = 0u64;
        results.push(bench("batch_former/open3_deadline_flush+stale_close", 100, 20_000, || {
            now += 1_000;
            let mut gen = 0u64;
            for &req in &reqs {
                if let BatchDecision::Opened { gen: g, .. } = coord.offer_rank(now, req) {
                    gen = g;
                }
            }
            assert!(coord.close_batch(now, inst, gen, &mut out), "deadline close drains the batch");
            std::hint::black_box(out.len());
            assert!(!coord.close_batch(now, inst, gen, &mut out), "second close is stale");
        }));
    }

    // The zero-allocation contract, extended to the batch former: every
    // cycle shape above must run allocation-free once member and drain
    // buffers reach their high-water capacity during warm-up.
    for r in &results {
        assert_eq!(
            r.allocs_per_op,
            Some(0.0),
            "steady-state allocation regression on '{}': {:?} allocs/op",
            r.name,
            r.allocs_per_op
        );
    }

    write_results("batching", &results);
}
