//! L3 hot-path microbenchmarks: the per-request coordinator operations
//! (routing, admission, cache lookups, hierarchy bookkeeping, histogram
//! recording) plus live PJRT execution benches when artifacts exist.
//!
//! The coordinator budget is microseconds — it must never show up next
//! to the tens-of-milliseconds ranking budget.  This binary installs the
//! counting allocator and *asserts* zero steady-state allocations for
//! the per-request control-plane ops (affinity route, admission
//! decide+release, hierarchy hit lookup) — the zero-allocation hot-path
//! contract, enforced on every bench run rather than assumed.

#[path = "harness.rs"]
mod harness;

use harness::{bench, write_results};

#[global_allocator]
static ALLOC: harness::CountingAlloc = harness::CountingAlloc;
use relaygr::relay::hbm::HbmCache;
use relaygr::relay::hierarchy::CacheHierarchy;
use relaygr::relay::router::{Router, RouterConfig};
use relaygr::relay::tier::{DramPolicy, EvictPolicy, PolicyTier, TierConfig};
use relaygr::relay::trigger::{BehaviorMeta, Trigger, TriggerConfig};
use relaygr::util::rng::Rng;
use relaygr::util::stats::Histogram;

fn main() {
    let mut results = Vec::new();
    let mut rng = Rng::new(7);

    // --- router ------------------------------------------------------------
    let mut router = Router::new(RouterConfig::default()).unwrap();
    let users: Vec<u64> = (0..4096).map(|_| rng.next_u64() % 100_000).collect();
    let mut i = 0;
    results.push(bench("router/route_special+complete", 100, 20_000, || {
        let u = users[i & 4095];
        i += 1;
        let r = router.route_special(u);
        router.on_complete(r.instance);
    }));
    let mut i = 0;
    results.push(bench("router/route_normal_least_conn", 100, 20_000, || {
        let u = users[i & 4095];
        i += 1;
        let r = router.route_normal(u);
        router.on_complete(r.instance);
    }));

    // --- trigger -----------------------------------------------------------
    let mut trigger = Trigger::new(
        TriggerConfig::paper_example(),
        Box::new(|m: &BehaviorMeta| m.prefix_len as f64 * 20.0),
    );
    let mut now = 0u64;
    let mut i = 0;
    results.push(bench("trigger/decide+release", 100, 20_000, || {
        now += 500;
        let meta = BehaviorMeta { user: users[i & 4095], prefix_len: 4096, dim: 256 };
        i += 1;
        if trigger.decide(now, &meta, 32 << 20) == relaygr::relay::trigger::Decision::Admit {
            trigger.release();
        }
    }));

    // --- HBM cache ---------------------------------------------------------
    let mut hbm: HbmCache<u32> = HbmCache::new(16 << 30);
    let mut now = 0u64;
    let mut u = 0u64;
    results.push(bench("hbm/produce+consume+evict", 100, 20_000, || {
        now += 100;
        u += 1;
        let user = u % 512;
        let _ = hbm.begin_produce(user, 32 << 20, now, 300_000);
        hbm.complete_produce(user, 1);
        hbm.consume(user);
        hbm.evict(user);
    }));

    // --- sharded per-user map (trigger window / single-flight backing) ------
    // The coordinator-stack per-user maps are ShardedMaps since the
    // trace-scale pass; the steady-state remove→insert→get_mut cycle on
    // a warmed key set must stay allocation-free (shards retain their
    // high-water capacity).
    {
        let mut map: relaygr::util::sharded::ShardedMap<(u64, usize)> =
            relaygr::util::sharded::ShardedMap::new();
        for user in 0..4096u64 {
            map.insert(user, (user, 32 << 20));
        }
        let mut u = 0u64;
        results.push(bench("sharded/remove+insert+get_mut", 100, 20_000, || {
            u += 1;
            let user = u % 4096;
            let v = map.remove(user);
            map.insert(user, v.unwrap_or((u, 32 << 20)));
            if let Some(slot) = map.get_mut(user) {
                slot.0 = u;
            }
        }));
    }

    // --- hierarchy hit lookup (the pseudo-pre-infer front door) -------------
    // Resident Ready entries with an effectively-infinite lease: every
    // probe is the pure lookup path — counter bumps only, no state
    // churn, and (asserted below) no allocator traffic.
    {
        let mut h: CacheHierarchy<u32> = CacheHierarchy::new(64 << 30, &[], 4);
        for user in 0..512u64 {
            h.hbm_mut().begin_produce(user, 16 << 20, 0, u64::MAX / 2).unwrap();
            h.hbm_mut().complete_produce(user, user as u32);
        }
        let mut u = 0u64;
        results.push(bench("hierarchy/lookup_hit", 100, 20_000, || {
            u += 1;
            std::hint::black_box(h.pseudo_pre_infer(u % 512, u));
        }));
    }

    // --- tier hierarchy -----------------------------------------------------
    let mut h: CacheHierarchy<u32> =
        CacheHierarchy::new(16 << 30, &[TierConfig::new(64 << 30, EvictPolicy::Lru)], 4);
    for user in 0..512u64 {
        h.spill(user, 32 << 20, user as u32);
    }
    let mut u = 0u64;
    results.push(bench("hierarchy/pseudo+reload_cycle", 100, 20_000, || {
        u += 1;
        let user = u % 512;
        match h.pseudo_pre_infer(user, u) {
            relaygr::relay::hierarchy::PseudoAction::StartReload { bytes } => {
                let done = h.complete_reload(user, 0, bytes, u, 1 << 40);
                let _ = done;
                h.hbm_mut().consume(user);
                h.hbm_mut().evict(user);
            }
            _ => {
                h.hbm_mut().consume(user);
                h.hbm_mut().evict(user);
            }
        }
    }));

    // --- tier eviction under churn ------------------------------------------
    // A deliberately tiny tier so every insert evicts: the O(log n)
    // victim index is what keeps this flat as resident count grows (the
    // old DRAM tier scanned all entries per eviction).
    for policy in [EvictPolicy::Lru, EvictPolicy::Lfu, EvictPolicy::CostAware] {
        let mut t: PolicyTier<u32> = PolicyTier::new(20_000 << 20, policy);
        for user in 0..20_000u64 {
            let _ = t.insert_evicting(user, 1 << 20, 0, false);
        }
        let mut u = 20_000u64;
        results.push(bench(
            &format!("tier/evict_churn_20k[{}]", policy.label()),
            100,
            20_000,
            || {
                u += 1;
                let _ = t.insert_evicting(u, 1 << 20, 0, false);
                t.get(u ^ 1);
            },
        ));
    }

    // --- coordinator: pure decision flow (no compute) ------------------------
    // The full per-request relay-race cycle through the shared
    // RelayCoordinator with an instantly-completing host: admission →
    // signal pseudo-pre-infer → routing → rank classification → consume →
    // completion + spill.  Regression baseline for future policy changes.
    // Run twice — flight recorder off and on — so BENCH_hotpath.json
    // carries the whole-decision-path cost of tracing as
    // `trace_overhead_ns_per_op` on the traced twin.
    {
        use relaygr::relay::coordinator::{RankAction, RelayCoordinator, SignalAction, Stage};
        for trace_spans in [0usize, 1 << 12] {
            let mut sim_cfg = relaygr::cluster::SimConfig::standard(
                relaygr::relay::baseline::Mode::RelayGr { dram: DramPolicy::Capacity(64 << 30) },
            );
            sim_cfg.trace_spans = trace_spans;
            let name = if trace_spans == 0 {
                "coordinator/full_decision_flow"
            } else {
                "coordinator/full_decision_flow_traced"
            };
            let mut coord: RelayCoordinator<()> =
                RelayCoordinator::new(sim_cfg.coordinator_config(), |_| sim_cfg.estimator())
                    .expect("coordinator builds");
            let kv = 32usize << 20;
            let mut id = 0u64;
            let mut now = 0u64;
            results.push(bench(name, 50, 20_000, || {
                id += 1;
                now += 700;
                let user = id % 1024;
                let (req, wants_trigger) = coord.on_arrival(now, id, user, 4096, &[]);
                if wants_trigger {
                    match coord.on_trigger_check(now, req) {
                        SignalAction::Produce { instance, user, .. } => {
                            coord.on_psi_ready(now, instance, user, Some(()));
                        }
                        SignalAction::Reload { instance, user, bytes } => {
                            coord.on_reload_done(now, instance, user, Some(()), bytes);
                        }
                        SignalAction::None => {}
                    }
                }
                let inst = coord
                    .on_stage_done(now, req, Stage::Preproc)
                    .expect("rank instance routed");
                if let RankAction::StartReload { bytes } = coord.on_rank_start(now, req) {
                    coord.on_reload_done(now, inst, user, Some(()), bytes);
                }
                let _ = coord.rank_compute(now, req);
                let done = coord.on_rank_done(now, req, kv);
                if let Some(bytes) = done.spill {
                    coord.complete_spill(now, done.instance, done.user, bytes, ());
                }
            }));
        }
        let base = results
            .iter()
            .find(|r| r.name == "coordinator/full_decision_flow")
            .map(|r| r.mean_us)
            .expect("untraced twin benchmarked");
        if let Some(t) =
            results.iter_mut().find(|r| r.name == "coordinator/full_decision_flow_traced")
        {
            t.extra.push(("trace_overhead_ns_per_op".to_string(), (t.mean_us - base) * 1e3));
        }
    }

    // --- flight recorder: span emission into a warm ring (PR 8) --------------
    // The per-event cost of tracing in isolation: shard select + slot
    // write, overwriting oldest once the ring is full.  The recorder
    // pre-sizes every shard at construction, so this is asserted
    // allocation-free below alongside the other hot ops.
    {
        use relaygr::relay::flight::{FlightRecorder, SpanKind};
        let mut fl = FlightRecorder::new(1 << 12);
        // Warm every shard past capacity so steady state is the
        // overwrite path.
        let mut i = 0u64;
        while i < (2 << 12) {
            fl.emit(i, i, SpanKind::Arrival, 0, 0);
            i += 1;
        }
        results.push(bench("coordinator/trace_emit", 100, 50_000, || {
            i += 1;
            fl.emit(i, i, SpanKind::RankDone, 1, 0);
        }));
        std::hint::black_box(fl.retained());
    }

    // --- coordinator: batch former (PR 7) ------------------------------------
    // The microbatching control plane in isolation: offer four
    // rank-ready passes on one instance until the batch fills, then
    // close it into a recycled drain buffer.  Member and drain buffers
    // are pooled (high-water capacity after warm-up), so the
    // steady-state form/flush cycle is asserted allocation-free below —
    // the PR 5 contract extended to the batch state.
    {
        use relaygr::relay::coordinator::{BatchDecision, RelayCoordinator, ReqId, Stage};
        let mut sim_cfg = relaygr::cluster::SimConfig::standard(
            relaygr::relay::baseline::Mode::RelayGr { dram: DramPolicy::Disabled },
        );
        sim_cfg.batch_window_us = 1_000;
        sim_cfg.batch_max = 4;
        let mut coord: RelayCoordinator<()> =
            RelayCoordinator::new(sim_cfg.coordinator_config(), |_| sim_cfg.estimator())
                .expect("coordinator builds");
        // Four perpetually rank-ready passes for one user (affinity
        // routes them to a single instance); the former never consumes
        // request state, so the same handles cycle forever.
        let mut inst = 0usize;
        let reqs: Vec<ReqId> = (0..4u64)
            .map(|i| {
                let (req, _) = coord.on_arrival(i * 10, i, 42, 4096, &[]);
                inst = coord.on_stage_done(i * 10, req, Stage::Preproc).expect("routed");
                let _ = coord.on_rank_start(i * 10, req);
                req
            })
            .collect();
        let mut out: Vec<ReqId> = Vec::with_capacity(4);
        let mut now = 0u64;
        results.push(bench("coordinator/batch_form+flush", 100, 20_000, || {
            now += 50;
            let mut gen = 0u64;
            for &req in &reqs {
                if let BatchDecision::Filled { gen: g } = coord.offer_rank(now, req) {
                    gen = g;
                }
            }
            assert!(coord.close_batch(now, inst, gen, &mut out), "fourth offer filled the batch");
            std::hint::black_box(out.len());
        }));
    }

    // --- metrics -----------------------------------------------------------
    let mut h = Histogram::new();
    let mut x = 1.0f64;
    results.push(bench("stats/histogram_record+p99", 100, 50_000, || {
        x = (x * 1.37) % 1e6 + 1.0;
        h.record(x);
        if (x as u64) % 64 == 0 {
            std::hint::black_box(h.p99());
        }
    }));

    // --- end-to-end simulated second ----------------------------------------
    results.push(bench("sim/one_simulated_second_300qps", 1, 20, || {
        let cfg = relaygr::cluster::SimConfig::standard(relaygr::relay::baseline::Mode::RelayGr {
            dram: DramPolicy::Capacity(500 << 30),
        });
        let wl = relaygr::workload::WorkloadConfig {
            qps: 300.0,
            duration_us: 1_000_000,
            num_users: 10_000,
            ..Default::default()
        };
        std::hint::black_box(relaygr::cluster::run_sim(cfg, &wl).unwrap());
    }));

    // --- live PJRT execution (when artifacts are present) -------------------
    if let Ok(engine) = relaygr::runtime::Engine::load("artifacts") {
        if let Some(spec) = engine.manifest.default_variant() {
            use relaygr::runtime::{synth_embedding, FnKind};
            let prefix_m = engine.model(FnKind::Prefix, &spec).unwrap();
            let rank_m = engine.model(FnKind::Rank, &spec).unwrap();
            let full_m = engine.model(FnKind::Full, &spec).unwrap();
            let prefix = synth_embedding(1, spec.prefix_len, spec.dim, 0.5);
            let incr = synth_embedding(2, spec.incr_len, spec.dim, 0.5);
            let items = synth_embedding(3, spec.num_items, spec.dim, 0.5);
            let kv = prefix_m.execute_to_device(&[&prefix]).unwrap();
            results.push(bench(&format!("pjrt/prefix[{}]", spec.name()), 3, 30, || {
                std::hint::black_box(prefix_m.execute_to_device(&[&prefix]).unwrap());
            }));
            results.push(bench(&format!("pjrt/rank_on_psi[{}]", spec.name()), 3, 30, || {
                std::hint::black_box(rank_m.execute_with_kv(&kv, &[&incr, &items]).unwrap());
            }));
            results.push(bench(&format!("pjrt/full[{}]", spec.name()), 3, 30, || {
                std::hint::black_box(full_m.execute_host(&[&prefix, &incr, &items]).unwrap());
            }));
            results.push(bench(&format!("pjrt/spill_d2h[{}]", spec.name()), 3, 30, || {
                std::hint::black_box(kv.to_host().unwrap());
            }));
            let host = kv.to_host().unwrap();
            results.push(bench(&format!("pjrt/reload_h2d[{}]", spec.name()), 3, 30, || {
                std::hint::black_box(rank_m.kv_from_host(&host).unwrap());
            }));
        }
    } else {
        eprintln!("(skipping pjrt benches: no artifacts — run `make artifacts`)");
    }

    // The zero-allocation hot-path contract: the per-request control
    // plane ops must show no allocator traffic in steady state (warm-up
    // grows every pool/table to its high-water mark first).
    for name in [
        "router/route_special+complete",
        "trigger/decide+release",
        "hierarchy/lookup_hit",
        "sharded/remove+insert+get_mut",
        "coordinator/batch_form+flush",
        "coordinator/trace_emit",
    ] {
        let r = results.iter().find(|r| r.name == name).expect("hot op benchmarked");
        assert_eq!(
            r.allocs_per_op,
            Some(0.0),
            "steady-state allocation regression on hot op '{name}': {:?} allocs/op",
            r.allocs_per_op
        );
    }

    write_results("hotpath", &results);
}
