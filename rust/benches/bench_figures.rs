//! End-to-end figure benches: one timed entry per paper table/figure.
//!
//! Each bench runs the corresponding figure experiment (shortened sweep)
//! and reports wall-clock cost, so `cargo bench` both regenerates every
//! figure's machinery and tracks the harness's own performance.  Full
//! paper-quality sweeps: `relaygr figure all`.

#[path = "harness.rs"]
mod harness;

use harness::{bench, write_results};
use relaygr::figures;
use relaygr::util::cli::Args;

fn quick_args() -> Args {
    Args::parse(
        ["bench", "figure", "--quick", "--results", "results/bench-figures"]
            .iter()
            .map(|s| s.to_string()),
    )
    .unwrap()
}

fn main() {
    let args = quick_args();
    let mut results = Vec::new();
    for id in figures::ALL {
        results.push(bench(&format!("figure/{id}"), 0, 1, || {
            figures::run_one(id, &args).unwrap_or_else(|e| panic!("{id}: {e:#}"));
        }));
    }
    write_results("figures", &results);
}
