//! Ablation benches for the design choices DESIGN.md calls out: the
//! trigger's admission knobs (M, r2, headroom), the router's virtual-node
//! count, and the hierarchy's promotion-concurrency cap.  Each prints a
//! table of the end-to-end effect through the simulator.

#[path = "harness.rs"]
mod harness;

use relaygr::cluster::{run_sim, SimConfig};
use relaygr::relay::baseline::Mode;
use relaygr::relay::router::{HashRing, Router, RouterConfig};
use relaygr::relay::tier::DramPolicy;
use relaygr::workload::WorkloadConfig;

fn wl(qps: f64) -> WorkloadConfig {
    WorkloadConfig {
        qps,
        duration_us: 8_000_000,
        num_users: 30_000,
        fixed_long_len: Some(3072),
        max_prefix: 3072,
        refresh_prob: 0.5,
        seed: 21,
        ..Default::default()
    }
}

fn main() {
    println!("=== ablation: model slots M (trigger Eq. 3 compute bound) ===");
    println!("{:>3} {:>10} {:>10} {:>10} {:>9}", "M", "p99_ms", "success", "hbm_hits", "admitted");
    for m_slots in [1usize, 2, 5, 10] {
        let mut cfg = SimConfig::standard(Mode::RelayGr { dram: DramPolicy::Disabled });
        cfg.m_slots = m_slots;
        let m = run_sim(cfg, &wl(300.0)).unwrap();
        println!(
            "{:>3} {:>10.1} {:>10.4} {:>10} {:>9}",
            m_slots,
            m.p99_e2e() / 1e3,
            m.success_rate(),
            m.outcome_counts[1],
            m.trigger.admitted
        );
    }

    println!("\n=== ablation: special-instance fraction r2 (placement density) ===");
    println!("{:>5} {:>9} {:>10} {:>10} {:>13}", "r2", "specials", "p99_ms", "success", "special_util");
    for r2 in [0.05, 0.1, 0.2, 0.4] {
        let mut cfg = SimConfig::standard(Mode::RelayGr { dram: DramPolicy::Disabled });
        cfg.router.r2 = r2;
        let m = run_sim(cfg, &wl(300.0)).unwrap();
        println!(
            "{:>5} {:>9} {:>10.1} {:>10.4} {:>12.1}%",
            r2,
            m.special_instances.len(),
            m.p99_e2e() / 1e3,
            m.success_rate(),
            m.special_util() * 100.0
        );
    }

    println!("\n=== ablation: trigger headroom (risk-test threshold) ===");
    println!("{:>9} {:>9} {:>12} {:>10}", "headroom", "admitted", "not_at_risk", "success");
    for headroom in [0.4, 0.8, 1.2] {
        // Headroom scales which lengths count as at-risk via the budget.
        let mut cfg = SimConfig::standard(Mode::RelayGr { dram: DramPolicy::Disabled });
        cfg.pipeline.rank_budget_us = 50_000.0 * headroom / 0.8;
        let m = run_sim(cfg, &wl(200.0)).unwrap();
        println!(
            "{:>9} {:>9} {:>12} {:>10.4}",
            headroom,
            m.trigger.admitted,
            m.trigger.not_at_risk,
            m.success_rate()
        );
    }

    println!("\n=== ablation: hierarchy reload concurrency cap ===");
    println!("{:>4} {:>9} {:>9} {:>9} {:>10}", "cap", "reloads", "queued", "joined", "load_p99");
    for cap in [1usize, 2, 4, 8] {
        let mut cfg = SimConfig::standard(Mode::RelayGr { dram: DramPolicy::Capacity(500 << 30) });
        cfg.max_reload_concurrency = cap;
        let mut w = wl(300.0);
        w.refresh_prob = 0.9;
        let m = run_sim(cfg, &w).unwrap();
        println!(
            "{:>4} {:>9} {:>9} {:>9} {:>10.2}",
            cap,
            m.hierarchy.reloads_started,
            m.hierarchy.reloads_queued,
            m.hierarchy.reloads_joined,
            m.load.p99() / 1e3
        );
    }

    println!("\n=== ablation: consistent-hash virtual nodes (balance vs ring size) ===");
    println!("{:>7} {:>12} {:>12}", "vnodes", "max/mean", "moved_on_churn");
    for vnodes in [4usize, 16, 64, 256] {
        let ring = HashRing::new(&(0..10).collect::<Vec<_>>(), vnodes);
        let mut counts = vec![0u64; 10];
        for key in 0..100_000u64 {
            counts[ring.route(key).unwrap()] += 1;
        }
        let mean = 100_000.0 / 10.0;
        let max = *counts.iter().max().unwrap() as f64;
        // Churn: remove node 0, count remapped keys.
        let mut router = Router::new(RouterConfig {
            vnodes,
            ..RouterConfig::default()
        })
        .unwrap();
        let before: Vec<usize> =
            (0..20_000u64).map(|u| { let r = router.route_special(u); router.on_complete(r.instance); r.instance }).collect();
        let victim = router.special_instances()[0];
        router.remove_special(victim);
        let moved = (0..20_000u64)
            .filter(|&u| {
                let r = router.route_special(u);
                router.on_complete(r.instance);
                r.instance != before[u as usize]
            })
            .count();
        println!(
            "{:>7} {:>12.3} {:>11.1}%",
            vnodes,
            max / mean,
            moved as f64 / 200.0
        );
    }
    println!("\nablation OK");
}
