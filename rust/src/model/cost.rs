//! Analytic hardware cost model used by the discrete-event simulator.
//!
//! The paper's testbed (Ascend 910C / 310 NPUs, PCIe hosts, tenant-
//! isolated network) is not available here, so simulated-time execution
//! costs come from this model.  Constants are chosen so the *paper's own
//! reported component latencies* are reproduced at the default setting
//! (§3.2 sanity check and §4: pre-inference ≈ 35 ms at 2K/8L/256d on
//! 910C, load < 20 ms at 15K tokens, rank < 10 ms, remote fetch ~100×
//! local access), and the CPU profile is *calibrated* from live PJRT
//! runs (`relaygr calibrate`) so live measurements and simulation agree
//! on the small grid.
//!
//! All returned durations are in microseconds of simulated time.

use crate::model::spec::ModelSpec;

/// One member of a microbatched rank pass, as priced by
/// [`HardwareProfile::rank_batched_us`]: the classification (cached vs
/// full) and prefix length are fixed per-request *before* the batch
/// former groups executions, so batching can change pricing but never
/// outcomes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BatchMember {
    pub cached: bool,
    pub prefix_len: usize,
}

/// Batch-efficiency exponent: total batched rank compute scales as
/// n^BATCH_ALPHA in the batch size (M-FALCON-style candidate/request
/// batching keeps the MXU busier than latency-bound single-request
/// scoring — the same effect `pre_eff_factor` models for the prefix
/// pass).  Sub-linear (< 1.0) so per-request compute amortizes; the
/// single shared launch amortizes the fixed overhead on top.
const BATCH_ALPHA: f64 = 0.8;

/// Hardware profile: effective rates, not peak (serving-shape batches).
#[derive(Debug, Clone)]
pub struct HardwareProfile {
    pub name: String,
    /// Effective sustained compute, FLOPs per microsecond (1 TFLOP/s = 1e6).
    pub eff_flops_per_us: f64,
    /// Pre-inference efficiency multiplier: the prefix pass is one large
    /// dense batch (S_l × S_l attention + S_l-row projections) that keeps
    /// the cube/MXU far busier than latency-bound incremental scoring, so
    /// its sustained FLOP rate is a multiple of `eff_flops_per_us`.  This
    /// is what lets pre-inference of multi-K prefixes complete within the
    /// retrieval+preprocessing slack (Figs. 4, 13b).
    pub pre_eff_factor: f64,
    /// Fixed per-launch overhead (graph launch, host sync).
    pub launch_us: f64,
    /// Host→device (and device→host) PCIe bandwidth, bytes/µs (1 GB/s = 1e3).
    pub pcie_bytes_per_us: f64,
    /// Fixed per-transfer DMA setup cost.
    pub dma_fixed_us: f64,
    /// DRAM copy bandwidth for tier spills, bytes/µs.
    pub dram_bytes_per_us: f64,
    /// Cross-server fetch: round-trip latency + effective network bandwidth.
    pub net_rtt_us: f64,
    pub net_bytes_per_us: f64,
    /// CPU feature/behaviour processing throughput, tokens/µs per core.
    pub cpu_tokens_per_us: f64,
    /// Device HBM capacity in bytes (per instance).
    pub hbm_bytes: usize,
}

impl HardwareProfile {
    /// Ascend 910C-class profile (paper's Type 2 NPU; the primary testbed).
    ///
    /// Effective 1.2 TFLOP/s at serving batch shapes reproduces the
    /// paper's "pre-inference takes 35 ms" example for 2K/8L/256d.
    pub fn ascend_910c() -> HardwareProfile {
        HardwareProfile {
            name: "ascend-910c".into(),
            eff_flops_per_us: 1.2e6,
            pre_eff_factor: 2.5,
            launch_us: 300.0,
            pcie_bytes_per_us: 32_000.0, // ~32 GB/s effective gen4 x16
            dma_fixed_us: 150.0,
            dram_bytes_per_us: 50_000.0,
            net_rtt_us: 500.0,
            net_bytes_per_us: 1_250.0, // ~10 GbE effective share
            cpu_tokens_per_us: 0.4,
            hbm_bytes: 32 << 30,
        }
    }

    /// Ascend 310-class profile (paper's Type 1 NPU): ~4-5× less compute,
    /// narrower PCIe, smaller HBM.
    pub fn ascend_310() -> HardwareProfile {
        HardwareProfile {
            name: "ascend-310".into(),
            eff_flops_per_us: 0.28e6,
            pre_eff_factor: 2.5,
            launch_us: 400.0,
            pcie_bytes_per_us: 12_000.0,
            dma_fixed_us: 200.0,
            dram_bytes_per_us: 40_000.0,
            net_rtt_us: 500.0,
            net_bytes_per_us: 1_250.0,
            cpu_tokens_per_us: 0.4,
            hbm_bytes: 8 << 30,
        }
    }

    /// CPU PJRT profile for cross-checking the simulator against live
    /// measurements on the small artifact grid.  `eff_flops_per_us` is
    /// overwritten by `relaygr calibrate` output when present.
    pub fn cpu_live() -> HardwareProfile {
        HardwareProfile {
            name: "cpu-pjrt".into(),
            eff_flops_per_us: 7_450.0, // fitted by `relaygr calibrate` on this host
            pre_eff_factor: 1.0,        // CPU: no batch-efficiency cliff
            launch_us: 200.0,
            pcie_bytes_per_us: 8_000.0, // memcpy-class
            dma_fixed_us: 20.0,
            dram_bytes_per_us: 8_000.0,
            net_rtt_us: 500.0,
            net_bytes_per_us: 1_250.0,
            cpu_tokens_per_us: 2.0,
            hbm_bytes: 4 << 30,
        }
    }

    pub fn by_name(name: &str) -> Option<HardwareProfile> {
        match name {
            "ascend-910c" | "910c" => Some(Self::ascend_910c()),
            "ascend-310" | "310" => Some(Self::ascend_310()),
            "cpu-pjrt" | "cpu" => Some(Self::cpu_live()),
            _ => None,
        }
    }

    // ----- execution-cost queries (all µs) ---------------------------------

    /// Pre-inference of the long-term prefix (the relay-race side path).
    pub fn pre_infer_us(&self, spec: &ModelSpec, prefix_len: usize) -> f64 {
        self.launch_us
            + spec.prefix_flops(prefix_len) / (self.eff_flops_per_us * self.pre_eff_factor)
    }

    /// Ranking-on-cache: incremental tokens + candidates over cached ψ.
    pub fn rank_cached_us(&self, spec: &ModelSpec, prefix_len: usize) -> f64 {
        self.launch_us + spec.rank_cached_flops(prefix_len) / self.eff_flops_per_us
    }

    /// Baseline full inline inference.
    pub fn rank_full_us(&self, spec: &ModelSpec, prefix_len: usize) -> f64 {
        self.launch_us + spec.full_flops(prefix_len) / self.eff_flops_per_us
    }

    /// Compute saved per candidate segment served from the shared
    /// segment cache (beyond-prefix reuse): the item-token K/V
    /// projections skipped when the segment KV is cache-resident.
    pub fn seg_save_us(&self, spec: &ModelSpec) -> f64 {
        spec.segment_flops() / self.eff_flops_per_us
    }

    /// Ranking-on-cache with `reused` candidate segments served from the
    /// segment cache.  `reused = 0` reproduces [`Self::rank_cached_us`]
    /// bit-for-bit, so segment-off runs stay decision-identical.
    pub fn rank_cached_reuse_us(&self, spec: &ModelSpec, prefix_len: usize, reused: usize) -> f64 {
        let base = self.rank_cached_us(spec, prefix_len);
        if reused == 0 {
            return base;
        }
        (base - reused as f64 * self.seg_save_us(spec)).max(self.launch_us)
    }

    /// Full inline inference with `reused` candidate segments served
    /// from the segment cache (the candidate tokens' KV is recomputed by
    /// the full pass too; reuse trims exactly that share).
    pub fn rank_full_reuse_us(&self, spec: &ModelSpec, prefix_len: usize, reused: usize) -> f64 {
        let base = self.rank_full_us(spec, prefix_len);
        if reused == 0 {
            return base;
        }
        (base - reused as f64 * self.seg_save_us(spec)).max(self.launch_us)
    }

    /// One microbatched rank pass over `members`, with `reused`
    /// candidate segments (summed across the batch) served from the
    /// segment cache.
    ///
    /// Contract (pinned by tests and by the `--batch-window 0`
    /// cross-engine identity):
    /// * empty batch → 0 (never formed);
    /// * exactly one member → bit-identical to
    ///   [`Self::rank_cached_reuse_us`] / [`Self::rank_full_reuse_us`],
    ///   so unbatched runs price decision-for-decision as before;
    /// * k > 1 → one shared launch plus the members' summed compute
    ///   amortized by the sub-linear batch-efficiency curve
    ///   (`n^(BATCH_ALPHA-1)` per member), minus the segment-reuse
    ///   savings, floored at the launch overhead.
    pub fn rank_batched_us(&self, spec: &ModelSpec, members: &[BatchMember], reused: usize) -> f64 {
        match members {
            [] => 0.0,
            [m] if m.cached => self.rank_cached_reuse_us(spec, m.prefix_len, reused),
            [m] => self.rank_full_reuse_us(spec, m.prefix_len, reused),
            _ => {
                let compute: f64 = members
                    .iter()
                    .map(|m| {
                        let flops = if m.cached {
                            spec.rank_cached_flops(m.prefix_len)
                        } else {
                            spec.full_flops(m.prefix_len)
                        };
                        flops / self.eff_flops_per_us
                    })
                    .sum();
                let amort = (members.len() as f64).powf(BATCH_ALPHA - 1.0);
                (self.launch_us + compute * amort - reused as f64 * self.seg_save_us(spec))
                    .max(self.launch_us)
            }
        }
    }

    /// DRAM → HBM reload of a spilled ψ (H2D over PCIe).
    pub fn load_us(&self, kv_bytes: usize) -> f64 {
        self.dma_fixed_us + kv_bytes as f64 / self.pcie_bytes_per_us
    }

    /// HBM → DRAM spill (D2H); same link, issued off the critical path.
    pub fn spill_us(&self, kv_bytes: usize) -> f64 {
        self.dma_fixed_us + kv_bytes as f64 / self.pcie_bytes_per_us
    }

    /// Remote fetch of ψ from another server's pool (the Fig. 12 strawman).
    pub fn remote_fetch_us(&self, kv_bytes: usize) -> f64 {
        self.net_rtt_us + kv_bytes as f64 / self.net_bytes_per_us
    }

    /// CPU-side behaviour/feature processing for `tokens` input tokens.
    pub fn feature_proc_us(&self, tokens: usize) -> f64 {
        tokens as f64 / self.cpu_tokens_per_us
    }

    /// H2D transfer of per-request embeddings.
    pub fn h2d_embed_us(&self, bytes: usize) -> f64 {
        self.dma_fixed_us + bytes as f64 / self.pcie_bytes_per_us
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::spec::ModelSpec;

    #[test]
    fn paper_sanity_pre_inference_tens_of_ms() {
        // §3.2 uses "if pre-inference takes 35 ms" as the worked example;
        // the model lands in the same regime (tens of ms, and fitting the
        // retrieval+preproc slack at the default 2K setting).
        let hw = HardwareProfile::ascend_910c();
        let spec = ModelSpec::paper_default();
        let pre_ms = hw.pre_infer_us(&spec, 2048) / 1e3;
        assert!((5.0..50.0).contains(&pre_ms), "pre-infer {pre_ms:.1} ms");
        // Pre-inference of a 4K prefix still fits the ~70 ms slack.
        assert!(hw.pre_infer_us(&spec, 4096) / 1e3 < 70.0);
    }

    #[test]
    fn paper_sanity_rank_under_ranking_budget() {
        // §4.3: rank-on-cache below ~10 ms, well under the 50 ms budget.
        let hw = HardwareProfile::ascend_910c();
        let spec = ModelSpec::paper_default();
        let rank_ms = hw.rank_cached_us(&spec, 2048) / 1e3;
        assert!(rank_ms < 20.0, "rank {rank_ms:.1} ms");
        // Baseline full inference at 2K can exceed the ranking budget (§4.4).
        let full_ms = hw.rank_full_us(&spec, 2048) / 1e3;
        assert!(full_ms > rank_ms * 2.0, "full {full_ms:.1} vs rank {rank_ms:.1}");
    }

    #[test]
    fn paper_sanity_load_under_20ms_at_15k() {
        // §4.3: sequences up to ~15K with load below 20 ms (no concurrency).
        let hw = HardwareProfile::ascend_910c();
        let spec = ModelSpec::paper_default();
        let load_ms = hw.load_us(spec.kv_bytes_for(15 * 1024)) / 1e3;
        assert!(load_ms < 20.0, "load {load_ms:.2} ms");
    }

    #[test]
    fn segment_reuse_trims_rank_monotonically() {
        let hw = HardwareProfile::ascend_910c();
        let spec = ModelSpec::paper_default();
        let p = 2048;
        // reused = 0 is bit-identical to the unsplit cost — the segment-
        // off configuration must stay decision-for-decision unchanged.
        assert_eq!(hw.rank_cached_reuse_us(&spec, p, 0).to_bits(), hw.rank_cached_us(&spec, p).to_bits());
        assert_eq!(hw.rank_full_reuse_us(&spec, p, 0).to_bits(), hw.rank_full_us(&spec, p).to_bits());
        // Strictly decreasing in the reuse count, bounded below by the
        // launch overhead, on both the cached and full paths.
        let mut last = hw.rank_cached_reuse_us(&spec, p, 0);
        for reused in [1, 16, 128, spec.num_items] {
            let t = hw.rank_cached_reuse_us(&spec, p, reused);
            assert!(t < last, "reused={reused}: {t} !< {last}");
            assert!(t >= hw.launch_us);
            last = t;
        }
        assert!(hw.rank_full_reuse_us(&spec, p, spec.num_items) < hw.rank_full_us(&spec, p));
        // Even full reuse leaves the attention + tower majority in place.
        assert!(
            hw.rank_cached_reuse_us(&spec, p, spec.num_items) > 0.5 * hw.rank_cached_us(&spec, p)
        );
    }

    #[test]
    fn batched_rank_is_bit_identical_at_batch_size_one() {
        // The batch former routes *every* rank pass through the batched
        // price; a batch of one must reproduce the PR 6 single-request
        // costs bit-for-bit on both classification paths, at every
        // reuse count.
        let hw = HardwareProfile::ascend_910c();
        let spec = ModelSpec::paper_default();
        for p in [512, 2048, 4096] {
            for reused in [0, 1, 16, spec.num_items] {
                let cached = [BatchMember { cached: true, prefix_len: p }];
                let full = [BatchMember { cached: false, prefix_len: p }];
                assert_eq!(
                    hw.rank_batched_us(&spec, &cached, reused).to_bits(),
                    hw.rank_cached_reuse_us(&spec, p, reused).to_bits()
                );
                assert_eq!(
                    hw.rank_batched_us(&spec, &full, reused).to_bits(),
                    hw.rank_full_reuse_us(&spec, p, reused).to_bits()
                );
            }
        }
        assert_eq!(hw.rank_batched_us(&spec, &[], 0), 0.0);
    }

    #[test]
    fn batched_rank_amortizes_sublinearly() {
        let hw = HardwareProfile::ascend_910c();
        let spec = ModelSpec::paper_default();
        let m = BatchMember { cached: true, prefix_len: 2048 };
        let solo = hw.rank_cached_us(&spec, 2048);
        let mut last_per_member = solo;
        for n in [2usize, 4, 8, 16, 32] {
            let members = vec![m; n];
            let batched = hw.rank_batched_us(&spec, &members, 0);
            // Strictly cheaper than n independent passes, floored at
            // one launch, and per-member cost strictly improving.
            assert!(batched < n as f64 * solo, "n={n}: {batched} !< {}", n as f64 * solo);
            assert!(batched >= hw.launch_us);
            let per_member = batched / n as f64;
            assert!(per_member < last_per_member, "n={n}: {per_member} !< {last_per_member}");
            last_per_member = per_member;
            // But batching is not free: the batch as a whole takes
            // longer than one solo pass (the P99 tension the figure
            // sweeps).
            assert!(batched > solo);
        }
        // Mixed batches price each member by its own classification.
        let mixed =
            [BatchMember { cached: true, prefix_len: 2048 }, BatchMember { cached: false, prefix_len: 2048 }];
        let both_cached = [m, m];
        assert!(hw.rank_batched_us(&spec, &mixed, 0) > hw.rank_batched_us(&spec, &both_cached, 0));
        // Segment reuse still trims the batched pass, floored at launch.
        let members = vec![m; 8];
        assert!(
            hw.rank_batched_us(&spec, &members, 64) < hw.rank_batched_us(&spec, &members, 0)
        );
        assert!(hw.rank_batched_us(&spec, &members, 1_000_000) >= hw.launch_us);
    }

    #[test]
    fn remote_fetch_is_orders_of_magnitude_slower() {
        // Fig. 12: remote fetch can be ~100× local-cache access.
        let hw = HardwareProfile::ascend_910c();
        let kv = ModelSpec::paper_default().kv_bytes();
        let remote = hw.remote_fetch_us(kv);
        // "local access" = in-HBM pointer handoff, modeled as ~launch cost.
        let local = hw.launch_us;
        assert!(remote / local > 50.0, "remote/local = {}", remote / local);
    }

    #[test]
    fn profiles_ordered_by_capability() {
        let a910 = HardwareProfile::ascend_910c();
        let a310 = HardwareProfile::ascend_310();
        assert!(a910.eff_flops_per_us > 3.0 * a310.eff_flops_per_us);
        let spec = ModelSpec::paper_default();
        assert!(a310.rank_full_us(&spec, 2048) > a910.rank_full_us(&spec, 2048));
    }

    #[test]
    fn by_name_roundtrip() {
        for n in ["ascend-910c", "ascend-310", "cpu-pjrt"] {
            assert_eq!(HardwareProfile::by_name(n).unwrap().name, n);
        }
        assert!(HardwareProfile::by_name("h100").is_none());
    }
}
