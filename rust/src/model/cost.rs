//! Analytic hardware cost model used by the discrete-event simulator.
//!
//! The paper's testbed (Ascend 910C / 310 NPUs, PCIe hosts, tenant-
//! isolated network) is not available here, so simulated-time execution
//! costs come from this model.  Constants are chosen so the *paper's own
//! reported component latencies* are reproduced at the default setting
//! (§3.2 sanity check and §4: pre-inference ≈ 35 ms at 2K/8L/256d on
//! 910C, load < 20 ms at 15K tokens, rank < 10 ms, remote fetch ~100×
//! local access), and the CPU profile is *calibrated* from live PJRT
//! runs (`relaygr calibrate`) so live measurements and simulation agree
//! on the small grid.
//!
//! All returned durations are in microseconds of simulated time.

use crate::model::spec::ModelSpec;

/// Hardware profile: effective rates, not peak (serving-shape batches).
#[derive(Debug, Clone)]
pub struct HardwareProfile {
    pub name: String,
    /// Effective sustained compute, FLOPs per microsecond (1 TFLOP/s = 1e6).
    pub eff_flops_per_us: f64,
    /// Pre-inference efficiency multiplier: the prefix pass is one large
    /// dense batch (S_l × S_l attention + S_l-row projections) that keeps
    /// the cube/MXU far busier than latency-bound incremental scoring, so
    /// its sustained FLOP rate is a multiple of `eff_flops_per_us`.  This
    /// is what lets pre-inference of multi-K prefixes complete within the
    /// retrieval+preprocessing slack (Figs. 4, 13b).
    pub pre_eff_factor: f64,
    /// Fixed per-launch overhead (graph launch, host sync).
    pub launch_us: f64,
    /// Host→device (and device→host) PCIe bandwidth, bytes/µs (1 GB/s = 1e3).
    pub pcie_bytes_per_us: f64,
    /// Fixed per-transfer DMA setup cost.
    pub dma_fixed_us: f64,
    /// DRAM copy bandwidth for tier spills, bytes/µs.
    pub dram_bytes_per_us: f64,
    /// Cross-server fetch: round-trip latency + effective network bandwidth.
    pub net_rtt_us: f64,
    pub net_bytes_per_us: f64,
    /// CPU feature/behaviour processing throughput, tokens/µs per core.
    pub cpu_tokens_per_us: f64,
    /// Device HBM capacity in bytes (per instance).
    pub hbm_bytes: usize,
}

impl HardwareProfile {
    /// Ascend 910C-class profile (paper's Type 2 NPU; the primary testbed).
    ///
    /// Effective 1.2 TFLOP/s at serving batch shapes reproduces the
    /// paper's "pre-inference takes 35 ms" example for 2K/8L/256d.
    pub fn ascend_910c() -> HardwareProfile {
        HardwareProfile {
            name: "ascend-910c".into(),
            eff_flops_per_us: 1.2e6,
            pre_eff_factor: 2.5,
            launch_us: 300.0,
            pcie_bytes_per_us: 32_000.0, // ~32 GB/s effective gen4 x16
            dma_fixed_us: 150.0,
            dram_bytes_per_us: 50_000.0,
            net_rtt_us: 500.0,
            net_bytes_per_us: 1_250.0, // ~10 GbE effective share
            cpu_tokens_per_us: 0.4,
            hbm_bytes: 32 << 30,
        }
    }

    /// Ascend 310-class profile (paper's Type 1 NPU): ~4-5× less compute,
    /// narrower PCIe, smaller HBM.
    pub fn ascend_310() -> HardwareProfile {
        HardwareProfile {
            name: "ascend-310".into(),
            eff_flops_per_us: 0.28e6,
            pre_eff_factor: 2.5,
            launch_us: 400.0,
            pcie_bytes_per_us: 12_000.0,
            dma_fixed_us: 200.0,
            dram_bytes_per_us: 40_000.0,
            net_rtt_us: 500.0,
            net_bytes_per_us: 1_250.0,
            cpu_tokens_per_us: 0.4,
            hbm_bytes: 8 << 30,
        }
    }

    /// CPU PJRT profile for cross-checking the simulator against live
    /// measurements on the small artifact grid.  `eff_flops_per_us` is
    /// overwritten by `relaygr calibrate` output when present.
    pub fn cpu_live() -> HardwareProfile {
        HardwareProfile {
            name: "cpu-pjrt".into(),
            eff_flops_per_us: 7_450.0, // fitted by `relaygr calibrate` on this host
            pre_eff_factor: 1.0,        // CPU: no batch-efficiency cliff
            launch_us: 200.0,
            pcie_bytes_per_us: 8_000.0, // memcpy-class
            dma_fixed_us: 20.0,
            dram_bytes_per_us: 8_000.0,
            net_rtt_us: 500.0,
            net_bytes_per_us: 1_250.0,
            cpu_tokens_per_us: 2.0,
            hbm_bytes: 4 << 30,
        }
    }

    pub fn by_name(name: &str) -> Option<HardwareProfile> {
        match name {
            "ascend-910c" | "910c" => Some(Self::ascend_910c()),
            "ascend-310" | "310" => Some(Self::ascend_310()),
            "cpu-pjrt" | "cpu" => Some(Self::cpu_live()),
            _ => None,
        }
    }

    // ----- execution-cost queries (all µs) ---------------------------------

    /// Pre-inference of the long-term prefix (the relay-race side path).
    pub fn pre_infer_us(&self, spec: &ModelSpec, prefix_len: usize) -> f64 {
        self.launch_us
            + spec.prefix_flops(prefix_len) / (self.eff_flops_per_us * self.pre_eff_factor)
    }

    /// Ranking-on-cache: incremental tokens + candidates over cached ψ.
    pub fn rank_cached_us(&self, spec: &ModelSpec, prefix_len: usize) -> f64 {
        self.launch_us + spec.rank_cached_flops(prefix_len) / self.eff_flops_per_us
    }

    /// Baseline full inline inference.
    pub fn rank_full_us(&self, spec: &ModelSpec, prefix_len: usize) -> f64 {
        self.launch_us + spec.full_flops(prefix_len) / self.eff_flops_per_us
    }

    /// Compute saved per candidate segment served from the shared
    /// segment cache (beyond-prefix reuse): the item-token K/V
    /// projections skipped when the segment KV is cache-resident.
    pub fn seg_save_us(&self, spec: &ModelSpec) -> f64 {
        spec.segment_flops() / self.eff_flops_per_us
    }

    /// Ranking-on-cache with `reused` candidate segments served from the
    /// segment cache.  `reused = 0` reproduces [`Self::rank_cached_us`]
    /// bit-for-bit, so segment-off runs stay decision-identical.
    pub fn rank_cached_reuse_us(&self, spec: &ModelSpec, prefix_len: usize, reused: usize) -> f64 {
        let base = self.rank_cached_us(spec, prefix_len);
        if reused == 0 {
            return base;
        }
        (base - reused as f64 * self.seg_save_us(spec)).max(self.launch_us)
    }

    /// Full inline inference with `reused` candidate segments served
    /// from the segment cache (the candidate tokens' KV is recomputed by
    /// the full pass too; reuse trims exactly that share).
    pub fn rank_full_reuse_us(&self, spec: &ModelSpec, prefix_len: usize, reused: usize) -> f64 {
        let base = self.rank_full_us(spec, prefix_len);
        if reused == 0 {
            return base;
        }
        (base - reused as f64 * self.seg_save_us(spec)).max(self.launch_us)
    }

    /// DRAM → HBM reload of a spilled ψ (H2D over PCIe).
    pub fn load_us(&self, kv_bytes: usize) -> f64 {
        self.dma_fixed_us + kv_bytes as f64 / self.pcie_bytes_per_us
    }

    /// HBM → DRAM spill (D2H); same link, issued off the critical path.
    pub fn spill_us(&self, kv_bytes: usize) -> f64 {
        self.dma_fixed_us + kv_bytes as f64 / self.pcie_bytes_per_us
    }

    /// Remote fetch of ψ from another server's pool (the Fig. 12 strawman).
    pub fn remote_fetch_us(&self, kv_bytes: usize) -> f64 {
        self.net_rtt_us + kv_bytes as f64 / self.net_bytes_per_us
    }

    /// CPU-side behaviour/feature processing for `tokens` input tokens.
    pub fn feature_proc_us(&self, tokens: usize) -> f64 {
        tokens as f64 / self.cpu_tokens_per_us
    }

    /// H2D transfer of per-request embeddings.
    pub fn h2d_embed_us(&self, bytes: usize) -> f64 {
        self.dma_fixed_us + bytes as f64 / self.pcie_bytes_per_us
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::spec::ModelSpec;

    #[test]
    fn paper_sanity_pre_inference_tens_of_ms() {
        // §3.2 uses "if pre-inference takes 35 ms" as the worked example;
        // the model lands in the same regime (tens of ms, and fitting the
        // retrieval+preproc slack at the default 2K setting).
        let hw = HardwareProfile::ascend_910c();
        let spec = ModelSpec::paper_default();
        let pre_ms = hw.pre_infer_us(&spec, 2048) / 1e3;
        assert!((5.0..50.0).contains(&pre_ms), "pre-infer {pre_ms:.1} ms");
        // Pre-inference of a 4K prefix still fits the ~70 ms slack.
        assert!(hw.pre_infer_us(&spec, 4096) / 1e3 < 70.0);
    }

    #[test]
    fn paper_sanity_rank_under_ranking_budget() {
        // §4.3: rank-on-cache below ~10 ms, well under the 50 ms budget.
        let hw = HardwareProfile::ascend_910c();
        let spec = ModelSpec::paper_default();
        let rank_ms = hw.rank_cached_us(&spec, 2048) / 1e3;
        assert!(rank_ms < 20.0, "rank {rank_ms:.1} ms");
        // Baseline full inference at 2K can exceed the ranking budget (§4.4).
        let full_ms = hw.rank_full_us(&spec, 2048) / 1e3;
        assert!(full_ms > rank_ms * 2.0, "full {full_ms:.1} vs rank {rank_ms:.1}");
    }

    #[test]
    fn paper_sanity_load_under_20ms_at_15k() {
        // §4.3: sequences up to ~15K with load below 20 ms (no concurrency).
        let hw = HardwareProfile::ascend_910c();
        let spec = ModelSpec::paper_default();
        let load_ms = hw.load_us(spec.kv_bytes_for(15 * 1024)) / 1e3;
        assert!(load_ms < 20.0, "load {load_ms:.2} ms");
    }

    #[test]
    fn segment_reuse_trims_rank_monotonically() {
        let hw = HardwareProfile::ascend_910c();
        let spec = ModelSpec::paper_default();
        let p = 2048;
        // reused = 0 is bit-identical to the unsplit cost — the segment-
        // off configuration must stay decision-for-decision unchanged.
        assert_eq!(hw.rank_cached_reuse_us(&spec, p, 0).to_bits(), hw.rank_cached_us(&spec, p).to_bits());
        assert_eq!(hw.rank_full_reuse_us(&spec, p, 0).to_bits(), hw.rank_full_us(&spec, p).to_bits());
        // Strictly decreasing in the reuse count, bounded below by the
        // launch overhead, on both the cached and full paths.
        let mut last = hw.rank_cached_reuse_us(&spec, p, 0);
        for reused in [1, 16, 128, spec.num_items] {
            let t = hw.rank_cached_reuse_us(&spec, p, reused);
            assert!(t < last, "reused={reused}: {t} !< {last}");
            assert!(t >= hw.launch_us);
            last = t;
        }
        assert!(hw.rank_full_reuse_us(&spec, p, spec.num_items) < hw.rank_full_us(&spec, p));
        // Even full reuse leaves the attention + tower majority in place.
        assert!(
            hw.rank_cached_reuse_us(&spec, p, spec.num_items) > 0.5 * hw.rank_cached_us(&spec, p)
        );
    }

    #[test]
    fn remote_fetch_is_orders_of_magnitude_slower() {
        // Fig. 12: remote fetch can be ~100× local-cache access.
        let hw = HardwareProfile::ascend_910c();
        let kv = ModelSpec::paper_default().kv_bytes();
        let remote = hw.remote_fetch_us(kv);
        // "local access" = in-HBM pointer handoff, modeled as ~launch cost.
        let local = hw.launch_us;
        assert!(remote / local > 50.0, "remote/local = {}", remote / local);
    }

    #[test]
    fn profiles_ordered_by_capability() {
        let a910 = HardwareProfile::ascend_910c();
        let a310 = HardwareProfile::ascend_310();
        assert!(a910.eff_flops_per_us > 3.0 * a310.eff_flops_per_us);
        let spec = ModelSpec::paper_default();
        assert!(a310.rank_full_us(&spec, 2048) > a910.rank_full_us(&spec, 2048));
    }

    #[test]
    fn by_name_roundtrip() {
        for n in ["ascend-910c", "ascend-310", "cpu-pjrt"] {
            assert_eq!(HardwareProfile::by_name(n).unwrap().name, n);
        }
        assert!(HardwareProfile::by_name("h100").is_none());
    }
}
