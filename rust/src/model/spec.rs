//! Model-variant descriptors mirroring `python/compile/configs.py`.
//!
//! A [`ModelSpec`] fully determines the static-shape bucket of one GR
//! backbone variant: tensor shapes, ψ footprint (Table 1), and FLOP
//! counts for each of the three entry points (prefix / rank / full).

/// GR model family, matching the paper's Fig. 15a.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ModelType {
    /// Type 1 — HSTU (SiLU pointwise attention).
    Hstu,
    /// Type 2 — revised HSTU: differs only in the attention computation.
    HstuRev,
    /// Type 3 — LONGER-style cached backbone + RankMixer-style DLRM tower.
    LongerRankMixer,
}

impl ModelType {
    pub fn from_index(i: usize) -> Option<ModelType> {
        match i {
            1 => Some(ModelType::Hstu),
            2 => Some(ModelType::HstuRev),
            3 => Some(ModelType::LongerRankMixer),
            _ => None,
        }
    }

    pub fn index(self) -> usize {
        match self {
            ModelType::Hstu => 1,
            ModelType::HstuRev => 2,
            ModelType::LongerRankMixer => 3,
        }
    }
}

/// Numeric format of activations / ψ.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Dtype {
    F32,
    F16,
}

impl Dtype {
    pub fn bytes(self) -> usize {
        match self {
            Dtype::F32 => 4,
            Dtype::F16 => 2,
        }
    }
}

/// One static-shape GR backbone variant.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct ModelSpec {
    pub model_type: ModelType,
    pub layers: usize,
    pub dim: usize,
    pub heads: usize,
    /// S_l — long-term behaviour prefix tokens (the cached part).
    pub prefix_len: usize,
    /// S̃_l — short-term behaviours + cross features.
    pub incr_len: usize,
    /// |I| — candidate items scored per request.
    pub num_items: usize,
    pub dtype: Dtype,
}

impl ModelSpec {
    /// The paper's default setting (Table 1): 8 layers, dim 256, fp32,
    /// 2K prefix — ψ = 32 MiB.
    pub fn paper_default() -> ModelSpec {
        ModelSpec {
            model_type: ModelType::Hstu,
            layers: 8,
            dim: 256,
            heads: 4,
            prefix_len: 2048,
            incr_len: 64,
            num_items: 512,
            dtype: Dtype::F32,
        }
    }

    pub fn head_dim(&self) -> usize {
        self.dim / self.heads
    }

    pub fn total_len(&self) -> usize {
        self.prefix_len + self.incr_len + self.num_items
    }

    pub fn items_start(&self) -> usize {
        self.prefix_len + self.incr_len
    }

    /// ψ footprint in bytes: per-layer K and V over the prefix.
    ///
    /// Table 1: 8 × 2 × 2048 × 256 × 4 B = 32 MiB.
    pub fn kv_bytes(&self) -> usize {
        self.layers * 2 * self.prefix_len * self.dim * self.dtype.bytes()
    }

    /// ψ footprint for an arbitrary prefix length (requests shorter than
    /// the bucket still produce bucket-shaped caches in live mode, but the
    /// simulator accounts true lengths).
    pub fn kv_bytes_for(&self, prefix_len: usize) -> usize {
        self.layers * 2 * prefix_len * self.dim * self.dtype.bytes()
    }

    /// Per-request host→device embedding payload: every input token is a
    /// dim-wide row fetched from the embedding service (tens of MB per
    /// request at production dims, per §2.4(3)).
    pub fn embed_bytes(&self, tokens: usize) -> usize {
        tokens * self.dim * self.dtype.bytes()
    }

    // ----- FLOP accounting -------------------------------------------------
    //
    // Per HSTU layer computing `s_new` rows against `s_kv` keys:
    //   projections (Q,K,V,U):   4 · 2 · s_new · D²
    //   attention  (QKᵀ + AV):   2 · 2 · s_new · s_kv · D
    //   output proj:                 2 · s_new · D²
    // ⇒ 10·s_new·D² + 4·s_new·s_kv·D  per layer.

    fn layer_flops(&self, s_new: usize, s_kv: usize) -> f64 {
        let d = self.dim as f64;
        let sn = s_new as f64;
        let sk = s_kv as f64;
        10.0 * sn * d * d + 4.0 * sn * sk * d
    }

    fn tower_flops(&self) -> f64 {
        let d = self.dim as f64;
        let n = self.num_items as f64;
        match self.model_type {
            // RankMixer-style: mixing layer + [D→4D→4D→1] MLP.
            ModelType::LongerRankMixer => n * (2.0 * d * d + 2.0 * d * 4.0 * d + 2.0 * 16.0 * d * d / 4.0 + 8.0 * d),
            // [D→2D→1] MLP.
            _ => n * (2.0 * d * 2.0 * d + 4.0 * d),
        }
    }

    /// FLOPs of pre-inference over a `prefix_len`-token prefix.
    pub fn prefix_flops(&self, prefix_len: usize) -> f64 {
        self.layers as f64 * self.layer_flops(prefix_len, prefix_len)
    }

    /// FLOPs of ranking-on-cache: incremental + item rows over the full span.
    pub fn rank_cached_flops(&self, prefix_len: usize) -> f64 {
        let s_new = self.incr_len + self.num_items;
        let s_kv = prefix_len + s_new;
        self.layers as f64 * self.layer_flops(s_new, s_kv) + self.tower_flops()
    }

    /// FLOPs of baseline full inline inference.
    pub fn full_flops(&self, prefix_len: usize) -> f64 {
        let s_tot = prefix_len + self.incr_len + self.num_items;
        self.layers as f64 * self.layer_flops(s_tot, s_tot) + self.tower_flops()
    }

    // ----- candidate-segment accounting ------------------------------------
    //
    // Beyond-prefix reuse: the KV of a candidate-item token is position-
    // independent, so a segment cached by one request's ranking pass is
    // reusable by every other request ranking the same (item, model
    // version) — what the segment cache exploits.  Reuse skips the
    // item's K/V *projections*; its Q row, attention and the task tower
    // still run (the score is always computed fresh).

    /// Tokens per candidate-item segment under the current item
    /// tokenization (one scoring token per candidate).
    pub const SEGMENT_TOKENS: usize = 1;

    /// FLOPs skipped when a candidate item's segment KV is served from
    /// the segment cache instead of recomputed: the K and V projections
    /// of its token(s) across layers (2 projections × 2·s·D² each).
    pub fn segment_flops(&self) -> f64 {
        let d = self.dim as f64;
        self.layers as f64 * 4.0 * Self::SEGMENT_TOKENS as f64 * d * d
    }

    /// ψ footprint of one candidate-item segment in bytes (per-layer K
    /// and V over the item's token(s) — KiB, vs MiB for a user prefix).
    pub fn segment_bytes(&self) -> usize {
        self.kv_bytes_for(Self::SEGMENT_TOKENS)
    }

    /// Artifact base name, matching `configs.ModelConfig.name`.
    pub fn name(&self) -> String {
        format!(
            "t{}_L{}_D{}_H{}_S{}_I{}_N{}",
            self.model_type.index(),
            self.layers,
            self.dim,
            self.heads,
            self.prefix_len,
            self.incr_len,
            self.num_items
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_kv_footprint_is_32mb() {
        let spec = ModelSpec::paper_default();
        assert_eq!(spec.kv_bytes(), 32 * 1024 * 1024, "Table 1: ψ = 32 MB");
    }

    #[test]
    fn kv_scales_linearly_in_len_layers_dim() {
        let base = ModelSpec::paper_default();
        let mut twice_len = base;
        twice_len.prefix_len *= 2;
        assert_eq!(twice_len.kv_bytes(), base.kv_bytes() * 2);
        let mut twice_layers = base;
        twice_layers.layers *= 2;
        assert_eq!(twice_layers.kv_bytes(), base.kv_bytes() * 2);
        let mut fp16 = base;
        fp16.dtype = Dtype::F16;
        assert_eq!(fp16.kv_bytes(), base.kv_bytes() / 2);
    }

    #[test]
    fn flops_decomposition_consistent() {
        let spec = ModelSpec::paper_default();
        let s = spec.prefix_len;
        // full > prefix + cached-rank contributions must cover overlap:
        // prefix rows in full attend the same columns, so
        // full ≈ prefix-part (but over wider kv) + rank-part.
        assert!(spec.full_flops(s) > spec.prefix_flops(s));
        assert!(spec.full_flops(s) > spec.rank_cached_flops(s));
        // Removing the prefix from the critical path saves the dominant part.
        let saved = spec.full_flops(s) - spec.rank_cached_flops(s);
        assert!(saved / spec.full_flops(s) > 0.5, "prefix dominates compute");
    }

    #[test]
    fn attention_grows_superlinearly_load_linearly() {
        let spec = ModelSpec::paper_default();
        let f1 = spec.prefix_flops(2048);
        let f2 = spec.prefix_flops(4096);
        assert!(f2 / f1 > 2.5, "attention quadratic term should dominate");
        assert_eq!(spec.kv_bytes_for(4096), spec.kv_bytes_for(2048) * 2);
    }

    #[test]
    fn segment_accounting_is_a_strict_slice_of_rank_compute() {
        let spec = ModelSpec::paper_default();
        // Table 1 arithmetic at one token: 8 × 2 × 1 × 256 × 4 B = 16 KiB.
        assert_eq!(spec.segment_bytes(), 16 * 1024);
        // The savable segment share must be a strict minority of the rank
        // pass even when every candidate hits (attention + tower remain).
        let all_items = spec.segment_flops() * spec.num_items as f64;
        assert!(all_items > 0.0);
        assert!(
            all_items < 0.5 * spec.rank_cached_flops(spec.prefix_len),
            "segment share {all_items:.3e} vs rank {:.3e}",
            spec.rank_cached_flops(spec.prefix_len)
        );
        assert!(all_items < 0.5 * spec.full_flops(spec.prefix_len));
    }

    #[test]
    fn name_matches_python_convention() {
        let mut spec = ModelSpec::paper_default();
        spec.layers = 2;
        spec.dim = 64;
        spec.heads = 2;
        spec.prefix_len = 512;
        spec.incr_len = 64;
        spec.num_items = 128;
        assert_eq!(spec.name(), "t1_L2_D64_H2_S512_I64_N128");
    }
}
