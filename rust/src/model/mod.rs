//! Model variant descriptors and the analytic hardware cost model.

pub mod cost;
pub mod spec;

pub use cost::{BatchMember, HardwareProfile};
pub use spec::{Dtype, ModelSpec, ModelType};
