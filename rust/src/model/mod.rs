//! Model variant descriptors and the analytic hardware cost model.

pub mod cost;
pub mod spec;

pub use cost::HardwareProfile;
pub use spec::{Dtype, ModelSpec, ModelType};
