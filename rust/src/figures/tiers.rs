//! `relaygr figure tiers` — the tier-hierarchy standing report: every
//! eviction policy of the DRAM tier (`lru`, `lfu`, `cost`, `lifecycle`)
//! across all four workload scenarios, in both decision engines — the
//! discrete-event simulator and the serialized reference driver (the
//! same instantly-completing-host engine `tests/cross_engine.rs` checks
//! the live engine against).  Both drive the identical
//! [`RelayCoordinator`], so per-policy hit/promotion/demotion behaviour
//! must agree; the simulator rows additionally carry latency.
//!
//! The DRAM tier is deliberately small (default 2 GB) so the eviction
//! policy actually binds — with a ~500 GB tier every policy is a no-op.

use anyhow::Result;

use crate::cluster::{run_reference, SimConfig};
use crate::figures::common::{ms, pct, sim, Table};
use crate::metrics::{dram_hit_rate, relay_hit_rate, RunMetrics};
use crate::relay::baseline::Mode;
use crate::relay::hbm::HbmStats;
use crate::relay::hierarchy::HierarchyStats;
use crate::relay::tier::{DramPolicy, EvictPolicy};
use crate::util::cli::Args;
use crate::workload::{ScenarioKind, WorkloadConfig};

#[allow(clippy::too_many_arguments)]
fn table_row(
    t: &mut Table,
    scenario: &str,
    policy: EvictPolicy,
    engine: &str,
    n: u64,
    p99: Option<f64>,
    counts: &[u64; 5],
    h: &HierarchyStats,
    hbm: &HbmStats,
) {
    t.row(vec![
        scenario.to_string(),
        policy.label().to_string(),
        engine.to_string(),
        n.to_string(),
        p99.map(ms).unwrap_or_else(|| "-".into()),
        pct(relay_hit_rate(counts)),
        pct(dram_hit_rate(counts)),
        // First-consume vs rapid-re-rank HBM probes, split.
        format!("{}/{}", hbm.ready_hits, hbm.consumed_hits),
        h.reloads_started.to_string(),
        h.spills.to_string(),
        h.dram_evictions.to_string(),
    ]);
}

/// `relaygr figure tiers [--qps N] [--dram-gb N] [--quick] [--scenario s]`.
pub fn tiers(args: &Args) -> Result<()> {
    let duration_us = if args.has_flag("quick") { 4_000_000 } else { 10_000_000 };
    let qps = args.get_f64("qps", 120.0)?;
    let seed = args.get_u64("seed", 42)?;
    let dram_gb = args.get_usize("dram-gb", 2)?;
    let kinds: Vec<ScenarioKind> = match args.get("scenario") {
        Some(s) => vec![ScenarioKind::parse(s).map_err(anyhow::Error::msg)?],
        None => ScenarioKind::NAMES
            .iter()
            .map(|n| ScenarioKind::parse(n).expect("built-in scenario"))
            .collect(),
    };
    let policies =
        [EvictPolicy::Lru, EvictPolicy::Lfu, EvictPolicy::CostAware, EvictPolicy::Lifecycle];
    let mut t = Table::new(
        "tiers",
        "DRAM eviction policies × scenarios (simulator + serialized reference)",
        &[
            "scenario", "policy", "engine", "n", "p99 ms", "relay hit", "dram hit",
            "hbm 1st/re-rank", "promoted", "demoted", "evicted",
        ],
    );
    for kind in &kinds {
        let wl = WorkloadConfig {
            qps,
            duration_us,
            num_users: 30_000,
            fixed_long_len: Some(3072),
            max_prefix: 3072,
            refresh_prob: 0.6,
            scenario: *kind,
            seed,
            ..Default::default()
        };
        for policy in policies {
            let mut cfg =
                SimConfig::standard(Mode::RelayGr { dram: DramPolicy::Capacity(dram_gb << 30) });
            cfg.dram_policy = policy;
            let m: RunMetrics = sim("tiers", cfg.clone(), &wl)?;
            table_row(
                &mut t,
                kind.label(),
                policy,
                "sim",
                m.completed,
                Some(m.p99_e2e()),
                &m.outcome_counts,
                &m.hierarchy,
                &m.hbm,
            );
            let r = run_reference(&cfg, &wl)?;
            let n = r.outcome_counts.iter().sum();
            table_row(
                &mut t,
                kind.label(),
                policy,
                "serial",
                n,
                None,
                &r.outcome_counts,
                &r.hierarchy,
                &r.hbm,
            );
        }
    }
    t.emit(args)
}
