//! `relaygr figure tiers` — the tier-hierarchy standing report: every
//! eviction policy of the DRAM tier (`lru`, `lfu`, `cost`, `lifecycle`)
//! across all four workload scenarios, in both decision engines — the
//! discrete-event simulator and the serialized reference driver (the
//! same instantly-completing-host engine `tests/cross_engine.rs` checks
//! the live engine against).  Both drive the identical
//! [`RelayCoordinator`], so per-policy hit/promotion/demotion behaviour
//! must agree; the simulator rows additionally carry latency.
//!
//! The DRAM tier is deliberately small (default 2 GB) so the eviction
//! policy actually binds — with a ~500 GB tier every policy is a no-op.
//!
//! Every (scenario, policy, engine) cell is independent — its own seeded
//! simulator or serialized coordinator — so the grid runs on the
//! deterministic parallel executor (`--jobs N`); rows are merged in
//! declaration order and are byte-identical at any job count.
//!
//! [`RelayCoordinator`]: crate::relay::RelayCoordinator

use anyhow::Result;

use crate::cluster::{run_reference, SimConfig};
use crate::figures::common::{ms, pct, sim, Table};
use crate::metrics::{dram_hit_rate, relay_hit_rate};
use crate::relay::baseline::Mode;
use crate::relay::hbm::HbmStats;
use crate::relay::hierarchy::HierarchyStats;
use crate::relay::tier::{DramPolicy, EvictPolicy};
use crate::util::cli::Args;
use crate::util::parallel;
use crate::workload::{ScenarioKind, WorkloadConfig};

#[derive(Clone, Copy)]
enum Engine {
    Sim,
    Serial,
}

#[allow(clippy::too_many_arguments)]
fn cell_row(
    scenario: &str,
    policy: EvictPolicy,
    engine: Engine,
    n: u64,
    p99: Option<f64>,
    counts: &[u64; 6],
    h: &HierarchyStats,
    hbm: &HbmStats,
) -> Vec<String> {
    vec![
        scenario.to_string(),
        policy.label().to_string(),
        match engine {
            Engine::Sim => "sim".to_string(),
            Engine::Serial => "serial".to_string(),
        },
        n.to_string(),
        p99.map(ms).unwrap_or_else(|| "-".into()),
        pct(relay_hit_rate(counts)),
        pct(dram_hit_rate(counts)),
        // First-consume vs rapid-re-rank HBM probes, split.
        format!("{}/{}", hbm.ready_hits, hbm.consumed_hits),
        h.reloads_started.to_string(),
        h.spills.to_string(),
        h.dram_evictions.to_string(),
    ]
}

/// `relaygr figure tiers [--qps N] [--dram-gb N] [--quick] [--scenario s]
/// [--jobs N]`.
pub fn tiers(args: &Args) -> Result<()> {
    let duration_us = if args.has_flag("quick") { 4_000_000 } else { 10_000_000 };
    let qps = args.get_f64("qps", 120.0)?;
    let seed = args.get_u64("seed", 42)?;
    let dram_gb = args.get_usize("dram-gb", 2)?;
    let jobs = parallel::jobs_from_args(args)?;
    let kinds: Vec<ScenarioKind> = match args.get("scenario") {
        Some(s) => vec![ScenarioKind::parse(s).map_err(anyhow::Error::msg)?],
        None => ScenarioKind::NAMES
            .iter()
            .map(|n| ScenarioKind::parse(n).expect("built-in scenario"))
            .collect(),
    };
    let policies =
        [EvictPolicy::Lru, EvictPolicy::Lfu, EvictPolicy::CostAware, EvictPolicy::Lifecycle];
    let mut cells: Vec<(ScenarioKind, EvictPolicy, Engine)> = Vec::new();
    for kind in &kinds {
        for policy in policies {
            cells.push((*kind, policy, Engine::Sim));
            cells.push((*kind, policy, Engine::Serial));
        }
    }
    let rows = parallel::map_indexed(jobs, cells.len(), |i| -> Result<Vec<String>> {
        let (kind, policy, engine) = cells[i];
        let wl = WorkloadConfig {
            qps,
            duration_us,
            num_users: 30_000,
            fixed_long_len: Some(3072),
            max_prefix: 3072,
            refresh_prob: 0.6,
            scenario: kind,
            seed,
            ..Default::default()
        };
        let mut cfg =
            SimConfig::standard(Mode::RelayGr { dram: DramPolicy::Capacity(dram_gb << 30) });
        cfg.dram_policy = policy;
        Ok(match engine {
            Engine::Sim => {
                let m = sim("tiers", cfg, &wl)?;
                cell_row(
                    kind.label(),
                    policy,
                    engine,
                    m.completed,
                    Some(m.p99_e2e()),
                    &m.outcome_counts,
                    &m.hierarchy,
                    &m.hbm,
                )
            }
            Engine::Serial => {
                let r = run_reference(&cfg, &wl)?;
                let n = r.outcome_counts.iter().sum();
                cell_row(
                    kind.label(),
                    policy,
                    engine,
                    n,
                    None,
                    &r.outcome_counts,
                    &r.hierarchy,
                    &r.hbm,
                )
            }
        })
    });
    let mut t = Table::new(
        "tiers",
        "DRAM eviction policies × scenarios (simulator + serialized reference)",
        &[
            "scenario", "policy", "engine", "n", "p99 ms", "relay hit", "dram hit",
            "hbm 1st/re-rank", "promoted", "demoted", "evicted",
        ],
    );
    for row in rows {
        t.row(row?);
    }
    t.emit(args)
}
