//! Figure runners: one per table/figure in the paper's evaluation
//! (§4, Figs. 1–15 + Table 1).  `relaygr figure <id>` regenerates the
//! rows/series the paper reports; `relaygr figure all` runs everything.
//! Results are printed and persisted under `results/`.

pub mod admission;
pub mod batching;
pub mod breakdown;
pub mod cells;
pub mod common;
pub mod fig11;
pub mod fig12;
pub mod fig13;
pub mod fig14;
pub mod fig15;
pub mod faults;
pub mod motivation;
pub mod scenarios;
pub mod segments;
pub mod tiers;

use anyhow::{bail, Result};

use crate::util::cli::Args;

/// All figure ids: the paper's figures in paper order, then the repo's
/// standing reports (scenario sweep, tier-policy sweep, segment-reuse
/// sweep).
pub const ALL: &[&str] = &[
    "fig1", "fig3", "fig11a", "fig11b", "fig11c", "fig11d", "fig12", "fig13a", "fig13b",
    "fig13c", "fig13d", "fig14a", "fig14b", "fig14c", "fig14d", "fig15a", "fig15b", "table1",
    "scenarios", "tiers", "segments", "admission", "batching", "breakdown", "cells", "faults",
];

pub fn run_one(id: &str, args: &Args) -> Result<()> {
    match id {
        "fig1" => motivation::fig1(args),
        "fig3" => motivation::fig3(args),
        "fig11a" => fig11::fig11a(args),
        "fig11b" => fig11::fig11b(args),
        "fig11c" => fig11::fig11c(args),
        "fig11d" => fig11::fig11d(args),
        "fig12" => fig12::fig12(args),
        "fig13a" => fig13::fig13a(args),
        "fig13b" => fig13::fig13b(args),
        "fig13c" => fig13::fig13c(args),
        "fig13d" => fig13::fig13d(args),
        "fig14a" => fig14::fig14a(args),
        "fig14b" => fig14::fig14b(args),
        "fig14c" => fig14::fig14c(args),
        "fig14d" => fig14::fig14d(args),
        "fig15a" => fig15::fig15a(args),
        "fig15b" => fig15::fig15b(args),
        "table1" => fig15::table1(args),
        "scenarios" => scenarios::scenarios(args),
        "tiers" => tiers::tiers(args),
        "segments" => segments::segments(args),
        "admission" => admission::admission(args),
        "batching" => batching::batching(args),
        "breakdown" => breakdown::breakdown(args),
        "cells" => cells::cells(args),
        "faults" => faults::faults(args),
        other => bail!("unknown figure '{other}' (available: {} all)", ALL.join(" ")),
    }
}

/// `relaygr figure <id>|all [--quick] [--results dir] [...]`.
pub fn run(args: &Args) -> Result<()> {
    let Some(id) = args.positionals.get(1) else {
        bail!("usage: relaygr figure <{}|all>", ALL.join("|"));
    };
    if id == "all" {
        for id in ALL {
            let t0 = std::time::Instant::now();
            run_one(id, args)?;
            log::info!("{id} done in {:.1?}", t0.elapsed());
        }
        Ok(())
    } else {
        run_one(id, args)
    }
}
