//! Fig. 13 — RelayGR for scaled sequences (Q2): graceful throughput
//! degradation, latency composition, cache loading under concurrency,
//! and the retrieval-slack effect.
//!
//! All four panels sweep independent seeded runs, so their cells run on
//! the deterministic `--jobs` executor and merge in declaration order —
//! output is byte-identical at any job count.

use anyhow::Result;

use crate::cluster::SimConfig;
use crate::figures::common::{self, Table};
use crate::metrics::slo;
use crate::relay::baseline::Mode;
use crate::relay::tier::DramPolicy;
use crate::util::cli::Args;
use crate::util::parallel;

/// Fig. 13a: SLO-compliant QPS vs sequence length per variant (paper:
/// baseline collapses beyond ~6K; RelayGR keeps tens of QPS; high DRAM
/// hit rates keep hundreds beyond 8K).
pub fn fig13a(args: &Args) -> Result<()> {
    let (_, dur) = common::durations(args);
    let mut t = Table::new(
        "fig13a",
        "SLO-compliant QPS vs sequence length (pipeline P99 ≤ 135 ms)",
        &["seq_len", "baseline", "relaygr", "relaygr+dram2g", "relaygr+dram500g"],
    );
    let lens = common::seq_lens();
    let modes = common::standard_modes();
    let jobs = parallel::jobs_from_args(args)?;
    // Flat (len, mode) cells: each is one SLO search; rows reassemble
    // from `modes.len()`-sized chunks after the ordered merge.
    let cells = parallel::map_indexed(jobs, lens.len() * modes.len(), |i| -> Result<String> {
        let (len, mode) = (lens[i / modes.len()], modes[i % modes.len()]);
        let cfg = SimConfig::standard(mode);
        // High refresh reuse so the DRAM variants reach the paper's
        // elevated hit-rate regimes at scale.
        let search = slo::max_qps(
            |q| {
                let mut wl = common::fixed_len_workload(len, q, dur, 50);
                wl.refresh_prob = 0.8;
                common::sim("fig13a", cfg.clone(), &wl).expect("sim")
            },
            2.0,
            3000.0,
            cfg.pipeline.required_success,
            0.05,
        );
        Ok(common::qps(search.value))
    });
    let cells = cells.into_iter().collect::<Result<Vec<_>>>()?;
    for (li, len) in lens.iter().enumerate() {
        let mut row = vec![len.to_string()];
        row.extend(cells[li * modes.len()..(li + 1) * modes.len()].iter().cloned());
        t.row(row);
    }
    t.emit(args)
}

/// Fig. 13b: latency composition as sequences scale — pre < baseline full
/// inference; load and rank stay within tens of ms up to ~15K.
pub fn fig13b(args: &Args) -> Result<()> {
    let (dur, _) = common::durations(args);
    let qps = args.get_f64("qps", 60.0)?;
    let mut t = Table::new(
        "fig13b",
        "component latency vs sequence length (P99 ms)",
        &["seq_len", "baseline_full", "pre", "load", "rank_on_cache"],
    );
    let lens = common::seq_lens();
    let jobs = parallel::jobs_from_args(args)?;
    let rows = parallel::map_indexed(jobs, lens.len(), |i| -> Result<Vec<String>> {
        let len = lens[i];
        let b_cfg = SimConfig::standard(Mode::Baseline);
        let b = common::sim("fig13b", b_cfg, &common::fixed_len_workload(len, qps, dur, 51))?;
        let r_cfg =
            SimConfig::standard(Mode::RelayGr { dram: DramPolicy::Capacity(500 << 30) });
        let m = common::sim("fig13b", r_cfg, &common::fixed_len_workload(len, qps, dur, 51))?;
        Ok(vec![
            len.to_string(),
            common::ms(b.rank_exec_long.p99()),
            common::ms(m.pre.p99()),
            common::ms(m.load.p99()),
            common::ms(m.rank_exec_long.p99()),
        ])
    });
    for row in rows {
        t.row(row?);
    }
    t.emit(args)
}

/// Fig. 13c: DRAM→HBM load latency vs length × concurrency (approx.
/// linear in cache size, far below full inference even under load).
pub fn fig13c(args: &Args) -> Result<()> {
    let (dur, _) = common::durations(args);
    let mode = Mode::RelayGr { dram: DramPolicy::Capacity(500 << 30) };
    let mut t = Table::new(
        "fig13c",
        "DRAM→HBM load P99 (ms) vs sequence length × offered QPS",
        &["seq_len", "qps50", "qps150", "qps300", "analytic_ms"],
    );
    let lens = [2048usize, 4096, 8192, 15360];
    let qpss = [50.0, 150.0, 300.0];
    let jobs = parallel::jobs_from_args(args)?;
    let cells = parallel::map_indexed(jobs, lens.len() * qpss.len(), |i| -> Result<String> {
        let (len, qps) = (lens[i / qpss.len()], qpss[i % qpss.len()]);
        let cfg = SimConfig::standard(mode);
        let mut wl = common::fixed_len_workload(len, qps, dur, 52);
        wl.refresh_prob = 0.8; // plenty of DRAM reuse to measure loads
        let m = common::sim("fig13c", cfg, &wl)?;
        Ok(if m.load.count() > 0 { common::ms(m.load.p99()) } else { "-".into() })
    });
    let cells = cells.into_iter().collect::<Result<Vec<_>>>()?;
    for (li, len) in lens.iter().enumerate() {
        let mut row = vec![len.to_string()];
        row.extend(cells[li * qpss.len()..(li + 1) * qpss.len()].iter().cloned());
        // The analytic bound is pure arithmetic — computed post-merge.
        let cfg = SimConfig::standard(mode);
        row.push(common::ms(cfg.hw.load_us(cfg.spec.kv_bytes_for(*len))));
        t.row(row);
    }
    t.emit(args)
}

/// Fig. 13d: retrieval slack → supported concurrency.  A larger retrieval
/// budget extends the pipeline SLO one-for-one, so the baseline (whose
/// cost all sits in ranking) is unaffected, while RelayGR converts the
/// extra slack into completed pre-inference (paper: ~5× the baseline's
/// concurrency at 100 ms retrieval P99).
pub fn fig13d(args: &Args) -> Result<()> {
    let (_, dur) = common::durations(args);
    let len = args.get_usize("len", 4096)?;
    let mut t = Table::new(
        "fig13d",
        "max supported load vs retrieval-stage P99 budget",
        &["retrieval_p99_ms", "variant", "max_qps", "concurrency"],
    );
    let mut cells: Vec<(f64, Mode)> = Vec::new();
    for retr_ms in [25.0, 50.0, 75.0, 100.0] {
        for mode in [Mode::Baseline, Mode::RelayGr { dram: DramPolicy::Disabled }] {
            cells.push((retr_ms, mode));
        }
    }
    let jobs = parallel::jobs_from_args(args)?;
    let rows = parallel::map_indexed(jobs, cells.len(), |i| -> Result<Vec<String>> {
        let (retr_ms, mode) = cells[i];
        let mut cfg = SimConfig::standard(mode);
        cfg.pipeline.retrieval_mean_us = retr_ms * 1e3 * 0.6;
        cfg.pipeline.retrieval_p99_us = retr_ms * 1e3;
        // Slack beyond the default 40 ms retrieval budget extends the
        // pipeline SLO (the paper varies the retrieval *budget*).
        cfg.pipeline.pipeline_slo_us = 135_000.0 + (retr_ms * 1e3 - 40_000.0).max(0.0);
        // The lifecycle window tracks the longer pipeline tail.
        cfg.pipeline.t_life_us =
            (2.0 * (retr_ms * 1e3 + cfg.pipeline.preproc_p99_us + cfg.pipeline.rank_budget_us))
                as u64;
        let required = cfg.pipeline.required_success;
        let mut conc = 0.0;
        let search = slo::max_qps(
            |q| {
                let wl = common::fixed_len_workload(len, q, dur, 53);
                let m = common::sim("fig13d", cfg.clone(), &wl).expect("sim");
                conc = m.goodput_qps() * m.e2e.mean() / 1e6;
                m
            },
            2.0,
            3000.0,
            required,
            0.05,
        );
        Ok(vec![
            format!("{retr_ms:.0}"),
            mode.label(),
            common::qps(search.value),
            format!("{conc:.1}"),
        ])
    });
    for row in rows {
        t.row(row?);
    }
    t.emit(args)
}
