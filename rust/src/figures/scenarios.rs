//! Scenario sweep: the four named workload scenarios (`steady`,
//! `diurnal`, `burst`, `coldstart`) through the simulator under baseline
//! vs RelayGR+DRAM, reporting per-scenario latency/SLO/cache behaviour.
//! Not a paper figure — the scenario engine's standing report
//! (`relaygr figure scenarios`).
//!
//! Every (scenario, mode) cell is an independent seeded simulation, so
//! the grid runs on the deterministic parallel executor (`--jobs N`);
//! rows come back in declaration order and are byte-identical at any job
//! count ([`grid_rows`] is pinned by `tests/cross_engine.rs` and timed
//! by `bench_simloop`).

use anyhow::Result;

use crate::cluster::SimConfig;
use crate::figures::common::{ms, pct, sim, Table};
use crate::relay::baseline::Mode;
use crate::relay::tier::DramPolicy;
use crate::util::cli::Args;
use crate::util::parallel;
use crate::workload::{ScenarioKind, WorkloadConfig};

/// Compute the grid's rows — (scenario × mode) cells on `--jobs` worker
/// threads, merged in declaration order.  Shared with `bench_simloop`
/// (wall-clock trajectory) and the cross-engine determinism test.
pub fn grid_rows(args: &Args) -> Result<Vec<Vec<String>>> {
    let duration_us = if args.has_flag("quick") { 6_000_000 } else { 15_000_000 };
    let qps = args.get_f64("qps", 150.0)?;
    let seed = args.get_u64("seed", 42)?;
    let jobs = parallel::jobs_from_args(args)?;
    let kinds: Vec<ScenarioKind> = match args.get("scenario") {
        Some(s) => vec![ScenarioKind::parse(s).map_err(anyhow::Error::msg)?],
        None => ScenarioKind::NAMES
            .iter()
            .map(|n| ScenarioKind::parse(n).expect("built-in scenario"))
            .collect(),
    };
    let modes = [Mode::Baseline, Mode::RelayGr { dram: DramPolicy::Capacity(500 << 30) }];
    let mut cells: Vec<(ScenarioKind, Mode)> = Vec::new();
    for kind in &kinds {
        for mode in modes.iter().copied() {
            cells.push((*kind, mode));
        }
    }
    let rows = parallel::map_indexed(jobs, cells.len(), |i| -> Result<Vec<String>> {
        let (kind, mode) = cells[i];
        let wl = WorkloadConfig {
            qps,
            duration_us,
            num_users: 30_000,
            fixed_long_len: Some(3072),
            max_prefix: 3072,
            refresh_prob: 0.5,
            scenario: kind,
            seed,
            ..Default::default()
        };
        let m = sim("scenarios", SimConfig::standard(mode), &wl)?;
        let shed = m.trigger.rate_limited + m.trigger.footprint_limited;
        Ok(vec![
            kind.label().to_string(),
            mode.label(),
            m.completed.to_string(),
            format!("{:.0}", m.goodput_qps()),
            ms(m.p99_e2e()),
            format!("{:.4}", m.success_rate()),
            pct(m.relay_hit_rate()),
            pct(m.dram_hit_rate()),
            shed.to_string(),
        ])
    });
    rows.into_iter().collect()
}

/// `relaygr figure scenarios [--qps N] [--quick] [--scenario name]
/// [--jobs N]`.
pub fn scenarios(args: &Args) -> Result<()> {
    let mut t = Table::new(
        "scenarios",
        "workload scenarios × serving modes (simulator)",
        &[
            "scenario", "mode", "n", "goodput", "p99 ms", "success", "relay hit", "dram hit",
            "shed",
        ],
    );
    for row in grid_rows(args)? {
        t.row(row);
    }
    t.emit(args)
}
