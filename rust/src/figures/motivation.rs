//! Fig. 1 and Fig. 3 — the motivation experiments: ranking-stage P99
//! restricts sequence length and throughput (baseline only).
//!
//! Both sweeps run their cells on the deterministic `--jobs` executor
//! with declaration-order merge — output is byte-identical at any job
//! count.

use anyhow::Result;

use crate::cluster::SimConfig;
use crate::figures::common::{self, Table};
use crate::metrics::slo;
use crate::relay::baseline::Mode;
use crate::util::cli::Args;
use crate::util::parallel;

/// Fig. 1a/1b: with full inference inline, (a) P99 blows past the SLO as
/// sequence length grows at fixed load, and (b) the SLO-compliant QPS
/// collapses with length.
pub fn fig1(args: &Args) -> Result<()> {
    let (dur, search_dur) = common::durations(args);
    let qps_fixed = args.get_f64("qps", 80.0)?;
    let mut t = Table::new(
        "fig1",
        "ranking-stage P99 restricts sequence length and throughput (baseline)",
        &["seq_len", "rank_p99_ms", "e2e_p99_ms", "success", "slo_ok", "max_qps"],
    );
    let lens = [1024usize, 2048, 3072, 4096, 6144, 8192];
    let jobs = parallel::jobs_from_args(args)?;
    let rows = parallel::map_indexed(jobs, lens.len(), |i| -> Result<Vec<String>> {
        let len = lens[i];
        let cfg = SimConfig::standard(Mode::Baseline);
        let wl = common::fixed_len_workload(len, qps_fixed, dur, 42);
        let m = common::sim("fig1", cfg.clone(), &wl)?;
        let search = slo::max_qps(
            |q| {
                let wl = common::fixed_len_workload(len, q, search_dur, 43);
                common::sim("fig1", cfg.clone(), &wl).expect("sim")
            },
            5.0,
            2000.0,
            cfg.pipeline.required_success,
            0.05,
        );
        Ok(vec![
            len.to_string(),
            common::ms(m.rank_stage_long.p99()),
            common::ms(m.e2e_long.p99()),
            format!("{:.4}", m.success_rate()),
            m.slo_compliant(cfg.pipeline.required_success).to_string(),
            common::qps(search.value),
        ])
    });
    for row in rows {
        t.row(row?);
    }
    t.emit(args)
}

/// Fig. 3: the budget forces capping either length or dimension — rank
/// latency vs length for several embedding dims, against the 50 ms line.
pub fn fig3(args: &Args) -> Result<()> {
    let (dur, _) = common::durations(args);
    let mut t = Table::new(
        "fig3",
        "limited sequences: rank-stage P99 (ms) vs length × dim, 50 ms budget",
        &["seq_len", "dim128", "dim256", "dim512", "dim1024"],
    );
    let lens = [512usize, 1024, 2048, 4096];
    let dims = [128usize, 256, 512, 1024];
    let jobs = parallel::jobs_from_args(args)?;
    let cells = parallel::map_indexed(jobs, lens.len() * dims.len(), |i| -> Result<String> {
        let (len, dim) = (lens[i / dims.len()], dims[i % dims.len()]);
        let mut cfg = SimConfig::standard(Mode::Baseline);
        cfg.spec.dim = dim;
        cfg.spec.heads = (dim / 64).max(1);
        let wl = common::fixed_len_workload(len, 30.0, dur, 44);
        let m = common::sim("fig3", cfg, &wl)?;
        Ok(common::ms(m.rank_stage_long.p99()))
    });
    let cells = cells.into_iter().collect::<Result<Vec<_>>>()?;
    for (li, len) in lens.iter().enumerate() {
        let mut row = vec![len.to_string()];
        row.extend(cells[li * dims.len()..(li + 1) * dims.len()].iter().cloned());
        t.row(row);
    }
    t.emit(args)
}
