//! `relaygr figure cells` — the multi-cell cluster standing report: the
//! two-level router (cell picker above the in-cell affinity router)
//! swept across picker policies and cluster-churn scenarios, in both
//! decision engines.
//!
//! Three claims are checked *inside* the figure rather than published on
//! trust:
//!
//! * **Engine identity** — cell routing, scripted failures, drains and
//!   elastic resizes are decisions, so they replay decision-for-decision
//!   in the serialized reference driver.  Every (picker, scenario) cell
//!   runs the simulator *and* the reference and asserts per-request
//!   outcomes are identical.
//! * **Locality pays** — on the cache-locality workload (a small user
//!   population re-arriving against warm ψ caches) the affinity picker
//!   must deliver strictly more HBM hits than spread: spread scatters a
//!   user's requests across cells, so its repeat arrivals land where no
//!   ψ was produced.
//! * **Sharding is visible** — at `--cells 4` the report must show a
//!   nonzero cross-cell ψ-miss count somewhere in the grid (the spread
//!   rows guarantee it); a zero column would mean the cell layer is not
//!   actually routing across cells.
//!
//! The churn rows additionally assert the scripted events happened:
//! failure rows must record injected failures (and their reload-storm
//! wipes), drain/elastic rows must still complete every request.

use anyhow::{ensure, Result};

use crate::cluster::SimConfig;
use crate::config::apply_candidate_flags;
use crate::figures::common::{ms, sim, Table};
use crate::metrics::RunMetrics;
use crate::relay::baseline::Mode;
use crate::relay::cell::{CellPickerKind, CellScenario};
use crate::relay::tier::DramPolicy;
use crate::util::cli::Args;
use crate::util::json::Json;
use crate::util::parallel;
use crate::workload::{ScenarioKind, WorkloadConfig};

const PICKERS: &[CellPickerKind] = &[CellPickerKind::Affinity, CellPickerKind::Spread];

/// `relaygr figure cells [--cells N] [--qps N] [--quick] [--jobs N]`.
///
/// Grid: both pickers × all churn scenarios at `--cells` (default 4),
/// plus a single-cell control row (the PR 8-identical configuration).
/// Each cell is self-contained, so the grid parallelizes on the
/// deterministic executor.
pub fn cells(args: &Args) -> Result<()> {
    let dur = if args.has_flag("quick") { 4_000_000u64 } else { 8_000_000 };
    let probe_qps = args.get_f64("qps", 100.0)?;
    let seed = args.get_u64("seed", 42)?;
    let n_cells = args.get_usize("cells", 4)?;
    ensure!(n_cells >= 2, "--cells must be >= 2 (the control row covers cells=1)");
    let jobs = parallel::jobs_from_args(args)?;

    // (cells, picker, scenario); the final entry is the 1-cell control.
    let mut grid: Vec<(usize, CellPickerKind, CellScenario)> = Vec::new();
    for &p in PICKERS {
        for name in CellScenario::NAMES {
            grid.push((n_cells, p, CellScenario::parse(name)?));
        }
    }
    grid.push((1, CellPickerKind::Affinity, CellScenario::None));

    let results = parallel::map_indexed(jobs, grid.len(), |i| -> Result<(Vec<String>, RunMetrics)> {
        let (cells, picker, scenario) = grid[i];
        // Cache-locality workload: a small population re-arrives against
        // warm ψ caches, so the picker's placement decides the hit rate.
        let mut wl = WorkloadConfig {
            qps: probe_qps,
            duration_us: dur,
            num_users: 200,
            fixed_long_len: Some(3072),
            max_prefix: 3072,
            refresh_prob: 0.0,
            scenario: ScenarioKind::Steady,
            seed,
            ..Default::default()
        };
        apply_candidate_flags(args, &mut wl)?;
        let mut cfg = SimConfig::standard(Mode::RelayGr { dram: DramPolicy::Disabled });
        // Timing-insensitive shape (no DRAM, lifecycle beyond the trace,
        // no refresh): sim-vs-reference divergence would be a genuine
        // policy difference, not clock skew.
        cfg.pipeline.t_life_us = 2 * dur;
        cfg.router.servers = 8; // divisible by 1, 2, 4, 8 cells
        cfg.cells = cells;
        cfg.cell_picker = picker;
        cfg.cell_scenario = scenario;
        cfg.log_outcomes = true;
        let m: RunMetrics = sim("cells", cfg.clone(), &wl)?;
        let serial = crate::cluster::run_reference(&cfg, &wl)?;
        let mut sim_log = m.outcome_log();
        sim_log.sort_by_key(|&(id, _)| id);
        ensure!(
            sim_log == serial.outcomes,
            "cells: engines diverged on per-request outcomes \
             (cells {cells}, picker {}, scenario {})",
            picker.label(),
            scenario.label()
        );
        let cross: u64 = m.cells.iter().map(|c| c.cross_routes).sum();
        let miss: u64 = m.cells.iter().map(|c| c.cross_psi_miss).sum();
        let fails: u64 = m.cells.iter().map(|c| c.failures).sum();
        let wipes: u64 = m.cells.iter().map(|c| c.storm_invalidations).sum();
        if scenario == CellScenario::Failure {
            ensure!(fails > 0, "failure scenario injected no failures");
        }
        let row = vec![
            cells.to_string(),
            picker.label().to_string(),
            scenario.label().to_string(),
            m.completed.to_string(),
            m.outcome_counts[1].to_string(),
            cross.to_string(),
            miss.to_string(),
            fails.to_string(),
            wipes.to_string(),
            ms(m.e2e.p99()),
            "ok".into(),
        ];
        Ok((row, m))
    });

    let mut t = Table::new(
        "cells",
        "multi-cell cluster: picker policy × churn scenario (simulator + serialized reference)",
        &[
            "cells", "picker", "cell_scenario", "n", "hbm_hits", "cross_routes",
            "cross_psi_miss", "failures", "storm_wipes", "p99 e2e ms", "outcomes",
        ],
    );
    t.meta.set("cells", n_cells.into()).set("probe_qps", probe_qps.into()).set(
        "scenarios",
        Json::Arr(CellScenario::NAMES.iter().map(|&s| s.into()).collect()),
    );
    let mut runs: Vec<RunMetrics> = Vec::new();
    for res in results {
        let (row, m) = res?;
        t.row(row);
        runs.push(m);
    }
    // Locality pays: affinity strictly beats spread on HBM hits in the
    // steady (no-churn) cells=N pair.
    let hbm_at = |p: CellPickerKind| {
        grid.iter()
            .zip(&runs)
            .find(|((c, pk, sc), _)| *c == n_cells && *pk == p && *sc == CellScenario::None)
            .map(|(_, m)| m.outcome_counts[1])
            .expect("grid row present")
    };
    let (aff, spr) = (hbm_at(CellPickerKind::Affinity), hbm_at(CellPickerKind::Spread));
    ensure!(
        aff > spr,
        "cells: affinity does not beat spread on cache locality \
         ({aff} vs {spr} HBM hits at cells={n_cells})"
    );
    // Sharding is visible: somewhere in the multi-cell grid, a long
    // request completed off its ψ home and paid the cross-cell miss.
    let total_miss: u64 = runs
        .iter()
        .flat_map(|m| m.cells.iter())
        .map(|c| c.cross_psi_miss)
        .sum();
    ensure!(total_miss > 0, "cells: no cross-cell psi misses anywhere at cells={n_cells}");
    t.emit(args)
}
