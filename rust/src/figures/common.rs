//! Shared infrastructure for the figure runners: table rendering, result
//! persistence (`results/<figure>.json`), standard sweeps and the
//! mode/variant sets the paper compares.

use anyhow::{Context, Result};

use crate::cluster::{run_sim, SimConfig};
use crate::metrics::RunMetrics;
use crate::relay::baseline::Mode;
use crate::relay::tier::DramPolicy;
use crate::util::cli::Args;
use crate::util::json::Json;
use crate::workload::WorkloadConfig;

/// The sequence-length sweep used across Figs. 11/13 (paper: 1K → ~15K).
pub fn seq_lens() -> Vec<usize> {
    vec![1024, 2048, 3072, 4096, 6144, 8192, 12288, 15360]
}

/// The four variants of Fig. 11/13: baseline, plain RelayGR, and two
/// DRAM-budget variants (the paper's "+x%" rows; x is *measured*).
pub fn standard_modes() -> Vec<Mode> {
    vec![
        Mode::Baseline,
        Mode::RelayGr { dram: DramPolicy::Disabled },
        Mode::RelayGr { dram: DramPolicy::Capacity(2 << 30) },
        Mode::RelayGr { dram: DramPolicy::Capacity(500 << 30) },
    ]
}

/// Run durations: full by default, short with `--quick` (used by tests).
pub fn durations(args: &Args) -> (u64, u64) {
    if args.has_flag("quick") {
        (6_000_000, 4_000_000) // (latency runs, search runs)
    } else {
        (20_000_000, 10_000_000)
    }
}

/// Workload whose long users all have exactly `len` tokens.
pub fn fixed_len_workload(len: usize, qps: f64, duration_us: u64, seed: u64) -> WorkloadConfig {
    WorkloadConfig {
        qps,
        duration_us,
        num_users: 50_000,
        fixed_long_len: Some(len),
        max_prefix: len.max(2048),
        seed,
        ..Default::default()
    }
}

/// Same, with an explicit special-service threshold (Fig. 14 width/depth
/// sweeps lower the threshold so the 2K-token long class is
/// relay-eligible — "length larger than a configured threshold", §4.1).
pub fn fixed_len_workload_thresh(
    len: usize,
    threshold: usize,
    qps: f64,
    duration_us: u64,
    seed: u64,
) -> WorkloadConfig {
    let mut wl = fixed_len_workload(len, qps, duration_us, seed);
    wl.long_threshold = threshold;
    wl
}

/// Run one simulation, with config errors contextualised by figure name.
pub fn sim(figure: &str, cfg: SimConfig, wl: &WorkloadConfig) -> Result<RunMetrics> {
    run_sim(cfg, wl).with_context(|| format!("{figure}: simulation failed"))
}

/// A rendered figure: header + rows, printed and persisted as JSON.
pub struct Table {
    pub name: String,
    pub title: String,
    pub columns: Vec<String>,
    pub rows: Vec<Vec<String>>,
    pub meta: Json,
}

impl Table {
    pub fn new(name: &str, title: &str, columns: &[&str]) -> Table {
        Table {
            name: name.to_string(),
            title: title.to_string(),
            columns: columns.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            meta: Json::obj(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.columns.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    /// Print aligned and write `results/<name>.json`.
    pub fn emit(&self, args: &Args) -> Result<()> {
        let mut widths: Vec<usize> = self.columns.iter().map(|c| c.len()).collect();
        for row in &self.rows {
            for (w, cell) in widths.iter_mut().zip(row) {
                *w = (*w).max(cell.len());
            }
        }
        println!("\n=== {} — {} ===", self.name, self.title);
        let fmt_row = |cells: &[String]| {
            cells
                .iter()
                .zip(&widths)
                .map(|(c, w)| format!("{c:>w$}"))
                .collect::<Vec<_>>()
                .join("  ")
        };
        println!("{}", fmt_row(&self.columns));
        println!("{}", "-".repeat(widths.iter().sum::<usize>() + 2 * widths.len()));
        for row in &self.rows {
            println!("{}", fmt_row(row));
        }
        let dir = args.get_or("results", "results");
        std::fs::create_dir_all(dir).with_context(|| format!("creating {dir}"))?;
        let mut j = Json::obj();
        j.set("figure", self.name.as_str().into())
            .set("title", self.title.as_str().into())
            .set("columns", Json::Arr(self.columns.iter().map(|c| c.as_str().into()).collect()))
            .set(
                "rows",
                Json::Arr(
                    self.rows
                        .iter()
                        .map(|r| Json::Arr(r.iter().map(|c| c.as_str().into()).collect()))
                        .collect(),
                ),
            )
            .set("meta", self.meta.clone());
        let path = format!("{dir}/{}.json", self.name);
        std::fs::write(&path, j.to_string_pretty()).with_context(|| format!("writing {path}"))?;
        Ok(())
    }
}

/// ms with 1 decimal.
pub fn ms(us: f64) -> String {
    format!("{:.1}", us / 1e3)
}

pub fn qps(v: f64) -> String {
    format!("{v:.0}")
}

pub fn pct(v: f64) -> String {
    format!("{:.0}%", v * 100.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_emit_writes_json() {
        let dir = std::env::temp_dir().join("relaygr_fig_test");
        std::fs::create_dir_all(&dir).unwrap();
        let args = Args::parse(
            ["p", "figure", "--results", dir.to_str().unwrap()].iter().map(|s| s.to_string()),
        )
        .unwrap();
        let mut t = Table::new("testfig", "demo", &["a", "b"]);
        t.row(vec!["1".into(), "2".into()]);
        t.emit(&args).unwrap();
        let text = std::fs::read_to_string(dir.join("testfig.json")).unwrap();
        let j = Json::parse(&text).unwrap();
        assert_eq!(j.req_str("figure").unwrap(), "testfig");
        assert_eq!(j.req_array("rows").unwrap().len(), 1);
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn row_arity_checked() {
        let mut t = Table::new("x", "y", &["a", "b"]);
        t.row(vec!["1".into()]);
    }

    #[test]
    fn formatting_helpers() {
        assert_eq!(ms(12_345.0), "12.3");
        assert_eq!(qps(99.6), "100");
        assert_eq!(pct(0.104), "10%");
    }
}
