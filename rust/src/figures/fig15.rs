//! Fig. 15 + Table 1 — generality across GR models (HSTU, revised HSTU,
//! LONGER+RankMixer) and across NPU types (Ascend 310 vs 910C), plus the
//! default-setting ψ footprint table.
//!
//! The two sweep panels run their (model|npu, variant) cells on the
//! deterministic `--jobs` executor with declaration-order merge; Table 1
//! is pure arithmetic and stays serial.

use anyhow::Result;

use crate::cluster::SimConfig;
use crate::figures::common::{self, Table};
use crate::metrics::slo;
use crate::model::{Dtype, HardwareProfile, ModelSpec, ModelType};
use crate::relay::baseline::Mode;
use crate::relay::tier::DramPolicy;
use crate::util::cli::Args;
use crate::util::parallel;

fn model_variants() -> Vec<(&'static str, ModelSpec)> {
    let base = ModelSpec::paper_default();
    vec![
        ("type1-hstu", ModelSpec { model_type: ModelType::Hstu, ..base }),
        ("type2-hstu-rev", ModelSpec { model_type: ModelType::HstuRev, ..base }),
        (
            // LONGER+RankMixer is "significantly larger" (§4.4): wider dim,
            // heavier DLRM tower; only the Longer backbone is cached.
            "type3-longer-rankmixer",
            ModelSpec {
                model_type: ModelType::LongerRankMixer,
                dim: 384,
                heads: 6,
                ..base
            },
        ),
    ]
}

/// Fig. 15a: across models — absolute numbers differ by large factors but
/// RelayGR consistently extends length and throughput.
pub fn fig15a(args: &Args) -> Result<()> {
    let (_, dur) = common::durations(args);
    let qps = args.get_f64("qps", 60.0)?;
    let mut t = Table::new(
        "fig15a",
        "generality across GR models: max length and SLO QPS",
        &["model", "variant", "max_seq_len", "max_qps"],
    );
    let mut cells: Vec<(&'static str, ModelSpec, Mode)> = Vec::new();
    for (name, spec) in model_variants() {
        for mode in [Mode::Baseline, Mode::RelayGr { dram: DramPolicy::Capacity(500 << 30) }] {
            cells.push((name, spec, mode));
        }
    }
    let jobs = parallel::jobs_from_args(args)?;
    let rows = parallel::map_indexed(jobs, cells.len(), |i| -> Result<Vec<String>> {
        let (name, spec, mode) = cells[i];
        let mut cfg = SimConfig::standard(mode);
        cfg.spec = spec;
        cfg.long_threshold = 1024; // relay-eligible from 1K tokens
        let lens = [1536usize, 2048, 3072, 4096, 6144];
        let len_search = slo::max_supported_len(
            |len| {
                let wl = common::fixed_len_workload_thresh(len, 1024, qps, dur, 70);
                common::sim("fig15a", cfg.clone(), &wl).expect("sim")
            },
            &lens,
            cfg.pipeline.required_success,
        );
        let qps_search = slo::max_qps(
            |q| {
                let wl = common::fixed_len_workload_thresh(1536, 1024, q, dur, 71);
                common::sim("fig15a", cfg.clone(), &wl).expect("sim")
            },
            2.0,
            3000.0,
            cfg.pipeline.required_success,
            0.05,
        );
        Ok(vec![
            name.to_string(),
            mode.label(),
            format!("{:.0}", len_search.value),
            common::qps(qps_search.value),
        ])
    });
    for row in rows {
        t.row(row?);
    }
    t.emit(args)
}

/// Fig. 15b: across NPU types (310 vs 910C) — absolute capability differs
/// by ~an order of magnitude; the RelayGR gain pattern is preserved.
pub fn fig15b(args: &Args) -> Result<()> {
    let (_, dur) = common::durations(args);
    let qps = args.get_f64("qps", 60.0)?;
    let mut t = Table::new(
        "fig15b",
        "generality across NPU types: max length and SLO QPS",
        &["npu", "variant", "max_seq_len", "max_qps"],
    );
    let mut cells: Vec<(HardwareProfile, Mode)> = Vec::new();
    for hw in [HardwareProfile::ascend_310(), HardwareProfile::ascend_910c()] {
        for mode in [Mode::Baseline, Mode::RelayGr { dram: DramPolicy::Capacity(500 << 30) }] {
            cells.push((hw.clone(), mode));
        }
    }
    let jobs = parallel::jobs_from_args(args)?;
    let rows = parallel::map_indexed(jobs, cells.len(), |i| -> Result<Vec<String>> {
        let (hw, mode) = &cells[i];
        let mut cfg = SimConfig::standard(*mode);
        // The 310 (edge-class, ~4× less compute) serves an edge-sized
        // GR variant, as in production tiering; absolute numbers
        // differ by ~an order of magnitude, trends must match.
        if hw.name == "ascend-310" {
            cfg.spec.layers = 4;
            cfg.spec.dim = 128;
            cfg.spec.heads = 2;
        }
        cfg.hw = hw.clone();
        cfg.long_threshold = 1024;
        let lens = [1536usize, 2048, 3072, 4096, 6144];
        let len_search = slo::max_supported_len(
            |len| {
                let wl = common::fixed_len_workload_thresh(len, 1024, qps, dur, 72);
                common::sim("fig15b", cfg.clone(), &wl).expect("sim")
            },
            &lens,
            cfg.pipeline.required_success,
        );
        let qps_search = slo::max_qps(
            |q| {
                let wl = common::fixed_len_workload_thresh(1536, 1024, q, dur, 73);
                common::sim("fig15b", cfg.clone(), &wl).expect("sim")
            },
            2.0,
            3000.0,
            cfg.pipeline.required_success,
            0.05,
        );
        Ok(vec![
            hw.name.clone(),
            mode.label(),
            format!("{:.0}", len_search.value),
            common::qps(qps_search.value),
        ])
    });
    for row in rows {
        t.row(row?);
    }
    t.emit(args)
}

/// Table 1: per-request ψ footprint under the default setting — must be
/// exactly 32 MB for 2K tokens, 8 layers, fp32, dim 256.
pub fn table1(args: &Args) -> Result<()> {
    let mut t = Table::new(
        "table1",
        "KV caches under default settings (2K seq, 8 layers, fp32, dim 256)",
        &["model", "seq", "layers", "format", "dim", "size_mb"],
    );
    for (name, mut spec) in model_variants() {
        // Table 1 reports all three types at the same default setting.
        spec.dim = 256;
        spec.heads = 4;
        spec.prefix_len = 2048;
        spec.layers = 8;
        spec.dtype = Dtype::F32;
        t.row(vec![
            name.to_string(),
            "2K".into(),
            spec.layers.to_string(),
            "fp32".into(),
            spec.dim.to_string(),
            format!("{:.0}", spec.kv_bytes() as f64 / (1024.0 * 1024.0)),
        ]);
    }
    t.emit(args)
}
