//! Fig. 14 — extension of RelayGR (Q3): candidate-set size, NPU
//! utilization, embedding-dimension scaling and model-depth scaling.
//!
//! All four panels sweep independent seeded runs, so their cells run on
//! the deterministic `--jobs` executor and merge in declaration order —
//! output is byte-identical at any job count.

use anyhow::Result;

use crate::cluster::SimConfig;
use crate::figures::common::{self, Table};
use crate::metrics::slo;
use crate::relay::baseline::Mode;
use crate::relay::tier::DramPolicy;
use crate::util::cli::Args;
use crate::util::parallel;

/// Fig. 14a: ranking latency vs candidate-set size (paper: rank-on-cache
/// below ~10 ms even at 2048 items; baseline carries the long prefix).
pub fn fig14a(args: &Args) -> Result<()> {
    let (dur, _) = common::durations(args);
    let len = args.get_usize("len", 3072)?;
    let qps = args.get_f64("qps", 60.0)?;
    let mut t = Table::new(
        "fig14a",
        "long-request rank-stage latency (ms) vs candidate-set size",
        &["items", "baseline_p50", "baseline_p99", "relaygr_p50", "relaygr_p99"],
    );
    let item_counts = [128usize, 256, 512, 1024, 2048];
    let modes = [Mode::Baseline, Mode::RelayGr { dram: DramPolicy::Disabled }];
    let jobs = parallel::jobs_from_args(args)?;
    let cells =
        parallel::map_indexed(jobs, item_counts.len() * modes.len(), |i| -> Result<[String; 2]> {
            let (items, mode) = (item_counts[i / modes.len()], modes[i % modes.len()]);
            let mut cfg = SimConfig::standard(mode);
            cfg.spec.num_items = items;
            let m = common::sim("fig14a", cfg, &common::fixed_len_workload(len, qps, dur, 60))?;
            Ok([common::ms(m.rank_stage_long.p50()), common::ms(m.rank_stage_long.p99())])
        });
    let cells = cells.into_iter().collect::<Result<Vec<_>>>()?;
    for (ii, items) in item_counts.iter().enumerate() {
        let mut row = vec![items.to_string()];
        for cell in &cells[ii * modes.len()..(ii + 1) * modes.len()] {
            row.extend(cell.iter().cloned());
        }
        t.row(row);
    }
    t.emit(args)
}

/// Fig. 14b: NPU (cube) utilization vs concurrency — RelayGR with 0% DRAM
/// hit adds pre-inference work (higher util); DRAM hits remove it.
pub fn fig14b(args: &Args) -> Result<()> {
    let (dur, _) = common::durations(args);
    let len = args.get_usize("len", 3072)?;
    let mut t = Table::new(
        "fig14b",
        "special/mean NPU utilization vs offered QPS",
        &["qps", "variant", "special_util", "mean_util", "p99_ms"],
    );
    let mut cells: Vec<(f64, Mode)> = Vec::new();
    for qps in [50.0, 100.0, 200.0, 400.0] {
        for mode in common::standard_modes() {
            cells.push((qps, mode));
        }
    }
    let jobs = parallel::jobs_from_args(args)?;
    let rows = parallel::map_indexed(jobs, cells.len(), |i| -> Result<Vec<String>> {
        let (qps, mode) = cells[i];
        let cfg = SimConfig::standard(mode);
        let m = common::sim("fig14b", cfg, &common::fixed_len_workload(len, qps, dur, 61))?;
        let special = if m.special_instances.is_empty() {
            m.mean_util(None)
        } else {
            m.special_util()
        };
        Ok(vec![
            common::qps(qps),
            mode.label(),
            common::pct(special),
            common::pct(m.mean_util(None)),
            common::ms(m.p99_e2e()),
        ])
    });
    for row in rows {
        t.row(row?);
    }
    t.emit(args)
}

/// Fig. 14c: throughput vs embedding dimension (paper: at 1024-dim the
/// baseline drops below 50 QPS; RelayGR ≥ 2×, ~3× with full DRAM reuse).
pub fn fig14c(args: &Args) -> Result<()> {
    let (_, dur) = common::durations(args);
    let len = args.get_usize("len", 2048)?;
    let mut t = Table::new(
        "fig14c",
        "SLO-compliant QPS vs embedding dimension",
        &["dim", "baseline", "relaygr", "relaygr+dram500g"],
    );
    let dims = [128usize, 256, 512, 768, 1024];
    let modes = [
        Mode::Baseline,
        Mode::RelayGr { dram: DramPolicy::Disabled },
        Mode::RelayGr { dram: DramPolicy::Capacity(500 << 30) },
    ];
    let jobs = parallel::jobs_from_args(args)?;
    let cells = parallel::map_indexed(jobs, dims.len() * modes.len(), |i| -> Result<String> {
        let (dim, mode) = (dims[i / modes.len()], modes[i % modes.len()]);
        let mut cfg = SimConfig::standard(mode);
        cfg.spec.dim = dim;
        cfg.spec.heads = (dim / 64).max(1);
        cfg.spec.layers = 4; // width sweep at moderate depth
        cfg.long_threshold = 1024; // 2K-token class is relay-eligible
        let search = slo::max_qps(
            |q| {
                let wl = common::fixed_len_workload_thresh(len, 1024, q, dur, 62);
                common::sim("fig14c", cfg.clone(), &wl).expect("sim")
            },
            2.0,
            3000.0,
            cfg.pipeline.required_success,
            0.05,
        );
        Ok(common::qps(search.value))
    });
    let cells = cells.into_iter().collect::<Result<Vec<_>>>()?;
    for (di, dim) in dims.iter().enumerate() {
        let mut row = vec![dim.to_string()];
        row.extend(cells[di * modes.len()..(di + 1) * modes.len()].iter().cloned());
        t.row(row);
    }
    t.emit(args)
}

/// Fig. 14d: throughput vs model depth (paper: 16 layers → RelayGR ≥ 4×
/// baseline; with 100% hit, doubling layers costs only ~14%).
pub fn fig14d(args: &Args) -> Result<()> {
    let (_, dur) = common::durations(args);
    let len = args.get_usize("len", 2048)?;
    let mut t = Table::new(
        "fig14d",
        "SLO-compliant QPS vs model depth",
        &["layers", "baseline", "relaygr", "relaygr+dram500g"],
    );
    let depths = [4usize, 8, 16, 24];
    let modes = [
        Mode::Baseline,
        Mode::RelayGr { dram: DramPolicy::Disabled },
        Mode::RelayGr { dram: DramPolicy::Capacity(500 << 30) },
    ];
    let jobs = parallel::jobs_from_args(args)?;
    let cells = parallel::map_indexed(jobs, depths.len() * modes.len(), |i| -> Result<String> {
        let (layers, mode) = (depths[i / modes.len()], modes[i % modes.len()]);
        let mut cfg = SimConfig::standard(mode);
        cfg.spec.layers = layers;
        cfg.long_threshold = 1024; // 2K-token class is relay-eligible
        let search = slo::max_qps(
            |q| {
                let wl = common::fixed_len_workload_thresh(len, 1024, q, dur, 63);
                common::sim("fig14d", cfg.clone(), &wl).expect("sim")
            },
            2.0,
            3000.0,
            cfg.pipeline.required_success,
            0.05,
        );
        Ok(common::qps(search.value))
    });
    let cells = cells.into_iter().collect::<Result<Vec<_>>>()?;
    for (di, layers) in depths.iter().enumerate() {
        let mut row = vec![layers.to_string()];
        row.extend(cells[di * modes.len()..(di + 1) * modes.len()].iter().cloned());
        t.row(row);
    }
    t.emit(args)
}
