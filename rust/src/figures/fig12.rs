//! Fig. 12 — affinity is necessary: local (RelayGR) cache access vs
//! remote fetch from a no-affinity distributed KV pool.  Remote fetch is
//! orders of magnitude slower and can exceed the lifecycle window.
//! (Pure arithmetic — no simulations, so no `--jobs` executor here.)

use anyhow::Result;

use crate::figures::common::{self, Table};
use crate::model::{HardwareProfile, ModelSpec};
use crate::relay::baseline::RemotePool;
use crate::util::cli::Args;

pub fn fig12(args: &Args) -> Result<()> {
    let hw = HardwareProfile::by_name(args.get_or("hw", "ascend-910c"))
        .ok_or_else(|| anyhow::anyhow!("unknown hw"))?;
    let spec = ModelSpec::paper_default();
    let pool = RemotePool { n_servers: args.get_usize("servers", 25)? };
    let t_life_ms = 300.0;
    let mut t = Table::new(
        "fig12",
        "local (RelayGR) vs remote fetch latency per ψ size",
        &["seq_len", "kv_mb", "local_ms", "remote_ms", "ratio", "exceeds_lifecycle"],
    );
    for len in common::seq_lens() {
        let kv = spec.kv_bytes_for(len);
        let local = pool.local_access_us(&hw);
        let remote = pool.remote_fetch_us(&hw, kv);
        t.row(vec![
            len.to_string(),
            format!("{:.0}", kv as f64 / 1e6),
            common::ms(local),
            common::ms(remote),
            format!("{:.0}x", remote / local),
            (remote / 1e3 > t_life_ms).to_string(),
        ]);
    }
    t.emit(args)
}
