//! `relaygr figure admission` — the closed-loop admission standing
//! report: static vs adaptive admission across all four workload
//! scenarios, in both decision engines (discrete-event simulator +
//! serialized reference driver).
//!
//! The run shape reproduces the motivating misprovisioning: the
//! provisioned worst-case ψ (`kv_p99` at 32K tokens ≈ 512 MB) exceeds
//! the r1·HBM slice (≈ 344 MB at r1 = 0.01), so the static Eq. 2 bound
//! collapses to `L_max = 0` and every at-risk request is
//! footprint-limited — r1·HBM sits idle while long traffic runs full
//! inference.  The adaptive controller admits against the *observed*
//! footprint distribution (48 MB at 3072 tokens), filling the slice
//! with ~6 live caches per special instance and never overcommitting
//! the window (no spill storms / lost productions: `rejected = lost =
//! 0` is asserted).
//!
//! Both modes drive the identical
//! [`RelayCoordinator`](crate::relay::RelayCoordinator), and the
//! adaptive controller's signals are decision-synchronous (observed
//! footprints, metadata estimates, arrival clocks — never completion
//! timing), so the figure
//! *asserts* per-request outcome equality between the simulator and the
//! serialized reference on every row rather than publishing rows from
//! diverged engines.  Like `figure segments`, the shape keeps ψ
//! decisions timing-insensitive: no DRAM tier, no refresh bursts,
//! T_life beyond the trace.

use anyhow::{ensure, Result};

use crate::cluster::{run_reference, SimConfig};
use crate::figures::common::{ms, sim, Table};
use crate::metrics::{outcome_index, RunMetrics};
use crate::relay::baseline::Mode;
use crate::relay::pipeline::CacheOutcome;
use crate::relay::tier::DramPolicy;
use crate::relay::trigger::AdmissionMode;
use crate::util::cli::Args;
use crate::util::parallel;
use crate::workload::{ScenarioKind, WorkloadConfig};

/// Per-(scenario, mode) results needed for the cross-mode assertions.
struct ModeRow {
    label: &'static str,
    sim: RunMetrics,
    serial_counts: [u64; 6],
    serial_trigger: crate::relay::trigger::TriggerStats,
    serial_mean_rank_us: f64,
}

/// `relaygr figure admission [--qps N] [--quick] [--scenario s]
/// [--headroom-min h] [--headroom-max h] [--adapt-window n] [--jobs N]`.
///
/// Each (scenario, admission-mode) cell runs both engines and asserts
/// their per-request outcome equality intra-cell; the static-vs-adaptive
/// comparisons need both of a scenario's cells, so they run on the
/// caller's thread after the deterministic merge, in declaration order.
pub fn admission(args: &Args) -> Result<()> {
    let duration_us = if args.has_flag("quick") { 4_000_000 } else { 8_000_000 };
    let qps = args.get_f64("qps", 60.0)?;
    let seed = args.get_u64("seed", 42)?;
    let jobs = parallel::jobs_from_args(args)?;
    let kinds: Vec<ScenarioKind> = match args.get("scenario") {
        Some(s) => vec![ScenarioKind::parse(s).map_err(anyhow::Error::msg)?],
        None => ScenarioKind::NAMES
            .iter()
            .map(|n| ScenarioKind::parse(n).expect("built-in scenario"))
            .collect(),
    };
    let mut t = Table::new(
        "admission",
        "static vs adaptive admission × scenarios (simulator + serialized reference)",
        &[
            "scenario", "admission", "engine", "n", "admitted", "fp-lim", "rate-lim", "hbm",
            "full", "mean rank ms", "l_max*",
        ],
    );
    let full_idx = outcome_index(CacheOutcome::FullInference);
    let hbm_idx = outcome_index(CacheOutcome::HbmHit);
    let mut cells: Vec<(ScenarioKind, AdmissionMode)> = Vec::new();
    for kind in &kinds {
        for mode in [AdmissionMode::Static, AdmissionMode::Adaptive] {
            cells.push((*kind, mode));
        }
    }
    let results = parallel::map_indexed(jobs, cells.len(), |i| -> Result<ModeRow> {
        let (kind, mode) = cells[i];
        let wl = WorkloadConfig {
            qps,
            duration_us,
            num_users: 30_000,
            long_frac: 0.2,
            fixed_long_len: Some(3072),
            max_prefix: 3072,
            refresh_prob: 0.0,
            scenario: kind,
            seed,
            ..Default::default()
        };
        let mut cfg = SimConfig::standard(Mode::RelayGr { dram: DramPolicy::Disabled });
        cfg.pipeline.t_life_us = 2 * wl.duration_us;
        // The misprovisioned static operating point: worst-case ψ
        // provisioned at 32K tokens against a 1% HBM slice.
        cfg.r1 = 0.01;
        cfg.kv_p99_prefix = 32_768;
        cfg.log_outcomes = true;
        cfg.admission = crate::config::parse_admission(args, &cfg.admission)?;
        cfg.admission.mode = mode;
        let m: RunMetrics = sim("admission", cfg.clone(), &wl)?;
        let serial = run_reference(&cfg, &wl)?;
        let mut sim_log = m.outcome_log();
        sim_log.sort_by_key(|&(id, _)| id);
        ensure!(
            sim_log == serial.outcomes,
            "admission: engines diverged on per-request outcomes \
             (scenario {}, admission {})",
            kind.label(),
            cfg.admission.label()
        );
        Ok(ModeRow {
            label: cfg.admission.label(),
            sim: m,
            serial_counts: serial.outcome_counts,
            serial_trigger: serial.trigger,
            serial_mean_rank_us: serial.mean_rank_us,
        })
    });
    let mut results = results.into_iter();
    for kind in &kinds {
        let mut rows: Vec<ModeRow> = Vec::new();
        for _mode in [AdmissionMode::Static, AdmissionMode::Adaptive] {
            let r = results.next().expect("one result per cell")?;
            let label = r.label.to_string();
            for (engine, n, trig, counts, rank_ms) in [
                (
                    "sim",
                    r.sim.completed,
                    r.sim.trigger,
                    r.sim.outcome_counts,
                    ms(r.sim.rank_exec.mean()),
                ),
                (
                    "serial",
                    r.serial_counts.iter().sum(),
                    r.serial_trigger,
                    r.serial_counts,
                    ms(r.serial_mean_rank_us),
                ),
            ] {
                t.row(vec![
                    kind.label().to_string(),
                    label.clone(),
                    engine.into(),
                    n.to_string(),
                    trig.admitted.to_string(),
                    trig.footprint_limited.to_string(),
                    trig.rate_limited.to_string(),
                    counts[hbm_idx].to_string(),
                    counts[full_idx].to_string(),
                    rank_ms,
                    trig.l_max_effective.to_string(),
                ]);
            }
            rows.push(r);
        }
        let (stat, adpt) = (&rows[0], &rows[1]);
        let scen = kind.label();
        // The collapsed static bound starves the relay path entirely…
        ensure!(
            stat.sim.trigger.admitted == 0 && stat.sim.trigger.footprint_limited > 0,
            "admission: static bound did not collapse on {scen} ({:?})",
            stat.sim.trigger
        );
        // …while the closed loop admits against observed footprints,
        // strictly reducing footprint-limited denials and full-inference
        // pressure in BOTH engines (steady included: no regression).
        for (name, s_fp, a_fp, s_full, a_full) in [
            (
                "sim",
                stat.sim.trigger.footprint_limited,
                adpt.sim.trigger.footprint_limited,
                stat.sim.outcome_counts[full_idx],
                adpt.sim.outcome_counts[full_idx],
            ),
            (
                "serial",
                stat.serial_trigger.footprint_limited,
                adpt.serial_trigger.footprint_limited,
                stat.serial_counts[full_idx],
                adpt.serial_counts[full_idx],
            ),
        ] {
            ensure!(
                a_fp < s_fp,
                "admission ({scen}/{name}): adaptive fp-limited {a_fp} !< static {s_fp}"
            );
            ensure!(
                a_full < s_full,
                "admission ({scen}/{name}): adaptive full {a_full} !< static {s_full}"
            );
        }
        ensure!(
            adpt.sim.rank_exec.mean() < stat.sim.rank_exec.mean()
                && adpt.serial_mean_rank_us < stat.serial_mean_rank_us,
            "admission ({scen}): adaptive mean rank must strictly drop"
        );
        // No spill storms / lost work: the occupancy-aware bound never
        // outruns the ψ window.
        ensure!(
            adpt.sim.hbm.rejected == 0 && adpt.sim.hbm.lost == 0,
            "admission ({scen}): adaptive overcommitted the window ({:?})",
            adpt.sim.hbm
        );
    }
    // Batch-window coupling: the adaptive controller charges the
    // microbatch window to its admission latency estimate, so opening a
    // 20 ms window can only move boundary requests *into* the relay
    // path — never out of it.  One extra steady cell per engine,
    // compared against a window-0 adaptive base (monotone-safe `<=`:
    // the sweep stays green even if no request sits on the boundary).
    if kinds.iter().any(|k| matches!(k, ScenarioKind::Steady)) {
        let run_steady = |window: u64| -> Result<ModeRow> {
            let wl = WorkloadConfig {
                qps,
                duration_us,
                num_users: 30_000,
                long_frac: 0.2,
                fixed_long_len: Some(3072),
                max_prefix: 3072,
                refresh_prob: 0.0,
                scenario: ScenarioKind::Steady,
                seed,
                ..Default::default()
            };
            let mut cfg = SimConfig::standard(Mode::RelayGr { dram: DramPolicy::Disabled });
            cfg.pipeline.t_life_us = 2 * wl.duration_us;
            cfg.r1 = 0.01;
            cfg.kv_p99_prefix = 32_768;
            cfg.batch_window_us = window;
            cfg.log_outcomes = true;
            cfg.admission = crate::config::parse_admission(args, &cfg.admission)?;
            cfg.admission.mode = AdmissionMode::Adaptive;
            let m: RunMetrics = sim("admission", cfg.clone(), &wl)?;
            let serial = run_reference(&cfg, &wl)?;
            let mut sim_log = m.outcome_log();
            sim_log.sort_by_key(|&(id, _)| id);
            ensure!(
                sim_log == serial.outcomes,
                "admission: engines diverged on per-request outcomes \
                 (steady, adaptive, batch-window {window})"
            );
            Ok(ModeRow {
                label: "adaptive+w20ms",
                sim: m,
                serial_counts: serial.outcome_counts,
                serial_trigger: serial.trigger,
                serial_mean_rank_us: serial.mean_rank_us,
            })
        };
        let base = run_steady(0)?;
        let w20 = run_steady(20_000)?;
        for (engine, n, trig, counts, rank_ms) in [
            ("sim", w20.sim.completed, w20.sim.trigger, w20.sim.outcome_counts,
             ms(w20.sim.rank_exec.mean())),
            ("serial", w20.serial_counts.iter().sum(), w20.serial_trigger, w20.serial_counts,
             ms(w20.serial_mean_rank_us)),
        ] {
            t.row(vec![
                "steady".into(),
                w20.label.to_string(),
                engine.into(),
                n.to_string(),
                trig.admitted.to_string(),
                trig.footprint_limited.to_string(),
                trig.rate_limited.to_string(),
                counts[hbm_idx].to_string(),
                counts[full_idx].to_string(),
                rank_ms,
                trig.l_max_effective.to_string(),
            ]);
        }
        for (name, b, w) in [
            ("sim", &base.sim.trigger, &w20.sim.trigger),
            ("serial", &base.serial_trigger, &w20.serial_trigger),
        ] {
            ensure!(
                w.assessed == b.assessed,
                "admission (steady/{name}): window changed the assessed count \
                 ({} vs {})",
                w.assessed,
                b.assessed
            );
            ensure!(
                w.not_at_risk <= b.not_at_risk,
                "admission (steady/{name}): 20 ms window left MORE requests \
                 not-at-risk ({} vs {}) — the estimate is not charging the window",
                w.not_at_risk,
                b.not_at_risk
            );
        }
    }
    t.emit(args)
}
