//! `relaygr figure batching` — the microbatched-ranking standing report:
//! the coordinator's batch-former window swept from 0 (unbatched, the
//! PR 6-identical configuration) up through multi-ms windows, across the
//! workload scenarios, in both decision engines.
//!
//! Two claims are checked *inside* the figure rather than published on
//! trust:
//!
//! * **Outcome identity** — batching changes pricing and timing, never
//!   [`CacheOutcome`](crate::relay::CacheOutcome) decisions:
//!   classification happens per-request before the batch former sees the
//!   pass.  Every (scenario, window) cell runs the simulator *and* the
//!   serialized reference driver and asserts their per-request outcomes
//!   are identical — even though the two engines form different batches
//!   (the sim offers at rank-exec-ready simulated times, the reference
//!   at arrival times).
//! * **Throughput** — on the burst scenario, at least one nonzero window
//!   must deliver strictly higher SLO-compliant throughput than window
//!   0: co-arriving spike traffic amortizes into shared launches
//!   (`n^α` total compute, α < 1), which is the point of the feature.
//!
//! The headline axis is SLO-compliant throughput ([`slo::max_qps`]):
//! batching trades single-request latency (leaders wait out the window,
//! batched passes run longer than solos) for per-member compute, so raw
//! latency columns would undersell it and a pure-throughput column would
//! hide the P99 cost.  The compliance search prices both sides.

use anyhow::{ensure, Result};

use crate::cluster::SimConfig;
use crate::config::apply_candidate_flags;
use crate::figures::common::{ms, qps, sim, Table};
use crate::metrics::{slo, RunMetrics};
use crate::relay::baseline::Mode;
use crate::relay::tier::DramPolicy;
use crate::util::cli::Args;
use crate::util::json::Json;
use crate::util::parallel;
use crate::workload::{ScenarioKind, WorkloadConfig};

/// The swept batch windows (µs).  0 is the unbatched control; the
/// nonzero points bracket the rank-pass service time (a few ms at the
/// default spec), where batches actually form near capacity.
const WINDOWS: &[u64] = &[0, 1_000, 5_000, 20_000];

/// `relaygr figure batching [--qps N] [--quick] [--scenario s]
/// [--batch-max n] [--jobs N]`.
///
/// Each (scenario, window) cell is self-contained — the probe run checks
/// sim-vs-reference outcome identity, the capacity search produces the
/// headline — so the grid parallelizes on the deterministic executor.
pub fn batching(args: &Args) -> Result<()> {
    let (probe_dur, search_dur) =
        if args.has_flag("quick") { (3_000_000, 2_000_000) } else { (8_000_000, 6_000_000) };
    let probe_qps = args.get_f64("qps", 60.0)?;
    let seed = args.get_u64("seed", 42)?;
    let batch_max = args.get_usize("batch-max", 8)?;
    ensure!(batch_max >= 1, "--batch-max must be >= 1");
    let jobs = parallel::jobs_from_args(args)?;
    let kinds: Vec<ScenarioKind> = match args.get("scenario") {
        Some(s) => vec![ScenarioKind::parse(s).map_err(anyhow::Error::msg)?],
        None => ScenarioKind::NAMES
            .iter()
            .map(|n| ScenarioKind::parse(n).expect("built-in scenario"))
            .collect(),
    };
    let mut cells: Vec<(ScenarioKind, u64)> = Vec::new();
    for kind in &kinds {
        for &w in WINDOWS {
            cells.push((*kind, w));
        }
    }
    // (row, headline qps) per cell; the burst strictness check needs the
    // numeric headline after the ordered merge.
    let results = parallel::map_indexed(jobs, cells.len(), |i| -> Result<(Vec<String>, f64)> {
        let (kind, window) = cells[i];
        let workload = |q: f64, duration_us: u64| -> Result<WorkloadConfig> {
            let mut wl = WorkloadConfig {
                qps: q,
                duration_us,
                num_users: 30_000,
                fixed_long_len: Some(3072),
                max_prefix: 3072,
                refresh_prob: 0.0,
                scenario: kind,
                seed,
                ..Default::default()
            };
            apply_candidate_flags(args, &mut wl)?;
            Ok(wl)
        };
        let mut cfg = SimConfig::standard(Mode::RelayGr { dram: DramPolicy::Disabled });
        // The strict timing-insensitive shape (no DRAM tier, lifecycle
        // beyond the trace, no refresh bursts): any sim-vs-reference
        // divergence is a genuine policy difference, not clock skew —
        // which is exactly what makes the outcome-identity assertion
        // meaningful while the two engines form *different* batches.
        cfg.pipeline.t_life_us = 2 * probe_dur.max(search_dur);
        cfg.batch_window_us = window;
        cfg.batch_max = batch_max;
        cfg.log_outcomes = true;
        let wl = workload(probe_qps, probe_dur)?;
        let m: RunMetrics = sim("batching", cfg.clone(), &wl)?;
        let serial = crate::cluster::run_reference(&cfg, &wl)?;
        let mut sim_log = m.outcome_log();
        sim_log.sort_by_key(|&(id, _)| id);
        ensure!(
            sim_log == serial.outcomes,
            "batching: engines diverged on per-request outcomes \
             (scenario {}, batch-window {window})",
            kind.label()
        );
        // Headline: the largest offered load that stays SLO-compliant
        // with this window.
        cfg.log_outcomes = false;
        let required = cfg.pipeline.required_success;
        let search = slo::max_qps(
            |q| {
                let wl = workload(q, search_dur).expect("workload");
                sim("batching", cfg.clone(), &wl).expect("sim")
            },
            2.0,
            3000.0,
            required,
            0.05,
        );
        Ok((
            vec![
                kind.label().to_string(),
                window.to_string(),
                qps(search.value),
                m.completed.to_string(),
                ms(m.rank_exec.mean()),
                ms(m.e2e.p99()),
                "ok".into(),
            ],
            search.value,
        ))
    });
    let mut t = Table::new(
        "batching",
        "SLO-compliant throughput vs batch-former window (simulator + serialized reference)",
        &["scenario", "window_us", "slo_qps", "n", "mean rank ms", "p99 e2e ms", "outcomes"],
    );
    t.meta
        .set("windows_us", Json::Arr(WINDOWS.iter().map(|&w| (w as usize).into()).collect()))
        .set("batch_max", batch_max.into())
        .set("probe_qps", probe_qps.into());
    let mut headline: Vec<(ScenarioKind, u64, f64)> = Vec::new();
    for (i, res) in results.into_iter().enumerate() {
        let (row, value) = res?;
        let (kind, window) = cells[i];
        headline.push((kind, window, value));
        t.row(row);
    }
    // The feature's reason to exist, asserted: on the burst scenario
    // some nonzero window beats the unbatched control outright.
    if kinds.iter().any(|k| matches!(k, ScenarioKind::Burst { .. })) {
        let at = |w: u64| {
            headline
                .iter()
                .find(|&&(k, win, _)| matches!(k, ScenarioKind::Burst { .. }) && win == w)
                .map(|&(_, _, v)| v)
                .expect("burst cell present")
        };
        let w0 = at(0);
        let best = WINDOWS[1..].iter().map(|&w| at(w)).fold(f64::MIN, f64::max);
        ensure!(
            best > w0,
            "batching: no nonzero window beats window 0 on burst \
             (best {best:.0} qps vs unbatched {w0:.0} qps)"
        );
    }
    t.emit(args)
}
