//! `relaygr figure faults` — the fault-plane standing report: injection
//! rate × retry policy × arrival scenario, in both decision engines.
//!
//! Three claims are checked *inside* the figure rather than published on
//! trust:
//!
//! * **Engine identity** — fault draws are pure functions of decision-
//!   plane state (seed, kind, stable id, attempt), so under the strict
//!   shape (no DRAM tier, T_life beyond the trace) the simulator and the
//!   serialized reference must classify every request identically AND
//!   produce byte-identical [`FaultReport`]s.  A divergence means a draw
//!   leaked clock or ordinal state.
//! * **Retries pay** — at an equal fault spec, turning bounded retries on
//!   must *strictly* reduce the full-inference count: recovered
//!   productions and trigger signals restore relay service that the
//!   retry-off run lost to the degradation ladder.
//! * **Shed is bounded** — under the burst scenario with a nonzero shed
//!   probability, the shed fraction of completed requests stays under a
//!   fixed bound: the ladder degrades to full inference by default and
//!   sheds only its configured slice of unrecovered faults.

use anyhow::{ensure, Result};

use crate::cluster::SimConfig;
use crate::config::apply_candidate_flags;
use crate::figures::common::{ms, sim, Table};
use crate::metrics::RunMetrics;
use crate::relay::baseline::Mode;
use crate::relay::fault::{FaultConfig, FaultReport};
use crate::relay::tier::DramPolicy;
use crate::util::cli::Args;
use crate::util::parallel;
use crate::workload::{ScenarioKind, WorkloadConfig};

/// Shed fraction of completed requests the burst rows must stay under.
const SHED_BOUND: f64 = 0.10;

/// `relaygr figure faults [--qps N] [--quick] [--jobs N] [--seed N]`.
///
/// Grid: {fault-off, low rate, high rate} × {retry off, retry:2} ×
/// {steady, burst}; fault-off runs once per scenario as the control row.
pub fn faults(args: &Args) -> Result<()> {
    let dur = if args.has_flag("quick") { 4_000_000u64 } else { 8_000_000 };
    let probe_qps = args.get_f64("qps", 100.0)?;
    let seed = args.get_u64("seed", 42)?;
    let jobs = parallel::jobs_from_args(args)?;

    let spec_at = |rate: f64, retry: bool| -> String {
        let mut s = format!("psi-fail:{rate},trigger-drop:{rate},shed:0.5");
        if retry {
            s.push_str(",retry:2,backoff:200us");
        }
        s
    };
    // (spec, scenario); rates chosen so even the quick trace injects
    // dozens of faults per kind.
    let mut grid: Vec<(String, ScenarioKind)> = Vec::new();
    for scenario in ["steady", "burst"] {
        let sc = ScenarioKind::parse(scenario).expect("built-in scenario");
        grid.push(("none".to_string(), sc));
        for rate in [0.05, 0.15] {
            grid.push((spec_at(rate, false), sc));
            grid.push((spec_at(rate, true), sc));
        }
    }

    let results =
        parallel::map_indexed(jobs, grid.len(), |i| -> Result<(Vec<String>, RunMetrics)> {
            let (spec, scenario) = &grid[i];
            let mut wl = WorkloadConfig {
                qps: probe_qps,
                duration_us: dur,
                num_users: 400,
                fixed_long_len: Some(3072),
                max_prefix: 3072,
                refresh_prob: 0.0,
                scenario: *scenario,
                seed,
                ..Default::default()
            };
            apply_candidate_flags(args, &mut wl)?;
            let mut cfg = SimConfig::standard(Mode::RelayGr { dram: DramPolicy::Disabled });
            // Strict engine-identity shape (no DRAM, lifecycle beyond the
            // trace): divergence means a fault draw leaked timing state.
            cfg.pipeline.t_life_us = 2 * dur;
            cfg.faults = FaultConfig::parse(spec)?;
            cfg.log_outcomes = true;
            let m: RunMetrics = sim("faults", cfg.clone(), &wl)?;
            let serial = crate::cluster::run_reference(&cfg, &wl)?;
            let mut sim_log = m.outcome_log();
            sim_log.sort_by_key(|&(id, _)| id);
            ensure!(
                sim_log == serial.outcomes,
                "faults: engines diverged on per-request outcomes \
                 (spec {spec}, scenario {})",
                scenario.label()
            );
            ensure!(
                m.faults == serial.faults,
                "faults: engines diverged on the fault report \
                 (spec {spec}, scenario {}): sim {:?} vs serial {:?}",
                scenario.label(),
                m.faults,
                serial.faults
            );
            let (inj, ret, rec, deg, shed) = m.faults.totals();
            if cfg.faults.enabled() {
                ensure!(inj > 0, "faults: spec {spec} injected nothing");
                if cfg.faults.retries > 0 {
                    ensure!(
                        rec > 0 && ret > 0,
                        "faults: retries configured but nothing recovered \
                         (spec {spec}, report {:?})",
                        m.faults
                    );
                }
            } else {
                ensure!(
                    !m.faults.any() && m.outcome_counts[5] == 0,
                    "faults: fault-off control row injected or shed"
                );
            }
            let row = vec![
                spec.clone(),
                scenario.label().to_string(),
                m.completed.to_string(),
                m.outcome_counts[0].to_string(),
                m.outcome_counts[4].to_string(),
                m.outcome_counts[5].to_string(),
                inj.to_string(),
                ret.to_string(),
                rec.to_string(),
                deg.to_string(),
                shed.to_string(),
                ms(m.e2e.p99()),
                "ok".into(),
            ];
            Ok((row, m))
        });

    let mut t = Table::new(
        "faults",
        "fault plane: injection rate × retry policy × scenario (simulator + serialized reference)",
        &[
            "faults", "scenario", "n", "full", "fallback", "shed_reqs", "injected", "retried",
            "recovered", "degraded", "shed", "p99 e2e ms", "outcomes",
        ],
    );
    t.meta
        .set("probe_qps", probe_qps.into())
        .set("shed_bound", SHED_BOUND.into())
        .set("seed", seed.into());
    let mut runs: Vec<RunMetrics> = Vec::new();
    for res in results {
        let (row, m) = res?;
        t.row(row);
        runs.push(m);
    }

    // Retries pay: at every (rate, scenario), retry-on strictly reduces
    // the full-inference count vs retry-off at the equal fault spec.
    for scenario in ["steady", "burst"] {
        for rate in [0.05, 0.15] {
            let full_at = |spec: &str| {
                grid.iter()
                    .zip(&runs)
                    .find(|((s, sc), _)| s == spec && sc.label() == scenario)
                    .map(|(_, m)| m.outcome_counts[0])
                    .expect("grid row present")
            };
            let off = full_at(&spec_at(rate, false));
            let on = full_at(&spec_at(rate, true));
            ensure!(
                on < off,
                "faults: retries do not reduce full inference at rate {rate} on {scenario} \
                 ({on} !< {off})"
            );
        }
    }
    // Shed is bounded under burst, at every faulty spec.
    for ((spec, scenario), m) in grid.iter().zip(&runs) {
        if spec == "none" || scenario.label() != "burst" {
            continue;
        }
        let shed_rate = m.outcome_counts[5] as f64 / m.completed.max(1) as f64;
        ensure!(
            shed_rate <= SHED_BOUND,
            "faults: shed rate {shed_rate:.3} exceeds bound {SHED_BOUND} \
             (spec {spec}, burst)"
        );
    }
    // The report's internal accounting stays coherent on every row.
    for m in &runs {
        let f: &FaultReport = &m.faults;
        let (inj, _, rec, deg, shed) = f.totals();
        ensure!(rec + deg + shed <= inj, "faults: resolved {rec}+{deg}+{shed} > injected {inj}");
        ensure!(
            m.outcome_counts[5] <= shed,
            "faults: more shed requests than shed fault events"
        );
    }
    t.emit(args)
}
