//! Fig. 11 — effectiveness of RelayGR (Q1): maximum supported sequence
//! length, tail latency under concurrency, component breakdown, and
//! SLO-compliant throughput.
//!
//! Every panel's cells are independent seeded runs, so each sweep runs
//! on the deterministic `--jobs` executor; cross-cell derivations (the
//! `vs_baseline` ratios) happen after the declaration-order merge, so
//! output is byte-identical at any job count.

use anyhow::Result;

use crate::cluster::SimConfig;
use crate::figures::common::{self, Table};
use crate::metrics::slo;
use crate::util::cli::Args;
use crate::util::parallel;

/// Fig. 11a: max supported sequence length per variant (paper: RelayGR up
/// to 1.5× baseline; DRAM reuse extends it further).
pub fn fig11a(args: &Args) -> Result<()> {
    let (_, dur) = common::durations(args);
    let qps = args.get_f64("qps", 80.0)?;
    let mut t = Table::new(
        "fig11a",
        "maximum supported sequence length (P99 ≤ 135 ms, success ≥ 99.9%)",
        &["variant", "max_seq_len", "dram_hit", "vs_baseline"],
    );
    // The last row models the paper's high-hit-rate regime (2–4 TB DRAM →
    // 50–100% measured hits): heavy rapid-refresh reuse.
    let mut variants: Vec<(crate::relay::baseline::Mode, f64, &str)> = common::standard_modes()
        .into_iter()
        .map(|m| (m, 0.3, ""))
        .collect();
    variants.push((
        crate::relay::baseline::Mode::RelayGr {
            dram: crate::relay::tier::DramPolicy::Capacity(4096 << 30),
        },
        0.95,
        " (high reuse)",
    ));
    let jobs = parallel::jobs_from_args(args)?;
    let cells = parallel::map_indexed(jobs, variants.len(), |i| -> Result<(String, f64, f64)> {
        let (mode, refresh_prob, suffix) = variants[i];
        let cfg = SimConfig::standard(mode);
        let mut last_hit = 0.0;
        let search = slo::max_supported_len(
            |len| {
                let mut wl = common::fixed_len_workload(len, qps, dur, 45);
                wl.refresh_prob = refresh_prob;
                let m = common::sim("fig11a", cfg.clone(), &wl).expect("sim");
                last_hit = m.dram_hit_rate();
                m
            },
            &common::seq_lens(),
            cfg.pipeline.required_success,
        );
        Ok((format!("{}{}", mode.label(), suffix), search.value, last_hit))
    });
    let cells = cells.into_iter().collect::<Result<Vec<_>>>()?;
    // Baseline is the first standard mode; the ratio is derived after
    // the merge so parallel cells never depend on each other.
    let baseline_len = cells[0].1.max(1.0);
    for (label, value, hit) in cells {
        t.row(vec![
            label,
            format!("{value:.0}"),
            common::pct(hit),
            format!("{:.2}x", value / baseline_len),
        ]);
    }
    t.emit(args)
}

/// Fig. 11b: end-to-end P99 vs concurrency at fixed sequence length
/// (paper: RelayGR sustains ~2× the concurrent in-flight requests).
pub fn fig11b(args: &Args) -> Result<()> {
    let (dur, _) = common::durations(args);
    let len = args.get_usize("len", 3072)?;
    let mut t = Table::new(
        "fig11b",
        "e2e P99 (ms) and concurrency vs offered QPS at fixed length",
        &["qps", "variant", "concurrency", "p99_ms", "success"],
    );
    let mut cells: Vec<(f64, crate::relay::baseline::Mode)> = Vec::new();
    for qps in [50.0, 100.0, 200.0, 400.0, 800.0] {
        for mode in common::standard_modes() {
            cells.push((qps, mode));
        }
    }
    let jobs = parallel::jobs_from_args(args)?;
    let rows = parallel::map_indexed(jobs, cells.len(), |i| -> Result<Vec<String>> {
        let (qps, mode) = cells[i];
        let cfg = SimConfig::standard(mode);
        let wl = common::fixed_len_workload(len, qps, dur, 46);
        let m = common::sim("fig11b", cfg, &wl)?;
        // Little's law: mean in-flight = completion rate × mean e2e.
        let conc = m.goodput_qps() * m.e2e.mean() / 1e6;
        Ok(vec![
            common::qps(qps),
            mode.label(),
            format!("{conc:.1}"),
            common::ms(m.p99_e2e()),
            format!("{:.4}", m.success_rate()),
        ])
    });
    for row in rows {
        t.row(row?);
    }
    t.emit(args)
}

/// Fig. 11c: P99 component breakdown — pre grows fast with length, load
/// and rank grow slowly; pre is off the ranking critical path.
pub fn fig11c(args: &Args) -> Result<()> {
    let (dur, _) = common::durations(args);
    let mode = crate::relay::baseline::Mode::RelayGr {
        dram: crate::relay::tier::DramPolicy::Capacity(500 << 30),
    };
    let mut t = Table::new(
        "fig11c",
        "P99 component latency (ms): pre (relay path) vs load/rank (critical path)",
        &["seq_len", "pre_p99", "load_p99", "rank_p99", "wait_p99", "rank_stage_p99"],
    );
    let qps = args.get_f64("qps", 80.0)?;
    let lens = common::seq_lens();
    let jobs = parallel::jobs_from_args(args)?;
    let rows = parallel::map_indexed(jobs, lens.len(), |i| -> Result<Vec<String>> {
        let len = lens[i];
        let cfg = SimConfig::standard(mode);
        let wl = common::fixed_len_workload(len, qps, dur, 47);
        let m = common::sim("fig11c", cfg, &wl)?;
        Ok(vec![
            len.to_string(),
            common::ms(m.pre.p99()),
            common::ms(m.load.p99()),
            common::ms(m.rank_exec_long.p99()),
            common::ms(m.wait.p99()),
            common::ms(m.rank_stage_long.p99()),
        ])
    });
    for row in rows {
        t.row(row?);
    }
    t.emit(args)
}

/// Fig. 11d: SLO-compliant throughput per variant (paper: up to 3.6× with
/// full DRAM reuse).
pub fn fig11d(args: &Args) -> Result<()> {
    let (_, dur) = common::durations(args);
    // Threshold 1024 / length 1920: the longest class for which the
    // baseline is still (barely) viable, so the paper's finite "up to
    // 3.6x" ratio is measurable (the gain is length-sensitive — "up to").
    let len = args.get_usize("len", 1920)?;
    let mut t = Table::new(
        "fig11d",
        "SLO-compliant throughput (QPS) per variant at fixed length",
        &["variant", "max_qps", "dram_hit", "vs_baseline"],
    );
    let modes = common::standard_modes();
    let jobs = parallel::jobs_from_args(args)?;
    let cells = parallel::map_indexed(jobs, modes.len(), |i| -> Result<(String, f64, f64)> {
        let mode = modes[i];
        let mut cfg = SimConfig::standard(mode);
        cfg.long_threshold = 1024;
        // Small pool + long-heavy traffic so capacity (not the search
        // ceiling) binds — the paper reports per-special-instance QPS.
        cfg.router.n_instances = 4;
        cfg.router.servers = 4;
        if mode != crate::relay::baseline::Mode::Baseline {
            cfg.router.r2 = 0.5;
        }
        let mut last_hit = 0.0;
        let search = slo::max_qps(
            |q| {
                let mut wl = common::fixed_len_workload_thresh(len, 1024, q, dur, 48);
                wl.long_frac = 0.6; // long-heavy microbench traffic
                let m = common::sim("fig11d", cfg.clone(), &wl).expect("sim");
                last_hit = m.dram_hit_rate();
                m
            },
            5.0,
            3000.0,
            cfg.pipeline.required_success,
            0.05,
        );
        Ok((mode.label(), search.value, last_hit))
    });
    let cells = cells.into_iter().collect::<Result<Vec<_>>>()?;
    let base = cells[0].1.max(1.0); // standard_modes()[0] is Baseline
    for (label, value, hit) in cells {
        t.row(vec![
            label,
            common::qps(value),
            common::pct(hit),
            format!("{:.2}x", value / base),
        ]);
    }
    t.emit(args)
}
