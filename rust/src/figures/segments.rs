//! `relaygr figure segments` — the candidate-segment reuse standing
//! report: segment cache on vs off across all four workload scenarios,
//! in both decision engines — the discrete-event simulator and the
//! serialized reference driver ([`run_reference`]).  Both drive the
//! identical [`RelayCoordinator`](crate::relay::RelayCoordinator), so —
//! as long as the ψ working set fits the carved-down ψ window (true at
//! this figure's loads; under real window pressure the partition *is*
//! contention and ψ outcomes legitimately shift) — enabling the segment
//! cache leaves every per-request
//! [`CacheOutcome`](crate::relay::CacheOutcome) unchanged while strictly
//! lowering mean rank-compute time wherever candidate sets overlap; the
//! figure *asserts* the sim-vs-reference outcome equality per row rather
//! than publishing rows from diverged engines.
//!
//! The run shape mirrors the strict cross-engine test: no DRAM tier, no
//! refresh bursts, T_life beyond the trace — so the ψ decisions are
//! timing-insensitive and any sim-vs-reference difference would be a
//! genuine policy divergence.

use anyhow::{ensure, Result};

use crate::cluster::{run_reference, SimConfig};
use crate::config::{apply_candidate_flags, parse_segment_frac};
use crate::figures::common::{ms, pct, sim, Table};
use crate::metrics::RunMetrics;
use crate::relay::baseline::Mode;
use crate::relay::segment::SegmentStats;
use crate::relay::tier::DramPolicy;
use crate::util::cli::Args;
use crate::util::parallel;
use crate::workload::{ScenarioKind, WorkloadConfig};

fn seg_cells(s: &SegmentStats) -> [String; 4] {
    [
        pct(s.hit_ratio()),
        s.joined.to_string(),
        s.produced.to_string(),
        format!("{:.1}", s.bytes_saved as f64 / 1e6),
    ]
}

/// `relaygr figure segments [--qps N] [--quick] [--scenario s]
/// [--segment-cache f] [--zipf s] [--jobs N]`.
///
/// Each (scenario, segment-cache) cell runs *both* engines — the
/// sim-vs-reference outcome assertion is intra-cell, so cells stay
/// independent and the grid parallelizes on the deterministic executor.
pub fn segments(args: &Args) -> Result<()> {
    let duration_us = if args.has_flag("quick") { 4_000_000 } else { 8_000_000 };
    let qps = args.get_f64("qps", 60.0)?;
    let seed = args.get_u64("seed", 42)?;
    let frac = parse_segment_frac(args, 0.25)?;
    ensure!(frac > 0.0, "figure segments compares reuse on vs off; --segment-cache must be > 0");
    let jobs = parallel::jobs_from_args(args)?;
    let kinds: Vec<ScenarioKind> = match args.get("scenario") {
        Some(s) => vec![ScenarioKind::parse(s).map_err(anyhow::Error::msg)?],
        None => ScenarioKind::NAMES
            .iter()
            .map(|n| ScenarioKind::parse(n).expect("built-in scenario"))
            .collect(),
    };
    let mut cells: Vec<(ScenarioKind, f64)> = Vec::new();
    for kind in &kinds {
        for &f in &[0.0, frac] {
            cells.push((*kind, f));
        }
    }
    let row_pairs = parallel::map_indexed(jobs, cells.len(), |i| -> Result<[Vec<String>; 2]> {
        let (kind, f) = cells[i];
        let mut wl = WorkloadConfig {
            qps,
            duration_us,
            num_users: 30_000,
            fixed_long_len: Some(3072),
            max_prefix: 3072,
            refresh_prob: 0.0,
            scenario: kind,
            seed,
            ..Default::default()
        };
        apply_candidate_flags(args, &mut wl)?;
        let mut cfg = SimConfig::standard(Mode::RelayGr { dram: DramPolicy::Disabled });
        cfg.pipeline.t_life_us = 2 * wl.duration_us;
        cfg.segment_frac = f;
        cfg.log_outcomes = true;
        let m: RunMetrics = sim("segments", cfg.clone(), &wl)?;
        let serial = run_reference(&cfg, &wl)?;
        let mut sim_log = m.outcome_log();
        sim_log.sort_by_key(|&(id, _)| id);
        ensure!(
            sim_log == serial.outcomes,
            "segments: engines diverged on per-request outcomes \
             (scenario {}, segment-cache {f})",
            kind.label()
        );
        let label = if f > 0.0 { format!("{f:.2}") } else { "off".into() };
        let sim_seg = seg_cells(&m.segments);
        let ser_seg = seg_cells(&serial.segments);
        Ok([
            vec![
                kind.label().to_string(),
                label.clone(),
                "sim".into(),
                m.completed.to_string(),
                ms(m.rank_exec.mean()),
                sim_seg[0].clone(),
                sim_seg[1].clone(),
                sim_seg[2].clone(),
                sim_seg[3].clone(),
                "ok".into(),
            ],
            vec![
                kind.label().to_string(),
                label,
                "serial".into(),
                serial.outcomes.len().to_string(),
                ms(serial.mean_rank_us),
                ser_seg[0].clone(),
                ser_seg[1].clone(),
                ser_seg[2].clone(),
                ser_seg[3].clone(),
                "ok".into(),
            ],
        ])
    });
    let mut t = Table::new(
        "segments",
        "candidate-segment reuse on/off × scenarios (simulator + serialized reference)",
        &[
            "scenario", "segcache", "engine", "n", "mean rank ms", "seg hit", "joined",
            "produced", "saved MB", "outcomes",
        ],
    );
    for pair in row_pairs {
        let [sim_row, serial_row] = pair?;
        t.row(sim_row);
        t.row(serial_row);
    }
    t.emit(args)
}
