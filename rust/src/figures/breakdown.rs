//! `relaygr figure breakdown` — the flight-recorder standing report:
//! per-stage latency breakdown (admission, ψ-wait, batch-wait, rank-exec,
//! spill) across the workload scenarios, in both decision engines, with
//! tracing on.
//!
//! Two claims are checked *inside* the figure rather than published on
//! trust:
//!
//! * **Observe-only** — tracing feeds no decision: every scenario runs
//!   the simulator twice, tracing on and off, and asserts the
//!   per-request outcomes are bit-identical.
//! * **Decision-plane identity** — the simulator and the serialized
//!   reference agree per-request on outcomes *and* per-stage fold counts
//!   for every decision-driven stage (admission, batch-wait, rank-exec,
//!   spill).  ψ-wait is the one timing-driven stage: the reference's
//!   instantly-completing host never waits by construction, so its
//!   ψ-wait column is structurally zero and excluded from the count
//!   assertion.
//!
//! Stage *durations* are engine-clock-specific (virtual vs arrival
//! time), so each row carries both engines' quantiles side by side; the
//! row set itself is deterministic — byte-identical across `--jobs`
//! (ordered merge on the deterministic executor) and across repeat runs.

use anyhow::{ensure, Result};

use crate::cluster::SimConfig;
use crate::config::apply_candidate_flags;
use crate::figures::common::{ms, sim, Table};
use crate::relay::baseline::Mode;
use crate::relay::flight::StageBreakdown;
use crate::relay::tier::DramPolicy;
use crate::util::cli::Args;
use crate::util::parallel;
use crate::workload::{ScenarioKind, WorkloadConfig};

/// Span retention for the traced probe runs.  The stage histograms fold
/// on emission (not from retained spans), so the bound only limits the
/// raw-span sidecar, never the breakdown counts.
const TRACE_SPANS: usize = 1 << 16;

/// `relaygr figure breakdown [--qps N] [--quick] [--scenario s]
/// [--jobs N]`.
pub fn breakdown(args: &Args) -> Result<()> {
    let dur = if args.has_flag("quick") { 3_000_000 } else { 8_000_000 };
    let probe_qps = args.get_f64("qps", 60.0)?;
    let seed = args.get_u64("seed", 42)?;
    let jobs = parallel::jobs_from_args(args)?;
    let kinds: Vec<ScenarioKind> = match args.get("scenario") {
        Some(s) => vec![ScenarioKind::parse(s).map_err(anyhow::Error::msg)?],
        None => ScenarioKind::NAMES
            .iter()
            .map(|n| ScenarioKind::parse(n).expect("built-in scenario"))
            .collect(),
    };
    // One cell per scenario; each produces the 5 stage rows.
    let results = parallel::map_indexed(jobs, kinds.len(), |i| -> Result<Vec<Vec<String>>> {
        let kind = kinds[i];
        let mut wl = WorkloadConfig {
            qps: probe_qps,
            duration_us: dur,
            num_users: 30_000,
            fixed_long_len: Some(3072),
            max_prefix: 3072,
            refresh_prob: 0.0,
            scenario: kind,
            seed,
            ..Default::default()
        };
        apply_candidate_flags(args, &mut wl)?;
        let mut cfg = SimConfig::standard(Mode::RelayGr { dram: DramPolicy::Capacity(8 << 30) });
        // Timing-insensitive lifecycle (as in `figure batching`): any
        // sim-vs-reference divergence is a genuine policy difference.
        cfg.pipeline.t_life_us = 2 * dur;
        cfg.log_outcomes = true;

        // Observe-only, asserted: tracing on vs off, decision-identical.
        let plain = sim("breakdown", cfg.clone(), &wl)?;
        cfg.trace_spans = TRACE_SPANS;
        let traced = sim("breakdown", cfg.clone(), &wl)?;
        ensure!(
            plain.outcome_log() == traced.outcome_log(),
            "breakdown: tracing changed decisions (scenario {})",
            kind.label()
        );
        ensure!(
            !traced.stages.is_empty() && plain.stages.is_empty(),
            "breakdown: stage histograms must fold exactly when tracing is on \
             (scenario {})",
            kind.label()
        );

        // Decision-plane identity vs the serialized reference.
        let serial = crate::cluster::run_reference(&cfg, &wl)?;
        let mut sim_log = traced.outcome_log();
        sim_log.sort_by_key(|&(id, _)| id);
        ensure!(
            sim_log == serial.outcomes,
            "breakdown: engines diverged on per-request outcomes (scenario {})",
            kind.label()
        );
        for (name, h_sim, h_ref) in counted_stages(&traced.stages, &serial.stages) {
            ensure!(
                h_sim == h_ref,
                "breakdown: {name} fold count diverged (scenario {}, sim {h_sim} \
                 vs reference {h_ref})",
                kind.label()
            );
        }

        let ref_named = serial.stages.named();
        let rows = traced
            .stages
            .named()
            .iter()
            .zip(ref_named.iter())
            .map(|((name, h), (_, hr))| {
                vec![
                    kind.label().to_string(),
                    name.to_string(),
                    h.count().to_string(),
                    ms(h.p50()),
                    ms(h.p99()),
                    hr.count().to_string(),
                    ms(hr.p50()),
                    ms(hr.p99()),
                    "ok".into(),
                ]
            })
            .collect();
        Ok(rows)
    });
    let mut t = Table::new(
        "breakdown",
        "Per-stage latency breakdown, tracing on (simulator + serialized reference)",
        &[
            "scenario",
            "stage",
            "n",
            "p50 ms",
            "p99 ms",
            "ref n",
            "ref p50 ms",
            "ref p99 ms",
            "checks",
        ],
    );
    t.meta
        .set("trace_spans", TRACE_SPANS.into())
        .set("probe_qps", probe_qps.into())
        .set("duration_s", (dur as f64 / 1e6).into());
    for res in results {
        for row in res? {
            t.row(row);
        }
    }
    t.emit(args)
}

/// The decision-plane stages whose fold counts must agree across
/// engines, as `(name, sim count, reference count)`.  ψ-wait is
/// excluded: it folds only where an engine actually waited, and the
/// serialized reference never waits (instant host).
fn counted_stages(a: &StageBreakdown, b: &StageBreakdown) -> [(&'static str, u64, u64); 4] {
    [
        ("admission", a.admission.count(), b.admission.count()),
        ("batch-wait", a.batch_wait.count(), b.batch_wait.count()),
        ("rank-exec", a.rank_exec.count(), b.rank_exec.count()),
        ("spill", a.spill.count(), b.spill.count()),
    ]
}
