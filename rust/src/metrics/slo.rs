//! Capacity searches defining the paper's headline metrics.
//!
//! * [`max_qps`] — SLO-compliant throughput: the largest offered QPS for
//!   which the run stays compliant (P99 ≤ SLO and success ≥ 99.9%),
//!   found by exponential probing + binary search.
//! * [`max_supported_len`] — maximum supported sequence length: the
//!   largest length bucket whose run is compliant at the given QPS.

use crate::metrics::RunMetrics;

/// Compliance predicate shared by both searches.
pub fn compliant(m: &RunMetrics, required_success: f64) -> bool {
    m.slo_compliant(required_success)
}

/// Compliance on the ranking stage only (Figs. 13a/13d: the binding
/// constraint is the ranking-stage budget).  Applies the same
/// one-failure small-sample allowance as [`RunMetrics::slo_compliant`],
/// counting failures exactly from the integer bucket counts — the float
/// derivation `round(n·(1−fraction_le))` flips compliance either way at
/// the boundary for large n.
pub fn compliant_rank_stage(m: &RunMetrics, budget_us: f64, required_success: f64) -> bool {
    m.rank_stage.p99() <= budget_us
        && crate::metrics::histogram_compliant(&m.rank_stage, budget_us, required_success)
        && crate::metrics::histogram_compliant(&m.rank_stage_long, budget_us, required_success)
}

/// Binary-search the largest QPS in `[lo, hi]` (within relative `tol`)
/// satisfying an arbitrary compliance predicate.
pub fn max_qps_where(
    mut run: impl FnMut(f64) -> RunMetrics,
    lo: f64,
    hi: f64,
    tol: f64,
    ok: impl Fn(&RunMetrics) -> bool,
) -> SearchResult {
    let mut evals = 0u32;
    let mut check = |q: f64, evals: &mut u32| {
        *evals += 1;
        ok(&run(q))
    };
    // If even `lo` fails, report zero capacity.
    if !check(lo, &mut evals) {
        return SearchResult { value: 0.0, evals };
    }
    let (mut good, mut bad) = (lo, hi);
    if check(hi, &mut evals) {
        return SearchResult { value: hi, evals };
    }
    while (bad - good) / good.max(1e-9) > tol {
        let mid = (good + bad) / 2.0;
        if check(mid, &mut evals) {
            good = mid;
        } else {
            bad = mid;
        }
    }
    SearchResult { value: good, evals }
}

/// SLO-compliant throughput under the paper's standard definition.
pub fn max_qps(
    run: impl FnMut(f64) -> RunMetrics,
    lo: f64,
    hi: f64,
    required_success: f64,
    tol: f64,
) -> SearchResult {
    max_qps_where(run, lo, hi, tol, |m| compliant(m, required_success))
}

/// Largest length bucket (from the ascending list) whose run is compliant.
pub fn max_supported_len(
    mut run: impl FnMut(usize) -> RunMetrics,
    lens: &[usize],
    required_success: f64,
) -> SearchResult {
    let mut best = 0usize;
    let mut evals = 0u32;
    for &len in lens {
        evals += 1;
        if compliant(&run(len), required_success) {
            best = len;
        } else {
            break; // latency is monotone in length; stop at first failure
        }
    }
    SearchResult { value: best as f64, evals }
}

/// Search outcome.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SearchResult {
    pub value: f64,
    pub evals: u32,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::relay::pipeline::{CacheOutcome, Lifecycle};

    /// Synthetic run: latency grows with qps; compliant iff qps <= cap.
    fn fake_run(qps: f64, cap: f64) -> RunMetrics {
        let mut m = RunMetrics::new(135_000.0);
        m.sim_duration_us = 1_000_000;
        let lat_ms = if qps <= cap { 100.0 } else { 200.0 };
        for _ in 0..1000 {
            m.record(
                &Lifecycle {
                    request: 0,
                    user: 0,
                    prefix_len: 0,
                    arrival_us: 0,
                    retrieval_done_us: 0,
                    preproc_done_us: 0,
                    rank_start_us: 0,
                    done_us: (lat_ms * 1e3) as u64,
                    pre_us: 0.0,
                    load_us: 0.0,
                    rank_us: 1.0,
                    wait_us: 0.0,
                    outcome: CacheOutcome::FullInference,
                    admitted: false,
                    instance: 0,
                },
                false,
            );
        }
        m
    }

    /// Satellite: SLO boundary behaviour.  Failures are counted exactly
    /// from histogram buckets, and the allowance is exact where
    /// `n·(1−s)` is integral — the float derivations flipped either
    /// side of the boundary.
    #[test]
    fn rank_stage_compliance_boundary_is_exact() {
        let budget = 50_000.0;
        // n·(1−s) exactly integral: 1000 samples at s = 0.998 allow 2.
        let run = |fails: u64| {
            let mut m = RunMetrics::new(135_000.0);
            m.rank_stage.record_n(10_000.0, 1000 - fails);
            m.rank_stage.record_n(1e6, fails);
            m
        };
        assert!(compliant_rank_stage(&run(2), budget, 0.998));
        // ± one sample around the boundary.
        assert!(!compliant_rank_stage(&run(3), budget, 0.998));
        assert!(compliant_rank_stage(&run(1), budget, 0.998));
    }

    /// Large-n regression that fails on the float derivation: at
    /// n = 2^53 + 2 the bucket count loses its low bit through f64, so
    /// `round(n·(1−fraction_le))` reports 2 failures where exactly 1
    /// exists — flipping compliance at a max(1, …) allowance.
    #[test]
    fn rank_stage_compliance_exact_at_float_breaking_n() {
        let budget = 50_000.0;
        let run = |fails: u64| {
            let mut m = RunMetrics::new(135_000.0);
            let n = (1u64 << 53) + 2;
            m.rank_stage.record_n(10_000.0, n - fails);
            m.rank_stage.record_n(1e6, fails);
            // The old derivation drifts on this histogram (pinned in
            // util::stats tests); the compliance verdict must not.
            m
        };
        assert!(compliant_rank_stage(&run(1), budget, 1.0), "exactly at the allowance");
        assert!(!compliant_rank_stage(&run(2), budget, 1.0), "one past the allowance");
    }

    #[test]
    fn allowance_is_exact_where_n_times_failure_rate_is_integral() {
        use crate::metrics::allowed_failures;
        // (1−0.9)·n floats to 0.09999999999999998·n — the raw floor gave
        // n/10 − 1 and quietly tightened the SLO.
        assert_eq!(allowed_failures(20, 0.9), 2);
        assert_eq!(allowed_failures(1000, 0.9), 100);
        assert_eq!(allowed_failures(1000, 0.998), 2);
        // Non-integral products still floor, and the one-failure grace
        // holds at tiny n.
        assert_eq!(allowed_failures(1000, 0.9985), 1);
        assert_eq!(allowed_failures(3, 0.999), 1);
    }

    #[test]
    fn binary_search_converges_to_capacity() {
        let r = max_qps(|q| fake_run(q, 330.0), 1.0, 1000.0, 0.999, 0.02);
        assert!((r.value - 330.0).abs() / 330.0 < 0.03, "found {}", r.value);
        assert!(r.evals < 20);
    }

    #[test]
    fn zero_capacity_when_lo_fails() {
        let r = max_qps(|q| fake_run(q, 0.5), 1.0, 1000.0, 0.999, 0.02);
        assert_eq!(r.value, 0.0);
    }

    #[test]
    fn full_capacity_when_hi_passes() {
        let r = max_qps(|q| fake_run(q, 1e9), 1.0, 1000.0, 0.999, 0.02);
        assert_eq!(r.value, 1000.0);
    }

    #[test]
    fn len_search_stops_at_first_failure() {
        let lens = [1024, 2048, 4096, 8192];
        let r = max_supported_len(|l| fake_run(l as f64, 4096.0), &lens, 0.999);
        assert_eq!(r.value, 4096.0);
        assert_eq!(r.evals, 4); // probed 8192, failed, stopped
        let r0 = max_supported_len(|l| fake_run(l as f64, 100.0), &lens, 0.999);
        assert_eq!(r0.value, 0.0);
    }
}
