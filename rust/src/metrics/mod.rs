//! Run-level metrics: per-request lifecycle aggregation, SLO compliance,
//! and the capacity searches the paper's headline metrics are defined by
//! (§4.1: *maximum supported sequence length* = largest length meeting
//! P99 ≤ SLO with success ≥ 99.9%; *SLO-compliant throughput* = max QPS
//! under the same constraints).

pub mod slo;

use crate::relay::cell::CellReport;
use crate::relay::fault::{FaultKind, FaultReport};
use crate::relay::flight::{FlightRecorder, StageBreakdown};
use crate::relay::hbm::HbmStats;
use crate::relay::hierarchy::HierarchyStats;
use crate::relay::pipeline::{CacheOutcome, Lifecycle};
use crate::relay::segment::SegmentStats;
use crate::relay::trigger::TriggerStats;
use crate::util::stats::{Histogram, Summary};

/// Aggregated results of one simulated or live run.
#[derive(Debug, Clone)]
pub struct RunMetrics {
    /// End-to-end pipeline latency.
    pub e2e: Histogram,
    /// Ranking-stage latency (the binding budget).
    pub rank_stage: Histogram,
    /// Component latencies (Fig. 11c / 13b breakdown).
    pub pre: Histogram,
    pub load: Histogram,
    pub rank_exec: Histogram,
    pub rank_exec_long: Histogram,
    pub wait: Histogram,
    /// Same but only for long-sequence (special-service) requests.
    pub e2e_long: Histogram,
    pub rank_stage_long: Histogram,

    pub completed: u64,
    pub outcome_counts: [u64; 6],
    pub admitted: u64,

    pub hbm: HbmStats,
    /// Tiered-cache flow + per-tier counters (promotion/demotion).
    pub hierarchy: HierarchyStats,
    /// Candidate-segment cache counters (beyond-prefix reuse).
    pub segments: SegmentStats,
    pub trigger: TriggerStats,

    /// Busy-time utilization per instance (0..1), and the special subset.
    pub util: Vec<f64>,
    pub special_instances: Vec<usize>,

    /// Per-cell routing/failure report (one entry per coordinator cell;
    /// single-cell runs report one entry with zero picker activity).
    pub cells: Vec<CellReport>,

    /// Fault-plane counters (injected/retried/recovered/degraded/shed
    /// per kind), merged across cells; all-zero for fault-free runs.
    pub faults: FaultReport,

    pub sim_duration_us: u64,
    /// Total events the simulator dispatched (0 for live runs) — the
    /// numerator of the end-to-end events/sec trajectory in
    /// `bench_simloop`.
    pub sim_events: u64,
    pub offered_qps: f64,
    pub pipeline_slo_us: f64,

    /// Workload scenario label this run served (empty when unknown).
    pub scenario: String,
    /// Per-request outcome capture mode (off by default — see
    /// [`OutcomeRecorder`]).
    pub outcomes: OutcomeRecorder,

    /// Per-stage latency breakdown folded by the flight recorder
    /// (empty unless the run traced with `--trace-spans > 0`).
    pub stages: StageBreakdown,
    /// The detached flight recorder itself (raw spans for `explain` /
    /// RGSP sidecar writing).  `Arc` keeps `RunMetrics: Clone` cheap —
    /// span buffers are shared, never copied.
    pub flight: Option<std::sync::Arc<FlightRecorder>>,
}

/// How [`RunMetrics::record`] captures per-request outcomes.
///
/// The old unconditional `Vec<(u64, CacheOutcome)>` log cost 16 bytes
/// per request and grew with the trace — at 100M requests that is 1.6 GB
/// just to compare two engines.  `Log` bitpacks each record into 8 bytes
/// ([`PackedOutcome`]); `Check` streams against a precomputed reference
/// table with one byte per request id and a capped mismatch list, so the
/// cross-engine comparison itself adds O(1) beyond the shared table.
#[derive(Debug, Clone, Default)]
pub enum OutcomeRecorder {
    /// Aggregate counters only (the default).
    #[default]
    Off,
    /// Append a bitpacked [`PackedOutcome`] per completed request.
    Log(Vec<PackedOutcome>),
    /// Bounded streaming compare against a reference run (see
    /// [`OutcomeCheck`]).
    Check(OutcomeCheck),
}

impl OutcomeRecorder {
    pub fn log() -> OutcomeRecorder {
        OutcomeRecorder::Log(Vec::new())
    }

    pub fn check(expected: std::sync::Arc<Vec<u8>>) -> OutcomeRecorder {
        OutcomeRecorder::Check(OutcomeCheck { expected, seen: 0, mismatches: Vec::new() })
    }
}

/// Bitpacked per-request outcome: request id in the high 61 bits, the
/// 3-bit outcome code in the low bits.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub struct PackedOutcome(u64);

impl PackedOutcome {
    pub fn new(request: u64, outcome: CacheOutcome) -> PackedOutcome {
        debug_assert!(request <= u64::MAX >> 3, "request id overflows packed record");
        PackedOutcome((request << 3) | outcome_index(outcome) as u64)
    }

    pub fn request(self) -> u64 {
        self.0 >> 3
    }

    pub fn outcome(self) -> CacheOutcome {
        outcome_from_index((self.0 & 7) as usize).expect("packed outcome code")
    }

    pub fn unpack(self) -> (u64, CacheOutcome) {
        (self.request(), self.outcome())
    }
}

/// Streaming cross-engine outcome comparison with bounded memory:
/// `expected[id]` holds the reference outcome code + 1 (0 = the
/// reference never completed that id).  Mismatches are capped at
/// [`OutcomeCheck::MAX_MISMATCHES`] — enough to diagnose, O(1) to hold.
#[derive(Debug, Clone)]
pub struct OutcomeCheck {
    expected: std::sync::Arc<Vec<u8>>,
    /// Requests checked so far.
    pub seen: u64,
    pub mismatches: Vec<OutcomeMismatch>,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct OutcomeMismatch {
    pub request: u64,
    /// `None`: the reference run never completed this request id.
    pub expected: Option<CacheOutcome>,
    pub got: CacheOutcome,
}

impl OutcomeCheck {
    pub const MAX_MISMATCHES: usize = 16;

    fn record(&mut self, request: u64, got: CacheOutcome) {
        self.seen += 1;
        let want = self.expected.get(request as usize).copied().unwrap_or(0);
        let matches = want != 0 && outcome_from_index((want - 1) as usize) == Some(got);
        if !matches && self.mismatches.len() < Self::MAX_MISMATCHES {
            let expected = if want == 0 {
                None
            } else {
                outcome_from_index((want - 1) as usize)
            };
            self.mismatches.push(OutcomeMismatch { request, expected, got });
        }
    }

    /// Every reference request seen exactly once with the same outcome?
    pub fn matches(&self) -> bool {
        self.mismatches.is_empty() && self.seen as usize == self.expected.len()
    }
}

/// Dense per-id expected-outcome table for [`OutcomeRecorder::Check`]
/// (request ids are contiguous from 0 in every generated trace): one
/// byte per id, code + 1, 0 = absent.
pub fn outcome_table(pairs: impl IntoIterator<Item = (u64, CacheOutcome)>) -> Vec<u8> {
    let mut table = Vec::new();
    for (id, outcome) in pairs {
        let idx = id as usize;
        if idx >= table.len() {
            table.resize(idx + 1, 0);
        }
        table[idx] = outcome_index(outcome) as u8 + 1;
    }
    table
}

/// Index of an outcome in [`RunMetrics::outcome_counts`] /
/// [`OUTCOME_NAMES`] (shared by the serialized reference engine).
pub fn outcome_index(o: CacheOutcome) -> usize {
    match o {
        CacheOutcome::FullInference => 0,
        CacheOutcome::HbmHit => 1,
        CacheOutcome::DramHit => 2,
        CacheOutcome::JoinedReload => 3,
        CacheOutcome::Fallback => 4,
        CacheOutcome::Shed => 5,
    }
}

/// Inverse of [`outcome_index`].
pub fn outcome_from_index(i: usize) -> Option<CacheOutcome> {
    Some(match i {
        0 => CacheOutcome::FullInference,
        1 => CacheOutcome::HbmHit,
        2 => CacheOutcome::DramHit,
        3 => CacheOutcome::JoinedReload,
        4 => CacheOutcome::Fallback,
        5 => CacheOutcome::Shed,
        _ => return None,
    })
}

pub const OUTCOME_NAMES: [&str; 6] = ["full", "hbm", "dram", "join", "fallback", "shed"];

/// The small-sample failure allowance shared by every compliance check:
/// `max(1, ⌊(1−s)·n⌋)`.  The product is nudged by one relative ulp
/// before flooring so an exactly-integral `(1−s)·n` (e.g. n = 1000 at
/// s = 0.9 → 100) is not floored to 99 by the representation error of
/// `1−s` — the counterpart of counting the failures themselves exactly.
pub fn allowed_failures(n: u64, required_success: f64) -> u64 {
    let x = (1.0 - required_success) * n as f64;
    std::cmp::max(1, (x * (1.0 + 1e-12)).floor() as u64)
}

/// Exact SLO-failure count + allowance check for one latency histogram.
pub(crate) fn histogram_compliant(
    h: &Histogram,
    threshold_us: f64,
    required_success: f64,
) -> bool {
    let n = h.count();
    if n == 0 {
        return true;
    }
    // Count failures exactly from the integer bucket counts: deriving
    // them from `n·(1−fraction_le)` flips compliance either way at the
    // boundary once n is large (double rounding through f64).
    let fails = n - h.count_le(threshold_us);
    fails <= allowed_failures(n, required_success)
}

/// Cache-hit rate among relay-routed long requests: any cache-served
/// outcome (HBM, DRAM, joined reload) over cache-served + fallback +
/// shed.  `counts` is indexed like [`RunMetrics::outcome_counts`].
pub fn relay_hit_rate(counts: &[u64; 6]) -> f64 {
    let hits = counts[1] + counts[2] + counts[3];
    let relayed = hits + counts[4] + counts[5];
    if relayed == 0 {
        0.0
    } else {
        hits as f64 / relayed as f64
    }
}

/// DRAM hit rate among cache-served requests (the paper's "+x%"):
/// DRAM-origin outcomes (reload + join) over all cache-served outcomes.
pub fn dram_hit_rate(counts: &[u64; 6]) -> f64 {
    let hits = counts[2] + counts[3];
    let served = hits + counts[1];
    if served == 0 {
        0.0
    } else {
        hits as f64 / served as f64
    }
}

impl RunMetrics {
    pub fn new(pipeline_slo_us: f64) -> RunMetrics {
        RunMetrics {
            e2e: Histogram::new(),
            rank_stage: Histogram::new(),
            pre: Histogram::new(),
            load: Histogram::new(),
            rank_exec: Histogram::new(),
            rank_exec_long: Histogram::new(),
            wait: Histogram::new(),
            e2e_long: Histogram::new(),
            rank_stage_long: Histogram::new(),
            completed: 0,
            outcome_counts: [0; 6],
            admitted: 0,
            hbm: HbmStats::default(),
            hierarchy: HierarchyStats::default(),
            segments: SegmentStats::default(),
            trigger: TriggerStats::default(),
            util: Vec::new(),
            special_instances: Vec::new(),
            cells: Vec::new(),
            faults: FaultReport::default(),
            sim_duration_us: 0,
            sim_events: 0,
            offered_qps: 0.0,
            pipeline_slo_us,
            scenario: String::new(),
            outcomes: OutcomeRecorder::Off,
            stages: StageBreakdown::default(),
            flight: None,
        }
    }

    /// Decoded per-request outcome log (empty unless the run used
    /// [`OutcomeRecorder::Log`]) — the small-run test/figure view of the
    /// bitpacked records.
    pub fn outcome_log(&self) -> Vec<(u64, CacheOutcome)> {
        match &self.outcomes {
            OutcomeRecorder::Log(log) => log.iter().map(|p| p.unpack()).collect(),
            _ => Vec::new(),
        }
    }

    /// The streaming-compare result, if this run ran with
    /// [`OutcomeRecorder::Check`].
    pub fn outcome_check(&self) -> Option<&OutcomeCheck> {
        match &self.outcomes {
            OutcomeRecorder::Check(c) => Some(c),
            _ => None,
        }
    }

    /// Fold one finished request in.
    pub fn record(&mut self, lc: &Lifecycle, is_long: bool) {
        self.completed += 1;
        self.e2e.record(lc.e2e_us());
        self.rank_stage.record(lc.rank_stage_us());
        if lc.pre_us > 0.0 {
            self.pre.record(lc.pre_us);
        }
        if lc.load_us > 0.0 {
            self.load.record(lc.load_us);
        }
        self.rank_exec.record(lc.rank_us);
        if lc.wait_us > 0.0 {
            self.wait.record(lc.wait_us);
        }
        if is_long {
            self.e2e_long.record(lc.e2e_us());
            self.rank_stage_long.record(lc.rank_stage_us());
            self.rank_exec_long.record(lc.rank_us);
        }
        self.outcome_counts[outcome_index(lc.outcome)] += 1;
        if lc.admitted {
            self.admitted += 1;
        }
        match &mut self.outcomes {
            OutcomeRecorder::Off => {}
            OutcomeRecorder::Log(log) => log.push(PackedOutcome::new(lc.request, lc.outcome)),
            OutcomeRecorder::Check(c) => c.record(lc.request, lc.outcome),
        }
    }

    /// Fraction of requests meeting the pipeline SLO (the paper's success
    /// rate; timeouts are requests beyond the deadline).
    pub fn success_rate(&self) -> f64 {
        self.e2e.fraction_le(self.pipeline_slo_us)
    }

    pub fn success_rate_long(&self) -> f64 {
        if self.e2e_long.count() == 0 {
            1.0
        } else {
            self.e2e_long.fraction_le(self.pipeline_slo_us)
        }
    }

    pub fn p99_e2e(&self) -> f64 {
        self.e2e.p99()
    }

    /// Completed-request throughput, queries/s.
    pub fn goodput_qps(&self) -> f64 {
        if self.sim_duration_us == 0 {
            0.0
        } else {
            self.completed as f64 / (self.sim_duration_us as f64 / 1e6)
        }
    }

    /// Does the run meet the paper's compliance definition (P99 ≤ SLO and
    /// success ≥ 99.9%)?  At simulation sample sizes the rate criterion is
    /// applied with a one-failure allowance so a single outlier among a
    /// few hundred requests does not dominate (the paper's runs have
    /// millions of queries; ⌈0.1%·n⌉ there is ≫ 1).
    pub fn slo_compliant(&self, required_success: f64) -> bool {
        self.p99_e2e() <= self.pipeline_slo_us
            && histogram_compliant(&self.e2e, self.pipeline_slo_us, required_success)
            && histogram_compliant(&self.e2e_long, self.pipeline_slo_us, required_success)
    }

    /// DRAM hit rate among relay-served long requests (the paper's "+x%").
    pub fn dram_hit_rate(&self) -> f64 {
        dram_hit_rate(&self.outcome_counts)
    }

    /// Cache-hit rate among relay-routed long requests.
    pub fn relay_hit_rate(&self) -> f64 {
        relay_hit_rate(&self.outcome_counts)
    }

    /// Mean utilization over an index subset (`None` = all instances) —
    /// computed over the slice in place, no per-call allocation.
    pub fn mean_util(&self, only: Option<&[usize]>) -> f64 {
        let (sum, n) = match only {
            Some(idx) => (idx.iter().map(|&i| self.util[i]).sum::<f64>(), idx.len()),
            None => (self.util.iter().sum::<f64>(), self.util.len()),
        };
        if n == 0 {
            0.0
        } else {
            sum / n as f64
        }
    }

    pub fn special_util(&self) -> f64 {
        self.mean_util(Some(&self.special_instances))
    }

    /// One-line human summary.
    pub fn brief(&self) -> String {
        let scen = if self.scenario.is_empty() {
            String::new()
        } else {
            format!("scenario={} ", self.scenario)
        };
        format!(
            "{scen}n={} qps={:.1} p99={:.1}ms success={:.4} outcomes[{}]",
            self.completed,
            self.goodput_qps(),
            self.p99_e2e() / 1e3,
            self.success_rate(),
            self.outcome_counts
                .iter()
                .zip(OUTCOME_NAMES)
                .map(|(c, n)| format!("{n}:{c}"))
                .collect::<Vec<_>>()
                .join(" "),
        )
    }

    pub fn e2e_summary(&self) -> Summary {
        self.e2e.summary()
    }

    /// One-line admission-adaptation report, present when the closed
    /// loop made decisions this run: headroom trajectory, the windowed
    /// footprint estimate vs the provisioned static bound, and the
    /// occupancy-aware live-cache limit.
    pub fn admission_brief(&self) -> Option<String> {
        let t = self.trigger;
        if t.adapted == 0 {
            return None;
        }
        Some(format!(
            "ADM adaptive        headroom=[{:.2}..{:.2}] fp-est={:.1}MB static-bound={:.1}MB l_max*={} fp-limited={} rate-limited={}",
            t.headroom_milli_min as f64 / 1e3,
            t.headroom_milli_max as f64 / 1e3,
            t.footprint_est_bytes as f64 / 1e6,
            t.footprint_static_bytes as f64 / 1e6,
            t.l_max_effective,
            t.footprint_limited,
            t.rate_limited,
        ))
    }

    /// One line per cache tier — level 0 is the HBM window (with
    /// first-consume vs rapid-re-rank hits split), then every lower tier
    /// with its policy-driven hit/promotion/demotion/eviction counters.
    pub fn tier_report(&self) -> Vec<String> {
        let h = self.hbm;
        let mut out = vec![format!(
            "L0 hbm[lifecycle]   ready={} re-rank={} producing={} miss={} evicted={} lost={}",
            h.ready_hits,
            h.consumed_hits,
            h.producing_hits,
            h.misses,
            h.evicted_consumed + h.evicted_expired,
            h.lost,
        )];
        for (i, t) in self.hierarchy.tiers.iter().enumerate() {
            out.push(format!(
                "L{} tier            hits={} miss={} promoted={} demoted-in={} evicted={} rejected={}",
                i + 1,
                t.hits,
                t.misses,
                t.promotions,
                t.demotions_in,
                t.evictions,
                t.rejected,
            ));
        }
        if self.segments.lookups > 0 {
            let s = self.segments;
            out.push(format!(
                "SEG candidate-cache hit={:.0}% reused={} joined={} produced={} bypassed={} aborted={} saved={:.1}MB",
                s.hit_ratio() * 100.0,
                s.reused + s.promoted,
                s.joined,
                s.produced,
                s.bypassed,
                s.aborted,
                s.bytes_saved as f64 / 1e6,
            ));
        }
        out
    }

    /// One line per coordinator cell: picker traffic split plus the
    /// cross-cell ψ-miss and failure/reload-storm counters.  Empty for
    /// single-cell runs — there is no second cell to route across, so
    /// the line would be all zeros.
    pub fn cells_report(&self) -> Vec<String> {
        if self.cells.len() < 2 {
            return Vec::new();
        }
        self.cells
            .iter()
            .enumerate()
            .map(|(i, c)| {
                format!(
                    "C{} cell            picks={} home={} spilled={} cross={} cross-psi-miss={} failures={} storm-wipes={} migrated={} migration-lost={}",
                    i,
                    c.picks,
                    c.home_picks,
                    c.spilled,
                    c.cross_routes,
                    c.cross_psi_miss,
                    c.failures,
                    c.storm_invalidations,
                    c.migrated,
                    c.migration_lost,
                )
            })
            .collect()
    }

    /// One line per fault kind with activity plus a totals line; empty
    /// when the fault plane never injected (fault-free runs stay quiet).
    pub fn faults_report(&self) -> Vec<String> {
        if !self.faults.any() {
            return Vec::new();
        }
        let f = &self.faults;
        let mut out = Vec::new();
        for k in FaultKind::ALL {
            let i = k.index();
            if f.injected[i] == 0 {
                continue;
            }
            out.push(format!(
                "F  {:<15} injected={} retried={} recovered={} degraded={} shed={}",
                k.name(),
                f.injected[i],
                f.retried[i],
                f.recovered[i],
                f.degraded[i],
                f.shed[i],
            ));
        }
        let (inj, ret, rec, deg, shed) = f.totals();
        out.push(format!(
            "F  total           injected={inj} retried={ret} recovered={rec} degraded={deg} shed={shed}"
        ));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::relay::pipeline::CacheOutcome;

    fn lc(e2e_ms: f64, outcome: CacheOutcome) -> Lifecycle {
        Lifecycle {
            request: 0,
            user: 0,
            prefix_len: 4096,
            arrival_us: 0,
            retrieval_done_us: 10,
            preproc_done_us: 20,
            rank_start_us: 20,
            done_us: (e2e_ms * 1e3) as u64,
            pre_us: 1000.0,
            load_us: 0.0,
            rank_us: 500.0,
            wait_us: 0.0,
            outcome,
            admitted: outcome != CacheOutcome::FullInference,
            instance: 0,
        }
    }

    #[test]
    fn success_rate_and_compliance() {
        let mut m = RunMetrics::new(135_000.0);
        m.sim_duration_us = 1_000_000;
        for _ in 0..998 {
            m.record(&lc(100.0, CacheOutcome::HbmHit), true);
        }
        m.record(&lc(200.0, CacheOutcome::Fallback), true);
        m.record(&lc(200.0, CacheOutcome::Fallback), true);
        assert!((m.success_rate() - 0.998).abs() < 1e-6);
        // 2 failures in 1000: allowed at 99.8%+1-grace, not at 99.99%.
        assert!(m.slo_compliant(0.998));
        assert!(!m.slo_compliant(0.9999));
        assert_eq!(m.completed, 1000);
        assert!((m.goodput_qps() - 1000.0).abs() < 1e-9);
    }

    #[test]
    fn p99_drives_compliance() {
        let mut m = RunMetrics::new(135_000.0);
        m.sim_duration_us = 1_000_000;
        // 2% of traffic above SLO → p99 > SLO → non-compliant.
        for _ in 0..98 {
            m.record(&lc(50.0, CacheOutcome::HbmHit), false);
        }
        for _ in 0..2 {
            m.record(&lc(500.0, CacheOutcome::FullInference), false);
        }
        assert!(!m.slo_compliant(0.9));
    }

    #[test]
    fn dram_hit_rate_counts_joins() {
        let mut m = RunMetrics::new(135_000.0);
        m.record(&lc(50.0, CacheOutcome::HbmHit), true);
        m.record(&lc(50.0, CacheOutcome::DramHit), true);
        m.record(&lc(50.0, CacheOutcome::JoinedReload), true);
        m.record(&lc(50.0, CacheOutcome::FullInference), false);
        assert!((m.dram_hit_rate() - 2.0 / 3.0).abs() < 1e-9);
    }

    #[test]
    fn tier_report_lists_every_level() {
        use crate::relay::tier::TierStats;
        let mut m = RunMetrics::new(1.0);
        m.hbm.ready_hits = 5;
        m.hbm.consumed_hits = 2;
        m.hierarchy.tiers = vec![
            TierStats { hits: 3, promotions: 3, ..Default::default() },
            TierStats { demotions_in: 1, ..Default::default() },
        ];
        let report = m.tier_report();
        assert_eq!(report.len(), 3, "L0 + two lower tiers");
        assert!(report[0].contains("ready=5") && report[0].contains("re-rank=2"));
        assert!(report[1].contains("promoted=3"));
        assert!(report[2].contains("demoted-in=1"));
        // The segment line appears only once the segment cache saw traffic.
        m.segments = SegmentStats {
            lookups: 10,
            reused: 6,
            joined: 1,
            produced: 3,
            bytes_saved: 7 << 20,
            ..Default::default()
        };
        let report = m.tier_report();
        assert_eq!(report.len(), 4);
        assert!(report[3].contains("hit=70%"), "{}", report[3]);
        assert!(report[3].contains("saved=7.3MB"), "{}", report[3]);
    }

    #[test]
    fn admission_brief_present_only_for_adaptive_runs() {
        let mut m = RunMetrics::new(1.0);
        assert!(m.admission_brief().is_none(), "static runs: no adaptation line");
        m.trigger.adapted = 5;
        m.trigger.headroom_milli_min = 520;
        m.trigger.headroom_milli_max = 950;
        m.trigger.footprint_est_bytes = 192 << 20;
        m.trigger.l_max_effective = 6;
        let line = m.admission_brief().unwrap();
        assert!(line.contains("headroom=[0.52..0.95]"), "{line}");
        assert!(line.contains("l_max*=6"), "{line}");
    }

    #[test]
    fn packed_outcomes_round_trip_all_codes() {
        for (i, name) in OUTCOME_NAMES.iter().enumerate() {
            let o = outcome_from_index(i).unwrap();
            assert_eq!(outcome_index(o), i, "{name}");
            let p = PackedOutcome::new(123_456_789, o);
            assert_eq!(p.unpack(), (123_456_789, o), "{name}");
        }
        assert!(outcome_from_index(6).is_none());
        // 8 bytes per record — half the old (u64, CacheOutcome) pair.
        assert_eq!(std::mem::size_of::<PackedOutcome>(), 8);
    }

    #[test]
    fn log_recorder_captures_bitpacked_outcomes() {
        let mut m = RunMetrics::new(135_000.0);
        assert!(m.outcome_log().is_empty(), "off by default");
        m.outcomes = OutcomeRecorder::log();
        let mut a = lc(50.0, CacheOutcome::HbmHit);
        a.request = 7;
        m.record(&a, true);
        let mut b = lc(60.0, CacheOutcome::Fallback);
        b.request = 3;
        m.record(&b, true);
        assert_eq!(
            m.outcome_log(),
            vec![(7, CacheOutcome::HbmHit), (3, CacheOutcome::Fallback)]
        );
    }

    #[test]
    fn streaming_check_matches_and_detects_divergence() {
        let reference =
            vec![(0u64, CacheOutcome::HbmHit), (1, CacheOutcome::FullInference)];
        let table = std::sync::Arc::new(outcome_table(reference));
        // Identical run: matches.
        let mut m = RunMetrics::new(135_000.0);
        m.outcomes = OutcomeRecorder::check(table.clone());
        for (id, o) in [(0u64, CacheOutcome::HbmHit), (1, CacheOutcome::FullInference)] {
            let mut l = lc(50.0, o);
            l.request = id;
            m.record(&l, false);
        }
        let c = m.outcome_check().unwrap();
        assert!(c.matches(), "{:?}", c.mismatches);
        assert_eq!(c.seen, 2);
        // Divergent outcome and an id the reference never completed.
        let mut d = RunMetrics::new(135_000.0);
        d.outcomes = OutcomeRecorder::check(table);
        for (id, o) in [(0u64, CacheOutcome::Fallback), (9, CacheOutcome::HbmHit)] {
            let mut l = lc(50.0, o);
            l.request = id;
            d.record(&l, false);
        }
        let c = d.outcome_check().unwrap();
        assert!(!c.matches());
        assert_eq!(c.mismatches.len(), 2);
        assert_eq!(c.mismatches[0].expected, Some(CacheOutcome::HbmHit));
        assert_eq!(c.mismatches[0].got, CacheOutcome::Fallback);
        assert_eq!(c.mismatches[1].expected, None, "unseen id flagged");
    }

    #[test]
    fn cells_report_only_for_multi_cell_runs() {
        let mut m = RunMetrics::new(1.0);
        m.cells = vec![CellReport { picks: 10, ..Default::default() }];
        assert!(m.cells_report().is_empty(), "single cell: nothing to report");
        m.cells = vec![
            CellReport { picks: 10, home_picks: 9, cross_routes: 1, ..Default::default() },
            CellReport { picks: 5, cross_psi_miss: 2, storm_invalidations: 3, ..Default::default() },
        ];
        let report = m.cells_report();
        assert_eq!(report.len(), 2);
        assert!(report[0].contains("picks=10") && report[0].contains("cross=1"), "{}", report[0]);
        assert!(report[1].contains("cross-psi-miss=2"), "{}", report[1]);
        assert!(report[1].contains("storm-wipes=3"), "{}", report[1]);
    }

    #[test]
    fn util_means() {
        let mut m = RunMetrics::new(1.0);
        m.util = vec![0.2, 0.4, 0.9];
        m.special_instances = vec![2];
        assert!((m.mean_util(None) - 0.5).abs() < 1e-9);
        assert!((m.special_util() - 0.9).abs() < 1e-9);
    }
}
