//! Discrete-event simulator of the production-mirror cluster (§4.1).
//!
//! Simulated entities: ranking instances (normal + special, each one NPU
//! with M model slots and a slice of HBM), servers (shared PCIe link and
//! a CPU core pool — the shared-resource contention of §2.4(3)), the
//! load-balancer/gateway fabric, the behaviour/embedding services
//! (latency only), and the three-stage cascade.  Execution costs come
//! from the calibrated [`HardwareProfile`] cost model.
//!
//! All queuing, affinity, admission and cache-lifecycle *decisions* are
//! made by the shared [`RelayCoordinator`] — the same state machine the
//! live engine drives.  This module is a pure time adapter: it turns
//! coordinator actions into simulated durations on contended resources
//! and reports completions back through the coordinator's event API.
//!
//! Resource discipline: every resource (NPU slot set, PCIe link, CPU
//! pool) is a k-server FIFO — work is assigned to the earliest-free
//! server *when it becomes ready*, which reproduces queuing delay and
//! tail amplification under load without modelling preemption.
//!
//! Hot-path discipline (the relay-race premise — control must cost
//! microseconds next to a tens-of-milliseconds ranking budget):
//!
//! * the event queue is a hierarchical [`TimerWheel`] — O(1) push, exact
//!   `(t, event_seq)` pop order, byte-identical outcomes to the
//!   `BinaryHeap` it replaced;
//! * arrivals stream lazily from the workload's [`ArrivalStream`] — the
//!   trace is never materialized, so memory is O(in-flight requests)
//!   at million-user scale;
//! * per-request state is keyed by the coordinator's generational
//!   [`ReqId`] handles in a dense [`SecondaryMap`], and events carry the
//!   handle (or the whole `Copy` pre-infer job) inline — no hashing, no
//!   per-event allocation.

use crate::cluster::wheel::TimerWheel;
use crate::metrics::RunMetrics;
use crate::model::{BatchMember, HardwareProfile, ModelSpec};
use crate::relay::baseline::Mode;
use crate::relay::cell::{CellConfig, CellPickerKind, CellReq, CellScenario, CellSet};
use crate::relay::fault::FaultConfig;
use crate::relay::coordinator::{
    BatchDecision, CoordinatorConfig, QueuedReload, RankAction, RelayCoordinator, ReqId,
    SignalAction, Stage,
};
use crate::relay::pipeline::{Lifecycle, PipelineConfig, StageSampler};
use crate::relay::router::RouterConfig;
use crate::relay::segment::SegmentConfig;
use crate::relay::tier::{EvictPolicy, TierConfig};
use crate::relay::trigger::{AdmissionConfig, BehaviorMeta, TriggerConfig};
use crate::util::rng::Rng;
use crate::util::slab::SecondaryMap;
use crate::workload::{ArrivalStream, GenRequest, WorkloadConfig};

/// Full simulation configuration.
#[derive(Debug, Clone)]
pub struct SimConfig {
    pub hw: HardwareProfile,
    /// Base model variant; per-request prefix lengths come from the
    /// workload, the spec fixes layers/dim/heads/incr/items.
    pub spec: ModelSpec,
    pub mode: Mode,
    pub router: RouterConfig,
    pub pipeline: PipelineConfig,
    /// NPU model slots per instance (the paper's M).
    pub m_slots: usize,
    /// CPU cores per server for feature/behaviour processing.
    pub cpu_cores: usize,
    /// r1 — HBM fraction reserved for live ψ caches.
    pub r1: f64,
    /// Hierarchy promotion (reload) concurrency cap.
    pub max_reload_concurrency: usize,
    /// Per network hop (LB → gateway → instance).
    pub hop_us: f64,
    /// Requests with prefix above this use the special service.
    pub long_threshold: usize,
    /// P99 prefix length used for kv_p99 in admission control.
    pub kv_p99_prefix: usize,
    /// Admission-control mode + closed-loop knobs (`--admission`).  The
    /// scenario's initial operating point is seeded at run start
    /// (`ScenarioKind::admission_profile`) unless set explicitly.
    pub admission: AdmissionConfig,
    /// Eviction policy for the mode-selected DRAM tier (`--dram-policy`).
    pub dram_policy: EvictPolicy,
    /// Explicit lower-tier stack override (`--tier`); `None` derives a
    /// single tier from the serving mode's DRAM capacity.
    pub tiers: Option<Vec<TierConfig>>,
    /// Fraction of the r1·HBM slice carved out for the candidate-segment
    /// cache (`--segment-cache`; 0 = disabled, PR 2-identical).
    pub segment_frac: f64,
    /// Staleness bound for cached candidate segments.
    pub seg_ttl_us: u64,
    /// Microbatch window for the coordinator's batch former
    /// (`--batch-window`, µs; 0 = unbatched, bit-identical to the
    /// pre-batching event flow).
    pub batch_window_us: u64,
    /// Maximum members per batched rank pass (`--batch-max`).
    pub batch_max: usize,
    /// Coordinator cells (`--cells`; 1 = the single pre-cell pool,
    /// decision-bit-identical to it).  Must divide `router.n_instances`
    /// and `router.servers`.
    pub cells: usize,
    /// Level-1 cell picker (`--cell-picker affinity|spread`).
    pub cell_picker: CellPickerKind,
    /// Affinity locality-vs-load knob (`--cell-spill`; `inf` = pure
    /// locality, never spill off the home cell).
    pub cell_spill: f64,
    /// Scripted cluster churn (`--cell-scenario`).
    pub cell_scenario: CellScenario,
    /// Record the bitpacked per-request outcome log in [`RunMetrics`]
    /// (cross-engine equivalence tests; off by default — it grows with
    /// the trace, 8 bytes/request).
    pub log_outcomes: bool,
    /// Streaming cross-engine compare: check each completed request's
    /// outcome against this reference table (see
    /// [`crate::metrics::outcome_table`]) instead of logging — bounded
    /// memory at any trace length.  Takes precedence over
    /// `log_outcomes`.
    pub outcome_check: Option<std::sync::Arc<Vec<u8>>>,
    /// Flight-recorder span retention (`--trace-spans`; 0 = tracing off).
    /// Observe-only: decisions are bit-identical either way.
    pub trace_spans: usize,
    /// Fault plane (`--faults`; default = no injection, decision-bit-
    /// identical to the fault-free build).  The run seed is folded in
    /// when the coordinator config is derived.
    pub faults: FaultConfig,
    pub seed: u64,
}

impl SimConfig {
    /// A small production-mirror cluster that runs fast while preserving
    /// the paper's ratios (r2 = 0.1, one special instance per server).
    pub fn standard(mode: Mode) -> SimConfig {
        let is_baseline = matches!(mode, Mode::Baseline);
        SimConfig {
            hw: HardwareProfile::ascend_910c(),
            spec: ModelSpec::paper_default(),
            mode,
            router: RouterConfig {
                n_instances: 20,
                servers: 10,
                r2: if is_baseline { 0.0 } else { 0.1 },
                max_special_per_server: 1,
                gateways: 4,
                vnodes: 64,
                normal_policy: crate::relay::router::BalancePolicy::LeastConnections,
            },
            pipeline: PipelineConfig::default(),
            m_slots: 5,
            cpu_cores: 16,
            r1: 0.5,
            max_reload_concurrency: 4,
            hop_us: 150.0,
            long_threshold: 2048,
            kv_p99_prefix: 8192,
            admission: AdmissionConfig::default(),
            dram_policy: EvictPolicy::Lru,
            tiers: None,
            segment_frac: 0.0,
            seg_ttl_us: 3_000_000,
            batch_window_us: 0,
            batch_max: 32,
            cells: 1,
            cell_picker: CellPickerKind::Affinity,
            cell_spill: 2.0,
            cell_scenario: CellScenario::None,
            log_outcomes: false,
            outcome_check: None,
            trace_spans: 0,
            faults: FaultConfig::default(),
            seed: 7,
        }
    }

    fn trigger_config(&self) -> TriggerConfig {
        // Admission keeps planning against the full r1 slice even when a
        // segment partition is carved out of it: the ψ window enforces
        // its (smaller) budget locally, so overcommit under pressure
        // degrades to the handled fallback path instead of silently
        // changing admission behaviour between reuse-on and reuse-off
        // runs — the segment plane must never perturb ψ decisions.
        TriggerConfig {
            rank_p99_budget_us: self.pipeline.rank_budget_us,
            headroom: 0.8,
            t_life_us: self.pipeline.t_life_us,
            kv_p99_bytes: self.spec.kv_bytes_for(self.kv_p99_prefix),
            hbm_bytes: self.hw.hbm_bytes,
            r1: self.r1,
            q_m: 1e6 / self.hw.pre_infer_us(&self.spec, self.kv_p99_prefix.min(4096)),
            m_slots: self.m_slots,
            r2: self.router.r2.max(1e-9),
            n_instances: self.router.n_instances,
            // Filled in by the coordinator from `batch_window_us` and the
            // fault plan's retry pricing.
            batch_window_us: 0,
            retry_budget_us: 0,
            admission: self.admission.clone(),
        }
    }

    /// The lower-tier stack this configuration induces (see
    /// [`Mode::tier_stack`] for the precedence rule).
    pub fn tier_stack(&self) -> Vec<TierConfig> {
        self.mode.tier_stack(self.dram_policy, self.tiers.as_deref())
    }

    /// The coordinator configuration this cluster shape induces.
    pub fn coordinator_config(&self) -> CoordinatorConfig {
        let spec = self.spec;
        CoordinatorConfig {
            mode: self.mode,
            router: self.router.clone(),
            trigger: self.trigger_config(),
            tiers: self.tier_stack(),
            long_threshold: self.long_threshold,
            t_life_us: self.pipeline.t_life_us,
            max_reload_concurrency: self.max_reload_concurrency,
            hbm_bytes: (self.r1 * self.hw.hbm_bytes as f64) as usize,
            dim: self.spec.dim,
            kv_bytes: Box::new(move |prefix_len| spec.kv_bytes_for(prefix_len)),
            segment: SegmentConfig {
                frac: self.segment_frac,
                ttl_us: self.seg_ttl_us,
                seg_bytes: self.spec.segment_bytes(),
                version: 0,
                tiers: Vec::new(),
            },
            batch_window_us: self.batch_window_us,
            batch_max: self.batch_max,
            trace_spans: self.trace_spans,
            faults: {
                // Fold the run seed so identical `--faults` specs draw
                // identically across engines and job counts.
                let mut f = self.faults.clone();
                f.seed = self.seed;
                f
            },
        }
    }

    /// The cluster-shape half of the cell layer.
    pub fn cell_config(&self) -> CellConfig {
        CellConfig {
            cells: self.cells,
            picker: self.cell_picker,
            spill_ratio: self.cell_spill,
            scenario: self.cell_scenario,
            crash: self.faults.crash,
        }
    }

    /// The coordinator configuration for ONE cell: the whole-cluster
    /// shape with the instance and server pools split evenly across
    /// cells (each cell keeps its own gateway fabric).  With
    /// `cells == 1` this IS [`SimConfig::coordinator_config`] — the
    /// pre-cell identity the cross-engine tests pin.
    pub fn cell_coordinator_config(&self) -> CoordinatorConfig {
        let mut per = self.clone();
        per.router.n_instances = self.router.n_instances / self.cells.max(1);
        per.router.servers = self.router.servers / self.cells.max(1);
        per.coordinator_config()
    }

    /// The cost-model latency estimator wired into each special
    /// instance's trigger.
    pub fn estimator(&self) -> crate::relay::trigger::Estimator {
        let hw = self.hw.clone();
        let spec = self.spec;
        Box::new(move |m: &BehaviorMeta| {
            let mut s = spec;
            s.dim = m.dim;
            hw.rank_full_us(&s, m.prefix_len)
        })
    }
}

// ---------------------------------------------------------------------------
// Event machinery
// ---------------------------------------------------------------------------

/// An admitted pre-inference job.  Carried inline in its events — the job
/// lives independently of the request (the rank may complete, by
/// fallback, before the side path finishes), so it must not be keyed by
/// the request's recyclable handle.
#[derive(Debug, Clone, Copy)]
struct PreJob {
    cell: usize,
    /// Cell-local instance index (the coordinator's namespace).
    inst: usize,
    user: u64,
    prefix_len: usize,
    issue_us: u64,
}

#[derive(Debug, Clone, Copy)]
enum Ev {
    /// Inject this arrival and pull the next one from the stream.
    Arrive(GenRequest),
    TriggerCheck(CellReq),
    PreCpuDone { job: PreJob, req: CellReq },
    PreXferDone { job: PreJob, req: CellReq },
    PreInferDone { job: PreJob, req: CellReq },
    RetrievalDone(CellReq),
    PreprocDone(CellReq),
    RankArrive(CellReq),
    RankCpuDone(CellReq),
    RankXferDone(CellReq),
    /// A DRAM→HBM reload of `bytes` finished on `cell`/`inst` for `user`.
    ReloadDone { user: u64, cell: usize, inst: usize, bytes: usize },
    RankExecDone(CellReq),
    /// The microbatch window on `cell`/`inst` closed: flush batch `gen`
    /// (a stale `gen` — already flushed by `Filled` — is a no-op).
    BatchFlush { cell: usize, inst: usize, gen: u64 },
}

/// Per-request timing record (decision state lives in the coordinator).
#[derive(Debug, Clone)]
struct ReqState {
    gen: GenRequest,
    /// Cell-local rank instance (the owning cell is in the [`CellReq`]).
    rank_instance: usize,
    pre_us: f64,
    load_us: f64,
    rank_us: f64,
    retrieval_done: u64,
    preproc_done: u64,
    rank_start: u64,
}

struct Server {
    pcie: [u64; 1],
    cpu: Vec<u64>,
}

/// k-server FIFO: assign to earliest-free server at ready time.
fn alloc(free: &mut [u64], now: u64, dur_us: f64) -> (u64, u64) {
    let (idx, _) = free
        .iter()
        .enumerate()
        .min_by_key(|&(_, &t)| t)
        .expect("resource with zero servers");
    let start = now.max(free[idx]);
    let end = start + dur_us.max(0.0).round() as u64;
    free[idx] = end;
    (start, end)
}

/// The simulator.
pub struct Sim {
    cfg: SimConfig,
    /// Workload shape kept for lazy per-request candidate derivation.
    workload: WorkloadConfig,
    /// Lazy arrival source (the trace is never materialized).
    arrivals: ArrivalStream,
    arrived: u64,
    /// The coordinator shards behind the two-level router.  Decisions
    /// happen per cell; the sim's *resources* stay global, indexed
    /// `cell × per-cell-count + local` (see [`Sim::gi`]).
    cells: CellSet<()>,
    inst_per_cell: usize,
    servers_per_cell: usize,
    /// Per-instance NPU model-slot FIFOs and busy time (global index).
    slots: Vec<Vec<u64>>,
    busy_us: Vec<f64>,
    servers: Vec<Server>,
    /// Per-cell request state: [`ReqId`] slots are per-cell slabs, so
    /// one global map would collide across cells.
    states: Vec<SecondaryMap<ReqState>>,
    /// Recycled candidate-set buffer (the coordinator copies it into the
    /// request's own recycled slot).
    cand_buf: Vec<u64>,
    /// Recycled batch-flush buffers (zero steady-state allocation, like
    /// `cand_buf`): drained members and their cost-model descriptors.
    batch_buf: Vec<ReqId>,
    member_buf: Vec<BatchMember>,
    /// `(time, tie-break seq)`-ordered event queue; events are `Copy` and
    /// stored inline in the wheel's recycled slot vectors.
    events: TimerWheel<Ev>,
    event_seq: u64,
    rng: Rng,
    retrieval: StageSampler,
    preproc: StageSampler,
    metrics: RunMetrics,
    end_us: u64,
}

impl Sim {
    pub fn new(mut cfg: SimConfig, workload: &WorkloadConfig) -> anyhow::Result<Sim> {
        if cfg.cells == 0
            || cfg.router.n_instances % cfg.cells != 0
            || cfg.router.servers % cfg.cells != 0
        {
            anyhow::bail!(
                "--cells {} must be >= 1 and divide both instances {} and servers {}",
                cfg.cells,
                cfg.router.n_instances,
                cfg.router.servers
            );
        }
        // Per-scenario initial operating point for the adaptive admission
        // controller (explicit CLI/config choices win; static ignores it).
        let profile = workload.scenario.admission_profile();
        cfg.admission.seed_operating_point(profile.headroom_init, profile.rate_mult_init);
        let arrivals = crate::workload::stream(workload);
        let coords = (0..cfg.cells)
            .map(|_| RelayCoordinator::new(cfg.cell_coordinator_config(), |_| cfg.estimator()))
            .collect::<anyhow::Result<Vec<_>>>()?;
        let cells = CellSet::new(cfg.cell_config(), coords, workload.duration_us)?;
        let slots = (0..cfg.router.n_instances).map(|_| vec![0u64; cfg.m_slots]).collect();
        let busy_us = vec![0.0; cfg.router.n_instances];
        let servers = (0..cfg.router.servers)
            .map(|_| Server { pcie: [0], cpu: vec![0; cfg.cpu_cores] })
            .collect();
        let retrieval = StageSampler::from_mean_p99(
            cfg.pipeline.retrieval_mean_us,
            cfg.pipeline.retrieval_p99_us,
        );
        let preproc =
            StageSampler::from_mean_p99(cfg.pipeline.preproc_mean_us, cfg.pipeline.preproc_p99_us);
        let mut metrics = RunMetrics::new(cfg.pipeline.pipeline_slo_us);
        metrics.scenario = workload.scenario.label().to_string();
        metrics.outcomes = if let Some(table) = &cfg.outcome_check {
            crate::metrics::OutcomeRecorder::check(table.clone())
        } else if cfg.log_outcomes {
            crate::metrics::OutcomeRecorder::log()
        } else {
            crate::metrics::OutcomeRecorder::Off
        };
        let end_us = workload.duration_us;
        Ok(Sim {
            rng: Rng::new(cfg.seed),
            inst_per_cell: cfg.router.n_instances / cfg.cells,
            servers_per_cell: cfg.router.servers / cfg.cells,
            states: (0..cfg.cells).map(|_| SecondaryMap::new()).collect(),
            cfg,
            workload: workload.clone(),
            arrivals,
            arrived: 0,
            cells,
            slots,
            busy_us,
            servers,
            cand_buf: Vec::new(),
            batch_buf: Vec::new(),
            member_buf: Vec::new(),
            events: TimerWheel::new(),
            event_seq: 0,
            retrieval,
            preproc,
            metrics,
            end_us,
        })
    }

    fn push(&mut self, t: u64, ev: Ev) {
        self.event_seq += 1;
        self.events.push(t, self.event_seq, ev);
    }

    /// Global instance index of a cell-local one (resource arrays).
    fn gi(&self, cell: usize, inst: usize) -> usize {
        cell * self.inst_per_cell + inst
    }

    /// Global server index for a cell-local instance.
    fn server_of(&self, cell: usize, inst: usize) -> usize {
        cell * self.servers_per_cell + self.cells.coord(cell).server_of(inst)
    }

    /// Run to completion and return the metrics.
    pub fn run(mut self) -> RunMetrics {
        if let Some(first) = self.arrivals.next() {
            self.push(first.arrival_us, Ev::Arrive(first));
        }
        while let Some((t, _seq, ev)) = self.events.pop() {
            self.dispatch(t, ev);
        }
        // Finalize utilization (busy over elapsed × slots).
        let elapsed = self.end_us.max(1) as f64;
        self.metrics.util = self
            .busy_us
            .iter()
            .map(|&b| (b / (elapsed * self.cfg.m_slots as f64)).min(1.0))
            .collect();
        // Deterministic cross-cell merge: cell-index order, always.
        let n_cells = self.cells.n_cells();
        self.metrics.special_instances = (0..n_cells)
            .flat_map(|c| {
                let per = self.inst_per_cell;
                self.cells.coord(c).special_instances().iter().map(move |&i| c * per + i)
            })
            .collect();
        let (mut hbm, mut hier, mut trig, mut seg) = (
            self.cells.coord(0).hbm_stats(),
            self.cells.coord(0).hierarchy_stats(),
            self.cells.coord(0).trigger_stats(),
            self.cells.coord(0).segment_stats(),
        );
        let mut faults = self.cells.coord(0).fault_report();
        for c in 1..n_cells {
            hbm.merge(self.cells.coord(c).hbm_stats());
            hier.merge(self.cells.coord(c).hierarchy_stats());
            trig.merge(self.cells.coord(c).trigger_stats());
            seg.merge(self.cells.coord(c).segment_stats());
            faults.merge(&self.cells.coord(c).fault_report());
        }
        self.metrics.faults = faults;
        self.metrics.hbm = hbm;
        self.metrics.hierarchy = hier;
        self.metrics.trigger = trig;
        self.metrics.segments = seg;
        self.metrics.cells = self.cells.reports();
        self.metrics.sim_duration_us = self.end_us;
        self.metrics.sim_events = self.event_seq;
        // Detach the flight recorder (tracing runs only): stage-latency
        // breakdown + raw spans travel with the metrics so the CLI can
        // write the RGSP sidecar and `figure breakdown` can report.
        if let Some(fl) = self.cells.take_flight() {
            self.metrics.stages = fl.breakdown.clone();
            self.metrics.flight = Some(std::sync::Arc::new(fl));
        }
        self.metrics
    }

    fn dispatch(&mut self, now: u64, ev: Ev) {
        match ev {
            Ev::Arrive(gen) => self.on_arrive(now, gen),
            Ev::TriggerCheck(r) => self.on_trigger_check(now, r),
            Ev::PreCpuDone { job, req } => self.on_pre_cpu_done(now, job, req),
            Ev::PreXferDone { job, req } => self.on_pre_xfer_done(now, job, req),
            Ev::PreInferDone { job, req } => self.on_pre_infer_done(now, job, req),
            Ev::RetrievalDone(r) => self.on_retrieval_done(now, r),
            Ev::PreprocDone(r) => self.on_preproc_done(now, r),
            Ev::RankArrive(r) => self.on_rank_arrive(now, r),
            Ev::RankCpuDone(r) => self.on_rank_cpu_done(now, r),
            Ev::RankXferDone(r) => self.on_rank_xfer_done(now, r),
            Ev::ReloadDone { user, cell, inst, bytes } => {
                self.on_reload_done(now, user, cell, inst, bytes)
            }
            Ev::RankExecDone(r) => self.on_rank_exec_done(now, r),
            Ev::BatchFlush { cell, inst, gen } => self.flush_batch(now, cell, inst, gen),
        }
    }

    // ---- pipeline front half ------------------------------------------------

    fn on_arrive(&mut self, now: u64, gen: GenRequest) {
        if let Some(next) = self.arrivals.next() {
            self.push(next.arrival_us, Ev::Arrive(next));
        }
        self.arrived += 1;
        // Candidate sets are only materialised when segment reuse is on
        // (request-keyed RNG stream: never perturbs the arrival trace).
        if self.cells.coord(0).segments_enabled() {
            crate::workload::candidate_set_into(&self.workload, &gen, &mut self.cand_buf);
        } else {
            self.cand_buf.clear();
        }
        let (req, wants_trigger) =
            self.cells.on_arrival(now, gen.rid(), gen.uid(), gen.plen(), &self.cand_buf);
        self.states[req.cell].insert(
            req.id,
            ReqState {
                gen,
                rank_instance: usize::MAX,
                pre_us: 0.0,
                load_us: 0.0,
                rank_us: 0.0,
                retrieval_done: 0,
                preproc_done: 0,
                rank_start: 0,
            },
        );
        let dur = self.retrieval.sample(&mut self.rng);
        self.push(now + dur as u64, Ev::RetrievalDone(req));
        if wants_trigger {
            let t = now + self.cfg.pipeline.trigger_us as u64;
            self.push(t, Ev::TriggerCheck(req));
        }
    }

    fn on_trigger_check(&mut self, now: u64, req: CellReq) {
        match self.cells.coord_mut(req.cell).on_trigger_check(now, req.id) {
            SignalAction::None => {}
            SignalAction::Produce { instance, user, prefix_len } => {
                // Behaviour fetch + CPU feature processing, then H2D, then
                // the prefix pass on an NPU slot.
                let job = PreJob { cell: req.cell, inst: instance, user, prefix_len, issue_us: now };
                let server = self.server_of(req.cell, instance);
                let cpu_dur = self.cfg.hw.feature_proc_us(prefix_len);
                let (_, end) = alloc(&mut self.servers[server].cpu, now, cpu_dur);
                self.push(end, Ev::PreCpuDone { job, req });
            }
            SignalAction::Reload { instance, user, bytes } => {
                let server = self.server_of(req.cell, instance);
                let dur = self.cfg.hw.load_us(bytes);
                let (_, end) = alloc(&mut self.servers[server].pcie, now, dur);
                self.push(end, Ev::ReloadDone { user, cell: req.cell, inst: instance, bytes });
            }
        }
    }

    fn on_pre_cpu_done(&mut self, now: u64, job: PreJob, req: CellReq) {
        let server = self.server_of(job.cell, job.inst);
        let bytes = self.cfg.spec.embed_bytes(job.prefix_len);
        let dur = self.cfg.hw.h2d_embed_us(bytes);
        let (_, end) = alloc(&mut self.servers[server].pcie, now, dur);
        self.push(end, Ev::PreXferDone { job, req });
    }

    fn on_pre_xfer_done(&mut self, now: u64, job: PreJob, req: CellReq) {
        let gi = self.gi(job.cell, job.inst);
        let dur = self.cfg.hw.pre_infer_us(&self.cfg.spec, job.prefix_len);
        let (_, end) = alloc(&mut self.slots[gi], now, dur);
        self.busy_us[gi] += dur;
        self.push(end, Ev::PreInferDone { job, req });
    }

    fn on_pre_infer_done(&mut self, now: u64, job: PreJob, req: CellReq) {
        // The request may already have completed (fallback): the stale
        // generational handle then simply misses.
        if let Some(st) = self.states[req.cell].get_mut(req.id) {
            st.pre_us = (now - job.issue_us) as f64;
        }
        // ψ ready: the coordinator classifies and wakes waiting ranks.
        let woken = self.cells.coord_mut(job.cell).on_psi_ready(now, job.inst, job.user, Some(()));
        for w in woken {
            self.start_rank_processing(now, CellReq { cell: job.cell, id: w });
        }
    }

    fn on_retrieval_done(&mut self, now: u64, req: CellReq) {
        self.states[req.cell].get_mut(req.id).unwrap().retrieval_done = now;
        self.cells.coord_mut(req.cell).on_stage_done(now, req.id, Stage::Retrieval);
        let dur = self.preproc.sample(&mut self.rng);
        self.push(now + dur as u64, Ev::PreprocDone(req));
    }

    fn on_preproc_done(&mut self, now: u64, req: CellReq) {
        // Late binding resolved here: the coordinator routes long-sequence
        // requests (consistency-hash-key) to the special service and short
        // ones by standard balancing.
        let inst = self
            .cells
            .coord_mut(req.cell)
            .on_stage_done(now, req.id, Stage::Preproc)
            .expect("preproc resolves the ranking instance");
        let st = self.states[req.cell].get_mut(req.id).unwrap();
        st.preproc_done = now;
        st.rank_instance = inst;
        let t = now + (2.0 * self.cfg.hop_us) as u64; // LB hop + gateway hop
        self.push(t, Ev::RankArrive(req));
    }

    // ---- ranking at the instance ---------------------------------------------

    fn on_rank_arrive(&mut self, now: u64, req: CellReq) {
        self.states[req.cell].get_mut(req.id).unwrap().rank_start = now;
        match self.cells.coord_mut(req.cell).on_rank_start(now, req.id) {
            RankAction::Proceed { .. } => self.start_rank_processing(now, req),
            // Waiting for ψ production or an in-flight reload: the
            // coordinator wakes the request from `on_psi_ready` /
            // `on_reload_done`.
            RankAction::Wait | RankAction::WaitReload => {}
            RankAction::StartReload { bytes } => {
                let (inst, user) = {
                    let st = self.states[req.cell].get(req.id).unwrap();
                    (st.rank_instance, st.gen.uid())
                };
                let server = self.server_of(req.cell, inst);
                let dur = self.cfg.hw.load_us(bytes);
                let (_, end) = alloc(&mut self.servers[server].pcie, now, dur);
                self.push(end, Ev::ReloadDone { user, cell: req.cell, inst, bytes });
            }
        }
    }

    fn on_reload_done(&mut self, now: u64, user: u64, cell: usize, inst: usize, bytes: usize) {
        let res = self.cells.coord_mut(cell).on_reload_done(now, inst, user, Some(()), bytes);
        let load = self.cfg.hw.load_us(bytes);
        // Wake all requests joined to this reload (≤ 1 H2D per burst).
        for w in res.woken {
            if let Some(st) = self.states[cell].get_mut(w) {
                st.load_us = load;
            }
            self.start_rank_processing(now, CellReq { cell, id: w });
        }
        // Grant the next queued reload its PCIe transfer.
        if let Some(next_user) = res.next {
            self.start_queued_reload(now, cell, inst, next_user);
        }
    }

    fn start_queued_reload(&mut self, now: u64, cell: usize, inst: usize, user: u64) {
        match self.cells.coord_mut(cell).begin_queued_reload(now, inst, user) {
            QueuedReload::Start { bytes } => {
                let server = self.server_of(cell, inst);
                let dur = self.cfg.hw.load_us(bytes);
                let (_, end) = alloc(&mut self.servers[server].pcie, now, dur);
                self.push(end, Ev::ReloadDone { user, cell, inst, bytes });
            }
            QueuedReload::Aborted { woken, next } => {
                // Evicted from DRAM while queued: waiters fall back.
                for w in woken {
                    self.start_rank_processing(now, CellReq { cell, id: w });
                }
                if let Some(nu) = next {
                    self.start_queued_reload(now, cell, inst, nu);
                }
            }
        }
    }

    /// CPU feature processing → H2D → NPU execution for the rank request.
    fn start_rank_processing(&mut self, now: u64, req: CellReq) {
        let inst = self.states[req.cell].get(req.id).unwrap().rank_instance;
        let tokens = self.rank_tokens(req);
        let server = self.server_of(req.cell, inst);
        let dur = self.cfg.hw.feature_proc_us(tokens);
        let (_, end) = alloc(&mut self.servers[server].cpu, now, dur);
        self.push(end, Ev::RankCpuDone(req));
    }

    /// Cached path processes only incremental tokens + items; fallback /
    /// baseline must process the whole sequence on the critical path.
    fn rank_tokens(&self, req: CellReq) -> usize {
        let spec = &self.cfg.spec;
        if self.cells.coord(req.cell).is_cached(req.id) {
            spec.incr_len + spec.num_items
        } else {
            self.states[req.cell].get(req.id).unwrap().gen.plen() + spec.incr_len + spec.num_items
        }
    }

    fn on_rank_cpu_done(&mut self, now: u64, req: CellReq) {
        let inst = self.states[req.cell].get(req.id).unwrap().rank_instance;
        let tokens = self.rank_tokens(req);
        let server = self.server_of(req.cell, inst);
        let dur = self.cfg.hw.h2d_embed_us(self.cfg.spec.embed_bytes(tokens));
        let (_, end) = alloc(&mut self.servers[server].pcie, now, dur);
        self.push(end, Ev::RankXferDone(req));
    }

    fn on_rank_xfer_done(&mut self, now: u64, req: CellReq) {
        // Offer the classified, execution-ready pass to the instance's
        // batch former (coordinator policy).  Window 0 answers `Solo`
        // without touching batch state, keeping the unbatched event
        // sequence bit-identical.
        match self.cells.coord_mut(req.cell).offer_rank(now, req.id) {
            BatchDecision::Solo => self.exec_rank_solo(now, req),
            BatchDecision::Opened { deadline, gen } => {
                let inst = self.states[req.cell].get(req.id).unwrap().rank_instance;
                self.push(deadline, Ev::BatchFlush { cell: req.cell, inst, gen });
            }
            BatchDecision::Joined => {}
            BatchDecision::Filled { gen } => {
                let inst = self.states[req.cell].get(req.id).unwrap().rank_instance;
                self.flush_batch(now, req.cell, inst, gen);
            }
        }
    }

    /// Unbatched rank execution — exactly the pre-batching pricing path.
    fn exec_rank_solo(&mut self, now: u64, req: CellReq) {
        let (inst, prefix_len) = {
            let st = self.states[req.cell].get(req.id).unwrap();
            (st.rank_instance, st.gen.plen())
        };
        // Consume ψ at execution start; segments the plan reuses (or
        // joins — the producer's execution pays) trim the rank compute.
        // With reuse off `skipped` is 0 and the costs are bit-identical
        // to the unsplit model.
        let rc = self.cells.coord_mut(req.cell).rank_compute(now, req.id);
        let skipped = rc.segments.map(|p| p.skipped()).unwrap_or(0);
        let dur = if rc.cached {
            self.cfg.hw.rank_cached_reuse_us(&self.cfg.spec, prefix_len, skipped)
        } else {
            self.cfg.hw.rank_full_reuse_us(&self.cfg.spec, prefix_len, skipped)
        };
        let gi = self.gi(req.cell, inst);
        let (_, end) = alloc(&mut self.slots[gi], now, dur);
        self.busy_us[gi] += dur;
        self.states[req.cell].get_mut(req.id).unwrap().rank_us = dur;
        self.push(end, Ev::RankExecDone(req));
    }

    /// Close batch `gen` on `inst` and run it as one batched rank pass:
    /// plan every member first (co-batched duplicate segments dedup via
    /// the single-flight store), price once with the sub-linear batched
    /// cost, occupy one NPU slot, and complete every member at the
    /// shared end time (`RankExecDone` events in offer order — the
    /// wheel's `(t, seq)` contract keeps completion order deterministic).
    fn flush_batch(&mut self, now: u64, cell: usize, inst: usize, gen: u64) {
        // `close_batch` drains into the recycled buffer; a stale
        // generation (already flushed by `Filled`) is a no-op.
        let mut batch = std::mem::take(&mut self.batch_buf);
        if !self.cells.coord_mut(cell).close_batch(now, inst, gen, &mut batch) {
            self.batch_buf = batch;
            return;
        }
        let mut members = std::mem::take(&mut self.member_buf);
        members.clear();
        let mut skipped = 0;
        for &req in batch.iter() {
            let prefix_len = self.states[cell].get(req).unwrap().gen.plen();
            let rc = self.cells.coord_mut(cell).rank_compute(now, req);
            skipped += rc.segments.map(|p| p.skipped()).unwrap_or(0);
            members.push(BatchMember { cached: rc.cached, prefix_len });
        }
        let dur = self.cfg.hw.rank_batched_us(&self.cfg.spec, &members, skipped);
        let gi = self.gi(cell, inst);
        let (_, end) = alloc(&mut self.slots[gi], now, dur);
        self.busy_us[gi] += dur;
        for &req in batch.iter() {
            self.states[cell].get_mut(req).unwrap().rank_us = dur;
            self.push(end, Ev::RankExecDone(CellReq { cell, id: req }));
        }
        batch.clear();
        self.batch_buf = batch;
        self.member_buf = members;
    }

    fn on_rank_exec_done(&mut self, now: u64, req: CellReq) {
        let st = self.states[req.cell].remove(req.id).unwrap();
        let kv = self.cfg.spec.kv_bytes_for(st.gen.plen());
        let done = self.cells.on_rank_done(now, req, kv);
        // Spill freshly produced caches to DRAM for short-term reuse (off
        // the critical path; occupies the PCIe link).
        if let Some(bytes) = done.spill {
            if self
                .cells
                .coord_mut(req.cell)
                .complete_spill(now, done.instance, done.user, bytes, ())
            {
                let server = self.server_of(req.cell, done.instance);
                let dur = self.cfg.hw.spill_us(bytes);
                let _ = alloc(&mut self.servers[server].pcie, now, dur);
            }
        }
        let lc = Lifecycle {
            request: st.gen.rid(),
            user: st.gen.uid(),
            prefix_len: st.gen.plen(),
            arrival_us: st.gen.arrival_us,
            retrieval_done_us: st.retrieval_done,
            preproc_done_us: st.preproc_done,
            rank_start_us: st.rank_start,
            done_us: now,
            pre_us: st.pre_us,
            load_us: st.load_us,
            rank_us: st.rank_us,
            wait_us: done.wait_us,
            outcome: done.outcome,
            admitted: done.admitted,
            // Global index: unambiguous across cells, value-identical at
            // cells = 1.
            instance: self.gi(req.cell, done.instance),
        };
        self.metrics.record(&lc, done.is_long);
        self.metrics.offered_qps = self.arrived as f64 / (self.end_us as f64 / 1e6);
    }
}

/// Convenience: run one simulation.
pub fn run_sim(cfg: SimConfig, workload: &WorkloadConfig) -> anyhow::Result<RunMetrics> {
    Ok(Sim::new(cfg, workload)?.run())
}
