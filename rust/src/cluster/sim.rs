//! Discrete-event simulator of the production-mirror cluster (§4.1).
//!
//! Simulated entities: ranking instances (normal + special, each one NPU
//! with M model slots and a slice of HBM), servers (shared PCIe link and
//! a CPU core pool — the shared-resource contention of §2.4(3)), the
//! load-balancer/gateway fabric, the behaviour/embedding services
//! (latency only), and the three-stage cascade.  Execution costs come
//! from the calibrated [`HardwareProfile`] cost model; queuing, affinity,
//! admission and cache lifecycle are simulated exactly through the same
//! `relay::*` state machines the live engine uses.
//!
//! Resource discipline: every resource (NPU slot set, PCIe link, CPU
//! pool) is a k-server FIFO — work is assigned to the earliest-free
//! server *when it becomes ready*, which reproduces queuing delay and
//! tail amplification under load without modelling preemption.

use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap};

use crate::util::fxhash::FxHashMap;

use crate::metrics::RunMetrics;
use crate::model::{HardwareProfile, ModelSpec};
use crate::relay::baseline::Mode;
use crate::relay::expander::{DramPolicy, Expander, PseudoAction};
use crate::relay::hbm::HbmCache;
use crate::relay::pipeline::{CacheOutcome, Lifecycle, PipelineConfig, StageSampler};
use crate::relay::router::{Router, RouterConfig};
use crate::relay::trigger::{BehaviorMeta, Decision, Trigger, TriggerConfig};
use crate::util::rng::Rng;
use crate::workload::{GenRequest, WorkloadConfig};

/// Full simulation configuration.
#[derive(Debug, Clone)]
pub struct SimConfig {
    pub hw: HardwareProfile,
    /// Base model variant; per-request prefix lengths come from the
    /// workload, the spec fixes layers/dim/heads/incr/items.
    pub spec: ModelSpec,
    pub mode: Mode,
    pub router: RouterConfig,
    pub pipeline: PipelineConfig,
    /// NPU model slots per instance (the paper's M).
    pub m_slots: usize,
    /// CPU cores per server for feature/behaviour processing.
    pub cpu_cores: usize,
    /// r1 — HBM fraction reserved for live ψ caches.
    pub r1: f64,
    /// Expander reload concurrency cap.
    pub max_reload_concurrency: usize,
    /// Per network hop (LB → gateway → instance).
    pub hop_us: f64,
    /// Requests with prefix above this use the special service.
    pub long_threshold: usize,
    /// P99 prefix length used for kv_p99 in admission control.
    pub kv_p99_prefix: usize,
    pub seed: u64,
}

impl SimConfig {
    /// A small production-mirror cluster that runs fast while preserving
    /// the paper's ratios (r2 = 0.1, one special instance per server).
    pub fn standard(mode: Mode) -> SimConfig {
        let is_baseline = matches!(mode, Mode::Baseline);
        SimConfig {
            hw: HardwareProfile::ascend_910c(),
            spec: ModelSpec::paper_default(),
            mode,
            router: RouterConfig {
                n_instances: 20,
                servers: 10,
                r2: if is_baseline { 0.0 } else { 0.1 },
                max_special_per_server: 1,
                gateways: 4,
                vnodes: 64,
                normal_policy: crate::relay::router::BalancePolicy::LeastConnections,
            },
            pipeline: PipelineConfig::default(),
            m_slots: 5,
            cpu_cores: 16,
            r1: 0.5,
            max_reload_concurrency: 4,
            hop_us: 150.0,
            long_threshold: 2048,
            kv_p99_prefix: 8192,
            seed: 7,
        }
    }

    fn trigger_config(&self) -> TriggerConfig {
        TriggerConfig {
            rank_p99_budget_us: self.pipeline.rank_budget_us,
            headroom: 0.8,
            t_life_us: self.pipeline.t_life_us,
            kv_p99_bytes: self.spec.kv_bytes_for(self.kv_p99_prefix),
            hbm_bytes: self.hw.hbm_bytes,
            r1: self.r1,
            q_m: 1e6 / self.hw.pre_infer_us(&self.spec, self.kv_p99_prefix.min(4096)),
            m_slots: self.m_slots,
            r2: self.router.r2.max(1e-9),
            n_instances: self.router.n_instances,
        }
    }

    fn dram_policy(&self) -> DramPolicy {
        match self.mode {
            Mode::RelayGr { dram } => dram,
            _ => DramPolicy::Disabled,
        }
    }
}

// ---------------------------------------------------------------------------
// Event machinery
// ---------------------------------------------------------------------------

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
enum Ev {
    /// Inject trace\[idx\] and schedule the next injection.
    Arrive(usize),
    TriggerCheck(u64),
    PreCpuDone(u64),
    PreXferDone(u64),
    PreInferDone(u64),
    RetrievalDone(u64),
    PreprocDone(u64),
    RankArrive(u64),
    RankCpuDone(u64),
    RankXferDone(u64),
    /// A DRAM→HBM reload of `bytes` finished on `inst` for `user`.
    ReloadDone { user: u64, inst: usize, bytes: usize },
    RankExecDone(u64),
}

#[derive(Debug, Clone)]
struct ReqState {
    gen: GenRequest,
    is_long: bool,
    admitted: bool,
    pre_instance: Option<usize>,
    rank_instance: usize,
    pre_issue_us: u64,
    pre_us: f64,
    load_us: f64,
    rank_us: f64,
    wait_us: f64,
    wait_since: u64,
    retrieval_done: u64,
    preproc_done: u64,
    rank_start: u64,
    outcome: CacheOutcome,
    /// Whether this request will run ranking-on-cache.
    cached: bool,
}

struct Instance {
    slots: Vec<u64>,
    hbm: HbmCache<()>,
    expander: Expander<()>,
    busy_us: f64,
    /// Rank requests waiting for ψ production to finish, per user.
    waiting_produce: FxHashMap<u64, Vec<u64>>,
    /// Rank requests joined to an in-flight/queued reload, per user.
    waiting_reload: FxHashMap<u64, Vec<u64>>,
    /// Where the currently-resident ψ came from (fresh pre-inference →
    /// `HbmHit`, DRAM reload → `DramHit`): drives the paper's hit-rate
    /// attribution even when a signal-initiated reload pre-warmed HBM.
    origin: FxHashMap<u64, CacheOutcome>,
}

struct Server {
    pcie: [u64; 1],
    cpu: Vec<u64>,
}

/// k-server FIFO: assign to earliest-free server at ready time.
fn alloc(free: &mut [u64], now: u64, dur_us: f64) -> (u64, u64) {
    let (idx, _) = free
        .iter()
        .enumerate()
        .min_by_key(|&(_, &t)| t)
        .expect("resource with zero servers");
    let start = now.max(free[idx]);
    let end = start + dur_us.max(0.0).round() as u64;
    free[idx] = end;
    (start, end)
}

/// An admitted pre-inference job (lives independently of the request:
/// the rank may complete — by fallback — before the side path finishes).
#[derive(Debug, Clone, Copy)]
struct PreJob {
    inst: usize,
    user: u64,
    prefix_len: usize,
    issue_us: u64,
}

/// The simulator.
pub struct Sim {
    cfg: SimConfig,
    trace: Vec<GenRequest>,
    router: Router,
    triggers: HashMap<usize, Trigger>,
    instances: Vec<Instance>,
    servers: Vec<Server>,
    states: FxHashMap<u64, ReqState>,
    pre_jobs: FxHashMap<u64, PreJob>,
    /// (time, tie-break seq, event) — events stored inline (Copy), no
    /// side table (perf: the old `Vec<Ev>` grew unboundedly and cost an
    /// extra indirection per dispatch).
    heap: BinaryHeap<Reverse<(u64, u64, Ev)>>,
    event_seq: u64,
    rng: Rng,
    retrieval: StageSampler,
    preproc: StageSampler,
    metrics: RunMetrics,
    end_us: u64,
}

impl Sim {
    pub fn new(cfg: SimConfig, workload: &WorkloadConfig) -> anyhow::Result<Sim> {
        let trace = crate::workload::generate(workload);
        let router = Router::new(cfg.router.clone())?;
        let tcfg = cfg.trigger_config();
        let hw = cfg.hw.clone();
        let spec = cfg.spec;
        let mut triggers = HashMap::new();
        for &i in router.special_instances() {
            let hw_c = hw.clone();
            let estimator: crate::relay::trigger::Estimator = Box::new(move |m: &BehaviorMeta| {
                let mut s = spec;
                s.dim = m.dim;
                hw_c.rank_full_us(&s, m.prefix_len)
            });
            triggers.insert(i, Trigger::new(tcfg.clone(), estimator));
        }
        let hbm_slice = (cfg.r1 * cfg.hw.hbm_bytes as f64) as usize;
        let dram = cfg.dram_policy();
        let instances = (0..cfg.router.n_instances)
            .map(|_| Instance {
                slots: vec![0; cfg.m_slots],
                hbm: HbmCache::new(hbm_slice),
                expander: Expander::new(dram, cfg.max_reload_concurrency),
                busy_us: 0.0,
                waiting_produce: FxHashMap::default(),
                waiting_reload: FxHashMap::default(),
                origin: FxHashMap::default(),
            })
            .collect();
        let servers = (0..cfg.router.servers)
            .map(|_| Server { pcie: [0], cpu: vec![0; cfg.cpu_cores] })
            .collect();
        let retrieval = StageSampler::from_mean_p99(
            cfg.pipeline.retrieval_mean_us,
            cfg.pipeline.retrieval_p99_us,
        );
        let preproc =
            StageSampler::from_mean_p99(cfg.pipeline.preproc_mean_us, cfg.pipeline.preproc_p99_us);
        let metrics = RunMetrics::new(cfg.pipeline.pipeline_slo_us);
        let end_us = workload.duration_us;
        Ok(Sim {
            rng: Rng::new(cfg.seed),
            cfg,
            trace,
            router,
            triggers,
            instances,
            servers,
            states: FxHashMap::default(),
            pre_jobs: FxHashMap::default(),
            heap: BinaryHeap::new(),
            event_seq: 0,
            retrieval,
            preproc,
            metrics,
            end_us,
        })
    }

    fn push(&mut self, t: u64, ev: Ev) {
        self.event_seq += 1;
        self.heap.push(Reverse((t, self.event_seq, ev)));
    }

    fn server_of(&self, inst: usize) -> usize {
        self.router.server_of(inst)
    }

    /// Run to completion and return the metrics.
    pub fn run(mut self) -> RunMetrics {
        if !self.trace.is_empty() {
            self.push(self.trace[0].arrival_us, Ev::Arrive(0));
        }
        while let Some(Reverse((t, _, ev))) = self.heap.pop() {
            self.dispatch(t, ev);
        }
        // Finalize utilization (busy over elapsed × slots).
        let elapsed = self.end_us.max(1) as f64;
        self.metrics.util = self
            .instances
            .iter()
            .map(|i| (i.busy_us / (elapsed * self.cfg.m_slots as f64)).min(1.0))
            .collect();
        self.metrics.special_instances = self.router.special_instances().to_vec();
        for inst in &self.instances {
            merge_hbm(&mut self.metrics.hbm, inst.hbm.stats());
            merge_expander(&mut self.metrics.expander, inst.expander.stats());
        }
        for tr in self.triggers.values() {
            merge_trigger(&mut self.metrics.trigger, tr.stats());
        }
        self.metrics.sim_duration_us = self.end_us;
        self.metrics
    }

    fn dispatch(&mut self, now: u64, ev: Ev) {
        match ev {
            Ev::Arrive(idx) => self.on_arrive(now, idx),
            Ev::TriggerCheck(r) => self.on_trigger_check(now, r),
            Ev::PreCpuDone(r) => self.on_pre_cpu_done(now, r),
            Ev::PreXferDone(r) => self.on_pre_xfer_done(now, r),
            Ev::PreInferDone(r) => self.on_pre_infer_done(now, r),
            Ev::RetrievalDone(r) => self.on_retrieval_done(now, r),
            Ev::PreprocDone(r) => self.on_preproc_done(now, r),
            Ev::RankArrive(r) => self.on_rank_arrive(now, r),
            Ev::RankCpuDone(r) => self.on_rank_cpu_done(now, r),
            Ev::RankXferDone(r) => self.on_rank_xfer_done(now, r),
            Ev::ReloadDone { user, inst, bytes } => self.on_reload_done(now, user, inst, bytes),
            Ev::RankExecDone(r) => self.on_rank_exec_done(now, r),
        }
    }

    // ---- pipeline front half ------------------------------------------------

    fn on_arrive(&mut self, now: u64, idx: usize) {
        if idx + 1 < self.trace.len() {
            let t = self.trace[idx + 1].arrival_us;
            self.push(t, Ev::Arrive(idx + 1));
        }
        let gen = self.trace[idx];
        let is_long = gen.prefix_len > self.cfg.long_threshold;
        let st = ReqState {
            gen,
            is_long,
            admitted: false,
            pre_instance: None,
            rank_instance: usize::MAX,
            pre_issue_us: 0,
            pre_us: 0.0,
            load_us: 0.0,
            rank_us: 0.0,
            wait_us: 0.0,
            wait_since: 0,
            retrieval_done: 0,
            preproc_done: 0,
            rank_start: 0,
            outcome: CacheOutcome::FullInference,
            cached: false,
        };
        self.states.insert(gen.id, st);
        let dur = self.retrieval.sample(&mut self.rng);
        self.push(now + dur as u64, Ev::RetrievalDone(gen.id));
        if self.cfg.mode.is_relay() && is_long {
            let t = now + self.cfg.pipeline.trigger_us as u64;
            self.push(t, Ev::TriggerCheck(gen.id));
        }
    }

    fn on_trigger_check(&mut self, now: u64, req: u64) {
        let (user, prefix_len, dim) = {
            let st = &self.states[&req];
            (st.gen.user, st.gen.prefix_len, self.cfg.spec.dim)
        };
        let route = self.router.route_special(user);
        self.router.on_complete(route.instance); // signal, not a held connection
        let inst = route.instance;
        let meta = BehaviorMeta { user, prefix_len, dim };
        let decision =
            self.triggers.get_mut(&inst).map(|t| t.decide(now, &meta)).unwrap_or(Decision::NotAtRisk);
        if decision != Decision::Admit {
            return;
        }
        let st = self.states.get_mut(&req).unwrap();
        st.admitted = true;
        st.pre_instance = Some(inst);
        st.pre_issue_us = now;
        self.pre_jobs.insert(req, PreJob { inst, user, prefix_len, issue_us: now });
        // The pre-infer signal itself performs the pseudo-pre-infer checks,
        // skipping redundant recomputation when ψ is already local (§3.4).
        let kv = self.cfg.spec.kv_bytes_for(prefix_len);
        let action = {
            let instance = &mut self.instances[inst];
            instance.expander.pseudo_pre_infer(user, &mut instance.hbm, now)
        };
        match action {
            PseudoAction::HbmHit | PseudoAction::WaitProducing => {
                // Cache already present / being produced: re-arm its
                // lifecycle for this request instead of recomputing.
                self.instances[inst]
                    .hbm
                    .extend_lease(user, now + self.cfg.pipeline.t_life_us);
                if let Some(t) = self.triggers.get_mut(&inst) {
                    t.release(); // no new live cache created by this admit
                }
            }
            PseudoAction::StartReload { bytes } => {
                let server = self.server_of(inst);
                let dur = self.cfg.hw.load_us(bytes);
                let (_, end) = alloc(&mut self.servers[server].pcie, now, dur);
                self.push(end, Ev::ReloadDone { user, inst, bytes });
            }
            PseudoAction::JoinReload | PseudoAction::QueuedReload => {
                // A reload is already pending; the signal needs no follow-up.
            }
            PseudoAction::Miss => {
                let instance = &mut self.instances[inst];
                match instance.hbm.begin_produce(user, kv, now, self.cfg.pipeline.t_life_us) {
                    Ok(()) => {
                        // Behaviour fetch + CPU feature processing.
                        let server = self.server_of(inst);
                        let cpu_dur = self.cfg.hw.feature_proc_us(prefix_len);
                        let (_, end) = alloc(&mut self.servers[server].cpu, now, cpu_dur);
                        self.push(end, Ev::PreCpuDone(req));
                    }
                    Err(_) => {
                        // Admission overcommitted (shouldn't happen when Eqs.
                        // 1-3 hold); treat as not admitted.
                        if let Some(t) = self.triggers.get_mut(&inst) {
                            t.release();
                        }
                        self.states.get_mut(&req).unwrap().admitted = false;
                    }
                }
            }
        }
    }

    fn on_pre_cpu_done(&mut self, now: u64, req: u64) {
        let PreJob { inst, prefix_len, .. } = self.pre_jobs[&req];
        let server = self.server_of(inst);
        let bytes = self.cfg.spec.embed_bytes(prefix_len);
        let dur = self.cfg.hw.h2d_embed_us(bytes);
        let (_, end) = alloc(&mut self.servers[server].pcie, now, dur);
        self.push(end, Ev::PreXferDone(req));
    }

    fn on_pre_xfer_done(&mut self, now: u64, req: u64) {
        let PreJob { inst, prefix_len, .. } = self.pre_jobs[&req];
        let dur = self.cfg.hw.pre_infer_us(&self.cfg.spec, prefix_len);
        let (_, end) = alloc(&mut self.instances[inst].slots, now, dur);
        self.instances[inst].busy_us += dur;
        self.push(end, Ev::PreInferDone(req));
    }

    fn on_pre_infer_done(&mut self, now: u64, req: u64) {
        let PreJob { inst, user, issue_us: issue, .. } =
            self.pre_jobs.remove(&req).expect("pre job exists");
        let ok = self.instances[inst].hbm.complete_produce(user, ());
        if ok {
            self.instances[inst].origin.insert(user, CacheOutcome::HbmHit);
        }
        if let Some(st) = self.states.get_mut(&req) {
            st.pre_us = (now - issue) as f64;
        }
        if !ok {
            // Entry evicted while producing (lost work).
            if let Some(t) = self.triggers.get_mut(&inst) {
                t.release();
            }
        }
        // Wake rank requests waiting for this ψ.
        let waiters = self.instances[inst].waiting_produce.remove(&user).unwrap_or_default();
        for w in waiters {
            let wait_since = self.states[&w].wait_since;
            {
                let st = self.states.get_mut(&w).unwrap();
                st.wait_us += (now - wait_since) as f64;
                if ok {
                    st.outcome = CacheOutcome::HbmHit;
                    st.cached = true;
                } else {
                    st.outcome = CacheOutcome::Fallback;
                    st.cached = false;
                }
            }
            self.start_rank_processing(now, w);
        }
    }

    fn on_retrieval_done(&mut self, now: u64, req: u64) {
        self.states.get_mut(&req).unwrap().retrieval_done = now;
        let dur = self.preproc.sample(&mut self.rng);
        self.push(now + dur as u64, Ev::PreprocDone(req));
    }

    fn on_preproc_done(&mut self, now: u64, req: u64) {
        let (user, is_long) = {
            let st = self.states.get_mut(&req).unwrap();
            st.preproc_done = now;
            (st.gen.user, st.is_long)
        };
        // Late binding resolved here: long-sequence requests carry the
        // consistency-hash-key and go to the special service; short ones
        // follow standard balancing.
        let route = if self.cfg.mode.is_relay() && is_long {
            self.router.route_special(user)
        } else {
            self.router.route_normal(user)
        };
        self.states.get_mut(&req).unwrap().rank_instance = route.instance;
        let t = now + (2.0 * self.cfg.hop_us) as u64; // LB hop + gateway hop
        self.push(t, Ev::RankArrive(req));
    }

    // ---- ranking at the instance ---------------------------------------------

    fn on_rank_arrive(&mut self, now: u64, req: u64) {
        let (inst, user, is_long, admitted) = {
            let st = self.states.get_mut(&req).unwrap();
            st.rank_start = now;
            (st.rank_instance, st.gen.user, st.is_long, st.admitted)
        };
        if !(self.cfg.mode.is_relay() && is_long) {
            // Baseline mode or short-sequence request: full inline inference.
            self.start_rank_processing(now, req);
            return;
        }
        // Pseudo-pre-infer fronting the ranking request (§3.4).
        let action = {
            let instance = &mut self.instances[inst];
            instance.expander.pseudo_pre_infer(user, &mut instance.hbm, now)
        };
        match action {
            PseudoAction::HbmHit => {
                let origin = self.instances[inst]
                    .origin
                    .get(&user)
                    .copied()
                    .unwrap_or(CacheOutcome::HbmHit);
                let st = self.states.get_mut(&req).unwrap();
                st.outcome = origin;
                st.cached = true;
                self.start_rank_processing(now, req);
            }
            PseudoAction::WaitProducing => {
                self.states.get_mut(&req).unwrap().wait_since = now;
                self.instances[inst].waiting_produce.entry(user).or_default().push(req);
            }
            PseudoAction::StartReload { bytes } => {
                {
                    let st = self.states.get_mut(&req).unwrap();
                    st.outcome = CacheOutcome::DramHit;
                    st.cached = true;
                    st.wait_since = now;
                }
                let server = self.server_of(inst);
                let dur = self.cfg.hw.load_us(bytes);
                let (_, end) = alloc(&mut self.servers[server].pcie, now, dur);
                self.instances[inst].waiting_reload.entry(user).or_default().push(req);
                self.push(end, Ev::ReloadDone { user, inst, bytes });
            }
            PseudoAction::JoinReload | PseudoAction::QueuedReload => {
                let st = self.states.get_mut(&req).unwrap();
                st.outcome = CacheOutcome::JoinedReload;
                st.cached = true;
                st.wait_since = now;
                self.instances[inst].waiting_reload.entry(user).or_default().push(req);
            }
            PseudoAction::Miss => {
                let st = self.states.get_mut(&req).unwrap();
                st.outcome =
                    if admitted { CacheOutcome::Fallback } else { CacheOutcome::FullInference };
                st.cached = false;
                self.start_rank_processing(now, req);
            }
        }
    }

    fn on_reload_done(&mut self, now: u64, user: u64, inst: usize, bytes: usize) {
        let done = {
            let instance = &mut self.instances[inst];
            let t_life = self.cfg.pipeline.t_life_us;
            instance.expander.complete_reload(user, (), bytes, now, t_life, &mut instance.hbm)
        };
        if done.installed {
            self.instances[inst].origin.insert(user, CacheOutcome::DramHit);
        }
        let load = self.cfg.hw.load_us(bytes);
        // Wake all requests joined to this reload (≤ 1 H2D per burst).
        let waiters = self.instances[inst].waiting_reload.remove(&user).unwrap_or_default();
        for w in waiters {
            let wait_since = self.states[&w].wait_since;
            {
                let st = self.states.get_mut(&w).unwrap();
                st.wait_us += (now - wait_since) as f64;
                st.load_us = load;
                if !done.installed {
                    st.outcome = CacheOutcome::Fallback;
                    st.cached = false;
                }
            }
            self.start_rank_processing(now, w);
        }
        // Grant the next queued reload its PCIe transfer.
        if let Some(next_user) = done.next {
            self.start_queued_reload(now, inst, next_user);
        }
    }

    fn start_queued_reload(&mut self, now: u64, inst: usize, user: u64) {
        match self.instances[inst].expander.dram_payload(user) {
            Some((bytes, ())) => {
                let server = self.server_of(inst);
                let dur = self.cfg.hw.load_us(bytes);
                let (_, end) = alloc(&mut self.servers[server].pcie, now, dur);
                self.push(end, Ev::ReloadDone { user, inst, bytes });
            }
            None => {
                // Evicted from DRAM while queued: abort and fall back.
                let next = self.instances[inst].expander.abort_reload(user);
                let waiters =
                    self.instances[inst].waiting_reload.remove(&user).unwrap_or_default();
                for w in waiters {
                    let wait_since = self.states[&w].wait_since;
                    let st = self.states.get_mut(&w).unwrap();
                    st.wait_us += (now - wait_since) as f64;
                    st.outcome = CacheOutcome::Fallback;
                    st.cached = false;
                    self.start_rank_processing(now, w);
                }
                if let Some(nu) = next {
                    self.start_queued_reload(now, inst, nu);
                }
            }
        }
    }

    /// CPU feature processing → H2D → NPU execution for the rank request.
    fn start_rank_processing(&mut self, now: u64, req: u64) {
        let (inst, cached, prefix_len) = {
            let st = &self.states[&req];
            (st.rank_instance, st.cached, st.gen.prefix_len)
        };
        let spec = &self.cfg.spec;
        // Cached path processes only incremental tokens + items; fallback /
        // baseline must process the whole sequence on the critical path.
        let tokens = if cached {
            spec.incr_len + spec.num_items
        } else {
            prefix_len + spec.incr_len + spec.num_items
        };
        let server = self.server_of(inst);
        let dur = self.cfg.hw.feature_proc_us(tokens);
        let (_, end) = alloc(&mut self.servers[server].cpu, now, dur);
        self.push(end, Ev::RankCpuDone(req));
    }

    fn on_rank_cpu_done(&mut self, now: u64, req: u64) {
        let (inst, cached, prefix_len) = {
            let st = &self.states[&req];
            (st.rank_instance, st.cached, st.gen.prefix_len)
        };
        let spec = &self.cfg.spec;
        let tokens = if cached {
            spec.incr_len + spec.num_items
        } else {
            prefix_len + spec.incr_len + spec.num_items
        };
        let server = self.server_of(inst);
        let dur = self.cfg.hw.h2d_embed_us(spec.embed_bytes(tokens));
        let (_, end) = alloc(&mut self.servers[server].pcie, now, dur);
        self.push(end, Ev::RankXferDone(req));
    }

    fn on_rank_xfer_done(&mut self, now: u64, req: u64) {
        let (inst, cached, prefix_len, user) = {
            let st = &self.states[&req];
            (st.rank_instance, st.cached, st.gen.prefix_len, st.gen.user)
        };
        let dur = if cached {
            // Consume ψ at execution start.
            self.instances[inst].hbm.consume(user);
            self.cfg.hw.rank_cached_us(&self.cfg.spec, prefix_len)
        } else {
            self.cfg.hw.rank_full_us(&self.cfg.spec, prefix_len)
        };
        let (_, end) = alloc(&mut self.instances[inst].slots, now, dur);
        self.instances[inst].busy_us += dur;
        self.states.get_mut(&req).unwrap().rank_us = dur;
        self.push(end, Ev::RankExecDone(req));
    }

    fn on_rank_exec_done(&mut self, now: u64, req: u64) {
        let st = self.states.remove(&req).unwrap();
        let inst = st.rank_instance;
        self.router.on_complete(inst);
        // Release the admitted live-cache slot.
        if st.admitted {
            if let Some(pre_inst) = st.pre_instance {
                if let Some(t) = self.triggers.get_mut(&pre_inst) {
                    t.release();
                }
            }
        }
        // The sliding window moves past a consumed ψ: spill freshly
        // produced caches to DRAM for short-term reuse (off the critical
        // path; occupies the PCIe link), then evict from HBM.
        if st.cached {
            let kv = self.cfg.spec.kv_bytes_for(st.gen.prefix_len);
            let user = st.gen.user;
            let fresh = self.instances[inst].origin.get(&user) == Some(&CacheOutcome::HbmHit);
            let mut in_dram = !fresh; // reloaded ψ is still resident in DRAM
            if fresh && self.instances[inst].expander.spill(user, kv, ()) {
                let server = self.server_of(inst);
                let dur = self.cfg.hw.spill_us(kv);
                let _ = alloc(&mut self.servers[server].pcie, now, dur);
                in_dram = true;
            }
            // Slide the window past the consumed entry only once the ψ is
            // safe in DRAM; without a DRAM tier it stays Consumed until
            // its lifecycle expires (probe-time reclamation).
            if in_dram
                && self.instances[inst].hbm.state_of(user)
                    == Some(crate::relay::hbm::EntryState::Consumed)
            {
                self.instances[inst].hbm.evict(user);
                self.instances[inst].origin.remove(&user);
            }
        }
        let lc = Lifecycle {
            request: st.gen.id,
            user: st.gen.user,
            prefix_len: st.gen.prefix_len,
            arrival_us: st.gen.arrival_us,
            retrieval_done_us: st.retrieval_done,
            preproc_done_us: st.preproc_done,
            rank_start_us: st.rank_start,
            done_us: now,
            pre_us: st.pre_us,
            load_us: st.load_us,
            rank_us: st.rank_us,
            wait_us: st.wait_us,
            outcome: st.outcome,
            admitted: st.admitted,
            instance: inst,
        };
        self.metrics.record(&lc, st.is_long);
        self.metrics.offered_qps = self.cfg_offered_qps();
    }

    fn cfg_offered_qps(&self) -> f64 {
        self.trace.len() as f64 / (self.end_us as f64 / 1e6)
    }
}

fn merge_hbm(a: &mut crate::relay::hbm::HbmStats, b: crate::relay::hbm::HbmStats) {
    a.inserts += b.inserts;
    a.ready_hits += b.ready_hits;
    a.producing_hits += b.producing_hits;
    a.misses += b.misses;
    a.consumed += b.consumed;
    a.evicted_consumed += b.evicted_consumed;
    a.evicted_expired += b.evicted_expired;
    a.lost += b.lost;
    a.rejected += b.rejected;
}

fn merge_expander(a: &mut crate::relay::expander::ExpanderStats, b: crate::relay::expander::ExpanderStats) {
    a.lookups += b.lookups;
    a.hbm_hits += b.hbm_hits;
    a.dram_hits += b.dram_hits;
    a.misses += b.misses;
    a.reloads_started += b.reloads_started;
    a.reloads_joined += b.reloads_joined;
    a.reloads_queued += b.reloads_queued;
    a.spills += b.spills;
    a.spill_rejected += b.spill_rejected;
    a.dram_evictions += b.dram_evictions;
}

fn merge_trigger(a: &mut crate::relay::trigger::TriggerStats, b: crate::relay::trigger::TriggerStats) {
    a.assessed += b.assessed;
    a.not_at_risk += b.not_at_risk;
    a.admitted += b.admitted;
    a.rate_limited += b.rate_limited;
    a.footprint_limited += b.footprint_limited;
}

/// Convenience: run one simulation.
pub fn run_sim(cfg: SimConfig, workload: &WorkloadConfig) -> anyhow::Result<RunMetrics> {
    Ok(Sim::new(cfg, workload)?.run())
}
