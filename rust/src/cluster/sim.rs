//! Discrete-event simulator of the production-mirror cluster (§4.1).
//!
//! Simulated entities: ranking instances (normal + special, each one NPU
//! with M model slots and a slice of HBM), servers (shared PCIe link and
//! a CPU core pool — the shared-resource contention of §2.4(3)), the
//! load-balancer/gateway fabric, the behaviour/embedding services
//! (latency only), and the three-stage cascade.  Execution costs come
//! from the calibrated [`HardwareProfile`] cost model.
//!
//! All queuing, affinity, admission and cache-lifecycle *decisions* are
//! made by the shared [`RelayCoordinator`] — the same state machine the
//! live engine drives.  This module is a pure time adapter: it turns
//! coordinator actions into simulated durations on contended resources
//! and reports completions back through the coordinator's event API.
//!
//! Resource discipline: every resource (NPU slot set, PCIe link, CPU
//! pool) is a k-server FIFO — work is assigned to the earliest-free
//! server *when it becomes ready*, which reproduces queuing delay and
//! tail amplification under load without modelling preemption.
//!
//! Hot-path discipline (the relay-race premise — control must cost
//! microseconds next to a tens-of-milliseconds ranking budget):
//!
//! * the event queue is a hierarchical [`TimerWheel`] — O(1) push, exact
//!   `(t, event_seq)` pop order, byte-identical outcomes to the
//!   `BinaryHeap` it replaced;
//! * arrivals stream lazily from the workload's [`ArrivalStream`] — the
//!   trace is never materialized, so memory is O(in-flight requests)
//!   at million-user scale;
//! * per-request state is keyed by the coordinator's generational
//!   [`ReqId`] handles in a dense [`SecondaryMap`], and events carry the
//!   handle (or the whole `Copy` pre-infer job) inline — no hashing, no
//!   per-event allocation.

use crate::cluster::wheel::TimerWheel;
use crate::metrics::RunMetrics;
use crate::model::{BatchMember, HardwareProfile, ModelSpec};
use crate::relay::baseline::Mode;
use crate::relay::coordinator::{
    BatchDecision, CoordinatorConfig, QueuedReload, RankAction, RelayCoordinator, ReqId,
    SignalAction, Stage,
};
use crate::relay::pipeline::{Lifecycle, PipelineConfig, StageSampler};
use crate::relay::router::RouterConfig;
use crate::relay::segment::SegmentConfig;
use crate::relay::tier::{EvictPolicy, TierConfig};
use crate::relay::trigger::{AdmissionConfig, BehaviorMeta, TriggerConfig};
use crate::util::rng::Rng;
use crate::util::slab::SecondaryMap;
use crate::workload::{ArrivalStream, GenRequest, WorkloadConfig};

/// Full simulation configuration.
#[derive(Debug, Clone)]
pub struct SimConfig {
    pub hw: HardwareProfile,
    /// Base model variant; per-request prefix lengths come from the
    /// workload, the spec fixes layers/dim/heads/incr/items.
    pub spec: ModelSpec,
    pub mode: Mode,
    pub router: RouterConfig,
    pub pipeline: PipelineConfig,
    /// NPU model slots per instance (the paper's M).
    pub m_slots: usize,
    /// CPU cores per server for feature/behaviour processing.
    pub cpu_cores: usize,
    /// r1 — HBM fraction reserved for live ψ caches.
    pub r1: f64,
    /// Hierarchy promotion (reload) concurrency cap.
    pub max_reload_concurrency: usize,
    /// Per network hop (LB → gateway → instance).
    pub hop_us: f64,
    /// Requests with prefix above this use the special service.
    pub long_threshold: usize,
    /// P99 prefix length used for kv_p99 in admission control.
    pub kv_p99_prefix: usize,
    /// Admission-control mode + closed-loop knobs (`--admission`).  The
    /// scenario's initial operating point is seeded at run start
    /// (`ScenarioKind::admission_profile`) unless set explicitly.
    pub admission: AdmissionConfig,
    /// Eviction policy for the mode-selected DRAM tier (`--dram-policy`).
    pub dram_policy: EvictPolicy,
    /// Explicit lower-tier stack override (`--tier`); `None` derives a
    /// single tier from the serving mode's DRAM capacity.
    pub tiers: Option<Vec<TierConfig>>,
    /// Fraction of the r1·HBM slice carved out for the candidate-segment
    /// cache (`--segment-cache`; 0 = disabled, PR 2-identical).
    pub segment_frac: f64,
    /// Staleness bound for cached candidate segments.
    pub seg_ttl_us: u64,
    /// Microbatch window for the coordinator's batch former
    /// (`--batch-window`, µs; 0 = unbatched, bit-identical to the
    /// pre-batching event flow).
    pub batch_window_us: u64,
    /// Maximum members per batched rank pass (`--batch-max`).
    pub batch_max: usize,
    /// Record the bitpacked per-request outcome log in [`RunMetrics`]
    /// (cross-engine equivalence tests; off by default — it grows with
    /// the trace, 8 bytes/request).
    pub log_outcomes: bool,
    /// Streaming cross-engine compare: check each completed request's
    /// outcome against this reference table (see
    /// [`crate::metrics::outcome_table`]) instead of logging — bounded
    /// memory at any trace length.  Takes precedence over
    /// `log_outcomes`.
    pub outcome_check: Option<std::sync::Arc<Vec<u8>>>,
    /// Flight-recorder span retention (`--trace-spans`; 0 = tracing off).
    /// Observe-only: decisions are bit-identical either way.
    pub trace_spans: usize,
    pub seed: u64,
}

impl SimConfig {
    /// A small production-mirror cluster that runs fast while preserving
    /// the paper's ratios (r2 = 0.1, one special instance per server).
    pub fn standard(mode: Mode) -> SimConfig {
        let is_baseline = matches!(mode, Mode::Baseline);
        SimConfig {
            hw: HardwareProfile::ascend_910c(),
            spec: ModelSpec::paper_default(),
            mode,
            router: RouterConfig {
                n_instances: 20,
                servers: 10,
                r2: if is_baseline { 0.0 } else { 0.1 },
                max_special_per_server: 1,
                gateways: 4,
                vnodes: 64,
                normal_policy: crate::relay::router::BalancePolicy::LeastConnections,
            },
            pipeline: PipelineConfig::default(),
            m_slots: 5,
            cpu_cores: 16,
            r1: 0.5,
            max_reload_concurrency: 4,
            hop_us: 150.0,
            long_threshold: 2048,
            kv_p99_prefix: 8192,
            admission: AdmissionConfig::default(),
            dram_policy: EvictPolicy::Lru,
            tiers: None,
            segment_frac: 0.0,
            seg_ttl_us: 3_000_000,
            batch_window_us: 0,
            batch_max: 32,
            log_outcomes: false,
            outcome_check: None,
            trace_spans: 0,
            seed: 7,
        }
    }

    fn trigger_config(&self) -> TriggerConfig {
        // Admission keeps planning against the full r1 slice even when a
        // segment partition is carved out of it: the ψ window enforces
        // its (smaller) budget locally, so overcommit under pressure
        // degrades to the handled fallback path instead of silently
        // changing admission behaviour between reuse-on and reuse-off
        // runs — the segment plane must never perturb ψ decisions.
        TriggerConfig {
            rank_p99_budget_us: self.pipeline.rank_budget_us,
            headroom: 0.8,
            t_life_us: self.pipeline.t_life_us,
            kv_p99_bytes: self.spec.kv_bytes_for(self.kv_p99_prefix),
            hbm_bytes: self.hw.hbm_bytes,
            r1: self.r1,
            q_m: 1e6 / self.hw.pre_infer_us(&self.spec, self.kv_p99_prefix.min(4096)),
            m_slots: self.m_slots,
            r2: self.router.r2.max(1e-9),
            n_instances: self.router.n_instances,
            admission: self.admission.clone(),
        }
    }

    /// The lower-tier stack this configuration induces (see
    /// [`Mode::tier_stack`] for the precedence rule).
    pub fn tier_stack(&self) -> Vec<TierConfig> {
        self.mode.tier_stack(self.dram_policy, self.tiers.as_deref())
    }

    /// The coordinator configuration this cluster shape induces.
    pub fn coordinator_config(&self) -> CoordinatorConfig {
        let spec = self.spec;
        CoordinatorConfig {
            mode: self.mode,
            router: self.router.clone(),
            trigger: self.trigger_config(),
            tiers: self.tier_stack(),
            long_threshold: self.long_threshold,
            t_life_us: self.pipeline.t_life_us,
            max_reload_concurrency: self.max_reload_concurrency,
            hbm_bytes: (self.r1 * self.hw.hbm_bytes as f64) as usize,
            dim: self.spec.dim,
            kv_bytes: Box::new(move |prefix_len| spec.kv_bytes_for(prefix_len)),
            segment: SegmentConfig {
                frac: self.segment_frac,
                ttl_us: self.seg_ttl_us,
                seg_bytes: self.spec.segment_bytes(),
                version: 0,
                tiers: Vec::new(),
            },
            batch_window_us: self.batch_window_us,
            batch_max: self.batch_max,
            trace_spans: self.trace_spans,
        }
    }

    /// The cost-model latency estimator wired into each special
    /// instance's trigger.
    pub fn estimator(&self) -> crate::relay::trigger::Estimator {
        let hw = self.hw.clone();
        let spec = self.spec;
        Box::new(move |m: &BehaviorMeta| {
            let mut s = spec;
            s.dim = m.dim;
            hw.rank_full_us(&s, m.prefix_len)
        })
    }
}

// ---------------------------------------------------------------------------
// Event machinery
// ---------------------------------------------------------------------------

/// An admitted pre-inference job.  Carried inline in its events — the job
/// lives independently of the request (the rank may complete, by
/// fallback, before the side path finishes), so it must not be keyed by
/// the request's recyclable handle.
#[derive(Debug, Clone, Copy)]
struct PreJob {
    inst: usize,
    user: u64,
    prefix_len: usize,
    issue_us: u64,
}

#[derive(Debug, Clone, Copy)]
enum Ev {
    /// Inject this arrival and pull the next one from the stream.
    Arrive(GenRequest),
    TriggerCheck(ReqId),
    PreCpuDone { job: PreJob, req: ReqId },
    PreXferDone { job: PreJob, req: ReqId },
    PreInferDone { job: PreJob, req: ReqId },
    RetrievalDone(ReqId),
    PreprocDone(ReqId),
    RankArrive(ReqId),
    RankCpuDone(ReqId),
    RankXferDone(ReqId),
    /// A DRAM→HBM reload of `bytes` finished on `inst` for `user`.
    ReloadDone { user: u64, inst: usize, bytes: usize },
    RankExecDone(ReqId),
    /// The microbatch window on `inst` closed: flush batch `gen` (a
    /// stale `gen` — already flushed by `Filled` — is a no-op).
    BatchFlush { inst: usize, gen: u64 },
}

/// Per-request timing record (decision state lives in the coordinator).
#[derive(Debug, Clone)]
struct ReqState {
    gen: GenRequest,
    rank_instance: usize,
    pre_us: f64,
    load_us: f64,
    rank_us: f64,
    retrieval_done: u64,
    preproc_done: u64,
    rank_start: u64,
}

struct Server {
    pcie: [u64; 1],
    cpu: Vec<u64>,
}

/// k-server FIFO: assign to earliest-free server at ready time.
fn alloc(free: &mut [u64], now: u64, dur_us: f64) -> (u64, u64) {
    let (idx, _) = free
        .iter()
        .enumerate()
        .min_by_key(|&(_, &t)| t)
        .expect("resource with zero servers");
    let start = now.max(free[idx]);
    let end = start + dur_us.max(0.0).round() as u64;
    free[idx] = end;
    (start, end)
}

/// The simulator.
pub struct Sim {
    cfg: SimConfig,
    /// Workload shape kept for lazy per-request candidate derivation.
    workload: WorkloadConfig,
    /// Lazy arrival source (the trace is never materialized).
    arrivals: ArrivalStream,
    arrived: u64,
    coord: RelayCoordinator<()>,
    /// Per-instance NPU model-slot FIFOs and busy time.
    slots: Vec<Vec<u64>>,
    busy_us: Vec<f64>,
    servers: Vec<Server>,
    states: SecondaryMap<ReqState>,
    /// Recycled candidate-set buffer (the coordinator copies it into the
    /// request's own recycled slot).
    cand_buf: Vec<u64>,
    /// Recycled batch-flush buffers (zero steady-state allocation, like
    /// `cand_buf`): drained members and their cost-model descriptors.
    batch_buf: Vec<ReqId>,
    member_buf: Vec<BatchMember>,
    /// `(time, tie-break seq)`-ordered event queue; events are `Copy` and
    /// stored inline in the wheel's recycled slot vectors.
    events: TimerWheel<Ev>,
    event_seq: u64,
    rng: Rng,
    retrieval: StageSampler,
    preproc: StageSampler,
    metrics: RunMetrics,
    end_us: u64,
}

impl Sim {
    pub fn new(mut cfg: SimConfig, workload: &WorkloadConfig) -> anyhow::Result<Sim> {
        // Per-scenario initial operating point for the adaptive admission
        // controller (explicit CLI/config choices win; static ignores it).
        let profile = workload.scenario.admission_profile();
        cfg.admission.seed_operating_point(profile.headroom_init, profile.rate_mult_init);
        let arrivals = crate::workload::stream(workload);
        let coord = RelayCoordinator::new(cfg.coordinator_config(), |_| cfg.estimator())?;
        let slots = (0..cfg.router.n_instances).map(|_| vec![0u64; cfg.m_slots]).collect();
        let busy_us = vec![0.0; cfg.router.n_instances];
        let servers = (0..cfg.router.servers)
            .map(|_| Server { pcie: [0], cpu: vec![0; cfg.cpu_cores] })
            .collect();
        let retrieval = StageSampler::from_mean_p99(
            cfg.pipeline.retrieval_mean_us,
            cfg.pipeline.retrieval_p99_us,
        );
        let preproc =
            StageSampler::from_mean_p99(cfg.pipeline.preproc_mean_us, cfg.pipeline.preproc_p99_us);
        let mut metrics = RunMetrics::new(cfg.pipeline.pipeline_slo_us);
        metrics.scenario = workload.scenario.label().to_string();
        metrics.outcomes = if let Some(table) = &cfg.outcome_check {
            crate::metrics::OutcomeRecorder::check(table.clone())
        } else if cfg.log_outcomes {
            crate::metrics::OutcomeRecorder::log()
        } else {
            crate::metrics::OutcomeRecorder::Off
        };
        let end_us = workload.duration_us;
        Ok(Sim {
            rng: Rng::new(cfg.seed),
            cfg,
            workload: workload.clone(),
            arrivals,
            arrived: 0,
            coord,
            slots,
            busy_us,
            servers,
            states: SecondaryMap::new(),
            cand_buf: Vec::new(),
            batch_buf: Vec::new(),
            member_buf: Vec::new(),
            events: TimerWheel::new(),
            event_seq: 0,
            retrieval,
            preproc,
            metrics,
            end_us,
        })
    }

    fn push(&mut self, t: u64, ev: Ev) {
        self.event_seq += 1;
        self.events.push(t, self.event_seq, ev);
    }

    fn server_of(&self, inst: usize) -> usize {
        self.coord.server_of(inst)
    }

    /// Run to completion and return the metrics.
    pub fn run(mut self) -> RunMetrics {
        if let Some(first) = self.arrivals.next() {
            self.push(first.arrival_us, Ev::Arrive(first));
        }
        while let Some((t, _seq, ev)) = self.events.pop() {
            self.dispatch(t, ev);
        }
        // Finalize utilization (busy over elapsed × slots).
        let elapsed = self.end_us.max(1) as f64;
        self.metrics.util = self
            .busy_us
            .iter()
            .map(|&b| (b / (elapsed * self.cfg.m_slots as f64)).min(1.0))
            .collect();
        self.metrics.special_instances = self.coord.special_instances().to_vec();
        self.metrics.hbm = self.coord.hbm_stats();
        self.metrics.hierarchy = self.coord.hierarchy_stats();
        self.metrics.trigger = self.coord.trigger_stats();
        self.metrics.segments = self.coord.segment_stats();
        self.metrics.sim_duration_us = self.end_us;
        self.metrics.sim_events = self.event_seq;
        // Detach the flight recorder (tracing runs only): stage-latency
        // breakdown + raw spans travel with the metrics so the CLI can
        // write the RGSP sidecar and `figure breakdown` can report.
        if let Some(fl) = self.coord.take_flight() {
            self.metrics.stages = fl.breakdown.clone();
            self.metrics.flight = Some(std::sync::Arc::new(fl));
        }
        self.metrics
    }

    fn dispatch(&mut self, now: u64, ev: Ev) {
        match ev {
            Ev::Arrive(gen) => self.on_arrive(now, gen),
            Ev::TriggerCheck(r) => self.on_trigger_check(now, r),
            Ev::PreCpuDone { job, req } => self.on_pre_cpu_done(now, job, req),
            Ev::PreXferDone { job, req } => self.on_pre_xfer_done(now, job, req),
            Ev::PreInferDone { job, req } => self.on_pre_infer_done(now, job, req),
            Ev::RetrievalDone(r) => self.on_retrieval_done(now, r),
            Ev::PreprocDone(r) => self.on_preproc_done(now, r),
            Ev::RankArrive(r) => self.on_rank_arrive(now, r),
            Ev::RankCpuDone(r) => self.on_rank_cpu_done(now, r),
            Ev::RankXferDone(r) => self.on_rank_xfer_done(now, r),
            Ev::ReloadDone { user, inst, bytes } => self.on_reload_done(now, user, inst, bytes),
            Ev::RankExecDone(r) => self.on_rank_exec_done(now, r),
            Ev::BatchFlush { inst, gen } => self.flush_batch(now, inst, gen),
        }
    }

    // ---- pipeline front half ------------------------------------------------

    fn on_arrive(&mut self, now: u64, gen: GenRequest) {
        if let Some(next) = self.arrivals.next() {
            self.push(next.arrival_us, Ev::Arrive(next));
        }
        self.arrived += 1;
        // Candidate sets are only materialised when segment reuse is on
        // (request-keyed RNG stream: never perturbs the arrival trace).
        if self.coord.segments_enabled() {
            crate::workload::candidate_set_into(&self.workload, &gen, &mut self.cand_buf);
        } else {
            self.cand_buf.clear();
        }
        let (req, wants_trigger) =
            self.coord.on_arrival(now, gen.rid(), gen.uid(), gen.plen(), &self.cand_buf);
        self.states.insert(
            req,
            ReqState {
                gen,
                rank_instance: usize::MAX,
                pre_us: 0.0,
                load_us: 0.0,
                rank_us: 0.0,
                retrieval_done: 0,
                preproc_done: 0,
                rank_start: 0,
            },
        );
        let dur = self.retrieval.sample(&mut self.rng);
        self.push(now + dur as u64, Ev::RetrievalDone(req));
        if wants_trigger {
            let t = now + self.cfg.pipeline.trigger_us as u64;
            self.push(t, Ev::TriggerCheck(req));
        }
    }

    fn on_trigger_check(&mut self, now: u64, req: ReqId) {
        match self.coord.on_trigger_check(now, req) {
            SignalAction::None => {}
            SignalAction::Produce { instance, user, prefix_len } => {
                // Behaviour fetch + CPU feature processing, then H2D, then
                // the prefix pass on an NPU slot.
                let job = PreJob { inst: instance, user, prefix_len, issue_us: now };
                let server = self.server_of(instance);
                let cpu_dur = self.cfg.hw.feature_proc_us(prefix_len);
                let (_, end) = alloc(&mut self.servers[server].cpu, now, cpu_dur);
                self.push(end, Ev::PreCpuDone { job, req });
            }
            SignalAction::Reload { instance, user, bytes } => {
                let server = self.server_of(instance);
                let dur = self.cfg.hw.load_us(bytes);
                let (_, end) = alloc(&mut self.servers[server].pcie, now, dur);
                self.push(end, Ev::ReloadDone { user, inst: instance, bytes });
            }
        }
    }

    fn on_pre_cpu_done(&mut self, now: u64, job: PreJob, req: ReqId) {
        let server = self.server_of(job.inst);
        let bytes = self.cfg.spec.embed_bytes(job.prefix_len);
        let dur = self.cfg.hw.h2d_embed_us(bytes);
        let (_, end) = alloc(&mut self.servers[server].pcie, now, dur);
        self.push(end, Ev::PreXferDone { job, req });
    }

    fn on_pre_xfer_done(&mut self, now: u64, job: PreJob, req: ReqId) {
        let dur = self.cfg.hw.pre_infer_us(&self.cfg.spec, job.prefix_len);
        let (_, end) = alloc(&mut self.slots[job.inst], now, dur);
        self.busy_us[job.inst] += dur;
        self.push(end, Ev::PreInferDone { job, req });
    }

    fn on_pre_infer_done(&mut self, now: u64, job: PreJob, req: ReqId) {
        // The request may already have completed (fallback): the stale
        // generational handle then simply misses.
        if let Some(st) = self.states.get_mut(req) {
            st.pre_us = (now - job.issue_us) as f64;
        }
        // ψ ready: the coordinator classifies and wakes waiting ranks.
        let woken = self.coord.on_psi_ready(now, job.inst, job.user, Some(()));
        for w in woken {
            self.start_rank_processing(now, w);
        }
    }

    fn on_retrieval_done(&mut self, now: u64, req: ReqId) {
        self.states.get_mut(req).unwrap().retrieval_done = now;
        self.coord.on_stage_done(now, req, Stage::Retrieval);
        let dur = self.preproc.sample(&mut self.rng);
        self.push(now + dur as u64, Ev::PreprocDone(req));
    }

    fn on_preproc_done(&mut self, now: u64, req: ReqId) {
        // Late binding resolved here: the coordinator routes long-sequence
        // requests (consistency-hash-key) to the special service and short
        // ones by standard balancing.
        let inst = self
            .coord
            .on_stage_done(now, req, Stage::Preproc)
            .expect("preproc resolves the ranking instance");
        let st = self.states.get_mut(req).unwrap();
        st.preproc_done = now;
        st.rank_instance = inst;
        let t = now + (2.0 * self.cfg.hop_us) as u64; // LB hop + gateway hop
        self.push(t, Ev::RankArrive(req));
    }

    // ---- ranking at the instance ---------------------------------------------

    fn on_rank_arrive(&mut self, now: u64, req: ReqId) {
        self.states.get_mut(req).unwrap().rank_start = now;
        match self.coord.on_rank_start(now, req) {
            RankAction::Proceed { .. } => self.start_rank_processing(now, req),
            // Waiting for ψ production or an in-flight reload: the
            // coordinator wakes the request from `on_psi_ready` /
            // `on_reload_done`.
            RankAction::Wait | RankAction::WaitReload => {}
            RankAction::StartReload { bytes } => {
                let (inst, user) = {
                    let st = self.states.get(req).unwrap();
                    (st.rank_instance, st.gen.uid())
                };
                let server = self.server_of(inst);
                let dur = self.cfg.hw.load_us(bytes);
                let (_, end) = alloc(&mut self.servers[server].pcie, now, dur);
                self.push(end, Ev::ReloadDone { user, inst, bytes });
            }
        }
    }

    fn on_reload_done(&mut self, now: u64, user: u64, inst: usize, bytes: usize) {
        let res = self.coord.on_reload_done(now, inst, user, Some(()), bytes);
        let load = self.cfg.hw.load_us(bytes);
        // Wake all requests joined to this reload (≤ 1 H2D per burst).
        for w in res.woken {
            if let Some(st) = self.states.get_mut(w) {
                st.load_us = load;
            }
            self.start_rank_processing(now, w);
        }
        // Grant the next queued reload its PCIe transfer.
        if let Some(next_user) = res.next {
            self.start_queued_reload(now, inst, next_user);
        }
    }

    fn start_queued_reload(&mut self, now: u64, inst: usize, user: u64) {
        match self.coord.begin_queued_reload(now, inst, user) {
            QueuedReload::Start { bytes } => {
                let server = self.server_of(inst);
                let dur = self.cfg.hw.load_us(bytes);
                let (_, end) = alloc(&mut self.servers[server].pcie, now, dur);
                self.push(end, Ev::ReloadDone { user, inst, bytes });
            }
            QueuedReload::Aborted { woken, next } => {
                // Evicted from DRAM while queued: waiters fall back.
                for w in woken {
                    self.start_rank_processing(now, w);
                }
                if let Some(nu) = next {
                    self.start_queued_reload(now, inst, nu);
                }
            }
        }
    }

    /// CPU feature processing → H2D → NPU execution for the rank request.
    fn start_rank_processing(&mut self, now: u64, req: ReqId) {
        let inst = self.states.get(req).unwrap().rank_instance;
        let tokens = self.rank_tokens(req);
        let server = self.server_of(inst);
        let dur = self.cfg.hw.feature_proc_us(tokens);
        let (_, end) = alloc(&mut self.servers[server].cpu, now, dur);
        self.push(end, Ev::RankCpuDone(req));
    }

    /// Cached path processes only incremental tokens + items; fallback /
    /// baseline must process the whole sequence on the critical path.
    fn rank_tokens(&self, req: ReqId) -> usize {
        let spec = &self.cfg.spec;
        if self.coord.is_cached(req) {
            spec.incr_len + spec.num_items
        } else {
            self.states.get(req).unwrap().gen.plen() + spec.incr_len + spec.num_items
        }
    }

    fn on_rank_cpu_done(&mut self, now: u64, req: ReqId) {
        let inst = self.states.get(req).unwrap().rank_instance;
        let tokens = self.rank_tokens(req);
        let server = self.server_of(inst);
        let dur = self.cfg.hw.h2d_embed_us(self.cfg.spec.embed_bytes(tokens));
        let (_, end) = alloc(&mut self.servers[server].pcie, now, dur);
        self.push(end, Ev::RankXferDone(req));
    }

    fn on_rank_xfer_done(&mut self, now: u64, req: ReqId) {
        // Offer the classified, execution-ready pass to the instance's
        // batch former (coordinator policy).  Window 0 answers `Solo`
        // without touching batch state, keeping the unbatched event
        // sequence bit-identical.
        match self.coord.offer_rank(now, req) {
            BatchDecision::Solo => self.exec_rank_solo(now, req),
            BatchDecision::Opened { deadline, gen } => {
                let inst = self.states.get(req).unwrap().rank_instance;
                self.push(deadline, Ev::BatchFlush { inst, gen });
            }
            BatchDecision::Joined => {}
            BatchDecision::Filled { gen } => {
                let inst = self.states.get(req).unwrap().rank_instance;
                self.flush_batch(now, inst, gen);
            }
        }
    }

    /// Unbatched rank execution — exactly the pre-batching pricing path.
    fn exec_rank_solo(&mut self, now: u64, req: ReqId) {
        let (inst, prefix_len) = {
            let st = self.states.get(req).unwrap();
            (st.rank_instance, st.gen.plen())
        };
        // Consume ψ at execution start; segments the plan reuses (or
        // joins — the producer's execution pays) trim the rank compute.
        // With reuse off `skipped` is 0 and the costs are bit-identical
        // to the unsplit model.
        let rc = self.coord.rank_compute(now, req);
        let skipped = rc.segments.map(|p| p.skipped()).unwrap_or(0);
        let dur = if rc.cached {
            self.cfg.hw.rank_cached_reuse_us(&self.cfg.spec, prefix_len, skipped)
        } else {
            self.cfg.hw.rank_full_reuse_us(&self.cfg.spec, prefix_len, skipped)
        };
        let (_, end) = alloc(&mut self.slots[inst], now, dur);
        self.busy_us[inst] += dur;
        self.states.get_mut(req).unwrap().rank_us = dur;
        self.push(end, Ev::RankExecDone(req));
    }

    /// Close batch `gen` on `inst` and run it as one batched rank pass:
    /// plan every member first (co-batched duplicate segments dedup via
    /// the single-flight store), price once with the sub-linear batched
    /// cost, occupy one NPU slot, and complete every member at the
    /// shared end time (`RankExecDone` events in offer order — the
    /// wheel's `(t, seq)` contract keeps completion order deterministic).
    fn flush_batch(&mut self, now: u64, inst: usize, gen: u64) {
        // `close_batch` drains into the recycled buffer; a stale
        // generation (already flushed by `Filled`) is a no-op.
        let mut batch = std::mem::take(&mut self.batch_buf);
        if !self.coord.close_batch(now, inst, gen, &mut batch) {
            self.batch_buf = batch;
            return;
        }
        let mut members = std::mem::take(&mut self.member_buf);
        members.clear();
        let mut skipped = 0;
        for &req in batch.iter() {
            let prefix_len = self.states.get(req).unwrap().gen.plen();
            let rc = self.coord.rank_compute(now, req);
            skipped += rc.segments.map(|p| p.skipped()).unwrap_or(0);
            members.push(BatchMember { cached: rc.cached, prefix_len });
        }
        let dur = self.cfg.hw.rank_batched_us(&self.cfg.spec, &members, skipped);
        let (_, end) = alloc(&mut self.slots[inst], now, dur);
        self.busy_us[inst] += dur;
        for &req in batch.iter() {
            self.states.get_mut(req).unwrap().rank_us = dur;
            self.push(end, Ev::RankExecDone(req));
        }
        batch.clear();
        self.batch_buf = batch;
        self.member_buf = members;
    }

    fn on_rank_exec_done(&mut self, now: u64, req: ReqId) {
        let st = self.states.remove(req).unwrap();
        let kv = self.cfg.spec.kv_bytes_for(st.gen.plen());
        let done = self.coord.on_rank_done(now, req, kv);
        // Spill freshly produced caches to DRAM for short-term reuse (off
        // the critical path; occupies the PCIe link).
        if let Some(bytes) = done.spill {
            if self.coord.complete_spill(now, done.instance, done.user, bytes, ()) {
                let server = self.server_of(done.instance);
                let dur = self.cfg.hw.spill_us(bytes);
                let _ = alloc(&mut self.servers[server].pcie, now, dur);
            }
        }
        let lc = Lifecycle {
            request: st.gen.rid(),
            user: st.gen.uid(),
            prefix_len: st.gen.plen(),
            arrival_us: st.gen.arrival_us,
            retrieval_done_us: st.retrieval_done,
            preproc_done_us: st.preproc_done,
            rank_start_us: st.rank_start,
            done_us: now,
            pre_us: st.pre_us,
            load_us: st.load_us,
            rank_us: st.rank_us,
            wait_us: done.wait_us,
            outcome: done.outcome,
            admitted: done.admitted,
            instance: done.instance,
        };
        self.metrics.record(&lc, done.is_long);
        self.metrics.offered_qps = self.arrived as f64 / (self.end_us as f64 / 1e6);
    }
}

/// Convenience: run one simulation.
pub fn run_sim(cfg: SimConfig, workload: &WorkloadConfig) -> anyhow::Result<RunMetrics> {
    Ok(Sim::new(cfg, workload)?.run())
}
