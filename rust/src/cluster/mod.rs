//! Discrete-event cluster simulator: the production-mirror substrate
//! standing in for the paper's Ascend testbed (see DESIGN.md
//! §Substitutions).  Queueing, affinity, admission and cache lifecycle
//! run through the exact `relay::*` state machines; only raw execution
//! durations come from the calibrated cost model.  [`reference`] is the
//! timing-free serialized engine the simulator (and live engine) are
//! pinned against.

pub mod reference;
pub mod sim;
pub mod wheel;

pub use reference::{
    build_cells, drive_reference, drive_reference_cells, run_reference, ReferenceRun,
};
pub use sim::{run_sim, Sim, SimConfig};
pub use wheel::TimerWheel;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::RunMetrics;
    use crate::relay::baseline::Mode;
    use crate::relay::tier::DramPolicy;
    use crate::workload::WorkloadConfig;

    fn small_workload(qps: f64) -> WorkloadConfig {
        WorkloadConfig {
            qps,
            duration_us: 10_000_000,
            num_users: 20_000,
            ..Default::default()
        }
    }

    fn run(mode: Mode, qps: f64) -> RunMetrics {
        run_sim(SimConfig::standard(mode), &small_workload(qps)).unwrap()
    }

    #[test]
    fn baseline_low_load_meets_slo() {
        // At very low QPS with mostly-short sequences the production
        // baseline is comfortably compliant.
        let m = run(Mode::Baseline, 20.0);
        assert!(m.completed > 150, "{}", m.brief());
        assert!(m.success_rate() > 0.9, "{}", m.brief());
        // All requests are full inference in baseline mode.
        assert_eq!(m.outcome_counts[1] + m.outcome_counts[2] + m.outcome_counts[3], 0);
    }

    #[test]
    fn relaygr_serves_long_requests_from_cache() {
        let m = run(Mode::RelayGr { dram: DramPolicy::Disabled }, 50.0);
        assert!(m.completed > 400, "{}", m.brief());
        assert!(m.outcome_counts[1] > 0, "expected HBM hits: {}", m.brief());
        assert!(m.trigger.admitted > 0);
        // Long-sequence tail should beat baseline's at the same load.
        let b = run(Mode::Baseline, 50.0);
        assert!(
            m.e2e_long.p99() < b.e2e_long.p99(),
            "relay p99 {} !< baseline p99 {}",
            m.e2e_long.p99(),
            b.e2e_long.p99()
        );
    }

    #[test]
    fn dram_tier_produces_dram_hits_on_refresh() {
        let wl = WorkloadConfig {
            qps: 50.0,
            duration_us: 10_000_000,
            num_users: 20_000,
            refresh_prob: 0.8,
            ..Default::default()
        };
        let cfg =
            SimConfig::standard(Mode::RelayGr { dram: DramPolicy::Capacity(512 << 30) });
        let m = run_sim(cfg, &wl).unwrap();
        assert!(
            m.outcome_counts[2] + m.outcome_counts[3] > 0,
            "expected DRAM hits: {}",
            m.brief()
        );
        assert!(m.hierarchy.spills > 0);
        assert!(m.dram_hit_rate() > 0.0);
    }

    #[test]
    fn deterministic_given_seed() {
        let a = run(Mode::RelayGr { dram: DramPolicy::Disabled }, 40.0);
        let b = run(Mode::RelayGr { dram: DramPolicy::Disabled }, 40.0);
        assert_eq!(a.completed, b.completed);
        assert_eq!(a.outcome_counts, b.outcome_counts);
        assert_eq!(a.p99_e2e(), b.p99_e2e());
    }

    #[test]
    fn overload_violates_slo() {
        // Far beyond capacity the baseline must blow through the SLO.
        let m = run(Mode::Baseline, 2_000.0);
        assert!(!m.slo_compliant(0.999), "{}", m.brief());
    }

    #[test]
    fn all_requests_complete_no_leaks() {
        // Every generated request must produce exactly one lifecycle.
        let wl = small_workload(80.0);
        let trace_len = crate::workload::generate(&wl).len();
        let m = run_sim(
            SimConfig::standard(Mode::RelayGr { dram: DramPolicy::Capacity(64 << 30) }),
            &wl,
        )
        .unwrap();
        assert_eq!(m.completed as usize, trace_len);
    }

    #[test]
    fn multi_cell_sim_shards_traffic_and_reports_cells() {
        let mut cfg = SimConfig::standard(Mode::RelayGr { dram: DramPolicy::Disabled });
        cfg.cells = 4;
        cfg.router.servers = 8; // 5 instances / 2 servers per cell
        let m = run_sim(cfg, &small_workload(80.0)).unwrap();
        assert_eq!(m.cells.len(), 4);
        let picks: u64 = m.cells.iter().map(|c| c.picks).sum();
        assert_eq!(picks, m.completed);
        // Affinity shards the population: more than one cell sees traffic.
        assert!(m.cells.iter().filter(|c| c.picks > 0).count() > 1, "{:?}", m.cells);
        assert!(m.outcome_counts[1] > 0, "cells still serve HBM hits: {}", m.brief());
        // One entry per global instance either way.
        assert_eq!(m.util.len(), 20);
        assert!(m.special_instances.iter().all(|&i| i < 20));
    }

    #[test]
    fn cells_must_divide_cluster_shape() {
        let mut cfg = SimConfig::standard(Mode::RelayGr { dram: DramPolicy::Disabled });
        cfg.cells = 3; // 20 instances / 10 servers: not divisible
        assert!(run_sim(cfg, &small_workload(10.0)).is_err());
    }

    #[test]
    fn utilization_bounded_and_nonzero() {
        let m = run(Mode::RelayGr { dram: DramPolicy::Disabled }, 100.0);
        assert!(!m.util.is_empty());
        for &u in &m.util {
            assert!((0.0..=1.0).contains(&u), "util {u}");
        }
        assert!(m.mean_util(None) > 0.0);
    }
}
