//! The serialized reference engine: every request runs start-to-finish
//! against the shared [`RelayCoordinator`] with an instantly-completing
//! host (productions, reloads and spills take zero time), using the
//! request's arrival time as the clock.
//!
//! This is the third decision engine next to the discrete-event
//! simulator and the live threaded engine — the one with *no* timing at
//! all, so any divergence from it is a genuine policy difference.  It is
//! shared by `relaygr figure tiers`/`figure segments`/`figure batching`
//! and by `tests/cross_engine.rs`, which pin the simulator (and, with
//! artifacts, the live engine) against it.
//!
//! Microbatching (`--batch-window > 0`): each classified rank pass is
//! offered to the coordinator's batch former.  Held members defer their
//! `rank_compute`/`on_rank_done` until the batch flushes — at its window
//! deadline (processed in deadline order against the arrival clock) or
//! when `batch_max` fills it — so co-batched duplicate segments dedup
//! through the single-flight store exactly as in the simulator.  Window
//! 0 takes the inline path below, bit-identical to the unbatched driver.

use std::collections::VecDeque;

use anyhow::{bail, Result};

use crate::cluster::SimConfig;
use crate::metrics::outcome_index;
use crate::model::BatchMember;
use crate::relay::cell::{CellReport, CellReq, CellSet};
use crate::relay::coordinator::{
    BatchDecision, RankAction, RelayCoordinator, ReqId, SignalAction, Stage,
};
use crate::relay::fault::FaultReport;
use crate::relay::flight::{FlightRecorder, StageBreakdown};
use crate::relay::hbm::HbmStats;
use crate::relay::hierarchy::HierarchyStats;
use crate::relay::pipeline::CacheOutcome;
use crate::relay::segment::SegmentStats;
use crate::relay::trigger::TriggerStats;
use crate::util::slab::SecondaryMap;
use crate::workload::{candidate_set_into, stream, GenRequest, WorkloadConfig};

/// One serialized run: per-request outcomes (sorted by request id), the
/// analytic rank-compute cost summed over the coordinator's decisions
/// (the reference engine has no clock, so its "rank time" is the cost
/// model evaluated on what the coordinator decided), and the cache-plane
/// counters.
pub struct ReferenceRun {
    pub outcomes: Vec<(u64, CacheOutcome)>,
    pub outcome_counts: [u64; 6],
    pub mean_rank_us: f64,
    pub segments: SegmentStats,
    pub hierarchy: HierarchyStats,
    pub hbm: HbmStats,
    pub trigger: TriggerStats,
    /// Stage-latency breakdown on the arrival clock (empty unless the
    /// coordinator traced with `trace_spans > 0`).
    pub stages: StageBreakdown,
    /// The detached flight recorder (raw spans), when tracing was on.
    pub flight: Option<std::sync::Arc<FlightRecorder>>,
    /// Per-cell routing/failure report (empty from the legacy
    /// single-coordinator driver, which predates the cell layer).
    pub cells: Vec<CellReport>,
    /// Fault-plane counters merged across cells (all-zero when the
    /// fault plane is off).
    pub faults: FaultReport,
}

/// Completion bookkeeping + pooled batch state shared by the inline
/// (solo) path and batch flushes.
struct Acc {
    outcomes: Vec<(u64, CacheOutcome)>,
    outcome_counts: [u64; 6],
    rank_us_sum: f64,
    /// Requests held open by the batch former: the per-request metadata
    /// needed when the batch flushes.
    held: SecondaryMap<GenRequest>,
    batch_buf: Vec<ReqId>,
    member_buf: Vec<BatchMember>,
}

impl Acc {
    fn finish(
        &mut self,
        coord: &mut RelayCoordinator<()>,
        now: u64,
        handle: ReqId,
        rid: u64,
        kv: usize,
    ) {
        let done = coord.on_rank_done(now, handle, kv);
        if let Some(bytes) = done.spill {
            coord.complete_spill(now, done.instance, done.user, bytes, ());
        }
        self.outcome_counts[outcome_index(done.outcome)] += 1;
        self.outcomes.push((rid, done.outcome));
    }
}

/// Flush batch `gen` on `inst` at clock `now`: plan every member first
/// (co-batched duplicates dedup into `Join` against the first member's
/// `Produce`), price the batch once, then complete each member.  Stale
/// generations (already flushed by `Filled`) are a no-op.
fn flush<K, R>(
    coord: &mut RelayCoordinator<()>,
    acc: &mut Acc,
    now: u64,
    inst: usize,
    gen: u64,
    kv_bytes: &K,
    rank_cost: &R,
) where
    K: Fn(usize) -> usize,
    R: Fn(&[BatchMember], usize) -> f64,
{
    let mut batch = std::mem::take(&mut acc.batch_buf);
    if !coord.close_batch(now, inst, gen, &mut batch) {
        acc.batch_buf = batch;
        return;
    }
    acc.member_buf.clear();
    let mut skipped = 0;
    for &h in batch.iter() {
        let g = *acc.held.get(h).expect("held batch member");
        let rc = coord.rank_compute(now, h);
        skipped += rc.segments.map(|p| p.skipped()).unwrap_or(0);
        acc.member_buf.push(BatchMember { cached: rc.cached, prefix_len: g.plen() });
    }
    let members = std::mem::take(&mut acc.member_buf);
    acc.rank_us_sum += rank_cost(&members, skipped);
    acc.member_buf = members;
    for &h in batch.iter() {
        let g = acc.held.remove(h).expect("held batch member");
        acc.finish(coord, now, h, g.rid(), kv_bytes(g.plen()));
    }
    batch.clear();
    acc.batch_buf = batch;
}

/// Drive `trace` through `coord` serially.  `rank_cost` prices one
/// (possibly single-member) batched rank pass from its member
/// descriptors and the summed segment-reuse count; candidate sets come
/// from the same workload derivation the other engines share.  The
/// trace is consumed as a stream, so replaying a recorded trace holds
/// O(in-flight) request state beyond the outcome log itself.
pub fn drive_reference(
    mut coord: RelayCoordinator<()>,
    trace: impl IntoIterator<Item = GenRequest>,
    wl: &WorkloadConfig,
    kv_bytes: impl Fn(usize) -> usize,
    rank_cost: impl Fn(&[BatchMember], usize) -> f64,
) -> Result<ReferenceRun> {
    let mut acc = Acc {
        outcomes: Vec::new(),
        outcome_counts: [0u64; 6],
        rank_us_sum: 0.0,
        held: SecondaryMap::new(),
        batch_buf: Vec::new(),
        member_buf: Vec::new(),
    };
    // Open batches pending their window deadline, in open order — which
    // is deadline order, since arrivals are monotone and the window is
    // fixed.
    let mut pending: VecDeque<(u64, usize, u64)> = VecDeque::new();
    let mut cands: Vec<u64> = Vec::new();
    for req in trace {
        let now = req.arrival_us;
        // Batches whose window closed before this arrival flush first,
        // at their deadline clock — matching the simulator's
        // `BatchFlush` timer event.
        while pending.front().is_some_and(|&(d, _, _)| d <= now) {
            let (d, inst, gen) = pending.pop_front().unwrap();
            flush(&mut coord, &mut acc, d, inst, gen, &kv_bytes, &rank_cost);
        }
        if coord.segments_enabled() {
            candidate_set_into(wl, &req, &mut cands);
        } else {
            cands.clear();
        }
        let (handle, wants_trigger) =
            coord.on_arrival(now, req.rid(), req.uid(), req.plen(), &cands);
        if wants_trigger {
            match coord.on_trigger_check(now, handle) {
                SignalAction::Produce { instance, user, .. } => {
                    coord.on_psi_ready(now, instance, user, Some(()));
                }
                SignalAction::Reload { instance, user, bytes } => {
                    coord.on_reload_done(now, instance, user, Some(()), bytes);
                }
                SignalAction::None => {}
            }
        }
        coord.on_stage_done(now, handle, Stage::Retrieval);
        let inst = coord
            .on_stage_done(now, handle, Stage::Preproc)
            .expect("preproc resolves the ranking instance");
        match coord.on_rank_start(now, handle) {
            RankAction::Proceed { .. } => {}
            RankAction::StartReload { bytes } => {
                coord.on_reload_done(now, inst, req.uid(), Some(()), bytes);
            }
            // With an instantly-completing host nothing can be pending; a
            // wait here means a coordinator invariant broke — fail rather
            // than report decisions from an unresolved request.
            other => bail!("serialized driver saw {other:?} for request {}", req.id),
        }
        match coord.offer_rank(now, handle) {
            BatchDecision::Solo => {
                let rc = coord.rank_compute(now, handle);
                let skipped = rc.segments.map(|p| p.skipped()).unwrap_or(0);
                let m = [BatchMember { cached: rc.cached, prefix_len: req.plen() }];
                acc.rank_us_sum += rank_cost(&m, skipped);
                acc.finish(&mut coord, now, handle, req.rid(), kv_bytes(req.plen()));
            }
            BatchDecision::Opened { deadline, gen } => {
                acc.held.insert(handle, req);
                pending.push_back((deadline, inst, gen));
            }
            BatchDecision::Joined => {
                acc.held.insert(handle, req);
            }
            BatchDecision::Filled { gen } => {
                acc.held.insert(handle, req);
                flush(&mut coord, &mut acc, now, inst, gen, &kv_bytes, &rank_cost);
            }
        }
    }
    // End of trace: flush every batch still waiting out its window.
    while let Some((d, inst, gen)) = pending.pop_front() {
        flush(&mut coord, &mut acc, d, inst, gen, &kv_bytes, &rank_cost);
    }
    acc.outcomes.sort_by_key(|&(id, _)| id);
    let (stages, flight) = match coord.take_flight() {
        Some(fl) => (fl.breakdown.clone(), Some(std::sync::Arc::new(fl))),
        None => (StageBreakdown::default(), None),
    };
    Ok(ReferenceRun {
        mean_rank_us: acc.rank_us_sum / acc.outcomes.len().max(1) as f64,
        segments: coord.segment_stats(),
        hierarchy: coord.hierarchy_stats(),
        hbm: coord.hbm_stats(),
        trigger: coord.trigger_stats(),
        outcomes: acc.outcomes,
        outcome_counts: acc.outcome_counts,
        stages,
        flight,
        cells: Vec::new(),
        faults: coord.fault_report(),
    })
}

/// Per-cell completion bookkeeping for the cell-aware driver: `held`
/// is one map per cell because [`ReqId`] slots are per-cell slabs.
struct CellAcc {
    outcomes: Vec<(u64, CacheOutcome)>,
    outcome_counts: [u64; 6],
    rank_us_sum: f64,
    held: Vec<SecondaryMap<GenRequest>>,
    batch_buf: Vec<ReqId>,
    member_buf: Vec<BatchMember>,
}

impl CellAcc {
    fn finish(&mut self, cells: &mut CellSet<()>, now: u64, req: CellReq, rid: u64, kv: usize) {
        // Through the cell layer, not the coordinator directly — the
        // wrapper is what counts cross-cell ψ misses on completion.
        let done = cells.on_rank_done(now, req, kv);
        if let Some(bytes) = done.spill {
            cells.coord_mut(req.cell).complete_spill(now, done.instance, done.user, bytes, ());
        }
        self.outcome_counts[outcome_index(done.outcome)] += 1;
        self.outcomes.push((rid, done.outcome));
    }
}

/// Cell-aware batch flush: same contract as [`flush`], scoped to one
/// cell's coordinator.
fn flush_cell<K, R>(
    cells: &mut CellSet<()>,
    acc: &mut CellAcc,
    now: u64,
    cell: usize,
    inst: usize,
    gen: u64,
    kv_bytes: &K,
    rank_cost: &R,
) where
    K: Fn(usize) -> usize,
    R: Fn(&[BatchMember], usize) -> f64,
{
    let mut batch = std::mem::take(&mut acc.batch_buf);
    if !cells.coord_mut(cell).close_batch(now, inst, gen, &mut batch) {
        acc.batch_buf = batch;
        return;
    }
    acc.member_buf.clear();
    let mut skipped = 0;
    for &h in batch.iter() {
        let g = *acc.held[cell].get(h).expect("held batch member");
        let rc = cells.coord_mut(cell).rank_compute(now, h);
        skipped += rc.segments.map(|p| p.skipped()).unwrap_or(0);
        acc.member_buf.push(BatchMember { cached: rc.cached, prefix_len: g.plen() });
    }
    let members = std::mem::take(&mut acc.member_buf);
    acc.rank_us_sum += rank_cost(&members, skipped);
    acc.member_buf = members;
    for &h in batch.iter() {
        let g = acc.held[cell].remove(h).expect("held batch member");
        acc.finish(cells, now, CellReq { cell, id: h }, g.rid(), kv_bytes(g.plen()));
    }
    batch.clear();
    acc.batch_buf = batch;
}

/// Drive `trace` through an N-cell [`CellSet`] serially — the cell-aware
/// counterpart of [`drive_reference`].  The two are deliberately
/// independent implementations: `tests/cross_engine.rs` pins this driver
/// at `cells = 1` decision-for-decision against the legacy one, so the
/// cell layer's structural-identity claim is checked against code that
/// never heard of cells.
pub fn drive_reference_cells(
    mut cells: CellSet<()>,
    trace: impl IntoIterator<Item = GenRequest>,
    wl: &WorkloadConfig,
    kv_bytes: impl Fn(usize) -> usize,
    rank_cost: impl Fn(&[BatchMember], usize) -> f64,
) -> Result<ReferenceRun> {
    let n_cells = cells.n_cells();
    let mut acc = CellAcc {
        outcomes: Vec::new(),
        outcome_counts: [0u64; 6],
        rank_us_sum: 0.0,
        held: (0..n_cells).map(|_| SecondaryMap::new()).collect(),
        batch_buf: Vec::new(),
        member_buf: Vec::new(),
    };
    // Open batches pending their window deadline: (deadline, cell, inst,
    // gen) in open order == deadline order (monotone arrivals, fixed
    // window).
    let mut pending: VecDeque<(u64, usize, usize, u64)> = VecDeque::new();
    let mut cands: Vec<u64> = Vec::new();
    for req in trace {
        let now = req.arrival_us;
        while pending.front().is_some_and(|&(d, _, _, _)| d <= now) {
            let (d, cell, inst, gen) = pending.pop_front().unwrap();
            flush_cell(&mut cells, &mut acc, d, cell, inst, gen, &kv_bytes, &rank_cost);
        }
        if cells.coord(0).segments_enabled() {
            candidate_set_into(wl, &req, &mut cands);
        } else {
            cands.clear();
        }
        let (handle, wants_trigger) =
            cells.on_arrival(now, req.rid(), req.uid(), req.plen(), &cands);
        let cell = handle.cell;
        if wants_trigger {
            match cells.coord_mut(cell).on_trigger_check(now, handle.id) {
                SignalAction::Produce { instance, user, .. } => {
                    cells.coord_mut(cell).on_psi_ready(now, instance, user, Some(()));
                }
                SignalAction::Reload { instance, user, bytes } => {
                    cells.coord_mut(cell).on_reload_done(now, instance, user, Some(()), bytes);
                }
                SignalAction::None => {}
            }
        }
        cells.coord_mut(cell).on_stage_done(now, handle.id, Stage::Retrieval);
        let inst = cells
            .coord_mut(cell)
            .on_stage_done(now, handle.id, Stage::Preproc)
            .expect("preproc resolves the ranking instance");
        match cells.coord_mut(cell).on_rank_start(now, handle.id) {
            RankAction::Proceed { .. } => {}
            RankAction::StartReload { bytes } => {
                cells.coord_mut(cell).on_reload_done(now, inst, req.uid(), Some(()), bytes);
            }
            other => bail!("serialized driver saw {other:?} for request {}", req.id),
        }
        match cells.coord_mut(cell).offer_rank(now, handle.id) {
            BatchDecision::Solo => {
                let rc = cells.coord_mut(cell).rank_compute(now, handle.id);
                let skipped = rc.segments.map(|p| p.skipped()).unwrap_or(0);
                let m = [BatchMember { cached: rc.cached, prefix_len: req.plen() }];
                acc.rank_us_sum += rank_cost(&m, skipped);
                acc.finish(&mut cells, now, handle, req.rid(), kv_bytes(req.plen()));
            }
            BatchDecision::Opened { deadline, gen } => {
                acc.held[cell].insert(handle.id, req);
                pending.push_back((deadline, cell, inst, gen));
            }
            BatchDecision::Joined => {
                acc.held[cell].insert(handle.id, req);
            }
            BatchDecision::Filled { gen } => {
                acc.held[cell].insert(handle.id, req);
                flush_cell(&mut cells, &mut acc, now, cell, inst, gen, &kv_bytes, &rank_cost);
            }
        }
    }
    while let Some((d, cell, inst, gen)) = pending.pop_front() {
        flush_cell(&mut cells, &mut acc, d, cell, inst, gen, &kv_bytes, &rank_cost);
    }
    acc.outcomes.sort_by_key(|&(id, _)| id);
    // Deterministic cross-cell merge, cell-index order — same rule as
    // the simulator's finalize.
    let (mut hbm, mut hier, mut trig, mut seg) = (
        cells.coord(0).hbm_stats(),
        cells.coord(0).hierarchy_stats(),
        cells.coord(0).trigger_stats(),
        cells.coord(0).segment_stats(),
    );
    let mut faults = cells.coord(0).fault_report();
    for c in 1..n_cells {
        hbm.merge(cells.coord(c).hbm_stats());
        hier.merge(cells.coord(c).hierarchy_stats());
        trig.merge(cells.coord(c).trigger_stats());
        seg.merge(cells.coord(c).segment_stats());
        faults.merge(&cells.coord(c).fault_report());
    }
    let (stages, flight) = match cells.take_flight() {
        Some(fl) => (fl.breakdown.clone(), Some(std::sync::Arc::new(fl))),
        None => (StageBreakdown::default(), None),
    };
    Ok(ReferenceRun {
        mean_rank_us: acc.rank_us_sum / acc.outcomes.len().max(1) as f64,
        segments: seg,
        hierarchy: hier,
        hbm,
        trigger: trig,
        outcomes: acc.outcomes,
        outcome_counts: acc.outcome_counts,
        stages,
        flight,
        cells: cells.reports(),
        faults,
    })
}

/// Build `cfg`'s [`CellSet`] — the per-cell coordinator shards behind
/// the two-level router — seeded exactly as [`run_reference`] seeds the
/// admission loop (shared with `tests/cross_engine.rs`).
pub fn build_cells(cfg: &SimConfig, wl: &WorkloadConfig) -> Result<CellSet<()>> {
    let mut cfg = cfg.clone();
    let profile = wl.scenario.admission_profile();
    cfg.admission.seed_operating_point(profile.headroom_init, profile.rate_mult_init);
    if cfg.cells == 0
        || cfg.router.n_instances % cfg.cells != 0
        || cfg.router.servers % cfg.cells != 0
    {
        bail!(
            "--cells {} must be >= 1 and divide both instances {} and servers {}",
            cfg.cells,
            cfg.router.n_instances,
            cfg.router.servers
        );
    }
    let coords = (0..cfg.cells)
        .map(|_| RelayCoordinator::new(cfg.cell_coordinator_config(), |_| cfg.estimator()))
        .collect::<Result<Vec<_>>>()?;
    CellSet::new(cfg.cell_config(), coords, wl.duration_us)
}

/// Convenience: serialized run of `cfg`'s cell set over `wl`'s trace,
/// pricing rank compute with `cfg`'s hardware cost model (batched costs
/// reduce bit-identically to the single-request model at batch size 1).
/// At `cells = 1` the cell layer is a structural passthrough, so this
/// remains the pre-cell serialized reference decision-for-decision.
pub fn run_reference(cfg: &SimConfig, wl: &WorkloadConfig) -> Result<ReferenceRun> {
    let cells = build_cells(cfg, wl)?;
    let spec = cfg.spec;
    let hw = cfg.hw.clone();
    drive_reference_cells(
        cells,
        stream(wl),
        wl,
        |p| spec.kv_bytes_for(p),
        move |members, skipped| hw.rank_batched_us(&spec, members, skipped),
    )
}
