//! The serialized reference engine: every request runs start-to-finish
//! against the shared [`RelayCoordinator`] with an instantly-completing
//! host (productions, reloads and spills take zero time), using the
//! request's arrival time as the clock.
//!
//! This is the third decision engine next to the discrete-event
//! simulator and the live threaded engine — the one with *no* timing at
//! all, so any divergence from it is a genuine policy difference.  It is
//! shared by `relaygr figure tiers`/`figure segments` and by
//! `tests/cross_engine.rs`, which pin the simulator (and, with
//! artifacts, the live engine) against it.

use anyhow::{bail, Result};

use crate::cluster::SimConfig;
use crate::metrics::outcome_index;
use crate::relay::coordinator::{RankAction, RelayCoordinator, SignalAction, Stage};
use crate::relay::hbm::HbmStats;
use crate::relay::hierarchy::HierarchyStats;
use crate::relay::pipeline::CacheOutcome;
use crate::relay::segment::SegmentStats;
use crate::relay::trigger::TriggerStats;
use crate::workload::{candidate_set_into, stream, GenRequest, WorkloadConfig};

/// One serialized run: per-request outcomes (sorted by request id), the
/// analytic rank-compute cost summed over the coordinator's decisions
/// (the reference engine has no clock, so its "rank time" is the cost
/// model evaluated on what the coordinator decided), and the cache-plane
/// counters.
pub struct ReferenceRun {
    pub outcomes: Vec<(u64, CacheOutcome)>,
    pub outcome_counts: [u64; 5],
    pub mean_rank_us: f64,
    pub segments: SegmentStats,
    pub hierarchy: HierarchyStats,
    pub hbm: HbmStats,
    pub trigger: TriggerStats,
}

/// Drive `trace` through `coord` serially.  `rank_cost` receives
/// `(cached, prefix_len, segments_skipped)` per request; candidate sets
/// come from the same workload derivation the other engines share.
/// The trace is consumed as a stream, so replaying a recorded trace
/// holds O(1) request state beyond the outcome log itself.
pub fn drive_reference(
    mut coord: RelayCoordinator<()>,
    trace: impl IntoIterator<Item = GenRequest>,
    wl: &WorkloadConfig,
    kv_bytes: impl Fn(usize) -> usize,
    rank_cost: impl Fn(bool, usize, usize) -> f64,
) -> Result<ReferenceRun> {
    let mut outcomes = Vec::new();
    let mut outcome_counts = [0u64; 5];
    let mut rank_us_sum = 0.0;
    let mut cands: Vec<u64> = Vec::new();
    for req in trace {
        let now = req.arrival_us;
        if coord.segments_enabled() {
            candidate_set_into(wl, &req, &mut cands);
        } else {
            cands.clear();
        }
        let (handle, wants_trigger) = coord.on_arrival(now, req.uid(), req.plen(), &cands);
        if wants_trigger {
            match coord.on_trigger_check(now, handle) {
                SignalAction::Produce { instance, user, .. } => {
                    coord.on_psi_ready(now, instance, user, Some(()));
                }
                SignalAction::Reload { instance, user, bytes } => {
                    coord.on_reload_done(now, instance, user, Some(()), bytes);
                }
                SignalAction::None => {}
            }
        }
        coord.on_stage_done(now, handle, Stage::Retrieval);
        let inst = coord
            .on_stage_done(now, handle, Stage::Preproc)
            .expect("preproc resolves the ranking instance");
        match coord.on_rank_start(now, handle) {
            RankAction::Proceed { .. } => {}
            RankAction::StartReload { bytes } => {
                coord.on_reload_done(now, inst, req.uid(), Some(()), bytes);
            }
            // With an instantly-completing host nothing can be pending; a
            // wait here means a coordinator invariant broke — fail rather
            // than report decisions from an unresolved request.
            other => bail!("serialized driver saw {other:?} for request {}", req.id),
        }
        let rc = coord.rank_compute(now, handle);
        let skipped = rc.segments.map(|p| p.skipped()).unwrap_or(0);
        rank_us_sum += rank_cost(rc.cached, req.plen(), skipped);
        let done = coord.on_rank_done(now, handle, kv_bytes(req.plen()));
        if let Some(bytes) = done.spill {
            coord.complete_spill(done.instance, done.user, bytes, ());
        }
        outcome_counts[outcome_index(done.outcome)] += 1;
        outcomes.push((req.rid(), done.outcome));
    }
    outcomes.sort_by_key(|&(id, _)| id);
    Ok(ReferenceRun {
        mean_rank_us: rank_us_sum / outcomes.len().max(1) as f64,
        segments: coord.segment_stats(),
        hierarchy: coord.hierarchy_stats(),
        hbm: coord.hbm_stats(),
        trigger: coord.trigger_stats(),
        outcomes,
        outcome_counts,
    })
}

/// Convenience: serialized run of `cfg`'s coordinator over `wl`'s trace,
/// pricing rank compute with `cfg`'s hardware cost model.
pub fn run_reference(cfg: &SimConfig, wl: &WorkloadConfig) -> Result<ReferenceRun> {
    // Same per-scenario adaptive operating point the simulator seeds —
    // the engines must start the closed loop from the same state.
    let mut cfg = cfg.clone();
    let profile = wl.scenario.admission_profile();
    cfg.admission.seed_operating_point(profile.headroom_init, profile.rate_mult_init);
    let coord: RelayCoordinator<()> =
        RelayCoordinator::new(cfg.coordinator_config(), |_| cfg.estimator())?;
    let spec = cfg.spec;
    let hw = cfg.hw.clone();
    drive_reference(
        coord,
        stream(wl),
        wl,
        |p| spec.kv_bytes_for(p),
        move |cached, p, skipped| {
            if cached {
                hw.rank_cached_reuse_us(&spec, p, skipped)
            } else {
                hw.rank_full_reuse_us(&spec, p, skipped)
            }
        },
    )
}
