//! Hierarchical timer wheel for the discrete-event simulator.
//!
//! The simulator's event queue was a `BinaryHeap<Reverse<(t, seq, Ev)>>`:
//! O(log n) per push/pop with poor locality once millions of events are
//! in flight.  This wheel is the classic hashed hierarchical design
//! (Varghese & Lauck): 11 levels × 64 slots of geometrically coarser
//! resolution (level *l* spans 2^(6·l) µs per slot, 66 bits total — any
//! `u64` timestamp fits with no overflow list).  Push is O(1); pop finds
//! the next occupied slot with one `trailing_zeros` per level and
//! cascades coarse slots down as the clock reaches them.
//!
//! ## Ordering contract (load-bearing)
//!
//! Pops are in **exactly** ascending `(t, seq)` order — byte-identical to
//! the `BinaryHeap` it replaced, for any interleaving of pushes and pops
//! with monotonically increasing `seq` and `t >= now()` (the simulator
//! never schedules into the past).  Two mechanisms guarantee it:
//!
//! * a level-0 slot holds exactly one µs tick, and is sorted by `seq`
//!   when drained (cascades append entries out of push order only in
//!   same-tick corner cases — the sort makes the contract unconditional);
//! * an event pushed *at* the current tick while that tick's batch is
//!   draining carries the largest `seq` issued so far, so appending it to
//!   the ready queue keeps the queue ascending.
//!
//! `tests::wheel_matches_heap_order_*` pin the contract against a live
//! `BinaryHeap` on adversarial event sets (same-tick bursts, far-future
//! reloads, pushes mid-drain).

use std::collections::VecDeque;

const SLOT_BITS: u32 = 6;
const SLOTS: usize = 1 << SLOT_BITS;
const MASK: u64 = (SLOTS - 1) as u64;
/// ceil(64 / SLOT_BITS): enough levels that any u64 delta has a home.
const LEVELS: usize = 11;

/// Timer wheel dispatching `(t, seq, E)` triples in `(t, seq)` order.
pub struct TimerWheel<E> {
    /// `LEVELS × SLOTS` cells, flattened; cell vectors are recycled (a
    /// drained cell keeps its capacity), so steady-state traffic through
    /// the wheel allocates nothing.
    cells: Vec<Vec<(u64, u64, E)>>,
    /// Per-level occupancy bitmap: bit *s* set iff cell *s* is non-empty.
    occ: [u64; LEVELS],
    /// Current tick: nothing earlier remains undelivered.
    now: u64,
    len: usize,
    /// The current tick's batch, ascending `(t, seq)`; popped from the
    /// front, same-tick pushes (largest seq so far) append at the back.
    ready: VecDeque<(u64, u64, E)>,
    /// Recycled buffer for cascading a coarse slot without allocating.
    scratch: Vec<(u64, u64, E)>,
}

impl<E> TimerWheel<E> {
    pub fn new() -> TimerWheel<E> {
        TimerWheel {
            cells: (0..LEVELS * SLOTS).map(|_| Vec::new()).collect(),
            occ: [0; LEVELS],
            now: 0,
            len: 0,
            ready: VecDeque::new(),
            scratch: Vec::new(),
        }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// The current tick (the `t` of the last pop, or 0 before any).
    pub fn now(&self) -> u64 {
        self.now
    }

    /// Level whose slot resolution matches the highest differing bit
    /// group between an event time and `now`.
    fn level_of(diff: u64) -> usize {
        debug_assert!(diff != 0);
        ((63 - diff.leading_zeros()) / SLOT_BITS) as usize
    }

    /// Schedule `ev` at time `t` with tie-break `seq`.  `seq` must be
    /// strictly greater than every previously pushed `seq` (the
    /// simulator's monotone event counter); `t` earlier than the current
    /// tick is clamped to it (fires immediately, in seq order).
    pub fn push(&mut self, t: u64, seq: u64, ev: E) {
        let t = t.max(self.now);
        self.len += 1;
        if t == self.now {
            if let Some(&(bt, bs, _)) = self.ready.back() {
                debug_assert!(
                    (bt, bs) < (t, seq),
                    "same-tick push must carry the largest (t, seq) so far"
                );
            }
            self.ready.push_back((t, seq, ev));
            return;
        }
        let lvl = Self::level_of(t ^ self.now);
        let slot = ((t >> (lvl as u32 * SLOT_BITS)) & MASK) as usize;
        self.cells[lvl * SLOTS + slot].push((t, seq, ev));
        self.occ[lvl] |= 1 << slot;
    }

    /// Deliver the earliest `(t, seq, E)`, advancing the clock to `t`.
    pub fn pop(&mut self) -> Option<(u64, u64, E)> {
        loop {
            if let Some(x) = self.ready.pop_front() {
                self.len -= 1;
                self.now = x.0;
                return Some(x);
            }
            if self.len == 0 {
                return None;
            }
            self.advance();
        }
    }

    /// One step of clock advance: drain the earliest level-0 slot into
    /// `ready`, or cascade the earliest coarse slot one level down.
    ///
    /// Level *l* entries lie inside now's level-*l* window but outside
    /// its level-(l−1) window, i.e. strictly after everything at lower
    /// levels — so the lowest occupied level always holds the earliest
    /// events, and within a level the smallest occupied slot index does
    /// (slot indices are absolute time bit-groups, and all wheel times
    /// are ≥ now, so indices never wrap within a window).
    fn advance(&mut self) {
        for lvl in 0..LEVELS {
            if self.occ[lvl] == 0 {
                continue;
            }
            let slot = self.occ[lvl].trailing_zeros() as usize;
            self.occ[lvl] &= !(1u64 << slot);
            if lvl == 0 {
                let cell = &mut self.cells[slot];
                debug_assert!(!cell.is_empty(), "occupancy bit set on empty cell");
                cell.sort_unstable_by_key(|&(t, seq, _)| (t, seq));
                self.now = cell[0].0;
                debug_assert!(
                    cell.iter().all(|&(t, _, _)| t == self.now),
                    "level-0 slot spans one tick"
                );
                for x in cell.drain(..) {
                    self.ready.push_back(x);
                }
                return;
            }
            // Cascade: advance the clock to the slot's window start (no
            // event precedes it), then re-insert the entries — they land
            // at lower levels (or in `ready`, for the window start tick).
            let shift = lvl as u32 * SLOT_BITS;
            let window = match shift + SLOT_BITS {
                s if s >= 64 => 0, // the top level's window is all of u64
                s => (self.now >> s) << s,
            };
            self.now = window | ((slot as u64) << shift);
            let cell = lvl * SLOTS + slot;
            let recycled = std::mem::take(&mut self.scratch);
            let mut batch = std::mem::replace(&mut self.cells[cell], recycled);
            self.len -= batch.len();
            for (t, seq, ev) in batch.drain(..) {
                debug_assert!(t >= self.now);
                self.push(t, seq, ev);
            }
            self.scratch = batch;
            return;
        }
        unreachable!("len > 0 with empty ready queue and no occupied slot");
    }
}

impl<E> Default for TimerWheel<E> {
    fn default() -> TimerWheel<E> {
        TimerWheel::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;
    use std::cmp::Reverse;
    use std::collections::BinaryHeap;

    /// Reference model: the exact heap the simulator used to run on.
    struct HeapModel {
        heap: BinaryHeap<Reverse<(u64, u64, u32)>>,
    }

    impl HeapModel {
        fn new() -> HeapModel {
            HeapModel { heap: BinaryHeap::new() }
        }
        fn push(&mut self, t: u64, seq: u64, ev: u32) {
            self.heap.push(Reverse((t, seq, ev)));
        }
        fn pop(&mut self) -> Option<(u64, u64, u32)> {
            self.heap.pop().map(|Reverse(x)| x)
        }
    }

    fn drain_both(wheel: &mut TimerWheel<u32>, heap: &mut HeapModel) {
        loop {
            let (a, b) = (wheel.pop(), heap.pop());
            assert_eq!(a, b, "drain order diverged");
            if a.is_none() {
                break;
            }
        }
        assert!(wheel.is_empty());
    }

    /// The tentpole determinism pin: on adversarial pushed sets — dense
    /// same-tick bursts, near-future work, far-future reloads (t_life /
    /// lease horizons land 10^5–10^9 µs out) — interleaved with pops, the
    /// wheel's pop order equals the `(t, seq)` heap order exactly.
    #[test]
    fn wheel_matches_heap_order_on_adversarial_sets() {
        for seed in 0..24u64 {
            let mut rng = Rng::new(0xEE1 ^ seed);
            let mut wheel: TimerWheel<u32> = TimerWheel::new();
            let mut heap = HeapModel::new();
            let mut seq = 0u64;
            let mut now = 0u64;
            for step in 0..4_000u32 {
                if rng.range(0, 100) < 55 {
                    let dt = match rng.range(0, 10) {
                        0..=3 => 0, // same-tick burst
                        4..=6 => rng.range(1, 64) as u64,
                        7 => rng.range(64, 4_096) as u64,
                        8 => rng.range(4_096, 300_000) as u64, // T_life-scale
                        _ => 300_000 + rng.range_u64(2_000_000_000), // far-future reload horizon
                    };
                    seq += 1;
                    wheel.push(now + dt, seq, step);
                    heap.push(now + dt, seq, step);
                } else {
                    let (a, b) = (wheel.pop(), heap.pop());
                    assert_eq!(a, b, "seed {seed} step {step}: pop diverged");
                    if let Some((t, _, _)) = a {
                        now = t;
                    }
                }
                assert_eq!(wheel.len(), heap.heap.len());
            }
            drain_both(&mut wheel, &mut heap);
        }
    }

    /// The simulator's dispatch pattern: handling an event pushes more
    /// events, often at the *current* tick (zero-duration resource
    /// grants).  Mid-drain same-tick pushes must fire after the rest of
    /// the tick's batch, in seq order.
    #[test]
    fn pushes_at_current_tick_during_drain_fire_in_seq_order() {
        let mut wheel: TimerWheel<u32> = TimerWheel::new();
        let mut heap = HeapModel::new();
        let mut seq = 0u64;
        for ev in 0..8u32 {
            seq += 1;
            wheel.push(100, seq, ev);
            heap.push(100, seq, ev);
        }
        // Pop one event of the tick, then push two more at t = 100 (the
        // current tick) and one at t = 100 + 64·k (a far slot).
        assert_eq!(wheel.pop(), heap.pop());
        for dt in [0u64, 0, 6400] {
            seq += 1;
            wheel.push(100 + dt, seq, 1000 + dt as u32);
            heap.push(100 + dt, seq, 1000 + dt as u32);
        }
        drain_both(&mut wheel, &mut heap);
    }

    /// Same-tick entries split across levels: some pushed from afar (the
    /// tick sat in a coarse slot), some pushed once the clock was near —
    /// the drained batch must still come out in seq order.
    #[test]
    fn cascaded_and_direct_entries_share_a_tick() {
        let mut wheel: TimerWheel<u32> = TimerWheel::new();
        let mut heap = HeapModel::new();
        // From t=0, t=70_000 lives in a coarse slot.
        wheel.push(70_000, 1, 1);
        heap.push(70_000, 1, 1);
        // A stepping stone advances the clock near the target window.
        wheel.push(69_999, 2, 2);
        heap.push(69_999, 2, 2);
        assert_eq!(wheel.pop(), heap.pop()); // now = 69_999
        // Direct same-tick push lands next to the coarse one's home.
        wheel.push(70_000, 3, 3);
        heap.push(70_000, 3, 3);
        drain_both(&mut wheel, &mut heap);
    }

    #[test]
    fn empty_wheel_pops_none_and_clock_is_monotone() {
        let mut wheel: TimerWheel<u32> = TimerWheel::new();
        assert_eq!(wheel.pop(), None);
        wheel.push(5, 1, 0);
        wheel.push(5, 2, 1);
        wheel.push(1 << 40, 3, 2); // deep coarse level
        let mut last = (0, 0);
        let mut popped = 0;
        while let Some((t, seq, _)) = wheel.pop() {
            assert!((t, seq) > last, "ordering violated");
            assert_eq!(wheel.now(), t);
            last = (t, seq);
            popped += 1;
        }
        assert_eq!(popped, 3);
        assert_eq!(wheel.pop(), None, "drained wheel stays empty");
    }
}
