//! Latency statistics: log-bucketed histograms (HDR-style) with
//! accurate-enough tail quantiles, plus online mean/variance.
//!
//! Values are recorded in microseconds (f64).  Buckets grow geometrically
//! at 2% per bucket, giving ≤2% quantile error over [1 µs, ~17 min] with
//! ~1.2 k buckets — plenty for P99/P99.9 SLO work.

/// Geometric-bucket histogram.
#[derive(Debug, Clone)]
pub struct Histogram {
    buckets: Vec<u64>,
    count: u64,
    sum: f64,
    min: f64,
    max: f64,
}

const GROWTH: f64 = 1.02;
const MIN_VALUE: f64 = 1.0; // 1 µs resolution floor
const NUM_BUCKETS: usize = 1500;

#[inline]
fn bucket_of(v: f64) -> usize {
    if v <= MIN_VALUE {
        return 0;
    }
    let b = (v / MIN_VALUE).ln() / GROWTH.ln();
    (b as usize + 1).min(NUM_BUCKETS - 1)
}

#[inline]
fn bucket_upper(i: usize) -> f64 {
    if i == 0 {
        MIN_VALUE
    } else {
        MIN_VALUE * GROWTH.powi(i as i32)
    }
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Self {
        Histogram {
            buckets: vec![0; NUM_BUCKETS],
            count: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    pub fn record(&mut self, v: f64) {
        debug_assert!(v.is_finite() && v >= 0.0, "bad sample {v}");
        self.buckets[bucket_of(v)] += 1;
        self.count += 1;
        self.sum += v;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    pub fn record_n(&mut self, v: f64, n: u64) {
        self.buckets[bucket_of(v)] += n;
        self.count += n;
        self.sum += v * n as f64;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    pub fn min(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.min
        }
    }

    pub fn max(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.max
        }
    }

    /// Quantile q in [0, 1]; returns bucket upper bound (clamped to
    /// observed min/max so p0/p100 are exact).
    pub fn quantile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let target = ((q * self.count as f64).ceil() as u64).clamp(1, self.count);
        let mut acc = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            acc += c;
            if acc >= target {
                return bucket_upper(i).clamp(self.min, self.max);
            }
        }
        self.max
    }

    pub fn p50(&self) -> f64 {
        self.quantile(0.50)
    }
    pub fn p90(&self) -> f64 {
        self.quantile(0.90)
    }
    pub fn p99(&self) -> f64 {
        self.quantile(0.99)
    }
    pub fn p999(&self) -> f64 {
        self.quantile(0.999)
    }

    pub fn merge(&mut self, other: &Histogram) {
        for (a, b) in self.buckets.iter_mut().zip(&other.buckets) {
            *a += b;
        }
        self.count += other.count;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    /// Exact number of samples ≤ threshold (integer bucket counts — SLO
    /// compliance math must count failures exactly; deriving them back
    /// from [`Histogram::fraction_le`] loses precision at large n).
    pub fn count_le(&self, threshold: f64) -> u64 {
        let cutoff = bucket_of(threshold);
        self.buckets[..=cutoff.min(self.buckets.len() - 1)].iter().sum()
    }

    /// Fraction of samples ≤ threshold (e.g. SLO compliance).
    pub fn fraction_le(&self, threshold: f64) -> f64 {
        if self.count == 0 {
            return 1.0;
        }
        self.count_le(threshold) as f64 / self.count as f64
    }

    pub fn summary(&self) -> Summary {
        Summary {
            count: self.count,
            mean: self.mean(),
            min: self.min(),
            p50: self.p50(),
            p90: self.p90(),
            p99: self.p99(),
            p999: self.p999(),
            max: self.max(),
        }
    }
}

/// Point-in-time summary of a histogram.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct Summary {
    pub count: u64,
    pub mean: f64,
    pub min: f64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
    pub p999: f64,
    pub max: f64,
}

impl Summary {
    /// Render in ms for human-readable tables (input stored in µs).
    pub fn fmt_ms(&self) -> String {
        format!(
            "n={:<7} mean={:8.2}ms p50={:8.2}ms p99={:8.2}ms p99.9={:8.2}ms max={:8.2}ms",
            self.count,
            self.mean / 1e3,
            self.p50 / 1e3,
            self.p99 / 1e3,
            self.p999 / 1e3,
            self.max / 1e3
        )
    }
}

/// Welford online mean/variance accumulator.
#[derive(Debug, Clone, Copy, Default)]
pub struct Online {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Online {
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn empty_histogram() {
        let h = Histogram::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.p99(), 0.0);
        assert_eq!(h.mean(), 0.0);
        assert_eq!(h.fraction_le(10.0), 1.0);
    }

    #[test]
    fn quantiles_within_bucket_error() {
        let mut h = Histogram::new();
        for i in 1..=10_000 {
            h.record(i as f64);
        }
        // exact p50 = 5000, p99 = 9900; bucket error ≤ 2%
        assert!((h.p50() - 5000.0).abs() / 5000.0 < 0.03, "p50={}", h.p50());
        assert!((h.p99() - 9900.0).abs() / 9900.0 < 0.03, "p99={}", h.p99());
        assert!((h.mean() - 5000.5).abs() < 1.0);
        assert_eq!(h.max(), 10_000.0);
        assert_eq!(h.min(), 1.0);
    }

    #[test]
    fn extreme_quantiles_clamped() {
        let mut h = Histogram::new();
        h.record(100.0);
        h.record(200.0);
        // Low quantiles land in the bucket containing 100 (≤2% error).
        let q0 = h.quantile(0.0).min(h.quantile(0.01));
        assert!((100.0..=102.5).contains(&q0), "q0={q0}");
        assert!(h.quantile(1.0) <= 200.0 + 1e-9);
    }

    #[test]
    fn merge_equals_combined() {
        let mut r = Rng::new(42);
        let (mut a, mut b, mut all) = (Histogram::new(), Histogram::new(), Histogram::new());
        for i in 0..20_000 {
            let v = r.lognormal(8.0, 1.0);
            if i % 2 == 0 {
                a.record(v)
            } else {
                b.record(v)
            }
            all.record(v);
        }
        a.merge(&b);
        assert_eq!(a.count(), all.count());
        assert_eq!(a.p99(), all.p99());
        assert!((a.mean() - all.mean()).abs() < 1e-6);
    }

    #[test]
    fn count_le_is_exact_at_float_breaking_scale() {
        // n = 2^53 + 2 with one failure: the float path (acc/count then
        // n·(1−fraction)) loses the low bit of acc and reports 2 failed
        // samples; the integer path must report exactly 1.
        let n = (1u64 << 53) + 2;
        let mut h = Histogram::new();
        h.record_n(10.0, n - 1);
        h.record_n(1e6, 1);
        assert_eq!(h.count(), n);
        assert_eq!(h.count() - h.count_le(1000.0), 1, "exact failure count");
        let drifted = ((h.count() as f64) * (1.0 - h.fraction_le(1000.0))).round() as u64;
        assert_ne!(drifted, 1, "float derivation drifts here — the bug this pins");
    }

    #[test]
    fn fraction_le_tracks_slo() {
        let mut h = Histogram::new();
        for i in 1..=1000 {
            h.record(i as f64 * 100.0); // 100..100_000 µs
        }
        let f = h.fraction_le(50_000.0);
        assert!((f - 0.5).abs() < 0.03, "f={f}");
        assert_eq!(h.fraction_le(1e9), 1.0);
        assert!(h.fraction_le(50.0) < 0.01);
    }

    #[test]
    fn online_moments() {
        let mut o = Online::default();
        for x in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            o.push(x);
        }
        assert!((o.mean() - 5.0).abs() < 1e-12);
        assert!((o.variance() - 32.0 / 7.0).abs() < 1e-12);
    }

    #[test]
    fn sub_resolution_values() {
        let mut h = Histogram::new();
        h.record(0.0);
        h.record(0.5);
        assert_eq!(h.count(), 2);
        assert!(h.p99() <= 1.0);
    }
}
