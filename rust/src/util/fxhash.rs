//! Fast non-cryptographic hasher for the coordinator's u64-keyed hot
//! maps (request ids, user ids).  std's default SipHash is DoS-resistant
//! but ~3-4× slower; keys here are internal identifiers, not
//! attacker-controlled strings, so a multiply-xor finalizer (the same
//! construction as rustc's FxHash/splitmix) is appropriate.

use std::hash::{BuildHasherDefault, Hasher};

/// Multiply-xor hasher specialised for integer keys.
#[derive(Default, Clone, Copy)]
pub struct FxHasher {
    state: u64,
}

const SEED: u64 = 0x51_7c_c1_b7_27_22_0a_95;

impl Hasher for FxHasher {
    #[inline]
    fn finish(&self) -> u64 {
        self.state
    }

    #[inline]
    fn write(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.write_u8(b);
        }
    }

    #[inline]
    fn write_u8(&mut self, n: u8) {
        self.write_u64(n as u64);
    }

    #[inline]
    fn write_u32(&mut self, n: u32) {
        self.write_u64(n as u64);
    }

    #[inline]
    fn write_u64(&mut self, n: u64) {
        let mut z = self.state.rotate_left(5) ^ n;
        z = z.wrapping_mul(SEED);
        z ^= z >> 32;
        self.state = z;
    }

    #[inline]
    fn write_usize(&mut self, n: usize) {
        self.write_u64(n as u64);
    }
}

/// `HashMap` with the fast hasher.
pub type FxHashMap<K, V> = std::collections::HashMap<K, V, BuildHasherDefault<FxHasher>>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn distributes_sequential_keys() {
        // Sequential ids must not collide in low bits (bucket index).
        let mut buckets = [0u32; 64];
        for k in 0..64_000u64 {
            let mut h = FxHasher::default();
            h.write_u64(k);
            buckets[(h.finish() & 63) as usize] += 1;
        }
        let (min, max) = (buckets.iter().min().unwrap(), buckets.iter().max().unwrap());
        assert!(*min > 700 && *max < 1300, "skewed buckets: {min}..{max}");
    }

    #[test]
    fn map_roundtrip() {
        let mut m: FxHashMap<u64, u64> = FxHashMap::default();
        for k in 0..10_000u64 {
            m.insert(k, k * 3);
        }
        for k in 0..10_000u64 {
            assert_eq!(m[&k], k * 3);
        }
        assert_eq!(m.len(), 10_000);
    }
}
