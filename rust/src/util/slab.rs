//! Generational slab: dense, index-addressed per-request storage for the
//! steady-state-allocation-free hot path.
//!
//! The relay-race control plane retires every request it admits, so its
//! per-request tables churn at line rate.  A hash map pays for that churn
//! twice — hashing on every event and an eventual rehash as the table
//! breathes — and `remove` drops any buffers the entry owned.  The slab
//! instead hands out [`SlabKey`] handles (slot index + generation):
//!
//! * **O(1) dense access** — events address `entries[idx]` directly, no
//!   hashing, no probing;
//! * **use-after-retire safety** — releasing a slot bumps its generation,
//!   so a stale handle (a late ψ completion for a request that already
//!   fell back) misses instead of aliasing the slot's next tenant;
//! * **buffer pooling** — `release` vacates a slot but leaves its value in
//!   place, and [`Slab::insert_with`] hands the recycled value to the
//!   caller to reset, so `Vec`s owned by the entry keep their capacity
//!   across tenants.  Once the live high-water mark is reached, inserting
//!   and releasing allocate nothing.
//!
//! [`SecondaryMap`] lets another subsystem (an engine's timing table)
//! attach its own per-request state to the same keys without sharing the
//! slab itself.

use std::fmt;

/// Handle to a slab slot: index plus the generation it was issued under.
#[derive(Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct SlabKey {
    idx: u32,
    gen: u32,
}

impl SlabKey {
    /// Slot index (stable for the entry's lifetime; reused after release).
    pub fn index(self) -> usize {
        self.idx as usize
    }

    /// Packed `(generation, index)` form for logs and ordering.
    pub fn packed(self) -> u64 {
        ((self.gen as u64) << 32) | self.idx as u64
    }
}

impl fmt::Debug for SlabKey {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "k{}v{}", self.idx, self.gen)
    }
}

struct Entry<T> {
    gen: u32,
    live: bool,
    value: T,
}

/// Generational slab with slot-value recycling (see module docs).
pub struct Slab<T> {
    entries: Vec<Entry<T>>,
    free: Vec<u32>,
    live: usize,
}

impl<T: Default> Slab<T> {
    pub fn new() -> Slab<T> {
        Slab { entries: Vec::new(), free: Vec::new(), live: 0 }
    }

    pub fn with_capacity(n: usize) -> Slab<T> {
        Slab { entries: Vec::with_capacity(n), free: Vec::with_capacity(n), live: 0 }
    }

    /// Live entries.
    pub fn len(&self) -> usize {
        self.live
    }

    pub fn is_empty(&self) -> bool {
        self.live == 0
    }

    /// Slots ever allocated (the high-water mark).
    pub fn capacity(&self) -> usize {
        self.entries.len()
    }

    /// Claim a slot and hand its (recycled) value to `init` for a full
    /// reset.  `init` MUST overwrite every field it relies on — the value
    /// is a previous tenant's, kept so owned buffers retain capacity.
    pub fn insert_with(&mut self, init: impl FnOnce(&mut T)) -> SlabKey {
        let idx = match self.free.pop() {
            Some(i) => i,
            None => {
                self.entries.push(Entry { gen: 0, live: false, value: T::default() });
                (self.entries.len() - 1) as u32
            }
        };
        let e = &mut self.entries[idx as usize];
        debug_assert!(!e.live, "free list handed out a live slot");
        e.live = true;
        init(&mut e.value);
        self.live += 1;
        SlabKey { idx, gen: e.gen }
    }

    pub fn contains(&self, key: SlabKey) -> bool {
        self.get(key).is_some()
    }

    pub fn get(&self, key: SlabKey) -> Option<&T> {
        match self.entries.get(key.idx as usize) {
            Some(e) if e.live && e.gen == key.gen => Some(&e.value),
            _ => None,
        }
    }

    pub fn get_mut(&mut self, key: SlabKey) -> Option<&mut T> {
        match self.entries.get_mut(key.idx as usize) {
            Some(e) if e.live && e.gen == key.gen => Some(&mut e.value),
            _ => None,
        }
    }

    /// Vacate the slot, keeping its value in place for the next tenant.
    /// Bumps the generation so the released key (and any copies of it)
    /// stop resolving.  Returns whether the key was live.
    pub fn release(&mut self, key: SlabKey) -> bool {
        match self.entries.get_mut(key.idx as usize) {
            Some(e) if e.live && e.gen == key.gen => {
                e.live = false;
                e.gen = e.gen.wrapping_add(1);
                self.free.push(key.idx);
                self.live -= 1;
                true
            }
            _ => false,
        }
    }
}

impl<T: Default> Default for Slab<T> {
    fn default() -> Slab<T> {
        Slab::new()
    }
}

/// Per-key side storage addressed by another slab's [`SlabKey`]s: dense
/// O(1) access with the same generation check, so a host engine can keep
/// its own per-request state (timings, trace rows) keyed by the
/// coordinator's handles without a hash map.
pub struct SecondaryMap<T> {
    entries: Vec<(u32, Option<T>)>,
    live: usize,
}

impl<T> SecondaryMap<T> {
    pub fn new() -> SecondaryMap<T> {
        SecondaryMap { entries: Vec::new(), live: 0 }
    }

    pub fn len(&self) -> usize {
        self.live
    }

    pub fn is_empty(&self) -> bool {
        self.live == 0
    }

    /// Insert under `key`, returning what the same generation previously
    /// held.  A value left behind by an *older* generation is dropped;
    /// inserting with a key older than the slot's stored generation is
    /// rejected (no-op, `value` dropped) — a stale handle must never
    /// clobber the live tenant, matching the generation checks on
    /// `get`/`get_mut`/`remove`.
    pub fn insert(&mut self, key: SlabKey, value: T) -> Option<T> {
        let idx = key.index();
        if idx >= self.entries.len() {
            self.entries.resize_with(idx + 1, || (0, None));
        }
        let e = &mut self.entries[idx];
        if e.0 > key.gen {
            debug_assert!(false, "stale-generation insert at slot {idx}");
            return None;
        }
        let same_gen = e.0 == key.gen;
        let prev = e.1.take();
        if prev.is_some() {
            self.live -= 1;
        }
        e.0 = key.gen;
        e.1 = Some(value);
        self.live += 1;
        if same_gen {
            prev
        } else {
            None
        }
    }

    pub fn get(&self, key: SlabKey) -> Option<&T> {
        match self.entries.get(key.index()) {
            Some((gen, Some(v))) if *gen == key.gen => Some(v),
            _ => None,
        }
    }

    pub fn get_mut(&mut self, key: SlabKey) -> Option<&mut T> {
        match self.entries.get_mut(key.index()) {
            Some((gen, v @ Some(_))) if *gen == key.gen => v.as_mut(),
            _ => None,
        }
    }

    pub fn remove(&mut self, key: SlabKey) -> Option<T> {
        match self.entries.get_mut(key.index()) {
            Some((gen, v)) if *gen == key.gen => {
                let out = v.take();
                if out.is_some() {
                    self.live -= 1;
                }
                out
            }
            _ => None,
        }
    }
}

impl<T> Default for SecondaryMap<T> {
    fn default() -> SecondaryMap<T> {
        SecondaryMap::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn insert_get_release_roundtrip() {
        let mut s: Slab<u32> = Slab::new();
        let a = s.insert_with(|v| *v = 10);
        let b = s.insert_with(|v| *v = 20);
        assert_eq!(s.len(), 2);
        assert_eq!(s.get(a), Some(&10));
        assert_eq!(s.get(b), Some(&20));
        *s.get_mut(a).unwrap() += 1;
        assert_eq!(s.get(a), Some(&11));
        assert!(s.release(a));
        assert_eq!(s.len(), 1);
        assert_eq!(s.get(a), None, "released key stops resolving");
        assert!(!s.release(a), "double release is a no-op");
        assert_eq!(s.get(b), Some(&20), "other entries unaffected");
    }

    #[test]
    fn stale_generation_never_aliases_new_tenant() {
        let mut s: Slab<u32> = Slab::new();
        let a = s.insert_with(|v| *v = 1);
        s.release(a);
        let b = s.insert_with(|v| *v = 2);
        assert_eq!(b.index(), a.index(), "slot reused");
        assert_ne!(a, b, "generation differs");
        assert_eq!(s.get(a), None, "stale handle misses");
        assert_eq!(s.get_mut(a), None);
        assert_eq!(s.get(b), Some(&2));
        assert!(!s.release(a), "stale release must not evict the new tenant");
        assert_eq!(s.get(b), Some(&2));
    }

    #[test]
    fn recycled_slots_keep_buffer_capacity() {
        let mut s: Slab<Vec<u64>> = Slab::new();
        let a = s.insert_with(|v| {
            v.clear();
            v.extend_from_slice(&[1, 2, 3, 4, 5, 6, 7, 8]);
        });
        let cap = s.get(a).unwrap().capacity();
        assert!(cap >= 8);
        s.release(a);
        // The next tenant of the slot sees the old buffer to reset — its
        // capacity survives, so steady-state inserts never allocate.
        let b = s.insert_with(|v| {
            assert!(v.capacity() >= 8, "recycled buffer lost its capacity");
            v.clear();
            v.push(9);
        });
        assert_eq!(s.get(b).unwrap().as_slice(), &[9]);
        assert!(s.get(b).unwrap().capacity() >= cap.min(8));
    }

    #[test]
    fn high_water_mark_bounds_slot_growth() {
        let mut s: Slab<u64> = Slab::new();
        // Churn 10k requests at 16 live: only 16 slots ever exist.
        let mut live = std::collections::VecDeque::new();
        for i in 0..10_000u64 {
            live.push_back(s.insert_with(|v| *v = i));
            if live.len() > 16 {
                assert!(s.release(live.pop_front().unwrap()));
            }
        }
        assert_eq!(s.capacity(), 17, "slots bounded by the live high-water mark");
        assert_eq!(s.len(), 16);
    }

    #[test]
    fn secondary_map_tracks_generations() {
        let mut s: Slab<u32> = Slab::new();
        let mut side: SecondaryMap<&'static str> = SecondaryMap::new();
        let a = s.insert_with(|v| *v = 1);
        assert_eq!(side.insert(a, "first"), None);
        assert_eq!(side.get(a), Some(&"first"));
        s.release(a);
        let b = s.insert_with(|v| *v = 2);
        assert_eq!(b.index(), a.index());
        // The stale tenant is invisible under the new key and dropped on
        // overwrite; the stale key no longer reads or removes anything.
        assert_eq!(side.get(b), None);
        assert_eq!(side.insert(b, "second"), None);
        assert_eq!(side.get(a), None);
        assert_eq!(side.remove(a), None);
        assert_eq!(side.remove(b), Some("second"));
        assert_eq!(side.len(), 0);
        assert_eq!(side.remove(b), None);
    }

    #[test]
    #[cfg_attr(debug_assertions, should_panic(expected = "stale-generation insert"))]
    fn secondary_map_rejects_stale_insert() {
        let mut s: Slab<u32> = Slab::new();
        let mut side: SecondaryMap<&'static str> = SecondaryMap::new();
        let a = s.insert_with(|v| *v = 1);
        s.release(a);
        let b = s.insert_with(|v| *v = 2);
        side.insert(b, "live");
        // A stale handle must never clobber the live tenant: debug builds
        // assert; release builds no-op and drop the value.
        assert_eq!(side.insert(a, "stale"), None);
        assert_eq!(side.get(b), Some(&"live"));
    }

    #[test]
    fn secondary_map_same_generation_overwrites() {
        let mut s: Slab<u32> = Slab::new();
        let mut side: SecondaryMap<u64> = SecondaryMap::new();
        let a = s.insert_with(|v| *v = 1);
        assert_eq!(side.insert(a, 10), None);
        assert_eq!(side.insert(a, 11), Some(10));
        assert_eq!(side.len(), 1);
        assert_eq!(side.get(a), Some(&11));
    }
}
