//! Deterministic parallel run executor for independent evaluation cells.
//!
//! The figure/sim grids are embarrassingly parallel — every (scenario,
//! policy, engine) cell builds its own seeded simulator and shares no
//! state — but their *output* must stay byte-identical at any `--jobs`
//! count.  [`map_indexed`] guarantees that by separating scheduling from
//! ordering: worker threads claim cell indices from a shared counter (so
//! a slow cell never idles the pool), and results are merged back into
//! **declaration order** before the caller sees them.  Printing,
//! persistence and error propagation all happen on the caller's thread,
//! in order, after the barrier.
//!
//! `std::thread::scope` only — no extra dependencies, no unsafe.  A cell
//! is "parallel-safe" iff it reaches shared state only through `&`
//! (configs, workload templates) and derives all randomness from its own
//! seed; see ROADMAP "Architecture notes (PR 5)".

use std::sync::atomic::{AtomicUsize, Ordering};

use anyhow::{ensure, Result};

use crate::util::cli::Args;

/// Parse the shared `--jobs N` flag (default 1 = serial; the serial path
/// does not spawn at all, so single-job runs are exactly the old code).
pub fn jobs_from_args(args: &Args) -> Result<usize> {
    let jobs = args.get_usize("jobs", 1)?;
    ensure!(jobs >= 1, "--jobs must be >= 1, got {jobs}");
    Ok(jobs)
}

/// Evaluate `f(0..n)` on up to `jobs` worker threads and return the
/// results in index order.  `f` must be safe to call concurrently for
/// distinct indices; each index is evaluated exactly once.
///
/// A panic in any cell propagates to the caller after the scope joins —
/// no result is silently dropped.
pub fn map_indexed<T, F>(jobs: usize, n: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    if jobs <= 1 || n <= 1 {
        return (0..n).map(f).collect();
    }
    let next = AtomicUsize::new(0);
    let mut out: Vec<Option<T>> = Vec::with_capacity(n);
    out.resize_with(n, || None);
    std::thread::scope(|scope| {
        let workers: Vec<_> = (0..jobs.min(n))
            .map(|_| {
                scope.spawn(|| {
                    let mut got: Vec<(usize, T)> = Vec::new();
                    loop {
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= n {
                            break;
                        }
                        got.push((i, f(i)));
                    }
                    got
                })
            })
            .collect();
        for w in workers {
            for (i, v) in w.join().expect("parallel cell panicked") {
                debug_assert!(out[i].is_none(), "cell {i} computed twice");
                out[i] = Some(v);
            }
        }
    });
    out.into_iter().map(|v| v.expect("every cell computed exactly once")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn results_in_declaration_order_at_any_job_count() {
        let f = |i: usize| i * i + 1;
        let serial: Vec<usize> = (0..37).map(f).collect();
        for jobs in [1, 2, 4, 16, 64] {
            assert_eq!(map_indexed(jobs, 37, f), serial, "jobs={jobs}");
        }
    }

    #[test]
    fn every_cell_runs_exactly_once() {
        use std::sync::atomic::AtomicU64;
        let counts: Vec<AtomicU64> = (0..100).map(|_| AtomicU64::new(0)).collect();
        let out = map_indexed(8, 100, |i| {
            counts[i].fetch_add(1, Ordering::Relaxed);
            i
        });
        assert_eq!(out, (0..100).collect::<Vec<_>>());
        assert!(counts.iter().all(|c| c.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn empty_and_tiny_inputs() {
        assert_eq!(map_indexed(4, 0, |i| i), Vec::<usize>::new());
        assert_eq!(map_indexed(4, 1, |i| i + 7), vec![7]);
    }

    #[test]
    fn more_jobs_than_cells_is_fine() {
        assert_eq!(map_indexed(32, 3, |i| i), vec![0, 1, 2]);
    }

    #[test]
    fn jobs_flag_parses_and_rejects_zero() {
        let parse = |v: &[&str]| {
            Args::parse(std::iter::once("p".to_string()).chain(v.iter().map(|s| s.to_string())))
                .unwrap()
        };
        assert_eq!(jobs_from_args(&parse(&["figure"])).unwrap(), 1);
        assert_eq!(jobs_from_args(&parse(&["figure", "--jobs", "4"])).unwrap(), 4);
        assert!(jobs_from_args(&parse(&["figure", "--jobs", "0"])).is_err());
    }
}
