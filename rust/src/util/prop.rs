//! Property-based test driver (the offline vendor set has no `proptest`).
//!
//! `check(name, cases, f)` runs `f` against `cases` independently seeded
//! RNGs.  On failure it retries the failing seed with progressively
//! simpler "shrink hints" is out of scope — instead the failing seed is
//! printed so the case is exactly reproducible with `check_seed`.

use crate::util::rng::Rng;

/// Run a randomized property. `f` returns Err(description) on violation.
pub fn check<F>(name: &str, cases: u64, f: F)
where
    F: Fn(&mut Rng) -> Result<(), String>,
{
    let base = base_seed(name);
    for i in 0..cases {
        let seed = base.wrapping_add(i);
        let mut rng = Rng::new(seed);
        if let Err(msg) = f(&mut rng) {
            panic!(
                "property '{name}' failed on case {i} (seed {seed:#x}): {msg}\n\
                 reproduce with prop::check_seed(\"{name}\", {seed:#x}, f)"
            );
        }
    }
}

/// Re-run a single failing case by seed.
pub fn check_seed<F>(name: &str, seed: u64, f: F)
where
    F: Fn(&mut Rng) -> Result<(), String>,
{
    let mut rng = Rng::new(seed);
    if let Err(msg) = f(&mut rng) {
        panic!("property '{name}' failed (seed {seed:#x}): {msg}");
    }
}

fn base_seed(name: &str) -> u64 {
    // FNV-1a over the property name + optional env override for CI sweeps.
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in name.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    if let Ok(s) = std::env::var("RELAYGR_PROP_SEED") {
        if let Ok(v) = s.parse::<u64>() {
            return h ^ v;
        }
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut hits = 0u64;
        // interior mutability not needed: use a cell via RefCell-free trick
        let counter = std::cell::Cell::new(0u64);
        check("add-commutes", 64, |rng| {
            counter.set(counter.get() + 1);
            let a = rng.next_u64() >> 1;
            let b = rng.next_u64() >> 1;
            if a + b == b + a {
                Ok(())
            } else {
                Err("math is broken".into())
            }
        });
        hits += counter.get();
        assert_eq!(hits, 64);
    }

    #[test]
    #[should_panic(expected = "property 'always-fails' failed")]
    fn failing_property_panics_with_seed() {
        check("always-fails", 4, |_| Err("nope".into()));
    }

    #[test]
    fn seeds_are_stable_across_runs() {
        assert_eq!(base_seed("x"), base_seed("x"));
        assert_ne!(base_seed("x"), base_seed("y"));
    }
}
