//! Tiny CLI argument parser (no `clap` in the offline vendor set).
//!
//! Grammar: `prog <subcommand> [positionals...] [--key value | --key=value | --flag]`.
//! Unknown keys are collected and can be rejected by the caller for
//! strictness.  Typed getters parse on demand with contextual errors.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub program: String,
    pub positionals: Vec<String>,
    kv: BTreeMap<String, String>,
    flags: Vec<String>,
}

#[derive(Debug)]
pub struct CliError(pub String);

impl fmt::Display for CliError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for CliError {}

impl Args {
    /// Parse from an iterator of raw arguments (excluding argv[0] handled here).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Result<Args, CliError> {
        let mut it = argv.into_iter();
        let program = it.next().unwrap_or_else(|| "relaygr".into());
        let mut args = Args { program, ..Default::default() };
        let rest: Vec<String> = it.collect();
        let mut i = 0;
        while i < rest.len() {
            let a = &rest[i];
            if let Some(stripped) = a.strip_prefix("--") {
                if stripped.is_empty() {
                    return Err(CliError("bare '--' not supported".into()));
                }
                if let Some((k, v)) = stripped.split_once('=') {
                    args.kv.insert(k.to_string(), v.to_string());
                } else if i + 1 < rest.len() && !rest[i + 1].starts_with("--") {
                    args.kv.insert(stripped.to_string(), rest[i + 1].clone());
                    i += 1;
                } else {
                    args.flags.push(stripped.to_string());
                }
            } else {
                args.positionals.push(a.clone());
            }
            i += 1;
        }
        Ok(args)
    }

    pub fn from_env() -> Result<Args, CliError> {
        Args::parse(std::env::args())
    }

    /// First positional = subcommand.
    pub fn subcommand(&self) -> Option<&str> {
        self.positionals.first().map(String::as_str)
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.kv.get(key).map(String::as_str)
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64, CliError> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| CliError(format!("--{key}: expected number, got '{v}'"))),
        }
    }

    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize, CliError> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| CliError(format!("--{key}: expected integer, got '{v}'"))),
        }
    }

    pub fn get_u64(&self, key: &str, default: u64) -> Result<u64, CliError> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| CliError(format!("--{key}: expected integer, got '{v}'"))),
        }
    }

    /// Comma-separated list of numbers, e.g. `--lens 1024,2048,4096`.
    pub fn get_usize_list(&self, key: &str, default: &[usize]) -> Result<Vec<usize>, CliError> {
        match self.get(key) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .map(|p| {
                    p.trim()
                        .parse()
                        .map_err(|_| CliError(format!("--{key}: bad element '{p}'")))
                })
                .collect(),
        }
    }

    /// Keys the caller never consumed (for strict validation).
    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.kv.keys().map(String::as_str).chain(self.flags.iter().map(String::as_str))
    }
}

/// Help text builder shared by the binary's subcommands.
pub struct Help {
    pub name: &'static str,
    pub about: &'static str,
    pub usage: &'static str,
    pub options: Vec<(&'static str, &'static str)>,
}

impl Help {
    pub fn render(&self) -> String {
        let mut s = format!("{} — {}\n\nUSAGE:\n  {}\n", self.name, self.about, self.usage);
        if !self.options.is_empty() {
            s.push_str("\nOPTIONS:\n");
            for (opt, desc) in &self.options {
                s.push_str(&format!("  {opt:<28} {desc}\n"));
            }
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(v: &[&str]) -> Args {
        Args::parse(std::iter::once("prog".to_string()).chain(v.iter().map(|s| s.to_string())))
            .unwrap()
    }

    #[test]
    fn subcommand_and_positionals() {
        let a = parse(&["figure", "fig11a"]);
        assert_eq!(a.subcommand(), Some("figure"));
        assert_eq!(a.positionals, vec!["figure", "fig11a"]);
    }

    #[test]
    fn kv_both_syntaxes() {
        let a = parse(&["serve", "--qps", "100", "--seed=7"]);
        assert_eq!(a.get("qps"), Some("100"));
        assert_eq!(a.get_u64("seed", 0).unwrap(), 7);
        assert_eq!(a.get_f64("qps", 0.0).unwrap(), 100.0);
    }

    #[test]
    fn bool_flags() {
        let a = parse(&["serve", "--verbose", "--qps", "5"]);
        assert!(a.has_flag("verbose"));
        assert!(!a.has_flag("quiet"));
        assert_eq!(a.get("qps"), Some("5"));
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = parse(&["x", "--a", "--b", "v"]);
        assert!(a.has_flag("a"));
        assert_eq!(a.get("b"), Some("v"));
    }

    #[test]
    fn lists_and_defaults() {
        let a = parse(&["x", "--lens", "1,2, 3"]);
        assert_eq!(a.get_usize_list("lens", &[9]).unwrap(), vec![1, 2, 3]);
        assert_eq!(a.get_usize_list("other", &[9]).unwrap(), vec![9]);
        assert_eq!(a.get_or("missing", "dflt"), "dflt");
    }

    #[test]
    fn type_errors() {
        let a = parse(&["x", "--n", "abc"]);
        assert!(a.get_usize("n", 0).is_err());
        assert!(a.get_f64("n", 0.0).is_err());
    }

    #[test]
    fn negative_number_as_value() {
        // "--delta -5" : '-5' doesn't start with '--' so it's a value.
        let a = parse(&["x", "--delta", "-5"]);
        assert_eq!(a.get_f64("delta", 0.0).unwrap(), -5.0);
    }
}
