//! Minimal JSON parser/serializer (the vendor set has no `serde`).
//!
//! Supports the full JSON grammar (objects, arrays, strings with escape
//! sequences incl. `\uXXXX`, numbers, booleans, null) with byte-position
//! error reporting.  Object key order is preserved (Vec of pairs) so that
//! written configs/results diff cleanly.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

/// Parse error with byte offset.
#[derive(Debug, Clone, PartialEq)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // -- constructors ------------------------------------------------------
    pub fn obj() -> Json {
        Json::Obj(Vec::new())
    }

    /// Insert/overwrite a key in an object (panics on non-object).
    pub fn set(&mut self, key: &str, value: Json) -> &mut Self {
        match self {
            Json::Obj(pairs) => {
                if let Some(p) = pairs.iter_mut().find(|(k, _)| k == key) {
                    p.1 = value;
                } else {
                    pairs.push((key.to_string(), value));
                }
            }
            _ => panic!("Json::set on non-object"),
        }
        self
    }

    // -- accessors ---------------------------------------------------------
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(pairs) => pairs.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn at(&self, idx: usize) -> Option<&Json> {
        match self {
            Json::Arr(v) => v.get(idx),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|n| n as i64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|n| if n >= 0.0 { Some(n as usize) } else { None })
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_object(&self) -> Option<&[(String, Json)]> {
        match self {
            Json::Obj(v) => Some(v),
            _ => None,
        }
    }

    /// Typed lookup helpers with descriptive errors (for manifest/config).
    pub fn req_f64(&self, key: &str) -> Result<f64, JsonError> {
        self.get(key)
            .and_then(Json::as_f64)
            .ok_or_else(|| JsonError { pos: 0, msg: format!("missing number field '{key}'") })
    }

    pub fn req_usize(&self, key: &str) -> Result<usize, JsonError> {
        self.get(key)
            .and_then(Json::as_usize)
            .ok_or_else(|| JsonError { pos: 0, msg: format!("missing usize field '{key}'") })
    }

    pub fn req_str(&self, key: &str) -> Result<&str, JsonError> {
        self.get(key)
            .and_then(Json::as_str)
            .ok_or_else(|| JsonError { pos: 0, msg: format!("missing string field '{key}'") })
    }

    pub fn req_array(&self, key: &str) -> Result<&[Json], JsonError> {
        self.get(key)
            .and_then(Json::as_array)
            .ok_or_else(|| JsonError { pos: 0, msg: format!("missing array field '{key}'") })
    }

    // -- parsing -----------------------------------------------------------
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // -- serialization -----------------------------------------------------
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => write_num(out, *n),
            Json::Str(s) => write_str(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    x.write(out, indent, depth + 1);
                }
                if !v.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(pairs) => {
                out.push('{');
                for (i, (k, x)) in pairs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_str(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    x.write(out, indent, depth + 1);
                }
                if !pairs.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

impl From<f64> for Json {
    fn from(n: f64) -> Json {
        Json::Num(n)
    }
}
impl From<usize> for Json {
    fn from(n: usize) -> Json {
        Json::Num(n as f64)
    }
}
impl From<u64> for Json {
    fn from(n: u64) -> Json {
        Json::Num(n as f64)
    }
}
impl From<i64> for Json {
    fn from(n: i64) -> Json {
        Json::Num(n as f64)
    }
}
impl From<bool> for Json {
    fn from(b: bool) -> Json {
        Json::Bool(b)
    }
}
impl From<&str> for Json {
    fn from(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Json {
        Json::Str(s)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Json {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}
impl From<BTreeMap<String, f64>> for Json {
    fn from(m: BTreeMap<String, f64>) -> Json {
        Json::Obj(m.into_iter().map(|(k, v)| (k, Json::Num(v))).collect())
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_num(out: &mut String, n: f64) {
    if n.is_finite() {
        if n == n.trunc() && n.abs() < 9e15 {
            out.push_str(&format!("{}", n as i64));
        } else {
            out.push_str(&format!("{n}"));
        }
    } else {
        out.push_str("null"); // JSON has no Inf/NaN
    }
}

fn write_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut pairs = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(pairs));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            pairs.push((key, v));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(pairs));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'n') => s.push('\n'),
                        Some(b'r') => s.push('\r'),
                        Some(b't') => s.push('\t'),
                        Some(b'u') => {
                            self.pos += 1;
                            let cp = self.hex4()?;
                            // Surrogate pair handling.
                            let c = if (0xD800..0xDC00).contains(&cp) {
                                if self.peek() == Some(b'\\') {
                                    self.pos += 1;
                                    self.expect(b'u')?;
                                    let lo = self.hex4()?;
                                    let combined = 0x10000
                                        + (((cp - 0xD800) as u32) << 10)
                                        + (lo - 0xDC00) as u32;
                                    char::from_u32(combined)
                                } else {
                                    None
                                }
                            } else {
                                char::from_u32(cp as u32)
                            };
                            s.push(c.ok_or_else(|| self.err("bad \\u escape"))?);
                            continue; // hex4 advanced pos already
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let rest = &self.b[self.pos..];
                    let len = utf8_len(rest[0]);
                    let chunk = std::str::from_utf8(&rest[..len.min(rest.len())])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    s.push_str(chunk);
                    self.pos += len;
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u16, JsonError> {
        if self.pos + 4 > self.b.len() {
            return Err(self.err("truncated \\u escape"));
        }
        let hex = std::str::from_utf8(&self.b[self.pos..self.pos + 4])
            .map_err(|_| self.err("bad hex"))?;
        let v = u16::from_str_radix(hex, 16).map_err(|_| self.err("bad hex"))?;
        self.pos += 4;
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        text.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }
}

fn utf8_len(b: u8) -> usize {
    match b {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for text in ["null", "true", "false", "0", "-1", "3.25", "1e3", "\"hi\""] {
            let v = Json::parse(text).unwrap();
            let v2 = Json::parse(&v.to_string()).unwrap();
            assert_eq!(v, v2, "{text}");
        }
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "x\ny", "c": null}], "d": -2.5e-1}"#).unwrap();
        assert_eq!(v.get("d").unwrap().as_f64().unwrap(), -0.25);
        assert_eq!(v.get("a").unwrap().at(2).unwrap().req_str("b").unwrap(), "x\ny");
        assert_eq!(v.get("a").unwrap().at(2).unwrap().get("c"), Some(&Json::Null));
    }

    #[test]
    fn unicode_escapes() {
        let v = Json::parse(r#""é€😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "é€😀");
        // serializer keeps raw utf-8; reparse matches
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn errors_report_position() {
        let e = Json::parse("{\"a\": }").unwrap_err();
        assert!(e.pos >= 6, "{e}");
        assert!(Json::parse("[1, 2,]").is_err());
        assert!(Json::parse("[1] x").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn key_order_preserved_and_set() {
        let mut o = Json::obj();
        o.set("z", 1.0.into()).set("a", 2.0.into()).set("z", 3.0.into());
        assert_eq!(o.to_string(), r#"{"z":3,"a":2}"#);
    }

    #[test]
    fn pretty_reparses() {
        let v = Json::parse(r#"{"a":[1,2],"b":{"c":true}}"#).unwrap();
        let p = v.to_string_pretty();
        assert_eq!(Json::parse(&p).unwrap(), v);
        assert!(p.contains('\n'));
    }

    #[test]
    fn large_ints_exact() {
        let v = Json::parse("1752190000").unwrap();
        assert_eq!(v.as_i64().unwrap(), 1752190000);
        assert_eq!(v.to_string(), "1752190000");
    }
}
