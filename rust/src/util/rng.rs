//! Deterministic PRNG + workload-distribution sampling.
//!
//! The offline vendor set has no `rand` crate, so this implements
//! xoshiro256** (Blackman/Vigna) seeded via SplitMix64, plus the
//! distributions the workload generator and simulator need: uniform,
//! normal (Box–Muller), exponential, Poisson, Zipf (bounded,
//! rejection-inversion), log-normal, Bernoulli, shuffle and choice.
//!
//! Everything in the simulator derives from an explicit seed so that
//! every figure run is exactly reproducible.

/// xoshiro256** PRNG.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second output of Box–Muller.
    gauss_spare: Option<f64>,
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create a generator from a 64-bit seed (any value, including 0).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, gauss_spare: None }
    }

    /// Derive an independent child generator (for per-entity streams).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1) with 53 bits of precision.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Unbiased uniform integer in [0, n) (Lemire-style rejection).
    pub fn range_u64(&mut self, n: u64) -> u64 {
        assert!(n > 0, "range_u64(0)");
        let threshold = n.wrapping_neg() % n;
        loop {
            let r = self.next_u64();
            if r >= threshold {
                return r % n;
            }
        }
    }

    /// Uniform usize in [lo, hi).
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi > lo);
        lo + self.range_u64((hi - lo) as u64) as usize
    }

    /// Uniform f64 in [lo, hi).
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller (with spare caching).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.gauss_spare.take() {
            return z;
        }
        loop {
            let u1 = self.f64();
            if u1 <= f64::EPSILON {
                continue;
            }
            let u2 = self.f64();
            let r = (-2.0 * u1.ln()).sqrt();
            let (s, c) = (2.0 * std::f64::consts::PI * u2).sin_cos();
            self.gauss_spare = Some(r * s);
            return r * c;
        }
    }

    /// Normal with given mean / standard deviation.
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Log-normal with the given *underlying* mu/sigma.
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Exponential inter-arrival with the given rate (per unit time).
    pub fn exponential(&mut self, rate: f64) -> f64 {
        assert!(rate > 0.0);
        let u = 1.0 - self.f64(); // (0, 1]
        -u.ln() / rate
    }

    /// Poisson-distributed count (Knuth for small λ, normal approx above).
    pub fn poisson(&mut self, lambda: f64) -> u64 {
        assert!(lambda >= 0.0);
        if lambda == 0.0 {
            return 0;
        }
        if lambda > 64.0 {
            let v = self.normal_ms(lambda, lambda.sqrt()).round();
            return if v < 0.0 { 0 } else { v as u64 };
        }
        let l = (-lambda).exp();
        let mut k = 0u64;
        let mut p = 1.0;
        loop {
            p *= self.f64();
            if p <= l {
                return k;
            }
            k += 1;
        }
    }

    /// Zipf over ranks 1..=n with exponent `s` (s > 0), by inverse CDF on a
    /// precomputed table-free harmonic approximation (rejection sampling
    /// after Jason Crease / rejection-inversion). Good enough for workload
    /// skew; exactness is not required.
    pub fn zipf(&mut self, n: u64, s: f64) -> u64 {
        debug_assert!(n >= 1);
        if n == 1 {
            return 1;
        }
        // Rejection-inversion (W. Hörmann, G. Derflinger).
        let s = if (s - 1.0).abs() < 1e-9 { 1.0 + 1e-9 } else { s };
        let nf = n as f64;
        let h = |x: f64| -> f64 { ((x + 0.5).powf(1.0 - s) - 1.0) / (1.0 - s) };
        let h_inv = |y: f64| -> f64 { (1.0 + y * (1.0 - s)).powf(1.0 / (1.0 - s)) - 0.5 };
        let hx0 = h(0.5) - 1.0;
        let hn = h(nf + 0.5);
        loop {
            let u = hx0 + self.f64() * (hn - hx0);
            let x = h_inv(u);
            let k = (x + 0.5).floor().clamp(1.0, nf);
            if u >= h(k + 0.5) - k.powf(-s) {
                return k as u64;
            }
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.range_u64((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// Uniformly pick an element.
    pub fn choice<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.range(0, xs.len())]
    }

    /// Vector of standard-normal f32s (for synthetic embeddings).
    pub fn normal_vec_f32(&mut self, n: usize, scale: f32) -> Vec<f32> {
        (0..n).map(|_| self.normal() as f32 * scale).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::new(8);
        assert_ne!(Rng::new(7).next_u64(), c.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(1);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn range_bounds_and_coverage() {
        let mut r = Rng::new(2);
        let mut seen = [false; 10];
        for _ in 0..1_000 {
            seen[r.range(0, 10)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let n = 200_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            sum += x;
            sq += x * x;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(4);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.exponential(2.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn poisson_mean() {
        let mut r = Rng::new(5);
        for &lam in &[0.5, 4.0, 30.0, 200.0] {
            let n = 20_000;
            let mean: f64 = (0..n).map(|_| r.poisson(lam) as f64).sum::<f64>() / n as f64;
            assert!((mean - lam).abs() < lam.max(1.0) * 0.05, "λ={lam} mean={mean}");
        }
    }

    #[test]
    fn zipf_skew_and_bounds() {
        let mut r = Rng::new(6);
        let n = 50_000;
        let mut counts = [0u64; 101];
        for _ in 0..n {
            let k = r.zipf(100, 1.2);
            assert!((1..=100).contains(&k));
            counts[k as usize] += 1;
        }
        // rank 1 should dominate rank 10 roughly by 10^1.2 ≈ 15.8
        assert!(counts[1] > counts[10] * 8, "{} vs {}", counts[1], counts[10]);
        assert!(counts[1] > counts[50] * 20);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(9);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_diverge() {
        let mut r = Rng::new(10);
        let mut a = r.fork(1);
        let mut b = r.fork(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
