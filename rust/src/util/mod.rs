//! Substrate utilities built from scratch for the offline environment:
//! JSON, PRNG + distributions, histograms/stats, CLI parsing, logging and
//! a property-test driver (standing in for serde/rand/hdrhistogram/clap/
//! proptest, none of which are in the vendored crate set).

pub mod cli;
pub mod fxhash;
pub mod json;
pub mod logging;
pub mod parallel;
pub mod prop;
pub mod rng;
pub mod sharded;
pub mod slab;
pub mod stats;
