//! User-id-sharded hash map for the per-user hot state of the relay
//! coordinator stack (trigger footprint window, hierarchy single-flight,
//! per-instance wait queues).
//!
//! At 10M-user scale a single `FxHashMap` concentrates every probe,
//! every resize and every tombstone in one table: a resize stalls the
//! event loop for the whole population and the table's peak footprint is
//! never returned.  Sharding by a strong hash of the user id bounds each
//! table to `1/SHARDS` of the population, so resizes are short and
//! independent and the per-probe working set is cache-friendlier.
//!
//! Determinism: every operation is keyed — there is no cross-shard
//! iteration order on any decision path.  `for_each` visits shards in
//! fixed index order (and keys within a shard in the map's order), so it
//! must only be used for order-insensitive aggregation (tests, drains
//! that sort afterwards), which the callers uphold.

use crate::util::fxhash::FxHashMap;

/// Number of shards (power of two; chosen so a 10M-entry map keeps each
/// shard under ~160k entries).
pub const SHARDS: usize = 64;

/// Strong 64-bit mix of the user id (splitmix64 finalizer) so shard
/// selection is independent of the in-shard FxHash probe sequence and of
/// any structure in the id space (sequential ids, coldstart minting).
#[inline]
pub fn shard_of(user: u64) -> usize {
    let mut z = user.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    (z ^ (z >> 31)) as usize & (SHARDS - 1)
}

/// A `u64 → V` map sharded by [`shard_of`] the key.  Keyed operations
/// mirror the `HashMap` API; whole-map operations (`len`, `clear`,
/// `for_each`, `retain`) aggregate over the fixed shard order.
#[derive(Debug, Clone)]
pub struct ShardedMap<V> {
    shards: Box<[FxHashMap<u64, V>]>,
}

impl<V> Default for ShardedMap<V> {
    fn default() -> Self {
        Self::new()
    }
}

impl<V> ShardedMap<V> {
    pub fn new() -> Self {
        let shards: Vec<FxHashMap<u64, V>> =
            (0..SHARDS).map(|_| FxHashMap::default()).collect();
        ShardedMap { shards: shards.into_boxed_slice() }
    }

    #[inline]
    fn shard(&self, key: u64) -> &FxHashMap<u64, V> {
        &self.shards[shard_of(key)]
    }

    #[inline]
    fn shard_mut(&mut self, key: u64) -> &mut FxHashMap<u64, V> {
        &mut self.shards[shard_of(key)]
    }

    #[inline]
    pub fn get(&self, key: u64) -> Option<&V> {
        self.shard(key).get(&key)
    }

    #[inline]
    pub fn get_mut(&mut self, key: u64) -> Option<&mut V> {
        self.shard_mut(key).get_mut(&key)
    }

    #[inline]
    pub fn contains_key(&self, key: u64) -> bool {
        self.shard(key).contains_key(&key)
    }

    #[inline]
    pub fn insert(&mut self, key: u64, value: V) -> Option<V> {
        self.shard_mut(key).insert(key, value)
    }

    #[inline]
    pub fn remove(&mut self, key: u64) -> Option<V> {
        self.shard_mut(key).remove(&key)
    }

    /// `entry(key).or_insert_with(default)` equivalent.
    #[inline]
    pub fn or_insert_with<F: FnOnce() -> V>(&mut self, key: u64, default: F) -> &mut V {
        self.shard_mut(key).entry(key).or_insert_with(default)
    }

    pub fn len(&self) -> usize {
        self.shards.iter().map(FxHashMap::len).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.shards.iter().all(FxHashMap::is_empty)
    }

    pub fn clear(&mut self) {
        for s in self.shards.iter_mut() {
            s.clear();
        }
    }

    /// Largest single shard (tests pin the anti-concentration property).
    pub fn max_shard_len(&self) -> usize {
        self.shards.iter().map(FxHashMap::len).max().unwrap_or(0)
    }

    /// Visit every entry, shards in fixed index order.  Only for
    /// order-insensitive aggregation — never on a decision path.
    pub fn for_each<F: FnMut(u64, &V)>(&self, mut f: F) {
        for s in self.shards.iter() {
            for (&k, v) in s.iter() {
                f(k, v);
            }
        }
    }

    pub fn retain<F: FnMut(u64, &mut V) -> bool>(&mut self, mut f: F) {
        for s in self.shards.iter_mut() {
            s.retain(|&k, v| f(k, v));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn keyed_ops_match_hashmap_semantics() {
        let mut m: ShardedMap<u32> = ShardedMap::new();
        assert!(m.is_empty());
        assert_eq!(m.insert(7, 1), None);
        assert_eq!(m.insert(7, 2), Some(1));
        assert_eq!(m.get(7), Some(&2));
        *m.get_mut(7).unwrap() += 1;
        assert_eq!(m.get(7), Some(&3));
        assert!(m.contains_key(7));
        assert!(!m.contains_key(8));
        assert_eq!(m.remove(7), Some(3));
        assert_eq!(m.remove(7), None);
        assert!(m.is_empty());
    }

    #[test]
    fn or_insert_with_inserts_once() {
        let mut m: ShardedMap<Vec<u32>> = ShardedMap::new();
        m.or_insert_with(5, Vec::new).push(1);
        m.or_insert_with(5, Vec::new).push(2);
        assert_eq!(m.get(5), Some(&vec![1, 2]));
        assert_eq!(m.len(), 1);
    }

    #[test]
    fn sequential_ids_spread_across_shards() {
        // Sequential user ids (the generator's id space) must not pile
        // into one shard — the whole point of the strong mix.
        let mut m: ShardedMap<()> = ShardedMap::new();
        let n = 100_000u64;
        for u in 0..n {
            m.insert(u, ());
        }
        assert_eq!(m.len(), n as usize);
        let ideal = n as usize / SHARDS;
        assert!(
            m.max_shard_len() < ideal * 2,
            "max shard {} vs ideal {ideal}",
            m.max_shard_len()
        );
    }

    #[test]
    fn retain_and_for_each_cover_all_entries() {
        let mut m: ShardedMap<u64> = ShardedMap::new();
        for u in 0..1000u64 {
            m.insert(u, u * 2);
        }
        let mut sum = 0u64;
        m.for_each(|k, &v| {
            assert_eq!(v, k * 2);
            sum += v;
        });
        assert_eq!(sum, (0..1000u64).map(|u| u * 2).sum());
        m.retain(|k, _| k % 2 == 0);
        assert_eq!(m.len(), 500);
        m.clear();
        assert!(m.is_empty());
    }

    #[test]
    fn clone_is_deep() {
        let mut a: ShardedMap<u64> = ShardedMap::new();
        a.insert(1, 10);
        let mut b = a.clone();
        b.insert(1, 20);
        assert_eq!(a.get(1), Some(&10));
        assert_eq!(b.get(1), Some(&20));
    }
}
