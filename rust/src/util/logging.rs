//! Minimal `log` facade backend: stderr, leveled, timestamped relative to
//! process start. Level from `RELAYGR_LOG` (error|warn|info|debug|trace),
//! default `info`.

use std::sync::atomic::{AtomicBool, Ordering};
use std::time::Instant;

use log::{Level, LevelFilter, Metadata, Record};

static INSTALLED: AtomicBool = AtomicBool::new(false);

struct Logger {
    start: Instant,
}

impl log::Log for Logger {
    fn enabled(&self, metadata: &Metadata) -> bool {
        metadata.level() <= log::max_level()
    }

    fn log(&self, record: &Record) {
        if !self.enabled(record.metadata()) {
            return;
        }
        let t = self.start.elapsed().as_secs_f64();
        let lvl = match record.level() {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        eprintln!("[{t:10.3}s {lvl} {}] {}", record.target(), record.args());
    }

    fn flush(&self) {}
}

/// Install the logger (idempotent).
pub fn init() {
    if INSTALLED.swap(true, Ordering::SeqCst) {
        return;
    }
    let level = match std::env::var("RELAYGR_LOG").as_deref() {
        Ok("error") => LevelFilter::Error,
        Ok("warn") => LevelFilter::Warn,
        Ok("info") => LevelFilter::Info,
        Ok("debug") => LevelFilter::Debug,
        Ok("trace") => LevelFilter::Trace,
        Ok(other) => {
            // One warning straight to stderr (the logger is not installed
            // yet), then the default — a typo'd level should not silently
            // change verbosity.
            eprintln!(
                "RELAYGR_LOG={other:?} is not a log level \
                 (error|warn|info|debug|trace); defaulting to info"
            );
            LevelFilter::Info
        }
        Err(_) => LevelFilter::Info,
    };
    let logger = Box::leak(Box::new(Logger { start: Instant::now() }));
    if log::set_logger(logger).is_ok() {
        log::set_max_level(level);
    }
}

#[cfg(test)]
mod tests {
    #[test]
    fn init_is_idempotent() {
        super::init();
        super::init();
        log::info!("logging smoke test");
    }
}
