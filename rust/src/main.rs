//! `relaygr` CLI — leader entrypoint for the RelayGR reproduction.
//!
//! Subcommands (see `relaygr help`):
//!   selftest   — load artifacts, run prefix→rank vs full, check ε-bound
//!   inspect    — list artifact variants and ψ footprints
//!   serve      — live threaded serving demo on real PJRT executables
//!   calibrate  — measure live costs and write calibration JSON
//!   figure     — regenerate a paper figure/table (fig1..fig15b, table1)
//!   plan       — admission-control capacity planning (Eqs. 1–3)
//!   trace      — record a workload to a compact binary trace / replay one
//!   explain    — reconstruct one request's lifecycle from a span sidecar

use anyhow::{anyhow, bail, Context, Result};

use relaygr::util::cli::Args;
use relaygr::util::logging;

fn main() {
    logging::init();
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("argument error: {e}");
            std::process::exit(2);
        }
    };
    let code = match run(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn run(args: &Args) -> Result<()> {
    match args.subcommand() {
        Some("selftest") => selftest(args),
        Some("inspect") => inspect(args),
        Some("serve") => relaygr::serve::cli::run(args),
        Some("calibrate") => relaygr::serve::calibrate::run(args),
        Some("figure") => relaygr::figures::run(args),
        Some("plan") => relaygr::relay::trigger::plan_cli(args),
        Some("trace") => trace_cli(args),
        Some("explain") => explain_cli(args),
        Some("help") | None => {
            print!("{}", help());
            Ok(())
        }
        Some(other) => bail!("unknown subcommand '{other}' (try `relaygr help`)"),
    }
}

fn help() -> String {
    "relaygr — cross-stage relay-race inference for generative recommendation\n\
     \n\
     USAGE:\n  relaygr <subcommand> [options]\n\
     \n\
     SUBCOMMANDS:\n\
     \x20 selftest   load artifacts, check ε-equivalence of cached vs full inference\n\
     \x20 inspect    list artifact variants and ψ footprints (Table 1)\n\
     \x20 serve      live threaded serving demo (real PJRT executables)\n\
     \x20 calibrate  measure live execution costs, write calibration JSON\n\
     \x20 figure     regenerate a paper figure/table: fig1 fig3 fig11a..d fig12\n\
     \x20            fig13a..d fig14a..d fig15a fig15b table1 scenarios tiers\n\
     \x20            segments admission batching breakdown cells faults all\n\
     \x20 plan       admission-control capacity planning (Eqs. 1–3); with\n\
     \x20            --admission adaptive also the closed-loop operating\n\
     \x20            bands and per-scenario initial operating points\n\
     \x20 trace      record <out> [workload flags] — capture the scenario's\n\
     \x20            arrival stream as a compact binary trace (delta-encoded,\n\
     \x20            varint ids; O(1) memory); replay <path> [--engine sim|\n\
     \x20            reference] — bit-identical re-run, prints events/sec;\n\
     \x20            inspect <path.rgsp> — summarize a recorded span sidecar\n\
     \x20 explain    <request-id> --trace <path.rgsp> — reconstruct one\n\
     \x20            request's lifecycle timeline with per-stage durations\n\
     \n\
     COMMON OPTIONS:\n\
     \x20 --artifacts <dir>     artifact directory (default: artifacts)\n\
     \x20 --seed <n>            base RNG seed (default: 42)\n\
     \x20 --scenario <name>     workload scenario: steady (default) | diurnal\n\
     \x20                       | burst | coldstart (serve + figure)\n\
     \x20 --dram-policy <name>  DRAM-tier eviction: lru (default) | lfu\n\
     \x20                       | cost | lifecycle (serve + figure/sim)\n\
     \x20 --tier <stack>        explicit lower-tier stack, top-down, e.g.\n\
     \x20                       8g:lru,500g:cost (serve + figure/sim)\n\
     \x20 --segment-cache <f>   fraction of the r1 HBM slice carved out for\n\
     \x20                       the candidate-segment cache (0 = off, default)\n\
     \x20 --zipf <s>            candidate-item popularity skew (default 1.1)\n\
     \x20 --admission <m>       admission control: static (default) | adaptive\n\
     \x20                       (+ --headroom-min/-max, --rate-mult-min/-max,\n\
     \x20                       --adapt-window; serve + figure/sim + plan)\n\
     \x20 --batch-window <us>   coordinator batch-former window in µs for\n\
     \x20                       microbatched ranking (0 = off, default;\n\
     \x20                       serve + figure/sim)\n\
     \x20 --batch-max <n>       max members per batched rank pass (default 32)\n\
     \x20 --cells <n>           coordinator cells behind the two-level router\n\
     \x20                       (default 1 = the pre-cell pool, decision-\n\
     \x20                       identical; must divide instances and servers)\n\
     \x20 --cell-picker <p>     level-1 cell pick: affinity (default) | spread\n\
     \x20 --cell-spill <r>      affinity locality-vs-load knob: spill off the\n\
     \x20                       home cell when its load exceeds r× the mean\n\
     \x20                       (default 2.0; inf = pure locality)\n\
     \x20 --cell-scenario <s>   scripted cluster churn: none (default) |\n\
     \x20                       failure | drain | elastic | rollout\n\
     \x20                       (serve + figure/sim)\n\
     \x20 --faults <spec>       deterministic fault plan: comma-separated\n\
     \x20                       psi-fail:R reload-fail:R trigger-drop:R\n\
     \x20                       spill-loss:R seg-abort:R crash@P%[:cellK]\n\
     \x20                       retry:N backoff:USus shed:R, or none (default;\n\
     \x20                       serve + figure/sim + trace replay)\n\
     \x20 --trace-spans <n>     flight-recorder span retention (0 = off,\n\
     \x20                       default; observe-only — decisions are\n\
     \x20                       bit-identical either way; serve + figure/sim)\n\
     \x20 --trace-out <path>    write retained spans to an RGSP sidecar at\n\
     \x20                       end of run (serve + trace replay)\n\
     \x20 --heartbeat <path>    JSONL metrics heartbeat sink (serve), one\n\
     \x20                       snapshot line every --heartbeat-ms (def. 1000)\n\
     \x20 --jobs <n>            worker threads for the figure/sim grids\n\
     \x20                       (default 1; output byte-identical at any n)\n"
        .to_string()
}

fn artifacts_dir(args: &Args) -> String {
    args.get_or("artifacts", "artifacts").to_string()
}

/// `relaygr trace record <out> [workload flags]` /
/// `relaygr trace replay <path> [--engine sim|reference] [--mode ...]`.
///
/// Record streams the configured scenario straight to disk (the full
/// workload config rides in the header, so a trace is self-describing);
/// replay rebuilds that config, swaps the arrival source for the file,
/// and drives the chosen engine — decisions are bit-identical to a live
/// run of the same scenario, which `tests/trace_replay.rs` pins.
fn trace_cli(args: &Args) -> Result<()> {
    use relaygr::workload::trace;

    let action = args.positionals.get(1).map(String::as_str);
    let path = args.positionals.get(2).map(String::as_str);
    match (action, path) {
        (Some("record"), Some(path)) => {
            let wl = relaygr::config::workload_config(args)?;
            let t0 = std::time::Instant::now();
            let (count, bytes) = trace::record(path, &wl)?;
            println!(
                "recorded {count} requests → {path} ({bytes} bytes, {:.2} B/request, {:.2}s)",
                bytes as f64 / count.max(1) as f64,
                t0.elapsed().as_secs_f64(),
            );
            Ok(())
        }
        (Some("replay"), Some(path)) => {
            let wl = trace::open_replay(path)?;
            let mode = relaygr::config::parse_mode(args.get_or("mode", "relaygr"))?;
            let cfg = relaygr::config::sim_config(args, mode)?;
            let t0 = std::time::Instant::now();
            match args.get_or("engine", "sim") {
                "sim" => {
                    let m = relaygr::cluster::run_sim(cfg, &wl)?;
                    let wall = t0.elapsed().as_secs_f64();
                    println!(
                        "replayed {path}: {} requests, {} sim events in {wall:.2}s \
                         ({:.0} events/sec, {:.0} requests/sec)",
                        m.completed,
                        m.sim_events,
                        m.sim_events as f64 / wall.max(1e-9),
                        m.completed as f64 / wall.max(1e-9),
                    );
                    report_cells(&m.cells);
                    report_faults(&m.faults);
                    report_spans(args, m.flight.as_deref(), wall)?;
                }
                "reference" => {
                    let r = relaygr::cluster::run_reference(&cfg, &wl)?;
                    let wall = t0.elapsed().as_secs_f64();
                    println!(
                        "replayed {path} (serialized reference): {} requests in {wall:.2}s \
                         ({:.0} requests/sec, mean rank {:.1} µs)",
                        r.outcomes.len(),
                        r.outcomes.len() as f64 / wall.max(1e-9),
                        r.mean_rank_us,
                    );
                    report_cells(&r.cells);
                    report_faults(&r.faults);
                    report_spans(args, r.flight.as_deref(), wall)?;
                }
                other => bail!("--engine {other}: expected sim | reference"),
            }
            Ok(())
        }
        (Some("inspect"), Some(path)) => {
            let f = relaygr::relay::flight::read_rgsp(path)?;
            print!("{}", relaygr::relay::flight::inspect_summary(&f));
            Ok(())
        }
        _ => bail!(
            "usage: relaygr trace record <out> [workload flags] | \
             relaygr trace replay <path> [--engine sim|reference] | \
             relaygr trace inspect <path.rgsp>"
        ),
    }
}

/// Print the cell-routing tail lines after a multi-cell replay (the CI
/// scale-smoke job greps the `cross-cell routes` total).
fn report_cells(cells: &[relaygr::relay::CellReport]) {
    if cells.len() < 2 {
        return;
    }
    let cross: u64 = cells.iter().map(|c| c.cross_routes).sum();
    let miss: u64 = cells.iter().map(|c| c.cross_psi_miss).sum();
    println!("{} cells: cross-cell routes {cross}, cross-cell psi misses {miss}", cells.len());
    for (i, c) in cells.iter().enumerate() {
        println!(
            "  C{i}: picks={} home={} spilled={} cross={} cross-psi-miss={} failures={} \
             storm-wipes={} migrated={} migration-lost={}",
            c.picks, c.home_picks, c.spilled, c.cross_routes, c.cross_psi_miss, c.failures,
            c.storm_invalidations, c.migrated, c.migration_lost,
        );
    }
}

/// Print the fault-plane tail line after a faulted replay (the CI
/// chaos-smoke job greps the recovered/shed totals).
fn report_faults(f: &relaygr::relay::fault::FaultReport) {
    if !f.any() {
        return;
    }
    let (inj, ret, rec, deg, shed) = f.totals();
    println!("faults: injected {inj} retried {ret} recovered {rec} degraded {deg} shed {shed}");
}

/// Print the flight-recorder tail line after a traced replay (span
/// throughput + the sample request id for `relaygr explain`), and write
/// the RGSP sidecar when `--trace-out` is given.
fn report_spans(args: &Args, fl: Option<&relaygr::relay::FlightRecorder>, wall: f64) -> Result<()> {
    let Some(fl) = fl else { return Ok(()) };
    println!(
        "traced {} spans ({} retained, {} dropped, {:.0} spans/sec), sample request {}",
        fl.emitted(),
        fl.retained(),
        fl.dropped(),
        fl.emitted() as f64 / wall.max(1e-9),
        fl.last_done_rid.map_or_else(|| "-".to_string(), |r| r.to_string()),
    );
    if let Some(out) = args.get("trace-out") {
        let (n, bytes) = fl.write_rgsp(out)?;
        println!("wrote {n} spans ({bytes} bytes) to {out}");
    }
    Ok(())
}

/// `relaygr explain <request-id> --trace <path.rgsp>` — reconstruct one
/// request's lifecycle timeline (per-span offsets + telescoping stage
/// durations) from a recorded span sidecar.
fn explain_cli(args: &Args) -> Result<()> {
    use relaygr::relay::flight;

    let rid: u64 = args
        .positionals
        .get(1)
        .ok_or_else(|| anyhow!("usage: relaygr explain <request-id> --trace <path.rgsp>"))?
        .parse()
        .context("request id")?;
    let path = args
        .get("trace")
        .ok_or_else(|| anyhow!("--trace <path.rgsp> is required"))?;
    let f = flight::read_rgsp(path)?;
    match flight::timeline(&f.spans, rid) {
        Some(tl) => {
            print!("{}", tl.render());
            Ok(())
        }
        None => bail!(
            "request {rid} has no spans in {path} (evicted by the {}-span retention \
             bound, or never traced)",
            f.trace_spans,
        ),
    }
}

/// Validate the python→rust bridge and the paper's ε-bound end to end:
/// run `full` inference, then `prefix`→ψ→`rank`, and compare scores.
fn selftest(args: &Args) -> Result<()> {
    use relaygr::runtime::{synth_embedding, Engine, FnKind};

    let engine = Engine::load(artifacts_dir(args))?;
    println!("platform: {}", engine.platform());
    let variants = engine.manifest.variants();
    if variants.is_empty() {
        bail!("no artifacts found — run `make artifacts`");
    }
    let mut worst: f64 = 0.0;
    for spec in &variants {
        let (Some(_), Some(_), Some(_)) = (
            engine.manifest.find(FnKind::Prefix, spec),
            engine.manifest.find(FnKind::Rank, spec),
            engine.manifest.find(FnKind::Full, spec),
        ) else {
            continue;
        };
        let prefix_m = engine.model(FnKind::Prefix, spec)?;
        let rank_m = engine.model(FnKind::Rank, spec)?;
        let full_m = engine.model(FnKind::Full, spec)?;

        let seed = args.get_u64("seed", 42)?;
        let prefix = synth_embedding(seed ^ 1, spec.prefix_len, spec.dim, 0.5);
        let incr = synth_embedding(seed ^ 2, spec.incr_len, spec.dim, 0.5);
        let items = synth_embedding(seed ^ 3, spec.num_items, spec.dim, 0.5);

        let t0 = std::time::Instant::now();
        let full = full_m.execute_host(&[&prefix, &incr, &items])?;
        let t_full = t0.elapsed();

        let t1 = std::time::Instant::now();
        let kv = prefix_m.execute_to_device(&[&prefix])?;
        let t_pre = t1.elapsed();
        let t2 = std::time::Instant::now();
        let cached = rank_m.execute_with_kv(&kv, &[&incr, &items])?;
        let t_rank = t2.elapsed();

        let eps = full
            .iter()
            .zip(&cached)
            .map(|(a, b)| (a - b).abs() as f64)
            .fold(0.0_f64, f64::max);
        worst = worst.max(eps);
        println!(
            "{:40} ε={eps:.3e}  full={:7.1?}  pre={:7.1?}  rank={:7.1?}  ψ={:.2} MB",
            spec.name(),
            t_full,
            t_pre,
            t_rank,
            kv.bytes as f64 / 1e6,
        );
        if eps > 1e-3 {
            bail!("ε-bound violated for {}: {eps}", spec.name());
        }
    }
    println!("selftest OK (worst ε = {worst:.3e})");
    Ok(())
}

/// Print the artifact inventory with ψ footprints (Table 1 arithmetic).
fn inspect(args: &Args) -> Result<()> {
    use relaygr::runtime::Manifest;

    let manifest = Manifest::load(artifacts_dir(args))?;
    println!("jax {}, {} artifacts", manifest.jax_version, manifest.artifacts.len());
    println!(
        "{:<6} {:<36} {:>6} {:>5} {:>6} {:>7} {:>6} {:>9}",
        "fn", "variant", "layers", "dim", "heads", "prefix", "items", "ψ bytes"
    );
    for a in &manifest.artifacts {
        println!(
            "{:<6} {:<36} {:>6} {:>5} {:>6} {:>7} {:>6} {:>9}",
            a.fn_kind.as_str(),
            a.spec.name(),
            a.spec.layers,
            a.spec.dim,
            a.spec.heads,
            a.spec.prefix_len,
            a.spec.num_items,
            a.spec.kv_bytes(),
        );
    }
    Ok(())
}
