//! Configuration system: typed run configs assembled from defaults, an
//! optional JSON config file (`--config path.json`) and CLI overrides.
//!
//! The precedence is CLI > file > defaults, the usual production layering.

use anyhow::{anyhow, bail, Context, Result};

use crate::cluster::SimConfig;
use crate::model::{Dtype, HardwareProfile, ModelSpec, ModelType};
use crate::relay::baseline::Mode;
use crate::relay::cell::{CellPickerKind, CellScenario};
use crate::relay::fault::FaultConfig;
use crate::relay::tier::{DramPolicy, EvictPolicy, TierConfig};
use crate::relay::trigger::{AdmissionConfig, AdmissionMode};
use crate::util::cli::Args;
use crate::util::json::Json;
use crate::workload::{ScenarioKind, WorkloadConfig};

/// Parse a `Mode` string: `baseline`, `relaygr`, `relaygr+dram<N>g`.
pub fn parse_mode(s: &str) -> Result<Mode> {
    if s == "baseline" {
        return Ok(Mode::Baseline);
    }
    if s == "relaygr" {
        return Ok(Mode::RelayGr { dram: DramPolicy::Disabled });
    }
    if let Some(rest) = s.strip_prefix("relaygr+dram") {
        let gb: usize = rest
            .strip_suffix('g')
            .ok_or_else(|| anyhow!("mode '{s}': expected relaygr+dram<N>g"))?
            .parse()
            .with_context(|| format!("mode '{s}'"))?;
        return Ok(Mode::RelayGr { dram: DramPolicy::Capacity(gb << 30) });
    }
    bail!("unknown mode '{s}' (baseline | relaygr | relaygr+dram<N>g)")
}

/// Parse an eviction policy: `lifecycle | lru | lfu | cost`.
pub fn parse_policy(s: &str) -> Result<EvictPolicy> {
    EvictPolicy::parse(s).map_err(|e| anyhow!(e))
}

/// Parse a lower-tier stack, top-down: comma-separated
/// `<size><g|m|b>[:<policy>]` items, e.g. `--tier 8g:lru,500g:cost`.
/// The policy defaults to `lru`.
pub fn parse_tiers(s: &str) -> Result<Vec<TierConfig>> {
    let mut tiers = Vec::new();
    for item in s.split(',') {
        let item = item.trim();
        let (size, policy) = match item.split_once(':') {
            Some((size, policy)) => (size, parse_policy(policy)?),
            None => (item, EvictPolicy::Lru),
        };
        let (num, shift) = match size.as_bytes().last().copied() {
            Some(b'g' | b'G') => (&size[..size.len() - 1], 30),
            Some(b'm' | b'M') => (&size[..size.len() - 1], 20),
            Some(b'b' | b'B') => (&size[..size.len() - 1], 0),
            _ => bail!("tier '{item}': expected <size><g|m|b>[:<policy>]"),
        };
        let n: usize = num.parse().with_context(|| format!("tier '{item}'"))?;
        if n == 0 {
            bail!("tier '{item}': capacity must be > 0");
        }
        tiers.push(TierConfig::new(n << shift, policy));
    }
    Ok(tiers)
}

/// Parse + validate the `--segment-cache` fraction — shared by the sim,
/// serve and figure CLIs so they agree on the accepted range (the
/// coordinator clamps defensively, but a silently clamped experiment
/// parameter is a mislabeled experiment).
pub fn parse_segment_frac(args: &Args, default: f64) -> Result<f64> {
    let frac = args.get_f64("segment-cache", default)?;
    if !(0.0..=0.9).contains(&frac) {
        bail!("--segment-cache must be in [0, 0.9], got {frac}");
    }
    Ok(frac)
}

/// Layer `--admission static|adaptive` plus the closed-loop knobs
/// (`--headroom-min/-max`, `--rate-mult-min/-max`, `--adapt-window`,
/// `--headroom-init`, `--rate-mult-init`) over `default` — shared by the
/// serve, sim/figure and `plan` CLIs so they agree on names and ranges.
pub fn parse_admission(args: &Args, default: &AdmissionConfig) -> Result<AdmissionConfig> {
    AdmissionConfig::from_args(args, default)
}

/// Apply the candidate-set flags (`--zipf`, `--cands`, `--catalog`) with
/// validation — shared by every CLI that builds a workload.
pub fn apply_candidate_flags(args: &Args, wl: &mut WorkloadConfig) -> Result<()> {
    wl.cand_zipf_s = args.get_f64("zipf", wl.cand_zipf_s)?;
    if wl.cand_zipf_s <= 0.0 {
        bail!("--zipf must be > 0, got {}", wl.cand_zipf_s);
    }
    wl.cand_per_request = args.get_usize("cands", wl.cand_per_request)?;
    wl.cand_catalog = args.get_u64("catalog", wl.cand_catalog)?;
    Ok(())
}

/// Apply a JSON object onto a [`ModelSpec`].
fn spec_from_json(mut spec: ModelSpec, j: &Json) -> Result<ModelSpec> {
    if let Some(v) = j.get("model_type").and_then(Json::as_usize) {
        spec.model_type = ModelType::from_index(v).ok_or_else(|| anyhow!("bad model_type"))?;
    }
    if let Some(v) = j.get("layers").and_then(Json::as_usize) {
        spec.layers = v;
    }
    if let Some(v) = j.get("dim").and_then(Json::as_usize) {
        spec.dim = v;
    }
    if let Some(v) = j.get("heads").and_then(Json::as_usize) {
        spec.heads = v;
    }
    if let Some(v) = j.get("prefix_len").and_then(Json::as_usize) {
        spec.prefix_len = v;
    }
    if let Some(v) = j.get("incr_len").and_then(Json::as_usize) {
        spec.incr_len = v;
    }
    if let Some(v) = j.get("num_items").and_then(Json::as_usize) {
        spec.num_items = v;
    }
    if let Some(v) = j.get("dtype").and_then(Json::as_str) {
        spec.dtype = match v {
            "float32" | "fp32" => Dtype::F32,
            "float16" | "fp16" => Dtype::F16,
            other => bail!("bad dtype '{other}'"),
        };
    }
    Ok(spec)
}

/// Build a [`SimConfig`] from mode + optional file + CLI overrides.
pub fn sim_config(args: &Args, mode: Mode) -> Result<SimConfig> {
    let mut cfg = SimConfig::standard(mode);
    if let Some(path) = args.get("config") {
        let text = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
        let j = Json::parse(&text).with_context(|| format!("parsing {path}"))?;
        if let Some(spec_j) = j.get("spec") {
            cfg.spec = spec_from_json(cfg.spec, spec_j)?;
        }
        if let Some(v) = j.get("hw").and_then(Json::as_str) {
            cfg.hw = HardwareProfile::by_name(v).ok_or_else(|| anyhow!("unknown hw '{v}'"))?;
        }
        if let Some(v) = j.get("n_instances").and_then(Json::as_usize) {
            cfg.router.n_instances = v;
        }
        if let Some(v) = j.get("servers").and_then(Json::as_usize) {
            cfg.router.servers = v;
        }
        if let Some(v) = j.get("r2").and_then(Json::as_f64) {
            cfg.router.r2 = v;
        }
        if let Some(v) = j.get("m_slots").and_then(Json::as_usize) {
            cfg.m_slots = v;
        }
        if let Some(v) = j.get("seed").and_then(Json::as_usize) {
            cfg.seed = v as u64;
        }
        if let Some(v) = j.get("dram_policy").and_then(Json::as_str) {
            cfg.dram_policy = parse_policy(v)?;
        }
        if let Some(v) = j.get("tiers").and_then(Json::as_str) {
            cfg.tiers = Some(parse_tiers(v)?);
        }
        if let Some(v) = j.get("segment_cache").and_then(Json::as_f64) {
            cfg.segment_frac = v;
        }
        if let Some(v) = j.get("admission").and_then(Json::as_str) {
            cfg.admission.mode = AdmissionMode::parse(v).context("config file")?;
        }
        if let Some(v) = j.get("headroom_min").and_then(Json::as_f64) {
            cfg.admission.headroom_min = v;
        }
        if let Some(v) = j.get("headroom_max").and_then(Json::as_f64) {
            cfg.admission.headroom_max = v;
        }
        if let Some(v) = j.get("batch_window").and_then(Json::as_usize) {
            cfg.batch_window_us = v as u64;
        }
        if let Some(v) = j.get("batch_max").and_then(Json::as_usize) {
            cfg.batch_max = v;
        }
        if let Some(v) = j.get("trace_spans").and_then(Json::as_usize) {
            cfg.trace_spans = v;
        }
        if let Some(v) = j.get("cells").and_then(Json::as_usize) {
            cfg.cells = v;
        }
        if let Some(v) = j.get("cell_picker").and_then(Json::as_str) {
            cfg.cell_picker = CellPickerKind::parse(v).context("config file")?;
        }
        if let Some(v) = j.get("cell_spill").and_then(Json::as_f64) {
            cfg.cell_spill = v;
        }
        if let Some(v) = j.get("cell_scenario").and_then(Json::as_str) {
            cfg.cell_scenario = CellScenario::parse(v).context("config file")?;
        }
        if let Some(v) = j.get("faults").and_then(Json::as_str) {
            cfg.faults = FaultConfig::parse(v).context("config file")?;
        }
    }
    // CLI overrides.
    if let Some(hw) = args.get("hw") {
        cfg.hw = HardwareProfile::by_name(hw).ok_or_else(|| anyhow!("unknown hw '{hw}'"))?;
    }
    cfg.router.n_instances = args.get_usize("instances", cfg.router.n_instances)?;
    cfg.router.servers = args.get_usize("servers", cfg.router.servers)?;
    cfg.router.r2 = args.get_f64("r2", cfg.router.r2)?;
    cfg.m_slots = args.get_usize("slots", cfg.m_slots)?;
    cfg.spec.layers = args.get_usize("layers", cfg.spec.layers)?;
    cfg.spec.dim = args.get_usize("dim", cfg.spec.dim)?;
    cfg.spec.num_items = args.get_usize("items", cfg.spec.num_items)?;
    cfg.long_threshold = args.get_usize("long-threshold", cfg.long_threshold)?;
    if let Some(p) = args.get("dram-policy") {
        cfg.dram_policy = parse_policy(p)?;
    }
    if let Some(t) = args.get("tier") {
        cfg.tiers = Some(parse_tiers(t)?);
    }
    cfg.segment_frac = parse_segment_frac(args, cfg.segment_frac)?;
    cfg.admission = parse_admission(args, &cfg.admission)?;
    cfg.batch_window_us = args.get_u64("batch-window", cfg.batch_window_us)?;
    cfg.batch_max = args.get_usize("batch-max", cfg.batch_max)?;
    if cfg.batch_max == 0 {
        bail!("--batch-max must be >= 1 (use --batch-window 0 to disable batching)");
    }
    cfg.trace_spans = args.get_usize("trace-spans", cfg.trace_spans)?;
    cfg.cells = args.get_usize("cells", cfg.cells)?;
    if let Some(p) = args.get("cell-picker") {
        cfg.cell_picker = CellPickerKind::parse(p)?;
    }
    cfg.cell_spill = args.get_f64("cell-spill", cfg.cell_spill)?;
    if cfg.cell_spill <= 0.0 {
        bail!("--cell-spill must be > 0 (use inf for pure locality), got {}", cfg.cell_spill);
    }
    if let Some(s) = args.get("cell-scenario") {
        cfg.cell_scenario = CellScenario::parse(s)?;
    }
    if let Some(s) = args.get("faults") {
        cfg.faults = FaultConfig::parse(s)?;
    }
    cfg.seed = args.get_u64("seed", cfg.seed)?;
    if cfg.spec.dim % cfg.spec.heads != 0 {
        // Keep heads consistent when dim is overridden.
        cfg.spec.heads = (cfg.spec.dim / 64).max(1);
    }
    Ok(cfg)
}

/// Build a [`WorkloadConfig`] from an optional config file + CLI
/// overrides (same precedence as [`sim_config`]).
pub fn workload_config(args: &Args) -> Result<WorkloadConfig> {
    let mut wl = WorkloadConfig::default();
    if let Some(path) = args.get("config") {
        let text = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
        let j = Json::parse(&text).with_context(|| format!("parsing {path}"))?;
        if let Some(v) = j.get("zipf").and_then(Json::as_f64) {
            wl.cand_zipf_s = v;
        }
        if let Some(v) = j.get("cands").and_then(Json::as_usize) {
            wl.cand_per_request = v;
        }
        if let Some(v) = j.get("catalog").and_then(Json::as_usize) {
            wl.cand_catalog = v as u64;
        }
    }
    wl.qps = args.get_f64("qps", wl.qps)?;
    wl.duration_us = (args.get_f64("duration-s", wl.duration_us as f64 / 1e6)? * 1e6) as u64;
    wl.num_users = args.get_u64("users", wl.num_users)?;
    // Requests carry 32-bit user ids, and the coldstart scenario mints
    // cold users *above* `num_users` — cap the base population at 2^31 so
    // minted ids can never silently truncate.  Reject, don't clamp: a
    // clamped population is a mislabeled experiment.
    const MAX_USERS: u64 = 1 << 31;
    if wl.num_users > MAX_USERS {
        bail!(
            "--users {} exceeds the supported maximum {MAX_USERS} (requests carry \
             32-bit user ids; coldstart mints cold users above the base population)",
            wl.num_users
        );
    }
    wl.long_frac = args.get_f64("long-frac", wl.long_frac)?;
    wl.long_threshold = args.get_usize("long-threshold", wl.long_threshold)?;
    wl.max_prefix = args.get_usize("max-prefix", wl.max_prefix)?;
    wl.refresh_prob = args.get_f64("refresh-prob", wl.refresh_prob)?;
    if let Some(s) = args.get("scenario") {
        wl.scenario = ScenarioKind::parse(s).map_err(|e| anyhow!(e))?;
    }
    apply_candidate_flags(args, &mut wl)?;
    wl.seed = args.get_u64("seed", wl.seed)?;
    Ok(wl)
}

/// Serialize a SimConfig summary for run records.
pub fn sim_config_json(cfg: &SimConfig, wl: &WorkloadConfig) -> Json {
    let mut j = Json::obj();
    j.set("mode", cfg.mode.label().as_str().into())
        .set("hw", cfg.hw.name.as_str().into())
        .set("spec", cfg.spec.name().as_str().into())
        .set("instances", cfg.router.n_instances.into())
        .set("servers", cfg.router.servers.into())
        .set("r2", cfg.router.r2.into())
        .set("m_slots", cfg.m_slots.into())
        .set("qps", wl.qps.into())
        .set("duration_s", (wl.duration_us as f64 / 1e6).into())
        .set("scenario", wl.scenario.label().into())
        .set(
            "tiers",
            cfg.tier_stack()
                .iter()
                .map(TierConfig::label)
                .collect::<Vec<_>>()
                .join(",")
                .as_str()
                .into(),
        )
        .set("segment_cache", cfg.segment_frac.into())
        .set("admission", cfg.admission.label().into())
        .set("batch_window", cfg.batch_window_us.into())
        .set("batch_max", cfg.batch_max.into())
        .set("cells", cfg.cells.into())
        .set("cell_picker", cfg.cell_picker.label().into())
        .set("cell_scenario", cfg.cell_scenario.label().into())
        .set("faults", cfg.faults.label().as_str().into())
        .set("zipf", wl.cand_zipf_s.into())
        .set("seed", cfg.seed.into());
    j
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(v: &[&str]) -> Args {
        Args::parse(std::iter::once("prog".to_string()).chain(v.iter().map(|s| s.to_string())))
            .unwrap()
    }

    #[test]
    fn mode_parsing() {
        assert_eq!(parse_mode("baseline").unwrap(), Mode::Baseline);
        assert_eq!(
            parse_mode("relaygr").unwrap(),
            Mode::RelayGr { dram: DramPolicy::Disabled }
        );
        assert_eq!(
            parse_mode("relaygr+dram500g").unwrap(),
            Mode::RelayGr { dram: DramPolicy::Capacity(500 << 30) }
        );
        assert!(parse_mode("remote").is_err());
        assert!(parse_mode("relaygr+dramXg").is_err());
    }

    #[test]
    fn cli_overrides_apply() {
        let a = args(&["figure", "--dim", "512", "--instances", "40", "--qps", "123"]);
        let cfg = sim_config(&a, Mode::Baseline).unwrap();
        assert_eq!(cfg.spec.dim, 512);
        assert_eq!(cfg.router.n_instances, 40);
        let wl = workload_config(&a).unwrap();
        assert!((wl.qps - 123.0).abs() < 1e-9);
    }

    #[test]
    fn config_file_layering() {
        let dir = std::env::temp_dir().join("relaygr_cfg_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("cfg.json");
        std::fs::write(
            &path,
            r#"{"spec": {"layers": 16, "dim": 128}, "hw": "ascend-310", "r2": 0.2}"#,
        )
        .unwrap();
        // CLI --dim beats the file; file layers/hw survive.
        let a = args(&["x", "--config", path.to_str().unwrap(), "--dim", "256"]);
        let cfg = sim_config(&a, Mode::Baseline).unwrap();
        assert_eq!(cfg.spec.layers, 16);
        assert_eq!(cfg.spec.dim, 256);
        assert_eq!(cfg.hw.name, "ascend-310");
        assert!((cfg.router.r2 - 0.2).abs() < 1e-12);
    }

    #[test]
    fn tier_stack_parsing() {
        assert_eq!(
            parse_tiers("8g:lru,500g:cost").unwrap(),
            vec![
                TierConfig::new(8 << 30, EvictPolicy::Lru),
                TierConfig::new(500 << 30, EvictPolicy::CostAware),
            ]
        );
        // Policy defaults to lru; m suffix scales by MiB.
        assert_eq!(
            parse_tiers("64m").unwrap(),
            vec![TierConfig::new(64 << 20, EvictPolicy::Lru)]
        );
        assert!(parse_tiers("8").is_err(), "missing unit suffix");
        assert!(parse_tiers("8g:mru").is_err(), "unknown policy");
        assert!(parse_tiers("0g").is_err(), "zero capacity");
        // Labels round-trip through the parser, including sub-GiB and
        // sub-MiB tiers.
        for stack in ["8g:lru,500g:cost", "64m:lfu", "1536m:lifecycle", "4097b:cost"] {
            let tiers = parse_tiers(stack).unwrap();
            let label =
                tiers.iter().map(TierConfig::label).collect::<Vec<_>>().join(",");
            assert_eq!(parse_tiers(&label).unwrap(), tiers, "label '{label}'");
        }
    }

    #[test]
    fn dram_policy_and_tier_flags_apply() {
        let mode = Mode::RelayGr { dram: DramPolicy::Capacity(500 << 30) };
        // Default: the mode's DRAM capacity under LRU.
        let plain = sim_config(&args(&["figure"]), mode).unwrap();
        assert_eq!(
            plain.tier_stack(),
            vec![TierConfig::new(500 << 30, EvictPolicy::Lru)]
        );
        // --dram-policy switches the derived tier's eviction policy.
        let cost = sim_config(&args(&["figure", "--dram-policy", "cost"]), mode).unwrap();
        assert_eq!(cost.tier_stack()[0].policy, EvictPolicy::CostAware);
        // --tier replaces the whole stack.
        let stack =
            sim_config(&args(&["figure", "--tier", "4g:lfu,64g:cost"]), mode).unwrap();
        assert_eq!(stack.tier_stack().len(), 2);
        assert_eq!(stack.tier_stack()[1].policy, EvictPolicy::CostAware);
        assert!(sim_config(&args(&["figure", "--dram-policy", "mru"]), mode).is_err());
    }

    #[test]
    fn user_population_beyond_u32_budget_is_rejected() {
        // The cap itself is accepted...
        let ok = args(&["figure", "--users", "2147483648"]);
        assert_eq!(workload_config(&ok).unwrap().num_users, 1 << 31);
        // ...one past it is an error naming the id width, never a
        // silently truncated population.
        let bad = args(&["figure", "--users", "2147483649"]);
        let err = workload_config(&bad).unwrap_err().to_string();
        assert!(err.contains("32-bit"), "unexpected error: {err}");
    }

    #[test]
    fn scenario_flag_selects_workload_shape() {
        let a = args(&["figure", "--scenario", "burst"]);
        let wl = workload_config(&a).unwrap();
        assert_eq!(wl.scenario.label(), "burst");
        let bad = args(&["figure", "--scenario", "lunar"]);
        assert!(workload_config(&bad).is_err());
        // Default stays steady — the seed workload.
        let none = args(&["figure"]);
        assert_eq!(workload_config(&none).unwrap().scenario, ScenarioKind::Steady);
    }

    #[test]
    fn segment_cache_and_zipf_flags_apply() {
        // Defaults: segment reuse off, candidate Zipf at the workload
        // default — the PR 2-identical configuration.
        let none = args(&["figure"]);
        assert_eq!(sim_config(&none, Mode::Baseline).unwrap().segment_frac, 0.0);
        let wl = workload_config(&none).unwrap();
        assert!((wl.cand_zipf_s - 1.1).abs() < 1e-12);
        // CLI flags.
        let a = args(&["figure", "--segment-cache", "0.25", "--zipf", "1.3", "--cands", "32"]);
        let cfg = sim_config(&a, Mode::Baseline).unwrap();
        assert!((cfg.segment_frac - 0.25).abs() < 1e-12);
        let wl = workload_config(&a).unwrap();
        assert!((wl.cand_zipf_s - 1.3).abs() < 1e-12);
        assert_eq!(wl.cand_per_request, 32);
        // Out-of-range values rejected.
        assert!(sim_config(&args(&["figure", "--segment-cache", "1.5"]), Mode::Baseline).is_err());
        assert!(workload_config(&args(&["figure", "--zipf", "-1"])).is_err());
        // File keys layer under CLI.
        let dir = std::env::temp_dir().join("relaygr_seg_cfg_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("cfg.json");
        std::fs::write(&path, r#"{"segment_cache": 0.4, "zipf": 1.6}"#).unwrap();
        let f = args(&["x", "--config", path.to_str().unwrap()]);
        assert!((sim_config(&f, Mode::Baseline).unwrap().segment_frac - 0.4).abs() < 1e-12);
        assert!((workload_config(&f).unwrap().cand_zipf_s - 1.6).abs() < 1e-12);
        let over = args(&["x", "--config", path.to_str().unwrap(), "--segment-cache", "0.1"]);
        assert!((sim_config(&over, Mode::Baseline).unwrap().segment_frac - 0.1).abs() < 1e-12);
    }

    #[test]
    fn admission_flags_and_file_keys_layer() {
        // Default: static — the decision-identical pre-adaptive trigger.
        let none = sim_config(&args(&["figure"]), Mode::Baseline).unwrap();
        assert_eq!(none.admission.mode, AdmissionMode::Static);
        // CLI flag flips the mode and knobs.
        let a = args(&["figure", "--admission", "adaptive", "--headroom-min", "0.55"]);
        let cfg = sim_config(&a, Mode::Baseline).unwrap();
        assert!(cfg.admission.is_adaptive());
        assert!((cfg.admission.headroom_min - 0.55).abs() < 1e-12);
        assert!(sim_config(&args(&["figure", "--admission", "psychic"]), Mode::Baseline).is_err());
        // File key layers under the CLI.
        let dir = std::env::temp_dir().join("relaygr_adm_cfg_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("cfg.json");
        std::fs::write(&path, r#"{"admission": "adaptive", "headroom_min": 0.6}"#).unwrap();
        let f = args(&["x", "--config", path.to_str().unwrap()]);
        let cfg = sim_config(&f, Mode::Baseline).unwrap();
        assert!(cfg.admission.is_adaptive());
        assert!((cfg.admission.headroom_min - 0.6).abs() < 1e-12);
        let over = args(&["x", "--config", path.to_str().unwrap(), "--admission", "static"]);
        let over_cfg = sim_config(&over, Mode::Baseline).unwrap();
        assert_eq!(over_cfg.admission.mode, AdmissionMode::Static);
        // The run record carries the admission label.
        let j = sim_config_json(&cfg, &WorkloadConfig::default());
        let parsed = Json::parse(&j.to_string()).unwrap();
        assert_eq!(parsed.req_str("admission").unwrap(), "adaptive");
    }

    #[test]
    fn batching_flags_and_file_keys_layer() {
        // Defaults: unbatched — the PR 6-identical configuration.
        let none = sim_config(&args(&["figure"]), Mode::Baseline).unwrap();
        assert_eq!(none.batch_window_us, 0);
        assert_eq!(none.batch_max, 32);
        // CLI flags.
        let a = args(&["figure", "--batch-window", "500", "--batch-max", "8"]);
        let cfg = sim_config(&a, Mode::Baseline).unwrap();
        assert_eq!(cfg.batch_window_us, 500);
        assert_eq!(cfg.batch_max, 8);
        // batch_max 0 is rejected, not clamped.
        let bad = args(&["figure", "--batch-max", "0"]);
        assert!(sim_config(&bad, Mode::Baseline).is_err());
        // File keys layer under CLI.
        let dir = std::env::temp_dir().join("relaygr_batch_cfg_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("cfg.json");
        std::fs::write(&path, r#"{"batch_window": 250, "batch_max": 4}"#).unwrap();
        let f = args(&["x", "--config", path.to_str().unwrap()]);
        let cfg = sim_config(&f, Mode::Baseline).unwrap();
        assert_eq!(cfg.batch_window_us, 250);
        assert_eq!(cfg.batch_max, 4);
        let over = args(&["x", "--config", path.to_str().unwrap(), "--batch-window", "100"]);
        assert_eq!(sim_config(&over, Mode::Baseline).unwrap().batch_window_us, 100);
        // The run record carries both knobs.
        let j = sim_config_json(&cfg, &WorkloadConfig::default());
        let parsed = Json::parse(&j.to_string()).unwrap();
        assert_eq!(parsed.req_usize("batch_window").unwrap(), 250);
        assert_eq!(parsed.req_usize("batch_max").unwrap(), 4);
    }

    #[test]
    fn cell_flags_and_file_keys_layer() {
        // Defaults: one cell — the pre-cell-layer identical configuration.
        let none = sim_config(&args(&["figure"]), Mode::Baseline).unwrap();
        assert_eq!(none.cells, 1);
        assert_eq!(none.cell_picker, CellPickerKind::Affinity);
        assert_eq!(none.cell_scenario, CellScenario::None);
        // CLI flags.
        let a = args(&[
            "figure", "--cells", "4", "--cell-picker", "spread", "--cell-spill", "1.5",
            "--cell-scenario", "drain",
        ]);
        let cfg = sim_config(&a, Mode::Baseline).unwrap();
        assert_eq!(cfg.cells, 4);
        assert_eq!(cfg.cell_picker, CellPickerKind::Spread);
        assert!((cfg.cell_spill - 1.5).abs() < 1e-12);
        assert_eq!(cfg.cell_scenario, CellScenario::Drain);
        // `inf` = pure locality; non-positive spill ratios are rejected.
        let inf = args(&["figure", "--cell-spill", "inf"]);
        assert!(sim_config(&inf, Mode::Baseline).unwrap().cell_spill.is_infinite());
        assert!(sim_config(&args(&["figure", "--cell-spill", "0"]), Mode::Baseline).is_err());
        assert!(sim_config(&args(&["figure", "--cell-picker", "random"]), Mode::Baseline).is_err());
        assert!(sim_config(&args(&["figure", "--cell-scenario", "meteor"]), Mode::Baseline).is_err());
        // File keys layer under CLI.
        let dir = std::env::temp_dir().join("relaygr_cell_cfg_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("cfg.json");
        std::fs::write(
            &path,
            r#"{"cells": 2, "cell_picker": "spread", "cell_scenario": "failure"}"#,
        )
        .unwrap();
        let f = args(&["x", "--config", path.to_str().unwrap()]);
        let cfg = sim_config(&f, Mode::Baseline).unwrap();
        assert_eq!(cfg.cells, 2);
        assert_eq!(cfg.cell_picker, CellPickerKind::Spread);
        assert_eq!(cfg.cell_scenario, CellScenario::Failure);
        let over = args(&["x", "--config", path.to_str().unwrap(), "--cells", "5"]);
        assert_eq!(sim_config(&over, Mode::Baseline).unwrap().cells, 5);
        // The run record carries the cell shape.
        let j = sim_config_json(&cfg, &WorkloadConfig::default());
        let parsed = Json::parse(&j.to_string()).unwrap();
        assert_eq!(parsed.req_usize("cells").unwrap(), 2);
        assert_eq!(parsed.req_str("cell_picker").unwrap(), "spread");
        assert_eq!(parsed.req_str("cell_scenario").unwrap(), "failure");
    }

    #[test]
    fn fault_flags_and_file_keys_layer() {
        use crate::relay::fault::FaultKind;
        // Default: fault plane off — the PR 9-identical configuration.
        let none = sim_config(&args(&["figure"]), Mode::Baseline).unwrap();
        assert!(!none.faults.enabled());
        assert_eq!(none.faults.label(), "none");
        // CLI flag parses the full spec grammar.
        let a = args(&["figure", "--faults", "psi-fail:0.01,crash@40%:cell0,retry:2"]);
        let cfg = sim_config(&a, Mode::Baseline).unwrap();
        assert!(cfg.faults.enabled());
        assert!((cfg.faults.rates[FaultKind::PsiFail.index()] - 0.01).abs() < 1e-12);
        assert_eq!(cfg.faults.crash.map(|c| (c.pct, c.cell)), Some((40, Some(0))));
        assert_eq!(cfg.faults.retries, 2);
        // Malformed specs are rejected, not clamped.
        let bad = args(&["figure", "--faults", "psi-fail:2.0"]);
        assert!(sim_config(&bad, Mode::Baseline).is_err());
        // File key layers under CLI.
        let dir = std::env::temp_dir().join("relaygr_fault_cfg_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("cfg.json");
        std::fs::write(&path, r#"{"faults": "reload-fail:0.05"}"#).unwrap();
        let f = args(&["x", "--config", path.to_str().unwrap()]);
        let cfg = sim_config(&f, Mode::Baseline).unwrap();
        assert!((cfg.faults.rates[FaultKind::ReloadFail.index()] - 0.05).abs() < 1e-12);
        let over =
            args(&["x", "--config", path.to_str().unwrap(), "--faults", "trigger-drop:0.1"]);
        let over_cfg = sim_config(&over, Mode::Baseline).unwrap();
        assert_eq!(over_cfg.faults.rates[FaultKind::ReloadFail.index()], 0.0);
        assert!((over_cfg.faults.rates[FaultKind::TriggerDrop.index()] - 0.1).abs() < 1e-12);
        // The run record carries the canonical label.
        let j = sim_config_json(&over_cfg, &WorkloadConfig::default());
        let parsed = Json::parse(&j.to_string()).unwrap();
        assert_eq!(parsed.req_str("faults").unwrap(), over_cfg.faults.label());
    }

    #[test]
    fn bad_config_is_rejected() {
        let a = args(&["x", "--hw", "h100"]);
        assert!(sim_config(&a, Mode::Baseline).is_err());
    }

    #[test]
    fn run_record_roundtrips() {
        let cfg = SimConfig::standard(Mode::Baseline);
        let wl = WorkloadConfig::default();
        let j = sim_config_json(&cfg, &wl);
        let parsed = Json::parse(&j.to_string()).unwrap();
        assert_eq!(parsed.req_str("mode").unwrap(), "baseline");
        assert_eq!(parsed.req_usize("instances").unwrap(), 20);
    }
}
