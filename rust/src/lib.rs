//! # RelayGR — cross-stage relay-race inference for generative recommendation
//!
//! Reproduction of *"RelayGR: Scaling Long-Sequence Generative
//! Recommendation via Cross-Stage Relay-Race Inference"* (CS.DC 2026) as a
//! three-layer Rust + JAX + Pallas stack:
//!
//! * **L1** — HSTU pointwise-attention Pallas kernels (`python/compile/kernels/`),
//! * **L2** — the GR backbone + task tower lowered AOT to HLO text
//!   (`python/compile/model.py` → `artifacts/`),
//! * **L3** — this crate: the serving coordinator implementing the paper's
//!   contribution (sequence-aware trigger, affinity-aware router,
//!   memory-aware expander, HBM lifecycle cache) over a PJRT runtime, a
//!   live threaded serving engine, and a calibrated discrete-event cluster
//!   simulator that regenerates every figure/table in the paper's
//!   evaluation.
//!
//! Python never runs on the request path: `make artifacts` lowers the
//! model once and the rust binary is self-contained afterwards.

pub mod util;

pub mod config;
pub mod model;
pub mod runtime;

pub mod cluster;
pub mod relay;
pub mod workload;

pub mod metrics;
pub mod serve;

pub mod figures;
