//! # RelayGR — cross-stage relay-race inference for generative recommendation
//!
//! Reproduction of *"RelayGR: Scaling Long-Sequence Generative
//! Recommendation via Cross-Stage Relay-Race Inference"* (CS.DC 2026) as a
//! three-layer Rust + JAX + Pallas stack:
//!
//! * **L1** — HSTU pointwise-attention Pallas kernels (`python/compile/kernels/`),
//! * **L2** — the GR backbone + task tower lowered AOT to HLO text
//!   (`python/compile/model.py` → `artifacts/`),
//! * **L3** — this crate: the serving coordinator implementing the paper's
//!   contribution (sequence-aware trigger, affinity-aware router, tiered
//!   ψ cache hierarchy over the HBM lifecycle window) over a PJRT runtime, a
//!   live threaded serving engine, and a calibrated discrete-event cluster
//!   simulator that regenerates every figure/table in the paper's
//!   evaluation.
//!
//! The L3 control plane is organised around two shared abstractions:
//!
//! * [`relay::RelayCoordinator`] — one clock-agnostic state machine
//!   owning the whole per-request relay-race decision flow (admission →
//!   placement → ψ lookup/production → wait-budget fallback →
//!   outcome classification → spill lifecycle).  The simulator
//!   ([`cluster`]) and the live engine ([`serve`]) are thin time/compute
//!   adapters over its event API, so a policy change lands in both
//!   engines at once — `tests/cross_engine.rs` asserts their per-request
//!   outcomes stay identical.
//! * [`workload::Scenario`] — named traffic shapes (`steady`, `diurnal`,
//!   `burst`, `coldstart`) behind one generator trait, selectable with
//!   `--scenario` in both engines and compared by `relaygr figure
//!   scenarios`.
//!
//! Python never runs on the request path: `make artifacts` lowers the
//! model once and the rust binary is self-contained afterwards.

pub mod util;

pub mod config;
pub mod model;
pub mod runtime;

pub mod cluster;
pub mod relay;
pub mod workload;

pub mod metrics;
pub mod serve;

pub mod figures;
