//! Serving modes and the Fig.-12 strawman: a distributed KV pool without
//! affinity, where ranking may need cross-server cache fetches.

use crate::model::HardwareProfile;
use crate::relay::tier::{DramPolicy, EvictPolicy, TierConfig};

/// Which serving policy a run evaluates.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Mode {
    /// Production baseline: full GR inference inline at ranking.
    Baseline,
    /// RelayGR in-HBM relay race; DRAM tier per policy (Disabled = the
    /// paper's plain "RelayGR", Capacity = "RelayGR +x%").
    RelayGr { dram: DramPolicy },
    /// Strawman for Fig. 12: prefix caches live in a distributed pool
    /// without affinity; a ranking instance holding the cache locally is
    /// a matter of luck (1/N), otherwise it fetches remotely.
    RemotePool,
}

impl Mode {
    pub fn label(&self) -> String {
        match self {
            Mode::Baseline => "baseline".into(),
            Mode::RelayGr { dram: DramPolicy::Disabled } => "relaygr".into(),
            Mode::RelayGr { dram: DramPolicy::Capacity(b) } => {
                format!("relaygr+dram{}g", b >> 30)
            }
            Mode::RemotePool => "remote-pool".into(),
        }
    }

    pub fn is_relay(&self) -> bool {
        matches!(self, Mode::RelayGr { .. })
    }

    /// The lower-tier stack a config induces: an explicit override
    /// (`--tier`) wins; otherwise relay mode's DRAM capacity becomes one
    /// tier under `policy` (`--dram-policy`, default LRU).  Shared by
    /// both engine configs so their precedence rules cannot drift.
    pub fn tier_stack(
        &self,
        policy: EvictPolicy,
        override_: Option<&[TierConfig]>,
    ) -> Vec<TierConfig> {
        if let Some(tiers) = override_ {
            return tiers.to_vec();
        }
        match *self {
            Mode::RelayGr { dram } => dram.tier_stack(policy),
            _ => Vec::new(),
        }
    }
}

/// Distributed-pool access model (Fig. 12): local hits are HBM pointer
/// handoffs; misses pay RTT + transfer over the shared network.
#[derive(Debug, Clone)]
pub struct RemotePool {
    pub n_servers: usize,
}

impl RemotePool {
    /// Probability the pool shard holding ψ is the local server.
    pub fn local_probability(&self) -> f64 {
        1.0 / self.n_servers.max(1) as f64
    }

    /// Latency of fetching ψ when it is remote.
    pub fn remote_fetch_us(&self, hw: &HardwareProfile, kv_bytes: usize) -> f64 {
        hw.remote_fetch_us(kv_bytes)
    }

    /// Latency of a local pool access (in-HBM handoff).
    pub fn local_access_us(&self, hw: &HardwareProfile) -> f64 {
        hw.launch_us
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ModelSpec;

    #[test]
    fn labels_distinguish_variants() {
        assert_eq!(Mode::Baseline.label(), "baseline");
        assert_eq!(Mode::RelayGr { dram: DramPolicy::Disabled }.label(), "relaygr");
        assert_eq!(
            Mode::RelayGr { dram: DramPolicy::Capacity(500 << 30) }.label(),
            "relaygr+dram500g"
        );
        assert!(Mode::RelayGr { dram: DramPolicy::Disabled }.is_relay());
        assert!(!Mode::Baseline.is_relay());
    }

    #[test]
    fn remote_fetch_dwarfs_local_access() {
        // Fig. 12: remote fetch is orders of magnitude above local access.
        let hw = HardwareProfile::ascend_910c();
        let pool = RemotePool { n_servers: 25 };
        let kv = ModelSpec::paper_default().kv_bytes();
        let ratio = pool.remote_fetch_us(&hw, kv) / pool.local_access_us(&hw);
        assert!(ratio > 50.0, "ratio {ratio:.0}");
        assert!((pool.local_probability() - 0.04).abs() < 1e-12);
    }
}
