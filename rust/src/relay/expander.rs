//! Memory-aware expander (§3.4): server-local DRAM as a controlled
//! compensation tier extending ψ reuse across repeated requests from the
//! same user (rapid refresh), without violating the no-remote-fetch
//! invariant (I1).
//!
//! Mechanisms reproduced from the paper:
//!
//! * **Two-level lookup** — HBM first, DRAM on miss; a DRAM hit triggers
//!   one rate-limited DRAM→HBM reload (H2D).
//! * **Per-user single-flight** — at most one cache-affecting action per
//!   user in flight; concurrent requests join the in-flight reload.
//! * **Pseudo-pre-inference** — every ranking request is fronted by an
//!   idempotent pseudo step performing the same checks as real
//!   pre-inference, so out-of-order arrivals (pre-infer delayed behind
//!   ranking) cause at most one reload per user per burst.
//! * **Bounded reload concurrency** — reloads above the cap queue rather
//!   than flooding PCIe.
//!
//! Like [`HbmCache`], the expander is payload-generic and clock-agnostic
//! (callers pass `now_us` and perform the actual H2D), so the simulator
//! and the live engine share it.

use std::collections::VecDeque;

use crate::util::fxhash::FxHashMap;

use crate::relay::hbm::{EntryState, HbmCache, Micros};

/// DRAM spill-tier policy.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum DramPolicy {
    /// No DRAM tier (plain RelayGR, 0% DRAM hit).
    Disabled,
    /// True capacity-bounded LRU tier (bytes).
    Capacity(usize),
}

/// What the pseudo-pre-infer step decided.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PseudoAction {
    /// ψ is in HBM (Ready or Consumed-but-resident): proceed directly.
    HbmHit,
    /// ψ is still being produced in HBM: wait for production to finish.
    WaitProducing,
    /// DRAM hit; this caller starts the one reload (caller performs the
    /// H2D and calls [`Expander::complete_reload`] when done).
    StartReload { bytes: usize },
    /// DRAM hit but a reload for this user is already in flight (or
    /// queued): join it, do not issue another transfer.
    JoinReload,
    /// DRAM hit but the reload-concurrency cap is reached: the reload is
    /// queued; caller waits for [`Expander::pop_queued_reload`] turn.
    QueuedReload,
    /// Not cached anywhere: fall back (full inference or real pre-infer).
    Miss,
}

/// Counters exported to metrics.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ExpanderStats {
    pub lookups: u64,
    pub hbm_hits: u64,
    pub dram_hits: u64,
    pub misses: u64,
    pub reloads_started: u64,
    pub reloads_joined: u64,
    pub reloads_queued: u64,
    pub spills: u64,
    pub spill_rejected: u64,
    pub dram_evictions: u64,
}

impl ExpanderStats {
    /// Accumulate another instance's counters (cluster-wide reporting).
    pub fn merge(&mut self, b: ExpanderStats) {
        self.lookups += b.lookups;
        self.hbm_hits += b.hbm_hits;
        self.dram_hits += b.dram_hits;
        self.misses += b.misses;
        self.reloads_started += b.reloads_started;
        self.reloads_joined += b.reloads_joined;
        self.reloads_queued += b.reloads_queued;
        self.spills += b.spills;
        self.spill_rejected += b.spill_rejected;
        self.dram_evictions += b.dram_evictions;
    }
}

#[derive(Debug)]
struct DramEntry<T> {
    bytes: usize,
    payload: T,
    last_used: u64,
}

/// Server-local DRAM tier with LRU eviction.
#[derive(Debug)]
pub struct DramTier<T> {
    capacity: usize,
    used: usize,
    entries: FxHashMap<u64, DramEntry<T>>,
    tick: u64,
    evictions: u64,
}

impl<T> DramTier<T> {
    pub fn new(capacity: usize) -> Self {
        DramTier { capacity, used: 0, entries: FxHashMap::default(), tick: 0, evictions: 0 }
    }

    pub fn used_bytes(&self) -> usize {
        self.used
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn contains(&self, user: u64) -> bool {
        self.entries.contains_key(&user)
    }

    fn touch(&mut self, user: u64) {
        self.tick += 1;
        let t = self.tick;
        if let Some(e) = self.entries.get_mut(&user) {
            e.last_used = t;
        }
    }

    /// Insert (replacing any previous entry), LRU-evicting to fit.
    /// Returns false if the object cannot fit at all.
    fn insert(&mut self, user: u64, bytes: usize, payload: T) -> bool {
        if bytes > self.capacity {
            return false;
        }
        if let Some(old) = self.entries.remove(&user) {
            self.used -= old.bytes;
        }
        while self.used + bytes > self.capacity {
            let lru = self
                .entries
                .iter()
                .min_by_key(|(_, e)| e.last_used)
                .map(|(&u, _)| u)
                .expect("used>0 implies entries");
            let e = self.entries.remove(&lru).unwrap();
            self.used -= e.bytes;
            self.evictions += 1;
        }
        self.tick += 1;
        self.entries.insert(user, DramEntry { bytes, payload, last_used: self.tick });
        self.used += bytes;
        true
    }

    fn remove(&mut self, user: u64) -> Option<(usize, T)> {
        self.entries.remove(&user).map(|e| {
            self.used -= e.bytes;
            (e.bytes, e.payload)
        })
    }
}

impl<T: Clone> DramTier<T> {
    fn get(&mut self, user: u64) -> Option<(usize, T)> {
        self.touch(user);
        self.entries.get(&user).map(|e| (e.bytes, e.payload.clone()))
    }
}

/// The memory-aware expander.
#[derive(Debug)]
pub struct Expander<T> {
    dram: Option<DramTier<T>>,
    /// Users with a reload in flight (single-flight) and join counts.
    inflight: FxHashMap<u64, u32>,
    /// Reloads waiting for a concurrency slot, FIFO.
    queued: VecDeque<u64>,
    active_reloads: usize,
    max_reload_concurrency: usize,
    stats: ExpanderStats,
}

impl<T: Clone> Expander<T> {
    pub fn new(policy: DramPolicy, max_reload_concurrency: usize) -> Self {
        let dram = match policy {
            DramPolicy::Disabled => None,
            DramPolicy::Capacity(bytes) => Some(DramTier::new(bytes)),
        };
        Expander {
            dram,
            inflight: FxHashMap::default(),
            queued: VecDeque::new(),
            active_reloads: 0,
            max_reload_concurrency: max_reload_concurrency.max(1),
            stats: ExpanderStats::default(),
        }
    }

    pub fn stats(&self) -> ExpanderStats {
        self.stats
    }

    pub fn dram_used_bytes(&self) -> usize {
        self.dram.as_ref().map(|d| d.used_bytes()).unwrap_or(0)
    }

    pub fn dram_len(&self) -> usize {
        self.dram.as_ref().map(|d| d.len()).unwrap_or(0)
    }

    pub fn active_reloads(&self) -> usize {
        self.active_reloads
    }

    pub fn inflight_for(&self, user: u64) -> bool {
        self.inflight.contains_key(&user)
    }

    /// The pseudo-pre-infer step fronting every ranking request (and also
    /// used by real pre-infer signals to skip redundant recomputation).
    pub fn pseudo_pre_infer(
        &mut self,
        user: u64,
        hbm: &mut HbmCache<T>,
        now: Micros,
    ) -> PseudoAction {
        self.stats.lookups += 1;
        match hbm.probe(user, now) {
            Some(EntryState::Ready) | Some(EntryState::Consumed) => {
                self.stats.hbm_hits += 1;
                return PseudoAction::HbmHit;
            }
            Some(EntryState::Producing) => {
                self.stats.hbm_hits += 1;
                return PseudoAction::WaitProducing;
            }
            None => {}
        }
        // Single-flight: join any in-flight/queued reload for this user.
        if let Some(joiners) = self.inflight.get_mut(&user) {
            *joiners += 1;
            self.stats.reloads_joined += 1;
            return PseudoAction::JoinReload;
        }
        let Some(dram) = self.dram.as_mut() else {
            self.stats.misses += 1;
            return PseudoAction::Miss;
        };
        let Some((bytes, _payload)) = dram.get(user) else {
            self.stats.misses += 1;
            return PseudoAction::Miss;
        };
        self.stats.dram_hits += 1;
        self.inflight.insert(user, 0);
        if self.active_reloads < self.max_reload_concurrency {
            self.active_reloads += 1;
            self.stats.reloads_started += 1;
            PseudoAction::StartReload { bytes }
        } else {
            self.queued.push_back(user);
            self.stats.reloads_queued += 1;
            PseudoAction::QueuedReload
        }
    }

    /// Read the payload for a user whose reload is starting (the caller
    /// performs the H2D from this host copy).
    pub fn dram_payload(&mut self, user: u64) -> Option<(usize, T)> {
        self.dram.as_mut().and_then(|d| d.get(user))
    }

    /// The H2D finished: install ψ into HBM as Ready, release the
    /// single-flight guard, and return (a) how many waiters were joined to
    /// this reload and (b) the next queued user now allowed to start (the
    /// caller begins its transfer).
    pub fn complete_reload(
        &mut self,
        user: u64,
        payload: T,
        bytes: usize,
        now: Micros,
        t_life_us: Micros,
        hbm: &mut HbmCache<T>,
    ) -> ReloadDone {
        let (joiners, next) = self.finish_reload(user);
        let installed = hbm.insert_ready(user, bytes, payload, now, t_life_us).is_ok();
        ReloadDone { joiners, installed, next }
    }

    /// Release single-flight/concurrency bookkeeping for a finished reload
    /// *without* touching HBM — used by the live engine, whose HBM cache
    /// holds device buffers while the DRAM tier holds host copies.
    pub fn finish_reload(&mut self, user: u64) -> (u32, Option<u64>) {
        let joiners = self.inflight.remove(&user).unwrap_or(0);
        self.active_reloads = self.active_reloads.saturating_sub(1);
        (joiners, self.pop_queued_reload())
    }

    /// Pull the next queued reload if a concurrency slot is free.
    /// Returns the user whose transfer should start now.
    pub fn pop_queued_reload(&mut self) -> Option<u64> {
        if self.active_reloads >= self.max_reload_concurrency {
            return None;
        }
        let user = self.queued.pop_front()?;
        self.active_reloads += 1;
        self.stats.reloads_started += 1;
        Some(user)
    }

    /// A reload failed (e.g. payload evicted from DRAM mid-flight):
    /// release guards so waiters can fall back.
    pub fn abort_reload(&mut self, user: u64) -> Option<u64> {
        self.inflight.remove(&user);
        self.active_reloads = self.active_reloads.saturating_sub(1);
        self.pop_queued_reload()
    }

    /// After ranking consumed ψ, spill it to DRAM for short-term reuse.
    pub fn spill(&mut self, user: u64, bytes: usize, payload: T) -> bool {
        let Some(dram) = self.dram.as_mut() else {
            self.stats.spill_rejected += 1;
            return false;
        };
        let before = dram.evictions;
        let ok = dram.insert(user, bytes, payload);
        self.stats.dram_evictions += dram.evictions - before;
        if ok {
            self.stats.spills += 1;
        } else {
            self.stats.spill_rejected += 1;
        }
        ok
    }

    /// Drop a user's DRAM entry (e.g. behaviours were refreshed upstream
    /// and the cached prefix is stale).
    pub fn invalidate(&mut self, user: u64) -> bool {
        self.dram.as_mut().and_then(|d| d.remove(user)).is_some()
    }
}

/// Result of [`Expander::complete_reload`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ReloadDone {
    /// Ranking requests that joined this reload instead of re-transferring.
    pub joiners: u32,
    /// Whether ψ was installed into HBM (false ⇒ HBM pressure; fall back).
    pub installed: bool,
    /// Next queued reload now permitted to start, if any.
    pub next: Option<u64>,
}

#[cfg(test)]
mod tests {
    use super::*;

    const MB: usize = 1 << 20;

    fn setup(dram_mb: usize) -> (Expander<u32>, HbmCache<u32>) {
        (Expander::new(DramPolicy::Capacity(dram_mb * MB), 2), HbmCache::new(64 * MB))
    }

    #[test]
    fn two_level_lookup_order() {
        let (mut ex, mut hbm) = setup(512);
        // Nothing anywhere → Miss.
        assert_eq!(ex.pseudo_pre_infer(1, &mut hbm, 0), PseudoAction::Miss);
        // In HBM → HbmHit (DRAM not consulted).
        hbm.insert_ready(1, MB, 7, 0, 300_000).unwrap();
        assert_eq!(ex.pseudo_pre_infer(1, &mut hbm, 0), PseudoAction::HbmHit);
        // Only in DRAM → StartReload.
        ex.spill(2, MB, 9);
        assert_eq!(ex.pseudo_pre_infer(2, &mut hbm, 0), PseudoAction::StartReload { bytes: MB });
        let s = ex.stats();
        assert_eq!((s.misses, s.hbm_hits, s.dram_hits), (1, 1, 1));
    }

    #[test]
    fn wait_for_producing_entry() {
        let (mut ex, mut hbm) = setup(512);
        hbm.begin_produce(1, MB, 0, 300_000).unwrap();
        assert_eq!(ex.pseudo_pre_infer(1, &mut hbm, 0), PseudoAction::WaitProducing);
    }

    #[test]
    fn single_flight_joins_burst() {
        // Out-of-order burst: three ranking requests for the same user
        // arrive before the (delayed) real pre-infer. Exactly one reload.
        let (mut ex, mut hbm) = setup(512);
        ex.spill(5, 2 * MB, 42);
        assert_eq!(ex.pseudo_pre_infer(5, &mut hbm, 0), PseudoAction::StartReload { bytes: 2 * MB });
        assert_eq!(ex.pseudo_pre_infer(5, &mut hbm, 0), PseudoAction::JoinReload);
        assert_eq!(ex.pseudo_pre_infer(5, &mut hbm, 0), PseudoAction::JoinReload);
        let done = ex.complete_reload(5, 42, 2 * MB, 10, 300_000, &mut hbm);
        assert_eq!(done.joiners, 2);
        assert!(done.installed);
        assert_eq!(done.next, None);
        // Everyone now hits HBM; at-most-once reload per burst.
        assert_eq!(ex.pseudo_pre_infer(5, &mut hbm, 0), PseudoAction::HbmHit);
        assert_eq!(ex.stats().reloads_started, 1);
    }

    #[test]
    fn reload_concurrency_bounded_and_fifo() {
        let (mut ex, mut hbm) = setup(512);
        for u in 1..=4u64 {
            ex.spill(u, MB, u as u32);
        }
        assert!(matches!(ex.pseudo_pre_infer(1, &mut hbm, 0), PseudoAction::StartReload { .. }));
        assert!(matches!(ex.pseudo_pre_infer(2, &mut hbm, 0), PseudoAction::StartReload { .. }));
        // Cap = 2: further reloads queue.
        assert_eq!(ex.pseudo_pre_infer(3, &mut hbm, 0), PseudoAction::QueuedReload);
        assert_eq!(ex.pseudo_pre_infer(4, &mut hbm, 0), PseudoAction::QueuedReload);
        assert_eq!(ex.active_reloads(), 2);
        // Completing one grants the slot to user 3 (FIFO).
        let done = ex.complete_reload(1, 1, MB, 5, 300_000, &mut hbm);
        assert_eq!(done.next, Some(3));
        assert_eq!(ex.active_reloads(), 2);
        let done = ex.complete_reload(2, 2, MB, 6, 300_000, &mut hbm);
        assert_eq!(done.next, Some(4));
    }

    #[test]
    fn spill_lru_eviction() {
        let mut ex: Expander<u32> = Expander::new(DramPolicy::Capacity(3 * MB), 1);
        let mut hbm: HbmCache<u32> = HbmCache::new(64 * MB);
        ex.spill(1, MB, 1);
        ex.spill(2, MB, 2);
        ex.spill(3, MB, 3);
        // Touch 1 so 2 becomes LRU, then overflow.
        assert!(matches!(ex.pseudo_pre_infer(1, &mut hbm, 0), PseudoAction::StartReload { .. }));
        ex.complete_reload(1, 1, MB, 0, 300_000, &mut hbm);
        ex.spill(4, MB, 4);
        assert_eq!(ex.dram_len(), 3);
        assert_eq!(ex.stats().dram_evictions, 1);
        // 2 was evicted; 3 and 4 remain.
        assert!(ex.dram_payload(2).is_none());
        assert!(ex.dram_payload(3).is_some());
        assert!(ex.dram_payload(4).is_some());
    }

    #[test]
    fn disabled_dram_always_misses_and_rejects_spills() {
        let mut ex: Expander<u32> = Expander::new(DramPolicy::Disabled, 4);
        let mut hbm: HbmCache<u32> = HbmCache::new(64 * MB);
        assert!(!ex.spill(1, MB, 1));
        assert_eq!(ex.pseudo_pre_infer(1, &mut hbm, 0), PseudoAction::Miss);
        assert_eq!(ex.stats().spill_rejected, 1);
    }

    #[test]
    fn abort_releases_slot() {
        let (mut ex, mut hbm) = setup(512);
        ex.spill(1, MB, 1);
        ex.spill(2, MB, 2);
        let mut ex2 = Expander::new(DramPolicy::Capacity(512 * MB), 1);
        ex2.spill(1, MB, 1u32);
        ex2.spill(2, MB, 2u32);
        assert!(matches!(ex2.pseudo_pre_infer(1, &mut hbm, 0), PseudoAction::StartReload { .. }));
        assert_eq!(ex2.pseudo_pre_infer(2, &mut hbm, 0), PseudoAction::QueuedReload);
        assert_eq!(ex2.abort_reload(1), Some(2));
        assert_eq!(ex2.active_reloads(), 1);
        let _ = ex; // silence unused in this scenario
    }

    #[test]
    fn invalidate_removes_stale_prefix() {
        let (mut ex, mut hbm) = setup(512);
        ex.spill(9, MB, 1);
        assert!(ex.invalidate(9));
        assert_eq!(ex.pseudo_pre_infer(9, &mut hbm, 0), PseudoAction::Miss);
        assert!(!ex.invalidate(9));
    }

    /// Property: random interleavings never issue concurrent reloads for
    /// one user, never exceed the concurrency cap, and each burst causes
    /// at most one transfer.
    #[test]
    fn prop_single_flight_and_bounded_concurrency() {
        crate::util::prop::check("expander-single-flight", 150, |rng| {
            let cap = 1 + rng.range(0, 3);
            let mut ex: Expander<u32> = Expander::new(DramPolicy::Capacity(1 << 30), cap);
            let mut hbm: HbmCache<u32> = HbmCache::new(1 << 30);
            let users: Vec<u64> = (0..6).collect();
            for &u in &users {
                ex.spill(u, MB, u as u32);
            }
            let mut inflight: Vec<u64> = Vec::new();
            for step in 0..300 {
                let u = *rng.choice(&users);
                if rng.bernoulli(0.6) {
                    match ex.pseudo_pre_infer(u, &mut hbm, 0) {
                        PseudoAction::StartReload { .. } => {
                            if inflight.contains(&u) {
                                return Err(format!("step {step}: duplicate reload for {u}"));
                            }
                            inflight.push(u);
                        }
                        PseudoAction::QueuedReload => {}
                        _ => {}
                    }
                } else if let Some(pos) = (!inflight.is_empty())
                    .then(|| rng.range(0, inflight.len()))
                {
                    let u = inflight.remove(pos);
                    let done = ex.complete_reload(u, 0, MB, step as u64, 1 << 40, &mut hbm);
                    if let Some(next) = done.next {
                        if inflight.contains(&next) {
                            return Err("queued duplicate".into());
                        }
                        inflight.push(next);
                    }
                }
                if ex.active_reloads() > cap {
                    return Err(format!("active {} > cap {cap}", ex.active_reloads()));
                }
            }
            Ok(())
        });
    }
}
