//! The paper's system contribution: *lifecycle caching under late-binding
//! placement*, decomposed exactly as §3.1 does —
//!
//! * **admission** — [`trigger`]: the sequence-aware trigger (Eqs. 1–3),
//! * **placement** — [`router`]: the affinity-aware consistent-hash router,
//! * **memory** — [`tier`] + [`hierarchy`]: the tiered ψ cache hierarchy
//!   generalising §3.4's memory-aware expander,
//!
//! with the [`pipeline`] cascade model and the [`baseline`] modes (inline
//! full inference and the no-affinity remote-pool strawman).  Beyond the
//! paper, [`segment`] adds cross-user candidate-segment KV reuse — a
//! ref-counted, deduplicated segment cache for ranking-side tokens,
//! layered on the same generic hierarchy (its second instantiation).
//!
//! ## The tier / hierarchy API
//!
//! Every level of the ψ memory hierarchy implements
//! [`tier::CacheTier`] — capacity, lookup, insert, evict and a shared
//! [`tier::TierStats`] counter block:
//!
//! * level 0 is the [`hbm::HbmCache`] sliding lifecycle window
//!   ([`tier::EvictPolicy::Lifecycle`]),
//! * every lower level is a [`tier::PolicyTier`] — a capacity-bounded
//!   tier with pluggable eviction (`Lru` | `Lfu` | `CostAware` | FIFO
//!   `Lifecycle`) behind an O(log n) ordered victim index.
//!
//! [`hierarchy::CacheHierarchy`] composes N levels into the flow that
//! used to be hand-rolled for exactly two: N-level lookup
//! (`pseudo_pre_infer`), per-user single-flight, bounded promotion
//! (DRAM→HBM reload), and demotion (spill) with cascade — a tier's
//! eviction victims drop one level down, and only last-tier victims
//! leave the hierarchy.
//!
//! **Adding a level**: push another [`tier::TierConfig`] onto the stack
//! (`--tier 8g:lru,500g:cost` on the CLIs, or `CoordinatorConfig::tiers`
//! programmatically) — lookup, promotion, demotion, metrics and both
//! engines pick it up with no other change.  **Adding a policy**: add an
//! [`tier::EvictPolicy`] variant and its `order_key` arm in
//! [`tier::PolicyTier`]; it becomes selectable everywhere via
//! `--dram-policy` and comparable via `relaygr figure tiers`.
//!
//! All modules are clock-agnostic state machines (callers pass `now_us`).
//! The [`coordinator`] composes them into the single per-request
//! relay-race decision flow — admission → placement → ψ
//! lookup/production → wait-budget fallback → [`CacheOutcome`]
//! classification → spill lifecycle — behind an event-style API
//! (`on_arrival`, `on_trigger_check`, `on_stage_done`, `on_rank_start`,
//! `on_psi_ready`, `on_reload_done`, `rank_compute`, `on_rank_done`).
//! The discrete-event simulator (`cluster::sim`) and the live threaded
//! engine (`serve::engine`) are thin time/compute adapters over it: they
//! translate coordinator actions into simulated or real durations and
//! never make a caching/placement/admission decision themselves.  A new
//! policy (cache tiers, admission rules) is implemented once in the
//! coordinator and both engines pick it up for free.

pub mod baseline;
pub mod cell;
pub mod coordinator;
pub mod fault;
pub mod flight;
pub mod hbm;
pub mod hierarchy;
pub mod pipeline;
pub mod router;
pub mod segment;
pub mod tier;
pub mod trigger;

pub use baseline::{Mode, RemotePool};
pub use cell::{
    CellConfig, CellPickerKind, CellReport, CellReq, CellScenario, CellSet, CellStats,
};
pub use coordinator::{
    Completion, CoordinatorConfig, FailStats, QueuedReload, RankAction, RankCompute,
    RelayCoordinator, ReloadResolution, ReqId, SignalAction, Stage,
};
pub use fault::{CrashSpec, FaultConfig, FaultKind, FaultOutcome, FaultPlan, FaultReport};
pub use flight::{FlightRecorder, Span, SpanKind, StageBreakdown, Timeline};
pub use hbm::{EntryState, HbmCache, HbmStats, InsertError, Micros};
pub use hierarchy::{CacheHierarchy, HierarchyStats, PseudoAction, ReloadDone};
pub use pipeline::{CacheOutcome, Lifecycle, PipelineConfig, StageSampler};
pub use router::{BalancePolicy, HashRing, Route, Router, RouterConfig, RouterStats};
pub use segment::{
    SegmentAction, SegmentConfig, SegmentKey, SegmentPlan, SegmentStats, SegmentStore,
};
pub use tier::{CacheTier, DramPolicy, EvictPolicy, PolicyTier, TierConfig, TierStats};
pub use trigger::{
    AdmissionLimits, BehaviorMeta, Decision, Trigger, TriggerConfig, TriggerStats,
};
