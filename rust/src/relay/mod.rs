//! The paper's system contribution: *lifecycle caching under late-binding
//! placement*, decomposed exactly as §3.1 does —
//!
//! * **admission** — [`trigger`]: the sequence-aware trigger (Eqs. 1–3),
//! * **placement** — [`router`]: the affinity-aware consistent-hash router,
//! * **local capacity extension** — [`expander`]: the memory-aware DRAM
//!   tier with per-user single-flight and pseudo-pre-inference,
//!
//! over the [`hbm`] sliding-window lifecycle cache, with the [`pipeline`]
//! cascade model and the [`baseline`] modes (inline full inference and the
//! no-affinity remote-pool strawman).
//!
//! All modules are clock-agnostic state machines (callers pass `now_us`).
//! The [`coordinator`] composes them into the single per-request
//! relay-race decision flow — admission → placement → ψ
//! lookup/production → wait-budget fallback → [`CacheOutcome`]
//! classification → spill lifecycle — behind an event-style API
//! (`on_arrival`, `on_trigger_check`, `on_stage_done`, `on_rank_start`,
//! `on_psi_ready`, `on_reload_done`, `rank_compute`, `on_rank_done`).
//! The discrete-event simulator (`cluster::sim`) and the live threaded
//! engine (`serve::engine`) are thin time/compute adapters over it: they
//! translate coordinator actions into simulated or real durations and
//! never make a caching/placement/admission decision themselves.  A new
//! policy (richer cache tiers, alternative admission rules) is
//! implemented once in the coordinator and both engines pick it up for
//! free.

pub mod baseline;
pub mod coordinator;
pub mod expander;
pub mod hbm;
pub mod pipeline;
pub mod router;
pub mod trigger;

pub use baseline::{Mode, RemotePool};
pub use coordinator::{
    Completion, CoordinatorConfig, QueuedReload, RankAction, RankCompute, RelayCoordinator,
    ReloadResolution, SignalAction, Stage,
};
pub use expander::{DramPolicy, Expander, ExpanderStats, PseudoAction};
pub use hbm::{EntryState, HbmCache, HbmStats, InsertError, Micros};
pub use pipeline::{CacheOutcome, Lifecycle, PipelineConfig, StageSampler};
pub use router::{BalancePolicy, HashRing, Route, Router, RouterConfig, RouterStats};
pub use trigger::{
    AdmissionLimits, BehaviorMeta, Decision, Trigger, TriggerConfig, TriggerStats,
};
