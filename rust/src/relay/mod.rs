//! The paper's system contribution: *lifecycle caching under late-binding
//! placement*, decomposed exactly as §3.1 does —
//!
//! * **admission** — [`trigger`]: the sequence-aware trigger (Eqs. 1–3),
//! * **placement** — [`router`]: the affinity-aware consistent-hash router,
//! * **local capacity extension** — [`expander`]: the memory-aware DRAM
//!   tier with per-user single-flight and pseudo-pre-inference,
//!
//! over the [`hbm`] sliding-window lifecycle cache, with the [`pipeline`]
//! cascade model and the [`baseline`] modes (inline full inference and the
//! no-affinity remote-pool strawman).
//!
//! All modules are clock-agnostic state machines (callers pass `now_us`),
//! shared verbatim by the discrete-event simulator and the live engine.

pub mod baseline;
pub mod expander;
pub mod hbm;
pub mod pipeline;
pub mod router;
pub mod trigger;

pub use baseline::{Mode, RemotePool};
pub use expander::{DramPolicy, Expander, ExpanderStats, PseudoAction};
pub use hbm::{EntryState, HbmCache, HbmStats, InsertError, Micros};
pub use pipeline::{CacheOutcome, Lifecycle, PipelineConfig, StageSampler};
pub use router::{BalancePolicy, HashRing, Route, Router, RouterConfig, RouterStats};
pub use trigger::{
    AdmissionLimits, BehaviorMeta, Decision, Trigger, TriggerConfig, TriggerStats,
};
