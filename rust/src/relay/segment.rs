//! Beyond-prefix candidate-segment KV reuse: a shared, deduplicated
//! segment cache for ranking-side tokens.
//!
//! The relay race pre-infers only the candidate-*independent* user prefix
//! ψ; every ranking pass still recomputes the KV of the candidate-item
//! tokens — even though high-QPS traffic ranks heavily overlapping
//! candidate sets (a hot item appears in thousands of concurrent
//! requests).  Position-independent beyond-prefix caching (RcLLM) makes
//! those segments reusable across requests *and across users*: the first
//! ranker of `(item, model_version)` computes the segment once, everyone
//! else reuses or joins.
//!
//! This module is the cache plane of that subsystem:
//!
//! * [`SegmentKey`] — the cache key `(item_id, model_version)`.  Bumping
//!   the version (model push) rotates the key space; stale segments stop
//!   matching and age out via their TTL.
//! * [`SegmentStore`] — a ref-counted, single-flight store layered on the
//!   generic [`CacheHierarchy`] (its second instantiation, after the
//!   per-user ψ hierarchy), holding its own HBM budget partition carved
//!   out of the r1 slice so prefix ψ caches and segment caches contend
//!   explicitly.  Lower segment tiers are one [`TierConfig`] away; a
//!   lower-tier hit promotes synchronously (segment promotion is
//!   bookkeeping, not a bulk H2D — segments are KiB, ψ is MiB).
//! * [`SegmentPlan`] / [`SegmentAction`] — what one rank pass decided per
//!   candidate, produced by the coordinator's `rank_compute` so both
//!   engines inherit identical decisions.
//!
//! Lifecycle mapping onto the level-0 lifecycle window:
//!
//! | store concept          | window state                              |
//! |------------------------|-------------------------------------------|
//! | in production          | `Producing` (single-flight reservation)    |
//! | pinned by ≥1 rank pass | `Ready` (protected, lease re-armed)        |
//! | refcount 0             | `Consumed` (evictable, still readable)     |
//! | stale (TTL passed)     | expired — reclaimed on next probe/pressure |
//!
//! Ref-counting is therefore capacity-safe by construction: the window
//! never evicts unexpired `Ready`/`Producing` entries, so a pinned
//! segment can only vanish if a production outlives its TTL — in which
//! case [`SegmentStore::complete`] reports a clean abort and every
//! release degrades to a no-op (the refcount never underflows).

use crate::relay::hierarchy::{CacheHierarchy, HierarchyStats, PseudoAction};
use crate::relay::tier::TierConfig;
use crate::util::fxhash::FxHashMap;

/// Item ids occupy the low 48 bits of a packed key; the model version
/// the high 16.
pub const ITEM_MASK: u64 = (1 << 48) - 1;

/// Cache key of one candidate-item segment.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct SegmentKey {
    pub item: u64,
    pub version: u16,
}

impl SegmentKey {
    pub fn new(item: u64, version: u16) -> SegmentKey {
        SegmentKey { item: item & ITEM_MASK, version }
    }

    /// Pack into the `u64` key space the cache hierarchy indexes by.
    pub fn packed(self) -> u64 {
        ((self.version as u64) << 48) | self.item
    }

    pub fn unpack(packed: u64) -> SegmentKey {
        SegmentKey { item: packed & ITEM_MASK, version: (packed >> 48) as u16 }
    }
}

/// Static segment-subsystem parameters (`CoordinatorConfig::segment`).
#[derive(Debug, Clone)]
pub struct SegmentConfig {
    /// Fraction of the r1·HBM slice carved out for the segment cache
    /// (`--segment-cache`; 0 disables the subsystem entirely).
    pub frac: f64,
    /// Segment staleness bound: entries older than this are treated as
    /// misses and reclaimed (item features refresh on this horizon).
    pub ttl_us: u64,
    /// ψ footprint of one candidate segment
    /// ([`ModelSpec::segment_bytes`](crate::model::ModelSpec::segment_bytes)).
    pub seg_bytes: usize,
    /// Model version — the second key dimension; bump on model push.
    pub version: u16,
    /// Optional lower segment tiers (none by default; segments are small
    /// enough that the HBM partition usually suffices).
    pub tiers: Vec<TierConfig>,
}

impl SegmentConfig {
    /// Segment reuse off — the ψ-only system, decision-identical.
    pub fn disabled() -> SegmentConfig {
        SegmentConfig {
            frac: 0.0,
            ttl_us: 3_000_000,
            seg_bytes: 16 << 10,
            version: 0,
            tiers: Vec::new(),
        }
    }

    pub fn enabled(&self) -> bool {
        self.frac > 0.0
    }
}

/// What one candidate's segment lookup decided.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SegmentAction {
    /// Resident in the HBM partition: KV reused, recompute skipped.
    Reuse,
    /// Resident in a lower segment tier: promoted synchronously, reused.
    Promote,
    /// First ranker of this `(item, version)`: this request computes the
    /// segment and installs it at completion
    /// ([`SegmentStore::complete`], passing back the `ticket` so a
    /// producer whose reservation was evicted and re-produced by a later
    /// pass cannot install into the successor's production).
    Produce { ticket: u64 },
    /// Another in-flight request is producing it: deduped — the producer
    /// pays the compute, this pass reuses the result.
    Join,
    /// Cache full of pinned/in-flight segments: compute inline, uncached.
    Bypass,
}

/// Counters exported to metrics.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SegmentStats {
    pub lookups: u64,
    /// Served straight from the HBM partition.
    pub reused: u64,
    /// Served after a synchronous promotion from a lower segment tier.
    pub promoted: u64,
    /// Deduped onto an in-flight production (cross-request single-flight).
    pub joined: u64,
    /// Computed and installed by the first ranker.
    pub produced: u64,
    /// Computed inline without caching (capacity pressure).
    pub bypassed: u64,
    /// Productions whose entry was evicted mid-flight (clean abort).
    pub aborted: u64,
    /// Segment KV bytes *not* recomputed (reused + promoted + joined).
    pub bytes_saved: u64,
}

impl SegmentStats {
    /// Accumulate another instance's counters (cluster-wide reporting).
    pub fn merge(&mut self, b: SegmentStats) {
        self.lookups += b.lookups;
        self.reused += b.reused;
        self.promoted += b.promoted;
        self.joined += b.joined;
        self.produced += b.produced;
        self.bypassed += b.bypassed;
        self.aborted += b.aborted;
        self.bytes_saved += b.bytes_saved;
    }

    /// Fraction of candidate lookups that skipped recomputation.
    pub fn hit_ratio(&self) -> f64 {
        let hits = self.reused + self.promoted + self.joined;
        if self.lookups == 0 {
            0.0
        } else {
            hits as f64 / self.lookups as f64
        }
    }
}

/// What the coordinator's segment planning decided for one rank pass.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SegmentPlan {
    /// Segments served from the cache (HBM hit or lower-tier promotion).
    pub reused: u32,
    /// Segments deduped onto another request's in-flight production.
    pub joined: u32,
    /// Segments this request computes and installs.
    pub produced: u32,
    /// Segments computed inline without caching (capacity pressure).
    pub bypassed: u32,
}

impl SegmentPlan {
    /// Candidate recomputations skipped on this rank pass.
    pub fn skipped(&self) -> usize {
        (self.reused + self.joined) as usize
    }

    pub fn total(&self) -> usize {
        (self.reused + self.joined + self.produced + self.bypassed) as usize
    }
}

/// The ref-counted, single-flight candidate-segment store: one per
/// instance, keyed by [`SegmentKey::packed`], layered on a second
/// [`CacheHierarchy`] instantiation with its own HBM budget partition.
#[derive(Debug)]
pub struct SegmentStore<T> {
    hier: CacheHierarchy<T>,
    /// In-flight rank passes holding each segment (pin ⇒ `Ready`
    /// state ⇒ protected from capacity eviction until the TTL passes).
    pins: FxHashMap<u64, u32>,
    /// Current production ownership: key → ticket of the pass allowed to
    /// install it.  A reservation evicted mid-flight and re-produced by
    /// a later pass displaces the old ticket, so the stale producer's
    /// [`SegmentStore::complete`] aborts instead of installing into the
    /// successor's production.
    producing: FxHashMap<u64, u64>,
    next_ticket: u64,
    ttl_us: u64,
    seg_bytes: usize,
    stats: SegmentStats,
}

impl<T: Clone> SegmentStore<T> {
    /// `hbm_bytes` is the segment partition (frac · r1 · HBM); `tiers`
    /// the optional lower segment tiers, top-down.
    pub fn new(hbm_bytes: usize, tiers: &[TierConfig], ttl_us: u64, seg_bytes: usize) -> Self {
        // Segment promotions complete synchronously inside `acquire`, so
        // the hierarchy's promotion-concurrency cap must never queue one.
        SegmentStore {
            hier: CacheHierarchy::new(hbm_bytes, tiers, usize::MAX),
            pins: FxHashMap::default(),
            producing: FxHashMap::default(),
            next_ticket: 0,
            ttl_us,
            seg_bytes,
            stats: SegmentStats::default(),
        }
    }

    pub fn from_config(hbm_bytes: usize, cfg: &SegmentConfig) -> Self {
        SegmentStore::new(hbm_bytes, &cfg.tiers, cfg.ttl_us, cfg.seg_bytes)
    }

    // ---- introspection -----------------------------------------------------

    pub fn stats(&self) -> SegmentStats {
        self.stats
    }

    /// Flow counters of the underlying hierarchy (lower segment tiers).
    pub fn hierarchy_stats(&self) -> HierarchyStats {
        self.hier.stats()
    }

    /// Segments resident in the HBM partition.
    pub fn len(&self) -> usize {
        self.hier.hbm().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn used_bytes(&self) -> usize {
        self.hier.hbm().used_bytes()
    }

    /// Current refcount of one segment (0 = unpinned).
    pub fn pinned(&self, key: u64) -> u32 {
        self.pins.get(&key).copied().unwrap_or(0)
    }

    /// Read a resident segment's payload (None while producing/absent).
    pub fn payload(&self, key: u64, now: u64) -> Option<T> {
        self.hier.hbm().peek(key, now)
    }

    // ---- the per-candidate decision ---------------------------------------

    /// Classify one candidate lookup and pin the segment for the calling
    /// rank pass.  Every non-`Bypass` action takes one pin that the
    /// caller must [`release`](SegmentStore::release) at completion;
    /// `Produce` additionally obliges the caller to
    /// [`complete`](SegmentStore::complete) before releasing.
    pub fn acquire(&mut self, key: u64, now: u64) -> SegmentAction {
        self.stats.lookups += 1;
        match self.hier.pseudo_pre_infer(key, now) {
            PseudoAction::HbmHit => {
                // Re-arm the staleness lease and revive Consumed → Ready.
                self.hier.hbm_mut().extend_lease(key, now + self.ttl_us);
                self.pin(key);
                self.stats.reused += 1;
                self.stats.bytes_saved += self.seg_bytes as u64;
                SegmentAction::Reuse
            }
            PseudoAction::WaitProducing => {
                self.pin(key);
                self.stats.joined += 1;
                self.stats.bytes_saved += self.seg_bytes as u64;
                SegmentAction::Join
            }
            PseudoAction::StartReload { .. } => match self.hier.payload_below(key) {
                Some((bytes, payload)) => {
                    let done = self.hier.complete_reload(key, payload, bytes, now, self.ttl_us);
                    if done.installed {
                        self.pin(key);
                        self.stats.promoted += 1;
                        self.stats.bytes_saved += self.seg_bytes as u64;
                        SegmentAction::Promote
                    } else {
                        // HBM partition is pinned-full: use the lower-tier
                        // copy inline without promoting.
                        self.stats.bypassed += 1;
                        SegmentAction::Bypass
                    }
                }
                None => {
                    self.hier.abort_reload(key);
                    self.produce_or_bypass(key, now)
                }
            },
            // Unreachable with synchronous promotions (the single-flight
            // guard is released before `acquire` returns), but a join is
            // the safe degradation: release tolerates an absent entry.
            PseudoAction::JoinReload | PseudoAction::QueuedReload => {
                self.pin(key);
                self.stats.joined += 1;
                self.stats.bytes_saved += self.seg_bytes as u64;
                SegmentAction::Join
            }
            PseudoAction::Miss => self.produce_or_bypass(key, now),
        }
    }

    fn produce_or_bypass(&mut self, key: u64, now: u64) -> SegmentAction {
        match self.hier.hbm_mut().begin_produce(key, self.seg_bytes, now, self.ttl_us) {
            Ok(()) => {
                self.pin(key);
                self.stats.produced += 1;
                let ticket = self.next_ticket;
                self.next_ticket += 1;
                // Displaces any stale owner whose reservation was evicted.
                self.producing.insert(key, ticket);
                SegmentAction::Produce { ticket }
            }
            Err(_) => {
                self.stats.bypassed += 1;
                SegmentAction::Bypass
            }
        }
    }

    /// The producing rank pass finished computing `key`'s segment KV.
    /// Returns false on a clean abort: either the reservation was
    /// evicted mid-flight (its TTL passed under capacity pressure) or —
    /// if a later pass already re-produced the key — this producer's
    /// `ticket` is stale, so it must not install into the successor's
    /// in-flight production.  Joiners' releases degrade to no-ops and
    /// the current/next ranker still installs its own segment.
    pub fn complete(&mut self, key: u64, ticket: u64, payload: T) -> bool {
        if self.producing.get(&key) == Some(&ticket) {
            self.producing.remove(&key);
            if self.hier.hbm_mut().complete_produce(key, payload) {
                return true;
            }
        }
        self.stats.aborted += 1;
        false
    }

    /// A rank pass that pinned `key` completed.  At refcount 0 the
    /// segment becomes evictable (`Consumed`) but stays readable — the
    /// next lookup within the TTL revives it.  Releasing an unpinned or
    /// vanished key is a no-op: the refcount never underflows.
    pub fn release(&mut self, key: u64) {
        let Some(n) = self.pins.get_mut(&key) else { return };
        *n -= 1;
        if *n == 0 {
            self.pins.remove(&key);
            let _ = self.hier.hbm_mut().consume(key);
        }
    }

    fn pin(&mut self, key: u64) {
        *self.pins.entry(key).or_insert(0) += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::relay::tier::EvictPolicy;

    const KB: usize = 1 << 10;
    const TTL: u64 = 1_000_000;

    fn store(budget_kb: usize) -> SegmentStore<u32> {
        SegmentStore::new(budget_kb * KB, &[], TTL, 16 * KB)
    }

    /// Acquire expecting `Produce`; returns the production ticket.
    fn produce(s: &mut SegmentStore<u32>, key: u64, now: u64) -> u64 {
        match s.acquire(key, now) {
            SegmentAction::Produce { ticket } => ticket,
            other => panic!("expected Produce for {key}, got {other:?}"),
        }
    }

    #[test]
    fn key_packing_round_trips() {
        for (item, version) in [(0u64, 0u16), (7, 3), (ITEM_MASK, u16::MAX), (123_456_789, 42)] {
            let k = SegmentKey::new(item, version);
            assert_eq!(SegmentKey::unpack(k.packed()), k);
        }
        // Same item under different versions must not collide.
        assert_ne!(SegmentKey::new(5, 0).packed(), SegmentKey::new(5, 1).packed());
        // Items beyond 48 bits are masked, never bleed into the version.
        let k = SegmentKey::new(u64::MAX, 0);
        assert_eq!(k.packed() >> 48, 0);
    }

    #[test]
    fn produce_release_then_reuse() {
        let mut s = store(256);
        let k = SegmentKey::new(1, 0).packed();
        let t = produce(&mut s, k, 0);
        assert!(s.complete(k, t, 7));
        s.release(k);
        // Refcount 0: evictable but still readable within the TTL.
        assert_eq!(s.acquire(k, 10), SegmentAction::Reuse);
        assert_eq!(s.payload(k, 10), Some(7));
        s.release(k);
        let st = s.stats();
        assert_eq!((st.produced, st.reused, st.joined), (1, 1, 0));
        assert_eq!(st.bytes_saved, 16 * KB as u64);
    }

    #[test]
    fn concurrent_rankers_dedup_onto_one_producer() {
        let mut s = store(256);
        let k = SegmentKey::new(9, 0).packed();
        let t = produce(&mut s, k, 0);
        // Two concurrent requests sharing the hot item join, not produce.
        assert_eq!(s.acquire(k, 1), SegmentAction::Join);
        assert_eq!(s.acquire(k, 2), SegmentAction::Join);
        assert_eq!(s.pinned(k), 3);
        assert!(s.complete(k, t, 42));
        // All joiners observe the producer's segment.
        assert_eq!(s.payload(k, 3), Some(42));
        s.release(k);
        s.release(k);
        assert_eq!(s.pinned(k), 1, "producer still holds its pin");
        s.release(k);
        assert_eq!(s.pinned(k), 0);
        assert_eq!(s.acquire(k, 4), SegmentAction::Reuse);
        assert_eq!(s.stats().joined, 2);
    }

    #[test]
    fn ttl_expiry_forces_reproduction() {
        let mut s = store(256);
        let k = SegmentKey::new(3, 0).packed();
        let t = produce(&mut s, k, 0);
        assert!(s.complete(k, t, 1));
        s.release(k);
        // Within TTL: reuse (and the lease re-arms from `now`).
        assert_eq!(s.acquire(k, TTL - 1), SegmentAction::Reuse);
        s.release(k);
        // Past the re-armed lease: stale, reproduced.
        let t = produce(&mut s, k, 3 * TTL);
        assert!(s.complete(k, t, 2));
        s.release(k);
        assert_eq!(s.payload(k, 3 * TTL + 1), Some(2));
    }

    #[test]
    fn bypass_when_partition_pinned_full() {
        // Budget for exactly two 16 KB segments, both in production.
        let mut s = store(32);
        let (a, b, c) = (1u64, 2u64, 3u64);
        let ta = produce(&mut s, a, 0);
        let _tb = produce(&mut s, b, 0);
        assert_eq!(s.acquire(c, 0), SegmentAction::Bypass);
        assert_eq!(s.stats().bypassed, 1);
        // Completing and releasing one frees its slot for the next miss.
        assert!(s.complete(a, ta, 0));
        s.release(a);
        produce(&mut s, c, 1);
    }

    #[test]
    fn inflight_eviction_aborts_cleanly() {
        let mut s = store(32);
        let (a, b, c) = (1u64, 2u64, 3u64);
        let ta = produce(&mut s, a, 0);
        // Past a's TTL, capacity pressure reclaims the expired
        // reservation to fit new producers.
        let late = TTL + 1;
        let tb = produce(&mut s, b, late);
        let tc = produce(&mut s, c, late);
        // a's production completes into a reclaimed slot: clean abort.
        assert!(!s.complete(a, ta, 9));
        assert_eq!(s.stats().aborted, 1);
        // Releasing the aborted producer's pin must not underflow or
        // wedge the store.
        s.release(a);
        assert_eq!(s.pinned(a), 0);
        assert!(s.complete(b, tb, 1) && s.complete(c, tc, 2));
        s.release(b);
        s.release(c);
        assert_eq!(s.acquire(b, late + 1), SegmentAction::Reuse);
    }

    #[test]
    fn stale_producer_cannot_install_into_successor_production() {
        // A's reservation expires and is evicted under pressure; B
        // re-produces the same key.  A's (stale-ticket) completion must
        // abort cleanly instead of installing A's payload into B's
        // in-flight production.
        let mut s = store(32); // two 16 KB slots
        let (k, x, y) = (1u64, 2u64, 3u64);
        let ta = produce(&mut s, k, 0);
        let tx = produce(&mut s, x, 0); // partition now full
        let late = TTL + 1;
        assert!(s.complete(x, tx, 0));
        s.release(x); // x Consumed: evictable, but k is older (front)
        // y's production needs a slot: the expired reservation k is the
        // window's first reclaim.
        let _ty = produce(&mut s, y, late);
        // B re-produces k (evicting the consumed x for room) while A is
        // still running.
        let tb = produce(&mut s, k, late);
        assert!(!s.complete(k, ta, 111), "stale producer must abort");
        assert_eq!(s.stats().aborted, 1);
        s.release(k); // A's pin
        // B still owns the production and installs its own segment.
        assert!(s.complete(k, tb, 222));
        s.release(k);
        assert_eq!(s.payload(k, late + 1), Some(222), "successor's segment survives");
    }

    #[test]
    fn release_of_unpinned_key_is_noop() {
        let mut s = store(64);
        s.release(123); // never acquired
        let k = SegmentKey::new(1, 0).packed();
        let t = produce(&mut s, k, 0);
        assert!(s.complete(k, t, 1));
        s.release(k);
        s.release(k); // double release
        s.release(k);
        assert_eq!(s.pinned(k), 0);
        assert_eq!(s.acquire(k, 1), SegmentAction::Reuse);
    }

    #[test]
    fn version_bump_rotates_key_space() {
        let mut s = store(256);
        let old = SegmentKey::new(7, 0).packed();
        let new = SegmentKey::new(7, 1).packed();
        let t = produce(&mut s, old, 0);
        assert!(s.complete(old, t, 1));
        s.release(old);
        // Same item under the new model version misses and re-produces.
        let t = produce(&mut s, new, 1);
        assert!(s.complete(new, t, 2));
        s.release(new);
        assert_eq!(s.payload(old, 2), Some(1));
        assert_eq!(s.payload(new, 2), Some(2));
    }

    #[test]
    fn lower_tier_hit_promotes_synchronously() {
        let mut s: SegmentStore<u32> =
            SegmentStore::new(256 * KB, &[TierConfig::new(1 << 20, EvictPolicy::Lru)], TTL, 16 * KB);
        let k = SegmentKey::new(4, 0).packed();
        // Seed the lower tier directly (as a demoted segment would be).
        assert!(s.hier.spill(k, 16 * KB, 77));
        assert_eq!(s.acquire(k, 0), SegmentAction::Promote);
        assert_eq!(s.payload(k, 1), Some(77));
        s.release(k);
        let st = s.stats();
        assert_eq!((st.promoted, st.produced), (1, 0));
    }

    /// Property: under random interleavings of acquire / complete /
    /// release across concurrent rank passes, the pin refcount exactly
    /// tracks outstanding acquires, never underflows, and the store
    /// never wedges (every key stays acquirable).
    #[test]
    fn prop_refcount_tracks_acquires_and_never_underflows() {
        crate::util::prop::check("segment-refcount", 120, |rng| {
            let mut s: SegmentStore<u32> = SegmentStore::new(1 << 20, &[], 1 << 40, 16 * KB);
            let keys: Vec<u64> = (0..6).map(|i| SegmentKey::new(i, 0).packed()).collect();
            let mut model: FxHashMap<u64, u32> = FxHashMap::default();
            let mut producing: Vec<(u64, u64)> = Vec::new();
            for step in 0..400 {
                let k = *rng.choice(&keys);
                match rng.range(0, 4) {
                    0 | 1 => {
                        let action = s.acquire(k, step as u64);
                        match action {
                            SegmentAction::Produce { ticket } => {
                                if producing.iter().any(|&(p, _)| p == k) {
                                    return Err(format!("step {step}: duplicate producer for {k}"));
                                }
                                producing.push((k, ticket));
                                *model.entry(k).or_insert(0) += 1;
                            }
                            SegmentAction::Reuse | SegmentAction::Join | SegmentAction::Promote => {
                                *model.entry(k).or_insert(0) += 1;
                            }
                            SegmentAction::Bypass => {}
                        }
                    }
                    2 => {
                        if let Some(pos) = producing.iter().position(|&(p, _)| p == k) {
                            let (_, ticket) = producing.remove(pos);
                            if !s.complete(k, ticket, step as u32) {
                                return Err(format!("step {step}: unexpired production aborted"));
                            }
                        }
                    }
                    _ => {
                        // Release — sometimes of keys never pinned.
                        s.release(k);
                        if let Some(n) = model.get_mut(&k) {
                            *n -= 1;
                            if *n == 0 {
                                model.remove(&k);
                            }
                        }
                    }
                }
                for &key in &keys {
                    let want = model.get(&key).copied().unwrap_or(0);
                    if s.pinned(key) != want {
                        return Err(format!(
                            "step {step}: pin count {} vs model {want} for {key}",
                            s.pinned(key)
                        ));
                    }
                }
            }
            Ok(())
        });
    }

    /// Property: per key there is at most one producer at a time, every
    /// concurrent ranker joins it, and once it completes all of them
    /// observe the producer's payload — the dedup contract.
    #[test]
    fn prop_dedup_joiners_observe_producer_segment() {
        crate::util::prop::check("segment-dedup", 120, |rng| {
            let mut s: SegmentStore<u32> = SegmentStore::new(1 << 22, &[], 1 << 40, 16 * KB);
            let keys: Vec<u64> = (0..5).map(|i| SegmentKey::new(i, 0).packed()).collect();
            let mut producer: FxHashMap<u64, (u64, u32)> = FxHashMap::default();
            let mut installed: FxHashMap<u64, u32> = FxHashMap::default();
            for step in 0..300u32 {
                let k = *rng.choice(&keys);
                if rng.bernoulli(0.6) {
                    match s.acquire(k, step as u64) {
                        SegmentAction::Produce { ticket } => {
                            if producer.contains_key(&k) {
                                return Err(format!("step {step}: two producers for {k}"));
                            }
                            producer.insert(k, (ticket, step));
                        }
                        SegmentAction::Join => {
                            if !producer.contains_key(&k) {
                                return Err(format!("step {step}: join with no producer for {k}"));
                            }
                        }
                        SegmentAction::Reuse => {
                            let Some(&v) = installed.get(&k) else {
                                return Err(format!("step {step}: reuse of never-installed {k}"));
                            };
                            if s.payload(k, step as u64) != Some(v) {
                                return Err(format!("step {step}: joiner saw a different segment"));
                            }
                        }
                        SegmentAction::Promote | SegmentAction::Bypass => {}
                    }
                } else {
                    let next = producer.iter().next().map(|(&k, &t)| (k, t));
                    if let Some((k, (ticket, tag))) = next {
                        producer.remove(&k);
                        if !s.complete(k, ticket, tag) {
                            return Err(format!("step {step}: unexpired production aborted"));
                        }
                        installed.insert(k, tag);
                        if s.payload(k, step as u64) != Some(tag) {
                            return Err(format!("step {step}: installed payload lost"));
                        }
                    }
                }
            }
            Ok(())
        });
    }

    /// Property: with a tiny partition and short TTL, expired in-flight
    /// productions evicted under pressure always abort cleanly — the
    /// store keeps serving, pins drain to zero, and no key gets stuck.
    #[test]
    fn prop_inflight_eviction_always_aborts_cleanly() {
        crate::util::prop::check("segment-abort", 120, |rng| {
            let ttl = 50;
            let mut s: SegmentStore<u32> = SegmentStore::new(64 * KB, &[], ttl, 16 * KB);
            let keys: Vec<u64> = (0..8).map(|i| SegmentKey::new(i, 0).packed()).collect();
            let mut producing: Vec<(u64, u64)> = Vec::new();
            let mut pinned: Vec<u64> = Vec::new();
            let mut now = 0u64;
            for step in 0..300 {
                now += rng.range(0, 40) as u64;
                let k = *rng.choice(&keys);
                match rng.range(0, 3) {
                    0 => match s.acquire(k, now) {
                        SegmentAction::Produce { ticket } => {
                            producing.push((k, ticket));
                            pinned.push(k);
                        }
                        SegmentAction::Reuse | SegmentAction::Join | SegmentAction::Promote => {
                            pinned.push(k)
                        }
                        SegmentAction::Bypass => {}
                    },
                    1 => {
                        if let Some(pos) =
                            (!producing.is_empty()).then(|| rng.range(0, producing.len()))
                        {
                            let (key, ticket) = producing.remove(pos);
                            // Aborts are allowed (TTL pressure); either way
                            // the store must keep functioning.
                            let _ = s.complete(key, ticket, step as u32);
                        }
                    }
                    _ => {
                        if let Some(pos) = (!pinned.is_empty()).then(|| rng.range(0, pinned.len()))
                        {
                            let key = pinned.remove(pos);
                            s.release(key);
                        }
                    }
                }
            }
            // Drain: complete leftover productions, release every pin.
            while let Some((k, ticket)) = producing.pop() {
                let _ = s.complete(k, ticket, 0);
            }
            while let Some(k) = pinned.pop() {
                s.release(k);
            }
            for &k in &keys {
                if s.pinned(k) != 0 {
                    return Err(format!("key {k} left pinned after drain"));
                }
            }
            // Every key is still acquirable (no wedged single-flight guard).
            now += 10 * ttl;
            for &k in &keys {
                match s.acquire(k, now) {
                    SegmentAction::Produce { ticket } => {
                        let _ = s.complete(k, ticket, 1);
                        s.release(k);
                    }
                    SegmentAction::Reuse => s.release(k),
                    other => return Err(format!("key {k} wedged: {other:?}")),
                }
            }
            Ok(())
        });
    }
}
