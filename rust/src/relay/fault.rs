//! Deterministic fault-injection plane: a seeded [`FaultPlan`] compiled
//! at coordinator construction (the `CellScenario` recipe) that injects
//! faults at named decision points of the relay-race flow, plus the
//! response machinery — bounded retries with deterministic exponential
//! backoff, and the graceful-degradation ladder
//! `Relay → DegradedPrefix → FullInference → Shed` that replaces the
//! single fall-to-full cliff.
//!
//! ## The injection-is-decision-synchronous contract
//!
//! Every fault draw is a pure function of `(plan seed, fault kind,
//! stable id, attempt)` — the stable id is the workload request id
//! (`GenRequest::rid`) or the user id, both assigned by the trace before
//! any engine runs.  Draws never read completion timing, engine clocks,
//! or engine-order-dependent counters (slab slots recycle in
//! completion order and differ across engines; ordinal counters at
//! `on_psi_ready`/`on_rank_start` sites would too).  Consequently the
//! discrete-event simulator, the serialized reference and the live
//! threaded engine inject the *same* faults at the *same* requests, and
//! `tests/cross_engine.rs` / `tests/fault_determinism.rs` pin the whole
//! plane bit-identical across engines and `--jobs` levels.
//!
//! ## Retries are priced, not timed
//!
//! A retry at attempt `i` waits `backoff · 2^(i-1)`; the total worst-case
//! budget `backoff · (2^retries − 1)` is folded into the trigger's
//! admission latency estimate ([`FaultConfig::retry_budget_us`], the
//! `batch_window_us` folding precedent) so the adaptive controller sees
//! retry pressure — but the decision of *whether* a retry recovers is
//! another seeded draw, never a timer race.  This keeps the fault plane
//! inside the decision plane.

use anyhow::{bail, Result};

/// Named decision points where the plan can inject a fault.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// ψ production fails on the special instance (side path): waiters
    /// take the degradation ladder, the lifecycle entry is evicted.
    PsiFail,
    /// DRAM→HBM reload/promotion fails at completion: the payload is not
    /// installed, woken joiners take the ladder.
    ReloadFail,
    /// Trigger signal dropped before the admission decision: the request
    /// is never admitted and ranks as plain full inference — the fault
    /// retries recover (the figure-`faults` headline).
    TriggerDrop,
    /// HBM→DRAM spill lost in flight: the demotion is suppressed, the
    /// next miss reloads nothing (non-retryable).
    SpillLoss,
    /// Candidate-segment production aborted before planning: ranking
    /// pays full prefill for the batch (pricing-only, non-retryable).
    SegAbort,
    /// Instance crash, compiled to cell-scenario events at a trace
    /// percentage (`crash@40%[:cellK]`) rather than drawn per request.
    Crash,
}

impl FaultKind {
    pub const COUNT: usize = 6;
    pub const NAMES: [&'static str; FaultKind::COUNT] =
        ["psi-fail", "reload-fail", "trigger-drop", "spill-loss", "seg-abort", "crash"];
    pub const ALL: [FaultKind; FaultKind::COUNT] = [
        FaultKind::PsiFail,
        FaultKind::ReloadFail,
        FaultKind::TriggerDrop,
        FaultKind::SpillLoss,
        FaultKind::SegAbort,
        FaultKind::Crash,
    ];

    pub fn index(self) -> usize {
        match self {
            FaultKind::PsiFail => 0,
            FaultKind::ReloadFail => 1,
            FaultKind::TriggerDrop => 2,
            FaultKind::SpillLoss => 3,
            FaultKind::SegAbort => 4,
            FaultKind::Crash => 5,
        }
    }

    pub fn name(self) -> &'static str {
        Self::NAMES[self.index()]
    }

    /// Whether the bounded-retry policy applies.  Spill loss and segment
    /// aborts are fire-and-forget side effects with no requester waiting
    /// on the spot to retry them; crashes are scenario events.
    pub fn retryable(self) -> bool {
        matches!(self, FaultKind::PsiFail | FaultKind::ReloadFail | FaultKind::TriggerDrop)
    }
}

/// Crash injection point: a percentage through the arrival sequence and
/// an optional target cell (`None` ⇒ every cell crashes one instance).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CrashSpec {
    /// Percent of the request trace after which the crash fires (1–99).
    pub pct: u32,
    /// Target cell index; `None` hits every cell.
    pub cell: Option<usize>,
}

/// Parsed `--faults` spec: per-kind injection rates plus the retry /
/// degradation policy.  The all-zero default (`--faults none`) disables
/// the plane entirely — zero draws, zero folded budget — so a fault-off
/// run is decision-bit-identical to a build without the plane.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultConfig {
    /// Injection probability per [`FaultKind`] (crash's slot is unused —
    /// crashes are scheduled via [`FaultConfig::crash`], not drawn).
    pub rates: [f64; FaultKind::COUNT],
    /// Scheduled instance crash, if any.
    pub crash: Option<CrashSpec>,
    /// Bounded retry attempts per injected retryable fault (0 = off).
    pub retries: u32,
    /// Base exponential-backoff step in µs (attempt i waits `2^(i-1)·backoff`).
    pub backoff_us: u64,
    /// Degradation-ladder shed probability: an unrecovered fault sheds
    /// with this probability instead of degrading to full inference.
    pub shed: f64,
    /// Draw seed, folded in by the engines from their run seed (the
    /// `batch_window_us` precedent) — not part of the spec grammar.
    pub seed: u64,
}

impl Default for FaultConfig {
    fn default() -> FaultConfig {
        FaultConfig {
            rates: [0.0; FaultKind::COUNT],
            crash: None,
            retries: 0,
            backoff_us: 200,
            shed: 0.0,
            seed: 0,
        }
    }
}

impl FaultConfig {
    /// Parse a `--faults` spec: comma-separated items of
    /// `psi-fail:R | reload-fail:R | trigger-drop:R | spill-loss:R |
    /// seg-abort:R | crash@P%[:cellK] | retry:N | backoff:USus | shed:R`,
    /// or `none`.
    pub fn parse(spec: &str) -> Result<FaultConfig> {
        let mut cfg = FaultConfig::default();
        let spec = spec.trim();
        if spec.is_empty() || spec == "none" {
            return Ok(cfg);
        }
        for item in spec.split(',') {
            let item = item.trim();
            if let Some(rest) = item.strip_prefix("crash@") {
                let (pct_s, cell) = match rest.split_once(':') {
                    Some((p, c)) => {
                        let Some(idx) = c.strip_prefix("cell") else {
                            bail!("faults: crash target must be cellK, got '{c}'");
                        };
                        (p, Some(idx.parse::<usize>().map_err(|_| {
                            anyhow::anyhow!("faults: bad crash cell index '{idx}'")
                        })?))
                    }
                    None => (rest, None),
                };
                let Some(pct_s) = pct_s.strip_suffix('%') else {
                    bail!("faults: crash point must be a percentage, got '{pct_s}'");
                };
                let pct: u32 = pct_s
                    .parse()
                    .map_err(|_| anyhow::anyhow!("faults: bad crash percentage '{pct_s}'"))?;
                if !(1..=99).contains(&pct) {
                    bail!("faults: crash percentage must be in 1..=99, got {pct}");
                }
                cfg.crash = Some(CrashSpec { pct, cell });
                continue;
            }
            let Some((key, val)) = item.split_once(':') else {
                bail!("faults: expected key:value, got '{item}'");
            };
            match key {
                "retry" => {
                    cfg.retries = val
                        .parse()
                        .map_err(|_| anyhow::anyhow!("faults: bad retry count '{val}'"))?;
                    if cfg.retries > 8 {
                        bail!("faults: retry count must be <= 8, got {}", cfg.retries);
                    }
                }
                "backoff" => {
                    let v = val.strip_suffix("us").unwrap_or(val);
                    cfg.backoff_us = v
                        .parse()
                        .map_err(|_| anyhow::anyhow!("faults: bad backoff '{val}'"))?;
                    if cfg.backoff_us == 0 || cfg.backoff_us > 1_000_000 {
                        bail!("faults: backoff must be in 1..=1000000 us, got {}", cfg.backoff_us);
                    }
                }
                "shed" => {
                    cfg.shed = val
                        .parse()
                        .map_err(|_| anyhow::anyhow!("faults: bad shed rate '{val}'"))?;
                    if !(0.0..=1.0).contains(&cfg.shed) {
                        bail!("faults: shed rate must be in [0, 1], got {}", cfg.shed);
                    }
                }
                "crash" => bail!("faults: crash is scheduled, not drawn — use crash@P%[:cellK]"),
                kind => {
                    let Some(k) = FaultKind::ALL.iter().find(|k| k.name() == kind) else {
                        bail!(
                            "faults: unknown key '{kind}' (expected one of {}, crash@P%, retry, backoff, shed)",
                            FaultKind::NAMES[..5].join(", ")
                        );
                    };
                    let rate: f64 = val
                        .parse()
                        .map_err(|_| anyhow::anyhow!("faults: bad rate '{val}' for {kind}"))?;
                    if !(0.0..=1.0).contains(&rate) {
                        bail!("faults: {kind} rate must be in [0, 1], got {rate}");
                    }
                    cfg.rates[k.index()] = rate;
                }
            }
        }
        Ok(cfg)
    }

    /// Canonical spec string — `parse(label())` round-trips (seed aside).
    pub fn label(&self) -> String {
        let mut parts = Vec::new();
        for k in FaultKind::ALL {
            if self.rates[k.index()] > 0.0 {
                parts.push(format!("{}:{}", k.name(), self.rates[k.index()]));
            }
        }
        if let Some(c) = self.crash {
            match c.cell {
                Some(i) => parts.push(format!("crash@{}%:cell{i}", c.pct)),
                None => parts.push(format!("crash@{}%", c.pct)),
            }
        }
        if self.retries > 0 {
            parts.push(format!("retry:{}", self.retries));
            parts.push(format!("backoff:{}us", self.backoff_us));
        }
        if self.shed > 0.0 {
            parts.push(format!("shed:{}", self.shed));
        }
        if parts.is_empty() {
            "none".to_string()
        } else {
            parts.join(",")
        }
    }

    /// Whether the plane does anything at all.
    pub fn enabled(&self) -> bool {
        self.crash.is_some() || self.rates.iter().any(|&r| r > 0.0)
    }

    /// Worst-case retry latency, priced into the trigger's admission
    /// estimate: `backoff · (2^retries − 1)` µs, the sum of the
    /// exponential-backoff waits.  Zero when no retryable fault can
    /// inject, so `--faults none` folds nothing.
    pub fn retry_budget_us(&self) -> u64 {
        let retryable = FaultKind::ALL
            .iter()
            .any(|k| k.retryable() && self.rates[k.index()] > 0.0);
        if !retryable || self.retries == 0 {
            return 0;
        }
        self.backoff_us.saturating_mul((1u64 << self.retries) - 1)
    }
}

/// Result of resolving one decision point against the plan.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultOutcome {
    /// No fault at this decision point.
    Clean,
    /// Injected, recovered by a bounded retry on the given attempt.
    Recovered { attempts: u32 },
    /// Injected; every retry (if any) failed — take the ladder.
    Failed,
}

/// Per-kind fault counters (the `FaultReport` of `RunMetrics`); merges
/// across per-cell coordinators like the other stat blocks.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct FaultReport {
    pub injected: [u64; FaultKind::COUNT],
    pub retried: [u64; FaultKind::COUNT],
    pub recovered: [u64; FaultKind::COUNT],
    pub degraded: [u64; FaultKind::COUNT],
    pub shed: [u64; FaultKind::COUNT],
}

impl FaultReport {
    pub fn merge(&mut self, b: &FaultReport) {
        for i in 0..FaultKind::COUNT {
            self.injected[i] += b.injected[i];
            self.retried[i] += b.retried[i];
            self.recovered[i] += b.recovered[i];
            self.degraded[i] += b.degraded[i];
            self.shed[i] += b.shed[i];
        }
    }

    pub fn any(&self) -> bool {
        self.injected.iter().any(|&c| c > 0)
    }

    /// `(injected, retried, recovered, degraded, shed)` summed over kinds.
    pub fn totals(&self) -> (u64, u64, u64, u64, u64) {
        (
            self.injected.iter().sum(),
            self.retried.iter().sum(),
            self.recovered.iter().sum(),
            self.degraded.iter().sum(),
            self.shed.iter().sum(),
        )
    }
}

/// SplitMix64 finalizer — the stateless mixing step behind every draw.
fn splitmix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9e37_79b9_7f4a_7c15);
    x = (x ^ (x >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    x ^ (x >> 31)
}

/// Salt domain separating shed draws from injection draws.
const SHED_SALT: u64 = 0x51ed_0000_0000_5a17;

/// The compiled plan a coordinator owns: the parsed config, the run
/// seed, and the counters.  All methods are allocation-free — the
/// inject/retry/degrade path sits on the rank hot path and
/// `bench_faults.rs` gates it at zero allocations per op.
#[derive(Debug, Clone)]
pub struct FaultPlan {
    cfg: FaultConfig,
    report: FaultReport,
}

impl FaultPlan {
    pub fn new(cfg: FaultConfig) -> FaultPlan {
        FaultPlan { cfg, report: FaultReport::default() }
    }

    pub fn config(&self) -> &FaultConfig {
        &self.cfg
    }

    pub fn enabled(&self) -> bool {
        self.cfg.enabled()
    }

    pub fn report(&self) -> FaultReport {
        self.report
    }

    /// A uniform draw in `[0, 1)` from `(seed, kind, id, attempt)` only.
    fn draw(&self, kind: FaultKind, id: u64, attempt: u32) -> f64 {
        let mut h = splitmix64(self.cfg.seed ^ (kind.index() as u64 + 1));
        h = splitmix64(h ^ id);
        h = splitmix64(h ^ ((attempt as u64) << 32));
        (h >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Resolve one decision point: inject with the configured rate,
    /// then (for retryable kinds) run the bounded-retry ladder — each
    /// attempt is an independent seeded draw against the same rate, so
    /// recovery is deterministic per `(kind, id)` and identical on every
    /// engine regardless of where the retry would have waited.
    pub fn resolve(&mut self, kind: FaultKind, id: u64) -> FaultOutcome {
        let rate = self.cfg.rates[kind.index()];
        if rate <= 0.0 {
            return FaultOutcome::Clean;
        }
        if self.draw(kind, id, 0) >= rate {
            return FaultOutcome::Clean;
        }
        self.report.injected[kind.index()] += 1;
        if kind.retryable() {
            for attempt in 1..=self.cfg.retries {
                self.report.retried[kind.index()] += 1;
                if self.draw(kind, id, attempt) >= rate {
                    self.report.recovered[kind.index()] += 1;
                    return FaultOutcome::Recovered { attempts: attempt };
                }
            }
        }
        FaultOutcome::Failed
    }

    /// Count an injection decided outside the draw path — scheduled
    /// crashes are compiled to cell events, not drawn per request.
    pub fn note_injected(&mut self, kind: FaultKind) {
        self.report.injected[kind.index()] += 1;
    }

    /// Degradation ladder for an unrecovered fault: shed with the
    /// configured probability (a seeded draw on the request id), else
    /// degrade to full inference.  Returns `true` to shed.
    pub fn shed_or_degrade(&mut self, kind: FaultKind, id: u64) -> bool {
        if self.cfg.shed > 0.0 {
            let mut h = splitmix64(self.cfg.seed ^ SHED_SALT);
            h = splitmix64(h ^ id);
            let u = (h >> 11) as f64 / (1u64 << 53) as f64;
            if u < self.cfg.shed {
                self.report.shed[kind.index()] += 1;
                return true;
            }
        }
        self.report.degraded[kind.index()] += 1;
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_none_and_empty_disable() {
        for spec in ["none", "", "  none  "] {
            let cfg = FaultConfig::parse(spec).unwrap();
            assert!(!cfg.enabled(), "{spec:?}");
            assert_eq!(cfg.retry_budget_us(), 0);
            assert_eq!(cfg.label(), "none");
        }
    }

    #[test]
    fn parse_full_spec_and_label_round_trip() {
        let spec = "psi-fail:0.01,reload-fail:0.05,trigger-drop:0.02,crash@40%:cell0,retry:3,backoff:400us,shed:0.25";
        let cfg = FaultConfig::parse(spec).unwrap();
        assert!(cfg.enabled());
        assert_eq!(cfg.rates[FaultKind::PsiFail.index()], 0.01);
        assert_eq!(cfg.rates[FaultKind::ReloadFail.index()], 0.05);
        assert_eq!(cfg.rates[FaultKind::TriggerDrop.index()], 0.02);
        assert_eq!(cfg.crash, Some(CrashSpec { pct: 40, cell: Some(0) }));
        assert_eq!(cfg.retries, 3);
        assert_eq!(cfg.backoff_us, 400);
        assert_eq!(cfg.shed, 0.25);
        // Worst-case budget: 400·(2³−1) = 2800 µs.
        assert_eq!(cfg.retry_budget_us(), 2800);
        let relabel = FaultConfig::parse(&cfg.label()).unwrap();
        assert_eq!(relabel, cfg);
        // Crash with no cell target round-trips too.
        let all = FaultConfig::parse("crash@60%").unwrap();
        assert_eq!(all.crash, Some(CrashSpec { pct: 60, cell: None }));
        assert_eq!(FaultConfig::parse(&all.label()).unwrap(), all);
    }

    #[test]
    fn parse_rejects_malformed_specs() {
        for bad in [
            "psi-fail",           // no rate
            "psi-fail:2.0",       // rate out of range
            "warp-core:0.1",      // unknown kind
            "crash:0.1",          // crash is scheduled, not drawn
            "crash@0%",           // pct out of range
            "crash@140%",         // pct out of range
            "crash@40",           // missing %
            "crash@40%:node0",    // bad cell prefix
            "retry:9",            // retry cap
            "backoff:0us",        // zero backoff
            "shed:1.5",           // shed out of range
        ] {
            assert!(FaultConfig::parse(bad).is_err(), "{bad:?} should fail");
        }
    }

    #[test]
    fn retry_budget_requires_retryable_rate() {
        // Retries configured but only non-retryable kinds active: no
        // budget is folded (nothing can consume a retry).
        let cfg = FaultConfig::parse("spill-loss:0.5,retry:4,backoff:100us").unwrap();
        assert_eq!(cfg.retry_budget_us(), 0);
        let cfg = FaultConfig::parse("trigger-drop:0.5,retry:4,backoff:100us").unwrap();
        assert_eq!(cfg.retry_budget_us(), 100 * 15);
    }

    #[test]
    fn draws_are_deterministic_and_rate_accurate() {
        let mut cfg = FaultConfig::parse("psi-fail:0.1").unwrap();
        cfg.seed = 42;
        let mut a = FaultPlan::new(cfg.clone());
        let mut b = FaultPlan::new(cfg);
        let mut injected = 0u64;
        for id in 0..10_000u64 {
            let oa = a.resolve(FaultKind::PsiFail, id);
            assert_eq!(oa, b.resolve(FaultKind::PsiFail, id), "id {id}");
            if oa != FaultOutcome::Clean {
                injected += 1;
            }
        }
        assert_eq!(a.report(), b.report());
        assert_eq!(a.report().injected[FaultKind::PsiFail.index()], injected);
        // ~10% of 10k with generous slack.
        assert!((800..=1200).contains(&injected), "injected {injected}");
        // A different seed draws a different fault set.
        let mut cfg2 = FaultConfig::parse("psi-fail:0.1").unwrap();
        cfg2.seed = 43;
        let mut c = FaultPlan::new(cfg2);
        let mut differs = false;
        for id in 0..10_000u64 {
            if c.resolve(FaultKind::PsiFail, id) != b.resolve(FaultKind::PsiFail, id) {
                differs = true;
                break;
            }
        }
        assert!(differs, "seed must matter");
    }

    #[test]
    fn retries_recover_a_strict_subset() {
        let base = FaultConfig::parse("trigger-drop:0.2").unwrap();
        let with_retry = FaultConfig::parse("trigger-drop:0.2,retry:3,backoff:100us").unwrap();
        let mut off = FaultPlan::new(base);
        let mut on = FaultPlan::new(with_retry);
        let (mut failed_off, mut failed_on) = (0u64, 0u64);
        for id in 0..10_000u64 {
            if off.resolve(FaultKind::TriggerDrop, id) == FaultOutcome::Failed {
                failed_off += 1;
            }
            if on.resolve(FaultKind::TriggerDrop, id) == FaultOutcome::Failed {
                failed_on += 1;
            }
        }
        // Same injection draw (attempt 0) → same injected set; retries
        // can only convert Failed → Recovered.
        assert_eq!(off.report().injected, on.report().injected);
        assert!(failed_on < failed_off, "retries must recover: {failed_on} vs {failed_off}");
        let r = on.report();
        let idx = FaultKind::TriggerDrop.index();
        assert_eq!(r.recovered[idx], failed_off - failed_on);
        assert!(r.retried[idx] >= r.recovered[idx]);
    }

    #[test]
    fn non_retryable_kinds_never_retry() {
        let cfg = FaultConfig::parse("spill-loss:0.5,seg-abort:0.5,retry:4").unwrap();
        let mut plan = FaultPlan::new(cfg);
        for id in 0..1000u64 {
            for kind in [FaultKind::SpillLoss, FaultKind::SegAbort] {
                let o = plan.resolve(kind, id);
                assert!(o == FaultOutcome::Clean || o == FaultOutcome::Failed, "{o:?}");
            }
        }
        let r = plan.report();
        assert!(r.any());
        assert_eq!(r.retried, [0; FaultKind::COUNT]);
        assert_eq!(r.recovered, [0; FaultKind::COUNT]);
    }

    #[test]
    fn shed_or_degrade_partitions_by_rate() {
        let mut cfg = FaultConfig::parse("psi-fail:1.0,shed:0.3").unwrap();
        cfg.seed = 7;
        let mut plan = FaultPlan::new(cfg);
        let mut sheds = 0u64;
        for id in 0..10_000u64 {
            if plan.shed_or_degrade(FaultKind::PsiFail, id) {
                sheds += 1;
            }
        }
        let r = plan.report();
        let idx = FaultKind::PsiFail.index();
        assert_eq!(r.shed[idx], sheds);
        assert_eq!(r.degraded[idx], 10_000 - sheds);
        assert!((2700..=3300).contains(&sheds), "sheds {sheds}");
        // shed:0 always degrades.
        let mut plan = FaultPlan::new(FaultConfig::parse("psi-fail:1.0").unwrap());
        assert!(!plan.shed_or_degrade(FaultKind::PsiFail, 1));
        assert_eq!(plan.report().degraded[idx], 1);
        assert_eq!(plan.report().shed[idx], 0);
    }

    #[test]
    fn report_merge_sums_per_kind() {
        let mut a = FaultReport::default();
        let mut b = FaultReport::default();
        a.injected[0] = 3;
        a.shed[4] = 2;
        b.injected[0] = 5;
        b.recovered[1] = 7;
        a.merge(&b);
        assert_eq!(a.injected[0], 8);
        assert_eq!(a.recovered[1], 7);
        assert_eq!(a.shed[4], 2);
        assert_eq!(a.totals(), (8, 0, 7, 0, 2));
    }
}
