//! HBM sliding-window lifecycle cache (Fig. 10).
//!
//! Per-user prefix caches ψ are *inserted* by pre-inference, *consumed*
//! by ranking, and *evicted* as new admitted users arrive.  Admission
//! control (the sequence-aware trigger) bounds the live footprint so the
//! window always covers one request lifecycle T_life; this module
//! enforces the capacity invariant locally and reports violations (a
//! cache evicted before consumption counts as `lost` — it forces the
//! consumer to fall back, never to fetch remotely: invariant I1).
//!
//! The cache is generic over the payload so the discrete-event simulator
//! (`T = ()`) and the live engine (`T = Arc<KvBuffer>`) share one
//! implementation and one test suite.

use std::collections::VecDeque;

use crate::util::fxhash::FxHashMap;

use crate::relay::tier::{CacheTier, EvictPolicy, TierStats};

pub type Micros = u64;

/// Lifecycle state of one per-user entry.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EntryState {
    /// Pre-inference running; space reserved, payload not yet available.
    Producing,
    /// ψ resident and consumable.
    Ready,
    /// Consumed by ranking; evictable (and spillable to DRAM).
    Consumed,
}

#[derive(Debug)]
struct Entry<T> {
    bytes: usize,
    state: EntryState,
    /// Entries older than this are expired (lifecycle over).
    deadline_us: Micros,
    /// Insertion sequence number; pairs entries with their `order` slot
    /// so removal can tombstone instead of scanning (perf: the O(n)
    /// `VecDeque::retain` dominated churn at production window sizes).
    seq: u64,
    payload: Option<T>,
}

/// Why an insert was refused.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum InsertError {
    /// Live (unexpired, unconsumed) caches fill the reserved footprint —
    /// the admission controller is overcommitting if this fires.
    CapacityExhausted,
    /// Entry larger than the whole reserved footprint.
    TooLarge,
}

/// Counters exported to metrics.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct HbmStats {
    pub inserts: u64,
    /// Probes that found a Ready (not-yet-consumed) ψ — first-consume
    /// hits on the relay fast path.
    pub ready_hits: u64,
    /// Probes that found an already-Consumed ψ still inside its window —
    /// rapid same-user re-ranks (reported separately so figure output
    /// can split first-consume from re-rank traffic).
    pub consumed_hits: u64,
    pub producing_hits: u64,
    pub misses: u64,
    pub consumed: u64,
    pub evicted_consumed: u64,
    pub evicted_expired: u64,
    /// Unconsumed live entries evicted under pressure (should be ~0 when
    /// admission control is correctly configured).
    pub lost: u64,
    pub rejected: u64,
}

impl HbmStats {
    /// Accumulate another instance's counters (cluster-wide reporting).
    pub fn merge(&mut self, b: HbmStats) {
        self.inserts += b.inserts;
        self.ready_hits += b.ready_hits;
        self.consumed_hits += b.consumed_hits;
        self.producing_hits += b.producing_hits;
        self.misses += b.misses;
        self.consumed += b.consumed;
        self.evicted_consumed += b.evicted_consumed;
        self.evicted_expired += b.evicted_expired;
        self.lost += b.lost;
        self.rejected += b.rejected;
    }
}

/// Sliding-window HBM cache with a byte-capacity bound.
#[derive(Debug)]
pub struct HbmCache<T> {
    capacity_bytes: usize,
    used_bytes: usize,
    entries: FxHashMap<u64, Entry<T>>,
    /// Insertion order as (seq, user); stale pairs (whose seq no longer
    /// matches the live entry) are tombstones skipped during eviction.
    order: VecDeque<(u64, u64)>,
    next_seq: u64,
    stats: HbmStats,
}

impl<T> HbmCache<T> {
    /// `capacity_bytes` is the r1·HBM slice reserved for live caches (Eq. 2).
    pub fn new(capacity_bytes: usize) -> Self {
        HbmCache {
            capacity_bytes,
            used_bytes: 0,
            entries: FxHashMap::default(),
            order: VecDeque::new(),
            next_seq: 0,
            stats: HbmStats::default(),
        }
    }

    pub fn capacity_bytes(&self) -> usize {
        self.capacity_bytes
    }

    pub fn used_bytes(&self) -> usize {
        self.used_bytes
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn stats(&self) -> HbmStats {
        self.stats
    }

    /// Number of live (Producing|Ready) entries — the paper's L (Eq. 1).
    pub fn live(&self) -> usize {
        self.entries
            .values()
            .filter(|e| matches!(e.state, EntryState::Producing | EntryState::Ready))
            .count()
    }

    fn remove_user(&mut self, user: u64) -> Option<Entry<T>> {
        if let Some(e) = self.entries.remove(&user) {
            self.used_bytes -= e.bytes;
            // The order slot becomes a tombstone (seq mismatch) and is
            // skipped lazily during eviction — O(1) removal.
            Some(e)
        } else {
            None
        }
    }

    /// Is the front order slot a tombstone? Pop it if so.
    fn pop_stale_front(&mut self) -> bool {
        if let Some(&(seq, user)) = self.order.front() {
            let stale = self.entries.get(&user).map(|e| e.seq) != Some(seq);
            if stale {
                self.order.pop_front();
                return true;
            }
        }
        false
    }

    /// Evict until `need` bytes are free.  Order: consumed (oldest first),
    /// then expired, then — only if `allow_lost` — live unexpired entries.
    fn make_room(&mut self, need: usize, now: Micros, allow_lost: bool) -> bool {
        if need > self.capacity_bytes {
            return false;
        }
        // The window slides oldest-first: walk from the front, reclaiming
        // consumed/expired entries (lifecycle order means they cluster at
        // the front); stop at the first live, unexpired entry.
        while self.capacity_bytes - self.used_bytes < need {
            if self.pop_stale_front() {
                continue;
            }
            let Some(&(_, user)) = self.order.front() else { break };
            let e = &self.entries[&user];
            if e.state == EntryState::Consumed {
                self.remove_user(user);
                self.order.pop_front();
                self.stats.evicted_consumed += 1;
            } else if e.deadline_us <= now {
                // Expired — including a Producing entry whose pre-inference
                // overran its lifecycle (complete_produce then reports the
                // lost work).
                self.remove_user(user);
                self.order.pop_front();
                self.stats.evicted_expired += 1;
            } else if allow_lost {
                self.remove_user(user);
                self.order.pop_front();
                self.stats.lost += 1;
            } else {
                break;
            }
        }
        self.capacity_bytes - self.used_bytes >= need
    }

    /// Reserve space for a pre-inference about to run (trigger admitted).
    pub fn begin_produce(
        &mut self,
        user: u64,
        bytes: usize,
        now: Micros,
        t_life_us: Micros,
    ) -> Result<(), InsertError> {
        if bytes > self.capacity_bytes {
            self.stats.rejected += 1;
            return Err(InsertError::TooLarge);
        }
        // Re-admitting the same user replaces the previous entry.
        self.remove_user(user);
        if !self.make_room(bytes, now, false) {
            self.stats.rejected += 1;
            return Err(InsertError::CapacityExhausted);
        }
        let seq = self.next_seq;
        self.next_seq += 1;
        self.entries.insert(
            user,
            Entry {
                bytes,
                state: EntryState::Producing,
                deadline_us: now + t_life_us,
                seq,
                payload: None,
            },
        );
        self.order.push_back((seq, user));
        self.used_bytes += bytes;
        self.stats.inserts += 1;
        Ok(())
    }

    /// Pre-inference finished: attach ψ and mark Ready.
    /// Returns false if the entry was evicted meanwhile (lost).
    pub fn complete_produce(&mut self, user: u64, payload: T) -> bool {
        match self.entries.get_mut(&user) {
            Some(e) if e.state == EntryState::Producing => {
                e.payload = Some(payload);
                e.state = EntryState::Ready;
                true
            }
            _ => false,
        }
    }

    /// Insert an already-materialised ψ (DRAM→HBM reload path).
    pub fn insert_ready(
        &mut self,
        user: u64,
        bytes: usize,
        payload: T,
        now: Micros,
        t_life_us: Micros,
    ) -> Result<(), InsertError> {
        self.begin_produce(user, bytes, now, t_life_us)?;
        let ok = self.complete_produce(user, payload);
        debug_assert!(ok);
        Ok(())
    }

    /// Non-consuming probe (the pseudo-pre-infer check).
    ///
    /// HBM guarantees availability only *within one lifecycle* (§3.3): a
    /// Ready/Consumed entry older than its T_life deadline is treated as
    /// a miss and reclaimed — the sliding window has moved past it.
    /// In-flight `Producing` entries are never expired by the probe.
    pub fn probe(&mut self, user: u64, now: Micros) -> Option<EntryState> {
        if let Some(e) = self.entries.get(&user) {
            if e.state != EntryState::Producing && e.deadline_us <= now {
                self.remove_user(user);
                self.stats.evicted_expired += 1;
                self.stats.misses += 1;
                return None;
            }
        }
        let state = self.entries.get(&user).map(|e| e.state);
        match state {
            Some(EntryState::Ready) => self.stats.ready_hits += 1,
            Some(EntryState::Producing) => self.stats.producing_hits += 1,
            Some(EntryState::Consumed) => self.stats.consumed_hits += 1,
            None => self.stats.misses += 1,
        }
        state
    }

    /// State without touching counters.
    pub fn state_of(&self, user: u64) -> Option<EntryState> {
        self.entries.get(&user).map(|e| e.state)
    }

    /// Re-arm an entry's lifecycle window: an admitted pre-infer signal
    /// that finds ψ already resident keeps it alive for the *new*
    /// request's lifecycle instead of recomputing it (§3.4 pseudo
    /// pre-inference semantics).  Also revives a Consumed entry to Ready.
    pub fn extend_lease(&mut self, user: u64, deadline_us: Micros) -> bool {
        match self.entries.get_mut(&user) {
            Some(e) => {
                e.deadline_us = e.deadline_us.max(deadline_us);
                if e.state == EntryState::Consumed {
                    e.state = EntryState::Ready;
                }
                true
            }
            None => false,
        }
    }

    /// Explicitly evict an entry (the window slides past a consumed ψ
    /// right after the hierarchy demotes it to DRAM).
    pub fn evict(&mut self, user: u64) -> bool {
        let existed = self.remove_user(user).is_some();
        if existed {
            self.stats.evicted_consumed += 1;
        }
        existed
    }
}

impl<T: Clone> HbmCache<T> {
    /// Ranking consumes ψ: marks Consumed (evictable) and returns the
    /// payload.  Consumed entries remain readable until evicted so that
    /// rapid same-user re-ranks within the window still hit.
    pub fn consume(&mut self, user: u64) -> Option<T> {
        match self.entries.get_mut(&user) {
            Some(e) if e.payload.is_some() => {
                e.state = EntryState::Consumed;
                self.stats.consumed += 1;
                e.payload.clone()
            }
            _ => None,
        }
    }

    /// Read a Ready/Consumed payload without state change.  Expired ψ
    /// (past its `deadline_us`) is never readable — the sliding window
    /// has moved past it, exactly as `probe` reports; `peek` merely skips
    /// the reclamation (it takes `&self`).
    pub fn peek(&self, user: u64, now: Micros) -> Option<T> {
        let e = self.entries.get(&user)?;
        if e.state != EntryState::Producing && e.deadline_us <= now {
            return None;
        }
        e.payload.clone()
    }
}

/// The HBM window as a [`CacheTier`]: the level-0 lifecycle tier of a
/// [`CacheHierarchy`](crate::relay::hierarchy::CacheHierarchy).  The
/// richer produce/consume lifecycle stays on the inherent API; the trait
/// view exposes the shared capacity/lookup/insert/evict/stats shape.
impl<T: Clone> CacheTier<T> for HbmCache<T> {
    fn policy(&self) -> EvictPolicy {
        EvictPolicy::Lifecycle
    }

    fn capacity_bytes(&self) -> usize {
        HbmCache::capacity_bytes(self)
    }

    fn used_bytes(&self) -> usize {
        HbmCache::used_bytes(self)
    }

    fn len(&self) -> usize {
        HbmCache::len(self)
    }

    fn contains(&self, user: u64) -> bool {
        self.state_of(user).is_some()
    }

    fn lookup(&mut self, user: u64, now: Micros) -> Option<(usize, T)> {
        match self.probe(user, now) {
            Some(EntryState::Ready) | Some(EntryState::Consumed) => {
                let e = &self.entries[&user];
                e.payload.clone().map(|p| (e.bytes, p))
            }
            _ => None,
        }
    }

    fn insert(
        &mut self,
        user: u64,
        bytes: usize,
        payload: T,
        now: Micros,
        t_life_us: Micros,
    ) -> bool {
        self.insert_ready(user, bytes, payload, now, t_life_us).is_ok()
    }

    fn evict(&mut self, user: u64) -> bool {
        HbmCache::evict(self, user)
    }

    fn tier_stats(&self) -> TierStats {
        let s = self.stats;
        TierStats {
            inserts: s.inserts,
            hits: s.ready_hits + s.consumed_hits + s.producing_hits,
            misses: s.misses,
            evictions: s.evicted_consumed + s.evicted_expired + s.lost,
            rejected: s.rejected,
            promotions: 0,
            demotions_in: 0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const MB: usize = 1 << 20;

    fn cache(cap_mb: usize) -> HbmCache<u32> {
        HbmCache::new(cap_mb * MB)
    }

    #[test]
    fn produce_consume_lifecycle() {
        let mut c = cache(64);
        c.begin_produce(1, 32 * MB, 0, 300_000).unwrap();
        assert_eq!(c.state_of(1), Some(EntryState::Producing));
        assert_eq!(c.consume(1), None, "cannot consume before ready");
        assert!(c.complete_produce(1, 7));
        assert_eq!(c.state_of(1), Some(EntryState::Ready));
        assert_eq!(c.consume(1), Some(7));
        assert_eq!(c.state_of(1), Some(EntryState::Consumed));
        assert_eq!(c.live(), 0);
        assert_eq!(c.stats().consumed, 1);
    }

    #[test]
    fn sliding_window_evicts_consumed_first() {
        let mut c = cache(64);
        for u in 0..2u64 {
            c.begin_produce(u, 32 * MB, 0, 300_000).unwrap();
            c.complete_produce(u, u as u32);
        }
        c.consume(0);
        // Cache full: a third producer must evict the consumed entry 0,
        // not the live entry 1.
        c.begin_produce(2, 32 * MB, 10, 300_000).unwrap();
        assert_eq!(c.state_of(0), None);
        assert_eq!(c.state_of(1), Some(EntryState::Ready));
        assert_eq!(c.stats().evicted_consumed, 1);
        assert_eq!(c.stats().lost, 0);
    }

    #[test]
    fn live_entries_protected_until_expiry() {
        let mut c = cache(64);
        c.begin_produce(1, 32 * MB, 0, 300_000).unwrap();
        c.begin_produce(2, 32 * MB, 0, 300_000).unwrap();
        // Both live & unexpired → insert must be refused, not steal.
        assert_eq!(
            c.begin_produce(3, 32 * MB, 100, 300_000),
            Err(InsertError::CapacityExhausted)
        );
        assert_eq!(c.stats().rejected, 1);
        // After T_life, expired live entries are reclaimable.
        assert!(c.begin_produce(3, 32 * MB, 300_001, 300_000).is_ok());
        assert_eq!(c.stats().evicted_expired, 1);
    }

    #[test]
    fn too_large_rejected() {
        let mut c = cache(16);
        assert_eq!(c.begin_produce(1, 17 * MB, 0, 1), Err(InsertError::TooLarge));
        assert_eq!(c.used_bytes(), 0);
    }

    #[test]
    fn readmission_replaces() {
        let mut c = cache(64);
        c.begin_produce(1, 8 * MB, 0, 300_000).unwrap();
        c.complete_produce(1, 1);
        c.begin_produce(1, 16 * MB, 5, 300_000).unwrap();
        assert_eq!(c.used_bytes(), 16 * MB);
        assert_eq!(c.len(), 1);
        assert_eq!(c.state_of(1), Some(EntryState::Producing));
    }

    #[test]
    fn probe_counts_hits_and_misses() {
        let mut c = cache(64);
        assert_eq!(c.probe(9, 0), None);
        c.begin_produce(9, MB, 0, 1000).unwrap();
        assert_eq!(c.probe(9, 0), Some(EntryState::Producing));
        c.complete_produce(9, 0);
        assert_eq!(c.probe(9, 0), Some(EntryState::Ready));
        let s = c.stats();
        assert_eq!((s.misses, s.producing_hits, s.ready_hits), (1, 1, 1));
    }

    #[test]
    fn probe_splits_ready_and_consumed_hits() {
        let mut c = cache(64);
        c.begin_produce(1, MB, 0, 10_000).unwrap();
        c.complete_produce(1, 5);
        assert_eq!(c.probe(1, 0), Some(EntryState::Ready));
        c.consume(1);
        // Rapid re-ranks probe the already-consumed entry.
        assert_eq!(c.probe(1, 10), Some(EntryState::Consumed));
        assert_eq!(c.probe(1, 20), Some(EntryState::Consumed));
        let s = c.stats();
        assert_eq!((s.ready_hits, s.consumed_hits), (1, 2));
    }

    #[test]
    fn peek_respects_lifecycle_deadline() {
        let mut c = cache(64);
        c.begin_produce(1, MB, 0, 1_000).unwrap();
        assert_eq!(c.peek(1, 0), None, "producing entries have no payload");
        c.complete_produce(1, 9);
        assert_eq!(c.peek(1, 500), Some(9));
        // Past the deadline the window has moved on: expired ψ must never
        // be readable, exactly as probe reports.
        assert_eq!(c.peek(1, 1_000), None);
        assert_eq!(c.probe(1, 1_000), None);
        // Consumed entries expire the same way.
        let mut d = cache(64);
        d.begin_produce(2, MB, 0, 1_000).unwrap();
        d.complete_produce(2, 7);
        d.consume(2);
        assert_eq!(d.peek(2, 500), Some(7));
        assert_eq!(d.peek(2, 2_000), None);
    }

    #[test]
    fn extend_lease_rearms_and_revives() {
        let mut c = cache(64);
        c.begin_produce(1, MB, 0, 100).unwrap();
        c.complete_produce(1, 5);
        c.consume(1);
        // Re-arm past expiry and revive Consumed → Ready.
        assert!(c.extend_lease(1, 10_000));
        assert_eq!(c.probe(1, 5_000), Some(EntryState::Ready));
        assert_eq!(c.consume(1), Some(5));
        // Expired without a lease extension would have been reclaimed.
        let mut d = cache(64);
        d.begin_produce(2, MB, 0, 100).unwrap();
        d.complete_produce(2, 9);
        assert_eq!(d.probe(2, 5_000), None, "expired entries are misses");
        assert!(!d.extend_lease(2, 10_000), "gone after reclamation");
    }

    #[test]
    fn complete_after_eviction_reports_lost_handle() {
        let mut c = cache(32);
        c.begin_produce(1, 32 * MB, 0, 100).unwrap();
        // Entry 1 expires; a new producer reclaims the space.
        c.begin_produce(2, 32 * MB, 200, 100).unwrap();
        assert!(!c.complete_produce(1, 9), "completing an evicted entry fails");
    }

    #[test]
    fn byte_accounting_is_exact() {
        let mut c = cache(100);
        c.begin_produce(1, 10 * MB, 0, 1000).unwrap();
        c.begin_produce(2, 20 * MB, 0, 1000).unwrap();
        assert_eq!(c.used_bytes(), 30 * MB);
        c.complete_produce(1, 0);
        c.consume(1);
        c.begin_produce(3, 80 * MB, 1, 1000).unwrap(); // evicts 1
        assert_eq!(c.used_bytes(), 100 * MB);
        assert_eq!(c.live(), 2);
    }

    // Property: under arbitrary operation sequences the capacity bound and
    // live-count accounting always hold.
    #[test]
    fn prop_capacity_invariant() {
        crate::util::prop::check("hbm-capacity-invariant", 200, |rng| {
            let cap = (1 + rng.range(0, 64)) * MB;
            let mut c: HbmCache<u32> = HbmCache::new(cap);
            let mut now: Micros = 0;
            for _ in 0..200 {
                now += rng.range(0, 50_000) as u64;
                let user = rng.range_u64(8);
                match rng.range(0, 4) {
                    0 => {
                        let bytes = (1 + rng.range(0, 40)) * MB / 2;
                        let _ = c.begin_produce(user, bytes, now, 300_000);
                    }
                    1 => {
                        c.complete_produce(user, 1);
                    }
                    2 => {
                        c.consume(user);
                    }
                    _ => {
                        c.probe(user, 0);
                    }
                }
                if c.used_bytes() > cap {
                    return Err(format!("used {} > cap {}", c.used_bytes(), cap));
                }
                let live = c.live();
                if live > c.len() {
                    return Err("live > len".into());
                }
            }
            Ok(())
        });
    }
}
