//! Affinity-aware router (§3.3): convert late-binding placement into an
//! early-binding contract via consistent hashing on a user-keyed header.
//!
//! Both the auxiliary pre-infer signal and the later ranking request for
//! the same user carry `consistency-hash-key: userID`; the load balancer
//! picks the gateway and the gateway picks the final instance by
//! consistent hashing on that key, so producer and consumer rendezvous at
//! the same *special* instance without coordination.  Normal (short-
//! sequence) requests use standard policies (round-robin /
//! least-connections).  Special-instance density per server is capped to
//! bound CPU/PCIe interference (Fig. 8).

use std::collections::{BTreeSet, HashSet};

use anyhow::{bail, Result};

/// 64-bit hash of the consistency-hash-key (userID) — splitmix64 finaliser.
#[inline]
pub fn hash_key(key: u64, salt: u64) -> u64 {
    let mut z = key ^ salt.wrapping_mul(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Consistent-hash ring with virtual nodes.
#[derive(Debug, Clone)]
pub struct HashRing {
    /// Sorted (point, node) pairs.
    points: Vec<(u64, usize)>,
    vnodes: usize,
}

impl HashRing {
    pub fn new(nodes: &[usize], vnodes: usize) -> HashRing {
        let mut ring = HashRing { points: Vec::new(), vnodes };
        for &n in nodes {
            ring.add(n);
        }
        ring
    }

    pub fn add(&mut self, node: usize) {
        for v in 0..self.vnodes {
            let point = hash_key(node as u64, 0xA5A5_0000 ^ v as u64);
            self.points.push((point, node));
        }
        self.points.sort_unstable();
    }

    pub fn remove(&mut self, node: usize) {
        self.points.retain(|&(_, n)| n != node);
    }

    pub fn is_empty(&self) -> bool {
        self.points.is_empty()
    }

    pub fn nodes(&self) -> Vec<usize> {
        let mut v: Vec<usize> = self.points.iter().map(|&(_, n)| n).collect();
        v.sort_unstable();
        v.dedup();
        v
    }

    /// Route a key to its node (first ring point clockwise of the hash).
    pub fn route(&self, key: u64) -> Option<usize> {
        if self.points.is_empty() {
            return None;
        }
        let h = hash_key(key, 0);
        let idx = self.points.partition_point(|&(p, _)| p < h);
        let (_, node) = self.points[idx % self.points.len()];
        Some(node)
    }
}

/// Policy for uncoupled (normal) requests.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BalancePolicy {
    RoundRobin,
    LeastConnections,
}

/// Router deployment shape.
#[derive(Debug, Clone)]
pub struct RouterConfig {
    pub n_instances: usize,
    pub servers: usize,
    /// r2 — fraction of instances designated special.
    pub r2: f64,
    /// Interference cap: max special instances per server (Fig. 8).
    pub max_special_per_server: usize,
    pub gateways: usize,
    pub vnodes: usize,
    pub normal_policy: BalancePolicy,
}

impl Default for RouterConfig {
    fn default() -> Self {
        RouterConfig {
            n_instances: 100,
            servers: 25,
            r2: 0.1,
            max_special_per_server: 1,
            gateways: 4,
            vnodes: 64,
            normal_policy: BalancePolicy::LeastConnections,
        }
    }
}

/// A routed destination: which gateway carried it and the final instance.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Route {
    pub gateway: usize,
    pub instance: usize,
}

/// Counters exported to metrics.
#[derive(Debug, Clone, Copy, Default)]
pub struct RouterStats {
    pub special_routed: u64,
    pub normal_routed: u64,
    pub affinity_breaks: u64,
}

/// The affinity-aware router over a special/normal instance split.
#[derive(Debug)]
pub struct Router {
    cfg: RouterConfig,
    /// instance id → server id.
    placement: Vec<usize>,
    special: Vec<usize>,
    normal: Vec<usize>,
    /// instance id → member of the normal pool (tracks the `lc_index`).
    is_normal: Vec<bool>,
    gw_ring: HashRing,
    special_ring: HashRing,
    /// Open connections per instance (least-connections policy).
    conns: Vec<u32>,
    /// Ordered least-connections index over the normal pool:
    /// `first()` is `(min conns, smallest instance id)` — exactly the
    /// instance the old O(n) `min_by_key` scan picked (the normal list
    /// is ascending, so first-minimum = smallest id), at O(log n).
    lc_index: BTreeSet<(u32, usize)>,
    rr_next: usize,
    stats: RouterStats,
}

impl Router {
    /// Place instances round-robin across servers, then designate ⌈r2·N⌉
    /// special instances subject to the per-server density cap.
    pub fn new(cfg: RouterConfig) -> Result<Router> {
        if cfg.n_instances == 0 || cfg.servers == 0 || cfg.gateways == 0 {
            bail!("router: instances/servers/gateways must be positive");
        }
        let want_special = ((cfg.r2 * cfg.n_instances as f64).ceil() as usize)
            .clamp(1, cfg.n_instances);
        if want_special > cfg.servers * cfg.max_special_per_server {
            bail!(
                "router: r2*N = {want_special} special instances cannot respect \
                 density cap {} on {} servers",
                cfg.max_special_per_server,
                cfg.servers
            );
        }
        let placement: Vec<usize> = (0..cfg.n_instances).map(|i| i % cfg.servers).collect();
        let mut special = Vec::new();
        let mut per_server = vec![0usize; cfg.servers];
        // Spread specials across servers: walk instances, take the first on
        // each server until the quota is met.
        for i in 0..cfg.n_instances {
            if special.len() == want_special {
                break;
            }
            let s = placement[i];
            if per_server[s] < cfg.max_special_per_server {
                per_server[s] += 1;
                special.push(i);
            }
        }
        if special.len() < want_special {
            bail!("router: could not place {want_special} special instances");
        }
        // Indexed membership: the old `special.contains` filter scanned
        // the special list once per instance (O(N²) at fleet sizes).
        let special_set: HashSet<usize> = special.iter().copied().collect();
        let normal: Vec<usize> =
            (0..cfg.n_instances).filter(|i| !special_set.contains(i)).collect();
        let mut is_normal = vec![false; cfg.n_instances];
        for &i in &normal {
            is_normal[i] = true;
        }
        let lc_index: BTreeSet<(u32, usize)> = normal.iter().map(|&i| (0, i)).collect();
        let gw_ring = HashRing::new(&(0..cfg.gateways).collect::<Vec<_>>(), cfg.vnodes);
        let special_ring = HashRing::new(&special, cfg.vnodes);
        Ok(Router {
            conns: vec![0; cfg.n_instances],
            placement,
            special,
            normal,
            is_normal,
            lc_index,
            gw_ring,
            special_ring,
            rr_next: 0,
            stats: RouterStats::default(),
            cfg,
        })
    }

    pub fn config(&self) -> &RouterConfig {
        &self.cfg
    }

    pub fn special_instances(&self) -> &[usize] {
        &self.special
    }

    pub fn normal_instances(&self) -> &[usize] {
        &self.normal
    }

    pub fn server_of(&self, instance: usize) -> usize {
        self.placement[instance]
    }

    pub fn stats(&self) -> RouterStats {
        self.stats
    }

    /// Read-only peek at the special instance `user`'s affinity keys
    /// map to — no stats, no connection bookkeeping.  Used when state
    /// is *placed* for a user (drain migration) rather than routed.
    pub fn peek_special(&self, user: u64) -> Option<usize> {
        self.special_ring.route(user)
    }

    /// Route a user-keyed request (pre-infer signal *or* long-sequence
    /// ranking request): consistent hashing at both hops, so coupled
    /// requests rendezvous deterministically.
    pub fn route_special(&mut self, user: u64) -> Route {
        self.stats.special_routed += 1;
        let gateway = self.gw_ring.route(user).expect("no gateways");
        let instance = self.special_ring.route(user).expect("no special instances");
        self.bump_conns(instance, 1);
        Route { gateway, instance }
    }

    /// Route an un-keyed normal request with the configured policy.
    pub fn route_normal(&mut self, user: u64) -> Route {
        self.stats.normal_routed += 1;
        let gateway = self.gw_ring.route(user).expect("no gateways");
        let instance = match self.cfg.normal_policy {
            BalancePolicy::RoundRobin => {
                let i = self.normal[self.rr_next % self.normal.len()];
                self.rr_next += 1;
                i
            }
            // O(log n) via the ordered index (decision bit-identical to
            // the old first-minimum scan of the ascending normal list).
            BalancePolicy::LeastConnections => {
                self.lc_index.first().expect("no normal instances").1
            }
        };
        self.bump_conns(instance, 1);
        Route { gateway, instance }
    }

    /// Adjust an instance's open-connection count, keeping the
    /// least-connections index in sync for normal-pool members.
    fn bump_conns(&mut self, instance: usize, delta: i32) {
        let before = self.conns[instance];
        let after = if delta >= 0 {
            before + delta as u32
        } else {
            before.saturating_sub((-delta) as u32)
        };
        if before == after {
            return;
        }
        self.conns[instance] = after;
        if self.is_normal[instance] {
            self.lc_index.remove(&(before, instance));
            self.lc_index.insert((after, instance));
        }
    }

    /// A request finished: release its connection slot.
    pub fn on_complete(&mut self, instance: usize) {
        self.bump_conns(instance, -1);
    }

    /// Deployment churn: a special instance leaves the affinity ring and
    /// keys remap (ranking requests routed before the change miss the
    /// cache and fall back — correctness preserved, optimization lost).
    /// The demoted instance *returns to the normal pool* — the symmetric
    /// inverse of [`Router::add_special`]: its NPU keeps serving, just
    /// under standard balancing instead of affinity traffic.  Returns
    /// `false` (no-op) when the instance was not special.
    pub fn remove_special(&mut self, instance: usize) -> bool {
        if !self.special.contains(&instance) {
            return false;
        }
        self.special_ring.remove(instance);
        self.special.retain(|&i| i != instance);
        // The instance did not die — it was demoted.  Its open affinity
        // connections are still genuinely in flight, so the count is
        // carried into the normal pool and drains through the ordinary
        // `on_complete` path (resetting it here would let those late
        // completions decrement *new* normal connections and make the
        // least-connections index flood a busy instance).
        debug_assert!(!self.is_normal[instance], "special was never in the normal pool");
        self.is_normal[instance] = true;
        // Keep `normal` ascending: round-robin order and the
        // least-connections first-minimum tie-break both rely on it.
        let pos = self.normal.partition_point(|&i| i < instance);
        self.normal.insert(pos, instance);
        self.lc_index.insert((self.conns[instance], instance));
        self.stats.affinity_breaks += 1;
        true
    }

    /// Promote an instance into the special pool (deployment churn /
    /// capacity scale-out).  The promotion *removes it from the normal
    /// pool* — an instance must never take least-connections traffic and
    /// affinity traffic at once — and respects the per-server density
    /// cap (Fig. 8 interference bound), exactly as initial placement
    /// does.  Returns whether the instance is special when the call
    /// returns (`false` ⇔ the density cap refused it).
    pub fn add_special(&mut self, instance: usize) -> bool {
        if self.special.contains(&instance) {
            return true; // idempotent
        }
        let server = self.placement[instance];
        let density = self.special.iter().filter(|&&i| self.placement[i] == server).count();
        if density >= self.cfg.max_special_per_server {
            return false;
        }
        if self.is_normal[instance] {
            self.is_normal[instance] = false;
            self.normal.retain(|&i| i != instance);
            self.lc_index.remove(&(self.conns[instance], instance));
        }
        self.special.push(instance);
        self.special_ring.add(instance);
        true
    }

    pub fn open_connections(&self, instance: usize) -> u32 {
        self.conns[instance]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashMap;

    fn router() -> Router {
        Router::new(RouterConfig::default()).unwrap()
    }

    #[test]
    fn coupled_requests_rendezvous() {
        let mut r = router();
        for user in 0..500u64 {
            let pre = r.route_special(user);
            let rank = r.route_special(user);
            assert_eq!(pre.instance, rank.instance, "user {user} split across instances");
            assert_eq!(pre.gateway, rank.gateway);
        }
    }

    #[test]
    fn routing_is_deterministic_across_router_instances() {
        let mut a = router();
        let mut b = router();
        for user in 0..100u64 {
            assert_eq!(a.route_special(user).instance, b.route_special(user).instance);
        }
    }

    #[test]
    fn special_pool_size_and_density_cap() {
        let r = router();
        assert_eq!(r.special_instances().len(), 10); // r2=0.1, N=100
        let mut per_server: HashMap<usize, usize> = HashMap::new();
        for &i in r.special_instances() {
            *per_server.entry(r.server_of(i)).or_default() += 1;
        }
        assert!(per_server.values().all(|&c| c <= 1), "density cap violated");
    }

    #[test]
    fn density_cap_infeasible_is_rejected() {
        let cfg = RouterConfig {
            n_instances: 100,
            servers: 4,
            r2: 0.1,
            max_special_per_server: 1,
            ..Default::default()
        };
        assert!(Router::new(cfg).is_err());
    }

    #[test]
    fn special_load_is_balanced() {
        let mut r = router();
        let mut counts: HashMap<usize, u64> = HashMap::new();
        for user in 0..20_000u64 {
            *counts.entry(r.route_special(user).instance).or_default() += 1;
        }
        let expect = 20_000.0 / r.special_instances().len() as f64;
        for (&inst, &c) in &counts {
            assert!(
                (c as f64) > expect * 0.5 && (c as f64) < expect * 1.6,
                "instance {inst} got {c} (expect ~{expect:.0})"
            );
        }
    }

    #[test]
    fn least_connections_prefers_idle() {
        let mut r = Router::new(RouterConfig {
            normal_policy: BalancePolicy::LeastConnections,
            ..Default::default()
        })
        .unwrap();
        let a = r.route_normal(1).instance;
        let b = r.route_normal(2).instance;
        assert_ne!(a, b, "second request should avoid the busy instance");
        r.on_complete(a);
        r.on_complete(b);
    }

    #[test]
    fn round_robin_cycles() {
        let mut r = Router::new(RouterConfig {
            normal_policy: BalancePolicy::RoundRobin,
            ..Default::default()
        })
        .unwrap();
        let n = r.normal_instances().len();
        let first = r.route_normal(0).instance;
        for _ in 1..n {
            r.route_normal(0);
        }
        assert_eq!(r.route_normal(0).instance, first, "wraps after a full cycle");
    }

    #[test]
    fn churn_remaps_bounded_fraction() {
        let mut r = router();
        let users: Vec<u64> = (0..5_000).collect();
        let before: Vec<usize> = users.iter().map(|&u| r.route_special(u).instance).collect();
        let victim = r.special_instances()[0];
        r.remove_special(victim);
        let after: Vec<usize> = users.iter().map(|&u| r.route_special(u).instance).collect();
        let moved = before
            .iter()
            .zip(&after)
            .filter(|(b, a)| b != a)
            .count();
        // Consistent hashing: only the victim's ~1/10 of keys remap.
        let frac = moved as f64 / users.len() as f64;
        assert!(frac < 0.2, "churn moved {:.0}% of keys", frac * 100.0);
        // Keys that moved must all have pointed at the removed instance.
        for ((&u, &b), &a) in users.iter().zip(&before).zip(&after) {
            if b != a {
                assert_eq!(b, victim, "user {u} moved from non-victim {b}");
            }
        }
        assert_eq!(r.stats().affinity_breaks, 1);
    }

    #[test]
    fn demoted_special_carries_open_connections_into_normal_pool() {
        let mut r = router();
        let victim = r.special_instances()[0];
        // Pump open connections onto the victim via affinity routing.
        let mut routed = 0;
        for user in 0..5_000u64 {
            if r.route_special(user).instance == victim {
                routed += 1;
            }
        }
        assert!(routed > 0 && r.open_connections(victim) == routed);
        // Demotion: the instance did not die — its in-flight affinity
        // connections stay on the ledger…
        assert!(r.remove_special(victim));
        assert_eq!(r.open_connections(victim), routed);
        // …so the busy demoted instance is not the least-connections
        // pick while every other normal instance is idle…
        let pick = r.route_normal(1).instance;
        assert_ne!(pick, victim, "busy demoted instance must not be the LC minimum");
        r.on_complete(pick);
        // …and the late completions from its special incarnation drain
        // the ledger exactly (no saturation, no skew).
        for _ in 0..routed {
            r.on_complete(victim);
        }
        assert_eq!(r.open_connections(victim), 0);
        // Re-promotion takes it back out of the normal pool cleanly.
        assert!(r.add_special(victim));
        assert_eq!(r.open_connections(victim), 0);
    }

    /// Satellite regression (fails on the pre-fix router): promoting an
    /// instance must pull it out of the normal pool — on the old code
    /// the idle promoted instance stayed the least-connections minimum
    /// and kept receiving normal traffic on top of affinity traffic.
    #[test]
    fn promoted_instance_stops_receiving_normal_traffic() {
        let mut r = router(); // 100 instances / 25 servers, specials 0..9
        let victim = r.normal_instances()[0]; // smallest id ⇒ next LC pick
        assert!(r.add_special(victim), "server has headroom under the cap");
        assert!(r.special_instances().contains(&victim));
        assert!(!r.normal_instances().contains(&victim));
        for user in 0..200u64 {
            let i = r.route_normal(user).instance;
            assert_ne!(i, victim, "promoted instance drew normal traffic");
        }
        // Promotion is idempotent...
        assert!(r.add_special(victim));
        // ...and respects the density cap: the victim's server is taken.
        let server = r.server_of(victim);
        let blocked = r
            .normal_instances()
            .iter()
            .copied()
            .find(|&i| r.server_of(i) == server)
            .expect("another instance on the same server");
        assert!(!r.add_special(blocked), "density cap must bind on promotion");
        assert!(r.normal_instances().contains(&blocked), "refused promotion leaves pools intact");
    }

    /// Promote/demote cycles keep the pools disjoint and consistent, and
    /// a demoted special resumes normal service with clean connections.
    #[test]
    fn promote_demote_cycle_keeps_pools_consistent() {
        let mut r = router();
        let n = r.config().n_instances;
        for round in 0..5 {
            let candidate = r.normal_instances()[round * 7 % r.normal_instances().len()];
            if !r.add_special(candidate) {
                continue; // density cap — legitimate refusal
            }
            // Load the promoted instance with affinity traffic.
            for user in 0..500u64 {
                let route = r.route_special(user);
                r.on_complete(route.instance);
            }
            for user in 0..50u64 {
                assert_ne!(r.route_normal(user).instance, candidate);
            }
            assert!(r.remove_special(candidate));
            assert!(r.normal_instances().contains(&candidate));
            assert_eq!(r.open_connections(candidate), 0, "no residual connections");
            assert!(!r.remove_special(candidate), "demoting a non-special is a no-op");
            // Invariants: disjoint pools covering consistent membership.
            let specials: std::collections::HashSet<usize> =
                r.special_instances().iter().copied().collect();
            for &i in r.normal_instances() {
                assert!(!specials.contains(&i), "round {round}: instance {i} in both pools");
            }
            assert_eq!(
                specials.len() + r.normal_instances().len(),
                n,
                "round {round}: pool membership leaked"
            );
            // Drain the open normal connections for the next round.
            for &i in r.normal_instances().to_vec().iter() {
                while r.open_connections(i) > 0 {
                    r.on_complete(i);
                }
            }
        }
    }

    /// The O(log n) least-connections index must agree with the naive
    /// first-minimum scan on every routing decision, under random
    /// route/complete interleavings *and promote/demote churn* — the
    /// index is a pure perf change, and churn must keep it in sync with
    /// the normal pool.
    #[test]
    fn prop_lc_index_matches_min_scan_reference() {
        crate::util::prop::check("router-lc-index-vs-scan", 80, |rng| {
            let cfg = RouterConfig {
                n_instances: 10 + rng.range(0, 60),
                servers: 10 + rng.range(0, 10),
                r2: rng.uniform(0.05, 0.3),
                max_special_per_server: 1 + rng.range(0, 2),
                gateways: 1 + rng.range(0, 4),
                vnodes: 16,
                normal_policy: BalancePolicy::LeastConnections,
            };
            let Ok(mut r) = Router::new(cfg) else {
                return Ok(()); // infeasible density caps may error
            };
            let mut model: Vec<u32> = vec![0; r.config().n_instances];
            let mut open: Vec<usize> = Vec::new();
            for step in 0..400 {
                match rng.range(0, 20) {
                    // Promote a random normal instance (may be refused by
                    // the density cap — pools must be untouched then).
                    0 if r.normal_instances().len() > 1 => {
                        let idx = rng.range(0, r.normal_instances().len());
                        let inst = r.normal_instances()[idx];
                        let promoted = r.add_special(inst);
                        if promoted {
                            // Its open normal connections keep draining via
                            // on_complete; the model just stops offering it.
                            if r.normal_instances().contains(&inst) {
                                return Err(format!("step {step}: {inst} in both pools"));
                            }
                        } else if !r.normal_instances().contains(&inst) {
                            return Err(format!("step {step}: refused promo removed {inst}"));
                        }
                    }
                    // Demote a random special: its open connections are
                    // carried into the normal pool (the model already
                    // tracks them) and keep draining via on_complete.
                    1 if r.special_instances().len() > 1 => {
                        let idx = rng.range(0, r.special_instances().len());
                        let inst = r.special_instances()[idx];
                        r.remove_special(inst);
                    }
                    _ if rng.bernoulli(0.65) || open.is_empty() => {
                        let user = rng.next_u64() % 500;
                        // Reference decision: first normal instance with the
                        // minimum open-connection count (ascending ids).
                        let want = *r
                            .normal_instances()
                            .iter()
                            .min_by_key(|&&i| model[i])
                            .expect("normal pool non-empty");
                        let got = r.route_normal(user).instance;
                        if got != want {
                            return Err(format!("step {step}: routed {got}, scan says {want}"));
                        }
                        model[got] += 1;
                        open.push(got);
                    }
                    _ => {
                        let i = open.swap_remove(rng.range(0, open.len()));
                        r.on_complete(i);
                        model[i] -= 1;
                    }
                }
                for (i, &m) in model.iter().enumerate() {
                    if r.open_connections(i) != m {
                        return Err(format!("step {step}: conns drift on {i}"));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn prop_affinity_holds_under_random_traffic() {
        crate::util::prop::check("router-affinity", 50, |rng| {
            let cfg = RouterConfig {
                n_instances: 10 + rng.range(0, 90),
                servers: 10 + rng.range(0, 20),
                r2: rng.uniform(0.05, 0.3),
                max_special_per_server: 1 + rng.range(0, 2),
                gateways: 1 + rng.range(0, 8),
                vnodes: 16 + rng.range(0, 64),
                normal_policy: BalancePolicy::RoundRobin,
            };
            let Ok(mut r) = Router::new(cfg) else {
                return Ok(()); // infeasible density caps are allowed to error
            };
            for _ in 0..200 {
                let u = rng.next_u64() % 1000;
                let first = r.route_special(u);
                let again = r.route_special(u);
                if first.instance != again.instance {
                    return Err(format!("user {u} lost affinity"));
                }
                if !r.special_instances().contains(&first.instance) {
                    return Err("routed to non-special instance".into());
                }
            }
            Ok(())
        });
    }
}
