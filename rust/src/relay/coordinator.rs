//! The shared relay-race coordinator: one clock-agnostic state machine
//! owning the full per-request decision flow of §3 — admission
//! ([`Trigger`]), placement ([`Router`]), ψ lookup/production across the
//! tiered [`CacheHierarchy`], wait-budget fallback, and
//! [`CacheOutcome`] classification — driven through a small event-style
//! API by *both* execution engines:
//!
//! * the discrete-event simulator (`cluster::sim`) advances a virtual
//!   clock and models compute/transfer durations with the cost model,
//! * the live threaded engine (`serve::engine`) uses wall-clock time and
//!   real PJRT executions.
//!
//! Neither engine makes a caching/placement/admission decision itself:
//! they translate coordinator *actions* into time (simulated or real) and
//! report completions back.  The event API:
//!
//! | event                | meaning                                        |
//! |----------------------|------------------------------------------------|
//! | [`on_arrival`]       | request entered the pipeline → [`ReqId`] handle|
//! | [`on_trigger_check`] | the trigger side path runs (admission + signal)|
//! | [`on_stage_done`]    | a cascade stage finished (routes at preproc)   |
//! | [`on_rank_start`]    | ranking request reached its instance           |
//! | [`on_psi_ready`]     | ψ production finished (or failed)              |
//! | [`on_reload_done`]   | a DRAM→HBM transfer finished (or failed)       |
//! | [`rank_compute`]     | ranking starts: consume ψ + plan segments      |
//! | [`on_rank_done`]     | ranking finished: release + spill lifecycle    |
//!
//! ## Zero-allocation hot path
//!
//! [`on_arrival`] returns a generational [`ReqId`] handle that every
//! later event takes back; per-request state lives in a [`Slab`] — dense
//! O(1) index access, no hashing — whose slots recycle their owned
//! buffers (candidate sets, segment pins), so the steady-state
//! per-request cycle allocates nothing.  A handle outlives its request
//! safely: releasing bumps the slot generation, so a late event for a
//! retired request (delayed ψ completion after a wait-budget fallback)
//! misses instead of aliasing the slot's next tenant.
//!
//! [`on_arrival`]: RelayCoordinator::on_arrival
//! [`on_trigger_check`]: RelayCoordinator::on_trigger_check
//! [`on_stage_done`]: RelayCoordinator::on_stage_done
//! [`on_rank_start`]: RelayCoordinator::on_rank_start
//! [`on_psi_ready`]: RelayCoordinator::on_psi_ready
//! [`on_reload_done`]: RelayCoordinator::on_reload_done
//! [`rank_compute`]: RelayCoordinator::rank_compute
//! [`on_rank_done`]: RelayCoordinator::on_rank_done

use std::collections::HashMap;

use anyhow::Result;

use crate::relay::baseline::Mode;
use crate::relay::fault::{FaultConfig, FaultKind, FaultOutcome, FaultPlan, FaultReport};
use crate::relay::flight::{
    psi_action, rank_action, trigger_reason, FlightRecorder, SpanKind, NONE_OPERAND,
};
use crate::relay::hbm::{EntryState, HbmStats};
use crate::relay::hierarchy::{CacheHierarchy, HierarchyStats, PseudoAction, ReloadDone};
use crate::relay::pipeline::CacheOutcome;
use crate::relay::router::{Router, RouterConfig};
use crate::relay::segment::{
    SegmentAction, SegmentConfig, SegmentKey, SegmentPlan, SegmentStats, SegmentStore,
};
use crate::relay::tier::TierConfig;
use crate::relay::trigger::{
    BehaviorMeta, Decision, Estimator, Trigger, TriggerConfig, TriggerStats,
};
use crate::util::sharded::ShardedMap;
use crate::util::slab::Slab;

/// Per-request handle issued by [`RelayCoordinator::on_arrival`] and
/// consumed by every later event; see [`crate::util::slab`].
pub type ReqId = crate::util::slab::SlabKey;

/// ψ footprint (bytes) as a function of prefix length.  Boxed so the
/// simulator wires in the analytic model (`kv_bytes_for`) and the live
/// engine the compiled artifact's fixed footprint.
pub type KvSizer = Box<dyn Fn(usize) -> usize + Send>;

/// Static coordinator parameters shared by both engines.
pub struct CoordinatorConfig {
    pub mode: Mode,
    pub router: RouterConfig,
    pub trigger: TriggerConfig,
    /// Cache levels below the HBM window, top-down (empty = plain
    /// RelayGR; one LRU entry = the paper's DRAM expander).
    pub tiers: Vec<TierConfig>,
    /// Requests with prefix above this use the special (relay) service.
    pub long_threshold: usize,
    /// Lifecycle window T_life for cache survivability.
    pub t_life_us: u64,
    pub max_reload_concurrency: usize,
    /// Per-instance HBM slice reserved for live ψ caches (r1·HBM).
    pub hbm_bytes: usize,
    /// Feature dimension reported in [`BehaviorMeta`].
    pub dim: usize,
    pub kv_bytes: KvSizer,
    /// Candidate-segment reuse (beyond-prefix): `frac > 0` carves a
    /// segment-cache partition out of the `hbm_bytes` slice, so prefix ψ
    /// caches and segment caches contend explicitly.  `frac = 0` keeps
    /// behaviour decision-for-decision identical to the ψ-only system.
    pub segment: SegmentConfig,
    /// Microbatch window (µs): rank passes reaching the same instance
    /// within this window group into one batched execution
    /// (`--batch-window`).  `0` disables the batch former entirely —
    /// [`RelayCoordinator::offer_rank`] answers `Solo` without touching
    /// batch state, so the unbatched event flow is bit-identical.
    pub batch_window_us: u64,
    /// Maximum members per batch (`--batch-max`); reaching it closes the
    /// batch immediately (`Filled`) without waiting out the window.
    pub batch_max: usize,
    /// Flight-recorder retention bound (`--trace-spans`): total lifecycle
    /// spans kept across the pooled per-shard rings.  `0` disables
    /// tracing entirely — no recorder is constructed and every emission
    /// hook is skipped.  The recorder is observe-only by contract (see
    /// [`crate::relay::flight`]): no decision path may read it, so the
    /// decision flow is bit-identical with tracing on or off.
    pub trace_spans: usize,
    /// The fault plane (`--faults <spec>`): a seeded [`FaultPlan`] is
    /// compiled at construction and consulted at the named decision
    /// points.  Every draw keys only on stable trace-assigned ids
    /// (request rid / user id) — never clocks or engine-order counters —
    /// so injection is decision-synchronous and all engines inject the
    /// same faults at the same requests.  The all-off default makes the
    /// plane a structural no-op: zero draws, zero folded retry budget.
    pub faults: FaultConfig,
}

/// Cascade stages the coordinator is told about.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Stage {
    Retrieval,
    Preproc,
}

/// What the admitted pre-infer signal must do next (the host performs the
/// compute/transfer and reports back).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SignalAction {
    /// No side path: not admitted, or ψ already resident / in flight.
    None,
    /// Compute ψ (behaviour fetch + feature proc + H2D + prefix pass) on
    /// `instance`, then call [`RelayCoordinator::on_psi_ready`].
    Produce { instance: usize, user: u64, prefix_len: usize },
    /// Perform one DRAM→HBM reload of `bytes` for `user` on `instance`,
    /// then call [`RelayCoordinator::on_reload_done`].
    Reload { instance: usize, user: u64, bytes: usize },
}

/// What the ranking request must do when it reaches its instance.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RankAction {
    /// Run ranking now; `cached` selects incremental vs full compute.
    Proceed { cached: bool, outcome: CacheOutcome },
    /// ψ is being produced: wait; resolved by
    /// [`RelayCoordinator::on_psi_ready`] (or a wait-budget timeout).
    Wait,
    /// This request starts the DRAM→HBM reload (performs the transfer,
    /// then calls [`RelayCoordinator::on_reload_done`], which resolves it
    /// and any joiners).
    StartReload { bytes: usize },
    /// Joined an in-flight or queued reload; resolved by
    /// [`RelayCoordinator::on_reload_done`].
    WaitReload,
}

/// Resolution of a finished DRAM→HBM reload.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ReloadResolution {
    /// Whether ψ was installed into HBM (false ⇒ waiters fell back).
    pub installed: bool,
    /// Ranking requests resolved by this reload (resume their processing).
    pub woken: Vec<ReqId>,
    /// Next queued reload now permitted to start
    /// (drive it via [`RelayCoordinator::begin_queued_reload`]).
    pub next: Option<u64>,
}

/// Outcome of granting a queued reload its turn.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum QueuedReload {
    /// The payload is still in DRAM: perform the transfer, then call
    /// [`RelayCoordinator::on_reload_done`].
    Start { bytes: usize },
    /// Evicted from DRAM while queued: aborted; `woken` requests fell
    /// back, `next` queued reload may start.
    Aborted { woken: Vec<ReqId>, next: Option<u64> },
}

/// ψ handed to the ranking execution.
pub struct RankCompute<T> {
    /// Whether ranking runs on the cached prefix (incremental tokens
    /// only) or must process the whole sequence.
    pub cached: bool,
    /// The consumed payload when cached (device buffer in the live
    /// engine, `()` in the simulator).
    pub payload: Option<T>,
    /// Candidate-segment plan for this rank pass (None when segment
    /// reuse is disabled or the request carried no candidate set).
    pub segments: Option<SegmentPlan>,
}

/// Everything the host needs to close out a finished request.
#[derive(Debug, Clone, PartialEq)]
pub struct Completion {
    pub user: u64,
    pub prefix_len: usize,
    pub is_long: bool,
    pub instance: usize,
    pub admitted: bool,
    pub cached: bool,
    pub outcome: CacheOutcome,
    /// Accumulated ranking-path wait for ψ production / reload (µs).
    pub wait_us: f64,
    /// `Some(bytes)`: freshly produced ψ is eligible for a DRAM spill —
    /// materialise a host copy and call
    /// [`RelayCoordinator::complete_spill`] (off the critical path).
    pub spill: Option<usize>,
}

/// What the batch former decided for one rank pass offered to it (see
/// [`RelayCoordinator::offer_rank`]).  All variants are `Copy`; the
/// member list stays pooled inside the coordinator until
/// [`RelayCoordinator::close_batch`] drains it.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BatchDecision {
    /// Batching is off (window 0): execute this pass alone, exactly as
    /// the unbatched engines always did.
    Solo,
    /// This pass opened a new batch on its instance.  The host must
    /// arrange a flush at `deadline` (timer-wheel event in the
    /// simulator, a bounded wait in the live engine) and then call
    /// [`RelayCoordinator::close_batch`] with `gen` — a stale `gen`
    /// means a `Filled` flush already closed it.
    Opened { deadline: u64, gen: u64 },
    /// Joined the instance's open batch; executed by whoever flushes it.
    Joined,
    /// Joining filled the batch to `batch_max`: the host must flush it
    /// now (`close_batch(gen)`), ahead of the window deadline.
    Filled { gen: u64 },
}

/// Per-instance microbatch former state.  The member buffer is pooled:
/// `close_batch` drains it into the caller's (also recycled) buffer, so
/// the steady-state form/flush cycle allocates nothing once capacities
/// are warm.
struct BatchCtl {
    members: Vec<ReqId>,
    /// Monotone per-instance batch generation; guards timer flushes
    /// against batches already closed by `Filled`.
    gen: u64,
    open: bool,
}

impl BatchCtl {
    fn new() -> BatchCtl {
        BatchCtl { members: Vec::new(), gen: 0, open: false }
    }
}

/// Per-coordinator failure-injection counters (multi-cell scenarios).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FailStats {
    /// Instance failures applied ([`RelayCoordinator::fail_instance`]).
    pub failures: u64,
    /// Settled ψ lineages lazily wiped after a failure — the reload-storm
    /// numerator: each wipe forces the user's next touch to re-produce.
    pub storm_invalidations: u64,
}

impl FailStats {
    pub fn merge(&mut self, o: FailStats) {
        self.failures += o.failures;
        self.storm_invalidations += o.storm_invalidations;
    }
}

/// Per-instance cache-plane state.
struct InstanceCtl<T> {
    /// The tiered ψ cache: HBM window + lower tiers + promotion flow.
    cache: CacheHierarchy<T>,
    /// The shared candidate-segment cache (cross-user, deduplicated) —
    /// present only when segment reuse is enabled.
    segments: Option<SegmentStore<T>>,
    /// Rank requests waiting for ψ production to finish, per user.
    /// These per-user maps are sharded by user-id hash so trace-scale
    /// populations never concentrate in one table; every access is keyed.
    waiting_produce: ShardedMap<Vec<ReqId>>,
    /// Rank requests joined to an in-flight/queued reload, per user.
    waiting_reload: ShardedMap<Vec<ReqId>>,
    /// Where the currently-resident ψ came from (fresh pre-inference →
    /// `HbmHit`, DRAM reload → `DramHit`): drives the paper's hit-rate
    /// attribution even when a signal-initiated reload pre-warmed HBM.
    origin: ShardedMap<CacheOutcome>,
    /// The instance's microbatch former (rank passes grouped per
    /// `--batch-window` / `--batch-max`).
    batch: BatchCtl,
    /// Failure plane (multi-cell scenarios): the arrival clock at which
    /// this instance last failed (0 = never failed).  Applied lazily by
    /// [`RelayCoordinator::enforce_failure`] at the classification
    /// sites, so both engines observe the wipe at identical
    /// arrival-derived clocks in identical per-user order.
    failed_at: u64,
    /// Per-user lineage stamp: the arrival clock at which the user's
    /// current settled ψ lineage was created (production begun, or the
    /// post-failure wipe that reset it).  `stamp >= failed_at` means the
    /// lineage postdates the failure and survives.
    psi_stamp: ShardedMap<u64>,
    /// Fault plane: users whose in-flight ψ production was doomed at
    /// signal time (the psi-fail draw, keyed on the producing request's
    /// rid).  Consumed by [`RelayCoordinator::on_psi_ready`], which
    /// converts the completion to the failure path both engines already
    /// share — so the engines need no fault-specific event flow.
    doomed_psi: ShardedMap<()>,
}

/// Per-request decision state, slab-resident.  The `Vec` fields are
/// recycled with the slot (see [`Slab::insert_with`]), so the per-request
/// cycle is allocation-free once buffer capacities are warm.
struct ReqCtl {
    /// Workload request id (`GenRequest::rid`) — carried only so the
    /// flight recorder can label spans; no decision path reads it.
    rid: u64,
    user: u64,
    prefix_len: usize,
    is_long: bool,
    /// Arrival clock (µs) — the engine-shared timestamp every
    /// failure-plane comparison keys on (identical in sim and reference,
    /// unlike the event clocks of later stages).
    arrival_us: u64,
    admitted: bool,
    pre_instance: Option<usize>,
    rank_instance: usize,
    outcome: CacheOutcome,
    cached: bool,
    wait_since: u64,
    wait_us: f64,
    /// Rank-side wait resolved (production/reload finished or timed out).
    resolved: bool,
    /// Candidate item ids awaiting segment planning (consumed by
    /// [`RelayCoordinator::rank_compute`]).
    cands: Vec<u64>,
    /// Segment keys pinned by this rank pass, and the production tickets
    /// among them (`seg_produced` keys ⊆ `seg_pinned`); released and
    /// installed by [`RelayCoordinator::on_rank_done`].
    seg_pinned: Vec<u64>,
    seg_produced: Vec<(u64, u64)>,
}

impl ReqCtl {
    /// Full per-tenant reset — the single authoritative list of every
    /// field's initial value.  Both fresh slots (via `Default`) and
    /// recycled slots (via `insert_with`) go through here, so a field
    /// added to the struct cannot be inherited from a previous tenant by
    /// being forgotten in one of two places.
    fn reset(&mut self, rid: u64, user: u64, prefix_len: usize, is_long: bool) {
        self.rid = rid;
        self.user = user;
        self.prefix_len = prefix_len;
        self.is_long = is_long;
        self.arrival_us = 0;
        self.admitted = false;
        self.pre_instance = None;
        self.rank_instance = usize::MAX;
        self.outcome = CacheOutcome::FullInference;
        self.cached = false;
        self.wait_since = 0;
        self.wait_us = 0.0;
        self.resolved = false;
        self.cands.clear();
        self.seg_pinned.clear();
        self.seg_produced.clear();
    }
}

impl Default for ReqCtl {
    fn default() -> ReqCtl {
        let mut st = ReqCtl {
            rid: 0,
            user: 0,
            prefix_len: 0,
            is_long: false,
            arrival_us: 0,
            admitted: false,
            pre_instance: None,
            rank_instance: 0,
            outcome: CacheOutcome::FullInference,
            cached: false,
            wait_since: 0,
            wait_us: 0.0,
            resolved: false,
            cands: Vec::new(),
            seg_pinned: Vec::new(),
            seg_produced: Vec::new(),
        };
        st.reset(0, 0, 0, false);
        st
    }
}

/// [`PseudoAction`] → flight-recorder ψ lookup code ([`psi_action`]).
fn psi_code(a: &PseudoAction) -> u64 {
    match a {
        PseudoAction::HbmHit => psi_action::HBM_HIT,
        PseudoAction::WaitProducing => psi_action::WAIT_PRODUCING,
        PseudoAction::StartReload { .. } => psi_action::START_RELOAD,
        PseudoAction::JoinReload => psi_action::JOIN_RELOAD,
        PseudoAction::QueuedReload => psi_action::QUEUED_RELOAD,
        PseudoAction::Miss => psi_action::MISS,
    }
}

/// The shared relay-race coordinator.
pub struct RelayCoordinator<T> {
    cfg: CoordinatorConfig,
    router: Router,
    triggers: HashMap<usize, Trigger>,
    instances: Vec<InstanceCtl<T>>,
    /// Failure-injection counters (multi-cell scenarios).
    fail: FailStats,
    /// Per-request decision state behind generational [`ReqId`] handles:
    /// dense O(1) access, recycled slots, no per-request allocation.
    requests: Slab<ReqCtl>,
    /// The observe-only flight recorder (`--trace-spans > 0`); never
    /// consulted by any decision path — see [`crate::relay::flight`].
    flight: Option<FlightRecorder>,
    /// The compiled fault plane (`--faults`); all draws are pure
    /// functions of (seed, kind, stable id, attempt), so consulting it
    /// is itself decision-synchronous.
    faults: FaultPlan,
}

impl<T: Clone + Default> RelayCoordinator<T> {
    /// Build the coordinator; `mk_estimator` supplies the latency
    /// estimator for each special instance's trigger.
    pub fn new(
        mut cfg: CoordinatorConfig,
        mut mk_estimator: impl FnMut(usize) -> Estimator,
    ) -> Result<RelayCoordinator<T>> {
        // The batch window is decision-synchronous latency every admitted
        // request will spend waiting out the former: fold it into the
        // trigger config so the adaptive controller's estimate charges it
        // to admission instead of silently attributing it to compute.
        // The coordinator's window is the single source of truth.
        cfg.trigger.batch_window_us = cfg.batch_window_us;
        // Same folding rule for the fault plan's worst-case retry
        // budget: an admitted request may sit out exponential backoff
        // before the degradation ladder resolves it, so the adaptive
        // controller charges that latency to admission.  Zero when the
        // plane is off — fault-free runs price identically to PR 9.
        cfg.trigger.retry_budget_us = cfg.faults.retry_budget_us();
        let router = Router::new(cfg.router.clone())?;
        let mut triggers = HashMap::new();
        for &i in router.special_instances() {
            triggers.insert(i, Trigger::new(cfg.trigger.clone(), mk_estimator(i)));
        }
        // The segment cache takes its partition out of the r1 slice, so
        // ψ windows and segment caches contend for the same budget.
        let seg_on = cfg.mode.is_relay() && cfg.segment.enabled();
        let seg_budget = if seg_on {
            (cfg.segment.frac.clamp(0.0, 0.9) * cfg.hbm_bytes as f64) as usize
        } else {
            0
        };
        let psi_budget = cfg.hbm_bytes - seg_budget;
        let instances = (0..cfg.router.n_instances)
            .map(|_| InstanceCtl {
                cache: CacheHierarchy::new(psi_budget, &cfg.tiers, cfg.max_reload_concurrency),
                segments: seg_on.then(|| SegmentStore::from_config(seg_budget, &cfg.segment)),
                waiting_produce: ShardedMap::new(),
                waiting_reload: ShardedMap::new(),
                origin: ShardedMap::new(),
                batch: BatchCtl::new(),
                failed_at: 0,
                psi_stamp: ShardedMap::new(),
                doomed_psi: ShardedMap::new(),
            })
            .collect();
        let flight = (cfg.trace_spans > 0).then(|| FlightRecorder::new(cfg.trace_spans));
        let faults = FaultPlan::new(cfg.faults.clone());
        Ok(RelayCoordinator {
            cfg,
            router,
            triggers,
            instances,
            fail: FailStats::default(),
            requests: Slab::new(),
            flight,
            faults,
        })
    }

    // ---- introspection -----------------------------------------------------

    pub fn config(&self) -> &CoordinatorConfig {
        &self.cfg
    }

    pub fn special_instances(&self) -> &[usize] {
        self.router.special_instances()
    }

    pub fn server_of(&self, instance: usize) -> usize {
        self.router.server_of(instance)
    }

    pub fn n_instances(&self) -> usize {
        self.instances.len()
    }

    /// Whether the request will run ranking-on-cache (valid once its
    /// rank-side classification is settled).
    pub fn is_cached(&self, req: ReqId) -> bool {
        self.requests.get(req).map(|r| r.cached).unwrap_or(false)
    }

    /// Whether the request holds an admitted live-cache slot.
    pub fn is_admitted(&self, req: ReqId) -> bool {
        self.requests.get(req).map(|r| r.admitted).unwrap_or(false)
    }

    /// Whether a waiting rank request has been resolved (woken or timed
    /// out) — the live engine polls this under its condvar.  A retired
    /// handle reads as resolved.
    pub fn wait_resolved(&self, req: ReqId) -> bool {
        self.requests.get(req).map(|r| r.resolved).unwrap_or(true)
    }

    /// Live (un-retired) requests — leak check for tests and benches.
    pub fn live_requests(&self) -> usize {
        self.requests.len()
    }

    /// The flight recorder, when tracing is on (`--trace-spans > 0`) —
    /// live heartbeats read span counters through this.
    pub fn flight(&self) -> Option<&FlightRecorder> {
        self.flight.as_ref()
    }

    /// Detach the flight recorder at end of run; engines fold its stage
    /// breakdown into their metrics and write the RGSP sidecar from it.
    pub fn take_flight(&mut self) -> Option<FlightRecorder> {
        self.flight.take()
    }

    /// Merged cache/admission counters across instances.
    pub fn hbm_stats(&self) -> HbmStats {
        let mut acc = HbmStats::default();
        for i in &self.instances {
            acc.merge(i.cache.hbm().stats());
        }
        acc
    }

    /// Merged hierarchy flow + per-tier counters across instances.
    pub fn hierarchy_stats(&self) -> HierarchyStats {
        let mut acc = HierarchyStats::default();
        for i in &self.instances {
            acc.merge(i.cache.stats());
        }
        acc
    }

    pub fn trigger_stats(&self) -> TriggerStats {
        let mut acc = TriggerStats::default();
        for t in self.triggers.values() {
            acc.merge(t.stats());
        }
        acc
    }

    /// Whether candidate-segment reuse is active (relay mode with a
    /// non-zero `--segment-cache` partition).  Hosts use this to decide
    /// whether to materialise candidate sets at all.
    pub fn segments_enabled(&self) -> bool {
        self.cfg.mode.is_relay() && self.cfg.segment.enabled()
    }

    /// Merged candidate-segment counters across instances.
    pub fn segment_stats(&self) -> SegmentStats {
        let mut acc = SegmentStats::default();
        for i in &self.instances {
            if let Some(s) = &i.segments {
                acc.merge(s.stats());
            }
        }
        acc
    }

    /// Rotate the segment key space to a new model version (model push):
    /// segments keyed under the old version stop matching from the next
    /// rank pass on and age out of the cache via their TTL.
    pub fn set_model_version(&mut self, version: u16) {
        self.cfg.segment.version = version;
    }

    /// Live-cache slots currently held across special instances (the
    /// paper's Σ L admission feedback).  Every `Decision::Admit` holds
    /// one slot until its request completes (`on_rank_done`) or the
    /// admit is cancelled at signal time (HBM overcommit).
    pub fn trigger_live(&self) -> usize {
        self.triggers.values().map(|t| t.live()).sum()
    }

    /// Host copy backing a reload the caller is about to perform
    /// (searched top-down through the lower tiers).
    pub fn dram_payload(&mut self, instance: usize, user: u64) -> Option<(usize, T)> {
        self.instances[instance].cache.payload_below(user)
    }

    /// Drop a user's lower-tier entries (behaviours refreshed upstream:
    /// the cached prefix is stale).  An in-flight promotion for the user
    /// aborts when it is granted its slot and finds the payload gone.
    pub fn invalidate_user(&mut self, instance: usize, user: u64) -> bool {
        self.instances[instance].cache.invalidate(user)
    }

    // ---- failure / churn plane (multi-cell scenarios) ----------------------

    /// Mark `instance` failed at arrival clock `at_us` (fail-restart: the
    /// process restarts with its ψ/segment caches lost; ring membership
    /// does not change).  The wipe itself is applied lazily, per user, at
    /// the classification sites ([`RelayCoordinator::enforce_failure`]) —
    /// the only clocks both engines share — so a failure is
    /// decision-bit-identical across sim and serialized reference.
    pub fn fail_instance(&mut self, at_us: u64, instance: usize) {
        self.instances[instance].failed_at = at_us.max(1);
        self.fail.failures += 1;
    }

    pub fn fail_stats(&self) -> FailStats {
        self.fail
    }

    /// Fault-plane counters (injected/retried/recovered/degraded/shed
    /// per kind) for this coordinator; cells merge these like the other
    /// stat blocks.
    pub fn fault_report(&self) -> FaultReport {
        self.faults.report()
    }

    /// Count a scheduled instance crash into the fault report.  The
    /// crash itself is applied through [`Self::fail_instance`] — the
    /// cell layer compiles `crash@P%` to a scripted event rather than a
    /// per-request draw.
    pub fn note_crash_injected(&mut self) {
        self.faults.note_injected(FaultKind::Crash);
    }

    /// Cell drain: remove and return every settled lower-tier ψ host
    /// copy, `(user, bytes, payload)` in instance-index then ascending
    /// user order — a deterministic manifest for cross-cell migration.
    /// HBM-resident entries stay behind (device memory does not ship);
    /// they expire with the drained cell's lifecycle window.
    pub fn drain_dram(&mut self) -> Vec<(u64, usize, T)> {
        let mut out = Vec::new();
        for ctl in &mut self.instances {
            out.extend(ctl.cache.drain_lower());
        }
        out
    }

    /// Adopt a migrated ψ host copy into this cell: it lands in the
    /// lower tier of the special instance this cell's affinity ring
    /// maps `user` to, exactly where the user's post-drain reload will
    /// look.  Returns `false` (migration lost) when no special route
    /// exists or the tier rejects the copy.
    pub fn adopt_psi(&mut self, user: u64, bytes: usize, payload: T) -> bool {
        let Some(inst) = self.router.peek_special(user) else {
            return false;
        };
        self.instances[inst].cache.spill(user, bytes, payload)
    }

    /// Lazily apply an instance failure to one user's ψ state: a request
    /// arriving at or after the failure clock must not observe settled
    /// state created before it.  In-flight lineages (HBM `Producing`, or
    /// a pending reload) survive: their pre-failure waiters already
    /// settled outcomes in the serialized reference, so wiping them would
    /// diverge the engines — a post-failure joiner converges to the same
    /// outcome either way.  Keyed on the request's *arrival* clock, which
    /// is identical in both engines (later event clocks are not).
    fn enforce_failure(&mut self, instance: usize, user: u64, arrival: u64) {
        let ctl = &mut self.instances[instance];
        if ctl.failed_at == 0 || arrival < ctl.failed_at {
            return;
        }
        if ctl.psi_stamp.get(user).copied().unwrap_or(0) >= ctl.failed_at {
            return; // lineage created after the failure — survives
        }
        // Restamp first so the survivors below are not re-examined on
        // every touch: an in-flight lineage that outlives the failure is
        // treated as post-failure from here on.
        ctl.psi_stamp.insert(user, arrival);
        if ctl.cache.hbm().state_of(user) == Some(EntryState::Producing)
            || ctl.cache.inflight_for(user)
        {
            return;
        }
        let mut wiped = ctl.cache.hbm_mut().evict(user);
        wiped |= ctl.cache.invalidate(user);
        ctl.origin.remove(user);
        if wiped {
            self.fail.storm_invalidations += 1;
        }
    }

    /// Promote `instance` into the special (relay) set, creating its
    /// trigger if it never had one.  Returns `false` when the router
    /// refuses (already special, or the per-server density cap).
    pub fn promote_special(&mut self, instance: usize, est: Estimator) -> bool {
        if !self.router.add_special(instance) {
            return false;
        }
        self.triggers
            .entry(instance)
            .or_insert_with(|| Trigger::new(self.cfg.trigger.clone(), est));
        true
    }

    /// Demote `instance` out of the special set.  Its trigger is kept so
    /// admission slots held by in-flight requests release cleanly; it
    /// simply receives no new signals once the ring stops routing to it.
    pub fn demote_special(&mut self, instance: usize) -> bool {
        self.router.remove_special(instance)
    }

    /// Flight-recorder hook for the cell layer (observe-only): which
    /// cell served this request, and whether the pick overrode the
    /// user's home cell.  Called by `CellSet` right after `on_arrival`.
    pub fn note_cell_routed(
        &mut self,
        now: u64,
        req: ReqId,
        cell: usize,
        home: usize,
        failover: bool,
    ) {
        if let Some(fl) = self.flight.as_mut() {
            fl.note_cell_route(now, req.index(), cell as u64, home as u64, failover);
        }
    }

    /// Observe-only flight spans for one fault-plane resolution.  Takes
    /// the workload `rid` directly — some injection sites (reload
    /// completion) have no slab slot in hand.
    fn note_fault_spans(&mut self, now: u64, rid: u64, kind: FaultKind, fate: FaultOutcome) {
        let retries = self.faults.config().retries as u64;
        let Some(fl) = self.flight.as_mut() else { return };
        let idx = kind.index() as u64;
        match fate {
            FaultOutcome::Clean => {}
            FaultOutcome::Recovered { attempts } => {
                fl.note_fault(now, rid, idx, true);
                for a in 1..=attempts as u64 {
                    fl.note_retry(now, rid, idx, a);
                }
            }
            FaultOutcome::Failed => {
                fl.note_fault(now, rid, idx, false);
                if kind.retryable() {
                    for a in 1..=retries {
                        fl.note_retry(now, rid, idx, a);
                    }
                }
            }
        }
    }

    // ---- event API ---------------------------------------------------------

    /// A request entered the pipeline.  `rid` is the workload request id
    /// (`GenRequest::rid`), used only to label flight-recorder spans.
    /// `candidates` is the ranking-side candidate item set (copied into
    /// the request's recycled slot buffer for segment planning at
    /// `rank_compute`; pass `&[]` when segment reuse is off — hosts
    /// should consult [`RelayCoordinator::segments_enabled`] before
    /// materialising it).  Returns the request's [`ReqId`] handle —
    /// every later event takes it back — and whether the trigger side
    /// path should run (relay mode, long sequence).
    pub fn on_arrival(
        &mut self,
        now: u64,
        rid: u64,
        user: u64,
        prefix_len: usize,
        candidates: &[u64],
    ) -> (ReqId, bool) {
        let is_long = prefix_len > self.cfg.long_threshold;
        let keep_cands = self.cfg.mode.is_relay() && self.cfg.segment.enabled();
        let req = self.requests.insert_with(|st| {
            st.reset(rid, user, prefix_len, is_long);
            st.arrival_us = now;
            if keep_cands {
                st.cands.extend_from_slice(candidates);
            }
        });
        if let Some(fl) = self.flight.as_mut() {
            fl.note_arrival(now, rid, req.index(), user, prefix_len as u64);
        }
        (req, self.cfg.mode.is_relay() && is_long)
    }

    /// The trigger side path: metadata risk test, admission control, and
    /// the signal-side pseudo-pre-infer (§3.2/§3.4).
    pub fn on_trigger_check(&mut self, now: u64, req: ReqId) -> SignalAction {
        let (rid, user, prefix_len, arrival) = {
            let st = self.requests.get(req).expect("trigger check for unknown request");
            (st.rid, st.user, st.prefix_len, st.arrival_us)
        };
        // Fault plane: the trigger signal may be dropped before the risk
        // test runs (keyed on the request's rid — stable across engines).
        // An unrecovered drop means the side path never fires: the
        // request is never admitted and pays full inference at ranking —
        // exactly the degradation the retry ladder exists to claw back,
        // which is why `figure faults` uses the full-inference count as
        // its headline.
        let fate = self.faults.resolve(FaultKind::TriggerDrop, rid);
        if fate != FaultOutcome::Clean {
            self.note_fault_spans(now, rid, FaultKind::TriggerDrop, fate);
            if fate == FaultOutcome::Failed {
                return SignalAction::None;
            }
        }
        let route = self.router.route_special(user);
        self.router.on_complete(route.instance); // signal, not a held connection
        let inst = route.instance;
        let meta = BehaviorMeta { user, prefix_len, dim: self.cfg.dim };
        // The observed ψ footprint is the adaptive controller's feedback
        // signal (static admission ignores it).
        let kv = (self.cfg.kv_bytes)(prefix_len);
        let decision = self
            .triggers
            .get_mut(&inst)
            .map(|t| t.decide(now, &meta, kv))
            .unwrap_or(Decision::NotAtRisk);
        if let Some(fl) = self.flight.as_mut() {
            fl.note_route(now, req.index(), false, inst as u64);
            let reason = match decision {
                Decision::NotAtRisk => trigger_reason::NOT_AT_RISK,
                Decision::Admit => trigger_reason::ADMIT,
                Decision::RateLimited => trigger_reason::RATE_LIMITED,
                Decision::FootprintLimited => trigger_reason::FOOTPRINT_LIMITED,
            };
            fl.note_trigger(now, req.index(), reason, inst as u64);
        }
        if decision != Decision::Admit {
            return SignalAction::None;
        }
        {
            let st = self.requests.get_mut(req).unwrap();
            st.admitted = true;
            st.pre_instance = Some(inst);
        }
        // The pre-infer signal itself performs the pseudo-pre-infer checks,
        // skipping redundant recomputation when ψ is already local (§3.4).
        self.enforce_failure(inst, user, arrival);
        let action = self.instances[inst].cache.pseudo_pre_infer(user, now);
        if let Some(fl) = self.flight.as_mut() {
            fl.note_psi(now, req.index(), psi_code(&action), false);
        }
        match action {
            PseudoAction::HbmHit | PseudoAction::WaitProducing => {
                // Cache already present / being produced: re-arm its
                // lifecycle for this request instead of recomputing.  The
                // admitted slot stays held until the request completes
                // (Eq. 1: L = Q_admit · T_life) and is released exactly
                // once, in `on_rank_done`.
                self.instances[inst].cache.hbm_mut().extend_lease(user, now + self.cfg.t_life_us);
                SignalAction::None
            }
            PseudoAction::StartReload { bytes } => {
                if let Some(fl) = self.flight.as_mut() {
                    fl.note_reload_begin(now, req.index(), user, inst as u64, bytes as u64);
                }
                SignalAction::Reload { instance: inst, user, bytes }
            }
            PseudoAction::JoinReload | PseudoAction::QueuedReload => {
                // A reload is already pending; the signal needs no follow-up.
                SignalAction::None
            }
            PseudoAction::Miss => {
                let instance = &mut self.instances[inst];
                match instance.cache.hbm_mut().begin_produce(user, kv, now, self.cfg.t_life_us) {
                    Ok(()) => {
                        // New lineage, stamped with the engine-shared
                        // arrival clock (failure-plane survivorship).
                        instance.psi_stamp.insert(user, arrival);
                        // Fault plane: doom this production now, keyed on
                        // the producing request's rid.  The doom is
                        // stored per user and consumed by `on_psi_ready`,
                        // which routes the completion down the failure
                        // path both engines already share — no
                        // fault-specific event flow needed.
                        let psi_fate = self.faults.resolve(FaultKind::PsiFail, rid);
                        if psi_fate == FaultOutcome::Failed {
                            self.instances[inst].doomed_psi.insert(user, ());
                        }
                        self.note_fault_spans(now, rid, FaultKind::PsiFail, psi_fate);
                        if let Some(fl) = self.flight.as_mut() {
                            fl.note_produce_begin(now, req.index(), user, inst as u64);
                        }
                        SignalAction::Produce { instance: inst, user, prefix_len }
                    }
                    Err(_) => {
                        // Admission overcommitted (shouldn't happen when Eqs.
                        // 1-3 hold); treat as not admitted.  The cancel frees
                        // the slot *and* the adaptive footprint reservation;
                        // clearing `st.admitted` below is what guarantees the
                        // release is not repeated at `on_rank_done` — the
                        // only other `release()` site.
                        if let Some(t) = self.triggers.get_mut(&inst) {
                            t.cancel_admit(user);
                        }
                        let st = self.requests.get_mut(req).unwrap();
                        st.admitted = false;
                        st.pre_instance = None;
                        let rid = st.rid;
                        if let Some(fl) = self.flight.as_mut() {
                            // Post-admit reversal: a second trigger span
                            // records the cancel (the first said `admit`).
                            fl.emit(
                                now,
                                rid,
                                SpanKind::TriggerDecision,
                                trigger_reason::OVERCOMMIT_CANCEL,
                                inst as u64,
                            );
                        }
                        SignalAction::None
                    }
                }
            }
        }
    }

    /// A cascade stage finished.  At pre-processing the late binding is
    /// resolved: long-sequence requests carry the consistency-hash-key
    /// and go to the special service; short ones follow standard
    /// balancing.  Returns the ranking instance at `Stage::Preproc`.
    pub fn on_stage_done(&mut self, now: u64, req: ReqId, stage: Stage) -> Option<usize> {
        if stage != Stage::Preproc {
            return None;
        }
        let (user, is_long) = {
            let st = self.requests.get(req).expect("stage done for unknown request");
            (st.user, st.is_long)
        };
        let route = if self.cfg.mode.is_relay() && is_long {
            self.router.route_special(user)
        } else {
            self.router.route_normal(user)
        };
        self.requests.get_mut(req).unwrap().rank_instance = route.instance;
        if let Some(fl) = self.flight.as_mut() {
            fl.note_route(now, req.index(), true, route.instance as u64);
        }
        Some(route.instance)
    }

    /// The ranking request reached its instance: run the pseudo-pre-infer
    /// fronting every ranking request (§3.4) and classify.
    pub fn on_rank_start(&mut self, now: u64, req: ReqId) -> RankAction {
        let (inst, user, is_long, admitted, arrival) = {
            let st = self.requests.get(req).expect("rank start for unknown request");
            (st.rank_instance, st.user, st.is_long, st.admitted, st.arrival_us)
        };
        if !(self.cfg.mode.is_relay() && is_long) {
            // Baseline mode or short-sequence request: full inline inference.
            self.requests.get_mut(req).unwrap().resolved = true;
            if let Some(fl) = self.flight.as_mut() {
                fl.note_rank_start(now, req.index(), rank_action::PROCEED, inst as u64);
            }
            return RankAction::Proceed { cached: false, outcome: CacheOutcome::FullInference };
        }
        self.enforce_failure(inst, user, arrival);
        let action = self.instances[inst].cache.pseudo_pre_infer(user, now);
        if let Some(fl) = self.flight.as_mut() {
            fl.note_psi(now, req.index(), psi_code(&action), true);
            let code = match &action {
                PseudoAction::HbmHit | PseudoAction::Miss => rank_action::PROCEED,
                PseudoAction::WaitProducing => rank_action::WAIT,
                PseudoAction::StartReload { .. } => rank_action::START_RELOAD,
                PseudoAction::JoinReload | PseudoAction::QueuedReload => rank_action::WAIT_RELOAD,
            };
            fl.note_rank_start(now, req.index(), code, inst as u64);
        }
        match action {
            PseudoAction::HbmHit => {
                let origin = self.instances[inst]
                    .origin
                    .get(user)
                    .copied()
                    .unwrap_or(CacheOutcome::HbmHit);
                let st = self.requests.get_mut(req).unwrap();
                st.outcome = origin;
                st.cached = true;
                st.resolved = true;
                RankAction::Proceed { cached: true, outcome: origin }
            }
            PseudoAction::WaitProducing => {
                self.requests.get_mut(req).unwrap().wait_since = now;
                self.instances[inst].waiting_produce.or_insert_with(user, Vec::new).push(req);
                RankAction::Wait
            }
            PseudoAction::StartReload { bytes } => {
                {
                    let st = self.requests.get_mut(req).unwrap();
                    st.outcome = CacheOutcome::DramHit;
                    st.cached = true;
                    st.wait_since = now;
                }
                self.instances[inst].waiting_reload.or_insert_with(user, Vec::new).push(req);
                if let Some(fl) = self.flight.as_mut() {
                    fl.note_reload_begin(now, req.index(), user, inst as u64, bytes as u64);
                }
                RankAction::StartReload { bytes }
            }
            PseudoAction::JoinReload | PseudoAction::QueuedReload => {
                {
                    let st = self.requests.get_mut(req).unwrap();
                    st.outcome = CacheOutcome::JoinedReload;
                    st.cached = true;
                    st.wait_since = now;
                }
                self.instances[inst].waiting_reload.or_insert_with(user, Vec::new).push(req);
                RankAction::WaitReload
            }
            PseudoAction::Miss => {
                let st = self.requests.get_mut(req).unwrap();
                st.outcome =
                    if admitted { CacheOutcome::Fallback } else { CacheOutcome::FullInference };
                st.cached = false;
                st.resolved = true;
                let outcome = st.outcome;
                if admitted {
                    if let Some(fl) = self.flight.as_mut() {
                        fl.note_fallback(now, req.index(), 4);
                    }
                }
                RankAction::Proceed { cached: false, outcome }
            }
        }
    }

    /// ψ production finished on `instance` (`payload = None` ⇒ it failed).
    /// Returns the rank requests resolved by it; the host resumes their
    /// processing.
    pub fn on_psi_ready(
        &mut self,
        now: u64,
        instance: usize,
        user: u64,
        payload: Option<T>,
    ) -> Vec<ReqId> {
        // Fault plane: a production doomed at signal time completes down
        // the shared failure path — payload dropped, reservation evicted
        // — so both engines observe the identical conversion regardless
        // of who computed ψ or when.
        let doomed = self.instances[instance].doomed_psi.remove(user).is_some();
        let payload = if doomed { None } else { payload };
        let ok = match payload {
            Some(p) => self.instances[instance].cache.hbm_mut().complete_produce(user, p),
            None => {
                // Production failed (live-engine execution error): drop the
                // reservation so later requests miss cleanly.
                self.instances[instance].cache.hbm_mut().evict(user);
                false
            }
        };
        if ok {
            self.instances[instance].origin.insert(user, CacheOutcome::HbmHit);
        }
        if let Some(fl) = self.flight.as_mut() {
            fl.note_produce_end(now, user, instance as u64, ok);
        }
        // On failure (entry evicted while producing — lost work) the
        // admitted slot is still released exactly once, by the owning
        // request's `on_rank_done`.
        let waiters =
            self.instances[instance].waiting_produce.remove(user).unwrap_or_default();
        for &w in &waiters {
            if let Some(st) = self.requests.get_mut(w) {
                let waited = now.saturating_sub(st.wait_since);
                st.wait_us += waited as f64;
                if ok {
                    st.outcome = CacheOutcome::HbmHit;
                    st.cached = true;
                } else {
                    // Degradation ladder for fault-doomed productions:
                    // shed pressure picks between `Shed` and the plain
                    // fallback rung.  Host-reported failures (live-engine
                    // execution errors) keep the plain fallback path.
                    let shed =
                        doomed && self.faults.shed_or_degrade(FaultKind::PsiFail, st.rid);
                    st.outcome =
                        if shed { CacheOutcome::Shed } else { CacheOutcome::Fallback };
                    st.cached = false;
                }
                st.resolved = true;
                let (rid, shed) = (st.rid, st.outcome == CacheOutcome::Shed);
                if let Some(fl) = self.flight.as_mut() {
                    fl.note_wait_resolved(now, w.index(), 0, waited);
                    if !ok {
                        if doomed {
                            fl.note_degraded(now, rid, FaultKind::PsiFail.index() as u64, shed);
                        }
                        if !shed {
                            fl.note_fallback(now, w.index(), 3);
                        }
                    }
                }
            }
        }
        waiters
    }

    /// A DRAM→HBM transfer finished (`payload = None` ⇒ the H2D failed).
    pub fn on_reload_done(
        &mut self,
        now: u64,
        instance: usize,
        user: u64,
        payload: Option<T>,
        bytes: usize,
    ) -> ReloadResolution {
        let t_life = self.cfg.t_life_us;
        // Fault plane: the H2D transfer may fail in flight.  Drawn only
        // when the host actually delivered a payload, keyed on the user
        // id alone (a reload has no single owning request; the user id
        // is stable and globally unique across engines and cells).
        let mut reload_fate = FaultOutcome::Clean;
        let payload = if payload.is_some() {
            reload_fate = self.faults.resolve(FaultKind::ReloadFail, user);
            if reload_fate == FaultOutcome::Failed { None } else { payload }
        } else {
            payload
        };
        let faulted = reload_fate == FaultOutcome::Failed;
        let done = {
            let inst = &mut self.instances[instance];
            match payload {
                Some(p) => inst.cache.complete_reload(user, p, bytes, now, t_life),
                None => {
                    let (joiners, next) = inst.cache.finish_reload(user);
                    ReloadDone { joiners, installed: false, next }
                }
            }
        };
        if done.installed {
            self.instances[instance].origin.insert(user, CacheOutcome::DramHit);
        }
        if let Some(fl) = self.flight.as_mut() {
            fl.note_reload_end(now, user, done.installed, bytes as u64);
        }
        let woken = self.instances[instance].waiting_reload.remove(user).unwrap_or_default();
        if reload_fate != FaultOutcome::Clean {
            // Span labelling: attribute the injection to the first woken
            // request when one exists (the reload itself has no rid).
            let span_rid = woken
                .first()
                .and_then(|&w| self.requests.get(w))
                .map_or(u64::MAX, |st| st.rid);
            self.note_fault_spans(now, span_rid, FaultKind::ReloadFail, reload_fate);
        }
        for &w in &woken {
            if let Some(st) = self.requests.get_mut(w) {
                let waited = now.saturating_sub(st.wait_since);
                st.wait_us += waited as f64;
                if !done.installed {
                    // Degradation ladder for fault-injected reload loss;
                    // host-reported H2D errors keep the plain fallback.
                    let shed =
                        faulted && self.faults.shed_or_degrade(FaultKind::ReloadFail, st.rid);
                    st.outcome =
                        if shed { CacheOutcome::Shed } else { CacheOutcome::Fallback };
                    st.cached = false;
                }
                st.resolved = true;
                let (rid, shed) = (st.rid, st.outcome == CacheOutcome::Shed);
                if let Some(fl) = self.flight.as_mut() {
                    fl.note_wait_resolved(now, w.index(), 1, waited);
                    if !done.installed {
                        if faulted {
                            fl.note_degraded(
                                now,
                                rid,
                                FaultKind::ReloadFail.index() as u64,
                                shed,
                            );
                        }
                        if !shed {
                            fl.note_fallback(now, w.index(), 1);
                        }
                    }
                }
            }
        }
        ReloadResolution { installed: done.installed, woken, next: done.next }
    }

    /// A queued reload was granted its concurrency slot.  If the payload
    /// was evicted from DRAM while queued, the reload aborts and its
    /// waiters fall back.
    pub fn begin_queued_reload(&mut self, now: u64, instance: usize, user: u64) -> QueuedReload {
        match self.instances[instance].cache.payload_below(user) {
            Some((bytes, _)) => QueuedReload::Start { bytes },
            None => {
                let next = self.instances[instance].cache.abort_reload(user);
                let woken =
                    self.instances[instance].waiting_reload.remove(user).unwrap_or_default();
                for &w in &woken {
                    if let Some(st) = self.requests.get_mut(w) {
                        let waited = now.saturating_sub(st.wait_since);
                        st.wait_us += waited as f64;
                        st.outcome = CacheOutcome::Fallback;
                        st.cached = false;
                        st.resolved = true;
                        if let Some(fl) = self.flight.as_mut() {
                            fl.note_wait_resolved(now, w.index(), 3, waited);
                            fl.note_fallback(now, w.index(), 1);
                        }
                    }
                }
                QueuedReload::Aborted { woken, next }
            }
        }
    }

    /// Wait-budget fallback: a rank request waited too long for ψ.  The
    /// request leaves its waiting list and falls back to full inference.
    pub fn on_wait_timeout(&mut self, now: u64, req: ReqId) {
        let Some(st) = self.requests.get_mut(req) else { return };
        let waited = now.saturating_sub(st.wait_since);
        st.wait_us += waited as f64;
        st.outcome = CacheOutcome::Fallback;
        st.cached = false;
        st.resolved = true;
        let (inst, user) = (st.rank_instance, st.user);
        if let Some(fl) = self.flight.as_mut() {
            fl.note_wait_resolved(now, req.index(), 2, waited);
            fl.note_fallback(now, req.index(), 0);
        }
        if inst < self.instances.len() {
            let ctl = &mut self.instances[inst];
            for map in [&mut ctl.waiting_produce, &mut ctl.waiting_reload] {
                if let Some(v) = map.get_mut(user) {
                    v.retain(|&r| r != req);
                    if v.is_empty() {
                        map.remove(user);
                    }
                }
            }
        }
    }

    /// Offer one rank pass — classified, wait-resolved, ready to
    /// execute — to its instance's microbatch former.
    ///
    /// The batch-former contract (PR 7): batching groups rank
    /// *executions* strictly after per-request classification
    /// ([`RelayCoordinator::on_rank_start`] and the wait/reload
    /// resolution events), so batch membership may change *pricing and
    /// timing* but never a request's [`CacheOutcome`].  Every rank pass
    /// is offered exactly once and lands in exactly one batch (`Solo`
    /// is its own batch of one); a batch is drained exactly once, by
    /// whichever of the `Filled` host or the window-deadline flush
    /// reaches [`RelayCoordinator::close_batch`] first with a live
    /// generation.
    pub fn offer_rank(&mut self, now: u64, req: ReqId) -> BatchDecision {
        let window = self.cfg.batch_window_us;
        if window == 0 {
            if let Some(fl) = self.flight.as_mut() {
                let inst =
                    self.requests.get(req).map_or(NONE_OPERAND, |st| st.rank_instance as u64);
                fl.note_batch(now, req.index(), SpanKind::BatchSolo, inst, 0);
            }
            return BatchDecision::Solo;
        }
        let inst = {
            let st = self.requests.get(req).expect("batch offer for unknown request");
            st.rank_instance
        };
        let max = self.cfg.batch_max.max(1);
        let b = &mut self.instances[inst].batch;
        if !b.open {
            b.gen += 1;
            b.members.push(req);
            let gen = b.gen;
            if max == 1 {
                // Degenerate cap: every batch closes as it opens.
                if let Some(fl) = self.flight.as_mut() {
                    fl.note_batch(now, req.index(), SpanKind::BatchFilled, inst as u64, gen);
                }
                return BatchDecision::Filled { gen };
            }
            b.open = true;
            if let Some(fl) = self.flight.as_mut() {
                fl.note_batch(now, req.index(), SpanKind::BatchOpen, inst as u64, gen);
            }
            BatchDecision::Opened { deadline: now + window, gen }
        } else {
            b.members.push(req);
            let gen = b.gen;
            if b.members.len() >= max {
                b.open = false;
                if let Some(fl) = self.flight.as_mut() {
                    fl.note_batch(now, req.index(), SpanKind::BatchFilled, inst as u64, gen);
                }
                BatchDecision::Filled { gen }
            } else {
                if let Some(fl) = self.flight.as_mut() {
                    fl.note_batch(now, req.index(), SpanKind::BatchJoin, inst as u64, gen);
                }
                BatchDecision::Joined
            }
        }
    }

    /// Close batch `gen` on `instance` and drain its members into `out`
    /// (cleared first; the internal buffer stays pooled).  Returns
    /// `false` — and leaves `out` empty — when the generation is stale:
    /// a `Filled` flush already drained this batch and the deadline
    /// timer fired late (or vice versa).  The host executes the drained
    /// members as one batched rank pass: `rank_compute` for *all*
    /// members first (co-batched duplicate segments dedup into
    /// `Join`/`Reuse` against the first member's `Produce` via the
    /// single-flight store), then one batched execution, then
    /// `on_rank_done` per member (installs/releases each pin exactly
    /// once).
    pub fn close_batch(
        &mut self,
        now: u64,
        instance: usize,
        gen: u64,
        out: &mut Vec<ReqId>,
    ) -> bool {
        out.clear();
        let b = &mut self.instances[instance].batch;
        if b.gen != gen || b.members.is_empty() {
            return false;
        }
        b.open = false;
        out.append(&mut b.members);
        if let Some(fl) = self.flight.as_mut() {
            for &r in out.iter() {
                fl.note_batch_flush(now, r.index(), instance as u64, gen);
            }
        }
        true
    }

    /// Whether batch `gen` on `instance` is still open (live-engine
    /// window leaders poll this under the condvar to detect a `Filled`
    /// flush by another worker).
    pub fn batch_open(&self, instance: usize, gen: u64) -> bool {
        let b = &self.instances[instance].batch;
        b.open && b.gen == gen
    }

    /// Ranking execution starts: consume ψ when cached, and plan the
    /// candidate-segment reuse for this pass — per candidate, reuse a
    /// resident segment, join an in-flight production, or become the
    /// producer (cross-request single-flight, implemented once here so
    /// both engines inherit identical dedup decisions).
    pub fn rank_compute(&mut self, now: u64, req: ReqId) -> RankCompute<T> {
        let (inst, user, cached) = {
            let st = self.requests.get(req).expect("rank compute for unknown request");
            (st.rank_instance, st.user, st.cached)
        };
        let payload =
            if cached { self.instances[inst].cache.hbm_mut().consume(user) } else { None };
        let segments = self.plan_segments(now, req, inst);
        if let Some(fl) = self.flight.as_mut() {
            let reused = segments.as_ref().map_or(0, |p| p.reused as u64);
            fl.note_exec_start(now, req.index(), cached, reused);
        }
        RankCompute { cached, payload, segments }
    }

    /// Per-candidate segment decisions for one rank pass; pins are held
    /// in the request's recycled slot buffers until
    /// [`RelayCoordinator::on_rank_done`] releases them.
    // Indexed loop: `st.cands` is read while `st.seg_pinned` is pushed —
    // same struct, so an iterator over `cands` cannot borrow-check.
    #[allow(clippy::needless_range_loop)]
    fn plan_segments(&mut self, now: u64, req: ReqId, inst: usize) -> Option<SegmentPlan> {
        let version = self.cfg.segment.version;
        let st = self.requests.get_mut(req)?;
        if st.cands.is_empty() {
            return None;
        }
        // Fault plane: segment-production abort — the pass prices as if
        // its candidate plan failed wholesale (no pins, no productions,
        // no reuse).  Non-retryable and pricing-only: the request's ψ
        // outcome is untouched.
        let rid = st.rid;
        if self.faults.resolve(FaultKind::SegAbort, rid) == FaultOutcome::Failed {
            st.cands.clear();
            if let Some(fl) = self.flight.as_mut() {
                fl.note_fault(now, rid, FaultKind::SegAbort.index() as u64, false);
            }
            return None;
        }
        let store = self.instances.get_mut(inst)?.segments.as_mut()?;
        let mut plan = SegmentPlan::default();
        for i in 0..st.cands.len() {
            let key = SegmentKey::new(st.cands[i], version).packed();
            match store.acquire(key, now) {
                SegmentAction::Reuse | SegmentAction::Promote => {
                    plan.reused += 1;
                    st.seg_pinned.push(key);
                }
                SegmentAction::Join => {
                    plan.joined += 1;
                    st.seg_pinned.push(key);
                }
                SegmentAction::Produce { ticket } => {
                    plan.produced += 1;
                    st.seg_pinned.push(key);
                    st.seg_produced.push((key, ticket));
                }
                SegmentAction::Bypass => plan.bypassed += 1,
            }
        }
        st.cands.clear();
        Some(plan)
    }

    /// The classified ψ was unusable at execution time (live engine only:
    /// e.g. the device buffer failed to materialise) — demote to a safe
    /// fallback so metrics reflect what actually ran.
    pub fn force_fallback(&mut self, now: u64, req: ReqId) {
        if let Some(st) = self.requests.get_mut(req) {
            st.outcome = CacheOutcome::Fallback;
            st.cached = false;
            if let Some(fl) = self.flight.as_mut() {
                fl.note_fallback(now, req.index(), 2);
            }
        }
    }

    /// Ranking finished: release the connection and the admitted
    /// live-cache slot, classify the spill lifecycle, and retire the
    /// request (its slab slot is recycled, buffers and all; the handle
    /// goes stale).  `kv_bytes` is this request's ψ footprint.
    pub fn on_rank_done(&mut self, now: u64, req: ReqId, kv_bytes: usize) -> Completion {
        let st = self.requests.get_mut(req).expect("completion for unknown request");
        let (rid, user, prefix_len, is_long, inst, admitted, cached, outcome, wait_us) = (
            st.rid,
            st.user,
            st.prefix_len,
            st.is_long,
            st.rank_instance,
            st.admitted,
            st.cached,
            st.outcome,
            st.wait_us,
        );
        let pre_instance = st.pre_instance;
        self.router.on_complete(inst);
        // Candidate-segment lifecycle: install what this pass produced
        // (waking up reuse for every request that joined), then release
        // each pin — at refcount 0 a segment becomes evictable but stays
        // readable until its TTL or capacity pressure reclaims it.  The
        // payload placeholder stands in for the segment KV the rank
        // execution materialised (the live rank kernel does not export
        // per-item KV slices; the decision plane is engine-shared either
        // way).
        if !st.seg_pinned.is_empty() {
            if let Some(store) = self.instances[inst].segments.as_mut() {
                for &(key, ticket) in &st.seg_produced {
                    store.complete(key, ticket, T::default());
                }
                for &key in &st.seg_pinned {
                    store.release(key);
                }
            }
        }
        self.requests.release(req);
        // Release the admitted live-cache slot — the unique pairing for
        // this request's admit: a signal-time overcommit already cleared
        // `st.admitted` (after its own `cancel_admit`), so the two
        // release sites are mutually exclusive per request.
        if admitted {
            if let Some(pre_inst) = pre_instance {
                if let Some(t) = self.triggers.get_mut(&pre_inst) {
                    t.release();
                }
            }
        }
        // The sliding window moves past a consumed ψ: freshly produced
        // caches are eligible for a DRAM spill (short-term reuse, off the
        // critical path); reloaded ψ is still resident in DRAM, so the
        // window slides immediately.
        let mut spill = None;
        if cached {
            let fresh =
                self.instances[inst].origin.get(user) == Some(&CacheOutcome::HbmHit);
            // Fault plane: spill loss models the D2H copy dying in
            // flight — the consumed ψ leaves HBM with no DRAM copy, the
            // exact path a non-fresh (reloaded) ψ already takes.  Keyed
            // on the completing request's rid; pricing/capacity only,
            // the request's own outcome is untouched.
            let lost = fresh
                && self.faults.resolve(FaultKind::SpillLoss, rid) == FaultOutcome::Failed;
            if lost {
                self.note_fault_spans(now, rid, FaultKind::SpillLoss, FaultOutcome::Failed);
            }
            if fresh && !lost {
                spill = Some(kv_bytes);
            } else {
                let ctl = &mut self.instances[inst];
                if ctl.cache.hbm().state_of(user) == Some(EntryState::Consumed) {
                    ctl.cache.hbm_mut().evict(user);
                    ctl.origin.remove(user);
                }
            }
        }
        if let Some(fl) = self.flight.as_mut() {
            fl.note_rank_done(
                now,
                req.index(),
                crate::metrics::outcome_index(outcome) as u64,
                wait_us,
            );
            if let Some(bytes) = spill {
                fl.note_spill_begin(now, rid, user, inst as u64, bytes as u64);
            }
        }
        Completion {
            user,
            prefix_len,
            is_long,
            instance: inst,
            admitted,
            cached,
            outcome,
            wait_us,
            spill,
        }
    }

    /// Spill a freshly produced ψ to DRAM (host supplies the host-memory
    /// copy).  Returns whether the spill was accepted — only then does
    /// the HBM window slide past the consumed entry; otherwise it stays
    /// `Consumed` until its lifecycle expires (probe-time reclamation).
    pub fn complete_spill(
        &mut self,
        now: u64,
        instance: usize,
        user: u64,
        bytes: usize,
        payload: T,
    ) -> bool {
        let ctl = &mut self.instances[instance];
        let accepted = ctl.cache.spill(user, bytes, payload);
        if accepted && ctl.cache.hbm().state_of(user) == Some(EntryState::Consumed) {
            ctl.cache.hbm_mut().evict(user);
            ctl.origin.remove(user);
        }
        if let Some(fl) = self.flight.as_mut() {
            fl.note_spill_end(now, user, accepted, bytes as u64);
        }
        accepted
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::relay::router::BalancePolicy;
    use crate::relay::tier::{DramPolicy, EvictPolicy};

    fn config(mode: Mode) -> CoordinatorConfig {
        CoordinatorConfig {
            mode,
            router: RouterConfig {
                n_instances: 4,
                servers: 2,
                r2: 0.5,
                max_special_per_server: 1,
                gateways: 2,
                vnodes: 16,
                normal_policy: BalancePolicy::LeastConnections,
            },
            trigger: TriggerConfig::paper_example(),
            tiers: vec![TierConfig::new(1 << 30, EvictPolicy::Lru)],
            long_threshold: 2048,
            t_life_us: 300_000,
            max_reload_concurrency: 2,
            hbm_bytes: 1 << 30,
            dim: 256,
            kv_bytes: Box::new(|_| 32 << 20),
            segment: SegmentConfig::disabled(),
            batch_window_us: 0,
            batch_max: 32,
            trace_spans: 0,
            faults: FaultConfig::default(),
        }
    }

    fn coord(mode: Mode) -> RelayCoordinator<u32> {
        RelayCoordinator::new(config(mode), |_| Box::new(|_: &BehaviorMeta| 1e9)).unwrap()
    }

    /// Drive one request end to end with an instantly-completing host.
    fn drive(c: &mut RelayCoordinator<u32>, now: u64, user: u64, prefix: usize) -> Completion {
        let (req, wants_trigger) = c.on_arrival(now, user, user, prefix, &[]);
        if wants_trigger {
            match c.on_trigger_check(now, req) {
                SignalAction::Produce { instance, user, .. } => {
                    let woken = c.on_psi_ready(now, instance, user, Some(7));
                    assert!(woken.is_empty(), "no rank request is waiting yet");
                }
                SignalAction::Reload { instance, user, bytes } => {
                    let res = c.on_reload_done(now, instance, user, Some(7), bytes);
                    assert!(res.installed);
                }
                SignalAction::None => {}
            }
        }
        c.on_stage_done(now, req, Stage::Retrieval);
        let inst = c.on_stage_done(now, req, Stage::Preproc).expect("rank instance routed");
        match c.on_rank_start(now, req) {
            RankAction::Proceed { .. } => {}
            RankAction::StartReload { bytes } => {
                c.on_reload_done(now, inst, user, Some(7), bytes);
            }
            RankAction::Wait | RankAction::WaitReload => {
                assert!(c.wait_resolved(req), "instant host should have resolved the wait");
            }
        }
        let rc = c.rank_compute(now, req);
        let done = c.on_rank_done(now, req, 32 << 20);
        if rc.cached {
            assert!(rc.payload.is_some());
        }
        if let Some(bytes) = done.spill {
            c.complete_spill(now, done.instance, done.user, bytes, 7);
        }
        done
    }

    #[test]
    fn baseline_mode_never_triggers_or_caches() {
        let mut c = coord(Mode::Baseline);
        for i in 0..20 {
            let done = drive(&mut c, i * 1000, i % 3, 4096);
            assert_eq!(done.outcome, CacheOutcome::FullInference);
            assert!(!done.admitted && !done.cached);
        }
        assert_eq!(c.trigger_stats().assessed, 0);
        assert_eq!(c.live_requests(), 0, "every request retired its slot");
    }

    #[test]
    fn relay_long_request_relays_and_spills() {
        let mut c = coord(Mode::RelayGr { dram: DramPolicy::Capacity(1 << 30) });
        let done = drive(&mut c, 0, 42, 4096);
        assert_eq!(done.outcome, CacheOutcome::HbmHit);
        assert!(done.admitted && done.cached && done.spill.is_some());
        // The spill landed in DRAM: a follow-up request reloads from it.
        let done2 = drive(&mut c, 500_000, 42, 4096);
        assert_eq!(done2.outcome, CacheOutcome::DramHit, "refresh must hit the DRAM tier");
        // Short request stays on the normal path.
        let done3 = drive(&mut c, 600_000, 99, 128);
        assert_eq!(done3.outcome, CacheOutcome::FullInference);
        assert!(!done3.admitted);
    }

    #[test]
    fn rank_waits_for_production_then_hits() {
        let mut c = coord(Mode::RelayGr { dram: DramPolicy::Disabled });
        let (req, wants) = c.on_arrival(0, 7, 7, 4096, &[]);
        assert!(wants);
        let SignalAction::Produce { instance, user, .. } = c.on_trigger_check(0, req) else {
            panic!("expected production");
        };
        c.on_stage_done(0, req, Stage::Preproc).unwrap();
        assert_eq!(c.on_rank_start(10, req), RankAction::Wait);
        assert!(!c.wait_resolved(req));
        let woken = c.on_psi_ready(5_000, instance, user, Some(3));
        assert_eq!(woken, vec![req]);
        assert!(c.wait_resolved(req) && c.is_cached(req));
        let rc = c.rank_compute(5_000, req);
        assert_eq!(rc.payload, Some(3));
        let done = c.on_rank_done(5_000, req, 1 << 20);
        assert_eq!(done.outcome, CacheOutcome::HbmHit);
        assert!((done.wait_us - 4_990.0).abs() < 1e-9);
    }

    #[test]
    fn failed_production_falls_back() {
        let mut c = coord(Mode::RelayGr { dram: DramPolicy::Disabled });
        let (req, wants) = c.on_arrival(0, 7, 7, 4096, &[]);
        assert!(wants);
        let SignalAction::Produce { instance, user, .. } = c.on_trigger_check(0, req) else {
            panic!("expected production");
        };
        c.on_stage_done(0, req, Stage::Preproc).unwrap();
        assert_eq!(c.on_rank_start(10, req), RankAction::Wait);
        let woken = c.on_psi_ready(2_000, instance, user, None);
        assert_eq!(woken, vec![req]);
        let rc = c.rank_compute(2_000, req);
        assert!(!rc.cached && rc.payload.is_none());
        let done = c.on_rank_done(2_000, req, 1 << 20);
        assert_eq!(done.outcome, CacheOutcome::Fallback);
        assert!(done.admitted, "fallback still counts as admitted");
    }

    #[test]
    fn wait_timeout_resolves_to_fallback_and_detaches() {
        let mut c = coord(Mode::RelayGr { dram: DramPolicy::Disabled });
        let (req, wants) = c.on_arrival(0, 7, 7, 4096, &[]);
        assert!(wants);
        let SignalAction::Produce { instance, user, .. } = c.on_trigger_check(0, req) else {
            panic!("expected production");
        };
        c.on_stage_done(0, req, Stage::Preproc).unwrap();
        assert_eq!(c.on_rank_start(10, req), RankAction::Wait);
        c.on_wait_timeout(200_010, req);
        assert!(c.wait_resolved(req));
        // Late production must not resurrect the timed-out request.
        let woken = c.on_psi_ready(300_000, instance, user, Some(3));
        assert!(woken.is_empty());
        let done = c.on_rank_done(300_000, req, 1 << 20);
        assert_eq!(done.outcome, CacheOutcome::Fallback);
        assert!((done.wait_us - 200_000.0).abs() < 1e-9);
    }

    #[test]
    fn stale_handle_misses_after_slot_recycled() {
        let mut c = coord(Mode::RelayGr { dram: DramPolicy::Disabled });
        let (old, _) = c.on_arrival(0, 1, 7, 4096, &[]);
        c.on_stage_done(0, old, Stage::Preproc).unwrap();
        let _ = c.on_rank_start(0, old);
        let _ = c.rank_compute(0, old);
        c.on_rank_done(0, old, 1 << 20);
        // The next arrival recycles the slot; the retired handle must
        // read as resolved/uncached rather than aliasing the new tenant.
        let (new, _) = c.on_arrival(10, 2, 9, 4096, &[]);
        assert_eq!(new.index(), old.index(), "slot recycled");
        assert_ne!(new, old);
        assert!(c.wait_resolved(old), "stale handle reads as resolved");
        assert!(!c.is_cached(old));
        assert!(!c.is_admitted(old));
        // A late timeout on the stale handle must not touch the new tenant.
        c.on_wait_timeout(20, old);
        assert!(!c.requests.get(new).unwrap().resolved);
        c.on_stage_done(20, new, Stage::Preproc).unwrap();
        let _ = c.on_rank_start(20, new);
        let _ = c.rank_compute(20, new);
        c.on_rank_done(20, new, 1 << 20);
        assert_eq!(c.live_requests(), 0);
    }

    #[test]
    fn admitted_slot_released_exactly_once() {
        let mut c = coord(Mode::RelayGr { dram: DramPolicy::Disabled });
        // Same user repeatedly: later admits find ψ resident (no new
        // cache produced) but every admit's slot must be held for the
        // request lifecycle and freed exactly once at completion —
        // otherwise the Eq. 2 footprint bound stops binding.
        for i in 0..6u64 {
            let now = i * 10_000;
            let (req, wants) = c.on_arrival(now, i, 7, 4096, &[]);
            assert!(wants);
            match c.on_trigger_check(now, req) {
                SignalAction::Produce { instance, user, .. } => {
                    c.on_psi_ready(now, instance, user, Some(1));
                }
                SignalAction::None => {}
                other => panic!("unexpected signal action {other:?}"),
            }
            assert_eq!(c.trigger_live(), 1, "admit {i} holds one slot in flight");
            c.on_stage_done(now, req, Stage::Preproc).unwrap();
            let _ = c.on_rank_start(now, req);
            let _ = c.rank_compute(now, req);
            let done = c.on_rank_done(now, req, 32 << 20);
            assert!(done.admitted);
            assert_eq!(c.trigger_live(), 0, "admit {i} freed exactly once at completion");
        }
    }

    #[test]
    fn joined_reload_classification() {
        let mut c = coord(Mode::RelayGr { dram: DramPolicy::Capacity(1 << 30) });
        // Seed DRAM for user 5 on its special instance via a full cycle.
        let first = drive(&mut c, 0, 5, 4096);
        assert!(first.spill.is_some());
        // Two refresh requests race: the first starts the reload, the
        // second joins it.
        let (r2, _) = c.on_arrival(400_000, 2, 5, 4096, &[]);
        let (r3, _) = c.on_arrival(400_000, 3, 5, 4096, &[]);
        // Skip admission (signal may be delayed): rank requests front
        // the reload themselves (out-of-order arrival, §3.4).
        let inst2 = c.on_stage_done(400_000, r2, Stage::Preproc).unwrap();
        c.on_stage_done(400_000, r3, Stage::Preproc).unwrap();
        let a = c.on_rank_start(400_000, r2);
        let RankAction::StartReload { bytes } = a else { panic!("expected StartReload, got {a:?}") };
        assert_eq!(c.on_rank_start(400_001, r3), RankAction::WaitReload);
        let res = c.on_reload_done(400_500, inst2, 5, Some(9), bytes);
        assert!(res.installed);
        let mut woken = res.woken;
        woken.sort_unstable();
        let mut expect = vec![r2, r3];
        expect.sort_unstable();
        assert_eq!(woken, expect);
        let d2 = c.on_rank_done(400_500, r2, bytes);
        let d3 = c.on_rank_done(400_500, r3, bytes);
        assert_eq!(d2.outcome, CacheOutcome::DramHit);
        assert_eq!(d3.outcome, CacheOutcome::JoinedReload);
    }

    /// Tentpole: a misprovisioned worst-case `kv_p99` (larger than the
    /// r1·HBM slice ⇒ static `L_max = 0`) starves the relay path, while
    /// the adaptive controller admits against observed footprints — same
    /// coordinator, both engines inherit the policy.
    #[test]
    fn adaptive_admission_beats_collapsed_static_bound() {
        use crate::relay::trigger::AdmissionConfig;
        let run = |adaptive: bool| {
            let mut cfg = config(Mode::RelayGr { dram: DramPolicy::Disabled });
            // Provisioned P99 ψ (32 GB) exceeds the 16 GB r1 slice.
            cfg.trigger.kv_p99_bytes = 32_000_000_000;
            assert_eq!(cfg.trigger.limits().l_max, 0);
            if adaptive {
                cfg.trigger.admission = AdmissionConfig::adaptive();
            }
            let mut c: RelayCoordinator<u32> =
                RelayCoordinator::new(cfg, |_| Box::new(|_: &BehaviorMeta| 1e9)).unwrap();
            let done = drive(&mut c, 0, 42, 4096);
            (done, c.trigger_stats())
        };
        let (stat_done, stat_s) = run(false);
        assert_eq!(stat_done.outcome, CacheOutcome::FullInference);
        assert!(!stat_done.admitted);
        assert_eq!((stat_s.admitted, stat_s.footprint_limited), (0, 1));
        let (adapt_done, adapt_s) = run(true);
        assert_eq!(adapt_done.outcome, CacheOutcome::HbmHit, "observed 32 MB ψ fits");
        assert!(adapt_done.admitted);
        assert_eq!((adapt_s.admitted, adapt_s.footprint_limited), (1, 0));
        assert!(adapt_s.l_max_effective > 0, "occupancy-aware bound reported");
    }

    /// A signal-time HBM overcommit under adaptive admission cancels the
    /// admit cleanly: slot and windowed footprint reservation both come
    /// back, and the release ledger stays balanced (no double release at
    /// completion, no spurious release).
    #[test]
    fn adaptive_overcommit_cancels_slot_and_footprint() {
        use crate::relay::trigger::AdmissionConfig;
        let mut cfg = config(Mode::RelayGr { dram: DramPolicy::Disabled });
        cfg.trigger.admission = AdmissionConfig::adaptive();
        // The ψ window is half the 1 GB instance slice (segment carve),
        // while admission plans against the full trigger slice — the
        // deliberate PR 3 mismatch that exercises the overcommit path.
        cfg.segment = SegmentConfig { frac: 0.5, ..SegmentConfig::disabled() };
        cfg.kv_bytes = Box::new(|_| 300 << 20);
        let mut c: RelayCoordinator<u32> =
            RelayCoordinator::new(cfg, |_| Box::new(|_: &BehaviorMeta| 1e9)).unwrap();
        // Request 1 produces 300 MB into the 512 MB window.
        let (r1, wants) = c.on_arrival(0, 1, 7, 4096, &[]);
        assert!(wants);
        let SignalAction::Produce { instance, user, .. } = c.on_trigger_check(0, r1) else {
            panic!("first admit produces");
        };
        c.on_psi_ready(0, instance, user, Some(1));
        // Request 2 (another user) also admits at the trigger, which
        // plans against the full 1 GB slice.  If consistent hashing
        // lands it on request 1's instance, its `begin_produce` finds
        // only 212 MB free in the carved-down window and the admit is
        // cancelled; on the other special instance it produces cleanly.
        // Both paths must leave the ledger balanced.
        let (r2, wants2) = c.on_arrival(10, 2, 7 + (1 << 40), 4096, &[]);
        assert!(wants2);
        let act = c.on_trigger_check(10, r2);
        match act {
            SignalAction::None => {
                // Overcommit on the rendezvous instance: cancelled admit.
                assert!(!c.is_admitted(r2), "cancelled admit is not admitted");
            }
            SignalAction::Produce { instance: i2, user: u2, .. } => {
                // Landed on a different special instance with a free
                // window: complete it; the ledger must still balance.
                c.on_psi_ready(10, i2, u2, Some(2));
            }
            other => panic!("unexpected action {other:?}"),
        }
        for req in [r1, r2] {
            c.on_stage_done(20, req, Stage::Preproc).unwrap();
            let _ = c.on_rank_start(20, req);
            let _ = c.rank_compute(20, req);
            c.on_rank_done(20, req, 300 << 20);
        }
        let s = c.trigger_stats();
        assert_eq!(c.trigger_live(), 0, "all slots returned");
        assert_eq!(s.spurious_release, 0, "ledger balanced: {s:?}");
        assert_eq!(s.admitted, s.released, "every admit released exactly once");
    }

    fn seg_config() -> CoordinatorConfig {
        let mut cfg = config(Mode::RelayGr { dram: DramPolicy::Disabled });
        cfg.segment =
            SegmentConfig { frac: 0.25, ..SegmentConfig::disabled() };
        cfg
    }

    /// Drive one request with candidates through the full event flow.
    fn drive_with_cands(
        c: &mut RelayCoordinator<u32>,
        now: u64,
        user: u64,
        cands: &[u64],
    ) -> (Completion, Option<SegmentPlan>) {
        let (req, wants_trigger) = c.on_arrival(now, user, user, 4096, cands);
        if wants_trigger {
            if let SignalAction::Produce { instance, user, .. } = c.on_trigger_check(now, req) {
                c.on_psi_ready(now, instance, user, Some(7));
            }
        }
        c.on_stage_done(now, req, Stage::Preproc).unwrap();
        let _ = c.on_rank_start(now, req);
        let rc = c.rank_compute(now, req);
        let done = c.on_rank_done(now, req, 32 << 20);
        (done, rc.segments)
    }

    #[test]
    fn segment_partition_carved_out_of_r1() {
        let c: RelayCoordinator<u32> =
            RelayCoordinator::new(seg_config(), |_| Box::new(|_: &BehaviorMeta| 1e9)).unwrap();
        assert!(c.segments_enabled());
        // 25% of the 1 GB slice goes to segments; the ψ window keeps 75%.
        let inst = &c.instances[0];
        assert_eq!(inst.cache.hbm().capacity_bytes(), (1usize << 30) - (1usize << 28));
        assert_eq!(inst.segments.as_ref().unwrap().used_bytes(), 0);
        // Disabled config: full slice to ψ, no store, no planning.
        let off = coord(Mode::RelayGr { dram: DramPolicy::Disabled });
        assert!(!off.segments_enabled());
        assert_eq!(off.instances[0].cache.hbm().capacity_bytes(), 1 << 30);
        assert!(off.instances[0].segments.is_none());
    }

    #[test]
    fn first_ranker_produces_next_reuses_across_users() {
        let mut c: RelayCoordinator<u32> =
            RelayCoordinator::new(seg_config(), |_| Box::new(|_: &BehaviorMeta| 1e9)).unwrap();
        // Different users sharing candidates — but segment reuse is
        // per-instance, so rendezvous the two requests on one instance
        // by using the same (affinity-hashed) user id.
        let (_, p1) = drive_with_cands(&mut c, 0, 42, &[10, 11, 12]);
        let p1 = p1.expect("segment plan present");
        assert_eq!((p1.produced, p1.reused, p1.joined), (3, 0, 0));
        let (_, p2) = drive_with_cands(&mut c, 1_000, 42, &[10, 11, 13]);
        let p2 = p2.expect("segment plan present");
        assert_eq!((p2.reused, p2.produced), (2, 1), "overlap reused, novelty produced");
        let s = c.segment_stats();
        assert_eq!((s.produced, s.reused), (4, 2));
        assert_eq!(s.bytes_saved, 2 * c.cfg.segment.seg_bytes as u64);
        assert!(s.hit_ratio() > 0.3);
    }

    #[test]
    fn concurrent_requests_join_inflight_segment_production() {
        let mut c: RelayCoordinator<u32> =
            RelayCoordinator::new(seg_config(), |_| Box::new(|_: &BehaviorMeta| 1e9)).unwrap();
        // Two requests overlap in time: both pass rank_compute before
        // either completes — the second joins the first's production.
        let mut reqs = Vec::new();
        for _ in 0..2 {
            let (req, wants) = c.on_arrival(0, 42, 42, 4096, &[77]);
            assert!(wants);
            if let SignalAction::Produce { instance, user, .. } = c.on_trigger_check(0, req) {
                c.on_psi_ready(0, instance, user, Some(7));
            }
            c.on_stage_done(0, req, Stage::Preproc).unwrap();
            let _ = c.on_rank_start(0, req);
            reqs.push(req);
        }
        let r1 = c.rank_compute(0, reqs[0]).segments.unwrap();
        let r2 = c.rank_compute(0, reqs[1]).segments.unwrap();
        assert_eq!(r1.produced, 1);
        assert_eq!(r2.joined, 1, "dedup: one compute for both requests");
        c.on_rank_done(10, reqs[0], 32 << 20);
        c.on_rank_done(10, reqs[1], 32 << 20);
        // The installed segment now serves later requests directly.
        let (_, p3) = drive_with_cands(&mut c, 1_000, 42, &[77]);
        assert_eq!(p3.unwrap().reused, 1);
        assert_eq!(c.segment_stats().joined, 1);
    }

    #[test]
    fn model_version_bump_rotates_segment_keys() {
        let mut c: RelayCoordinator<u32> =
            RelayCoordinator::new(seg_config(), |_| Box::new(|_: &BehaviorMeta| 1e9)).unwrap();
        let (_, p1) = drive_with_cands(&mut c, 0, 42, &[5]);
        assert_eq!(p1.unwrap().produced, 1);
        let (_, p2) = drive_with_cands(&mut c, 100, 42, &[5]);
        assert_eq!(p2.unwrap().reused, 1);
        // Model push: the same item must be re-produced under the new key.
        c.set_model_version(1);
        let (_, p3) = drive_with_cands(&mut c, 200, 42, &[5]);
        assert_eq!(p3.unwrap().produced, 1, "stale-version segment must not match");
    }

    fn batch_config(window_us: u64, max: usize) -> CoordinatorConfig {
        let mut cfg = config(Mode::RelayGr { dram: DramPolicy::Disabled });
        cfg.batch_window_us = window_us;
        cfg.batch_max = max;
        cfg
    }

    /// Bring one request to the rank-ready point (classified, resolved)
    /// and return its handle + instance.
    fn rank_ready(c: &mut RelayCoordinator<u32>, now: u64, user: u64) -> (ReqId, usize) {
        let (req, wants) = c.on_arrival(now, user, user, 4096, &[]);
        if wants {
            if let SignalAction::Produce { instance, user, .. } = c.on_trigger_check(now, req) {
                c.on_psi_ready(now, instance, user, Some(7));
            }
        }
        let inst = c.on_stage_done(now, req, Stage::Preproc).unwrap();
        let _ = c.on_rank_start(now, req);
        (req, inst)
    }

    #[test]
    fn window_zero_offer_is_solo_and_touches_no_batch_state() {
        let mut c = coord(Mode::RelayGr { dram: DramPolicy::Disabled });
        for i in 0..8u64 {
            let (req, inst) = rank_ready(&mut c, i * 1_000, i);
            assert_eq!(c.offer_rank(i * 1_000, req), BatchDecision::Solo);
            assert_eq!(c.instances[inst].batch.gen, 0, "window 0 never opens a batch");
            assert!(c.instances[inst].batch.members.is_empty());
            let _ = c.rank_compute(i * 1_000, req);
            c.on_rank_done(i * 1_000, req, 1 << 20);
        }
        assert_eq!(c.live_requests(), 0);
    }

    /// Property: every offered rank pass lands in exactly one batch —
    /// drained by exactly one successful `close_batch` — regardless of
    /// how window flushes and `Filled` flushes interleave.
    #[test]
    fn every_offered_pass_lands_in_exactly_one_batch() {
        let mut c: RelayCoordinator<u32> =
            RelayCoordinator::new(batch_config(500, 3), |_| Box::new(|_: &BehaviorMeta| 1e9))
                .unwrap();
        // (deadline, inst, gen) flushes pending, in open order.
        let mut pending: Vec<(u64, usize, u64)> = Vec::new();
        let mut offered: Vec<ReqId> = Vec::new();
        let mut drained: Vec<ReqId> = Vec::new();
        let mut buf: Vec<ReqId> = Vec::new();
        let mut flushes = 0;
        for i in 0..40u64 {
            let now = i * 137; // several arrivals per 500 µs window
            // Window-deadline flushes due before this offer fire first.
            while pending.first().is_some_and(|&(d, _, _)| d <= now) {
                let (d, inst, gen) = pending.remove(0);
                if c.close_batch(d, inst, gen, &mut buf) {
                    flushes += 1;
                    for &r in &buf {
                        let _ = c.rank_compute(d, r);
                        drained.push(r);
                        c.on_rank_done(d, r, 1 << 20);
                    }
                }
            }
            let (req, inst) = rank_ready(&mut c, now, 42); // one rendezvous instance
            offered.push(req);
            match c.offer_rank(now, req) {
                BatchDecision::Solo => panic!("window > 0 must not answer Solo"),
                BatchDecision::Opened { deadline, gen } => {
                    assert_eq!(deadline, now + 500);
                    pending.push((deadline, inst, gen));
                }
                BatchDecision::Joined => {}
                BatchDecision::Filled { gen } => {
                    assert!(c.close_batch(now, inst, gen, &mut buf), "filled batch drains");
                    flushes += 1;
                    assert_eq!(buf.len(), 3, "filled at batch_max");
                    for &r in &buf {
                        let _ = c.rank_compute(now, r);
                        drained.push(r);
                        c.on_rank_done(now, r, 1 << 20);
                    }
                }
            }
        }
        for (d, inst, gen) in pending.drain(..) {
            if c.close_batch(d, inst, gen, &mut buf) {
                flushes += 1;
                for &r in &buf {
                    let _ = c.rank_compute(d, r);
                    drained.push(r);
                    c.on_rank_done(d, r, 1 << 20);
                }
            }
        }
        // Exactly-once: same passes, same multiplicity, nothing left over.
        let mut o = offered.clone();
        let mut g = drained.clone();
        o.sort_unstable();
        g.sort_unstable();
        assert_eq!(o, g, "every offered pass drained exactly once");
        assert!(flushes > offered.len() / 3, "both Filled and deadline flushes occurred");
        assert_eq!(c.live_requests(), 0);
    }

    #[test]
    fn filled_flush_makes_the_deadline_timer_stale() {
        let mut c: RelayCoordinator<u32> =
            RelayCoordinator::new(batch_config(1_000, 2), |_| Box::new(|_: &BehaviorMeta| 1e9))
                .unwrap();
        let (r1, inst) = rank_ready(&mut c, 0, 42);
        let BatchDecision::Opened { deadline, gen } = c.offer_rank(0, r1) else {
            panic!("first offer opens");
        };
        assert_eq!(deadline, 1_000);
        assert!(c.batch_open(inst, gen));
        let (r2, _) = rank_ready(&mut c, 10, 42);
        assert_eq!(c.offer_rank(10, r2), BatchDecision::Filled { gen });
        assert!(!c.batch_open(inst, gen), "filled batch is no longer open");
        let mut buf = Vec::new();
        assert!(c.close_batch(10, inst, gen, &mut buf));
        assert_eq!(buf.len(), 2);
        for &r in &buf {
            let _ = c.rank_compute(10, r);
            c.on_rank_done(10, r, 1 << 20);
        }
        // The deadline timer fires later: its generation is stale.
        assert!(
            !c.close_batch(1_000, inst, gen, &mut buf),
            "deadline flush after Filled is a no-op"
        );
        assert!(buf.is_empty());
        // The next offer opens a fresh generation.
        let (r3, _) = rank_ready(&mut c, 2_000, 42);
        let BatchDecision::Opened { gen: gen2, .. } = c.offer_rank(2_000, r3) else {
            panic!("fresh batch opens");
        };
        assert_eq!(gen2, gen + 1);
        assert!(c.close_batch(2_000, inst, gen2, &mut buf));
        assert_eq!(buf, vec![r3]);
        let _ = c.rank_compute(2_100, r3);
        c.on_rank_done(2_100, r3, 1 << 20);
        assert_eq!(c.live_requests(), 0);
    }

    /// Tentpole: co-batched duplicates of the same segment key plan as
    /// one `Produce` + joins, because the whole batch runs
    /// `rank_compute` before any member's `on_rank_done` installs.
    #[test]
    fn co_batched_duplicate_segments_produce_once() {
        let mut cfg = seg_config();
        cfg.batch_window_us = 1_000;
        cfg.batch_max = 4;
        let mut c: RelayCoordinator<u32> =
            RelayCoordinator::new(cfg, |_| Box::new(|_: &BehaviorMeta| 1e9)).unwrap();
        let mut inst = 0;
        let mut last = BatchDecision::Solo;
        for _ in 0..3 {
            let (req, wants) = c.on_arrival(0, 42, 42, 4096, &[10, 11]);
            if wants {
                if let SignalAction::Produce { instance, user, .. } = c.on_trigger_check(0, req) {
                    c.on_psi_ready(0, instance, user, Some(7));
                }
            }
            inst = c.on_stage_done(0, req, Stage::Preproc).unwrap();
            let _ = c.on_rank_start(0, req);
            last = c.offer_rank(0, req);
        }
        assert_eq!(last, BatchDecision::Joined, "3 members under batch_max 4 stay open");
        let gen = c.instances[inst].batch.gen;
        assert!(c.batch_open(inst, gen));
        let mut buf = Vec::new();
        // Deadline flush at window close.
        assert!(c.close_batch(1_000, inst, gen, &mut buf));
        assert_eq!(buf.len(), 3);
        let mut produced = 0;
        let mut joined = 0;
        let mut reused = 0;
        for &r in &buf {
            let plan = c.rank_compute(1_000, r).segments.expect("plan present");
            produced += plan.produced;
            joined += plan.joined;
            reused += plan.reused;
        }
        // 2 distinct keys × 3 members: one Produce per key, the
        // co-batched duplicates join — not N independent productions.
        assert_eq!((produced, joined, reused), (2, 4, 0));
        for &r in &buf {
            c.on_rank_done(1_000, r, 1 << 20);
        }
        // Pins installed/released exactly once per member: the store's
        // refcounts are back to zero and the segments serve reuse now.
        let (_, p) = drive_with_cands(&mut c, 2_000, 42, &[10, 11]);
        assert_eq!(p.unwrap().reused, 2);
        assert_eq!(c.live_requests(), 0);
    }

    /// Tentpole: with tracing on, a full relay lifecycle emits a span
    /// stream whose reconstructed timeline telescopes to the request's
    /// e2e latency, and `take_flight` detaches the recorder (stage
    /// breakdown included) exactly once.
    #[test]
    fn flight_recorder_traces_full_lifecycle_and_telescopes() {
        use crate::relay::flight::timeline;
        let mut cfg = config(Mode::RelayGr { dram: DramPolicy::Capacity(1 << 30) });
        cfg.trace_spans = 4096;
        let mut c: RelayCoordinator<u32> =
            RelayCoordinator::new(cfg, |_| Box::new(|_: &BehaviorMeta| 1e9)).unwrap();
        let done = drive(&mut c, 0, 42, 4096);
        assert_eq!(done.outcome, CacheOutcome::HbmHit);
        let fl = c.take_flight().expect("recorder constructed when trace_spans > 0");
        assert!(c.take_flight().is_none(), "recorder detaches once");
        let spans = fl.spans_sorted();
        assert!(spans.iter().any(|s| s.kind == SpanKind::Arrival && s.rid == 42));
        assert!(spans.iter().any(|s| s.kind == SpanKind::TriggerDecision
            && s.a == trigger_reason::ADMIT));
        assert!(spans.iter().any(|s| s.kind == SpanKind::RankDone));
        assert!(spans.iter().any(|s| s.kind == SpanKind::SpillEnd), "spill recorded end-to-end");
        let tl = timeline(&spans, 42).expect("request reconstructed from its spans");
        let total: u64 = tl.stages.iter().map(|&(_, d)| d).sum();
        assert_eq!(total, tl.e2e_us(), "stage durations telescope to e2e");
        assert_eq!(tl.outcome, Some(crate::metrics::outcome_index(CacheOutcome::HbmHit)));
        assert_eq!(fl.breakdown.admission.count(), 1, "admission interval folded");
    }

    fn fault_config(mode: Mode, spec: &str) -> CoordinatorConfig {
        let mut cfg = config(mode);
        cfg.faults = FaultConfig::parse(spec).unwrap();
        cfg
    }

    fn fault_coord(mode: Mode, spec: &str) -> RelayCoordinator<u32> {
        RelayCoordinator::new(fault_config(mode, spec), |_| Box::new(|_: &BehaviorMeta| 1e9))
            .unwrap()
    }

    /// Tentpole: a dropped trigger signal means the side path never
    /// fires — the request is never admitted and pays full inference at
    /// ranking (the `figure faults` headline signal).
    #[test]
    fn dropped_trigger_signal_pays_full_inference() {
        let mut c =
            fault_coord(Mode::RelayGr { dram: DramPolicy::Disabled }, "trigger-drop:1");
        let done = drive(&mut c, 0, 42, 4096);
        assert_eq!(done.outcome, CacheOutcome::FullInference);
        assert!(!done.admitted, "dropped signal never admits");
        assert_eq!(c.fault_report().injected[FaultKind::TriggerDrop.index()], 1);
        assert_eq!(c.trigger_stats().assessed, 0, "risk test never ran");
        assert_eq!(c.trigger_live(), 0);
        assert_eq!(c.live_requests(), 0);
    }

    /// Tentpole: a production doomed at signal time completes down the
    /// shared failure path; the waiting rank request takes the
    /// degradation ladder (Fallback, or Shed under shed pressure), and
    /// the admitted slot still releases exactly once.
    #[test]
    fn doomed_production_degrades_waiter_and_balances_ledger() {
        for (spec, want) in [
            ("psi-fail:1", CacheOutcome::Fallback),
            ("psi-fail:1,shed:1", CacheOutcome::Shed),
        ] {
            let mut c = fault_coord(Mode::RelayGr { dram: DramPolicy::Disabled }, spec);
            let (req, wants) = c.on_arrival(0, 7, 7, 4096, &[]);
            assert!(wants);
            let SignalAction::Produce { instance, user, .. } = c.on_trigger_check(0, req)
            else {
                panic!("expected production");
            };
            c.on_stage_done(0, req, Stage::Preproc).unwrap();
            assert_eq!(c.on_rank_start(10, req), RankAction::Wait);
            // The host delivers a payload, but the plan doomed it.
            let woken = c.on_psi_ready(2_000, instance, user, Some(3));
            assert_eq!(woken, vec![req]);
            let rc = c.rank_compute(2_000, req);
            assert!(!rc.cached && rc.payload.is_none());
            let done = c.on_rank_done(2_000, req, 1 << 20);
            assert_eq!(done.outcome, want, "{spec}");
            assert!(done.admitted, "ladder outcomes still count as admitted");
            let r = c.fault_report();
            let k = FaultKind::PsiFail.index();
            assert_eq!(r.injected[k], 1, "{spec}");
            if want == CacheOutcome::Shed {
                assert_eq!((r.shed[k], r.degraded[k]), (1, 0), "{spec}");
            } else {
                assert_eq!((r.shed[k], r.degraded[k]), (0, 1), "{spec}");
            }
            assert_eq!(c.trigger_live(), 0, "admit released exactly once");
            assert_eq!(c.trigger_stats().spurious_release, 0);
            assert_eq!(c.live_requests(), 0);
        }
    }

    #[test]
    fn reload_fault_converts_delivered_payload_to_fallback() {
        let mut c =
            fault_coord(Mode::RelayGr { dram: DramPolicy::Capacity(1 << 30) }, "reload-fail:1");
        // Seed DRAM via a full produce→spill cycle (no reload drawn yet).
        let first = drive(&mut c, 0, 5, 4096);
        assert_eq!(first.outcome, CacheOutcome::HbmHit);
        assert!(first.spill.is_some());
        // The refresh starts a rank-side reload; the host delivers the
        // payload but the fault plane drops it in flight.
        let (r2, _) = c.on_arrival(400_000, 2, 5, 4096, &[]);
        let inst2 = c.on_stage_done(400_000, r2, Stage::Preproc).unwrap();
        let a = c.on_rank_start(400_000, r2);
        let RankAction::StartReload { bytes } = a else {
            panic!("expected StartReload, got {a:?}")
        };
        let res = c.on_reload_done(400_500, inst2, 5, Some(9), bytes);
        assert!(!res.installed, "fault plane dropped the delivered payload");
        assert_eq!(res.woken, vec![r2]);
        let rc = c.rank_compute(400_500, r2);
        assert!(!rc.cached && rc.payload.is_none());
        let done = c.on_rank_done(400_500, r2, bytes);
        assert_eq!(done.outcome, CacheOutcome::Fallback);
        let r = c.fault_report();
        let k = FaultKind::ReloadFail.index();
        assert_eq!((r.injected[k], r.degraded[k]), (1, 1));
        assert_eq!(c.live_requests(), 0);
    }

    #[test]
    fn spill_loss_drops_the_dram_copy_and_slides_the_window() {
        let mut c =
            fault_coord(Mode::RelayGr { dram: DramPolicy::Capacity(1 << 30) }, "spill-loss:1");
        let done = drive(&mut c, 0, 42, 4096);
        assert_eq!(done.outcome, CacheOutcome::HbmHit, "outcome untouched by spill loss");
        assert!(done.spill.is_none(), "spill lost in flight");
        // No DRAM copy landed and the consumed entry slid out of the
        // window: the refresh must re-produce, not reload.
        let (r2, wants) = c.on_arrival(500_000, 2, 42, 4096, &[]);
        assert!(wants);
        let act = c.on_trigger_check(500_000, r2);
        assert!(matches!(act, SignalAction::Produce { .. }), "no DRAM copy to reload: {act:?}");
        if let SignalAction::Produce { instance, user, .. } = act {
            c.on_psi_ready(500_000, instance, user, Some(9));
        }
        c.on_stage_done(500_000, r2, Stage::Preproc).unwrap();
        let _ = c.on_rank_start(500_000, r2);
        let _ = c.rank_compute(500_000, r2);
        c.on_rank_done(500_000, r2, 32 << 20);
        assert!(c.fault_report().injected[FaultKind::SpillLoss.index()] >= 1);
        assert_eq!(c.live_requests(), 0);
    }

    #[test]
    fn seg_abort_prices_the_pass_without_touching_psi_outcome() {
        let mut cfg = seg_config();
        cfg.faults = FaultConfig::parse("seg-abort:1").unwrap();
        let mut c: RelayCoordinator<u32> =
            RelayCoordinator::new(cfg, |_| Box::new(|_: &BehaviorMeta| 1e9)).unwrap();
        let (done, plan) = drive_with_cands(&mut c, 0, 42, &[10, 11, 12]);
        assert!(plan.is_none(), "aborted pass carries no segment plan");
        assert_eq!(done.outcome, CacheOutcome::HbmHit, "ψ outcome untouched");
        assert_eq!(c.segment_stats().lookups, 0, "no pins, no productions");
        let r = c.fault_report();
        let k = FaultKind::SegAbort.index();
        assert_eq!(r.injected[k], 1);
        assert_eq!(r.degraded[k] + r.shed[k], 0, "pricing-only: no ladder");
        assert_eq!(c.live_requests(), 0);
    }

    #[test]
    fn retry_budget_priced_into_admission_estimate() {
        let c = fault_coord(
            Mode::RelayGr { dram: DramPolicy::Disabled },
            "psi-fail:0.1,retry:3,backoff:100us",
        );
        assert_eq!(c.config().trigger.retry_budget_us, 700, "backoff·(2^3−1)");
        // All-off default folds nothing — fault-free pricing matches PR 9.
        let off = coord(Mode::RelayGr { dram: DramPolicy::Disabled });
        assert_eq!(off.config().trigger.retry_budget_us, 0);
    }

    #[test]
    fn fault_free_plan_draws_nothing() {
        let mut c = coord(Mode::RelayGr { dram: DramPolicy::Capacity(1 << 30) });
        for i in 0..10 {
            drive(&mut c, i * 10_000, i % 3, 4096);
        }
        assert!(!c.fault_report().any(), "all-off default never injects");
    }

    /// With tracing on, injected faults land in the span stream: the
    /// fault-injected, retry and degraded kinds appear with the right
    /// fault-kind operands.
    #[test]
    fn fault_spans_traced_when_recorder_on() {
        let mut cfg =
            fault_config(Mode::RelayGr { dram: DramPolicy::Disabled }, "psi-fail:1,shed:1");
        cfg.trace_spans = 4096;
        let mut c: RelayCoordinator<u32> =
            RelayCoordinator::new(cfg, |_| Box::new(|_: &BehaviorMeta| 1e9)).unwrap();
        let (req, _) = c.on_arrival(0, 7, 7, 4096, &[]);
        let SignalAction::Produce { instance, user, .. } = c.on_trigger_check(0, req) else {
            panic!("expected production");
        };
        c.on_stage_done(0, req, Stage::Preproc).unwrap();
        assert_eq!(c.on_rank_start(10, req), RankAction::Wait);
        c.on_psi_ready(2_000, instance, user, Some(3));
        let _ = c.rank_compute(2_000, req);
        let done = c.on_rank_done(2_000, req, 1 << 20);
        assert_eq!(done.outcome, CacheOutcome::Shed);
        let fl = c.take_flight().unwrap();
        let spans = fl.spans_sorted();
        let kidx = FaultKind::PsiFail.index() as u64;
        assert!(spans
            .iter()
            .any(|s| s.kind == SpanKind::FaultInjected && s.a == kidx && s.b == 0));
        assert!(spans.iter().any(|s| s.kind == SpanKind::Degraded && s.a == kidx && s.b == 1));
    }

    #[test]
    fn segments_ignored_without_candidates_or_in_baseline() {
        let mut c: RelayCoordinator<u32> =
            RelayCoordinator::new(seg_config(), |_| Box::new(|_: &BehaviorMeta| 1e9)).unwrap();
        let (_, plan) = drive_with_cands(&mut c, 0, 42, &[]);
        assert!(plan.is_none(), "no candidates ⇒ no plan");
        assert_eq!(c.segment_stats().lookups, 0);
        // Baseline mode never builds a store even with frac set.
        let mut cfg = config(Mode::Baseline);
        cfg.segment = SegmentConfig { frac: 0.25, ..SegmentConfig::disabled() };
        let mut b: RelayCoordinator<u32> =
            RelayCoordinator::new(cfg, |_| Box::new(|_: &BehaviorMeta| 1e9)).unwrap();
        assert!(!b.segments_enabled());
        let (req, wants) = b.on_arrival(0, 7, 7, 4096, &[1, 2]);
        assert!(!wants);
        b.on_stage_done(0, req, Stage::Preproc).unwrap();
        let _ = b.on_rank_start(0, req);
        assert!(b.rank_compute(0, req).segments.is_none());
        b.on_rank_done(0, req, 1 << 20);
    }
}
