//! Coordinator-resident flight recorder: a zero-allocation, structured
//! per-request event trace of every relay-race lifecycle transition.
//!
//! The recorder is strictly **decision-observing**: it is consulted by no
//! decision path, feeds no policy, and a run with tracing on must be
//! decision-for-decision bit-identical to the same run with tracing off
//! (pinned by `tests/cross_engine.rs`).  It lives inside
//! [`RelayCoordinator`](crate::relay::coordinator::RelayCoordinator) — the
//! PR 1 invariant that all decisions flow through the coordinator means
//! all three engines (discrete-event sim, serialized reference, live
//! threaded) emit spans for free, each with its own clock.
//!
//! ## Span records
//!
//! Each lifecycle transition is one fixed-size [`Span`]: a global emission
//! ordinal (`ord`, the deterministic sort key), the host clock `t_us`, the
//! workload request id `rid`, a [`SpanKind`] tag and two operands whose
//! meaning depends on the kind (reason codes, instance ids, byte counts —
//! see the kind docs).  Spans land in pooled per-shard ring buffers
//! (sharded by `rid`, overwrite-oldest, bounded by `--trace-spans`), so
//! steady-state emission into a warm ring performs **zero allocations** —
//! asserted by `bench_hotpath` (`coordinator/trace_emit`).
//!
//! ## RGSP sidecar format (version 1)
//!
//! Retained spans serialize to a compact binary sidecar mirroring the
//! RGTR trace conventions (`workload/trace.rs`):
//!
//! ```text
//! magic "RGSP" | version u8 | span count u64 LE
//!   | varint trace_spans | varint emitted | varint dropped
//!   | records…
//! ```
//!
//! Each record is `varint Δord | zigzag-varint Δt_us | varint rid |
//! kind u8 | varint a | varint b`, with deltas against the previous
//! record in `ord` order (ords are strictly increasing; `t_us` is
//! near-monotone, so both deltas stay small).  **Extension recipe**
//! (mirrors RGTR's): new span kinds append to the [`SpanKind`] table with
//! the next free tag — readers skip unknown tags, so old tooling reads
//! new files; removing or renumbering a tag requires a version bump.
//!
//! ## Stage-latency breakdown
//!
//! Alongside the raw spans the recorder folds per-request stage durations
//! into [`StageBreakdown`] histograms (admission, ψ-wait, batch-wait,
//! rank-exec, spill) using a slot-indexed clock table keyed by the
//! coordinator's slab slots.  Engines copy the breakdown into
//! [`RunMetrics`](crate::metrics::RunMetrics) at end of run; `relaygr
//! figure breakdown` reports P50/P99 per stage × scenario × engine.

use std::collections::HashMap;

use anyhow::{bail, Context, Result};

use crate::util::stats::Histogram;
use crate::workload::trace::{put_varint, read_u8, read_varint};

/// Sentinel for "no instance / not applicable" operands.
pub const NONE_OPERAND: u64 = u64::MAX;

/// One lifecycle transition.  `a`/`b` are kind-specific operands (see
/// [`SpanKind`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Span {
    /// Global emission ordinal — the deterministic sort/merge key.
    pub ord: u64,
    /// Host-engine clock at emission (µs; virtual, arrival or wall).
    pub t_us: u64,
    /// Workload request id (`GenRequest::rid`), NOT the slab handle.
    pub rid: u64,
    pub kind: SpanKind,
    pub a: u64,
    pub b: u64,
}

/// Span tags.  Operand meaning per kind is listed as `a` / `b`.
///
/// Tags are append-only (see the module-level extension recipe).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum SpanKind {
    /// a = user, b = prefix_len.
    Arrival = 0,
    /// a = reason code ([`trigger_reason`]), b = signal instance (or
    /// [`NONE_OPERAND`]).
    TriggerDecision = 1,
    /// a = ψ lookup outcome ([`psi_action`]), b = side (0 signal, 1 rank).
    PsiLookup = 2,
    /// a = stage (0 signal/retrieval, 1 preproc→rank), b = instance.
    Route = 3,
    /// a = instance, b = ψ bytes (0 when unknown at begin).
    ProduceBegin = 4,
    /// a = instance, b = 1 installed / 0 failed.
    ProduceEnd = 5,
    /// a = rank action code ([`rank_action`]), b = instance.
    RankStart = 6,
    /// a = cause (0 ψ ready, 1 reload done, 2 timeout, 3 abort), b = wait µs.
    WaitResolved = 7,
    /// a = instance, b = bytes.
    ReloadBegin = 8,
    /// a = 1 installed / 0 failed-or-aborted, b = bytes.
    ReloadEnd = 9,
    /// a = instance, b = batch generation.
    BatchOpen = 10,
    /// a = instance, b = batch generation.
    BatchJoin = 11,
    /// a = instance, b = batch generation.
    BatchFilled = 12,
    /// a = instance, b = batch generation.
    BatchFlush = 13,
    /// a = instance, b = 0 (window 0 / unbatched pass).
    BatchSolo = 14,
    /// a = 1 cached / 0 full, b = reused segment count.
    ExecStart = 15,
    /// a = outcome index ([`crate::metrics::outcome_index`]), b = wait µs.
    RankDone = 16,
    /// a = cause (0 wait-budget, 1 reload-abort, 2 forced,
    /// 3 produce-failed, 4 admitted-miss), b = 0.
    Fallback = 17,
    /// a = instance, b = bytes.
    SpillBegin = 18,
    /// a = 1 accepted / 0 rejected, b = bytes.
    SpillEnd = 19,
    /// a = chosen cell, b = home (affinity) cell.
    CellRouted = 20,
    /// a = chosen cell, b = home (affinity) cell — emitted instead of
    /// [`SpanKind::CellRouted`] when the picker overrode the user's home
    /// cell (load spill, drain, failure eligibility).
    CellFailover = 21,
    /// Fault plane injected a fault at a decision point.  a = fault kind
    /// index ([`crate::relay::fault::FaultKind`]), b = 1 when the retry
    /// ladder later recovered it, 0 when it stuck.
    FaultInjected = 22,
    /// A deterministic retry attempt against an injected fault.  a =
    /// fault kind index, b = attempt number (1-based).
    RetryScheduled = 23,
    /// Degradation-ladder verdict for an unrecovered fault.  a = fault
    /// kind index, b = rung (0 degraded-to-fallback, 1 shed).
    Degraded = 24,
}

impl SpanKind {
    pub fn from_u8(tag: u8) -> Option<SpanKind> {
        use SpanKind::*;
        Some(match tag {
            0 => Arrival,
            1 => TriggerDecision,
            2 => PsiLookup,
            3 => Route,
            4 => ProduceBegin,
            5 => ProduceEnd,
            6 => RankStart,
            7 => WaitResolved,
            8 => ReloadBegin,
            9 => ReloadEnd,
            10 => BatchOpen,
            11 => BatchJoin,
            12 => BatchFilled,
            13 => BatchFlush,
            14 => BatchSolo,
            15 => ExecStart,
            16 => RankDone,
            17 => Fallback,
            18 => SpillBegin,
            19 => SpillEnd,
            20 => CellRouted,
            21 => CellFailover,
            22 => FaultInjected,
            23 => RetryScheduled,
            24 => Degraded,
            _ => return None,
        })
    }

    pub fn label(self) -> &'static str {
        use SpanKind::*;
        match self {
            Arrival => "arrival",
            TriggerDecision => "trigger",
            PsiLookup => "psi-lookup",
            Route => "route",
            ProduceBegin => "produce-begin",
            ProduceEnd => "produce-end",
            RankStart => "rank-start",
            WaitResolved => "wait-resolved",
            ReloadBegin => "reload-begin",
            ReloadEnd => "reload-end",
            BatchOpen => "batch-open",
            BatchJoin => "batch-join",
            BatchFilled => "batch-filled",
            BatchFlush => "batch-flush",
            BatchSolo => "batch-solo",
            ExecStart => "exec-start",
            RankDone => "rank-done",
            Fallback => "fallback",
            SpillBegin => "spill-begin",
            SpillEnd => "spill-end",
            CellRouted => "cell-routed",
            CellFailover => "cell-failover",
            FaultInjected => "fault-injected",
            RetryScheduled => "retry",
            Degraded => "degraded",
        }
    }

    /// The pipeline stage an interval *ending* at this span belongs to —
    /// the explain timeline's bucketing rule.  Intervals telescope, so
    /// whatever the labels, stage durations sum exactly to `done −
    /// arrival`.
    pub fn stage(self) -> &'static str {
        use SpanKind::*;
        match self {
            Arrival | CellRouted | CellFailover => "arrival",
            TriggerDecision | PsiLookup | Route | ProduceBegin | ProduceEnd | FaultInjected
            | RetryScheduled => "admission",
            RankStart => "rank-queue",
            WaitResolved | ReloadBegin | ReloadEnd | Fallback | Degraded => "psi-wait",
            BatchOpen | BatchJoin | BatchFilled | BatchFlush | BatchSolo => "batch-form",
            ExecStart => "batch-wait",
            RankDone => "rank-exec",
            SpillBegin | SpillEnd => "spill",
        }
    }
}

/// Reason codes for [`SpanKind::TriggerDecision`], aligned with
/// [`Decision`](crate::relay::trigger::Decision) plus the overcommit
/// cancel (a post-admit reversal when the ψ window rejects the
/// reservation).
pub mod trigger_reason {
    pub const NOT_AT_RISK: u64 = 0;
    pub const ADMIT: u64 = 1;
    pub const RATE_LIMITED: u64 = 2;
    pub const FOOTPRINT_LIMITED: u64 = 3;
    pub const OVERCOMMIT_CANCEL: u64 = 4;

    pub const NAMES: [&str; 5] =
        ["not-at-risk", "admit", "rate-limited", "footprint-limited", "overcommit-cancel"];
}

/// ψ lookup outcome codes for [`SpanKind::PsiLookup`], aligned with
/// [`PseudoAction`](crate::relay::hierarchy::PseudoAction).
pub mod psi_action {
    pub const HBM_HIT: u64 = 0;
    pub const WAIT_PRODUCING: u64 = 1;
    pub const START_RELOAD: u64 = 2;
    pub const JOIN_RELOAD: u64 = 3;
    pub const QUEUED_RELOAD: u64 = 4;
    pub const MISS: u64 = 5;

    pub const NAMES: [&str; 6] =
        ["hbm-hit", "wait-producing", "start-reload", "join-reload", "queued-reload", "miss"];
}

/// Rank action codes for [`SpanKind::RankStart`].
pub mod rank_action {
    pub const PROCEED: u64 = 0;
    pub const WAIT: u64 = 1;
    pub const START_RELOAD: u64 = 2;
    pub const WAIT_RELOAD: u64 = 3;

    pub const NAMES: [&str; 4] = ["proceed", "wait", "start-reload", "wait-reload"];
}

// ---- stage-latency breakdown --------------------------------------------

/// Per-stage latency histograms folded by the recorder as requests
/// complete.  Empty (all zero counts) when tracing is off.
#[derive(Debug, Clone, Default)]
pub struct StageBreakdown {
    /// Arrival → trigger decision (requests whose trigger ran).
    pub admission: Histogram,
    /// Rank-side ψ wait (wait-for-produce / reload promotion), µs.
    pub psi_wait: Histogram,
    /// Batch-former offer → execution start (nonzero only for window
    /// leaders and joiners that waited out the window).
    pub batch_wait: Histogram,
    /// Execution start → rank done.
    pub rank_exec: Histogram,
    /// Spill begin → spill end (D2H demotion, post-completion).
    pub spill: Histogram,
}

impl StageBreakdown {
    /// `(stage name, histogram)` in report order.
    pub fn named(&self) -> [(&'static str, &Histogram); 5] {
        [
            ("admission", &self.admission),
            ("psi-wait", &self.psi_wait),
            ("batch-wait", &self.batch_wait),
            ("rank-exec", &self.rank_exec),
            ("spill", &self.spill),
        ]
    }

    pub fn is_empty(&self) -> bool {
        self.named().iter().all(|(_, h)| h.count() == 0)
    }
}

// ---- recorder ------------------------------------------------------------

const SHARDS: usize = 8;
const UNSET: u64 = u64::MAX;

/// Per-slot stage clocks (slab-slot-indexed — slots recycle, Arrival
/// resets).  `UNSET` marks a stage the request never entered.
#[derive(Debug, Clone, Copy)]
struct StageClock {
    rid: u64,
    arrival: u64,
    offered: u64,
    exec_start: u64,
}

impl StageClock {
    const EMPTY: StageClock =
        StageClock { rid: UNSET, arrival: UNSET, offered: UNSET, exec_start: UNSET };
}

#[derive(Debug, Default)]
struct Ring {
    buf: Vec<Span>,
    /// Retention bound for this shard (`Vec::capacity` may over-reserve).
    cap: usize,
    /// Oldest retained span once the ring is full (next overwrite target).
    head: usize,
}

/// The flight recorder (see module docs).  Constructed only when
/// `trace_spans > 0`; every hook is a no-op at the coordinator level when
/// the recorder is absent.
#[derive(Debug)]
pub struct FlightRecorder {
    shards: Vec<Ring>,
    /// Total retention bound (`--trace-spans`), split across shards.
    trace_spans: usize,
    ord: u64,
    emitted: u64,
    dropped: u64,
    clocks: Vec<StageClock>,
    /// user → (rid, t_begin) for in-flight signal-side productions.
    pending_produce: HashMap<u64, (u64, u64)>,
    /// user → (rid, t_begin) for in-flight DRAM→HBM reloads.
    pending_reload: HashMap<u64, (u64, u64)>,
    /// user → (rid, t_begin) for in-flight D2H spills.
    pending_spill: HashMap<u64, (u64, u64)>,
    pub breakdown: StageBreakdown,
    /// Batch-former event counts `[open, join, filled, flush, solo]` —
    /// the serve heartbeat's batch snapshot (no other component counts
    /// these).
    pub batch_counts: [u64; 5],
    /// Most recently completed request id — the CLI's sample pick for
    /// `relaygr explain` smoke runs.
    pub last_done_rid: Option<u64>,
}

impl FlightRecorder {
    /// `trace_spans` bounds total retained spans across all shards.
    pub fn new(trace_spans: usize) -> FlightRecorder {
        let cap = trace_spans.max(SHARDS).div_ceil(SHARDS);
        FlightRecorder {
            shards: (0..SHARDS)
                .map(|_| Ring { buf: Vec::with_capacity(cap), cap, head: 0 })
                .collect(),
            trace_spans,
            ord: 0,
            emitted: 0,
            dropped: 0,
            clocks: Vec::new(),
            pending_produce: HashMap::new(),
            pending_reload: HashMap::new(),
            pending_spill: HashMap::new(),
            breakdown: StageBreakdown::default(),
            batch_counts: [0; 5],
            last_done_rid: None,
        }
    }

    /// Spans ever emitted (retained + dropped).
    pub fn emitted(&self) -> u64 {
        self.emitted
    }

    /// Spans overwritten by the bounded rings.
    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    /// Spans currently retained.
    pub fn retained(&self) -> usize {
        self.shards.iter().map(|s| s.buf.len()).sum()
    }

    /// Core emission: one span into the rid's shard ring.  Warm rings
    /// (at capacity, or with capacity pre-reserved) never allocate — the
    /// `coordinator/trace_emit` zero-alloc contract.
    #[inline]
    pub fn emit(&mut self, t_us: u64, rid: u64, kind: SpanKind, a: u64, b: u64) {
        let span = Span { ord: self.ord, t_us, rid, kind, a, b };
        self.ord += 1;
        self.emitted += 1;
        let ring = &mut self.shards[(rid as usize) & (SHARDS - 1)];
        if ring.buf.len() < ring.cap {
            ring.buf.push(span);
        } else {
            self.dropped += 1;
            ring.buf[ring.head] = span;
            ring.head = (ring.head + 1) % ring.buf.len();
        }
    }

    #[inline]
    fn clock_mut(&mut self, slot: usize) -> &mut StageClock {
        if slot >= self.clocks.len() {
            self.clocks.resize(slot + 1, StageClock::EMPTY);
        }
        &mut self.clocks[slot]
    }

    #[inline]
    fn rid_of(&self, slot: usize) -> u64 {
        self.clocks.get(slot).map_or(UNSET, |c| c.rid)
    }

    // ---- lifecycle hooks (called by the coordinator, observe-only) ------

    pub fn note_arrival(&mut self, t: u64, rid: u64, slot: usize, user: u64, prefix_len: u64) {
        *self.clock_mut(slot) =
            StageClock { rid, arrival: t, offered: UNSET, exec_start: UNSET };
        self.emit(t, rid, SpanKind::Arrival, user, prefix_len);
    }

    pub fn note_trigger(&mut self, t: u64, slot: usize, reason: u64, instance: u64) {
        let c = *self.clock_mut(slot);
        if c.arrival != UNSET && t >= c.arrival {
            self.breakdown.admission.record((t - c.arrival) as f64);
        }
        self.emit(t, c.rid, SpanKind::TriggerDecision, reason, instance);
    }

    pub fn note_psi(&mut self, t: u64, slot: usize, action: u64, rank_side: bool) {
        let rid = self.rid_of(slot);
        self.emit(t, rid, SpanKind::PsiLookup, action, u64::from(rank_side));
    }

    pub fn note_route(&mut self, t: u64, slot: usize, rank_side: bool, instance: u64) {
        let rid = self.rid_of(slot);
        self.emit(t, rid, SpanKind::Route, u64::from(rank_side), instance);
    }

    pub fn note_produce_begin(&mut self, t: u64, slot: usize, user: u64, instance: u64) {
        let rid = self.rid_of(slot);
        self.pending_produce.insert(user, (rid, t));
        self.emit(t, rid, SpanKind::ProduceBegin, instance, 0);
    }

    pub fn note_produce_end(&mut self, t: u64, user: u64, instance: u64, installed: bool) {
        let (rid, _) = self.pending_produce.remove(&user).unwrap_or((UNSET, t));
        self.emit(t, rid, SpanKind::ProduceEnd, instance, u64::from(installed));
    }

    pub fn note_rank_start(&mut self, t: u64, slot: usize, action: u64, instance: u64) {
        let rid = self.rid_of(slot);
        self.emit(t, rid, SpanKind::RankStart, action, instance);
    }

    pub fn note_wait_resolved(&mut self, t: u64, slot: usize, cause: u64, wait_us: u64) {
        let rid = self.rid_of(slot);
        self.emit(t, rid, SpanKind::WaitResolved, cause, wait_us);
    }

    pub fn note_reload_begin(&mut self, t: u64, slot: usize, user: u64, instance: u64, bytes: u64) {
        let rid = self.rid_of(slot);
        self.pending_reload.insert(user, (rid, t));
        self.emit(t, rid, SpanKind::ReloadBegin, instance, bytes);
    }

    pub fn note_reload_end(&mut self, t: u64, user: u64, installed: bool, bytes: u64) {
        let (rid, _) = self.pending_reload.remove(&user).unwrap_or((UNSET, t));
        self.emit(t, rid, SpanKind::ReloadEnd, u64::from(installed), bytes);
    }

    pub fn note_batch(&mut self, t: u64, slot: usize, kind: SpanKind, instance: u64, gen: u64) {
        let c = self.clock_mut(slot);
        if c.offered == UNSET {
            c.offered = t;
        }
        let rid = c.rid;
        match kind {
            SpanKind::BatchOpen => self.batch_counts[0] += 1,
            SpanKind::BatchJoin => self.batch_counts[1] += 1,
            SpanKind::BatchFilled => self.batch_counts[2] += 1,
            SpanKind::BatchSolo => self.batch_counts[4] += 1,
            _ => {}
        }
        self.emit(t, rid, kind, instance, gen);
    }

    pub fn note_batch_flush(&mut self, t: u64, slot: usize, instance: u64, gen: u64) {
        let rid = self.rid_of(slot);
        self.batch_counts[3] += 1;
        self.emit(t, rid, SpanKind::BatchFlush, instance, gen);
    }

    pub fn note_exec_start(&mut self, t: u64, slot: usize, cached: bool, reused: u64) {
        let c = self.clock_mut(slot);
        c.exec_start = t;
        let (rid, offered) = (c.rid, c.offered);
        if offered != UNSET && t >= offered {
            self.breakdown.batch_wait.record((t - offered) as f64);
        }
        self.emit(t, rid, SpanKind::ExecStart, u64::from(cached), reused);
    }

    pub fn note_rank_done(&mut self, t: u64, slot: usize, outcome: u64, wait_us: f64) {
        let c = *self.clock_mut(slot);
        if wait_us > 0.0 {
            self.breakdown.psi_wait.record(wait_us);
        }
        if c.exec_start != UNSET && t >= c.exec_start {
            self.breakdown.rank_exec.record((t - c.exec_start) as f64);
        }
        if c.rid != UNSET {
            self.last_done_rid = Some(c.rid);
        }
        self.emit(t, c.rid, SpanKind::RankDone, outcome, wait_us as u64);
    }

    /// Two-level routing: the cell picked for this request at arrival
    /// (`CellFailover` when the choice overrode the user's home cell).
    pub fn note_cell_route(&mut self, t: u64, slot: usize, cell: u64, home: u64, failover: bool) {
        let rid = self.rid_of(slot);
        let kind = if failover { SpanKind::CellFailover } else { SpanKind::CellRouted };
        self.emit(t, rid, kind, cell, home);
    }

    pub fn note_fallback(&mut self, t: u64, slot: usize, cause: u64) {
        let rid = self.rid_of(slot);
        self.emit(t, rid, SpanKind::Fallback, cause, 0);
    }

    /// Fault-plane injection at a decision point.  Takes `rid` directly
    /// (like spills) — some injection sites (reload completion) have no
    /// slab slot in hand.
    pub fn note_fault(&mut self, t: u64, rid: u64, kind_idx: u64, recovered: bool) {
        self.emit(t, rid, SpanKind::FaultInjected, kind_idx, u64::from(recovered));
    }

    /// One deterministic retry attempt (1-based) against an injected fault.
    pub fn note_retry(&mut self, t: u64, rid: u64, kind_idx: u64, attempt: u64) {
        self.emit(t, rid, SpanKind::RetryScheduled, kind_idx, attempt);
    }

    /// Degradation-ladder verdict for an unrecovered fault (`shed` picks
    /// the rung).
    pub fn note_degraded(&mut self, t: u64, rid: u64, kind_idx: u64, shed: bool) {
        self.emit(t, rid, SpanKind::Degraded, kind_idx, u64::from(shed));
    }

    pub fn note_spill_begin(&mut self, t: u64, rid: u64, user: u64, instance: u64, bytes: u64) {
        self.pending_spill.insert(user, (rid, t));
        self.emit(t, rid, SpanKind::SpillBegin, instance, bytes);
    }

    pub fn note_spill_end(&mut self, t: u64, user: u64, accepted: bool, bytes: u64) {
        let (rid, begin) = self.pending_spill.remove(&user).unwrap_or((UNSET, t));
        if t >= begin {
            self.breakdown.spill.record((t - begin) as f64);
        }
        self.emit(t, rid, SpanKind::SpillEnd, u64::from(accepted), bytes);
    }

    // ---- extraction ------------------------------------------------------

    /// Fold another recorder (a different cell's) into this one: the
    /// other's retained spans are re-ordinalized in their emission order
    /// after this recorder's existing spans, and the stage histograms,
    /// batch counters and drop accounting merge.  Per-request span order
    /// is preserved because a request lives in exactly one cell; callers
    /// absorb cells in fixed index order so the merged stream is
    /// deterministic.
    pub fn absorb(&mut self, other: &FlightRecorder) {
        for s in other.spans_sorted() {
            self.emit(s.t_us, s.rid, s.kind, s.a, s.b);
        }
        // `emit` charged only the other's *retained* spans; fold in the
        // spans its bounded rings had already overwritten.
        self.emitted += other.dropped;
        self.dropped += other.dropped;
        self.breakdown.admission.merge(&other.breakdown.admission);
        self.breakdown.psi_wait.merge(&other.breakdown.psi_wait);
        self.breakdown.batch_wait.merge(&other.breakdown.batch_wait);
        self.breakdown.rank_exec.merge(&other.breakdown.rank_exec);
        self.breakdown.spill.merge(&other.breakdown.spill);
        for (c, o) in self.batch_counts.iter_mut().zip(other.batch_counts) {
            *c += o;
        }
        self.last_done_rid = other.last_done_rid.or(self.last_done_rid);
    }

    /// All retained spans in deterministic emission (`ord`) order.
    pub fn spans_sorted(&self) -> Vec<Span> {
        let mut all: Vec<Span> = self.shards.iter().flat_map(|s| s.buf.iter().copied()).collect();
        all.sort_by_key(|s| s.ord);
        all
    }

    /// Serialize retained spans to an RGSP sidecar.  Returns
    /// `(spans written, bytes)`.
    pub fn write_rgsp(&self, path: &str) -> Result<(u64, u64)> {
        let spans = self.spans_sorted();
        let mut buf = Vec::with_capacity(32 + spans.len() * 8);
        buf.extend_from_slice(RGSP_MAGIC);
        buf.push(RGSP_VERSION);
        buf.extend_from_slice(&(spans.len() as u64).to_le_bytes());
        put_varint(&mut buf, self.trace_spans as u64);
        put_varint(&mut buf, self.emitted);
        put_varint(&mut buf, self.dropped);
        let (mut prev_ord, mut prev_t) = (0u64, 0u64);
        for s in &spans {
            put_varint(&mut buf, s.ord - prev_ord);
            put_varint(&mut buf, zigzag(s.t_us.wrapping_sub(prev_t) as i64));
            put_varint(&mut buf, s.rid);
            buf.push(s.kind as u8);
            put_varint(&mut buf, s.a);
            put_varint(&mut buf, s.b);
            prev_ord = s.ord;
            prev_t = s.t_us;
        }
        let bytes = buf.len() as u64;
        std::fs::write(path, &buf).with_context(|| format!("writing span sidecar '{path}'"))?;
        Ok((spans.len() as u64, bytes))
    }
}

pub const RGSP_MAGIC: &[u8; 4] = b"RGSP";
pub const RGSP_VERSION: u8 = 1;

#[inline]
fn zigzag(v: i64) -> u64 {
    ((v << 1) ^ (v >> 63)) as u64
}

#[inline]
fn unzigzag(v: u64) -> i64 {
    ((v >> 1) as i64) ^ -((v & 1) as i64)
}

/// A parsed RGSP sidecar.
#[derive(Debug, Clone)]
pub struct SpanFile {
    pub spans: Vec<Span>,
    /// The run's `--trace-spans` retention bound.
    pub trace_spans: u64,
    pub emitted: u64,
    pub dropped: u64,
}

/// Parse an RGSP sidecar written by [`FlightRecorder::write_rgsp`].
/// Unknown span tags are skipped (forward compatibility within a
/// version — see the module-level extension recipe).
pub fn read_rgsp(path: &str) -> Result<SpanFile> {
    let data = std::fs::read(path).with_context(|| format!("opening span sidecar '{path}'"))?;
    let mut r = data.as_slice();
    let mut magic = [0u8; 4];
    std::io::Read::read_exact(&mut r, &mut magic).context("sidecar header truncated")?;
    if &magic != RGSP_MAGIC {
        bail!("'{path}' is not an RGSP span sidecar (bad magic)");
    }
    let version = read_u8(&mut r)?;
    if version != RGSP_VERSION {
        bail!("sidecar '{path}' has unsupported version {version} (expected {RGSP_VERSION})");
    }
    let mut count = [0u8; 8];
    std::io::Read::read_exact(&mut r, &mut count)?;
    let count = u64::from_le_bytes(count);
    let trace_spans = read_varint(&mut r)?;
    let emitted = read_varint(&mut r)?;
    let dropped = read_varint(&mut r)?;
    let mut spans = Vec::with_capacity(count as usize);
    let (mut prev_ord, mut prev_t) = (0u64, 0u64);
    for i in 0..count {
        let ord = prev_ord + read_varint(&mut r).with_context(|| format!("span {i}"))?;
        let t_us = prev_t.wrapping_add(unzigzag(read_varint(&mut r)?) as u64);
        let rid = read_varint(&mut r)?;
        let tag = read_u8(&mut r)?;
        let a = read_varint(&mut r)?;
        let b = read_varint(&mut r)?;
        prev_ord = ord;
        prev_t = t_us;
        if let Some(kind) = SpanKind::from_u8(tag) {
            spans.push(Span { ord, t_us, rid, kind, a, b });
        }
    }
    Ok(SpanFile { spans, trace_spans, emitted, dropped })
}

// ---- timeline reconstruction (`relaygr explain`) -------------------------

/// A request's reconstructed lifecycle: its spans in emission order, the
/// per-stage durations between consecutive lifecycle spans (telescoping,
/// so they sum exactly to `done − arrival`), and any post-completion
/// spans (spill end) reported separately.
#[derive(Debug, Clone)]
pub struct Timeline {
    pub rid: u64,
    pub arrival_us: u64,
    /// Clock of the completion span ([`SpanKind::RankDone`]), or the last
    /// observed span for a request still in flight at capture time.
    pub done_us: u64,
    /// Outcome index from the completion span, `None` if still in flight.
    pub outcome: Option<usize>,
    /// `(stage, total µs)` aggregated over the lifecycle intervals in
    /// first-entered order.  Sums exactly to [`Timeline::e2e_us`].
    pub stages: Vec<(&'static str, u64)>,
    /// Lifecycle spans (arrival..=completion), ord-sorted.
    pub events: Vec<Span>,
    /// Spans recorded after completion (e.g. spill end), ord-sorted.
    pub post: Vec<Span>,
}

impl Timeline {
    pub fn e2e_us(&self) -> u64 {
        self.done_us - self.arrival_us
    }

    /// Human rendering: one line per span with its +offset, then the
    /// stage totals and the telescoping e2e sum.
    pub fn render(&self) -> String {
        use std::fmt::Write;
        let mut out = String::new();
        let outcome = match self.outcome {
            Some(i) => crate::metrics::OUTCOME_NAMES.get(i).copied().unwrap_or("?"),
            None => "in-flight",
        };
        let _ = writeln!(
            out,
            "request {} — {} spans, e2e {:.3} ms, outcome {}",
            self.rid,
            self.events.len() + self.post.len(),
            self.e2e_us() as f64 / 1e3,
            outcome,
        );
        for s in &self.events {
            let _ = writeln!(
                out,
                "  t+{:>10.3} ms  {:<14} {}",
                (s.t_us - self.arrival_us) as f64 / 1e3,
                s.kind.label(),
                describe(s),
            );
        }
        for s in &self.post {
            let _ = writeln!(
                out,
                "  t+{:>10.3} ms  {:<14} {} (post-completion)",
                (s.t_us - self.arrival_us) as f64 / 1e3,
                s.kind.label(),
                describe(s),
            );
        }
        let total: u64 = self.stages.iter().map(|&(_, d)| d).sum();
        let stages = self
            .stages
            .iter()
            .map(|&(name, d)| format!("{name} {:.3} ms", d as f64 / 1e3))
            .collect::<Vec<_>>()
            .join(" | ");
        let _ = writeln!(
            out,
            "stage totals: {stages} | total {:.3} ms (= e2e {:.3} ms)",
            total as f64 / 1e3,
            self.e2e_us() as f64 / 1e3,
        );
        out
    }
}

fn describe(s: &Span) -> String {
    use SpanKind::*;
    let name = |table: &[&str], i: u64| -> String {
        table.get(i as usize).map_or_else(|| format!("?{i}"), |n| n.to_string())
    };
    let inst = |i: u64| {
        if i == NONE_OPERAND {
            "-".to_string()
        } else {
            i.to_string()
        }
    };
    match s.kind {
        Arrival => format!("user={} prefix={}", s.a, s.b),
        TriggerDecision => {
            format!("{} instance={}", name(&trigger_reason::NAMES, s.a), inst(s.b))
        }
        PsiLookup => format!(
            "{} side={}",
            name(&psi_action::NAMES, s.a),
            if s.b == 0 { "signal" } else { "rank" }
        ),
        Route => format!(
            "{} instance={}",
            if s.a == 0 { "signal" } else { "rank" },
            inst(s.b)
        ),
        ProduceBegin => format!("instance={}", inst(s.a)),
        ProduceEnd => format!("instance={} installed={}", inst(s.a), s.b == 1),
        RankStart => format!("{} instance={}", name(&rank_action::NAMES, s.a), inst(s.b)),
        WaitResolved => format!("cause={} waited={} µs", s.a, s.b),
        ReloadBegin => format!("instance={} bytes={}", inst(s.a), s.b),
        ReloadEnd => format!("installed={} bytes={}", s.a == 1, s.b),
        BatchOpen | BatchJoin | BatchFilled | BatchFlush | BatchSolo => {
            format!("instance={} gen={}", inst(s.a), s.b)
        }
        ExecStart => format!("cached={} reused={}", s.a == 1, s.b),
        RankDone => format!(
            "outcome={} waited={} µs",
            name(&crate::metrics::OUTCOME_NAMES, s.a),
            s.b
        ),
        Fallback => format!("cause={}", s.a),
        SpillBegin => format!("instance={} bytes={}", inst(s.a), s.b),
        SpillEnd => format!("accepted={} bytes={}", s.a == 1, s.b),
        CellRouted | CellFailover => format!("cell={} home={}", s.a, s.b),
        FaultInjected => format!(
            "{} recovered={}",
            name(&crate::relay::fault::FaultKind::NAMES, s.a),
            s.b == 1
        ),
        RetryScheduled => format!(
            "{} attempt={}",
            name(&crate::relay::fault::FaultKind::NAMES, s.a),
            s.b
        ),
        Degraded => format!(
            "{} rung={}",
            name(&crate::relay::fault::FaultKind::NAMES, s.a),
            if s.b == 1 { "shed" } else { "fallback" }
        ),
    }
}

/// Reconstruct request `rid`'s timeline from a span set (any order).
/// Returns `None` when no span for `rid` exists (evicted from the
/// bounded rings, or never traced).
pub fn timeline(spans: &[Span], rid: u64) -> Option<Timeline> {
    let mut mine: Vec<Span> = spans.iter().filter(|s| s.rid == rid).copied().collect();
    if mine.is_empty() {
        return None;
    }
    mine.sort_by_key(|s| s.ord);
    // The lifecycle closes at the completion span; anything after it
    // (spill completion) is post-lifecycle and excluded from the
    // telescoping sum.
    let done_idx = mine.iter().position(|s| s.kind == SpanKind::RankDone);
    let split = done_idx.map_or(mine.len(), |i| i + 1);
    let post = mine.split_off(split);
    let arrival_us = mine.first()?.t_us;
    let done_us = mine.last()?.t_us;
    let outcome = done_idx.map(|_| mine.last().map_or(0, |s| s.a as usize));
    let mut stages: Vec<(&'static str, u64)> = Vec::new();
    for w in mine.windows(2) {
        let (prev, cur) = (w[0], w[1]);
        let d = cur.t_us.saturating_sub(prev.t_us);
        let stage = cur.kind.stage();
        match stages.iter_mut().find(|(n, _)| *n == stage) {
            Some((_, total)) => *total += d,
            None => stages.push((stage, d)),
        }
    }
    Some(Timeline { rid, arrival_us, done_us, outcome, stages, events: mine, post })
}

/// `relaygr trace inspect` summary of a span sidecar.
pub fn inspect_summary(f: &SpanFile) -> String {
    use std::fmt::Write;
    let mut out = String::new();
    let mut by_kind: Vec<(SpanKind, u64)> = Vec::new();
    let mut rids: Vec<u64> = Vec::new();
    let (mut t_min, mut t_max) = (u64::MAX, 0u64);
    for s in &f.spans {
        match by_kind.iter_mut().find(|(k, _)| *k == s.kind) {
            Some((_, n)) => *n += 1,
            None => by_kind.push((s.kind, 1)),
        }
        rids.push(s.rid);
        t_min = t_min.min(s.t_us);
        t_max = t_max.max(s.t_us);
    }
    rids.sort_unstable();
    rids.dedup();
    let _ = writeln!(
        out,
        "{} spans retained ({} emitted, {} dropped by the {}-span bound)",
        f.spans.len(),
        f.emitted,
        f.dropped,
        f.trace_spans,
    );
    if f.spans.is_empty() {
        return out;
    }
    let _ = writeln!(
        out,
        "{} distinct requests, clock range [{:.3} ms .. {:.3} ms]",
        rids.len(),
        t_min as f64 / 1e3,
        t_max as f64 / 1e3,
    );
    for (k, n) in &by_kind {
        let _ = writeln!(out, "  {:<14} {n}", k.label());
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> String {
        let dir = std::env::temp_dir().join("relaygr_flight_test");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name).to_str().unwrap().to_string()
    }

    /// Drive one synthetic request through the hook API.
    fn record_one(fl: &mut FlightRecorder, rid: u64, slot: usize, t0: u64) {
        fl.note_arrival(t0, rid, slot, 7, 4096);
        fl.note_trigger(t0 + 10, slot, trigger_reason::ADMIT, 3);
        fl.note_psi(t0 + 10, slot, psi_action::MISS, false);
        fl.note_produce_begin(t0 + 10, slot, 7, 3);
        fl.note_route(t0 + 500, slot, true, 3);
        fl.note_rank_start(t0 + 500, slot, rank_action::PROCEED, 3);
        fl.note_batch(t0 + 500, slot, SpanKind::BatchSolo, 3, 0);
        fl.note_exec_start(t0 + 700, slot, true, 0);
        fl.note_produce_end(t0 + 800, 7, 3, true);
        fl.note_rank_done(t0 + 2_000, slot, 1, 0.0);
        fl.note_spill_begin(t0 + 2_000, rid, 7, 3, 1 << 20);
        fl.note_spill_end(t0 + 2_500, 7, true, 1 << 20);
    }

    #[test]
    fn timeline_stage_durations_telescope_to_e2e() {
        let mut fl = FlightRecorder::new(1024);
        record_one(&mut fl, 42, 0, 1_000);
        let spans = fl.spans_sorted();
        let tl = timeline(&spans, 42).expect("request traced");
        assert_eq!(tl.arrival_us, 1_000);
        assert_eq!(tl.done_us, 3_000, "lifecycle closes at rank-done");
        assert_eq!(tl.e2e_us(), 2_000);
        let total: u64 = tl.stages.iter().map(|&(_, d)| d).sum();
        assert_eq!(total, tl.e2e_us(), "stage durations must telescope to e2e");
        assert_eq!(tl.outcome, Some(1), "outcome reconstructed from the completion span");
        assert_eq!(tl.post.len(), 2, "spill begin+end are post-completion");
        let rendered = tl.render();
        assert!(rendered.contains("outcome hbm"), "{rendered}");
        assert!(rendered.contains("stage totals:"), "{rendered}");
        // Breakdown folds: admission 10 µs, rank-exec 1300 µs, spill 500 µs,
        // batch-wait 200 µs.
        assert_eq!(fl.breakdown.admission.count(), 1);
        assert!((fl.breakdown.admission.max() - 10.0).abs() < 1e-9);
        assert!((fl.breakdown.rank_exec.max() - 1300.0).abs() < 1e-9);
        assert!((fl.breakdown.batch_wait.max() - 200.0).abs() < 1e-9);
        assert!((fl.breakdown.spill.max() - 500.0).abs() < 1e-9);
    }

    #[test]
    fn ring_bound_overwrites_oldest_and_counts_drops() {
        // Bound far below the emission volume: old spans fall off, the
        // newest survive, accounting stays exact.
        let mut fl = FlightRecorder::new(16);
        for i in 0..100u64 {
            fl.emit(i, i, SpanKind::Arrival, 0, 0);
        }
        assert_eq!(fl.emitted(), 100);
        assert_eq!(fl.retained(), 16);
        assert_eq!(fl.dropped(), 84);
        let spans = fl.spans_sorted();
        assert!(spans.windows(2).all(|w| w[0].ord < w[1].ord), "ord-sorted");
        // Each rid-shard retains its own newest spans.
        assert!(spans.iter().all(|s| s.ord >= 100 - 8 * 2 - 8), "only recent spans retained");
    }

    #[test]
    fn rgsp_round_trips_and_rejects_bad_headers() {
        let mut fl = FlightRecorder::new(4096);
        for slot in 0..20usize {
            record_one(&mut fl, slot as u64 * 3 + 1, slot, slot as u64 * 10_000);
        }
        let path = tmp("roundtrip.rgsp");
        let (n, bytes) = fl.write_rgsp(&path).unwrap();
        assert_eq!(n as usize, fl.retained());
        assert!(bytes > 0);
        let back = read_rgsp(&path).unwrap();
        assert_eq!(back.spans, fl.spans_sorted(), "lossless round trip");
        assert_eq!(back.emitted, fl.emitted());
        assert_eq!(back.dropped, 0);
        // Compactness: well under the 48-byte in-memory span.
        assert!((bytes as f64 / n as f64) < 16.0, "{:.1} bytes/span", bytes as f64 / n as f64);
        let summary = inspect_summary(&back);
        assert!(summary.contains("20 distinct requests"), "{summary}");
        assert!(summary.contains("rank-done"), "{summary}");

        let bad = tmp("bad.rgsp");
        std::fs::write(&bad, b"NOPE").unwrap();
        assert!(read_rgsp(&bad).is_err());
        std::fs::write(&bad, b"RGSP\x63").unwrap();
        assert!(read_rgsp(&bad).is_err(), "unsupported version");
    }

    #[test]
    fn zigzag_round_trips() {
        for v in [0i64, 1, -1, 2, -2, i64::MAX, i64::MIN, 123_456, -123_456] {
            assert_eq!(unzigzag(zigzag(v)), v, "v={v}");
        }
    }

    #[test]
    fn cell_route_spans_round_trip_and_render() {
        let mut fl = FlightRecorder::new(64);
        fl.note_arrival(100, 7, 0, 1, 4096);
        fl.note_cell_route(100, 0, 2, 2, false);
        fl.note_arrival(200, 8, 1, 9, 4096);
        fl.note_cell_route(200, 1, 0, 3, true);
        let spans = fl.spans_sorted();
        assert_eq!(spans[1].kind, SpanKind::CellRouted);
        assert_eq!((spans[1].a, spans[1].b), (2, 2));
        assert_eq!(spans[3].kind, SpanKind::CellFailover);
        assert_eq!((spans[3].a, spans[3].b), (0, 3));
        // Tags are append-only past the PR 8 table.
        assert_eq!(SpanKind::from_u8(20), Some(SpanKind::CellRouted));
        assert_eq!(SpanKind::from_u8(21), Some(SpanKind::CellFailover));
        assert_eq!(SpanKind::from_u8(22), Some(SpanKind::FaultInjected));
        assert_eq!(SpanKind::from_u8(23), Some(SpanKind::RetryScheduled));
        assert_eq!(SpanKind::from_u8(24), Some(SpanKind::Degraded));
        assert_eq!(SpanKind::from_u8(25), None);
        let path = tmp("cells.rgsp");
        fl.write_rgsp(&path).unwrap();
        let back = read_rgsp(&path).unwrap();
        assert_eq!(back.spans, spans, "new tags survive the sidecar round trip");
        let tl = timeline(&spans, 8).unwrap();
        assert!(tl.render().contains("cell-failover"), "{}", tl.render());
        assert!(tl.render().contains("cell=0 home=3"), "{}", tl.render());
    }

    #[test]
    fn absorb_merges_cells_deterministically() {
        let mut a = FlightRecorder::new(1024);
        record_one(&mut a, 1, 0, 0);
        let mut b = FlightRecorder::new(1024);
        record_one(&mut b, 2, 0, 500);
        let (ea, eb) = (a.emitted(), b.emitted());
        let (ca, cb) = (a.batch_counts, b.batch_counts);
        a.absorb(&b);
        assert_eq!(a.emitted(), ea + eb);
        assert_eq!(a.retained(), (ea + eb) as usize, "nothing dropped at this bound");
        for (i, (x, y)) in ca.iter().zip(cb).enumerate() {
            assert_eq!(a.batch_counts[i], x + y);
        }
        assert_eq!(a.breakdown.admission.count(), 2, "stage histograms merged");
        let spans = a.spans_sorted();
        assert!(spans.windows(2).all(|w| w[0].ord < w[1].ord), "ords stay unique");
        // Both requests' timelines survive the merge intact.
        assert!(timeline(&spans, 1).is_some());
        let tl = timeline(&spans, 2).unwrap();
        let total: u64 = tl.stages.iter().map(|&(_, d)| d).sum();
        assert_eq!(total, tl.e2e_us(), "absorbed request still telescopes");
    }

    #[test]
    fn missing_request_yields_no_timeline() {
        let mut fl = FlightRecorder::new(64);
        record_one(&mut fl, 5, 0, 0);
        assert!(timeline(&fl.spans_sorted(), 999).is_none());
    }

    #[test]
    fn in_flight_request_renders_without_outcome() {
        let mut fl = FlightRecorder::new(64);
        fl.note_arrival(100, 9, 0, 1, 2048);
        fl.note_trigger(150, 0, trigger_reason::RATE_LIMITED, NONE_OPERAND);
        let tl = timeline(&fl.spans_sorted(), 9).unwrap();
        assert_eq!(tl.outcome, None);
        assert_eq!(tl.e2e_us(), 50);
        assert!(tl.render().contains("in-flight"));
        assert!(tl.render().contains("rate-limited"));
    }
}
