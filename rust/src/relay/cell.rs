//! Multi-cell cluster layer: two-level cache-affinity routing.
//!
//! Production fleets are organised as *cells* (pods / datacenters), each
//! with its own coordinator, ψ cache-hierarchy set and affinity router.
//! [`CellSet`] owns one [`RelayCoordinator`] shard per cell and adds the
//! level *above* the paper's router: a user→cell pick, after which the
//! existing in-cell consistent-hash / least-connections route runs
//! unchanged.
//!
//! ## The two-level routing contract
//!
//! * **Level 1 (this module)** — pick a cell for the request.  The
//!   *home* cell is the rendezvous (highest-random-weight) argmax of
//!   `hash_key(user, salt[cell])` over **all** cells, so a user's home
//!   is a pure function of the user id and the cell count — stable
//!   across drain/failure churn.  The pick itself runs over the
//!   *eligible* (active ∧ not-drained) cells only:
//!   - [`CellPickerKind::Affinity`] routes to the user's rendezvous
//!     choice among eligible cells, spilling to the least-loaded
//!     eligible cell when the home's recent load exceeds
//!     `spill_ratio ×` the eligible mean (the locality-vs-load knob;
//!     `inf` = pure locality).  Load is an exponentially-decayed
//!     arrival count (half-life [`LOAD_HALF_LIFE_US`]).
//!   - [`CellPickerKind::Spread`] rendezvous-hashes the *request id*
//!     instead of the user — load-uniform, locality-blind.
//! * **Level 2 (unchanged)** — the chosen cell's own
//!   [`Router`](crate::relay::router::Router) routes gateways and
//!   instances exactly as before.
//!
//! Every input to the pick (user id, request id, eligibility masks,
//! decayed loads keyed by the engine-shared *arrival* clock) evolves
//! deterministically from the arrival sequence, so the discrete-event
//! simulator and the serialized reference make bit-identical cell
//! choices.  Nothing here reads `ShardedMap` iteration order or any
//! other engine-dependent state.  With `cells == 1` the pick
//! short-circuits to cell 0 and touches no picker state at all —
//! structurally identical to the pre-cell coordinator.
//!
//! ## Adding a cell-picker policy
//!
//! Add a [`CellPickerKind`] variant, its `parse`/`label` arms, and one
//! match arm in `CellSet::pick` that maps `(user, rid, eligible mask,
//! loads)` to a cell index.  Keep it a pure function of those inputs —
//! that is the whole determinism contract — and extend
//! `picker_is_deterministic` in this module's tests.
//!
//! ## Scenario scripts
//!
//! [`CellScenario`] compiles to a fixed event list at construction
//! (fractions of the run duration) and is applied lazily on the arrival
//! path, so failure / drain / elasticity churn is driven through the
//! shared decision plane and stays engine-identical:
//!
//! * `failure` — at 40% of the run, cell 0's first special instance
//!   fails: settled ψ lineages on it are wiped lazily (reload storm),
//!   in-flight lineages survive (see
//!   [`RelayCoordinator::fail_instance`]).
//! * `drain` — cell 1 (cell 0 when single-cell) drains at 30% and
//!   returns at 70%: no new picks land on it; in-flight work completes.
//! * `elastic` — the last cell starts deactivated, scales up at 30%
//!   (diurnal peak) and back down at 80%.

use anyhow::{bail, Result};

use crate::relay::coordinator::{Completion, RelayCoordinator, ReqId};
use crate::relay::fault::CrashSpec;
use crate::relay::flight::FlightRecorder;
use crate::relay::pipeline::CacheOutcome;
use crate::relay::router::hash_key;
use crate::relay::trigger::Estimator;

/// Half-life of the picker's exponentially-decayed per-cell arrival
/// load (µs).  One second: long enough to smooth a microbatch window,
/// short enough to track a diurnal ramp.
pub const LOAD_HALF_LIFE_US: u64 = 1_000_000;

/// Salt namespace for the per-cell rendezvous hashes.
const CELL_SALT: u64 = 0xCE11_5A17;

/// Level-1 routing policy: how a request picks its cell.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CellPickerKind {
    /// Cache-locality-aware: rendezvous-hash the *user* so producer and
    /// consumer meet in one cell, spilling off an overloaded home.
    Affinity,
    /// Load-uniform strawman: rendezvous-hash the *request id* — every
    /// cell sees every user, so cross-cell ψ misses are the norm.
    Spread,
}

impl CellPickerKind {
    pub fn parse(s: &str) -> Result<CellPickerKind> {
        match s {
            "affinity" => Ok(CellPickerKind::Affinity),
            "spread" => Ok(CellPickerKind::Spread),
            other => bail!("unknown cell picker {other:?} (expected affinity|spread)"),
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            CellPickerKind::Affinity => "affinity",
            CellPickerKind::Spread => "spread",
        }
    }
}

/// Built-in cluster-churn scripts (fractions of the run duration).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CellScenario {
    None,
    Failure,
    Drain,
    Elastic,
    /// Staggered model push: each cell rotates its model/segment key
    /// space at its own scripted time, never all cells at once.
    Rollout,
}

impl CellScenario {
    pub const NAMES: [&'static str; 5] = ["none", "failure", "drain", "elastic", "rollout"];

    pub fn parse(s: &str) -> Result<CellScenario> {
        match s {
            "none" => Ok(CellScenario::None),
            "failure" => Ok(CellScenario::Failure),
            "drain" => Ok(CellScenario::Drain),
            "elastic" => Ok(CellScenario::Elastic),
            "rollout" => Ok(CellScenario::Rollout),
            other => bail!(
                "unknown cell scenario {other:?} (expected none|failure|drain|elastic|rollout)"
            ),
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            CellScenario::None => "none",
            CellScenario::Failure => "failure",
            CellScenario::Drain => "drain",
            CellScenario::Elastic => "elastic",
            CellScenario::Rollout => "rollout",
        }
    }

    /// Compile the script against a concrete cell count and duration.
    /// `fail_instance` is the instance the failure scenario kills (the
    /// first special of cell 0, resolved by [`CellSet::new`]).
    fn events(self, cells: usize, duration_us: u64, fail_instance: usize) -> Vec<CellEvent> {
        let at = |frac_pct: u64| duration_us / 100 * frac_pct;
        match self {
            CellScenario::None => Vec::new(),
            CellScenario::Failure => vec![CellEvent {
                at_us: at(40),
                action: CellAction::FailInstance { cell: 0, instance: fail_instance },
            }],
            CellScenario::Drain => {
                let target = if cells > 1 { 1 } else { 0 };
                vec![
                    CellEvent { at_us: at(30), action: CellAction::Drain(target) },
                    CellEvent { at_us: at(70), action: CellAction::Undrain(target) },
                ]
            }
            CellScenario::Elastic => {
                if cells < 2 {
                    return Vec::new(); // nothing to scale
                }
                let last = cells - 1;
                vec![
                    CellEvent { at_us: 0, action: CellAction::Deactivate(last) },
                    CellEvent { at_us: at(30), action: CellAction::Activate(last) },
                    CellEvent { at_us: at(80), action: CellAction::Deactivate(last) },
                ]
            }
            CellScenario::Rollout => {
                // Cell c pushes version 1 at 30% + c·(40%/cells): the
                // invalidation wave is always cell-local, never a
                // cluster-wide storm.
                (0..cells)
                    .map(|c| CellEvent {
                        at_us: at(30 + 40 * c as u64 / cells as u64),
                        action: CellAction::SetModelVersion { cell: c, version: 1 },
                    })
                    .collect()
            }
        }
    }
}

/// One scripted churn step, applied on the arrival path at `at_us`
/// (engine-shared arrival clock ⇒ engine-identical application point).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CellEvent {
    pub at_us: u64,
    pub action: CellAction,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CellAction {
    /// An instance inside a cell dies: its settled ψ lineages are lost.
    FailInstance { cell: usize, instance: usize },
    /// Stop routing new work to a cell; in-flight work completes.
    Drain(usize),
    Undrain(usize),
    /// Elasticity: remove / return a whole cell's capacity.
    Deactivate(usize),
    Activate(usize),
    /// Staggered model push: rotate one cell's model/segment version.
    SetModelVersion { cell: usize, version: u16 },
    /// Scheduled fault-plane crash (`--faults crash@P%[:cellK]`): kills
    /// like [`CellAction::FailInstance`] and counts into the cell's
    /// [`crate::relay::fault::FaultReport`].
    Crash { cell: usize, instance: usize },
}

/// Cluster-shape configuration for a [`CellSet`].
#[derive(Debug, Clone)]
pub struct CellConfig {
    /// Number of cells (1–64; 1 reproduces the pre-cell coordinator).
    pub cells: usize,
    pub picker: CellPickerKind,
    /// Locality-vs-load knob for the affinity picker: spill off the
    /// home cell when its decayed load exceeds `spill_ratio ×` the mean
    /// eligible load.  `f64::INFINITY` = never spill (pure locality).
    pub spill_ratio: f64,
    pub scenario: CellScenario,
    /// Scheduled fault-plane crash (`--faults crash@P%[:cellK]`),
    /// compiled to scripted events at construction like the scenario.
    pub crash: Option<CrashSpec>,
}

impl Default for CellConfig {
    fn default() -> CellConfig {
        CellConfig {
            cells: 1,
            picker: CellPickerKind::Affinity,
            spill_ratio: 2.0,
            scenario: CellScenario::None,
            crash: None,
        }
    }
}

/// A request handle scoped to the cell that owns its coordinator slot.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CellReq {
    pub cell: usize,
    pub id: ReqId,
}

/// Per-cell picker counters (see [`CellReport`] for the merged view).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CellStats {
    /// Arrivals this cell served.
    pub picks: u64,
    /// Arrivals whose all-cells rendezvous home was this cell.
    pub home_picks: u64,
    /// Picks that landed here via the affinity load-spill override.
    pub spilled: u64,
    /// Picks served here for a user homed elsewhere (locality lost).
    pub cross_routes: u64,
    /// Cross-routed *long* requests that paid for it — the ψ produced
    /// in the user's home cell was unreachable, so ranking ran
    /// `FullInference` / `Fallback` / `Shed` here.
    pub cross_psi_miss: u64,
    /// ψ host copies this cell shipped out when it drained, landed in
    /// their users' rendezvous-overflow cells.
    pub migrated: u64,
    /// Drain-time copies that found no landing spot (no eligible cell,
    /// no special route, or the target tier rejected them).
    pub migration_lost: u64,
}

/// One row of the `cells` metrics report: picker counters plus the
/// cell coordinator's failure-plane counters.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CellReport {
    pub picks: u64,
    pub home_picks: u64,
    pub spilled: u64,
    pub cross_routes: u64,
    pub cross_psi_miss: u64,
    /// Instances failed in this cell (scenario injection).
    pub failures: u64,
    /// Settled ψ lineages wiped by failure enforcement (reload storm).
    pub storm_invalidations: u64,
    /// ψ host copies shipped out of this cell by a drain.
    pub migrated: u64,
    /// Drain-time copies lost in migration.
    pub migration_lost: u64,
}

struct Pick {
    cell: usize,
    /// All-cells rendezvous home (churn-independent).
    home: usize,
    /// The affinity picker overrode an overloaded home.
    spilled: bool,
}

/// N coordinator shards behind one two-level router (module doc).
pub struct CellSet<T> {
    cfg: CellConfig,
    cells: Vec<RelayCoordinator<T>>,
    /// Per-cell rendezvous salts (pure function of the cell index).
    salts: Vec<u64>,
    /// Elasticity / drain eligibility bitmasks (cells ≤ 64).
    active: u64,
    drained: u64,
    /// Exponentially-decayed arrival counts, last decayed at `load_at`.
    loads: Vec<f64>,
    load_at: u64,
    /// Scenario script, sorted by `at_us`; `next_event` is the cursor.
    events: Vec<CellEvent>,
    next_event: usize,
    stats: Vec<CellStats>,
    /// Cross-route flag per live coordinator slot, per cell (slots are
    /// recycled, so these stay bounded by live concurrency).
    cross: Vec<Vec<bool>>,
    /// Dynamically promoted specials, insertion-ordered `(cell,
    /// instance)`.  Cell-scoped on purpose: instance indices repeat
    /// across cells, so a per-instance ledger would conflate them.
    promoted: Vec<(usize, usize)>,
}

fn all_mask(n: usize) -> u64 {
    if n >= 64 {
        !0
    } else {
        (1u64 << n) - 1
    }
}

impl<T: Clone + Default> CellSet<T> {
    /// Wrap per-cell coordinators (built by the engine, one config
    /// each) into a cluster.  `duration_us` anchors the scenario
    /// script's event times.
    pub fn new(
        cfg: CellConfig,
        cells: Vec<RelayCoordinator<T>>,
        duration_us: u64,
    ) -> Result<CellSet<T>> {
        if cfg.cells == 0 || cfg.cells > 64 {
            bail!("cells: need 1..=64 cells (got {})", cfg.cells);
        }
        if cells.len() != cfg.cells {
            bail!("cells: {} coordinators for --cells {}", cells.len(), cfg.cells);
        }
        if !(cfg.spill_ratio > 0.0) {
            bail!("cells: --cell-spill must be > 0 (got {})", cfg.spill_ratio);
        }
        let fail_instance = cells[0].special_instances().first().copied().unwrap_or(0);
        let mut events = cfg.scenario.events(cfg.cells, duration_us, fail_instance);
        // Compile the fault plane's scheduled crash to scripted events:
        // a percentage of the run's arrival clock, so both engines kill
        // at the identical decision point.  A zero duration (the live
        // engine's open-ended runs) compiles no events.
        if let Some(c) = cfg.crash {
            if let Some(target) = c.cell {
                if target >= cfg.cells {
                    bail!("faults: crash cell {target} out of range (--cells {})", cfg.cells);
                }
            }
            if duration_us > 0 {
                let at_us = duration_us / 100 * c.pct as u64;
                for (cell, coord) in cells.iter().enumerate() {
                    if c.cell.is_some_and(|t| t != cell) {
                        continue;
                    }
                    let instance = coord.special_instances().first().copied().unwrap_or(0);
                    events.push(CellEvent { at_us, action: CellAction::Crash { cell, instance } });
                }
            }
        }
        events.sort_by_key(|e| e.at_us);
        let n = cfg.cells;
        Ok(CellSet {
            cells,
            salts: (0..n as u64).map(|c| hash_key(c, CELL_SALT)).collect(),
            active: all_mask(n),
            drained: 0,
            loads: vec![0.0; n],
            load_at: 0,
            events,
            next_event: 0,
            stats: vec![CellStats::default(); n],
            cross: vec![Vec::new(); n],
            promoted: Vec::new(),
            cfg,
        })
    }

    // ---- introspection -----------------------------------------------------

    pub fn n_cells(&self) -> usize {
        self.cells.len()
    }

    pub fn config(&self) -> &CellConfig {
        &self.cfg
    }

    pub fn coord(&self, cell: usize) -> &RelayCoordinator<T> {
        &self.cells[cell]
    }

    pub fn coord_mut(&mut self, cell: usize) -> &mut RelayCoordinator<T> {
        &mut self.cells[cell]
    }

    pub fn is_drained(&self, cell: usize) -> bool {
        self.drained >> cell & 1 == 1
    }

    pub fn is_active(&self, cell: usize) -> bool {
        self.active >> cell & 1 == 1
    }

    pub fn cell_stats(&self) -> &[CellStats] {
        &self.stats
    }

    /// Per-cell report rows: picker counters + failure-plane counters.
    pub fn reports(&self) -> Vec<CellReport> {
        self.stats
            .iter()
            .zip(&self.cells)
            .map(|(s, c)| {
                let f = c.fail_stats();
                CellReport {
                    picks: s.picks,
                    home_picks: s.home_picks,
                    spilled: s.spilled,
                    cross_routes: s.cross_routes,
                    cross_psi_miss: s.cross_psi_miss,
                    failures: f.failures,
                    storm_invalidations: f.storm_invalidations,
                    migrated: s.migrated,
                    migration_lost: s.migration_lost,
                }
            })
            .collect()
    }

    /// `(cross-cell routes, cross-cell ψ misses)` summed over cells.
    pub fn cross_totals(&self) -> (u64, u64) {
        self.stats
            .iter()
            .fold((0, 0), |(r, m), s| (r + s.cross_routes, m + s.cross_psi_miss))
    }

    /// Insertion-ordered promoted-special ledger (tests / drain audit).
    pub fn promoted_ledger(&self) -> &[(usize, usize)] {
        &self.promoted
    }

    // ---- churn API ---------------------------------------------------------

    /// Kill an instance inside a cell (see
    /// [`RelayCoordinator::fail_instance`] for the lazy-wipe contract).
    pub fn fail_instance(&mut self, at_us: u64, cell: usize, instance: usize) {
        self.cells[cell].fail_instance(at_us, instance);
    }

    /// Drain a cell: no new picks land on it (in-flight work completes)
    /// and every special *this layer* promoted into it is demoted in
    /// promotion order.  Cell-scoped ledger removal on purpose — a
    /// naive per-instance `retain` would also strip same-numbered
    /// instances promoted in *other* cells, orphaning their ledger
    /// entries (pinned by `drain_demotes_only_its_own_cells_specials`).
    pub fn drain_cell(&mut self, cell: usize) {
        self.drained |= 1 << cell;
        let mut i = 0;
        while i < self.promoted.len() {
            if self.promoted[i].0 == cell {
                let (_, inst) = self.promoted.remove(i);
                self.cells[cell].demote_special(inst);
            } else {
                i += 1;
            }
        }
        // ψ migration: ship the drained cell's settled host copies to
        // each user's rendezvous-overflow cell — exactly where the
        // picker sends the user's post-drain traffic, so reloads keep
        // hitting.  The manifest order (instance index, then user id)
        // and the rendezvous target are pure functions of decision
        // state, so both engines migrate identically.  With no other
        // eligible cell (single cell, or everything drained) the copies
        // stay put: traffic falls back onto this cell anyway.
        let eligible = self.active & !self.drained;
        if eligible != 0 {
            for (user, bytes, payload) in self.cells[cell].drain_dram() {
                let target = Self::rendezvous(user, &self.salts, eligible);
                if self.cells[target].adopt_psi(user, bytes, payload) {
                    self.stats[cell].migrated += 1;
                } else {
                    self.stats[cell].migration_lost += 1;
                }
            }
        }
    }

    pub fn undrain_cell(&mut self, cell: usize) {
        self.drained &= !(1 << cell);
    }

    /// Elasticity: remove / return a whole cell's capacity.
    pub fn deactivate_cell(&mut self, cell: usize) {
        self.active &= !(1 << cell);
    }

    pub fn activate_cell(&mut self, cell: usize) {
        self.active |= 1 << cell;
    }

    /// Promote an instance into a cell's special pool, recording it in
    /// the drain ledger.  Mirrors
    /// [`RelayCoordinator::promote_special`]'s density-cap semantics.
    pub fn promote_special(&mut self, cell: usize, instance: usize, est: Estimator) -> bool {
        if !self.cells[cell].promote_special(instance, est) {
            return false;
        }
        if !self.promoted.contains(&(cell, instance)) {
            self.promoted.push((cell, instance));
        }
        true
    }

    /// Demote an instance from a cell's special pool; removes exactly
    /// this cell's ledger entry.
    pub fn demote_special(&mut self, cell: usize, instance: usize) -> bool {
        if let Some(pos) = self.promoted.iter().position(|&e| e == (cell, instance)) {
            self.promoted.remove(pos);
        }
        self.cells[cell].demote_special(instance)
    }

    // ---- routing -----------------------------------------------------------

    fn apply_events(&mut self, now: u64) {
        while self.next_event < self.events.len() && self.events[self.next_event].at_us <= now {
            let ev = self.events[self.next_event];
            self.next_event += 1;
            match ev.action {
                CellAction::FailInstance { cell, instance } => {
                    // Anchored at the scripted time, not the arrival
                    // that noticed it: enforcement compares lineage
                    // stamps against the failure epoch.
                    self.cells[cell].fail_instance(ev.at_us, instance);
                }
                CellAction::Drain(c) => self.drain_cell(c),
                CellAction::Undrain(c) => self.undrain_cell(c),
                CellAction::Deactivate(c) => self.deactivate_cell(c),
                CellAction::Activate(c) => self.activate_cell(c),
                CellAction::SetModelVersion { cell, version } => {
                    self.cells[cell].set_model_version(version);
                }
                CellAction::Crash { cell, instance } => {
                    self.cells[cell].note_crash_injected();
                    self.cells[cell].fail_instance(ev.at_us, instance);
                }
            }
        }
    }

    /// Rendezvous (highest-random-weight) pick over the masked cells;
    /// ties (never in practice) break to the lowest index.
    fn rendezvous(key: u64, salts: &[u64], mask: u64) -> usize {
        let mut best = 0usize;
        let mut best_h = 0u64;
        let mut seen = false;
        for (c, &salt) in salts.iter().enumerate() {
            if mask >> c & 1 == 0 {
                continue;
            }
            let h = hash_key(key, salt);
            if !seen || h > best_h {
                best = c;
                best_h = h;
                seen = true;
            }
        }
        debug_assert!(seen, "rendezvous over empty mask");
        best
    }

    fn pick(&mut self, now: u64, user: u64, rid: u64) -> Pick {
        let n = self.cells.len();
        if n == 1 {
            // Structural identity with the pre-cell coordinator: no
            // picker state is read or written.
            return Pick { cell: 0, home: 0, spilled: false };
        }
        let dt = now.saturating_sub(self.load_at);
        if dt > 0 {
            let decay = 0.5f64.powf(dt as f64 / LOAD_HALF_LIFE_US as f64);
            for l in &mut self.loads {
                *l *= decay;
            }
            self.load_at = now;
        }
        let home = Self::rendezvous(user, &self.salts, all_mask(n));
        let mut eligible = self.active & !self.drained;
        if eligible == 0 {
            // A fully drained/deactivated cluster still serves: fall
            // back to every cell rather than dropping traffic.
            eligible = all_mask(n);
        }
        let (cell, spilled) = match self.cfg.picker {
            CellPickerKind::Spread => (Self::rendezvous(rid, &self.salts, eligible), false),
            CellPickerKind::Affinity => {
                let target = Self::rendezvous(user, &self.salts, eligible);
                let mut spill = None;
                if self.cfg.spill_ratio.is_finite() {
                    let mut sum = 0.0;
                    let mut cnt = 0u32;
                    for c in 0..n {
                        if eligible >> c & 1 == 1 {
                            sum += self.loads[c];
                            cnt += 1;
                        }
                    }
                    if self.loads[target] > self.cfg.spill_ratio * (sum / cnt as f64) {
                        let mut best = target;
                        let mut best_l = f64::INFINITY;
                        for c in 0..n {
                            if eligible >> c & 1 == 1 && self.loads[c] < best_l {
                                best_l = self.loads[c];
                                best = c;
                            }
                        }
                        if best != target {
                            spill = Some(best);
                        }
                    }
                }
                match spill {
                    Some(c) => (c, true),
                    None => (target, false),
                }
            }
        };
        self.loads[cell] += 1.0;
        Pick { cell, home, spilled }
    }

    // ---- event API (the wrapped subset) ------------------------------------

    /// Level-1 route + delegate to the chosen cell's coordinator.
    /// Every later event goes straight to `coord_mut(req.cell)` with
    /// `req.id` — only arrival and completion need the cell layer.
    pub fn on_arrival(
        &mut self,
        now: u64,
        rid: u64,
        user: u64,
        prefix_len: usize,
        candidates: &[u64],
    ) -> (CellReq, bool) {
        self.apply_events(now);
        let pick = self.pick(now, user, rid);
        let (id, relay) = self.cells[pick.cell].on_arrival(now, rid, user, prefix_len, candidates);
        if self.cells.len() > 1 {
            let cross = pick.cell != pick.home;
            self.cells[pick.cell].note_cell_routed(now, id, pick.cell, pick.home, cross);
            let s = &mut self.stats[pick.cell];
            s.picks += 1;
            if pick.spilled {
                s.spilled += 1;
            }
            if cross {
                s.cross_routes += 1;
            }
            self.stats[pick.home].home_picks += 1;
            let flags = &mut self.cross[pick.cell];
            let slot = id.index();
            if slot >= flags.len() {
                flags.resize(slot + 1, false);
            }
            flags[slot] = cross;
        }
        (CellReq { cell: pick.cell, id }, relay)
    }

    /// Completion wrapper: counts the cross-cell ψ miss — a long
    /// request served away from its home cell whose ranking ran
    /// without a usable ψ (`FullInference` / `Fallback`).
    pub fn on_rank_done(&mut self, now: u64, req: CellReq, kv_bytes: usize) -> Completion {
        let done = self.cells[req.cell].on_rank_done(now, req.id, kv_bytes);
        if self.cells.len() > 1 {
            let slot = req.id.index();
            let flags = &mut self.cross[req.cell];
            let cross = slot < flags.len() && std::mem::replace(&mut flags[slot], false);
            if cross
                && done.is_long
                && matches!(
                    done.outcome,
                    CacheOutcome::FullInference | CacheOutcome::Fallback | CacheOutcome::Shed
                )
            {
                self.stats[req.cell].cross_psi_miss += 1;
            }
        }
        done
    }

    /// Detach and merge the per-cell flight recorders.  Single-cell
    /// clusters hand back cell 0's recorder untouched (span-identical
    /// to the pre-cell coordinator); multi-cell clusters re-emit every
    /// cell's spans into one recorder in cell-index order.
    pub fn take_flight(&mut self) -> Option<FlightRecorder> {
        if self.cells.len() == 1 {
            return self.cells[0].take_flight();
        }
        let cap = self.cells[0].config().trace_spans;
        if cap == 0 {
            return None;
        }
        let mut merged = FlightRecorder::new(cap.saturating_mul(self.cells.len()));
        for cell in &mut self.cells {
            if let Some(fl) = cell.take_flight() {
                merged.absorb(&fl);
            }
        }
        Some(merged)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::relay::baseline::Mode;
    use crate::relay::coordinator::CoordinatorConfig;
    use crate::relay::fault::{FaultConfig, FaultKind};
    use crate::relay::router::{BalancePolicy, RouterConfig};
    use crate::relay::segment::SegmentConfig;
    use crate::relay::tier::{DramPolicy, EvictPolicy, TierConfig};
    use crate::relay::trigger::{BehaviorMeta, TriggerConfig};

    fn coord_config(trace_spans: usize) -> CoordinatorConfig {
        CoordinatorConfig {
            mode: Mode::RelayGr { dram: DramPolicy::Disabled },
            router: RouterConfig {
                n_instances: 4,
                servers: 2,
                r2: 0.5,
                max_special_per_server: 1,
                gateways: 2,
                vnodes: 16,
                normal_policy: BalancePolicy::LeastConnections,
            },
            trigger: TriggerConfig::paper_example(),
            tiers: vec![TierConfig::new(1 << 30, EvictPolicy::Lru)],
            long_threshold: 2048,
            t_life_us: 300_000,
            max_reload_concurrency: 2,
            hbm_bytes: 1 << 30,
            dim: 256,
            kv_bytes: Box::new(|_| 32 << 20),
            segment: SegmentConfig::disabled(),
            batch_window_us: 0,
            batch_max: 32,
            trace_spans,
            faults: FaultConfig::default(),
        }
    }

    fn cell_set(cfg: CellConfig, duration_us: u64) -> CellSet<u32> {
        cell_set_traced(cfg, duration_us, 0)
    }

    fn cell_set_traced(cfg: CellConfig, duration_us: u64, spans: usize) -> CellSet<u32> {
        let coords = (0..cfg.cells)
            .map(|_| {
                RelayCoordinator::new(coord_config(spans), |_| {
                    Box::new(|_: &BehaviorMeta| 1e9)
                })
                .unwrap()
            })
            .collect();
        CellSet::new(cfg, coords, duration_us).unwrap()
    }

    /// Route an arrival and immediately retire it (picker-level tests
    /// don't need the rank pipeline).
    fn route_one(set: &mut CellSet<u32>, now: u64, rid: u64, user: u64) -> usize {
        let (req, _) = set.on_arrival(now, rid, user, 1024, &[]);
        set.coord_mut(req.cell).on_stage_done(now, req.id, crate::relay::Stage::Retrieval);
        set.coord_mut(req.cell).on_stage_done(now, req.id, crate::relay::Stage::Preproc);
        let _ = set.coord_mut(req.cell).on_rank_start(now, req.id);
        let _ = set.coord_mut(req.cell).rank_compute(now, req.id);
        set.on_rank_done(now, req, 32 << 20);
        req.cell
    }

    #[test]
    fn single_cell_short_circuits_all_picker_state() {
        let mut set = cell_set(CellConfig::default(), 1_000_000);
        for i in 0..32u64 {
            let cell = route_one(&mut set, i * 1000, i, i % 5);
            assert_eq!(cell, 0);
        }
        // No picker state was touched: stats stay zero and the load
        // clock never advanced — the structural PR-8 identity.
        assert_eq!(set.cell_stats()[0], CellStats::default());
        assert_eq!(set.load_at, 0);
        assert_eq!(set.cross_totals(), (0, 0));
    }

    #[test]
    fn affinity_is_user_stable_and_covers_cells() {
        // Pure locality (spill off): the pick must be a function of the
        // user alone while eligibility is stable.
        let cfg = CellConfig { cells: 4, spill_ratio: f64::INFINITY, ..CellConfig::default() };
        let mut set = cell_set(cfg, 10_000_000);
        let mut homes = std::collections::HashMap::new();
        let mut seen = [false; 4];
        for i in 0..400u64 {
            let user = i % 100;
            // Arrivals spread out so the load spill never engages.
            let cell = route_one(&mut set, i * 100_000, i, user);
            seen[cell] = true;
            // A user's cell never changes while eligibility is stable.
            assert_eq!(*homes.entry(user).or_insert(cell), cell, "user {user}");
        }
        assert!(seen.iter().filter(|&&s| s).count() >= 3, "rendezvous covers cells: {seen:?}");
        let total: u64 = set.cell_stats().iter().map(|s| s.picks).sum();
        assert_eq!(total, 400);
        // Stable eligibility ⇒ the eligible rendezvous IS the all-cells
        // home: nothing cross-routes.
        assert_eq!(set.cross_totals(), (0, 0));
    }

    /// The determinism property the cross-engine contract rests on: the
    /// pick is a pure function of the arrival sequence — two identical
    /// cell sets fed the same `(now, rid, user)` stream make identical
    /// choices, under every picker and scenario.  (Nothing here may
    /// ever read `ShardedMap` iteration order; the picker state is
    /// plain index-ordered vectors and bitmasks.)
    #[test]
    fn picker_is_deterministic() {
        for picker in [CellPickerKind::Affinity, CellPickerKind::Spread] {
            for scenario in [
                CellScenario::None,
                CellScenario::Failure,
                CellScenario::Drain,
                CellScenario::Elastic,
                CellScenario::Rollout,
            ] {
                let cfg = CellConfig { cells: 4, picker, spill_ratio: 1.2, scenario };
                let duration = 2_000_000;
                let mut a = cell_set(cfg.clone(), duration);
                let mut b = cell_set(cfg, duration);
                for i in 0..600u64 {
                    // Bursty arrivals (10 per tick) so the load spill
                    // path engages too.
                    let now = i / 10 * 33_000;
                    let user = hash_key(i, 17) % 50;
                    let ca = route_one(&mut a, now, i, user);
                    let cb = route_one(&mut b, now, i, user);
                    assert_eq!(ca, cb, "{picker:?}/{scenario:?} diverged at arrival {i}");
                }
            }
        }
    }

    #[test]
    fn drain_diverts_and_undrain_restores() {
        let cfg = CellConfig { cells: 2, scenario: CellScenario::Drain, ..CellConfig::default() };
        let duration = 1_000_000;
        let mut set = cell_set(cfg, duration);
        // Find a user homed on cell 1 (the drain target).
        let user = (0..64)
            .find(|&u| {
                CellSet::<u32>::rendezvous(u, &set.salts, 0b11) == 1
            })
            .expect("some user homes on cell 1");
        assert_eq!(route_one(&mut set, 0, 0, user), 1, "before the drain");
        // 30%..70% of the run: cell 1 is drained — the pick diverts to
        // cell 0 and counts as a cross-route.
        assert_eq!(route_one(&mut set, 400_000, 1, user), 0, "during the drain");
        assert!(set.is_drained(1));
        assert_eq!(set.cell_stats()[0].cross_routes, 1);
        // After 70%: restored.
        assert_eq!(route_one(&mut set, 800_000, 2, user), 1, "after the undrain");
        assert!(!set.is_drained(1));
    }

    #[test]
    fn elastic_scenario_toggles_last_cell() {
        let cfg = CellConfig { cells: 3, scenario: CellScenario::Elastic, ..CellConfig::default() };
        let mut set = cell_set(cfg, 1_000_000);
        route_one(&mut set, 1, 0, 1);
        assert!(!set.is_active(2), "scaled down from t=0");
        route_one(&mut set, 400_000, 1, 1);
        assert!(set.is_active(2), "scaled up at 30%");
        route_one(&mut set, 900_000, 2, 1);
        assert!(!set.is_active(2), "scaled back down at 80%");
    }

    #[test]
    fn failure_scenario_reaches_cell_zero_coordinator() {
        let cfg = CellConfig { cells: 2, scenario: CellScenario::Failure, ..CellConfig::default() };
        let mut set = cell_set(cfg, 1_000_000);
        route_one(&mut set, 0, 0, 1);
        assert_eq!(set.coord(0).fail_stats().failures, 0);
        route_one(&mut set, 500_000, 1, 1);
        assert_eq!(set.coord(0).fail_stats().failures, 1, "fired at 40%");
        assert_eq!(set.coord(1).fail_stats().failures, 0, "scoped to cell 0");
    }

    #[test]
    fn spread_picker_ignores_user_affinity() {
        let cfg = CellConfig { cells: 4, picker: CellPickerKind::Spread, ..CellConfig::default() };
        let mut set = cell_set(cfg, 10_000_000);
        let mut cells = std::collections::HashSet::new();
        for i in 0..64u64 {
            cells.insert(route_one(&mut set, i * 1000, i, 7)); // one hot user
        }
        assert!(cells.len() > 1, "one user spreads across cells");
        let (cross, _) = set.cross_totals();
        assert!(cross > 0, "spread loses locality by construction");
    }

    #[test]
    fn affinity_spills_off_overloaded_home_and_tracks_load() {
        let cfg = CellConfig { cells: 2, spill_ratio: 1.5, ..CellConfig::default() };
        let mut set = cell_set(cfg, 10_000_000);
        let home = CellSet::<u32>::rendezvous(7, &set.salts, 0b11);
        // Hammer one user at one instant: its home's decayed load blows
        // past 1.5× the mean and picks spill to the idle cell.
        let mut spilled_to = None;
        for i in 0..32u64 {
            let cell = route_one(&mut set, 1000, i, 7);
            if cell != home {
                spilled_to = Some(cell);
            }
        }
        let other = 1 - home;
        assert_eq!(spilled_to, Some(other), "spill lands on the idle cell");
        assert!(set.cell_stats()[other].spilled > 0);
        assert!(set.cell_stats()[other].cross_routes > 0, "spill is a locality loss");
    }

    /// Satellite regression: draining a cell demotes only *its own*
    /// promoted specials.  A naive per-instance split of the ledger
    /// (`retain(|&(_, i)| i != inst)`) strips the same instance index
    /// promoted in other cells — this test fails on that bug.
    #[test]
    fn drain_demotes_only_its_own_cells_specials() {
        let mut set = cell_set(CellConfig { cells: 2, ..CellConfig::default() }, 1_000_000);
        // Instance 1 shares its index across both cells (specials sit
        // on instance 0 under the 4-inst/2-server/r2=0.5 fixture, so 1
        // is promotable in each cell).
        assert!(set.promote_special(0, 1, Box::new(|_: &BehaviorMeta| 1e9)));
        assert!(set.promote_special(1, 1, Box::new(|_: &BehaviorMeta| 1e9)));
        assert_eq!(set.promoted_ledger(), &[(0, 1), (1, 1)]);
        set.drain_cell(0);
        // Cell 0's promotion is gone; cell 1's survives in the ledger
        // AND on its router.
        assert_eq!(set.promoted_ledger(), &[(1, 1)]);
        assert!(!set.coord(0).special_instances().contains(&1), "cell 0 demoted");
        assert!(set.coord(1).special_instances().contains(&1), "cell 1 untouched");
        // Demoting cell 1's is cell-scoped too.
        assert!(set.demote_special(1, 1));
        assert!(set.promoted_ledger().is_empty());
    }

    /// Satellite: a staggered rollout bumps each cell's model version at
    /// its own scripted time — per-cell invalidation, never a
    /// cluster-wide storm.
    #[test]
    fn rollout_staggers_model_version_per_cell() {
        let cfg =
            CellConfig { cells: 2, scenario: CellScenario::Rollout, ..CellConfig::default() };
        let mut set = cell_set(cfg, 1_000_000);
        route_one(&mut set, 0, 0, 1);
        assert_eq!(set.coord(0).config().segment.version, 0);
        assert_eq!(set.coord(1).config().segment.version, 0);
        // Cell 0 pushes at 30%, cell 1 not until 50%: mid-rollout the
        // wave is strictly cell-local.
        route_one(&mut set, 400_000, 1, 1);
        assert_eq!(set.coord(0).config().segment.version, 1, "cell 0 pushed at 30%");
        assert_eq!(set.coord(1).config().segment.version, 0, "cell 1 still on v0");
        route_one(&mut set, 600_000, 2, 1);
        assert_eq!(set.coord(1).config().segment.version, 1, "cell 1 pushed at 50%");
    }

    /// Satellite: draining a cell ships its settled ψ host copies to the
    /// rendezvous-overflow cell — where the drained users' traffic goes
    /// next — and counts the moves.
    #[test]
    fn drain_migrates_psi_to_overflow_cell_and_counts() {
        let mut set = cell_set(CellConfig { cells: 2, ..CellConfig::default() }, 1_000_000);
        let src_inst = set.coord(1).special_instances()[0];
        assert!(
            set.coord_mut(1).complete_spill(0, src_inst, 7, 32 << 20, 42),
            "seed a settled DRAM copy in the cell about to drain"
        );
        set.drain_cell(1);
        assert_eq!(set.cell_stats()[1].migrated, 1);
        assert_eq!(set.cell_stats()[1].migration_lost, 0);
        assert_eq!(set.reports()[1].migrated, 1, "report row carries the counter");
        // The copy moved: gone from cell 1, resident in cell 0 at the
        // instance cell 0's affinity ring serves user 7 from.
        let n = set.coord(0).n_instances();
        assert!((0..n).all(|i| set.coord_mut(1).dram_payload(i, 7).is_none()));
        let found = (0..n).find_map(|i| set.coord_mut(0).dram_payload(i, 7));
        assert_eq!(found, Some((32 << 20, 42)));
        // Single-cell drains migrate nothing — the traffic has nowhere
        // else to go, so the copies stay put.
        let mut one = cell_set(CellConfig::default(), 1_000_000);
        let inst = one.coord(0).special_instances()[0];
        assert!(one.coord_mut(0).complete_spill(0, inst, 7, 32 << 20, 9));
        one.drain_cell(0);
        assert_eq!(one.cell_stats()[0].migrated, 0);
        assert_eq!(one.coord_mut(0).dram_payload(inst, 7), Some((32 << 20, 9)));
    }

    /// Fault-plane crash spec compiles to a scripted cell event: fires
    /// at the trace percentage, scoped to the target cell, counted in
    /// that cell's fault report.
    #[test]
    fn crash_spec_compiles_to_scoped_cell_event() {
        let cfg = CellConfig {
            cells: 2,
            crash: Some(CrashSpec { pct: 50, cell: Some(1) }),
            ..CellConfig::default()
        };
        let mut set = cell_set(cfg, 1_000_000);
        route_one(&mut set, 0, 0, 1);
        assert_eq!(set.coord(1).fail_stats().failures, 0, "not before 50%");
        route_one(&mut set, 600_000, 1, 1);
        assert_eq!(set.coord(1).fail_stats().failures, 1, "fired at 50%");
        assert_eq!(set.coord(1).fault_report().injected[FaultKind::Crash.index()], 1);
        assert_eq!(set.coord(0).fail_stats().failures, 0, "scoped to cell 1");
        assert_eq!(set.coord(0).fault_report().injected[FaultKind::Crash.index()], 0);
        // Out-of-range target is a config error, not a silent no-op.
        let mk = || {
            RelayCoordinator::<u32>::new(coord_config(0), |_| Box::new(|_: &BehaviorMeta| 1e9))
                .unwrap()
        };
        let bad = CellConfig {
            cells: 2,
            crash: Some(CrashSpec { pct: 50, cell: Some(5) }),
            ..CellConfig::default()
        };
        assert!(CellSet::new(bad, vec![mk(), mk()], 1_000_000).is_err());
    }

    #[test]
    fn take_flight_merges_cells_in_index_order() {
        let cfg = CellConfig { cells: 2, picker: CellPickerKind::Spread, ..CellConfig::default() };
        let mut set = cell_set_traced(cfg, 10_000_000, 256);
        let mut cells = std::collections::HashSet::new();
        for i in 0..16u64 {
            cells.insert(route_one(&mut set, i * 1000, i, 7));
        }
        assert_eq!(cells.len(), 2, "both cells served traffic");
        let fl = set.take_flight().expect("tracing was on");
        let spans = fl.spans_sorted();
        assert!(!spans.is_empty());
        let cell_spans = spans
            .iter()
            .filter(|s| matches!(s.kind, crate::relay::SpanKind::CellRouted | crate::relay::SpanKind::CellFailover))
            .count();
        assert_eq!(cell_spans, 16, "one cell-route span per arrival");
        // Single-cell sets hand the recorder through untouched — and
        // emit no cell spans at all.
        let mut one = cell_set_traced(CellConfig::default(), 10_000_000, 256);
        route_one(&mut one, 0, 0, 7);
        let fl1 = one.take_flight().expect("tracing was on");
        assert!(fl1
            .spans_sorted()
            .iter()
            .all(|s| !matches!(s.kind, crate::relay::SpanKind::CellRouted | crate::relay::SpanKind::CellFailover)));
    }

    #[test]
    fn config_validation() {
        let coords: Vec<RelayCoordinator<u32>> = Vec::new();
        assert!(CellSet::new(CellConfig { cells: 0, ..CellConfig::default() }, coords, 1).is_err());
        let mk = || {
            RelayCoordinator::<u32>::new(coord_config(0), |_| Box::new(|_: &BehaviorMeta| 1e9))
                .unwrap()
        };
        assert!(CellSet::new(CellConfig { cells: 2, ..CellConfig::default() }, vec![mk()], 1).is_err());
        let one = vec![mk()];
        assert!(
            CellSet::new(CellConfig { spill_ratio: 0.0, ..CellConfig::default() }, one, 1).is_err()
        );
        assert!(CellPickerKind::parse("affinity").is_ok());
        assert!(CellPickerKind::parse("spred").is_err());
        assert!(CellScenario::parse("elastic").is_ok());
        assert!(CellScenario::parse("chaos").is_err());
    }
}
