//! Sequence-aware trigger (§3.2): admit only *at-risk* requests for
//! prefix pre-inference, under bounded HBM footprint and bounded
//! pre-inference load.
//!
//! The trigger runs beside retrieval on lightweight behaviour metadata
//! (prefix length / feature dimension) — never the full sequence.  Its
//! admission budget implements the paper's Eqs. 1–3:
//!
//! ```text
//! (1)  L        = Q_admit · T_life              live caches per instance
//! (2)  L · kv_p99 ≤ r1 · HBM                    survivability
//! (3)  Q_admit ≤ Q_m · M ,  Q_max ≤ Q_m·M·r2·N  load bounds
//! ```
//!
//! Rate limiting uses a token bucket per special instance; the live-cache
//! footprint is tracked through feedback from the HBM cache (`release`).

use anyhow::Result;

use crate::util::cli::Args;

/// Lightweight per-request behaviour metadata the trigger inspects.
#[derive(Debug, Clone, Copy)]
pub struct BehaviorMeta {
    pub user: u64,
    /// Long-term behaviour prefix length in tokens.
    pub prefix_len: usize,
    /// Feature/embedding dimension.
    pub dim: usize,
}

/// Static admission-control parameters (the paper's symbols).
#[derive(Debug, Clone)]
pub struct TriggerConfig {
    /// Ranking-stage P99 budget (≈50 ms in the paper's pipeline).
    pub rank_p99_budget_us: f64,
    /// Risk margin: at-risk iff estimated full inference > headroom·budget.
    pub headroom: f64,
    /// T_life — request lifecycle window (retrieval+preproc+ranking tail).
    pub t_life_us: u64,
    /// kv_p99 — P99 per-user ψ footprint in bytes.
    pub kv_p99_bytes: usize,
    /// Device HBM capacity in bytes.
    pub hbm_bytes: usize,
    /// r1 — HBM fraction reserved for live caches.
    pub r1: f64,
    /// Q_m — sustainable pre-infer throughput per model slot (queries/s).
    pub q_m: f64,
    /// M — concurrent model slots per special instance.
    pub m_slots: usize,
    /// r2 — fraction of ranking instances designated special.
    pub r2: f64,
    /// N — total ranking instances.
    pub n_instances: usize,
}

impl TriggerConfig {
    /// The paper's §3.2 sanity-check configuration.
    pub fn paper_example() -> TriggerConfig {
        TriggerConfig {
            rank_p99_budget_us: 50_000.0,
            headroom: 0.8,
            t_life_us: 300_000,
            kv_p99_bytes: 100 * 1000 * 1000, // ~0.1 GB
            hbm_bytes: 32_000_000_000,
            r1: 0.5,
            q_m: 30.0,
            m_slots: 5,
            r2: 0.1,
            n_instances: 100,
        }
    }

    /// Derived admission limits (Eqs. 1–3).
    pub fn limits(&self) -> AdmissionLimits {
        let l_max = ((self.r1 * self.hbm_bytes as f64) / self.kv_p99_bytes as f64).floor() as usize;
        let q_life = l_max as f64 / (self.t_life_us as f64 / 1e6); // Eq. 1 inverted
        let q_compute = self.q_m * self.m_slots as f64; // Eq. 3, per instance
        let q_admit_max = q_life.min(q_compute);
        let specials = (self.r2 * self.n_instances as f64).round().max(1.0);
        AdmissionLimits { l_max, q_admit_max, q_max_system: q_compute * specials, specials: specials as usize }
    }
}

/// The derived bounds.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AdmissionLimits {
    /// Max simultaneously-live caches per special instance (Eq. 2).
    pub l_max: usize,
    /// Max admitted pre-infer rate per special instance, queries/s.
    pub q_admit_max: f64,
    /// System-wide admitted long-sequence traffic bound, queries/s (Eq. 3).
    pub q_max_system: f64,
    /// Number of special instances (r2·N).
    pub specials: usize,
}

/// Trigger decision for one request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Decision {
    /// Full inference comfortably fits the ranking budget — no side path.
    NotAtRisk,
    /// Admitted for prefix pre-inference.
    Admit,
    /// At risk, but the per-instance admitted rate is exhausted.
    RateLimited,
    /// At risk, but live caches would outgrow the r1·HBM slice.
    FootprintLimited,
}

/// Token bucket (rate per second over microsecond timestamps).
#[derive(Debug, Clone)]
pub struct TokenBucket {
    rate_per_us: f64,
    burst: f64,
    tokens: f64,
    last_us: u64,
}

impl TokenBucket {
    pub fn new(rate_per_s: f64, burst: f64) -> TokenBucket {
        TokenBucket { rate_per_us: rate_per_s / 1e6, burst, tokens: burst, last_us: 0 }
    }

    pub fn try_take(&mut self, now_us: u64) -> bool {
        let dt = now_us.saturating_sub(self.last_us) as f64;
        self.last_us = self.last_us.max(now_us);
        self.tokens = (self.tokens + dt * self.rate_per_us).min(self.burst);
        // Grant with a tiny epsilon so repeated fractional refills (e.g.
        // 10 × 0.1) are not lost to fp rounding just below 1.0.
        if self.tokens >= 1.0 - 1e-9 {
            self.tokens -= 1.0;
            true
        } else {
            false
        }
    }
}

/// Latency estimator used by the metadata risk test.  Deliberately a
/// boxed fn so the simulator wires in the hardware cost model and tests
/// wire in synthetic estimators.
pub type Estimator = Box<dyn Fn(&BehaviorMeta) -> f64 + Send>;

/// Per-special-instance trigger state.
pub struct Trigger {
    cfg: TriggerConfig,
    limits: AdmissionLimits,
    bucket: TokenBucket,
    /// Live caches currently attributed to this instance (feedback).
    live: usize,
    estimator: Estimator,
    stats: TriggerStats,
}

/// Counters exported to metrics.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct TriggerStats {
    pub assessed: u64,
    pub not_at_risk: u64,
    pub admitted: u64,
    pub rate_limited: u64,
    pub footprint_limited: u64,
}

impl TriggerStats {
    /// Accumulate another instance's counters (cluster-wide reporting).
    pub fn merge(&mut self, b: TriggerStats) {
        self.assessed += b.assessed;
        self.not_at_risk += b.not_at_risk;
        self.admitted += b.admitted;
        self.rate_limited += b.rate_limited;
        self.footprint_limited += b.footprint_limited;
    }
}

impl Trigger {
    pub fn new(cfg: TriggerConfig, estimator: Estimator) -> Trigger {
        let limits = cfg.limits();
        // Burst sized to the slot count: a short spike can fill the slots,
        // sustained rate is capped at q_admit_max.
        let burst = cfg.m_slots.max(1) as f64;
        Trigger {
            bucket: TokenBucket::new(limits.q_admit_max, burst),
            limits,
            cfg,
            live: 0,
            estimator,
            stats: TriggerStats::default(),
        }
    }

    pub fn limits(&self) -> AdmissionLimits {
        self.limits
    }

    pub fn config(&self) -> &TriggerConfig {
        &self.cfg
    }

    pub fn stats(&self) -> TriggerStats {
        self.stats
    }

    pub fn live(&self) -> usize {
        self.live
    }

    /// Metadata risk test + admission control.
    pub fn decide(&mut self, now_us: u64, meta: &BehaviorMeta) -> Decision {
        self.stats.assessed += 1;
        let est_full_us = (self.estimator)(meta);
        if est_full_us <= self.cfg.headroom * self.cfg.rank_p99_budget_us {
            self.stats.not_at_risk += 1;
            return Decision::NotAtRisk;
        }
        if self.live >= self.limits.l_max {
            self.stats.footprint_limited += 1;
            return Decision::FootprintLimited;
        }
        if !self.bucket.try_take(now_us) {
            self.stats.rate_limited += 1;
            return Decision::RateLimited;
        }
        self.live += 1;
        self.stats.admitted += 1;
        Decision::Admit
    }

    /// Feedback: a cache left the live set (consumed, expired or lost).
    pub fn release(&mut self) {
        self.live = self.live.saturating_sub(1);
    }

    /// Whether a request with this metadata is at risk (no admission).
    pub fn at_risk(&self, meta: &BehaviorMeta) -> bool {
        (self.estimator)(meta) > self.cfg.headroom * self.cfg.rank_p99_budget_us
    }
}

/// `relaygr plan` — print the derived Eqs. 1–3 limits, defaulting to the
/// paper's §3.2 sanity-check numbers.
pub fn plan_cli(args: &Args) -> Result<()> {
    let d = TriggerConfig::paper_example();
    let cfg = TriggerConfig {
        rank_p99_budget_us: args.get_f64("budget-ms", d.rank_p99_budget_us / 1e3)? * 1e3,
        headroom: args.get_f64("headroom", d.headroom)?,
        t_life_us: (args.get_f64("t-life-ms", d.t_life_us as f64 / 1e3)? * 1e3) as u64,
        kv_p99_bytes: (args.get_f64("kv-gb", d.kv_p99_bytes as f64 / 1e9)? * 1e9) as usize,
        hbm_bytes: (args.get_f64("hbm-gb", d.hbm_bytes as f64 / 1e9)? * 1e9) as usize,
        r1: args.get_f64("r1", d.r1)?,
        q_m: args.get_f64("qm", d.q_m)?,
        m_slots: args.get_usize("slots", d.m_slots)?,
        r2: args.get_f64("r2", d.r2)?,
        n_instances: args.get_usize("instances", d.n_instances)?,
    };
    let lim = cfg.limits();
    println!("sequence-aware trigger: admission plan (Eqs. 1-3)");
    println!("  HBM reserved for live caches (r1*HBM) : {:>10.1} GB", cfg.r1 * cfg.hbm_bytes as f64 / 1e9);
    println!("  kv_p99 per admitted user              : {:>10.3} GB", cfg.kv_p99_bytes as f64 / 1e9);
    println!("  L_max live caches / special instance  : {:>10}", lim.l_max);
    println!("  T_life lifecycle window               : {:>10.0} ms", cfg.t_life_us as f64 / 1e3);
    println!("  Q_admit cap (survivability, Eq.1-2)   : {:>10.1} q/s", lim.l_max as f64 / (cfg.t_life_us as f64 / 1e6));
    println!("  Q_admit cap (compute, Eq.3)           : {:>10.1} q/s", cfg.q_m * cfg.m_slots as f64);
    println!("  Q_admit effective per special instance: {:>10.1} q/s", lim.q_admit_max);
    println!("  special instances (r2*N)              : {:>10}", lim.specials);
    println!("  Q_max system-wide admitted traffic    : {:>10.1} q/s", lim.q_max_system);
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn meta(prefix_len: usize) -> BehaviorMeta {
        BehaviorMeta { user: 1, prefix_len, dim: 256 }
    }

    /// Estimator: 20 µs per token (2K tokens → 41 ms, at risk vs 40 ms line).
    fn linear_estimator() -> Estimator {
        Box::new(|m: &BehaviorMeta| m.prefix_len as f64 * 20.0)
    }

    #[test]
    fn paper_sanity_check_numbers() {
        // §3.2: kv=0.1GB, HBM=32GB, r1=0.5 → L ≤ 160; Qm=30, M=5 → 150 QPS;
        // N=100, r2=0.1 → pool cap 1500 QPS.
        let lim = TriggerConfig::paper_example().limits();
        assert_eq!(lim.l_max, 160);
        assert!((lim.q_admit_max - 150.0).abs() < 1e-9, "{}", lim.q_admit_max);
        assert_eq!(lim.specials, 10);
        assert!((lim.q_max_system - 1500.0).abs() < 1e-9);
    }

    #[test]
    fn survivability_binds_when_t_life_large() {
        // With a 2 s lifecycle, Eq. 1 gives 160/2 = 80 QPS < 150 QPS compute.
        let mut cfg = TriggerConfig::paper_example();
        cfg.t_life_us = 2_000_000;
        let lim = cfg.limits();
        assert!((lim.q_admit_max - 80.0).abs() < 1e-9, "{}", lim.q_admit_max);
    }

    #[test]
    fn short_sequences_not_at_risk() {
        let mut t = Trigger::new(TriggerConfig::paper_example(), linear_estimator());
        assert_eq!(t.decide(0, &meta(512)), Decision::NotAtRisk);
        assert_eq!(t.decide(0, &meta(4096)), Decision::Admit);
        let s = t.stats();
        assert_eq!((s.not_at_risk, s.admitted), (1, 1));
    }

    #[test]
    fn rate_limit_enforced_and_refills() {
        let mut cfg = TriggerConfig::paper_example();
        cfg.m_slots = 2; // burst 2, compute cap 60 q/s
        let mut t = Trigger::new(cfg, linear_estimator());
        assert_eq!(t.decide(0, &meta(4096)), Decision::Admit);
        assert_eq!(t.decide(0, &meta(4096)), Decision::Admit);
        t.release();
        t.release(); // footprint freed; rate still empty
        assert_eq!(t.decide(0, &meta(4096)), Decision::RateLimited);
        // 60 q/s → one token every ~16.7 ms.
        assert_eq!(t.decide(20_000, &meta(4096)), Decision::Admit);
    }

    #[test]
    fn footprint_limit_uses_feedback() {
        let mut cfg = TriggerConfig::paper_example();
        cfg.kv_p99_bytes = 8_000_000_000; // 8 GB → L_max = 2
        cfg.q_m = 1e9; // rate never binds
        let mut t = Trigger::new(cfg, linear_estimator());
        assert_eq!(t.limits().l_max, 2);
        assert_eq!(t.decide(0, &meta(4096)), Decision::Admit);
        assert_eq!(t.decide(0, &meta(4096)), Decision::Admit);
        assert_eq!(t.decide(0, &meta(4096)), Decision::FootprintLimited);
        t.release();
        assert_eq!(t.decide(1_000_000, &meta(4096)), Decision::Admit);
        assert_eq!(t.live(), 2);
    }

    #[test]
    fn token_bucket_sustained_rate() {
        let mut b = TokenBucket::new(100.0, 1.0); // 100/s, burst 1
        let mut granted = 0;
        for ms in 0..1000u64 {
            if b.try_take(ms * 1000) {
                granted += 1;
            }
        }
        assert!((95..=106).contains(&granted), "granted {granted}");
    }

    #[test]
    fn prop_admitted_never_exceeds_limits() {
        crate::util::prop::check("trigger-bounds", 100, |rng| {
            let mut cfg = TriggerConfig::paper_example();
            cfg.kv_p99_bytes = (1 + rng.range(0, 20)) * 1_000_000_000;
            cfg.q_m = rng.uniform(1.0, 50.0);
            cfg.m_slots = 1 + rng.range(0, 8);
            let limits = cfg.limits();
            let mut t = Trigger::new(cfg, Box::new(|_| 1e9)); // always at risk
            let mut now = 0u64;
            let mut admitted_in_window = 0u64;
            for _ in 0..300 {
                now += rng.range(0, 20_000) as u64;
                match t.decide(now, &meta(4096)) {
                    Decision::Admit => admitted_in_window += 1,
                    _ => {}
                }
                if t.live() > limits.l_max {
                    return Err(format!("live {} > L_max {}", t.live(), limits.l_max));
                }
                if rng.bernoulli(0.3) {
                    t.release();
                }
            }
            // Sustained admission ≤ q_admit_max * elapsed + burst slack.
            let cap = limits.q_admit_max * (now as f64 / 1e6) + t.config().m_slots as f64 + 1.0;
            if (admitted_in_window as f64) > cap {
                return Err(format!("admitted {admitted_in_window} > cap {cap:.1}"));
            }
            Ok(())
        });
    }
}
